"""Fleet-serving benchmark: bursty open-arrival traffic over a
mixed-criticality resident fleet (DESIGN.md §12).

Per device the bench installs a resident fleet — one RT "assist" model
plus tier-1 "train" and tier-0 "batch" best-effort background — and
then drives open Poisson-burst arrivals of short interactive "chat"
sessions (tier 2, highest criticality) at it: every session is a
distinct RT job priced by the admission RTA from the *measured*
per-slice profile of the synthetic workload, so arrivals past platform
capacity are refused, not over-promised.  Best-effort work rides under
a ``ShedPolicy`` with a tier-0 budget, so the bench also exercises the
multi-tier shedding ladder as the platform fills.

The workloads are synthetic (sleep-based slices) so the bench measures
the scheduling platform — admission, placement, per-tier stats,
shedding — not XLA.  Emits ``BENCH_fleet.json`` (marker
``fleet-bench-v1``) for the CI gate (benchmarks/check_regression.py):
the gate is structural (mixed fleet present, RT sessions admitted and
completing) because latency values on shared runners are trajectory
data, not comparable ceilings.

    PYTHONPATH=src python benchmarks/fleet_bench.py --quick
    PYTHONPATH=src python benchmarks/fleet_bench.py --quick --json \
        BENCH_fleet.json
"""
from __future__ import annotations

import argparse
import dataclasses
import json
import math
import random
import time
from typing import List

from repro.core.segments import SegmentedWorkload, SlicedOp
from repro.sched import JobProfile, connect
from repro.sched.elastic import ShedPolicy

MARKER = "fleet-bench-v1"


def _poisson(rng: random.Random, lam: float) -> int:
    limit = math.exp(-lam)
    k, p = 0, 1.0
    while True:
        p *= rng.random()
        if p <= limit:
            return k
        k += 1


def _sleep_workload(name: str, slices: int, slice_ms: float
                    ) -> SegmentedWorkload:
    """A synthetic model: one device segment of ``slices`` sleep-based
    slices — the platform sees real (wall-clock) slice durations without
    paying for XLA programs."""
    def op() -> SlicedOp:
        def step(carry, i):
            time.sleep(slice_ms / 1e3)
            return carry + 1

        return SlicedOp(slices, lambda: 0, step, lambda c: c, label=name)

    return SegmentedWorkload(name).device(op, label=name)


def run_fleet_bench(*, n_devices: int = 2, duration_s: float = 3.0,
                    lam: float = 2.0, burst_interval_s: float = 0.25,
                    seed: int = 7, session_iters: int = 3,
                    verbose: bool = True) -> dict:
    log = print if verbose else (lambda *a, **k: None)
    rng = random.Random(seed)
    t_start = time.perf_counter()

    # measured profiles for the synthetic fleet (one template per role)
    templates = {
        "assist": _sleep_workload("assist", slices=2, slice_ms=3.0),
        "train": _sleep_workload("train", slices=4, slice_ms=5.0),
        "batch": _sleep_workload("batch", slices=3, slice_ms=6.0),
        "chat": _sleep_workload("chat", slices=2, slice_ms=2.0),
    }
    profiles = {k: wl.profile(reps=2) for k, wl in templates.items()}
    max_slice = max(p.max_slice_ms for p in profiles.values())
    eps_ms = 1.0 + max_slice * 1.2

    shed = ShedPolicy(shed_at=0.9, resume_at=0.7, tier_budgets={0: 0.35})
    client = connect(n_devices=n_devices, policy="ioctl",
                     wait_mode="suspend", n_cpus=2, epsilon_ms=eps_ms,
                     shed_policy=shed)
    cluster = client.cluster
    submitted = admitted = 0
    session_jobs: List = []
    try:
        # resident fleet: best-effort background + one RT assist model
        # per device, running for the whole bench
        for d in range(n_devices):
            for role, tier, prio, period, be in (
                    ("batch", 0, 1, 800.0, True),
                    ("train", 1, 5, 600.0, True),
                    ("assist", 1, 40, 500.0, False)):
                jp = dataclasses.replace(
                    JobProfile.from_workload(
                        profiles[role], period_ms=period,
                        priority=prio + d,
                        best_effort=be, margin=1.5, device=d, tier=tier),
                    name=f"{role}{d}")
                res = client.submit(jp, workload=templates[role],
                                    n_iterations=10_000, start=True,
                                    stop_after_s=duration_s + 0.5)
                submitted += 1
                admitted += bool(res.accepted)
                if not res.accepted and not be:
                    raise SystemExit(f"resident RT model {jp.name} "
                                     f"refused: {res.reason}")

        # open Poisson-burst arrivals of interactive chat sessions:
        # each is its own RT job (admission may refuse past capacity —
        # that is the point), round-robin across devices
        k = 0
        t_end = time.perf_counter() + duration_s
        while time.perf_counter() < t_end:
            burst = _poisson(rng, lam)
            for _ in range(burst):
                d = k % n_devices
                jp = dataclasses.replace(
                    JobProfile.from_workload(
                        profiles["chat"], period_ms=250.0,
                        priority=60 + k, margin=1.5, device=d, tier=2),
                    name=f"chat{k}")
                res = client.submit(jp, workload=templates["chat"],
                                    n_iterations=session_iters,
                                    start=True)
                submitted += 1
                if res.accepted:
                    admitted += 1
                    session_jobs.append(res.job)
                k += 1
            time.sleep(burst_interval_s)
        log(f"arrivals done: {submitted} submitted, {admitted} admitted "
            f"({submitted - admitted} refused at capacity)")

        for job in session_jobs:
            job.join(30)
        client.join(duration_s + 60)

        stats = cluster.stats()
        report = {
            "marker": MARKER,
            "n_devices": n_devices,
            "duration_s": duration_s,
            "lam": lam,
            "seed": seed,
            "epsilon_ms": eps_ms,
            "admission": {"submitted": submitted, "admitted": admitted,
                          "rejected": submitted - admitted},
            "per_model": stats["per_model"],
            "per_tier": {str(t): row
                         for t, row in stats["per_tier"].items()},
            "shed": stats.get("shed"),
            "admission_latency": stats.get("admission_latency"),
            "wall_clock_s": round(time.perf_counter() - t_start, 2),
        }
    finally:
        client.close(shutdown=True)
    cluster.assert_migration_free()
    return report


def main() -> None:
    ap = argparse.ArgumentParser(
        description="mixed-criticality fleet bench (bursty arrivals)")
    ap.add_argument("--quick", action="store_true",
                    help="CI profile: ~3s of traffic")
    ap.add_argument("--n-devices", type=int, default=2)
    ap.add_argument("--duration", type=float, default=None)
    ap.add_argument("--lam", type=float, default=2.0,
                    help="mean Poisson burst size")
    ap.add_argument("--seed", type=int, default=7)
    ap.add_argument("--json", default=None, metavar="PATH")
    args = ap.parse_args()

    duration = args.duration if args.duration is not None else \
        (3.0 if args.quick else 10.0)
    report = run_fleet_bench(n_devices=args.n_devices,
                             duration_s=duration, lam=args.lam,
                             seed=args.seed)
    for tier in sorted(report["per_tier"], reverse=True):
        row = report["per_tier"][tier]
        p99 = (f"{row['p99_ms']:.1f}ms"
               if row.get("p99_ms") is not None else "-")
        print(f"tier {tier}: {len(row['jobs'])} models, completions "
              f"{row['completions']}, misses {row['deadline_misses']}, "
              f"p99 {p99}, util {row['utilization']:.3f} "
              f"(budget {row['budget']})")
    adm = report["admission"]
    print(f"admission: {adm['admitted']}/{adm['submitted']} admitted, "
          f"{adm['rejected']} refused; shed events: {report['shed']}")
    if args.json:
        with open(args.json, "w") as f:
            json.dump(report, f, indent=2, default=str)
        print(f"wrote {args.json}")


if __name__ == "__main__":
    main()
