"""Schedulability experiments (paper Figs. 7-12).

For each sweep point, N random tasksets (Table II parameters) are tested
under every approach; the acceptance ratio is reported.  Our approaches
follow the paper's evaluation pipeline (Sec. VII-A): improved analysis
(IOCTL) / baseline analysis (kthread), first with default RM priorities,
then retrying with Audsley GPU-segment priorities.  The corrected analysis
variants (see repro.core.analysis errata) are used throughout — they are
sound against the simulator; epsilon = 1 ms for our approaches, zero
overhead for prior work (as in the paper).

Run as a script for the full sweep with a parallel per-taskset fan-out:

    PYTHONPATH=src python benchmarks/schedulability.py --quick
    PYTHONPATH=src python benchmarks/schedulability.py --n 200 --workers 8
    PYTHONPATH=src python benchmarks/schedulability.py --n-devices 1 2 4

The third form runs the multi-device axis instead: heuristic vs
cross-device fixed-point acceptance under both busy-wait approaches
(DESIGN.md §4).  ``--json PATH`` dumps rows + wall-clock for the CI
benchmark-regression gate (benchmarks/check_regression.py).

Each taskset is an independent unit of work, so the sweep parallelizes
with ``multiprocessing`` (fork) across ``--workers`` processes; results
are bit-identical to the serial path (the per-taskset evaluation is
deterministic and seeds are assigned before the fan-out)."""
from __future__ import annotations

import functools
import os
import warnings
from typing import Callable, Dict, List, Optional

from repro.core import (GenParams, SoundnessWarning, fmlp_schedulable,
                        generate_taskset, ioctl_busy_improved_rta,
                        ioctl_busy_rta, ioctl_suspend_improved_rta,
                        kthread_busy_rta, mpcp_schedulable, schedulable)
from repro.core.audsley import assign_gpu_priorities


def _ours(rta) -> Callable:
    def test(ts) -> bool:
        if schedulable(ts, rta):
            return True
        return assign_gpu_priorities(ts, rta) is not None
    return test


def _heuristic(rta) -> Callable:
    """The pre-fixed-point constant-charge projection, for the heuristic
    vs fixed-point comparison on the --n-devices axis.  The escape hatch
    warns by design; the comparison is the one intended consumer.
    ``functools.wraps`` keeps the base RTA's signature visible so the
    early_exit / Audsley ``only=`` accelerations stay enabled for the
    heuristic arms (apples-to-apples sweep cost)."""
    @functools.wraps(rta)
    def wrapped(ts, **kw):
        kw.setdefault("method", "heuristic")
        with warnings.catch_warnings():
            warnings.simplefilter("ignore", SoundnessWarning)
            return rta(ts, **kw)
    return wrapped


METHODS: Dict[str, Callable] = {
    "kthread_busy": _ours(kthread_busy_rta),
    "ioctl_busy": _ours(ioctl_busy_improved_rta),
    "ioctl_suspend": _ours(ioctl_suspend_improved_rta),
    "mpcp": mpcp_schedulable,
    "fmlp+": fmlp_schedulable,
}

# heuristic vs joint-fixed-point acceptance on multi-device platforms
# (the heuristic is *unsound* under busy-waiting — tests/test_cross_
# soundness.py — so its higher acceptance is not a win; the axis shows
# the price of soundness)
DEVICE_METHODS: Dict[str, Callable] = {
    "kthread_busy_fixed": _ours(kthread_busy_rta),
    "kthread_busy_heur": _ours(_heuristic(kthread_busy_rta)),
    "ioctl_busy_fixed": _ours(ioctl_busy_rta),
    "ioctl_busy_heur": _ours(_heuristic(ioctl_busy_rta)),
}

METHOD_SETS: Dict[str, Dict[str, Callable]] = {
    "default": METHODS,
    "devices": DEVICE_METHODS,
}


def _eval_taskset(args) -> Dict[str, bool]:
    """One unit of parallel work: every method on one generated taskset."""
    seed, params, methods_key = args
    methods = METHOD_SETS[methods_key]
    ts = generate_taskset(seed, params)
    ts.kthread_cpu = ts.n_cpus  # dedicated scheduler core
    return {m: bool(fn(ts)) for m, fn in methods.items()}


def default_workers() -> int:
    env = os.environ.get("REPRO_SWEEP_WORKERS")
    if env:
        return max(int(env), 1)
    return os.cpu_count() or 1


def acceptance(params: GenParams, n: int, seed0: int = 0,
               workers: Optional[int] = None,
               methods_key: str = "default") -> Dict[str, float]:
    """Acceptance ratio per method over n tasksets.  ``workers`` > 1 fans
    the tasksets out over a process pool; None keeps the serial path
    (safe inside test processes that already hold accelerator runtimes).
    ``methods_key`` selects a METHOD_SETS entry (module-level so the
    forked workers resolve it by name — closures don't pickle)."""
    methods = METHOD_SETS[methods_key]
    jobs = [(seed0 + i, params, methods_key) for i in range(n)]
    if workers is not None and workers > 1:
        import multiprocessing as mp
        chunk = max(1, n // (workers * 4))
        with mp.Pool(workers) as pool:
            results = pool.map(_eval_taskset, jobs, chunksize=chunk)
    else:
        results = [_eval_taskset(j) for j in jobs]
    wins = {m: 0 for m in methods}
    for r in results:
        for m in methods:
            if r[m]:
                wins[m] += 1
    return {m: w / n for m, w in wins.items()}


def _sweep_seed(name: str) -> int:
    """Stable per-sweep base seed (the historical ``hash(name)`` changed
    with PYTHONHASHSEED, making sweep results irreproducible run-to-run)."""
    import zlib
    return zlib.crc32(name.encode()) % 10_000


def sweep(name: str, param_list: List[tuple], n: int,
          workers: Optional[int] = None,
          methods_key: str = "default") -> List[dict]:
    rows = []
    for label, params in param_list:
        row = {"sweep": name, "x": label,
               **acceptance(params, n, seed0=_sweep_seed(name),
                            workers=workers, methods_key=methods_key)}
        rows.append(row)
        print(f"  {name} x={label}: " + " ".join(
            f"{m}={row[m]:.2f}" for m in METHOD_SETS[methods_key]))
    return rows


# NOTE: our generator + corrected (sound) analyses sit ~0.1 utilization
# harder than the paper's dynamic range; the non-utilization sweeps pin
# util_per_cpu to (0.30, 0.40) to show the same acceptance dynamic range
# as the paper's figures (documented in EXPERIMENTS.md).
BAND = (0.30, 0.40)


def fig7_n_tasks(n: int, workers: Optional[int] = None) -> List[dict]:
    pts = [(k, GenParams(n_tasks_total=k, util_per_cpu=BAND))
           for k in (8, 12, 16, 20, 24)]
    return sweep("fig7_n_tasks", pts, n, workers)


def fig8_n_cpus(n: int, workers: Optional[int] = None) -> List[dict]:
    pts = [(c, GenParams(n_cpus=c, util_per_cpu=BAND))
           for c in (2, 4, 6, 8)]
    return sweep("fig8_n_cpus", pts, n, workers)


def fig9_util(n: int, workers: Optional[int] = None) -> List[dict]:
    pts = [(u, GenParams(util_per_cpu=(u - 0.05, u + 0.05)))
           for u in (0.25, 0.3, 0.35, 0.4, 0.45, 0.5)]
    return sweep("fig9_util", pts, n, workers)


def fig10_gpu_ratio(n: int, workers: Optional[int] = None) -> List[dict]:
    pts = [(r, GenParams(gpu_task_ratio=(r - 0.1, r + 0.1),
                         util_per_cpu=BAND))
           for r in (0.2, 0.4, 0.6, 0.8)]
    return sweep("fig10_gpu_ratio", pts, n, workers)


def fig11_g_to_c(n: int, workers: Optional[int] = None) -> List[dict]:
    pts = [(g, GenParams(g_to_c_ratio=(g * 0.5, g * 1.5),
                         util_per_cpu=BAND))
           for g in (0.2, 0.5, 1.0, 2.0, 4.0)]
    return sweep("fig11_g_to_c", pts, n, workers)


def fig12_best_effort(n: int, workers: Optional[int] = None) -> List[dict]:
    pts = [(r, GenParams(best_effort_ratio=r, util_per_cpu=(0.4, 0.5)))
           for r in (0.0, 0.2, 0.4, 0.6)]
    return sweep("fig12_best_effort", pts, n, workers)


def fig13_n_devices(n: int, workers: Optional[int] = None,
                    device_counts=(1, 2, 4)) -> List[dict]:
    """Multi-device axis: heuristic vs cross-device fixed-point acceptance
    under both busy-wait approaches (DESIGN.md §4).  On one device the
    two coincide; with more devices the (unsound) heuristic over-accepts
    and the gap is the cross-device busy-wait coupling it ignores."""
    pts = [(d, GenParams(n_devices=d, util_per_cpu=BAND))
           for d in device_counts]
    return sweep("fig13_n_devices", pts, n, workers, methods_key="devices")


ALL = [fig7_n_tasks, fig8_n_cpus, fig9_util, fig10_gpu_ratio, fig11_g_to_c,
       fig12_best_effort]


def run(n: int = 200, workers: Optional[int] = None) -> List[dict]:
    rows = []
    for fn in ALL:
        rows.extend(fn(n, workers))
    return rows


def main() -> None:
    import argparse
    import json
    import time

    ap = argparse.ArgumentParser(description=__doc__.split("\n")[0])
    ap.add_argument("--quick", action="store_true",
                    help="40 tasksets per sweep point (default 200)")
    ap.add_argument("--n", type=int, default=0,
                    help="tasksets per sweep point (overrides --quick)")
    ap.add_argument("--workers", type=int, default=0,
                    help="process-pool size (0 = all cores, 1 = serial)")
    ap.add_argument("--n-devices", type=int, nargs="+", default=None,
                    metavar="D",
                    help="run the multi-device axis over these device "
                         "counts (heuristic vs fixed-point acceptance) "
                         "instead of the paper sweeps")
    ap.add_argument("--json", default=None, metavar="PATH",
                    help="write rows + wall-clock to PATH (CI regression "
                         "gate reads this)")
    args = ap.parse_args()
    n = args.n or (40 if args.quick else 200)
    workers = args.workers or default_workers()
    t0 = time.time()
    if args.n_devices:
        rows = fig13_n_devices(n, workers=workers,
                               device_counts=tuple(args.n_devices))
    else:
        rows = run(n, workers=workers)
    dt = time.time() - t0
    print(f"schedulability sweep: {len(rows)} points x {n} tasksets, "
          f"{workers} workers, {dt:.1f}s wall-clock")
    if args.json:
        with open(args.json, "w") as f:
            json.dump({"rows": rows, "n": n, "workers": workers,
                       "wall_clock_s": round(dt, 3)}, f, indent=2)
        print(f"wrote {args.json}")


if __name__ == "__main__":
    main()
