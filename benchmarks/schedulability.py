"""Schedulability experiments (paper Figs. 7-12).

For each sweep point, N random tasksets (Table II parameters) are tested
under every approach; the acceptance ratio is reported.  Our approaches
follow the paper's evaluation pipeline (Sec. VII-A): improved analysis
(IOCTL) / baseline analysis (kthread), first with default RM priorities,
then retrying with Audsley GPU-segment priorities.  The corrected analysis
variants (see repro.core.analysis errata) are used throughout — they are
sound against the simulator; epsilon = 1 ms for our approaches, zero
overhead for prior work (as in the paper)."""
from __future__ import annotations

import math
from typing import Callable, Dict, List

from repro.core import (GenParams, fmlp_schedulable, generate_taskset,
                        ioctl_busy_improved_rta, ioctl_suspend_improved_rta,
                        kthread_busy_rta, mpcp_schedulable, schedulable)
from repro.core.audsley import assign_gpu_priorities


def _ours(rta) -> Callable:
    def test(ts) -> bool:
        if schedulable(ts, rta):
            return True
        return assign_gpu_priorities(ts, rta) is not None
    return test


METHODS: Dict[str, Callable] = {
    "kthread_busy": _ours(kthread_busy_rta),
    "ioctl_busy": _ours(ioctl_busy_improved_rta),
    "ioctl_suspend": _ours(ioctl_suspend_improved_rta),
    "mpcp": mpcp_schedulable,
    "fmlp+": fmlp_schedulable,
}


def acceptance(params: GenParams, n: int, seed0: int = 0
               ) -> Dict[str, float]:
    wins = {m: 0 for m in METHODS}
    for i in range(n):
        ts = generate_taskset(seed0 + i, params)
        ts.kthread_cpu = ts.n_cpus  # dedicated scheduler core
        for m, fn in METHODS.items():
            if fn(ts):
                wins[m] += 1
    return {m: w / n for m, w in wins.items()}


def sweep(name: str, param_list: List[tuple], n: int) -> List[dict]:
    rows = []
    for label, params in param_list:
        row = {"sweep": name, "x": label,
               **acceptance(params, n, seed0=hash(name) % 10_000)}
        rows.append(row)
        print(f"  {name} x={label}: " + " ".join(
            f"{m}={row[m]:.2f}" for m in METHODS))
    return rows


# NOTE: our generator + corrected (sound) analyses sit ~0.1 utilization
# harder than the paper's dynamic range; the non-utilization sweeps pin
# util_per_cpu to (0.30, 0.40) to show the same acceptance dynamic range
# as the paper's figures (documented in EXPERIMENTS.md).
BAND = (0.30, 0.40)


def fig7_n_tasks(n: int) -> List[dict]:
    pts = [(k, GenParams(n_tasks_total=k, util_per_cpu=BAND))
           for k in (8, 12, 16, 20, 24)]
    return sweep("fig7_n_tasks", pts, n)


def fig8_n_cpus(n: int) -> List[dict]:
    pts = [(c, GenParams(n_cpus=c, util_per_cpu=BAND))
           for c in (2, 4, 6, 8)]
    return sweep("fig8_n_cpus", pts, n)


def fig9_util(n: int) -> List[dict]:
    pts = [(u, GenParams(util_per_cpu=(u - 0.05, u + 0.05)))
           for u in (0.25, 0.3, 0.35, 0.4, 0.45, 0.5)]
    return sweep("fig9_util", pts, n)


def fig10_gpu_ratio(n: int) -> List[dict]:
    pts = [(r, GenParams(gpu_task_ratio=(r - 0.1, r + 0.1),
                         util_per_cpu=BAND))
           for r in (0.2, 0.4, 0.6, 0.8)]
    return sweep("fig10_gpu_ratio", pts, n)


def fig11_g_to_c(n: int) -> List[dict]:
    pts = [(g, GenParams(g_to_c_ratio=(g * 0.5, g * 1.5),
                         util_per_cpu=BAND))
           for g in (0.2, 0.5, 1.0, 2.0, 4.0)]
    return sweep("fig11_g_to_c", pts, n)


def fig12_best_effort(n: int) -> List[dict]:
    pts = [(r, GenParams(best_effort_ratio=r, util_per_cpu=(0.4, 0.5)))
           for r in (0.0, 0.2, 0.4, 0.6)]
    return sweep("fig12_best_effort", pts, n)


ALL = [fig7_n_tasks, fig8_n_cpus, fig9_util, fig10_gpu_ratio, fig11_g_to_c,
       fig12_best_effort]


def run(n: int = 200) -> List[dict]:
    rows = []
    for fn in ALL:
        rows.extend(fn(n))
    return rows
