"""Schedulability experiments (paper Figs. 7-12).

For each sweep point, N random tasksets (Table II parameters) are tested
under every approach; the acceptance ratio is reported.  Our approaches
follow the paper's evaluation pipeline (Sec. VII-A): improved analysis
(IOCTL) / baseline analysis (kthread), first with default RM priorities,
then retrying with Audsley GPU-segment priorities.  The corrected analysis
variants (see repro.core.analysis errata) are used throughout — they are
sound against the simulator; epsilon = 1 ms for our approaches, zero
overhead for prior work (as in the paper).

Three analysis backends (select with ``--backend``, default ``batch``):

  * ``batch`` — the NumPy vectorized backend (`repro.core.batch`,
    DESIGN.md §5): each worker's chunk of tasksets is packed into arrays
    once and every "ours" method runs as lockstep fixed points over the
    whole chunk, with the Audsley retry batched across tasksets.
    Decision-identical to scalar (tests/test_batch_equivalence.py pins
    it).
  * ``jax`` — the same packs lowered to jit-compiled device kernels
    (`repro.core.batch_jax`, DESIGN.md §8), built for 10k+-taskset
    sweep points.  Bit-identical decisions again; defaults to serial
    (no fork) so one process owns the device and the jit cache, and so
    chunks stay large — splitting a batch across workers shrinks the
    arrays the kernels amortize over.
  * ``scalar`` — the reference per-taskset path, kept runnable for
    differential timing and debugging.

``--scale-demo`` runs the backend-scaling measurement instead of the
paper sweeps: one sweep point at ``--scale-small``/``--scale-large``
tasksets through the NumPy and JAX backends (taskset generation
excluded, cold and warm JAX timings separated), with the explicit
criterion record ("JAX at the large size vs NumPy at the small size")
that lands in BENCH_sweep.json.

Run as a script for the full sweep with a parallel per-chunk fan-out:

    PYTHONPATH=src python benchmarks/schedulability.py --quick
    PYTHONPATH=src python benchmarks/schedulability.py --n 200 --workers 8
    PYTHONPATH=src python benchmarks/schedulability.py --n-devices 1 2 4
    PYTHONPATH=src python benchmarks/schedulability.py --quick --backend scalar

The third form runs the multi-device axis instead: heuristic vs
cross-device fixed-point acceptance under both busy-wait approaches
(DESIGN.md §4).  ``--json PATH`` dumps rows + wall-clock (total and
per-sweep) + backend tag for the CI benchmark-regression gate
(benchmarks/check_regression.py).

Tasksets are deterministic in their seeds and seeds are assigned before
the fan-out, so results are bit-identical across worker counts and
across backends; the sweep parallelizes with ``multiprocessing`` (fork)
over contiguous seed chunks (one chunk = one batch for the vectorized
backend)."""
from __future__ import annotations

import functools
import os
import time
import warnings
from typing import Callable, Dict, List, Optional

from repro.core import (GenParams, SoundnessWarning, batch_accept_many,
                        fmlp_schedulable, generate_taskset,
                        ioctl_busy_improved_rta, ioctl_busy_rta,
                        ioctl_suspend_improved_rta, kthread_busy_rta,
                        mpcp_schedulable, schedulable)
from repro.core.audsley import assign_gpu_priorities


def _ours(rta) -> Callable:
    def test(ts) -> bool:
        if schedulable(ts, rta):
            return True
        return assign_gpu_priorities(ts, rta) is not None
    return test


def _heuristic(rta) -> Callable:
    """The pre-fixed-point constant-charge projection, for the heuristic
    vs fixed-point comparison on the --n-devices axis.  The escape hatch
    warns by design; the comparison is the one intended consumer.
    ``functools.wraps`` keeps the base RTA's signature visible so the
    early_exit / Audsley ``only=`` accelerations stay enabled for the
    heuristic arms (apples-to-apples sweep cost)."""
    @functools.wraps(rta)
    def wrapped(ts, **kw):
        kw.setdefault("method", "heuristic")
        with warnings.catch_warnings():
            warnings.simplefilter("ignore", SoundnessWarning)
            return rta(ts, **kw)
    return wrapped


METHODS: Dict[str, Callable] = {
    "kthread_busy": _ours(kthread_busy_rta),
    "ioctl_busy": _ours(ioctl_busy_improved_rta),
    "ioctl_suspend": _ours(ioctl_suspend_improved_rta),
    "mpcp": mpcp_schedulable,
    "fmlp+": fmlp_schedulable,
}

# heuristic vs joint-fixed-point acceptance on multi-device platforms
# (the heuristic is *unsound* under busy-waiting — tests/test_cross_
# soundness.py — so its higher acceptance is not a win; the axis shows
# the price of soundness)
DEVICE_METHODS: Dict[str, Callable] = {
    "kthread_busy_fixed": _ours(kthread_busy_rta),
    "kthread_busy_heur": _ours(_heuristic(kthread_busy_rta)),
    "ioctl_busy_fixed": _ours(ioctl_busy_rta),
    "ioctl_busy_heur": _ours(_heuristic(ioctl_busy_rta)),
}

METHOD_SETS: Dict[str, Dict[str, Callable]] = {
    "default": METHODS,
    "devices": DEVICE_METHODS,
}

# batch-backend routing: method name -> (batch kind, multi-device method);
# methods without a vectorized kind (prior-work baselines) stay scalar.
BATCH_SPECS: Dict[str, Dict[str, Optional[tuple]]] = {
    "default": {
        "kthread_busy": ("kthread_busy", "fixed_point"),
        "ioctl_busy": ("ioctl_busy_improved", "fixed_point"),
        "ioctl_suspend": ("ioctl_suspend_improved", "fixed_point"),
        "mpcp": None,
        "fmlp+": None,
    },
    "devices": {
        "kthread_busy_fixed": ("kthread_busy", "fixed_point"),
        "kthread_busy_heur": ("kthread_busy", "heuristic"),
        "ioctl_busy_fixed": ("ioctl_busy", "fixed_point"),
        "ioctl_busy_heur": ("ioctl_busy", "heuristic"),
    },
}


def _eval_chunk(args) -> List[Dict[str, bool]]:
    """One unit of parallel work: every method on one contiguous chunk of
    generated tasksets (the chunk is the vectorized backend's batch)."""
    seeds, params, methods_key, backend = args
    methods = METHOD_SETS[methods_key]
    tss = []
    for seed in seeds:
        ts = generate_taskset(seed, params)
        ts.kthread_cpu = ts.n_cpus  # dedicated scheduler core
        tss.append(ts)
    out: List[Dict[str, bool]] = [{} for _ in tss]
    if backend in ("batch", "jax"):
        specs = {m: s for m, s in BATCH_SPECS[methods_key].items()
                 if s is not None}
        with warnings.catch_warnings():
            # the heuristic arms of the --n-devices axis warn by design
            warnings.simplefilter("ignore", SoundnessWarning)
            acc = batch_accept_many(
                specs, tss,
                backend="jax" if backend == "jax" else "numpy")
        for m, bits in acc.items():
            for d, b in zip(out, bits):
                d[m] = bool(b)
        rest = [m for m in methods if m not in specs]
    else:
        rest = list(methods)
    for m in rest:
        fn = methods[m]
        for d, ts in zip(out, tss):
            d[m] = bool(fn(ts))
    return out


def default_workers(backend: str = "batch") -> int:
    env = os.environ.get("REPRO_SWEEP_WORKERS")
    if env:
        return max(int(env), 1)
    if backend == "jax":
        # serial by default: forked workers would each recompile the
        # kernels, and splitting a batch shrinks the arrays they
        # amortize over
        return 1
    # capped: the batch backend saturates cores with NumPy, and raw
    # cpu_count() oversubscribes small CI runners
    return min(os.cpu_count() or 1, 4)


_POOL = None
_POOL_WORKERS = 0


def _get_pool(workers: int):
    """One long-lived process pool for the whole sweep run — per-point
    pool spawning used to dominate the parallel quick sweep's overhead.
    Library callers of ``acceptance()`` need not manage it: a mismatched
    worker count recycles the pool and an atexit hook reaps the last
    one (``main()`` still closes eagerly)."""
    global _POOL, _POOL_WORKERS
    if _POOL is None or _POOL_WORKERS != workers:
        if _POOL is None:  # first pool in this process: register the reaper
            import atexit
            atexit.register(close_pool)
        close_pool()
        import multiprocessing as mp
        _POOL = mp.Pool(workers)
        _POOL_WORKERS = workers
    return _POOL


def close_pool() -> None:
    global _POOL, _POOL_WORKERS
    if _POOL is not None:
        _POOL.terminate()
        _POOL.join()
        _POOL = None
        _POOL_WORKERS = 0


def acceptance(params: GenParams, n: int, seed0: int = 0,
               workers: Optional[int] = None,
               methods_key: str = "default",
               backend: str = "batch") -> Dict[str, float]:
    """Acceptance ratio per method over n tasksets.  ``workers`` > 1 fans
    contiguous seed chunks out over a (long-lived) process pool; None
    keeps the serial path (safe inside test processes that already hold
    accelerator runtimes).  Results are bit-identical across worker
    counts and backends (``methods_key``/``backend`` are plain values so
    the forked workers resolve the method tables by name — closures
    don't pickle)."""
    methods = METHOD_SETS[methods_key]
    seeds = [seed0 + i for i in range(n)]
    if workers is not None and workers > 1:
        n_chunks = max(workers, 1)
        size = max(1, (n + n_chunks - 1) // n_chunks)
        jobs = [(tuple(seeds[i:i + size]), params, methods_key, backend)
                for i in range(0, n, size)]
        chunks = _get_pool(workers).map(_eval_chunk, jobs)
        results = [r for c in chunks for r in c]
    else:
        results = _eval_chunk((tuple(seeds), params, methods_key, backend))
    wins = {m: 0 for m in methods}
    for r in results:
        for m in methods:
            if r[m]:
                wins[m] += 1
    return {m: w / n for m, w in wins.items()}


def _sweep_seed(name: str) -> int:
    """Stable per-sweep base seed (the historical ``hash(name)`` changed
    with PYTHONHASHSEED, making sweep results irreproducible run-to-run)."""
    import zlib
    return zlib.crc32(name.encode()) % 10_000


SWEEP_TIMES: Dict[str, float] = {}  # per-sweep wall-clock of the last run


def sweep(name: str, param_list: List[tuple], n: int,
          workers: Optional[int] = None,
          methods_key: str = "default",
          backend: str = "batch") -> List[dict]:
    rows = []
    t0 = time.time()
    for label, params in param_list:
        row = {"sweep": name, "x": label,
               **acceptance(params, n, seed0=_sweep_seed(name),
                            workers=workers, methods_key=methods_key,
                            backend=backend)}
        rows.append(row)
        print(f"  {name} x={label}: " + " ".join(
            f"{m}={row[m]:.2f}" for m in METHOD_SETS[methods_key]))
    SWEEP_TIMES[name] = round(time.time() - t0, 3)
    return rows


# NOTE: our generator + corrected (sound) analyses sit ~0.1 utilization
# harder than the paper's dynamic range; the non-utilization sweeps pin
# util_per_cpu to (0.30, 0.40) to show the same acceptance dynamic range
# as the paper's figures (documented in EXPERIMENTS.md).
BAND = (0.30, 0.40)


def fig7_n_tasks(n: int, workers: Optional[int] = None,
                 backend: str = "batch") -> List[dict]:
    pts = [(k, GenParams(n_tasks_total=k, util_per_cpu=BAND))
           for k in (8, 12, 16, 20, 24)]
    return sweep("fig7_n_tasks", pts, n, workers, backend=backend)


def fig8_n_cpus(n: int, workers: Optional[int] = None,
                backend: str = "batch") -> List[dict]:
    pts = [(c, GenParams(n_cpus=c, util_per_cpu=BAND))
           for c in (2, 4, 6, 8)]
    return sweep("fig8_n_cpus", pts, n, workers, backend=backend)


def fig9_util(n: int, workers: Optional[int] = None,
              backend: str = "batch") -> List[dict]:
    pts = [(u, GenParams(util_per_cpu=(u - 0.05, u + 0.05)))
           for u in (0.25, 0.3, 0.35, 0.4, 0.45, 0.5)]
    return sweep("fig9_util", pts, n, workers, backend=backend)


def fig10_gpu_ratio(n: int, workers: Optional[int] = None,
                    backend: str = "batch") -> List[dict]:
    pts = [(r, GenParams(gpu_task_ratio=(r - 0.1, r + 0.1),
                         util_per_cpu=BAND))
           for r in (0.2, 0.4, 0.6, 0.8)]
    return sweep("fig10_gpu_ratio", pts, n, workers, backend=backend)


def fig11_g_to_c(n: int, workers: Optional[int] = None,
                 backend: str = "batch") -> List[dict]:
    pts = [(g, GenParams(g_to_c_ratio=(g * 0.5, g * 1.5),
                         util_per_cpu=BAND))
           for g in (0.2, 0.5, 1.0, 2.0, 4.0)]
    return sweep("fig11_g_to_c", pts, n, workers, backend=backend)


def fig12_best_effort(n: int, workers: Optional[int] = None,
                      backend: str = "batch") -> List[dict]:
    pts = [(r, GenParams(best_effort_ratio=r, util_per_cpu=(0.4, 0.5)))
           for r in (0.0, 0.2, 0.4, 0.6)]
    return sweep("fig12_best_effort", pts, n, workers, backend=backend)


def fig13_n_devices(n: int, workers: Optional[int] = None,
                    device_counts=(1, 2, 4),
                    backend: str = "batch") -> List[dict]:
    """Multi-device axis: heuristic vs cross-device fixed-point acceptance
    under both busy-wait approaches (DESIGN.md §4).  On one device the
    two coincide; with more devices the (unsound) heuristic over-accepts
    and the gap is the cross-device busy-wait coupling it ignores."""
    pts = [(d, GenParams(n_devices=d, util_per_cpu=BAND))
           for d in device_counts]
    return sweep("fig13_n_devices", pts, n, workers, methods_key="devices",
                 backend=backend)


ALL = [fig7_n_tasks, fig8_n_cpus, fig9_util, fig10_gpu_ratio, fig11_g_to_c,
       fig12_best_effort]


def scale_demo(n_small: int = 1000, n_large: int = 10000,
               seed0: int = 0) -> dict:
    """Backend-scaling measurement for one sweep point (the BAND
    configuration, both improved "ours" methods — RM test + batched
    Audsley retry): the NumPy backend at both sizes, the JAX backend at
    the large size cold (first call compiles the bucketed kernels) and
    warm (compiled kernels reused — the steady state of a sweep, where
    every point shares one bucket shape).

    Taskset generation runs outside every timed region, and all times
    are single-process wall-clock on the same host, so the numbers are
    directly comparable.  The returned dict includes the explicit
    criterion record ("JAX at n_large inside NumPy's n_small budget")
    with its measured verdict — on accelerator hardware the batched
    kernels are the scaling story; on a small CPU host the honest
    outcome of that comparison belongs in the record, not in a
    footnote (see DESIGN.md §8)."""
    params = GenParams(util_per_cpu=BAND)
    specs = {m: s for m, s in BATCH_SPECS["default"].items()
             if s is not None}

    def gen(n: int) -> list:
        tss = []
        for seed in range(seed0, seed0 + n):
            ts = generate_taskset(seed, params)
            ts.kthread_cpu = ts.n_cpus
            tss.append(ts)
        return tss

    def timed(tss, backend: str) -> float:
        t0 = time.perf_counter()
        batch_accept_many(specs, tss, backend=backend)
        return time.perf_counter() - t0

    small, large = gen(n_small), gen(n_large)
    t_np_small = timed(small, "numpy")
    t_np_large = timed(large, "numpy")
    t_jax_cold = timed(large, "jax")
    t_jax_warm = timed(large, "jax")
    t_jax_small = timed(small, "jax")
    passed = t_jax_warm < t_np_small
    demo = {
        "point": {"util_per_cpu": list(BAND), "methods": sorted(specs)},
        "n_small": n_small, "n_large": n_large,
        "numpy_s": {f"n={n_small}": round(t_np_small, 3),
                    f"n={n_large}": round(t_np_large, 3)},
        "jax_s": {f"n={n_large}_cold": round(t_jax_cold, 3),
                  f"n={n_large}_warm": round(t_jax_warm, 3),
                  f"n={n_small}_warm": round(t_jax_small, 3)},
        "per_taskset_ms": {
            "numpy": round(t_np_large / n_large * 1e3, 4),
            "jax_warm": round(t_jax_warm / n_large * 1e3, 4)},
        "jax_speedup_at_n_large": round(t_np_large / t_jax_warm, 2),
        "criterion": {
            "statement": f"jax n={n_large} (warm) completes within "
                         f"numpy's n={n_small} wall-clock",
            f"jax_{n_large}_warm_s": round(t_jax_warm, 3),
            f"numpy_{n_small}_s": round(t_np_small, 3),
            "passed": passed,
            "host": f"{os.cpu_count()}-core CPU (no accelerator)"},
    }
    print(f"scale demo (n_small={n_small}, n_large={n_large}):")
    print(f"  numpy   n={n_small}: {t_np_small:.2f}s   "
          f"n={n_large}: {t_np_large:.2f}s")
    print(f"  jax     n={n_large}: cold {t_jax_cold:.2f}s  "
          f"warm {t_jax_warm:.2f}s  "
          f"({t_np_large / t_jax_warm:.1f}x numpy at n={n_large})")
    print(f"  criterion {'PASSED' if passed else 'FAILED'}: "
          f"jax {n_large} warm = {t_jax_warm:.2f}s vs "
          f"numpy {n_small} = {t_np_small:.2f}s")
    return demo


def run(n: int = 200, workers: Optional[int] = None,
        backend: str = "batch") -> List[dict]:
    rows = []
    for fn in ALL:
        rows.extend(fn(n, workers, backend=backend))
    return rows


def main() -> None:
    import argparse
    import json

    ap = argparse.ArgumentParser(description=__doc__.split("\n")[0])
    ap.add_argument("--quick", action="store_true",
                    help="40 tasksets per sweep point (default 200)")
    ap.add_argument("--n", type=int, default=0,
                    help="tasksets per sweep point (overrides --quick)")
    ap.add_argument("--workers", type=int, default=0,
                    help="process-pool size (0 = default_workers(), "
                         "1 = serial)")
    ap.add_argument("--backend", choices=("batch", "jax", "scalar"),
                    default="batch",
                    help="analysis backend: vectorized NumPy batch "
                         "(default), jit-compiled jax, or the scalar "
                         "reference path")
    ap.add_argument("--n-devices", type=int, nargs="+", default=None,
                    metavar="D",
                    help="run the multi-device axis over these device "
                         "counts (heuristic vs fixed-point acceptance) "
                         "instead of the paper sweeps")
    ap.add_argument("--scale-demo", action="store_true",
                    help="run the backend-scaling measurement (numpy vs "
                         "jax, small vs large batch) instead of the "
                         "paper sweeps")
    ap.add_argument("--scale-small", type=int, default=1000,
                    help="scale-demo small batch size (default 1000)")
    ap.add_argument("--scale-large", type=int, default=10000,
                    help="scale-demo large batch size (default 10000)")
    ap.add_argument("--json", default=None, metavar="PATH",
                    help="write rows + wall-clock + backend to PATH (CI "
                         "regression gate reads this)")
    args = ap.parse_args()
    if args.scale_demo:
        demo = scale_demo(args.scale_small, args.scale_large)
        if args.json:
            with open(args.json, "w") as f:
                json.dump({"scale_demo": demo}, f, indent=2)
            print(f"wrote {args.json}")
        return
    n = args.n or (40 if args.quick else 200)
    workers = args.workers or default_workers(args.backend)
    t0 = time.time()
    try:
        if args.n_devices:
            rows = fig13_n_devices(n, workers=workers,
                                   device_counts=tuple(args.n_devices),
                                   backend=args.backend)
        else:
            rows = run(n, workers=workers, backend=args.backend)
    finally:
        close_pool()
    dt = time.time() - t0
    print(f"schedulability sweep: {len(rows)} points x {n} tasksets, "
          f"{workers} workers, backend={args.backend}, "
          f"{dt:.1f}s wall-clock")
    if args.json:
        with open(args.json, "w") as f:
            json.dump({"rows": rows, "n": n, "workers": workers,
                       "backend": args.backend,
                       "wall_clock_s": round(dt, 3),
                       "sweep_wall_clock_s": dict(SWEEP_TIMES)}, f,
                      indent=2)
        print(f"wrote {args.json}")


if __name__ == "__main__":
    main()
