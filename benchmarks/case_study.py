"""Case study (paper Sec. VII-B, Tables III/IV, Figs. 15/16): a taskset of
real JAX workloads scheduled by the device executor under each approach.

Jobs (reduced-config models — real jitted device programs):
  1  infer_hi   smollm-135m-reduced decode chunks   (highest priority)
  2  infer_mid  olmo-1b-reduced prefill+decode
  3  host_only  numpy host work, no device segments
  4  train_mid  olmo-1b-reduced train steps
  5  infer_lo   musicgen-reduced decode chunks
  6  train_be   minitron-reduced train steps        (best-effort)
  7  infer_be   smollm-reduced decode chunks        (best-effort)

Pipeline per approach: profile segment WCETs -> admission control (the
paper's RTA with measured epsilon) -> run for `duration` seconds -> report
max observed response time (MORT) vs analytic WCRT.  The single-core
container maps all host segments onto one analysed CPU (n_cpus=1) —
conservative and faithful to the hardware."""
from __future__ import annotations

import time
from typing import Dict, List

import jax
import jax.numpy as jnp
import numpy as np

from repro.configs import get
from repro.launch.serve import InferenceEngine
from repro.models import transformer
from repro.optim import adamw
from repro.sched import AdmissionController, DeviceExecutor, JobProfile, RTJob


def _host_work(ms: float) -> None:
    t0 = time.perf_counter()
    x = np.random.default_rng(0).random((64, 64))
    while (time.perf_counter() - t0) * 1e3 < ms:
        x = x @ x * 1e-3


class Workloads:
    """Compiled device programs shared by all scheduling modes."""

    def __init__(self):
        self.engines = {
            "smollm": InferenceEngine(get("smollm-135m").reduced(),
                                      max_len=64),
            "olmo": InferenceEngine(get("olmo-1b").reduced(), max_len=64),
            "musicgen": InferenceEngine(get("musicgen-medium").reduced(),
                                        max_len=64),
        }
        self.train_cfg = get("olmo-1b").reduced()
        params = transformer.init_params(self.train_cfg,
                                         jax.random.PRNGKey(0))
        opt = adamw.init_opt_state(params)
        self.train_state = {"params": params, "opt": opt}
        from repro.launch.steps import build_train_step
        self._train = jax.jit(build_train_step(self.train_cfg))
        self.train_batch = {
            "inputs": jnp.zeros((2, 32), jnp.int32),
            "labels": jnp.zeros((2, 32), jnp.int32)}
        self.warmup()

    def prefill(self, engine: str, batch=2, length=16):
        eng = self.engines[engine]
        cfg = eng.cfg
        if cfg.input_mode == "embeddings":
            toks = jnp.zeros((batch, length, cfg.d_model), jnp.float32)
        else:
            toks = jnp.zeros((batch, length), jnp.int32)
        return eng.prefill_batch(toks)

    def decode(self, engine: str, n: int):
        return self.engines[engine].decode_chunk(n)

    def train_step(self):
        p, o, m = self._train(self.train_state["params"],
                              self.train_state["opt"], self.train_batch)
        self.train_state = {"params": p, "opt": o}
        return m

    def warmup(self):
        for name in self.engines:
            self.prefill(name)
            self.decode(name, 2)
        self.train_step()


def make_jobs(w: Workloads, ex: DeviceExecutor) -> List[RTJob]:
    def infer_body(engine, n_decode, host_ms):
        def body(job, it):
            _host_work(host_ms)
            with ex.device_segment(job):
                ex.run(job, w.prefill, engine)
                ex.run(job, w.decode, engine, n_decode)
            _host_work(host_ms / 2)
        return body

    def train_body(host_ms):
        def body(job, it):
            _host_work(host_ms)
            with ex.device_segment(job):
                ex.run(job, w.train_step)
            _host_work(host_ms / 2)
        return body

    def host_body(ms):
        def body(job, it):
            _host_work(ms)
        return body

    return [
        RTJob("infer_hi", infer_body("smollm", 4, 4), period_s=0.60,
              priority=70, n_iterations=1000),
        RTJob("infer_mid", infer_body("olmo", 4, 6), period_s=0.90,
              priority=69, n_iterations=1000),
        RTJob("host_only", host_body(30), period_s=1.20, priority=68,
              n_iterations=1000),
        RTJob("train_mid", train_body(6), period_s=1.50, priority=67,
              n_iterations=1000),
        RTJob("infer_lo", infer_body("musicgen", 6, 6), period_s=2.00,
              priority=66, n_iterations=1000),
        RTJob("train_be", train_body(4), period_s=1.00, priority=0,
              best_effort=True, n_iterations=1000),
        RTJob("infer_be", infer_body("smollm", 8, 4), period_s=0.80,
              priority=0, best_effort=True, n_iterations=1000),
    ]


def profile_segments(w: Workloads, reps: int = 3) -> Dict[str, dict]:
    """Measure worst-case host/device segment times (ms) over reps."""
    out = {}

    def wc(fn, *a):
        ts = []
        for _ in range(reps):
            t0 = time.perf_counter()
            jax.block_until_ready(fn(*a))
            ts.append((time.perf_counter() - t0) * 1e3)
        return max(ts)

    out["smollm_seg"] = wc(lambda: (w.prefill("smollm"),
                                    w.decode("smollm", 4)))
    out["olmo_seg"] = wc(lambda: (w.prefill("olmo"), w.decode("olmo", 4)))
    out["musicgen_seg"] = wc(lambda: (w.prefill("musicgen"),
                                      w.decode("musicgen", 6)))
    out["train_seg"] = wc(w.train_step)
    return out


def run_case_study(duration_s: float = 8.0, modes=None) -> List[dict]:
    w = Workloads()
    prof = profile_segments(w)
    margin = 1.5  # single-core wall-clock jitter allowance
    # epsilon = admission update + the residual of an in-flight device
    # program (program-boundary preemption, DESIGN.md §2): the longest
    # single program in the mix bounds it
    eps_ms = max(prof.values()) * margin + 1.0

    profiles = [
        JobProfile("infer_hi", [4, 2], [(1.0, prof["smollm_seg"] * margin)],
                   600, 70, cpu=0),
        JobProfile("infer_mid", [6, 3], [(1.0, prof["olmo_seg"] * margin)],
                   900, 69, cpu=0),
        JobProfile("host_only", [30 * margin], [], 1200, 68, cpu=0),
        JobProfile("train_mid", [6, 3], [(1.0, prof["train_seg"] * margin)],
                   1500, 67, cpu=0),
        JobProfile("infer_lo", [6, 3],
                   [(1.0, prof["musicgen_seg"] * margin)], 2000, 66, cpu=0),
        JobProfile("train_be", [4, 2], [(1.0, prof["train_seg"] * margin)],
                   1000, 0, cpu=0, best_effort=True),
        JobProfile("infer_be", [4, 2],
                   [(1.0, prof["smollm_seg"] * 2 * margin)], 800, 0,
                   cpu=0, best_effort=True),
    ]

    rows = []
    # scheduling approaches by registry name (core.policy); the legacy
    # executor mode names would work too, but the registry names are the
    # single shared vocabulary of simulator, analysis, and runtime
    modes = modes or [("unmanaged", "suspend"), ("kthread", "busy"),
                      ("ioctl", "busy"), ("ioctl", "suspend")]
    for mode, wait in modes:
        label = {"unmanaged": "unmanaged", "poll": "kthread_busy",
                 "kthread": "kthread_busy"}.get(mode, f"ioctl_{wait}")
        wcrt = {}
        if mode != "unmanaged":
            ac = AdmissionController(policy=mode, wait_mode=wait, n_cpus=1,
                                     epsilon_ms=eps_ms)
            for p in profiles:
                res = ac.try_admit(p)
                if res["wcrt"]:
                    wcrt = {k: v for k, v in res["wcrt"].items()
                            if v is not None}
        ex = DeviceExecutor(policy=mode, wait_mode=wait)
        jobs = make_jobs(w, ex)
        for j in jobs:
            j.start(ex, stop_after_s=duration_s)
        for j in jobs:
            j.join(duration_s + 30)
            j.stop()
        ex.shutdown()
        eps_samples = [t * 1e6 for t in ex.update_times]
        for j in jobs:
            rows.append({
                "mode": label, "task": j.name, "rt": j.is_rt,
                # mort is None until the first completion — report NaN so
                # an idle job can't read as meeting its deadline at 0.0ms
                "mort_ms": round(j.stats.mort * 1e3, 2)
                if j.stats.mort is not None else float("nan"),
                "wcrt_ms": round(wcrt.get(j.name, float("nan")), 2)
                if wcrt.get(j.name) is not None else float("nan"),
                "jobs": j.stats.completions,
                "misses": j.stats.deadline_misses,
            })
        rows.append({"mode": label, "task": "_epsilon_us",
                     "mort_ms": round(float(np.max(eps_samples)), 1)
                     if eps_samples else 0.0,
                     "wcrt_ms": round(float(np.median(eps_samples)), 1)
                     if eps_samples else 0.0,
                     "jobs": len(eps_samples), "rt": False, "misses": 0})
        print(f"  case_study[{label}]: " + " ".join(
            f"{r['task']}={r['mort_ms']}ms" for r in rows
            if r["mode"] == label and r["task"] != "_epsilon_us"))
    return rows
