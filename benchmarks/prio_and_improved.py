"""Figs. 13 and 14: schedulability gains from GPU-segment priority
assignment and from the reduced-pessimism analysis."""
from __future__ import annotations

from typing import List

from repro.core import (GenParams, generate_taskset, ioctl_busy_improved_rta,
                        ioctl_busy_rta, ioctl_suspend_improved_rta,
                        ioctl_suspend_rta, kthread_busy_rta, schedulable)
from repro.core.audsley import assign_gpu_priorities


def fig13_gpu_priority_gain(n: int = 200) -> List[dict]:
    """Baseline analyses with vs without separate GPU priorities."""
    methods = {"kthread_busy": kthread_busy_rta,
               "ioctl_busy": ioctl_busy_rta,
               "ioctl_suspend": ioctl_suspend_rta}
    rows = []
    for u in (0.3, 0.35, 0.4):
        p = GenParams(util_per_cpu=(u - 0.05, u + 0.05))
        acc = {f"{m}{suffix}": 0 for m in methods
               for suffix in ("", "+gpu_prio")}
        for i in range(n):
            ts = generate_taskset(31_000 + i, p)
            ts.kthread_cpu = ts.n_cpus
            for m, rta in methods.items():
                base = schedulable(ts, rta)
                if base:
                    acc[m] += 1
                    acc[m + "+gpu_prio"] += 1
                elif assign_gpu_priorities(ts, rta) is not None:
                    acc[m + "+gpu_prio"] += 1
        row = {"sweep": "fig13", "x": u,
               **{k: v / n for k, v in acc.items()}}
        rows.append(row)
        print(f"  fig13 u={u}: " + " ".join(
            f"{k}={v:.2f}" for k, v in row.items() if k not in
            ("sweep", "x")))
    return rows


def _fig14_taskset(seed: int, util_extra: float):
    """Paper Sec. VII-A.3: 2 CPUs, [2,4] generated tasks per CPU, PLUS two
    high-rate CPU-heavy tasks and one long-GPU task — the structure whose
    guaranteed segment overlaps (O^cg/O^gc) the improved analysis exploits
    (the long pure-GPU segment fully contains several short CPU jobs)."""
    import random

    from repro.core import GpuSegment, Task, Taskset
    p = GenParams(n_cpus=2, tasks_per_cpu=(2, 4),
                  util_per_cpu=(util_extra - 0.05, util_extra + 0.05))
    base = generate_taskset(seed, p)
    rng = random.Random(seed + 999)
    tasks = list(base.tasks)
    # two high-utilization short-period CPU tasks
    for cpu in (0, 1):
        T = rng.uniform(18.0, 30.0)
        tasks.append(Task(f"cpu_hot{cpu}", [0.30 * T], [], T, T, cpu,
                          priority=5000 + cpu))
    # one long-GPU task (lowest priority; its pure GPU segment spans
    # several periods of the hot CPU tasks)
    Tg = rng.uniform(350.0, 450.0)
    ge = rng.uniform(90.0, 140.0)
    tasks.append(Task("gpu_long", [2.0, 2.0], [GpuSegment(2.0, ge)],
                      Tg, Tg, rng.randint(0, 1), priority=1))
    return Taskset(tasks, n_cpus=2, epsilon=base.epsilon,
                   kthread_cpu=2)


def fig14_improved_analysis_gain(n: int = 200) -> List[dict]:
    methods = {
        "ioctl_busy": (ioctl_busy_rta, ioctl_busy_improved_rta),
        "ioctl_suspend": (ioctl_suspend_rta, ioctl_suspend_improved_rta),
    }
    rows = []
    for u in (0.2, 0.3, 0.4):
        acc = {f"{m}{s}": 0 for m in methods for s in ("", "+improved")}
        for i in range(n):
            ts = _fig14_taskset(47_000 + i, u)
            for m, (base_rta, imp_rta) in methods.items():
                if schedulable(ts, base_rta):
                    acc[m] += 1
                if schedulable(ts, imp_rta):
                    acc[m + "+improved"] += 1
        row = {"sweep": "fig14", "x": u,
               **{k: v / n for k, v in acc.items()}}
        rows.append(row)
        print(f"  fig14 u={u}: " + " ".join(
            f"{k}={v:.2f}" for k, v in row.items() if k not in
            ("sweep", "x")))
    return rows
