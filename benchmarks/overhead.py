"""Runtime overhead microbenchmarks (paper Table V / Fig. 18).

Three measurements:

  * **ioctl_update / poll_rewrite** — the runlist-update cost under the
    admission mutex (the epsilon of the analysis), max/min/avg/median in
    microseconds — the shape of the paper's Table V;
  * **preemption latency** — wall time from a high-priority release to its
    first device program starting while a best-effort job streams sliced
    device work through ``run_sliced``.  The paper's claim, on the sliced
    API: the observed latency is bounded by one slice duration + epsilon,
    not by the lower-priority job's whole program.

``--json PATH`` writes the ``BENCH_overhead.json`` perf-trajectory
artifact (the runtime counterpart of check_regression.py's
``BENCH_sweep.json``); CI uploads it on every push so runtime-overhead
history is a comparable series rather than an empty trajectory.

Usage:
    PYTHONPATH=src python benchmarks/overhead.py --quick \
        --json BENCH_overhead.json
"""
from __future__ import annotations

import argparse
import json
import time
from typing import Dict, List

import numpy as np

from repro.core.segments import SlicedOp
from repro.sched import ClusterExecutor, DeviceExecutor, RTJob


def measure_ioctl_updates(n: int = 20_000) -> np.ndarray:
    ex = DeviceExecutor(policy="ioctl")
    jobs = [RTJob(f"j{i}", lambda job, it: None, period_s=1.0,
                  priority=10 + i) for i in range(8)]
    ts = []
    for i in range(n):
        j = jobs[i % len(jobs)]
        t0 = time.perf_counter()
        with ex._mutex:
            ex._ioctl_add(j)
        with ex._mutex:
            ex._ioctl_remove(j)
        ts.append((time.perf_counter() - t0) * 1e6 / 2)
    ex.shutdown()
    return np.array(ts)


def measure_poll_rewrites(n: int = 5_000) -> np.ndarray:
    ex = DeviceExecutor(policy="kthread", poll_interval=0.0005)
    jobs = [RTJob(f"p{i}", lambda job, it: None, period_s=1.0,
                  priority=10 + i) for i in range(4)]
    for _ in range(n // len(jobs)):
        for j in jobs:
            ex.on_job_start(j)
        time.sleep(0.001)
        for j in jobs:
            ex.on_job_complete(j)
    time.sleep(0.05)
    out = np.array([t * 1e6 for t in ex.update_times]) \
        if ex.update_times else np.zeros(1)
    ex.shutdown()
    return out


def measure_preemption_latency(n_releases: int = 20,
                               slice_s: float = 0.01,
                               n_devices: int = 1) -> Dict:
    """Per device of an ``n_devices`` cluster: release a high-priority
    job ``n_releases`` times against a best-effort job streaming
    ``slice_s``-long sliced dispatches on the *same* device; return the
    release→first-program latency distribution (ms) and the analytic
    bound (one slice + measured epsilon).  The flat keys are device 0
    (the historical single-device artifact shape); ``per_device`` holds
    every device when ``n_devices > 1`` — the bound must hold on each
    device independently (no cross-device interference)."""
    cluster = ClusterExecutor(n_devices=n_devices, policy="ioctl",
                              wait_mode="suspend", n_cpus=2)
    latencies: Dict[int, List[float]] = {d: [] for d in range(n_devices)}
    stop = []
    bes: List[RTJob] = []
    rts: List[RTJob] = []

    def be_body(job, it):
        def step(carry, i):
            if not stop:
                time.sleep(slice_s)  # device residency of one slice
            return carry

        with cluster.device_segment(job):
            cluster.run_sliced(job, SlicedOp(50, lambda: None, step,
                                             lambda c: c,
                                             label="be_slice"))

    def rt_body(job, it):
        t_req = time.perf_counter()
        with cluster.device_segment(job):
            cluster.run(job, lambda: latencies[job.device].append(
                (time.perf_counter() - t_req) * 1e3))

    horizon = n_releases * 3 * slice_s + 2.0
    for d in range(n_devices):
        be = RTJob(f"be{d}", be_body, period_s=0.001, priority=d,
                   best_effort=True, n_iterations=10_000, device=d)
        rt = RTJob(f"rt{d}", rt_body, period_s=3 * slice_s,
                   priority=50 + d, n_iterations=n_releases, device=d)
        cluster.bind_job(be)
        cluster.bind_job(rt)
        bes.append(be)
        rts.append(rt)
    for be in bes:
        be.start(cluster, stop_after_s=horizon)
    time.sleep(2 * slice_s)  # let the BE streams get going
    for rt in rts:
        rt.start(cluster)
    for rt in rts:
        rt.join(horizon + 30)
    stop.append(True)
    for be in bes:
        be.stop()
        be.join(10)
    cluster.shutdown()
    cluster.assert_migration_free()

    def summary(d: int) -> Dict:
        ex = cluster.executors[d]
        eps_ms = (max(ex.update_times) * 1e3) if ex.update_times else 0.0
        # an absent measurement must not read as perfect latency (same
        # rule as JobStats.mort): NaN, never 0.0
        lat = (np.array(latencies[d]) if latencies[d]
               else np.full(1, np.nan))
        return {
            "n": len(latencies[d]),
            "slice_ms": slice_s * 1e3,
            "epsilon_ms": round(eps_ms, 4),
            "bound_ms": round(slice_s * 1e3 + eps_ms, 3),
            "max_ms": round(float(np.max(lat)), 3),
            "avg_ms": round(float(np.mean(lat)), 3),
            "median_ms": round(float(np.median(lat)), 3),
            "be_slices": len(bes[d].stats.slice_times),
        }

    out = summary(0)
    out["n_devices"] = n_devices
    if n_devices > 1:
        out["per_device"] = {d: summary(d) for d in range(n_devices)}
    return out


def run(quick: bool = False) -> List[Dict]:
    rows = []
    n_ioctl, n_poll = (2_000, 1_000) if quick else (20_000, 5_000)
    for name, samples in [("ioctl_update", measure_ioctl_updates(n_ioctl)),
                          ("poll_rewrite", measure_poll_rewrites(n_poll))]:
        rows.append({
            "name": name, "n": len(samples),
            "max_us": round(float(np.max(samples)), 2),
            "min_us": round(float(np.min(samples)), 2),
            "avg_us": round(float(np.mean(samples)), 2),
            "median_us": round(float(np.median(samples)), 2),
            "p999_us": round(float(np.percentile(samples, 99.9)), 2),
        })
        print(f"  overhead[{name}]: " + " ".join(
            f"{k}={v}" for k, v in rows[-1].items() if k != "name"))
    return rows


def main() -> None:
    ap = argparse.ArgumentParser(description=__doc__.split("\n")[0])
    ap.add_argument("--json", default=None, metavar="PATH",
                    help="write the BENCH_overhead.json artifact")
    ap.add_argument("--quick", action="store_true",
                    help="CI-sized sample counts")
    ap.add_argument("--n-devices", type=int, default=1,
                    help="measure preemption latency per device of an "
                         "N-device cluster (the bound must hold on each)")
    args = ap.parse_args()

    rows = run(quick=args.quick)
    preempt = measure_preemption_latency(
        n_releases=10 if args.quick else 30, n_devices=args.n_devices)
    print("  preemption_latency: " + " ".join(
        f"{k}={v}" for k, v in preempt.items() if k != "per_device"))
    for d, row in preempt.get("per_device", {}).items():
        print(f"  preemption_latency[device {d}]: " + " ".join(
            f"{k}={v}" for k, v in row.items()))
    if args.json:
        with open(args.json, "w") as f:
            json.dump({"rows": rows, "preemption_latency": preempt}, f,
                      indent=2)
        print(f"wrote {args.json}")


if __name__ == "__main__":
    main()
