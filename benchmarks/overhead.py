"""Runlist-update overhead epsilon (paper Table V / Fig. 18).

Microbenchmark of the executor's admission updates (the IOCTL-analogue
add/remove under the mutex, and the polling scheduler's reservation
rewrite), reported in microseconds: max / min / avg / median — the shape of
the paper's Table V.  The measured distribution feeds the epsilon used by
admission control (sched/admission.py)."""
from __future__ import annotations

import time
from typing import Dict, List

import numpy as np

from repro.sched import DeviceExecutor, RTJob


def measure_ioctl_updates(n: int = 20_000) -> np.ndarray:
    ex = DeviceExecutor(mode="notify")
    jobs = [RTJob(f"j{i}", lambda job, it: None, period_s=1.0,
                  priority=10 + i) for i in range(8)]
    ts = []
    for i in range(n):
        j = jobs[i % len(jobs)]
        t0 = time.perf_counter()
        with ex._mutex:
            ex._ioctl_add(j)
        with ex._mutex:
            ex._ioctl_remove(j)
        ts.append((time.perf_counter() - t0) * 1e6 / 2)
    ex.shutdown()
    return np.array(ts)


def measure_poll_rewrites(n: int = 5_000) -> np.ndarray:
    ex = DeviceExecutor(mode="poll", poll_interval=0.0005)
    jobs = [RTJob(f"p{i}", lambda job, it: None, period_s=1.0,
                  priority=10 + i) for i in range(4)]
    for _ in range(n // len(jobs)):
        for j in jobs:
            ex.on_job_start(j)
        time.sleep(0.001)
        for j in jobs:
            ex.on_job_complete(j)
    time.sleep(0.05)
    out = np.array([t * 1e6 for t in ex.update_times]) \
        if ex.update_times else np.zeros(1)
    ex.shutdown()
    return out


def run() -> List[Dict]:
    rows = []
    for name, samples in [("ioctl_update", measure_ioctl_updates()),
                          ("poll_rewrite", measure_poll_rewrites())]:
        rows.append({
            "name": name, "n": len(samples),
            "max_us": round(float(np.max(samples)), 2),
            "min_us": round(float(np.min(samples)), 2),
            "avg_us": round(float(np.mean(samples)), 2),
            "median_us": round(float(np.median(samples)), 2),
            "p999_us": round(float(np.percentile(samples, 99.9)), 2),
        })
        print(f"  overhead[{name}]: " + " ".join(
            f"{k}={v}" for k, v in rows[-1].items() if k != "name"))
    return rows
