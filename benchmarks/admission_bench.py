"""Admission-throughput benchmark: the scale metric for streaming
admission (ROADMAP "online admission at traffic scale", DESIGN.md §11).

Drives open-arrival Poisson bursts of generated :class:`JobProfile`s
through ``AdmissionController.try_admit_many`` (and, with ``--scalar``,
the sequential ``try_admit`` path), measuring the *incremental* decision
path (``warm_start=True``: cached Task objects, running utilization
totals, warm-start WCRT seeds) against the from-scratch baseline it
replaced (``warm_start=False``: every decision re-converts every
admitted profile, re-sums headroom, solves cold from zero).

Three regimes, each measured in its own stream (see ``_schedules`` for
why they are not chained into a single pass):

  * **growth** — a fresh controller absorbs an arrival stream while
    capacity lasts; nearly every decision is an accept, so warm seeds
    and cached state pay on every decision.  This is the streaming
    regime the incremental state targets, and the phase the ≥2×
    acceptance criterion is recorded against (numpy backend, quick
    profile).
  * **churn** — steady state: each burst of arrivals is matched by
    releases of the oldest admitted profiles.  Every RT release
    invalidates the warm cache (the shrink direction is unsound —
    DESIGN.md §11), so this phase measures throughput *with* recurring
    invalidation: the honest middle ground.
  * **saturated** — arrivals continue past capacity; refusals run the
    Audsley retry, whose cost is identical warm and cold, so the ratio
    compresses.  Reported so the headline number cannot hide it.

Warm and cold controllers see the identical arrival/release schedule
and the run asserts their decisions match field-for-field
(admitted/reason/via) — the benchmark doubles as an end-to-end identity
check on exactly the traffic it measures.

Reported per backend (numpy always; jax when importable): per-phase
wall time, sustained admissions/sec and decisions/sec, arrival→decision
latency percentiles (from each decision's ``latency_ms`` stamp), and
the explicit criterion record.  ``--json`` emits BENCH_admission.json
for the CI gate (benchmarks/check_regression.py).

    PYTHONPATH=src python benchmarks/admission_bench.py --quick
    PYTHONPATH=src python benchmarks/admission_bench.py --quick --json \
        benchmarks/results/BENCH_admission.json
"""
from __future__ import annotations

import math
import random
import time
from typing import Dict, List, Optional, Tuple

from repro.sched.admission import (AdmissionController, JobProfile,
                                   nearest_rank)

MARKER = "admission-bench-v1"

#: workload shape: light periodic tasks on an 8-core/1-device platform,
#: sized so the platform sustains ~130+ RT tasks before the RTA starts
#: refusing — large enough that per-decision work (the thing this PR
#: attacks) dominates over fixed costs.
N_CPUS = 8
PERIODS = (200.0, 400.0, 800.0)


def _poisson(rng: random.Random, lam: float) -> int:
    """Knuth's Poisson sampler (no numpy dependency on the hot path)."""
    limit = math.exp(-lam)
    k, p = 0, 1.0
    while True:
        p *= rng.random()
        if p <= limit:
            return k
        k += 1


def _profile(i: int, rng: random.Random) -> JobProfile:
    return JobProfile(
        name=f"j{i}",
        host_segments_ms=[round(rng.uniform(0.05, 0.1), 3)],
        device_segments_ms=[(0.01, round(rng.uniform(0.05, 0.15), 3))],
        period_ms=rng.choice(PERIODS),
        priority=100_000 - i,
        cpu=i % N_CPUS,
        device=0,
    )


def _bursts(rng: random.Random, phase: str, total: int,
            lam: float) -> List[Tuple[str, int]]:
    out: List[Tuple[str, int]] = []
    n = 0
    while n < total:
        b = min(max(1, _poisson(rng, lam)), total - n)
        out.append((phase, b))
        n += b
    return out


def _schedules(seed: int, grow_to: int, churn_rounds: int,
               sat_arrivals: int, lam: float
               ) -> Dict[str, List[Tuple[str, int]]]:
    """Deterministic (phase, burst_size) streams shared by every run so
    warm/cold (and numpy/jax) see byte-identical traffic.  Each regime
    is measured in its *own* stream — the saturated regime's
    Audsley-retry load would otherwise run right before the next pass's
    growth timing and bleed into it (allocator and cpufreq state).  The
    churn and saturated streams replay the growth prefix untimed
    ("warmup" phase) to reach their starting state."""
    rng = random.Random(seed)
    grow = _bursts(rng, "growth", grow_to, lam)
    warm_prefix = [("warmup", b) for _, b in grow]
    churn = warm_prefix + [("churn", max(1, _poisson(rng, lam)))
                           for _ in range(churn_rounds)]
    sat = warm_prefix + _bursts(rng, "saturated", sat_arrivals, lam)
    return {"growth": grow, "churn": churn, "saturated": sat}


def _percentiles(lat: List[float]) -> Dict[str, float]:
    if not lat:
        return {"decisions": 0}
    s = sorted(lat)

    def pct(q: float) -> float:
        return nearest_rank(s, q)

    return {"decisions": len(s),
            "mean_ms": round(sum(s) / len(s), 4),
            "p50_ms": round(pct(0.50), 4),
            "p90_ms": round(pct(0.90), 4),
            "p99_ms": round(pct(0.99), 4),
            "max_ms": round(s[-1], 4)}


def run_stream(schedule: List[Tuple[str, int]], *, warm: bool,
               backend: str, seed: int) -> dict:
    """One pass of one arrival/release stream through a fresh
    controller.  ``warmup`` bursts execute (to reach the regime's
    starting state) but are not timed; their decisions still join the
    trace so the warm/cold identity check covers them.  Returns
    per-phase metrics, raw per-decision latencies (``_lat``), and the
    decision trace (admitted/reason/via)."""
    rng = random.Random(seed + 1)
    ctl = AdmissionController(policy="ioctl", wait_mode="suspend",
                              n_cpus=N_CPUS, n_devices=1,
                              warm_start=warm)
    phases: Dict[str, dict] = {}
    latencies: Dict[str, List[float]] = {}
    trace: List[Tuple[bool, Optional[str], Optional[str]]] = []
    i = 0
    for phase, burst in schedule:
        profs = [_profile(i + k, rng) for k in range(burst)]
        i += burst
        # churn: the arrivals displace the oldest admitted profiles —
        # each RT release invalidates the warm cache, which is the point
        release = ([p.name for p in ctl.admitted[:burst]]
                   if phase == "churn" else [])
        t0 = time.perf_counter()
        for name in release:
            ctl.release(name)
        if backend == "scalar":
            decs = [ctl.try_admit(p) for p in profs]
        else:
            decs = ctl.try_admit_many(profs, backend=backend)
        dt = time.perf_counter() - t0
        trace.extend((d["admitted"], d.get("reason"), d.get("via"))
                     for d in decs)
        if phase == "warmup":
            continue
        row = phases.setdefault(
            phase, {"arrivals": 0, "accepted": 0, "wall_s": 0.0})
        row["wall_s"] += dt
        row["arrivals"] += burst
        row["accepted"] += sum(d["admitted"] for d in decs)
        latencies.setdefault(phase, []).extend(
            d["latency_ms"] for d in decs)
    return {"warm_start": warm, "backend": backend,
            "admitted_final": len(ctl.admitted),
            "phases": phases, "_lat": latencies, "_trace": trace}


def bench_backend(backend: str, schedules: Dict[str, List[Tuple[str, int]]],
                  *, seed: int, reps: int) -> dict:
    """warm-vs-cold comparison on one backend: each regime's stream is
    run ``reps`` times per mode (fresh controllers each pass),
    identity-checked pass by pass, then summed.

    One untimed pass of each mode over each stream precedes the timed
    ones: the jax jit cache (and numpy/lru warmup) is process-global,
    so whichever mode ran first would otherwise pay every shape-bucket
    compilation for both and the comparison would measure compile
    order, not the decision path."""
    agg = {True: {}, False: {}}
    lat = {True: {}, False: {}}
    admitted_final = {True: {}, False: {}}
    for name, sched in schedules.items():
        # per-stream warmup immediately before its timed reps, and all
        # of a stream's reps back to back: the saturated stream's
        # Audsley-retry load measurably perturbs a growth pass that
        # follows it (allocator / frequency state), so regimes must not
        # interleave
        for w in (True, False):
            run_stream(sched, warm=w, backend=backend, seed=seed)
        for rep in range(reps):
            # alternate execution order so slow drift in the host
            # (thermal, co-tenant load) cancels instead of biasing one
            # mode
            order = (True, False) if rep % 2 == 0 else (False, True)
            runs = {m: run_stream(sched, warm=m, backend=backend,
                                  seed=seed) for m in order}
            if runs[True].pop("_trace") != runs[False].pop("_trace"):
                raise AssertionError(
                    f"warm/cold decision divergence on backend "
                    f"{backend!r}, stream {name!r}")
            for m in (True, False):
                r = runs[m]
                admitted_final[m][name] = r["admitted_final"]
                for p, row in r["phases"].items():
                    dst = agg[m].setdefault(
                        p, {"arrivals": 0, "accepted": 0, "wall_s": 0.0})
                    for k in dst:
                        dst[k] += row[k]
                for p, ls in r["_lat"].items():
                    lat[m].setdefault(p, []).extend(ls)

    def fold(m: bool) -> dict:
        phases = agg[m]
        for p, row in phases.items():
            w = row["wall_s"]
            row["wall_s"] = round(w, 4)
            row["admissions_per_s"] = \
                round(row["accepted"] / w, 1) if w else None
            row["decisions_per_s"] = \
                round(row["arrivals"] / w, 1) if w else None
            row["latency_ms"] = _percentiles(lat[m].get(p, []))
        return {"warm_start": m, "backend": backend,
                "admitted_final": admitted_final[m],
                "phases": phases,
                "latency_ms": _percentiles(
                    [v for ls in lat[m].values() for v in ls])}

    warm, cold = fold(True), fold(False)
    gw = warm["phases"]["growth"]["admissions_per_s"]
    gc = cold["phases"]["growth"]["admissions_per_s"]
    criterion = {
        "metric": "sustained admissions/sec, growth phase",
        "warm_admissions_per_s": gw,
        "cold_admissions_per_s": gc,
        "ratio": round(gw / gc, 2) if gw and gc else None,
        "target_ratio": 2.0,
        "met": bool(gw and gc and gw / gc >= 2.0),
    }
    return {"warm": warm, "cold": cold,
            "identical_decisions": True, "criterion": criterion}


def main() -> None:
    import argparse
    import json

    ap = argparse.ArgumentParser(description=__doc__.split("\n")[0])
    ap.add_argument("--quick", action="store_true",
                    help="CI-sized stream (grow to 64 tasks, 6 churn "
                         "rounds, 32 post-capacity arrivals, 6 reps)")
    ap.add_argument("--seed", type=int, default=3)
    ap.add_argument("--lam", type=float, default=8.0,
                    help="Poisson burst-size mean (default 8)")
    ap.add_argument("--reps", type=int, default=0,
                    help="stream passes per backend (0 = profile default)")
    ap.add_argument("--scalar", action="store_true",
                    help="also run the sequential try_admit path")
    ap.add_argument("--no-jax", action="store_true",
                    help="skip the jax backend even if importable")
    ap.add_argument("--json", default=None, metavar="PATH",
                    help="write BENCH_admission.json for the CI gate")
    args = ap.parse_args()

    if args.quick:
        grow_to, churn_rounds, sat_arrivals = 64, 6, 32
        reps = args.reps or 6
    else:
        grow_to, churn_rounds, sat_arrivals = 96, 12, 48
        reps = args.reps or 4
    schedules = _schedules(args.seed, grow_to, churn_rounds,
                           sat_arrivals, args.lam)

    backends = ["numpy"]
    if not args.no_jax:
        backends.append("jax")
    if args.scalar:
        backends.append("scalar")

    result = {"marker": MARKER, "quick": bool(args.quick),
              "profile": {"grow_to": grow_to,
                          "churn_rounds": churn_rounds,
                          "sat_arrivals": sat_arrivals,
                          "lam": args.lam, "seed": args.seed,
                          "reps": reps, "n_cpus": N_CPUS},
              "backends": {}}
    for be in backends:
        if be == "jax":
            # deferred import: the jax runtime must not be resident (its
            # compile/dispatch threads add noise) while numpy is timed
            try:
                from repro.core.batch_jax import HAVE_JAX
            except Exception:
                HAVE_JAX = False
            if not HAVE_JAX:
                print("   jax: skipped (jax not importable)")
                continue
        t0 = time.time()
        row = bench_backend(be, schedules, seed=args.seed, reps=reps)
        row["bench_wall_s"] = round(time.time() - t0, 1)
        result["backends"][be] = row
        crit = row["criterion"]
        print(f"{be:>6}: growth warm {crit['warm_admissions_per_s']}/s "
              f"cold {crit['cold_admissions_per_s']}/s "
              f"ratio {crit['ratio']}x (target 2.0x, "
              f"{'met' if crit['met'] else 'NOT met'}); "
              f"p50 {row['warm']['latency_ms'].get('p50_ms')}ms "
              f"p99 {row['warm']['latency_ms'].get('p99_ms')}ms")

    if args.json:
        with open(args.json, "w") as f:
            json.dump(result, f, indent=2)
        print(f"wrote {args.json}")


if __name__ == "__main__":
    main()
