"""Roofline analysis (deliverable g) over the dry-run artifacts.

Per (arch x shape x mesh) cell, three per-device roofline terms in seconds:

  compute    = HLO_FLOPs / peak_FLOPs            (197 TFLOP/s bf16, v5e)
  memory     = HLO_bytes / HBM_bw                (819 GB/s)
  collective = collective_bytes / link_bw        (50 GB/s/link ICI)

HLO numbers come from cost_analysis()/HLO-text parsing with the scan-body
correction (launch/hlo_analysis.py).  Two documented adjustments:
  * chunked-attention scans count one KV chunk; the analytic closed-form
    attention FLOPs for the remaining chunks are added (exact math).
  * "pod" axis collectives (gradient reduction) are DCN-class; they are
    reported within the same collective term (conservative).

MODEL_FLOPS uses 6*N_active*D (train) / 2*N_active*D (prefill/decode) plus
the attention term; the ratio MODEL_FLOPS/HLO_FLOPs flags remat/dispatch
waste.  No pass/fail gate — the deliverable is the table and the §Perf
iteration log driving the dominant term down."""
from __future__ import annotations

import json
from typing import Dict, List, Optional

PEAK_FLOPS = 197e12     # bf16 per chip
HBM_BW = 819e9          # bytes/s per chip
LINK_BW = 50e9          # bytes/s per ICI link
ATTN_CHUNK = 2048       # ref.attention_chunked chunk size


def _arch_cfg(arch: str):
    from repro.configs import get
    return get(arch).config()


def _attention_flops(cfg, shape_kind: str, seq: int, batch: int,
                     per_device: int) -> Dict[str, float]:
    """Closed-form attention FLOPs (global), and the single-chunk portion
    already present in the measured HLO numbers."""
    n_attn = sum(1 for sp in cfg.pattern if sp.kind == "attn") * cfg.repeats
    n_cross = sum(1 for sp in cfg.pattern
                  if sp.kind == "cross") * cfg.repeats
    d_attn = cfg.n_heads * cfg.head_dim
    if shape_kind == "decode":
        ctx = seq if cfg.window is None else min(seq, cfg.window)
        fwd = 4.0 * batch * ctx * d_attn * n_attn
        fwd += 4.0 * batch * cfg.cross_source_len * d_attn * n_cross
        return {"total": fwd, "in_hlo": fwd}  # no chunk scan in decode
    kv = seq if cfg.window is None else min(seq, cfg.window)
    causal_frac = 0.5 if cfg.window is None else 1.0
    fwd_self = 4.0 * batch * seq * kv * causal_frac * d_attn * n_attn
    fwd_cross = 4.0 * batch * seq * cfg.cross_source_len * d_attn * n_cross
    mult = 3.0 if shape_kind == "train" else 1.0  # fwd+bwd
    total = (fwd_self + fwd_cross) * mult
    n_chunks = max(seq // min(getattr(cfg, "attn_chunk", ATTN_CHUNK), seq), 1)
    if seq <= getattr(cfg, "attn_chunk", ATTN_CHUNK):
        n_chunks = 1
    # the HLO counts one chunk of each self-attention scan (cross is dense)
    in_hlo = (fwd_self / n_chunks + fwd_cross) * mult
    return {"total": total, "in_hlo": in_hlo}


def _recurrence_flops(cfg, shape_kind: str, seq: int, batch: int) -> float:
    """Closed-form SSM/WKV recurrence FLOPs (global).  The time dimension
    is a lax.scan in the reference path, so the HLO counts one timestep —
    these terms are added analytically (like the attention chunks)."""
    n_mamba = sum(1 for sp in cfg.pattern
                  if sp.kind == "mamba") * cfg.repeats
    n_rwkv = sum(1 for sp in cfg.pattern if sp.kind == "rwkv") * cfg.repeats
    steps = batch * (seq if shape_kind != "decode" else 1)
    fwd = 0.0
    if n_mamba:
        fwd += 8.0 * steps * cfg.mamba_d_inner * cfg.mamba_d_state * n_mamba
    if n_rwkv:
        fwd += 8.0 * steps * cfg.rwkv_heads * cfg.rwkv_head_dim ** 2 \
            * n_rwkv
    return fwd * (3.0 if shape_kind == "train" else 1.0)


def model_flops(rec: dict, cfg) -> float:
    """6*N_flops*D for train, 2*N_flops*D for inference, plus attention and
    recurrence terms.  N_flops excludes the input embedding table (a
    gather, not a matmul) unless it is tied (then it appears once, as the
    unembedding)."""
    from repro.configs import SHAPES
    shape = SHAPES[rec["shape"]]
    n_act = rec["params_active"]
    if not cfg.tie_embeddings:
        n_act = n_act - cfg.vocab_size * cfg.d_model
    if shape.kind == "train":
        toks = shape.global_batch * shape.seq_len
        base = 6.0 * n_act * toks
    elif shape.kind == "prefill":
        toks = shape.global_batch * shape.seq_len
        base = 2.0 * n_act * toks
    else:
        base = 2.0 * n_act * shape.global_batch
    attn = _attention_flops(cfg, shape.kind, shape.seq_len,
                            shape.global_batch, rec["n_devices"])["total"]
    rec_f = _recurrence_flops(cfg, shape.kind, shape.seq_len,
                              shape.global_batch)
    return base + attn + rec_f


def analyze_cell(rec: dict, cfg=None) -> Optional[dict]:
    if not rec.get("ok") or rec.get("skipped"):
        return None
    from repro.configs import SHAPES
    if cfg is None:
        cfg = _arch_cfg(rec["arch"])
    shape = SHAPES[rec["shape"]]
    nd = rec["n_devices"]

    attn = _attention_flops(cfg, shape.kind, shape.seq_len,
                            shape.global_batch, nd)
    rec_f = _recurrence_flops(cfg, shape.kind, shape.seq_len,
                              shape.global_batch)
    flops_dev = rec["cost_corrected"]["flops"] \
        + (attn["total"] - attn["in_hlo"] + rec_f) / nd
    bytes_dev = rec["cost_corrected"]["bytes"]
    coll_dev = rec["collectives_corrected"].get("total", 0.0)

    t_compute = flops_dev / PEAK_FLOPS
    t_memory = bytes_dev / HBM_BW
    t_coll = coll_dev / LINK_BW
    terms = {"compute": t_compute, "memory": t_memory,
             "collective": t_coll}
    dominant = max(terms, key=terms.get)
    bottleneck_t = terms[dominant]

    mf = model_flops(rec, cfg)
    mf_dev = mf / nd
    useful_ratio = mf_dev / max(flops_dev, 1e-30)
    # achievable fraction of peak FLOPs given the bottleneck:
    roofline_fraction = (mf_dev / PEAK_FLOPS) / max(bottleneck_t, 1e-30)

    return {
        "arch": rec["arch"], "shape": rec["shape"], "mesh": rec["mesh"],
        "kind": rec["kind"],
        "t_compute_s": t_compute, "t_memory_s": t_memory,
        "t_collective_s": t_coll, "dominant": dominant,
        "model_flops_per_dev": mf_dev, "hlo_flops_per_dev": flops_dev,
        "useful_ratio": useful_ratio,
        "roofline_fraction": min(roofline_fraction, 1.0),
        "peak_hbm_gb": rec["peak_hbm_bytes"] / 1e9,
        "fits_hbm": rec["fits_hbm"],
        "collectives": {k: v for k, v in
                        rec["collectives_corrected"].items()
                        if k != "total"},
    }


def load(path: str = "benchmarks/results/dryrun.json") -> List[dict]:
    with open(path) as f:
        data = json.load(f)
    rows = []
    for rec in data.values():
        row = analyze_cell(rec)
        if row is not None:
            rows.append(row)
    return rows


def table(rows: List[dict], mesh: str = "pod16x16") -> str:
    """EXPERIMENTS.md-ready markdown table (single-pod per the spec)."""
    hdr = ("| arch | shape | compute s | memory s | coll s | dominant | "
           "useful | roofline |\n|---|---|---|---|---|---|---|---|")
    lines = [hdr]
    for r in sorted(rows, key=lambda r: (r["arch"], r["shape"])):
        if r["mesh"] != mesh:
            continue
        lines.append(
            f"| {r['arch']} | {r['shape']} | {r['t_compute_s']:.3e} | "
            f"{r['t_memory_s']:.3e} | {r['t_collective_s']:.3e} | "
            f"{r['dominant']} | {r['useful_ratio']:.2f} | "
            f"{r['roofline_fraction']:.3f} |")
    return "\n".join(lines)


def pick_hillclimb_cells(rows: List[dict]) -> Dict[str, dict]:
    """Worst roofline fraction, most collective-bound, and the cell most
    representative of the paper's technique (the serving decode cell the
    preemptive executor schedules most often)."""
    single = [r for r in rows if r["mesh"] == "pod16x16"]
    worst = min(single, key=lambda r: r["roofline_fraction"])
    coll = max(single, key=lambda r: (r["t_collective_s"]
                                      / max(max(r["t_compute_s"],
                                                r["t_memory_s"]), 1e-30)))
    paper = [r for r in single
             if r["kind"] == "decode" and r["arch"] == "smollm-135m"]
    return {"worst_roofline": worst, "most_collective": coll,
            "paper_representative": paper[0] if paper else single[0]}


if __name__ == "__main__":
    rows = load()
    print(table(rows))
    picks = pick_hillclimb_cells(rows)
    for k, v in picks.items():
        print(f"{k}: {v['arch']}|{v['shape']} dominant={v['dominant']} "
              f"roofline={v['roofline_fraction']:.3f}")
