"""§Perf hillclimbing driver (deliverable g + the grading axis).

Each iteration = hypothesis -> config/sharding change -> re-lower ->
re-analyse the three roofline terms.  Results accumulate in
benchmarks/results/perf_iterations.json; EXPERIMENTS.md §Perf narrates the
hypothesis/confirmation log.

MUST run with 512 host devices:
  PYTHONPATH=src python -m benchmarks.perf_iterations --cell <name>
"""
import os
os.environ.setdefault("XLA_FLAGS",
                      "--xla_force_host_platform_device_count=512")

import argparse    # noqa: E402
import dataclasses  # noqa: E402
import json        # noqa: E402
import sys         # noqa: E402

sys.path.insert(0, os.path.join(
    os.path.dirname(os.path.dirname(os.path.abspath(__file__))), "src"))
sys.path.insert(0, os.path.dirname(os.path.dirname(
    os.path.abspath(__file__))))

RESULTS = os.path.join(os.path.dirname(os.path.abspath(__file__)),
                       "results", "perf_iterations.json")


def measure(arch: str, shape: str, cfg=None, label: str = "baseline"):
    import jax  # noqa: F401
    from benchmarks import roofline
    from repro.launch.dryrun import run_cell
    from repro.launch.mesh import make_production_mesh
    mesh = make_production_mesh()
    rec = run_cell(arch, shape, mesh, "pod16x16", components=True, cfg=cfg)
    if not rec.get("ok"):
        return {"label": label, "arch": arch, "shape": shape, "ok": False,
                "error": rec.get("error")}
    row = roofline.analyze_cell(rec, cfg=cfg)
    row.update({"label": label, "ok": True,
                "peak_hbm_gb": rec["peak_hbm_bytes"] / 1e9,
                "collective_breakdown": rec["collectives_corrected"]})
    return row


def record(row: dict) -> None:
    data = []
    if os.path.exists(RESULTS):
        with open(RESULTS) as f:
            data = json.load(f)
    data = [r for r in data
            if not (r.get("label") == row.get("label")
                    and r.get("arch") == row.get("arch")
                    and r.get("shape") == row.get("shape"))]
    data.append(row)
    os.makedirs(os.path.dirname(RESULTS), exist_ok=True)
    with open(RESULTS, "w") as f:
        json.dump(data, f, indent=1, default=str)
    if row.get("ok"):
        print(f"[{row['label']}] {row['arch']}|{row['shape']}: "
              f"compute={row['t_compute_s']:.3e}s "
              f"memory={row['t_memory_s']:.3e}s "
              f"coll={row['t_collective_s']:.3e}s "
              f"dominant={row['dominant']} "
              f"roofline={row['roofline_fraction']:.4f} "
              f"hbm={row['peak_hbm_gb']:.1f}GB")
    else:
        print(f"[{row['label']}] FAILED: {row.get('error', '')[:200]}")


# ---------------------------------------------------------------------------
# variant builders per hillclimbed cell
# ---------------------------------------------------------------------------

def internlm2_train_variants():
    """Collective-bound dense training: TP+SP baseline vs alternatives."""
    from repro.configs import get
    base = get("internlm2-20b").config()
    yield "baseline_tp_sp", None
    # H1: save matmul outputs in remat -> backward skips re-gathering
    yield "remat_dots", dataclasses.replace(
        base, remat_policy="dots_with_no_batch_dims_saveable")
    # H2: pure ZeRO-3 data parallelism -> weight gathers replace activation
    # gathers (bytes: params*3 << activations*layers)
    yield "fsdp_dp", dataclasses.replace(base, sharding_profile="fsdp_dp",
                                         fsdp=False)
    # H3: fsdp_dp + cheaper remat
    yield "fsdp_dp_remat_dots", dataclasses.replace(
        base, sharding_profile="fsdp_dp", fsdp=False,
        remat_policy="dots_with_no_batch_dims_saveable")
    # H4 (memory term): single-chunk attention — one pass over scores
    # instead of a 2-chunk online-softmax scan (fewer q/acc re-reads);
    # per-device scores (1,48,4096,4096)f32 fit under fsdp_dp
    yield "fsdp_dp_attn1chunk", dataclasses.replace(
        base, sharding_profile="fsdp_dp", fsdp=False, attn_chunk=4096)


def mixtral_train_variants():
    """MoE training: dispatch gathers dominate the collective term."""
    from repro.configs import get
    base = get("mixtral-8x22b").config()
    yield "baseline_tp", None
    # H1: more dispatch chunks -> smaller token gathers (same total bytes,
    # smaller working set; tests whether bytes or buffer size dominates)
    yield "moe_chunks8", dataclasses.replace(base, moe_seq_chunks=8)
    # H2: fsdp_dp — experts unsharded (each device runs all experts on its
    # local tokens: dispatch becomes device-local, no token all-gather)
    yield "fsdp_dp_local_experts", dataclasses.replace(
        base, sharding_profile="fsdp_dp", fsdp=False)
    # H3: local experts + dots remat
    yield "fsdp_dp_remat_dots", dataclasses.replace(
        base, sharding_profile="fsdp_dp", fsdp=False,
        remat_policy="dots_with_no_batch_dims_saveable")


def smollm_decode_variants():
    """The paper-representative serving cell: decode latency is the
    executor's preemption quantum."""
    from repro.configs import get
    base = get("smollm-135m").config()
    yield "baseline_hybrid", None
    # H1: pure DP — batch over both axes (128 over 256 fails -> data only),
    # params fully sharded
    yield "fsdp_dp", dataclasses.replace(base, sharding_profile="fsdp_dp")
    # H2: tp profile (9 heads indivisible -> MLP-only TP), batch over data
    yield "tp_mlp_only", dataclasses.replace(base, sharding_profile="tp")
    # H3 (code change, see kernels/ref.py + blocks._write_at): grouped-GQA
    # decode contraction (no KV repeat) + true scatter cache write (no
    # full-cache select).  Measured with the same baseline config.
    yield "opt_decode_path", None


CELLS = {
    "internlm2_train": ("internlm2-20b", "train_4k",
                        internlm2_train_variants),
    "mixtral_train": ("mixtral-8x22b", "train_4k", mixtral_train_variants),
    "smollm_decode": ("smollm-135m", "decode_32k", smollm_decode_variants),
}


def main() -> None:
    ap = argparse.ArgumentParser()
    ap.add_argument("--cell", required=True, choices=list(CELLS))
    ap.add_argument("--only", default="")
    args = ap.parse_args()
    arch, shape, gen = CELLS[args.cell]
    for label, cfg in gen():
        if args.only and label not in args.only.split(","):
            continue
        try:
            row = measure(arch, shape, cfg=cfg, label=label)
        except Exception as e:  # noqa: BLE001
            row = {"label": label, "arch": arch, "shape": shape,
                   "ok": False, "error": f"{type(e).__name__}: {e}"}
        record(row)


if __name__ == "__main__":
    main()
