"""Benchmark orchestrator — one section per paper table/figure.

  PYTHONPATH=src python -m benchmarks.run [--quick] [--only X,Y]

Prints ``name,us_per_call,derived`` CSV lines (contract of the original
scaffold) and writes full results to benchmarks/results/*.json.
"""
from __future__ import annotations

import argparse
import json
import os
import sys
import time

sys.path.insert(0, os.path.dirname(os.path.dirname(
    os.path.abspath(__file__))))

RESULTS = os.path.join(os.path.dirname(os.path.abspath(__file__)),
                       "results")


def _save(name: str, rows) -> None:
    os.makedirs(RESULTS, exist_ok=True)
    with open(os.path.join(RESULTS, f"{name}.json"), "w") as f:
        json.dump(rows, f, indent=1)


def _csv(name: str, t_us: float, derived: str) -> None:
    print(f"{name},{t_us:.1f},{derived}")


def bench_policies():
    """Simulate a small fixed taskset under EVERY registered scheduling
    policy (resolved by name from repro.core.policy) — a new policy
    registered anywhere shows up here with no further edits."""
    from repro.core import (GenParams, available_policies, generate_taskset,
                            simulate)
    ts = generate_taskset(0, GenParams(n_cpus=2, tasks_per_cpu=(2, 3),
                                       epsilon=0.5))
    ts.kthread_cpu = ts.n_cpus
    horizon = 4 * max(t.period for t in ts.tasks)
    rows = []
    t0 = time.time()
    for name in available_policies():
        res = simulate(ts, name, horizon=horizon)
        rows.append({"policy": name,
                     "max_mort_ms": round(max(res.mort.values()), 3),
                     "misses": sum(res.deadline_misses.values())})
    _save("policies", rows)
    per = (time.time() - t0) * 1e6 / max(len(rows), 1)
    _csv("policy_registry_sim", per,
         "policies=" + "|".join(r["policy"] for r in rows))
    return rows


def bench_schedulability(n: int):
    from benchmarks import schedulability
    t0 = time.time()
    rows = schedulability.run(n, workers=schedulability.default_workers())
    _save("schedulability", rows)
    per = (time.time() - t0) * 1e6 / max(len(rows) * n * 5, 1)
    # headline: peak advantage of our best approach over the best baseline
    best_gap = max(
        (max(r["ioctl_busy"], r["ioctl_suspend"])
         - max(r["mpcp"], r["fmlp+"])) for r in rows)
    _csv("schedulability_figs7_12", per,
         f"max_gap_vs_baselines={best_gap:.2f}")
    return rows


def bench_prio_and_improved(n: int):
    from benchmarks.prio_and_improved import (fig13_gpu_priority_gain,
                                              fig14_improved_analysis_gain)
    t0 = time.time()
    rows13 = fig13_gpu_priority_gain(n)
    rows14 = fig14_improved_analysis_gain(n)
    _save("fig13_gpu_priority", rows13)
    _save("fig14_improved", rows14)
    per = (time.time() - t0) * 1e6 / max((len(rows13) + len(rows14)) * n, 1)
    gain13 = max(r["ioctl_busy+gpu_prio"] - r["ioctl_busy"] for r in rows13)
    gain14 = max(r["ioctl_busy+improved"] - r["ioctl_busy"] for r in rows14)
    _csv("fig13_gpu_priority_gain", per, f"max_gain={gain13:.2f}")
    _csv("fig14_improved_gain", per, f"max_gain={gain14:.2f}")


def bench_case_study(duration: float):
    from benchmarks.case_study import run_case_study
    t0 = time.time()
    rows = run_case_study(duration_s=duration)
    _save("case_study", rows)
    rt = [r for r in rows if r.get("rt")]
    ok = all(r["mort_ms"] <= r["wcrt_ms"] * 1.0 + 1e-9 for r in rt
             if r["wcrt_ms"] == r["wcrt_ms"] and r["mode"] != "unmanaged")
    misses = sum(r["misses"] for r in rt)
    _csv("case_study_table4", (time.time() - t0) * 1e6 / max(len(rows), 1),
         f"mort_within_wcrt={ok};rt_deadline_misses={misses}")


def bench_overhead():
    from benchmarks import overhead
    t0 = time.time()
    rows = overhead.run()
    _save("overhead", rows)
    _csv("overhead_table5", (time.time() - t0) * 1e6,
         f"ioctl_median_us={rows[0]['median_us']}")


def bench_roofline():
    from benchmarks import roofline
    path = os.path.join(RESULTS, "dryrun.json")
    if not os.path.exists(path):
        print("roofline: no dryrun.json yet — run repro.launch.dryrun",
              file=sys.stderr)
        return
    t0 = time.time()
    rows = roofline.load(path)
    _save("roofline", rows)
    single = [r for r in rows if r["mesh"] == "pod16x16"]
    if single:
        med = sorted(r["roofline_fraction"] for r in single)[
            len(single) // 2]
        picks = roofline.pick_hillclimb_cells(rows)
        _csv("roofline_table", (time.time() - t0) * 1e6 / len(rows),
             f"cells={len(single)};median_fraction={med:.3f};"
             f"worst={picks['worst_roofline']['arch']}|"
             f"{picks['worst_roofline']['shape']}")


def main() -> None:
    ap = argparse.ArgumentParser()
    ap.add_argument("--quick", action="store_true")
    ap.add_argument("--only", default="")
    ap.add_argument("--n", type=int, default=0,
                    help="tasksets per sweep point (0 = auto)")
    args = ap.parse_args()
    n = args.n or (40 if args.quick else 200)
    dur = 4.0 if args.quick else 8.0
    only = set(args.only.split(",")) if args.only else None

    def want(name):
        return only is None or name in only

    print("name,us_per_call,derived")
    if want("policies"):
        bench_policies()
    if want("schedulability"):
        bench_schedulability(n)
    if want("prio"):
        bench_prio_and_improved(n)
    if want("case_study"):
        bench_case_study(dur)
    if want("overhead"):
        bench_overhead()
    if want("roofline"):
        bench_roofline()


if __name__ == "__main__":
    main()
