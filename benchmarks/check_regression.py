"""CI gate: compare a schedulability-sweep result JSON against the
committed baseline (benchmarks/results/ci_baseline.json).

Fails (exit 1) when wall-clock regresses more than --max-regression
(default 25%) over the baseline.  Acceptance-ratio drift is reported but
does not gate here: the sweep seeds are fixed, so ratios only move when
the analysis itself changes — which the soundness job and the golden
vectors in tests/test_analysis.py adjudicate, not a perf gate.

The baseline records the sweep configuration (n, workers); the CI job
pins --workers to the baseline's value so the comparison is
parallelism-for-parallelism.  Wall-clock still depends on host
hardware: if runner hardware shifts the floor, regenerate the baseline
from the job's uploaded artifact rather than widening the margin.

Usage:
    python benchmarks/schedulability.py --quick --json current.json
    python benchmarks/check_regression.py current.json
"""

from __future__ import annotations

import argparse
import json
import sys


def load(path: str) -> dict:
    with open(path) as f:
        return json.load(f)


def drifted_rows(current: dict, baseline: dict) -> list[str]:
    base_by_key = {
        (r.get("sweep"), r.get("x")): r for r in baseline.get("rows", [])
    }
    drifts = []
    for row in current.get("rows", []):
        base = base_by_key.get((row.get("sweep"), row.get("x")))
        if base is None:
            continue
        for method, value in row.items():
            if method in ("sweep", "x") or method not in base:
                continue
            if abs(value - base[method]) > 1e-9:
                drifts.append(
                    f"{row['sweep']} x={row['x']} {method}: "
                    f"{base[method]:.3f} -> {value:.3f}"
                )
    return drifts


def main() -> int:
    ap = argparse.ArgumentParser(description=__doc__.split("\n")[0])
    ap.add_argument("current", help="result JSON from --json")
    ap.add_argument(
        "--baseline", default="benchmarks/results/ci_baseline.json"
    )
    ap.add_argument(
        "--max-regression",
        type=float,
        default=0.25,
        help="allowed fractional wall-clock slowdown (default 0.25)",
    )
    args = ap.parse_args()

    current = load(args.current)
    baseline = load(args.baseline)
    cur_s = float(current["wall_clock_s"])
    base_s = float(baseline["wall_clock_s"])
    for key in ("n", "workers"):
        if current.get(key) != baseline.get(key):
            print(
                f"note: sweep configs differ (current {key}="
                f"{current.get(key)}, baseline {key}={baseline.get(key)}) "
                "— wall-clock gate is apples-to-oranges",
                file=sys.stderr,
            )

    for line in drifted_rows(current, baseline):
        print(f"acceptance drift (informational): {line}")

    limit = base_s * (1.0 + args.max_regression)
    print(
        f"wall-clock: current {cur_s:.1f}s vs baseline {base_s:.1f}s "
        f"(limit {limit:.1f}s)"
    )
    if cur_s > limit:
        print(
            f"FAIL: sweep wall-clock regressed more than "
            f"{args.max_regression:.0%} over baseline",
            file=sys.stderr,
        )
        return 1
    print("OK: within budget")
    return 0


if __name__ == "__main__":
    sys.exit(main())
