"""CI gate: compare schedulability-sweep and admission-throughput
result JSONs against the committed baseline
(benchmarks/results/ci_baseline.json).

Three gates (exit 1 on any), applied per result file:

  * **wall-clock** — fails when a sweep regresses more than
    --max-regression (default 25%) over the baseline entry *of the same
    backend* (like-for-like only: a NumPy result is never timed against
    the JAX baseline and vice versa — the backends have different cost
    models, so a cross comparison gates nothing meaningful);
  * **acceptance ratios** — fails on *any* drift from the baseline rows.
    The sweep seeds are fixed and both vectorized backends are pinned
    decision-identical to the scalar reference, so ratios only move when
    the analysis itself changes — a silent result change from a backend
    or analysis edit must show up as a named CI failure, not as a perf
    footnote.  Intentional analysis changes regenerate the baseline
    (and justify it in the PR);
  * **admission throughput** — a BENCH_admission.json result (marker
    ``admission-bench-v1`` from ``admission_bench.py --quick --json``)
    is gated per backend against ``baseline["admission"]``: sustained
    growth-phase admissions/sec must not drop more than
    --max-regression below baseline, and warm p50/p99 decision latency
    must not rise more than --max-regression above it.  The warm/cold
    speedup ratio is reported (and carried in the trajectory artifact)
    but not gated on its own — it divides two wall-clocks, so host
    noise moves it twice.

The sweep baseline is keyed per backend: ``{"backends": {tag: result}}``,
where each entry records its own sweep configuration (n, workers) so
the CI job can pin the matching flags.  The legacy flat single-result
format still loads (its ``backend`` field names its only entry).
Wall-clock still depends on host hardware: if runner hardware shifts
the floor, regenerate the baseline from the job's uploaded artifacts
rather than widening the margin.

--emit-trajectory PATH writes the perf-trajectory artifact: per-backend
wall-clock (total and per sweep) for every result passed, plus the
scale-demo record (the "JAX 10k vs NumPy 1k" criterion measurement from
``schedulability.py --scale-demo``) when one of the results carries it.
CI uploads it as ``BENCH_sweep.json`` so every push leaves a comparable
perf datapoint next to the full rows.

Usage:
    python benchmarks/schedulability.py --quick --json numpy.json
    python benchmarks/schedulability.py --quick --backend jax --json jax.json
    python benchmarks/schedulability.py --scale-demo --json demo.json
    python benchmarks/admission_bench.py --quick --json admission.json
    python benchmarks/check_regression.py numpy.json jax.json demo.json \
        admission.json --emit-trajectory BENCH_sweep.json
"""

from __future__ import annotations

import argparse
import json
import shutil
import sys
from pathlib import Path

#: repo root — BENCH_*.json artifacts are mirrored here so the
#: cross-PR perf trajectory is discoverable in the tree itself, not
#: only in CI artifact zips
REPO_ROOT = Path(__file__).resolve().parent.parent


def load(path: str) -> dict:
    with open(path) as f:
        return json.load(f)


def mirror_bench_artifacts(paths: list[str]) -> None:
    """Copy every ``BENCH_*.json`` the gate touched to the repo root
    (skipping ones already there), so each push leaves the trajectory
    next to the code."""
    for p in paths:
        src = Path(p)
        if not (src.name.startswith("BENCH_") and src.suffix == ".json"):
            continue
        if not src.exists():
            continue
        dst = REPO_ROOT / src.name
        if src.resolve() == dst.resolve():
            continue
        shutil.copyfile(src, dst)
        print(f"mirrored {src} -> {dst}")


def baseline_entries(baseline: dict) -> dict:
    """Per-backend baseline map; a legacy flat baseline becomes the one
    entry its ``backend`` field names."""
    if "backends" in baseline:
        return baseline["backends"]
    return {baseline.get("backend", "scalar"): baseline}


def drifted_rows(current: dict, baseline: dict) -> list[str]:
    """Every baseline datapoint must reappear in the current result with
    the same value — a row or method that *disappears* is a silent
    result change too, so absences count as drift in both directions."""
    cur_by_key = {
        (r.get("sweep"), r.get("x")): r for r in current.get("rows", [])
    }
    drifts = []
    for base in baseline.get("rows", []):
        key = (base.get("sweep"), base.get("x"))
        row = cur_by_key.get(key)
        if row is None:
            drifts.append(f"{key[0]} x={key[1]}: row missing from current")
            continue
        for method, expected in base.items():
            if method in ("sweep", "x"):
                continue
            if method not in row:
                drifts.append(
                    f"{key[0]} x={key[1]} {method}: missing from current"
                )
            elif abs(row[method] - expected) > 1e-9:
                drifts.append(
                    f"{key[0]} x={key[1]} {method}: "
                    f"{expected:.3f} -> {row[method]:.3f}"
                )
        extra = set(row) - set(base) - {"sweep", "x"}
        for method in sorted(extra):
            drifts.append(
                f"{key[0]} x={key[1]} {method}: not in baseline "
                f"(-> {row[method]:.3f})"
            )
    return drifts


def trajectory_entry(current: dict) -> dict:
    """One backend's perf-trajectory datapoint."""
    return {
        "wall_clock_s": current.get("wall_clock_s"),
        "sweep_wall_clock_s": current.get("sweep_wall_clock_s", {}),
        "n": current.get("n"),
        "workers": current.get("workers"),
    }


def check_one(current: dict, bases: dict, max_regression: float) -> bool:
    """Gate one sweep result against its same-backend baseline entry.
    Returns True on failure."""
    tag = current.get("backend", "scalar")
    base = bases.get(tag)
    if base is None:
        print(
            f"note: no {tag!r} baseline entry — wall-clock and drift "
            "gates skipped for this result (commit one to enable them)",
            file=sys.stderr,
        )
        return False
    for key in ("n", "workers"):
        if current.get(key) != base.get(key):
            print(
                f"note: {tag} sweep configs differ (current {key}="
                f"{current.get(key)}, baseline {key}={base.get(key)}) "
                "— wall-clock gate is apples-to-oranges",
                file=sys.stderr,
            )

    failed = False
    drifts = drifted_rows(current, base)
    for line in drifts:
        print(f"acceptance drift [{tag}]: {line}", file=sys.stderr)
    if drifts:
        print(
            f"FAIL [{tag}]: {len(drifts)} acceptance ratio(s) drifted "
            "from the baseline — analysis results changed (regenerate "
            "the baseline only for an intentional, justified change)",
            file=sys.stderr,
        )
        failed = True

    cur_s = float(current["wall_clock_s"])
    base_s = float(base["wall_clock_s"])
    limit = base_s * (1.0 + max_regression)
    print(
        f"wall-clock [{tag}]: current {cur_s:.1f}s vs baseline "
        f"{base_s:.1f}s (limit {limit:.1f}s)"
    )
    if cur_s > limit:
        print(
            f"FAIL [{tag}]: sweep wall-clock regressed more than "
            f"{max_regression:.0%} over baseline",
            file=sys.stderr,
        )
        failed = True
    return failed


def admission_trajectory(current: dict) -> dict:
    """Per-backend admission-throughput trajectory datapoint."""
    out = {}
    for tag, row in current.get("backends", {}).items():
        crit = row.get("criterion", {})
        lat = row.get("warm", {}).get("latency_ms", {})
        out[tag] = {
            "warm_admissions_per_s": crit.get("warm_admissions_per_s"),
            "cold_admissions_per_s": crit.get("cold_admissions_per_s"),
            "ratio": crit.get("ratio"),
            "warm_p50_ms": lat.get("p50_ms"),
            "warm_p99_ms": lat.get("p99_ms"),
        }
    return out


def check_admission(current: dict, base: dict | None,
                    max_regression: float) -> bool:
    """Gate an admission-bench result against ``baseline["admission"]``.
    Returns True on failure."""
    if base is None:
        print(
            "note: no admission baseline section — admission gates "
            "skipped (commit one to enable them)",
            file=sys.stderr,
        )
        return False
    failed = False
    base_backends = base.get("backends", {})
    for tag, row in current.get("backends", {}).items():
        b = base_backends.get(tag)
        cur = admission_trajectory({"backends": {tag: row}})[tag]
        ratio = cur["ratio"]
        print(
            f"admission [{tag}]: warm {cur['warm_admissions_per_s']}/s "
            f"(warm/cold {ratio}x), p50 {cur['warm_p50_ms']}ms "
            f"p99 {cur['warm_p99_ms']}ms"
        )
        if b is None:
            print(
                f"note: no {tag!r} admission baseline entry — gates "
                "skipped for this backend",
                file=sys.stderr,
            )
            continue
        floor = b["warm_admissions_per_s"] * (1.0 - max_regression)
        if cur["warm_admissions_per_s"] < floor:
            print(
                f"FAIL [{tag}]: warm admissions/sec "
                f"{cur['warm_admissions_per_s']:.1f} below "
                f"{floor:.1f} (baseline {b['warm_admissions_per_s']:.1f} "
                f"- {max_regression:.0%})",
                file=sys.stderr,
            )
            failed = True
        for key in ("warm_p50_ms", "warm_p99_ms"):
            limit = b[key] * (1.0 + max_regression)
            if cur[key] > limit:
                print(
                    f"FAIL [{tag}]: {key} {cur[key]:.3f}ms above "
                    f"{limit:.3f}ms (baseline {b[key]:.3f}ms "
                    f"+ {max_regression:.0%})",
                    file=sys.stderr,
                )
                failed = True
    return failed


def fleet_trajectory(current: dict) -> dict:
    """Per-tier fleet trajectory datapoint (counts + tails)."""
    out = {
        "n_devices": current.get("n_devices"),
        "n_models": len(current.get("per_model", {})),
        "admission": current.get("admission", {}),
        "per_tier": {},
    }
    for tier, row in current.get("per_tier", {}).items():
        out["per_tier"][str(tier)] = {
            "completions": row.get("completions"),
            "deadline_misses": row.get("deadline_misses"),
            "p50_ms": row.get("p50_ms"),
            "p99_ms": row.get("p99_ms"),
            "mort_ms": row.get("mort_ms"),
        }
    return out


def check_fleet(current: dict) -> bool:
    """Gate a BENCH_fleet.json result (marker ``fleet-bench-v1``).
    Structural gates only — the fleet bench runs wall-clock workloads
    on shared runners, so latency values are recorded in the trajectory
    but never compared against a hardware-dependent ceiling.  Returns
    True on failure."""
    failed = False
    per_model = current.get("per_model", {})
    rt = {n: m for n, m in per_model.items() if not m.get("best_effort")}
    be = {n: m for n, m in per_model.items() if m.get("best_effort")}
    adm = current.get("admission", {})
    print(
        f"fleet: {len(rt)} RT + {len(be)} best-effort models, "
        f"admitted {adm.get('admitted')}/{adm.get('submitted')}"
    )
    if not rt or not be:
        print(
            "FAIL [fleet]: a mixed-criticality fleet needs at least one "
            f"RT and one best-effort model (got {len(rt)} RT, "
            f"{len(be)} BE)",
            file=sys.stderr,
        )
        failed = True
    if not adm.get("admitted"):
        print("FAIL [fleet]: no model was admitted", file=sys.stderr)
        failed = True
    for name, row in rt.items():
        if not row.get("completions"):
            print(
                f"FAIL [fleet]: RT model {name!r} completed no "
                "iterations — the fleet never actually ran",
                file=sys.stderr,
            )
            failed = True
        elif row.get("mort_ms") is None:
            print(
                f"FAIL [fleet]: RT model {name!r} reports no MORT",
                file=sys.stderr,
            )
            failed = True
    tiers = {m.get("tier") for m in per_model.values()}
    missing = tiers - {int(t) for t in current.get("per_tier", {})}
    if missing:
        print(
            f"FAIL [fleet]: tiers {sorted(missing)} present on models "
            "but absent from the per-tier rollup",
            file=sys.stderr,
        )
        failed = True
    return failed


def main() -> int:
    ap = argparse.ArgumentParser(description=__doc__.split("\n")[0])
    ap.add_argument(
        "current",
        nargs="+",
        help="result JSON(s) from --json — one per backend, plus "
        "optionally a --scale-demo result",
    )
    ap.add_argument(
        "--baseline", default="benchmarks/results/ci_baseline.json"
    )
    ap.add_argument(
        "--max-regression",
        type=float,
        default=0.25,
        help="allowed fractional wall-clock slowdown (default 0.25)",
    )
    ap.add_argument(
        "--emit-trajectory",
        default=None,
        metavar="PATH",
        help="write the perf-trajectory artifact (per-backend wall-clock "
        "per sweep + the scale-demo record) to PATH",
    )
    args = ap.parse_args()

    baseline = load(args.baseline)
    bases = baseline_entries(baseline)
    results = [load(p) for p in args.current]
    traj: dict = {"backends": {}}
    failed = False
    for current in results:
        if current.get("marker") == "admission-bench-v1":
            traj["admission"] = admission_trajectory(current)
            failed |= check_admission(
                current, baseline.get("admission"), args.max_regression)
            continue
        if current.get("marker") == "fleet-bench-v1":
            traj["fleet"] = fleet_trajectory(current)
            failed |= check_fleet(current)
            continue
        if "scale_demo" in current:
            traj["scale_demo"] = current["scale_demo"]
        if "rows" not in current:
            continue  # a pure scale-demo result carries no sweep gates
        traj["backends"][current.get("backend", "scalar")] = (
            trajectory_entry(current)
        )
        failed |= check_one(current, bases, args.max_regression)

    if args.emit_trajectory:
        with open(args.emit_trajectory, "w") as f:
            json.dump(traj, f, indent=2)
        print(f"wrote trajectory {args.emit_trajectory}")

    # every BENCH_*.json this gate read or wrote is mirrored to the
    # repo root — the cross-PR perf trajectory must be discoverable in
    # the tree, not only inside CI artifact zips
    mirror_bench_artifacts(
        list(args.current)
        + ([args.emit_trajectory] if args.emit_trajectory else [])
    )

    if failed:
        return 1
    print("OK: within budget")
    return 0


if __name__ == "__main__":
    sys.exit(main())
