"""CI gate: compare a schedulability-sweep result JSON against the
committed baseline (benchmarks/results/ci_baseline.json).

Two gates (exit 1 on either):

  * **wall-clock** — fails when the sweep regresses more than
    --max-regression (default 25%) over the baseline;
  * **acceptance ratios** — fails on *any* drift from the baseline rows.
    The sweep seeds are fixed and the batch backend is pinned
    decision-identical to the scalar reference, so ratios only move when
    the analysis itself changes — a silent result change from a backend
    or analysis edit must show up as a named CI failure, not as a perf
    footnote.  Intentional analysis changes regenerate the baseline
    (and justify it in the PR).

The baseline records the sweep configuration (n, workers, backend); the
CI job pins --workers to the baseline's value so the comparison is
parallelism-for-parallelism.  Wall-clock still depends on host
hardware: if runner hardware shifts the floor, regenerate the baseline
from the job's uploaded artifact rather than widening the margin.

--emit-trajectory PATH writes a small perf-trajectory artifact
(wall-clock, per-sweep wall-clocks, backend tag, sweep config) from the
current result; CI uploads it as ``BENCH_sweep.json`` so every push
leaves a comparable perf datapoint next to the full rows.

Usage:
    python benchmarks/schedulability.py --quick --json current.json
    python benchmarks/check_regression.py current.json \
        --emit-trajectory BENCH_sweep.json
"""

from __future__ import annotations

import argparse
import json
import sys


def load(path: str) -> dict:
    with open(path) as f:
        return json.load(f)


def drifted_rows(current: dict, baseline: dict) -> list[str]:
    """Every baseline datapoint must reappear in the current result with
    the same value — a row or method that *disappears* is a silent
    result change too, so absences count as drift in both directions."""
    cur_by_key = {
        (r.get("sweep"), r.get("x")): r for r in current.get("rows", [])
    }
    drifts = []
    for base in baseline.get("rows", []):
        key = (base.get("sweep"), base.get("x"))
        row = cur_by_key.get(key)
        if row is None:
            drifts.append(f"{key[0]} x={key[1]}: row missing from current")
            continue
        for method, expected in base.items():
            if method in ("sweep", "x"):
                continue
            if method not in row:
                drifts.append(
                    f"{key[0]} x={key[1]} {method}: missing from current"
                )
            elif abs(row[method] - expected) > 1e-9:
                drifts.append(
                    f"{key[0]} x={key[1]} {method}: "
                    f"{expected:.3f} -> {row[method]:.3f}"
                )
        extra = set(row) - set(base) - {"sweep", "x"}
        for method in sorted(extra):
            drifts.append(
                f"{key[0]} x={key[1]} {method}: not in baseline "
                f"(-> {row[method]:.3f})"
            )
    return drifts


def trajectory(current: dict) -> dict:
    """The perf-trajectory datapoint CI commits as an artifact."""
    return {
        "wall_clock_s": current.get("wall_clock_s"),
        "sweep_wall_clock_s": current.get("sweep_wall_clock_s", {}),
        "backend": current.get("backend", "scalar"),
        "n": current.get("n"),
        "workers": current.get("workers"),
    }


def main() -> int:
    ap = argparse.ArgumentParser(description=__doc__.split("\n")[0])
    ap.add_argument("current", help="result JSON from --json")
    ap.add_argument(
        "--baseline", default="benchmarks/results/ci_baseline.json"
    )
    ap.add_argument(
        "--max-regression",
        type=float,
        default=0.25,
        help="allowed fractional wall-clock slowdown (default 0.25)",
    )
    ap.add_argument(
        "--emit-trajectory",
        default=None,
        metavar="PATH",
        help="write the perf-trajectory artifact (wall-clock per sweep "
        "+ backend tag) to PATH",
    )
    args = ap.parse_args()

    current = load(args.current)
    baseline = load(args.baseline)
    cur_s = float(current["wall_clock_s"])
    base_s = float(baseline["wall_clock_s"])
    for key in ("n", "workers", "backend"):
        if current.get(key) != baseline.get(key):
            print(
                f"note: sweep configs differ (current {key}="
                f"{current.get(key)}, baseline {key}={baseline.get(key)}) "
                "— wall-clock gate is apples-to-oranges",
                file=sys.stderr,
            )

    if args.emit_trajectory:
        with open(args.emit_trajectory, "w") as f:
            json.dump(trajectory(current), f, indent=2)
        print(f"wrote trajectory {args.emit_trajectory}")

    failed = False
    drifts = drifted_rows(current, baseline)
    for line in drifts:
        print(f"acceptance drift: {line}", file=sys.stderr)
    if drifts:
        print(
            f"FAIL: {len(drifts)} acceptance ratio(s) drifted from the "
            "baseline — analysis results changed (regenerate the "
            "baseline only for an intentional, justified change)",
            file=sys.stderr,
        )
        failed = True

    limit = base_s * (1.0 + args.max_regression)
    print(
        f"wall-clock: current {cur_s:.1f}s vs baseline {base_s:.1f}s "
        f"(limit {limit:.1f}s)"
    )
    if cur_s > limit:
        print(
            f"FAIL: sweep wall-clock regressed more than "
            f"{args.max_regression:.0%} over baseline",
            file=sys.stderr,
        )
        failed = True
    if failed:
        return 1
    print("OK: within budget")
    return 0


if __name__ == "__main__":
    sys.exit(main())
