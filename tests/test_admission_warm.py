"""Warm-started incremental admission (DESIGN.md §11).

The controller's persistent state — cached built Tasks, running
utilization totals, warm-start WCRT seeds — must be *invisible* in the
decisions: ``warm_start=True`` and the faithful from-scratch baseline
(``warm_start=False``) must produce identical decisions and
WCRTs-to-tolerance over any admit/release/shed/fail-over sequence, for
every RTA kind and every solver backend.  These tests drive randomized
sequences through paired controllers (hypothesis when installed, a
seeded sweep always), pin the cache-invalidation rules the soundness
argument rests on (admitting only ADDS interference; any removal is
the unsound seed direction), and check the batch-layer seed plumbing
(`batch_rta(seeds=...)`, `batch_rta_prefixes`) against the unseeded
ground truth.
"""
import math
import random

import pytest

from repro.core import Taskset
from repro.core.analysis import (ioctl_busy_rta, ioctl_suspend_rta,
                                 kthread_busy_rta)
from repro.core.batch import batch_rta, batch_rta_prefixes
from repro.core.batch_jax import HAVE_JAX
from repro.core.improved import (ioctl_busy_improved_rta,
                                 ioctl_suspend_improved_rta)
from repro.sched.admission import AdmissionController, JobProfile

from _optional import HAVE_HYPOTHESIS, given, settings, st

RTAS = {
    "kthread_busy": kthread_busy_rta,
    "ioctl_busy": ioctl_busy_rta,
    "ioctl_suspend": ioctl_suspend_rta,
    "ioctl_busy_improved": ioctl_busy_improved_rta,
    "ioctl_suspend_improved": ioctl_suspend_improved_rta,
}

BACKENDS = [
    "scalar",
    "numpy",
    pytest.param("jax", marks=pytest.mark.skipif(
        not HAVE_JAX, reason="jax not importable")),
]


def _prof(i, rng, **kw):
    d = dict(name=f"job{i}",
             host_segments_ms=[round(rng.uniform(0.5, 2.0), 3)],
             device_segments_ms=[(0.2, round(rng.uniform(1.0, 5.0), 3))],
             period_ms=rng.choice([40.0, 60.0, 80.0, 120.0]),
             priority=10_000 - i, cpu=i % 4)
    d.update(kw)
    return JobProfile(**d)


def _pair(rta):
    """A (warm, cold) controller pair under the same platform config."""
    ctls = []
    for warm in (True, False):
        c = AdmissionController(policy="ioctl", wait_mode="suspend",
                                n_cpus=4, warm_start=warm)
        c.rta = rta  # exercise all five kinds through one config
        ctls.append(c)
    return ctls


def _assert_wcrt_close(a, b):
    assert set(a) == set(b)
    for name, ra in a.items():
        rb = b[name]
        if ra is None or rb is None:
            assert ra is None and rb is None, name
        elif math.isinf(ra) or math.isinf(rb):
            assert math.isinf(ra) and math.isinf(rb), name
        else:
            assert abs(ra - rb) <= 1e-6 * max(1.0, abs(ra)), name


def _assert_same_decision(dw, dc):
    for key in ("admitted", "reason", "via", "error", "gpu_priorities"):
        assert dw.get(key) == dc.get(key), key
    _assert_wcrt_close(dw["wcrt"], dc["wcrt"])
    # satellite contract: every decision carries its processing latency
    assert dw["latency_ms"] >= 0.0 and dc["latency_ms"] >= 0.0


def _run_sequence(seed, rta, backend, n_ops=10):
    """Drive one randomized admit/release/shed/fail-over sequence
    through a warm and a cold controller in lockstep, asserting
    decision identity at every step and the §11 invalidation rules on
    the warm side."""
    warm, cold = _pair(rta)
    rng = random.Random(seed)
    i = 0
    for _ in range(n_ops):
        op = rng.choice(["admit", "admit", "admit",
                         "release", "shed", "failover"])
        if op == "admit" or not warm.admitted:
            burst = [_prof(i + k, rng) for k in range(rng.randint(1, 5))]
            if burst and rng.random() < 0.2:
                burst[0] = _prof(i, rng, best_effort=True)
            i += len(burst)
            if backend == "scalar":
                dws = [warm.try_admit(p) for p in burst]
                dcs = [cold.try_admit(p) for p in burst]
            else:
                dws = warm.try_admit_many(burst, backend=backend)
                dcs = cold.try_admit_many(burst, backend=backend)
            for dw, dc in zip(dws, dcs):
                _assert_same_decision(dw, dc)
                if dw["admitted"] and dw["via"] == "audsley":
                    # Audsley bounds hold under reassigned GPU
                    # priorities, not the default recurrence — the
                    # cache must not carry them
                    assert warm._warm is None
        elif op == "release":
            name = rng.choice([p.name for p in warm.admitted])
            was_rt = warm._tasks[name].is_rt
            assert warm.release(name) and cold.release(name)
            if was_rt:  # RT removal shrinks interference: unsound seeds
                assert warm._warm is None
        elif op == "shed":
            # shedding evicts the lowest-priority admitted profile
            # (sched/elastic.py) — another removal path
            victim = min((p for p in warm.admitted),
                         key=lambda p: p.priority)
            assert warm.release(victim.name) and cold.release(victim.name)
        else:  # fail-over epoch reset: wholesale reassignment
            keep = [p for p in warm.admitted if rng.random() < 0.7]
            warm.admitted = keep
            cold.admitted = list(keep)
            assert warm._warm is None
        assert ([p.name for p in warm.admitted]
                == [p.name for p in cold.admitted])


@pytest.mark.parametrize("backend", BACKENDS)
@pytest.mark.parametrize("kind", sorted(RTAS))
def test_warm_cold_identity_seeded(kind, backend):
    """Seeded fallback sweep: always runs, hypothesis or not."""
    for seed in (0, 1):
        _run_sequence(seed * 997 + hash(kind) % 1000, RTAS[kind], backend)


if HAVE_HYPOTHESIS:
    @settings(max_examples=15, deadline=None)
    @given(st.integers(min_value=0, max_value=2**31 - 1))
    def test_warm_cold_identity_property(seed):
        _run_sequence(seed, ioctl_suspend_rta, "numpy")


# --------------------------------------------------------------------------
# pinned invalidation regressions
# --------------------------------------------------------------------------

def test_release_never_leaves_stale_seeds():
    """Post-release decisions must match a freshly built controller:
    cached bounds from the pre-release set sit ABOVE the shrunk fixed
    point (the unsound direction), so reusing them could under-admit or
    (worse) hand out wrong WCRT evidence."""
    rng = random.Random(7)
    ctl = AdmissionController(policy="ioctl", wait_mode="suspend",
                              n_cpus=4, warm_start=True)
    profs = [_prof(i, rng) for i in range(8)]
    for p in profs:
        ctl.try_admit(p)
    assert ctl._warm is not None
    released = ctl.admitted[2].name
    assert ctl.release(released)
    assert ctl._warm is None  # the pinned invalidation

    fresh = AdmissionController(policy="ioctl", wait_mode="suspend",
                                n_cpus=4, warm_start=True)
    for p in ctl.admitted:
        assert fresh.try_admit(p)["admitted"]
    probe = _prof(99, rng)
    _assert_same_decision(ctl.try_admit(probe), fresh.try_admit(probe))


def test_best_effort_paths_keep_warm_cache():
    """BE tasks never enter the RT recurrences: admitting or releasing
    one must not throw away converged RT bounds."""
    rng = random.Random(11)
    ctl = AdmissionController(policy="ioctl", wait_mode="suspend",
                              n_cpus=4, warm_start=True)
    for i in range(4):
        assert ctl.try_admit(_prof(i, rng))["admitted"]
    cached = ctl._warm
    assert cached is not None
    assert ctl.try_admit(_prof(50, rng, best_effort=True))["admitted"]
    assert ctl._warm is cached
    assert ctl.release("job50")
    assert ctl._warm is cached


def test_latency_summary_tracks_decisions():
    rng = random.Random(13)
    ctl = AdmissionController(policy="ioctl", wait_mode="suspend", n_cpus=4)
    assert ctl.latency_summary()["decisions"] == 0
    ctl.try_admit_many([_prof(i, rng) for i in range(5)])
    s = ctl.latency_summary()
    assert s["decisions"] == 5
    for key in ("mean_ms", "p50_ms", "p99_ms", "max_ms"):
        assert s[key] >= 0.0


def test_latency_summary_p99_is_nearest_rank_not_max():
    """The pinned percentile bug: on a 100-sample window the naive
    ``int(q*n)`` index returned the window *maximum* for p99.  With
    nearest-rank (``ceil(q*n) - 1``) the p99 of 1..100 ms is the 99th
    element, strictly below the max."""
    from repro.sched.admission import nearest_rank

    ctl = AdmissionController(policy="ioctl", wait_mode="suspend", n_cpus=4)
    window = [float(i) for i in range(1, 101)]
    shuffled = list(window)
    random.Random(7).shuffle(shuffled)
    ctl._latencies.clear()
    ctl._latencies.extend(shuffled)
    s = ctl.latency_summary()
    assert s["window"] == 100
    assert s["max_ms"] == 100.0
    assert s["p99_ms"] == 99.0          # not the max
    assert s["p50_ms"] == 50.0
    # the helper itself, on edge cases
    assert nearest_rank([5.0], 0.99) == 5.0
    assert nearest_rank([1.0, 2.0], 0.5) == 1.0
    with pytest.raises(ValueError):
        nearest_rank([], 0.5)


# --------------------------------------------------------------------------
# batch-layer seed plumbing
# --------------------------------------------------------------------------

def _taskset(n, rng, n_be=0):
    profs = [_prof(i, rng) for i in range(n)]
    for k in range(n_be):
        profs[k] = _prof(k, rng, best_effort=True)
    tasks = [p.to_task() for p in profs]
    return Taskset(tasks, n_cpus=4, epsilon=1.0, kthread_cpu=4,
                   n_devices=1)


@pytest.mark.parametrize("kind", sorted(RTAS))
def test_batch_rta_seeds_do_not_change_results(kind):
    """Any sound seed (≤ the fixed point) must converge to the same
    bounds as the unseeded ascent — here: the converged bounds halved."""
    rng = random.Random(23)
    tss = [_taskset(n, rng) for n in (4, 7, 10)]
    cold = batch_rta(kind, tss)
    seeds = [{k: v / 2.0 for k, v in r.items()
              if v is not None and math.isfinite(v)} for r in cold]
    warm = batch_rta(kind, tss, seeds=seeds)
    for a, b in zip(cold, warm):
        _assert_wcrt_close(a, b)
    with pytest.raises(ValueError):
        batch_rta(kind, tss, seeds=seeds[:1])  # length mismatch


@pytest.mark.parametrize("kind", sorted(RTAS))
@pytest.mark.parametrize("n_base,n_cand,n_be", [(0, 3, 0), (5, 4, 0),
                                                (6, 3, 2)])
def test_batch_rta_prefixes_matches_batch(kind, n_base, n_cand, n_be):
    """The triangular-mask packing must be value-identical to solving
    each prefix taskset independently — with and without seeds."""
    rng = random.Random(31)
    full = _taskset(n_base + n_cand, rng, n_be=n_be)
    prefixes = [Taskset(list(full.tasks[:n_base + 1 + k]), n_cpus=4,
                        epsilon=1.0, kthread_cpu=4, n_devices=1)
                for k in range(n_cand)]
    expected = batch_rta(kind, prefixes)
    got = batch_rta_prefixes(kind, full, n_cand)
    assert len(got) == n_cand
    for a, b in zip(expected, got):
        _assert_wcrt_close(a, b)
    if n_base:
        base_bounds = batch_rta(kind, [prefixes[0]])[0]
        seed = {k: v / 2.0 for k, v in base_bounds.items()
                if v is not None and math.isfinite(v)}
        seeded = batch_rta_prefixes(kind, full, n_cand, seeds=seed)
        for a, b in zip(expected, seeded):
            _assert_wcrt_close(a, b)
