"""Kill-and-recover: an admitted RT job survives ``kill -9`` of the
scheduling daemon with its guarantee intact (DESIGN.md §9).

Subprocess-driven: a real ``python -m repro.sched.daemon`` process, a
real unix socket, a real SIGKILL mid-slice.  Asserts the three recovery
invariants:

  (a) the rebuilt admission state is decision-identical to the journal
      (checked both by the daemon's own conformance pass and
      independently by ``AdmissionController.rebuild`` in this process);
  (b) the sliced job resumes from the latest checkpointed carry at the
      journaled slice index — not from scratch;
  (c) post-recovery MORT stays within the admitted WCRT.
"""
import json
import os
import signal
import subprocess
import sys
import time

from repro.sched import AdmissionController, JobStore, connect

SRC = os.path.abspath(os.path.join(os.path.dirname(__file__),
                                   os.pardir, "src"))
ENV = dict(os.environ, REPRO_PALLAS="interpret",
           PYTHONPATH=SRC + os.pathsep + os.environ.get("PYTHONPATH", ""))

# the subject job: 25 sleep-slices of 80 ms — long enough to SIGKILL
# mid-iteration, cheap enough for CI
SLICES, SLICE_MS = 25, 80.0
EXEC_MS, PERIOD_MS = 3000.0, 6000.0
N_ITER = 2


def start_daemon(store, sock):
    proc = subprocess.Popen(
        [sys.executable, "-m", "repro.sched.daemon",
         "--store", store, "--socket", sock, "--n-devices", "1"],
        env=ENV, stdout=subprocess.PIPE, stderr=subprocess.STDOUT,
        text=True)
    deadline = time.monotonic() + 120
    client = connect(sock)
    while time.monotonic() < deadline:
        if proc.poll() is not None:
            raise AssertionError(
                f"daemon died during startup (rc={proc.returncode}):\n"
                f"{proc.stdout.read()}")
        try:
            client.ping()
            return proc, client
        except (OSError, RuntimeError):
            time.sleep(0.2)
    proc.kill()
    raise AssertionError("daemon never became ready")


def journal_records(store, kind, job=None):
    path = os.path.join(store, "journal.jsonl")
    out = []
    if not os.path.exists(path):
        return out
    with open(path) as f:
        for line in f:
            try:
                rec = json.loads(line)
            except json.JSONDecodeError:
                continue
            if rec.get("rec") == kind and (job is None
                                           or rec.get("job") == job):
                out.append(rec)
    return out


def wait_for(pred, timeout, what):
    deadline = time.monotonic() + timeout
    while time.monotonic() < deadline:
        got = pred()
        if got:
            return got
        time.sleep(0.2)
    raise AssertionError(f"timed out waiting for {what}")


def test_kill_minus_nine_and_recover(tmp_path):
    store = str(tmp_path / "store")
    sock = str(tmp_path / "sock")
    proc, client = start_daemon(store, sock)
    try:
        dec = client.submit(
            _spin_profile("spin"),
            workload_spec={"name": "demo.spin",
                           "kwargs": {"slices": SLICES,
                                      "slice_ms": SLICE_MS}},
            n_iterations=N_ITER, start=True)
        assert dec.accepted, dec
        wcrt_ms = dec.wcrt["spin"]
        be = client.submit(
            _spin_profile("background", best_effort=True),
            workload_spec={"name": "demo.spin",
                           "kwargs": {"slices": 4, "slice_ms": 10.0}},
            n_iterations=1, start=True)
        assert be.accepted and be.via == "best_effort"

        # SIGKILL mid-slice: wait until a few slices of iteration 0
        # are checkpointed, then no clean shutdown whatsoever
        wait_for(lambda: [r for r in
                          journal_records(store, "carry", "spin")
                          if r["iteration"] == 0 and r["slice"] >= 3],
                 90, "3 checkpointed slices")
        os.kill(proc.pid, signal.SIGKILL)
        proc.wait(30)
    finally:
        if proc.poll() is None:
            proc.kill()

    carries = [r for r in journal_records(store, "carry", "spin")
               if r["iteration"] == 0]
    last_slice = max(r["slice"] for r in carries)
    assert 1 <= last_slice < SLICES, "kill was not mid-iteration"

    # (a) independent decision-conformance: re-run admission over the
    # journaled taskset in this process; identity or it raises
    state = JobStore(store).load()
    ctl = AdmissionController.rebuild(state.config,
                                      state.admission_entries(),
                                      conform=True)
    assert [p.name for p in ctl.admitted] == ["spin", "background"]
    assert state.jobs["spin"].carry["slice"] == last_slice

    # restart: the daemon must rebuild + resume on its own
    proc, client = start_daemon(store, sock)
    try:
        st = client.status()
        # (a) the daemon's own conformance pass ran and passed
        assert st["recovery"]["conformance"] == "checked"
        assert sorted(st["recovery"]["recovered"]) == ["background",
                                                       "spin"]
        assert st["admitted"] == ["spin", "background"]
        # (b) resumed mid-segment at the journaled slice, not slice 0
        resumed = st["recovery"]["resumed"]["spin"]
        assert resumed == {"device": 0, "iteration": 0,
                           "slice": last_slice,
                           "remaining_iterations": N_ITER}

        jobs = wait_for(
            lambda: (lambda j: j if j["spin"]["done_iterations"]
                     == N_ITER else None)(client.jobs()),
            120, "resumed job to finish both iterations")
        # (b) the resume audit record agrees with the last checkpoint
        resumes = journal_records(store, "resume", "spin")
        assert resumes == [{"rec": "resume", "job": "spin",
                            "iteration": 0, "slice": last_slice}]
        # (c) MORT <= admitted WCRT, across the crash
        mort_ms = jobs["spin"]["mort_s"] * 1e3
        assert mort_ms <= wcrt_ms + 1e-6, \
            f"recovered MORT {mort_ms:.1f}ms exceeds WCRT {wcrt_ms:.1f}ms"
        assert jobs["spin"]["deadline_misses"] == 0
        client.close(shutdown=True)
        proc.wait(30)
    finally:
        if proc.poll() is None:
            proc.kill()


def test_daemon_refuses_tampered_journal(tmp_path):
    """Drifted WCRT evidence in the journal must abort recovery: the
    daemon exits rather than serving guarantees it cannot re-prove."""
    store = str(tmp_path / "store")
    sock = str(tmp_path / "sock")
    proc, client = start_daemon(store, sock)
    try:
        assert client.submit(
            _spin_profile("spin"),
            workload_spec={"name": "demo.spin",
                           "kwargs": {"slices": 2, "slice_ms": 5.0}},
            n_iterations=1).accepted
        client.close(shutdown=True)
        proc.wait(30)
    finally:
        if proc.poll() is None:
            proc.kill()
    path = os.path.join(store, "journal.jsonl")
    with open(path) as f:
        lines = f.readlines()
    for i, line in enumerate(lines):
        rec = json.loads(line)
        if rec.get("rec") == "decision":
            rec["decision"]["wcrt"]["spin"] = 1.0    # forged evidence
            lines[i] = json.dumps(rec, sort_keys=True) + "\n"
    with open(path, "w") as f:
        f.writelines(lines)
    out = subprocess.run(
        [sys.executable, "-m", "repro.sched.daemon",
         "--store", store, "--socket", sock, "--n-devices", "1"],
        env=ENV, capture_output=True, text=True, timeout=120)
    assert out.returncode != 0
    assert "RecoveryConformanceError" in out.stderr


def _spin_profile(name, best_effort=False):
    from repro.sched import JobProfile
    return JobProfile(name, host_segments_ms=[1.0],
                      device_segments_ms=[(0.5, EXEC_MS)],
                      period_ms=PERIOD_MS, priority=10, cpu=0,
                      best_effort=best_effort, device=0)
