"""Chaos suite: the fault-containment layer under deterministic
injected faults (DESIGN.md §10).

Matrix: {slice exception, device hang, daemon SIGKILL, overload burst}
× device counts, asserting the two §10 invariants throughout:

  * surviving RT jobs' MORT stays within their admitted WCRT — the
    guarantee holds *through* the fault, not just before it;
  * no silent job loss — every job that ever held an admission is,
    after the dust settles, either live (possibly re-bound in a new
    epoch, with fresh journaled evidence) or explicitly refused on the
    record; ``StoreState.unaccounted()`` must drain to ``[]``.

In-process legs drive a ``ClusterExecutor`` directly (injector installed
on the executor); subprocess legs drive a real ``repro.sched.daemon``
whose faults come from ``$REPRO_FAULT_PLAN`` — the daemon SIGKILLs
*itself* mid-slice, exactly like a machine check, and must recover.  The
supervisor legs close the loop: kill → auto-restart → recovery, and the
give-up path that surfaces ``RecoveryConformanceError`` instead of
masking it behind restarts.
"""
import json
import os
import signal
import subprocess
import sys
import threading
import time

import pytest

from repro.sched import (ClusterExecutor, FaultContained, FaultInjector,
                         FaultSpec, HealthConfig, JobEvicted, JobProfile,
                         JobStore, ShedPolicy, Supervisor, connect)
from repro.sched.daemon import SchedDaemon
from repro.sched.fault import FAILED, SUSPECT

SRC = os.path.abspath(os.path.join(os.path.dirname(__file__),
                                   os.pardir, "src"))
ENV = dict(os.environ, REPRO_PALLAS="interpret",
           PYTHONPATH=SRC + os.pathsep + os.environ.get("PYTHONPATH", ""))
ENV.pop("REPRO_FAULT_PLAN", None)

# in-process subject: 10 sleep-slices of 30 ms (~0.3 s per release),
# priced at 1 s of a 5 s period — generous WCRT slack so observed
# response times stay inside the evidence even on a loaded CI host
SLICES, SLICE_MS = 10, 30.0
EXEC_MS, PERIOD_MS = 1000.0, 5000.0
SPIN = {"name": "demo.spin",
        "kwargs": {"slices": SLICES, "slice_ms": SLICE_MS}}


def prof(name, prio=10, device=0, exec_ms=EXEC_MS, period_ms=PERIOD_MS,
         cpu=0, best_effort=False):
    return JobProfile(name, host_segments_ms=[1.0],
                      device_segments_ms=[(0.5, exec_ms)],
                      period_ms=period_ms, priority=prio, cpu=cpu,
                      best_effort=best_effort, device=device)


def wait_for(pred, timeout, what):
    deadline = time.monotonic() + timeout
    while time.monotonic() < deadline:
        got = pred()
        if got:
            return got
        time.sleep(0.05)
    raise AssertionError(f"timed out waiting for {what}")


def make_cluster(tmp_path, n_devices, **kw):
    return ClusterExecutor(
        n_devices=n_devices, policy="ioctl", n_cpus=4, trace=True,
        store=JobStore(str(tmp_path / "store"), sync=False), **kw)


def journal_records(store_dir, kind, job=None):
    path = os.path.join(str(store_dir), "journal.jsonl")
    out = []
    if not os.path.exists(path):
        return out
    with open(path) as f:
        for line in f:
            try:
                rec = json.loads(line)
            except json.JSONDecodeError:
                continue
            if rec.get("rec") == kind and (job is None
                                           or rec.get("job") == job):
                out.append(rec)
    return out


def assert_no_silent_loss(cluster):
    """The §10 audit: the journal's displaced ledger is drained and the
    live/binding views are internally consistent."""
    state = cluster.store.load()
    assert state.unaccounted() == [], \
        f"jobs neither re-bound nor refused: {state.unaccounted()}"
    cluster.assert_migration_free()
    return state


# ---------------------------------------------------------------------------
# slice exception → health verdict → fail-over (in-process)
# ---------------------------------------------------------------------------

@pytest.mark.parametrize("n_devices", [2, 4])
def test_slice_exception_failover_rebinds_to_survivor(tmp_path, n_devices):
    """An injected slice exception trips the device's error threshold;
    the health monitor declares the device failed and the fail-over
    epoch re-binds the victim to a survivor with fresh WCRT evidence.
    A witness job on another device must never notice."""
    cl = make_cluster(tmp_path, n_devices,
                      health=HealthConfig(stall_timeout_s=60.0,
                                          fail_timeout_s=60.0,
                                          error_threshold=1,
                                          poll_interval_s=0.02))
    client = connect(cl)
    try:
        witness_dev = n_devices - 1
        wd = client.submit(prof("witness", 20, device=witness_dev),
                           workload_spec=SPIN, n_iterations=1, start=True)
        assert wd.accepted
        cl.executors[0].fault_injector = FaultInjector(
            [FaultSpec(kind="raise", device=0, slice_idx=2)])
        vd = client.submit(prof("victim", 10, device=0),
                           workload_spec=SPIN, n_iterations=1, start=True)
        assert vd.accepted and vd["device"] == 0

        wait_for(lambda: cl.failed_devices == [0], 30,
                 "device 0 declared failed")
        assert cl.epoch == 1
        assert cl.device_health(0).state == FAILED
        assert [e["kind"] for e in cl.executors[0].fault_injector.log] \
            == ["raise"]

        job = wait_for(lambda: (lambda j: j if j is not None
                                and j.device != 0 else None)(
                                    cl.find_job("victim")),
                       30, "victim re-bound to a survivor")
        wait_for(lambda: job.state == "done", 60, "re-bound victim done")
        assert job.error is None and job.stats.completions == 1

        state = assert_no_silent_loss(cl)
        assert state.epoch == 1 and state.failed_devices == {0}
        rec = state.jobs["victim"]
        assert rec.device == job.device != 0
        # MORT <= WCRT: the witness against its original evidence, the
        # re-bound victim against the new epoch's fresh evidence
        witness = cl.find_job("witness")
        wait_for(lambda: witness.state == "done", 60, "witness done")
        assert witness.stats.mort * 1e3 <= wd.wcrt["witness"] + 1e-6
        assert job.stats.mort * 1e3 \
            <= rec.decision["wcrt"]["victim"] + 1e-6
    finally:
        client.close()
        cl.shutdown()
        cl.store.close()


# ---------------------------------------------------------------------------
# device hang → stall → suspect → failed ladder (in-process)
# ---------------------------------------------------------------------------

def test_device_hang_escalates_stall_suspect_failed(tmp_path):
    """A hung slice (injected sleep inside the device lock) never
    raises, so only the slice-level heartbeat can see it: the monitor
    must walk the full healthy→suspect→failed ladder and fail the
    device over while the kernel is still stuck."""
    cl = make_cluster(tmp_path, 2,
                      health=HealthConfig(stall_timeout_s=0.15,
                                          fail_timeout_s=0.2,
                                          error_threshold=100,
                                          poll_interval_s=0.03))
    client = connect(cl)
    try:
        cl.executors[0].fault_injector = FaultInjector(
            [FaultSpec(kind="hang", device=0, slice_idx=1, hang_s=2.0)])
        dec = client.submit(prof("victim", 10, device=0),
                            workload_spec=SPIN, n_iterations=1,
                            start=True)
        assert dec.accepted
        wait_for(lambda: cl.failed_devices == [0], 30,
                 "hung device declared failed")
        h = cl.device_health(0)
        hops = [(frm, to) for _, frm, to, _ in h.transitions]
        assert ("healthy", SUSPECT) in hops and (SUSPECT, FAILED) in hops
        assert "stalled" in h.reason

        job = wait_for(lambda: (lambda j: j if j is not None
                                and j.device == 1 else None)(
                                    cl.find_job("victim")),
                       30, "victim re-bound to device 1")
        wait_for(lambda: job.state == "done", 60, "re-bound victim done")
        state = assert_no_silent_loss(cl)
        assert state.jobs["victim"].device == 1
        assert job.stats.mort * 1e3 \
            <= state.jobs["victim"].decision["wcrt"]["victim"] + 1e-6
    finally:
        client.close()
        cl.shutdown()
        cl.store.close()


# ---------------------------------------------------------------------------
# single device: fail-over has no survivors — explicit refusal, no loss
# ---------------------------------------------------------------------------

def test_single_device_failover_refuses_on_the_record(tmp_path):
    cl = make_cluster(tmp_path, 1)
    client = connect(cl)
    try:
        dec = client.submit(
            prof("solo", 10, device=0), n_iterations=1, start=True,
            workload_spec={"name": "demo.spin",
                           "kwargs": {"slices": 60, "slice_ms": 25.0}})
        assert dec.accepted
        job = cl.find_job("solo")
        out = cl.fail_device(0, reason="pulled for test")
        assert out["epoch"] == 1
        assert out["rebound"] == [] and out["refused"] == ["solo"]
        # the victim's thread ends orderly with the platform's verdict
        wait_for(lambda: job.state == "done", 30, "victim orderly stop")
        assert isinstance(job.error, FaultContained)
        state = assert_no_silent_loss(cl)
        assert "solo" not in state.jobs
        assert any(r["profile"]["name"] == "solo"
                   for r in state.refusals)
        # and a fresh submission is refused explicitly, not rta-rejected
        d2 = client.submit(prof("late", 10, device=0),
                           workload_spec=SPIN, n_iterations=1)
        assert not d2.accepted and "no live device" in (d2.error or "")
        # idempotent: failing a failed device is a no-op
        again = cl.fail_device(0)
        assert again.get("already_failed") and cl.epoch == 1
    finally:
        client.close()
        cl.shutdown()
        cl.store.close()


# ---------------------------------------------------------------------------
# overload burst → degradation ladder → hysteretic resume (in-process)
# ---------------------------------------------------------------------------

@pytest.mark.parametrize("n_devices", [1, 2])
def test_overload_burst_sheds_best_effort_then_resumes(tmp_path,
                                                       n_devices):
    """An RT arrival that pushes total device utilization past
    ``shed_at`` evicts the best-effort job (journaled ``shed`` record,
    orderly ``JobEvicted`` stop); releasing the RT job frees capacity
    below ``resume_at`` and the victim climbs back up the ladder."""
    cl = make_cluster(tmp_path, n_devices,
                      shed_policy=ShedPolicy(shed_at=0.5, resume_at=0.45))
    client = connect(cl)
    try:
        # BE utilization 1500/5000 = 0.3 — fits alone
        bd = client.submit(
            prof("bg", 0, device=0, exec_ms=1500.0, best_effort=True),
            workload_spec={"name": "demo.spin",
                           "kwargs": {"slices": 40, "slice_ms": 20.0}},
            n_iterations=1, start=True)
        assert bd.accepted
        bg = cl.find_job("bg")
        # RT burst: +0.3 utilization → 0.6 > shed_at → bg is the rung
        rd = client.submit(prof("burst", 10, device=0, exec_ms=1500.0),
                           workload_spec=SPIN, n_iterations=1,
                           start=True)
        assert rd.accepted
        assert cl.shed_jobs == ["bg"]
        sheds = journal_records(tmp_path / "store", "shed", "bg")
        assert len(sheds) == 1 and "overload" in sheds[0]["reason"]
        state = cl.store.load()
        assert "bg" in state.shed and "bg" not in state.jobs
        wait_for(lambda: bg.error is not None, 30, "bg evicted")
        assert isinstance(bg.error, JobEvicted)

        # the RT job runs clean to completion inside its evidence
        burst = cl.find_job("burst")
        wait_for(lambda: burst.state == "done", 60, "burst done")
        assert burst.stats.deadline_misses == 0
        assert burst.stats.mort * 1e3 <= rd.wcrt["burst"] + 1e-6

        # hysteretic resume: only after the release frees capacity
        assert client.release("burst")
        assert cl.shed_jobs == []
        resumed = wait_for(lambda: cl.find_job("bg"), 30, "bg resumed")
        wait_for(lambda: resumed.state == "done", 60, "resumed bg done")
        assert resumed.error is None
        end = cl.store.load()
        assert "bg" in end.jobs and not end.shed
        assert end.unaccounted() == []
    finally:
        client.close()
        cl.shutdown()
        cl.store.close()


# ---------------------------------------------------------------------------
# daemon SIGKILL mid-slice via $REPRO_FAULT_PLAN (subprocess)
# ---------------------------------------------------------------------------

def start_daemon(store, sock, n_devices=1, env=None, extra=()):
    proc = subprocess.Popen(
        [sys.executable, "-m", "repro.sched.daemon",
         "--store", store, "--socket", sock,
         "--n-devices", str(n_devices), *extra],
        env=env or ENV, stdout=subprocess.PIPE,
        stderr=subprocess.STDOUT, text=True)
    deadline = time.monotonic() + 120
    client = connect(sock)
    while time.monotonic() < deadline:
        if proc.poll() is not None:
            raise AssertionError(
                f"daemon died during startup (rc={proc.returncode}):\n"
                f"{proc.stdout.read()}")
        try:
            client.ping()
            return proc, client
        except (OSError, RuntimeError):
            time.sleep(0.2)
    proc.kill()
    raise AssertionError("daemon never became ready")


@pytest.mark.parametrize("n_devices", [1, 2])
def test_daemon_self_sigkill_via_fault_plan_recovers(tmp_path, n_devices):
    """The ``kill`` fault kind SIGKILLs the daemon from *inside* a slice
    dispatch (no test-side kill, no cleanup — a machine check).  The
    restarted daemon must resume the job from its checkpointed carry
    and finish inside the admitted WCRT, with the audit ledger clean."""
    store = str(tmp_path / "store")
    sock = str(tmp_path / "sock")
    plan = json.dumps([{"kind": "kill", "job": "spin", "slice_idx": 5}])
    env = dict(ENV, REPRO_FAULT_PLAN=plan)
    proc, client = start_daemon(store, sock, n_devices, env=env)
    try:
        dec = client.submit(
            prof("spin", 10, device=0, exec_ms=3000.0, period_ms=6000.0),
            workload_spec={"name": "demo.spin",
                           "kwargs": {"slices": 25, "slice_ms": 80.0}},
            n_iterations=1, start=True)
        assert dec.accepted
        wcrt_ms = dec.wcrt["spin"]
        proc.wait(90)       # the plan kills the daemon at slice 5
        assert proc.returncode == -signal.SIGKILL
    finally:
        if proc.poll() is None:
            proc.kill()
    carries = journal_records(store, "carry", "spin")
    assert carries and max(r["slice"] for r in carries) == 5

    # restart WITHOUT the fault plan: recovery must resume slice 5
    proc, client = start_daemon(store, sock, n_devices, env=ENV)
    try:
        st = client.status()
        assert st["recovery"]["conformance"] == "checked"
        assert st["recovery"]["resumed"]["spin"]["slice"] == 5
        jobs = wait_for(
            lambda: (lambda j: j if j["spin"]["done_iterations"] == 1
                     and j["spin"]["mort_s"] is not None else None)(
                         client.jobs()),
            120, "resumed job to finish")
        assert jobs["spin"]["mort_s"] * 1e3 <= wcrt_ms + 1e-6
        audit = client._backend.request("audit")
        assert audit["unaccounted"] == [] and audit["live"] == ["spin"]
        assert audit["epoch"] == 0 and audit["failed_devices"] == []
        client.close(shutdown=True)
        proc.wait(30)
    finally:
        if proc.poll() is None:
            proc.kill()


# ---------------------------------------------------------------------------
# supervisor: kill → auto-restart → recovery round trip (subprocess)
# ---------------------------------------------------------------------------

def test_supervisor_kill_autorestart_recovery_roundtrip(tmp_path,
                                                        monkeypatch):
    monkeypatch.setenv("PYTHONPATH", ENV["PYTHONPATH"])
    monkeypatch.setenv("REPRO_PALLAS", "interpret")
    monkeypatch.delenv("REPRO_FAULT_PLAN", raising=False)
    store = str(tmp_path / "store")
    sock = str(tmp_path / "sock")
    hb = str(tmp_path / "hb.json")
    os.makedirs(store, exist_ok=True)
    cmd = [sys.executable, "-m", "repro.sched.daemon",
           "--store", store, "--socket", sock, "--n-devices", "1",
           "--heartbeat-file", hb]
    sup = Supervisor(cmd, heartbeat_file=hb, heartbeat_timeout_s=60.0,
                     min_uptime_s=0.5, max_restarts=3,
                     restart_backoff_s=0.1, poll_s=0.05,
                     log_path=str(tmp_path / "daemon.log"))
    sup.start()
    client = connect(sock)
    client._backend.retries = 8
    try:
        wait_for(lambda: _ping_ok(client), 120, "daemon under supervisor")
        dec = client.submit(
            prof("spin", 10, device=0, exec_ms=3000.0, period_ms=6000.0),
            workload_spec={"name": "demo.spin",
                           "kwargs": {"slices": 25, "slice_ms": 80.0}},
            n_iterations=1, start=True)
        assert dec.accepted
        wait_for(lambda: journal_records(store, "carry", "spin"), 90,
                 "first checkpointed carry")
        pid1 = sup.pid()
        os.kill(pid1, signal.SIGKILL)
        wait_for(lambda: sup.pid() not in (None, pid1), 60,
                 "supervisor to respawn the daemon")
        wait_for(lambda: _ping_ok(client), 120, "respawned daemon ready")
        assert sup.restarts >= 1 and not sup.gave_up
        st = client.status()
        assert st["recovery"]["conformance"] == "checked"
        assert st["recovery"]["recovered"] == ["spin"]
        jobs = wait_for(
            lambda: (lambda j: j if j["spin"]["done_iterations"] == 1
                     and j["spin"]["mort_s"] is not None else None)(
                         client.jobs()),
            120, "recovered job to finish")
        assert jobs["spin"]["mort_s"] * 1e3 <= dec.wcrt["spin"] + 1e-6
    finally:
        sup.stop()
    events = [e for _, e, _ in sup.events]
    assert "spawn" in events and "restart" in events


def _ping_ok(client):
    try:
        return bool(client.ping().get("ok"))
    except (OSError, RuntimeError):
        return False


def test_supervisor_gives_up_on_unrecoverable_store(tmp_path):
    """A daemon that cannot come up (tampered journal →
    RecoveryConformanceError) must NOT be restarted forever: the
    supervisor gives up after ``max_restarts`` fast failures and
    surfaces the conformance traceback in its give-up reason."""
    store = str(tmp_path / "store")
    d = SchedDaemon(store, socket_path=str(tmp_path / "s1"), n_devices=1)
    out = d.handle({"op": "submit", "profile": prof("spin").to_dict(),
                    "workload": {"name": "demo.spin",
                                 "kwargs": {"slices": 2,
                                            "slice_ms": 5.0}},
                    "n_iterations": 1})
    assert out["admitted"]
    d.stop()
    path = os.path.join(store, "journal.jsonl")
    with open(path) as f:
        lines = f.readlines()
    for i, line in enumerate(lines):
        rec = json.loads(line)
        if rec.get("rec") == "decision":
            rec["decision"]["wcrt"]["spin"] = 1.0    # forged evidence
            lines[i] = json.dumps(rec, sort_keys=True) + "\n"
    with open(path, "w") as f:
        f.writelines(lines)

    cmd = [sys.executable, "-m", "repro.sched.daemon",
           "--store", store, "--socket", str(tmp_path / "s2")]
    sup = Supervisor(cmd, min_uptime_s=30.0, max_restarts=1,
                     restart_backoff_s=0.05, poll_s=0.05,
                     log_path=str(tmp_path / "daemon.log"))
    env = dict(os.environ)
    os.environ.update(PYTHONPATH=ENV["PYTHONPATH"],
                      REPRO_PALLAS="interpret")
    try:
        sup.run()       # blocks until give-up
    finally:
        os.environ.clear()
        os.environ.update(env)
    assert sup.gave_up
    assert "RecoveryConformanceError" in sup.give_up_reason
    assert [e for _, e, _ in sup.events].count("spawn") == 2


def test_supervisor_sigkills_hung_child(tmp_path):
    """A live pid with a stale heartbeat is a *hung* daemon: the
    supervisor must SIGKILL it (SIGTERM would be absorbed) and restart
    through the exit path."""
    hb = str(tmp_path / "hb.json")
    script = ("import json,sys,time\n"
              "open(sys.argv[1],'w').write(json.dumps({'t': time.time()}"
              "))\n"
              "time.sleep(600)\n")
    sup = Supervisor([sys.executable, "-c", script, hb],
                     heartbeat_file=hb, heartbeat_timeout_s=0.5,
                     poll_s=0.05, min_uptime_s=0.1, max_restarts=100,
                     restart_backoff_s=0.05)
    sup.start()
    try:
        wait_for(lambda: any(e == "hang_kill"
                             for _, e, _ in sup.events), 30,
                 "stale heartbeat detected")
        wait_for(lambda: sup.restarts >= 1, 30, "restart after kill")
        assert not sup.gave_up
    finally:
        sup.stop()


# ---------------------------------------------------------------------------
# idempotent submissions + transport retry (satellites)
# ---------------------------------------------------------------------------

def test_request_id_dedup_never_double_admits(tmp_path):
    """One logical submission = one admission, no matter how many times
    the request lands — including across a daemon restart, where the
    dedup table is rebuilt from the journal."""
    store = str(tmp_path / "store")
    req = {"op": "submit", "profile": prof("spin").to_dict(),
           "workload": {"name": "demo.spin",
                        "kwargs": {"slices": 2, "slice_ms": 5.0}},
           "n_iterations": 1, "request_id": "rid-0001"}
    d = SchedDaemon(store, socket_path=str(tmp_path / "s1"), n_devices=1)
    try:
        first = d.handle(dict(req))
        assert first["admitted"] and "deduped" not in first
        second = d.handle(dict(req))
        assert second["admitted"] and second["deduped"]
        assert [p.name for p in d.cluster.admission.admitted] == ["spin"]
    finally:
        d.stop()
    d2 = SchedDaemon(store, socket_path=str(tmp_path / "s2"),
                     n_devices=1, resume_jobs=False)
    try:
        third = d2.handle(dict(req))
        assert third["admitted"] and third["deduped"]
        assert third["wcrt"] == first["wcrt"]
        assert [p.name for p in d2.cluster.admission.admitted] \
            == ["spin"]
    finally:
        d2.stop()


def test_client_retries_through_daemon_outage(tmp_path):
    """Transport failures (daemon restarting under its supervisor) are
    retried with backoff; an application-level refusal from a live
    daemon is NOT (it would just refuse again)."""
    store = str(tmp_path / "store")
    sock = str(tmp_path / "sock")
    d = SchedDaemon(store, socket_path=sock, n_devices=1)
    t = threading.Timer(0.5, d.start)
    t.start()
    client = connect(sock)
    client._backend.retries = 8
    try:
        assert client.ping()["ok"]     # socket appears mid-retry-loop
        with pytest.raises(RuntimeError, match="daemon refused"):
            client._backend.request("no-such-op")
    finally:
        t.cancel()
        client.close()
        d.stop()


def test_client_reports_unreachable_after_retries(tmp_path):
    client = connect(str(tmp_path / "never-bound.sock"))
    client._backend.retries = 1
    client._backend.backoff_s = 0.01
    with pytest.raises(RuntimeError, match="unreachable .* 2 attempts"):
        client.ping()


# ---------------------------------------------------------------------------
# fault primitives (units for the satellite fixes)
# ---------------------------------------------------------------------------

def test_with_retry_enforces_per_attempt_timeout():
    from repro.sched import StallError, with_retry
    calls = []

    def slow():
        calls.append(1)
        time.sleep(5.0)

    wrapped = with_retry(slow, n_retries=1, timeout_s=0.1,
                         backoff_s=0.01)
    t0 = time.monotonic()
    with pytest.raises(StallError, match="timeout_s"):
        wrapped()
    # both attempts were cut off at the deadline, not run to completion
    assert len(calls) == 2
    assert time.monotonic() - t0 < 2.0


def test_with_retry_does_not_retry_orderly_stops():
    from repro.sched import with_retry
    calls = []

    def evicted():
        calls.append(1)
        raise JobEvicted("shed")

    with pytest.raises(JobEvicted):
        with_retry(evicted, n_retries=3, backoff_s=0.01)()
    assert len(calls) == 1      # a platform verdict is not a straggler


def test_heartbeat_beat_clears_stale_flag():
    from repro.sched import Heartbeat, StallError
    hb = Heartbeat(timeout_s=0.1)
    try:
        wait_for(lambda: hb._stalled, 10, "watchdog to flag the stall")
        with pytest.raises(StallError):
            hb.check()
        hb.beat()               # a recovered worker is not poisoned
        hb.check()
    finally:
        hb.stop()


def test_fault_spec_filters_after_matches_and_once():
    inj = FaultInjector([FaultSpec(kind="raise", job="a",
                                   after_matches=2)])
    for _ in range(2):          # first two matching dispatches skipped
        inj.fire(device=0, job="a", slice_idx=0)
    inj.fire(device=0, job="b", slice_idx=0)     # filtered out entirely
    with pytest.raises(Exception, match="injected slice exception"):
        inj.fire(device=0, job="a", slice_idx=0)
    inj.fire(device=0, job="a", slice_idx=0)     # once=True: spent
    assert len(inj.fired("raise")) == 1


def test_fault_plan_from_env_inline_and_file(tmp_path):
    from repro.sched import faultinject
    inline = faultinject.from_env(
        {"REPRO_FAULT_PLAN": '[{"kind": "hang", "hang_s": 0.5}]'})
    assert inline.specs[0].kind == "hang"
    path = tmp_path / "plan.json"
    path.write_text('{"kind": "kill", "job": "spin"}')
    from_file = faultinject.from_env({"REPRO_FAULT_PLAN": str(path)})
    assert [s.kind for s in from_file.specs] == ["kill"]
    assert faultinject.from_env({}) is None      # production fast path
