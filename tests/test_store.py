"""Journaled job store (sched/store.py) + the structured
AdmissionDecision (DESIGN.md §9): journal/replay round-trips, atomic
snapshot compaction (including the crash window between the two
replaces), decision compatibility with historical bare-dict call sites,
admission state export → rebuild with decision-conformance, and the
checkpointer's shutdown-drain / gc-vs-restore guards the durable path
leans on."""
import json
import os
import subprocess
import sys
import threading

import pytest

from repro.sched import (AdmissionController, AdmissionDecision,
                         CompactionPolicy, JobProfile, JobStore,
                         RecoveryConformanceError, decisions_match)


def prof(name, prio, device=0, exec_ms=4.0, period_ms=50.0, cpu=0,
         best_effort=False):
    return JobProfile(name, host_segments_ms=[1.0],
                      device_segments_ms=[(0.5, exec_ms)],
                      period_ms=period_ms, priority=prio, cpu=cpu,
                      best_effort=best_effort, device=device)


# ---------------------------------------------------------------------------
# AdmissionDecision: structured result, historical dict face intact
# ---------------------------------------------------------------------------

def test_decision_bool_and_dict_faces_agree():
    acc = AdmissionDecision.accept("default", {"a": 12.5})
    ref = AdmissionDecision.refuse("rta-reject", wcrt={"a": None})
    assert acc and not ref                      # __bool__
    assert acc["admitted"] and not ref["admitted"]   # historical face
    assert acc.reason == "accepted" and ref.reason == "rta-reject"
    assert acc.wcrt == {"a": 12.5} == acc["wcrt"]
    # equality with a plain dict still holds (tests compare verbatim)
    assert acc == {"admitted": True, "reason": "accepted",
                   "via": "default", "wcrt": {"a": 12.5}}


def test_decision_validates_reason_consistency():
    with pytest.raises(ValueError, match="unknown reason"):
        AdmissionDecision(admitted=True, reason="because")
    with pytest.raises(ValueError, match="contradicts"):
        AdmissionDecision(admitted=True, reason="rta-reject")


def test_decision_journal_form_strips_live_job():
    dec = AdmissionDecision.accept("default", {"a": 1.0})
    bound = dec.bound(2, object())
    jf = bound.journal_form()
    assert jf["device"] == 2 and "job" not in jf
    json.dumps(jf)  # journalable verbatim


def test_try_admit_reason_codes():
    ctl = AdmissionController(policy="ioctl", n_devices=1)
    assert ctl.try_admit(prof("ok", 1)).reason == "accepted"
    assert (ctl.try_admit(prof("bad-dev", 2, device=5)).reason
            == "validation-refused")
    assert (ctl.try_admit(prof("ok", 2)).reason  # duplicate name
            == "validation-refused")
    hot = ctl.try_admit(prof("hot", 2, exec_ms=80.0, period_ms=50.0))
    assert hot.reason == "headroom-fast-reject" and hot.wcrt == {}
    tight = ctl.try_admit(prof("tight", 2, exec_ms=44.0,
                               period_ms=50.0))
    assert tight.reason == "rta-reject" and tight.wcrt  # evidence kept


def test_decisions_match_tolerance_and_inf():
    a = {"admitted": True, "reason": "accepted", "via": "default",
         "wcrt": {"x": 10.0}}
    assert decisions_match(a, dict(a, wcrt={"x": 10.0 + 1e-9}))
    assert not decisions_match(a, dict(a, wcrt={"x": 10.1}))
    assert not decisions_match(a, dict(a, via="audsley"))
    inf = dict(a, admitted=False, reason="rta-reject", via=None,
               wcrt={"x": None})
    assert decisions_match(inf, dict(inf, wcrt={"x": float("inf")}))


# ---------------------------------------------------------------------------
# journal / replay
# ---------------------------------------------------------------------------

def test_journal_replay_round_trip(tmp_path):
    with JobStore(str(tmp_path)) as st:
        ctl = AdmissionController(policy="ioctl", n_devices=2)
        st.record_config(ctl.export_config(), {"n_devices": 2})
        p = prof("a", 1)
        st.record_decision(p, ctl.try_admit(p), device=0,
                           workload={"name": "demo.spin", "kwargs": {}},
                           n_iterations=3)
        st.record_carry("a", 0, 2)
        st.record_carry("a", 0, 4)
        st.record_iteration_done("a", 0)
        st.record_carry("a", 1, 1)
        refused = prof("a", 2)           # duplicate -> refusal audit
        st.record_decision(refused, ctl.try_admit(refused))
    state = JobStore(str(tmp_path)).load()
    assert state.config["n_devices"] == 2
    rec = state.jobs["a"]
    assert rec.device == 0 and rec.n_iterations == 3
    assert rec.done_iterations == 1           # iter 0 finalized
    assert rec.carry == {"iteration": 1, "slice": 1}
    assert len(state.refusals) == 1
    assert state.refusals[0]["decision"]["reason"] == "validation-refused"


def test_release_removes_job_from_state(tmp_path):
    with JobStore(str(tmp_path)) as st:
        ctl = AdmissionController(policy="ioctl")
        p = prof("a", 1)
        st.record_decision(p, ctl.try_admit(p), device=0)
        st.record_release("a")
        assert st.load().jobs == {}


def test_torn_final_journal_line_is_skipped(tmp_path):
    st = JobStore(str(tmp_path))
    ctl = AdmissionController(policy="ioctl")
    p = prof("a", 1)
    st.record_decision(p, ctl.try_admit(p), device=0)
    st.close()
    with open(os.path.join(str(tmp_path), "journal.jsonl"), "a") as f:
        f.write('{"rec": "carry", "job": "a", "iter')   # crash mid-append
    state = JobStore(str(tmp_path)).load()
    assert "a" in state.jobs and state.jobs["a"].carry is None


def test_unknown_record_kinds_are_skipped(tmp_path):
    st = JobStore(str(tmp_path))
    st._append({"rec": "future-audit-kind", "x": 1})
    assert st.load().jobs == {}


# ---------------------------------------------------------------------------
# compaction
# ---------------------------------------------------------------------------

def test_compaction_preserves_state_and_truncates_journal(tmp_path):
    st = JobStore(str(tmp_path))
    ctl = AdmissionController(policy="ioctl", n_devices=1)
    st.record_config(ctl.export_config(), {"n_devices": 1})
    for name in ("a", "b"):
        p = prof(name, {"a": 1, "b": 2}[name])
        st.record_decision(p, ctl.try_admit(p), device=0)
    st.record_carry("a", 0, 3)
    st.compact()
    assert os.path.getsize(os.path.join(str(tmp_path),
                                        "journal.jsonl")) == 0
    state = st.load()
    assert sorted(state.jobs) == ["a", "b"]
    assert state.jobs["a"].carry == {"iteration": 0, "slice": 3}
    assert state.config is not None
    # appends keep working after compaction, on top of the snapshot
    st.record_release("a")
    assert sorted(st.load().jobs) == ["b"]
    st.close()


def test_compaction_crash_window_double_apply_is_idempotent(tmp_path):
    """Snapshot replaced but journal not yet truncated (the crash window
    between compact()'s two atomic replaces): replay applies every
    journal record on top of a snapshot that already contains it."""
    st = JobStore(str(tmp_path))
    ctl = AdmissionController(policy="ioctl")
    p = prof("a", 1)
    st.record_decision(p, ctl.try_admit(p), device=0)
    st.record_carry("a", 0, 2)
    before = st.load()
    # simulate: write the snapshot exactly as compact() would, but leave
    # the journal in place
    snap = {"v": 1, "config": before.config, "cluster": before.cluster,
            "jobs": {n: r.to_json() for n, r in before.jobs.items()}}
    with open(os.path.join(str(tmp_path), "snapshot.json"), "w") as f:
        json.dump(snap, f)
    after = JobStore(str(tmp_path)).load()
    assert after.jobs["a"].to_json() == before.jobs["a"].to_json()
    st.close()


def test_compact_concurrent_appends_lose_nothing(tmp_path):
    """Compaction racing a writer must not drop records: an earlier
    ``compact()`` folded the journal *outside* the store lock, so a
    decision appended between the fold and the journal truncation
    silently vanished.  Hammer that window: a thread appends admitted
    decisions while the main thread compacts in a tight loop — every
    appended job must survive into the folded state."""
    st = JobStore(str(tmp_path), sync=False)
    n = 300
    dec = {"admitted": True, "reason": "accepted", "via": "default",
           "wcrt": {}}

    def spam():
        for i in range(n):
            st.record_decision(prof(f"j{i}", 1), dec, device=0)

    t = threading.Thread(target=spam)
    t.start()
    while t.is_alive():
        st.compact()
    t.join()
    st.compact()
    state = st.load()
    assert sorted(state.jobs) == sorted(f"j{i}" for i in range(n)), \
        f"lost {n - len(state.jobs)} records to the compaction race"
    st.close()


def test_auto_compaction_policy_triggers(tmp_path):
    pol = CompactionPolicy(max_bytes=None, max_records=10)
    assert pol.due(0, 10, 0.0) and not pol.due(10**9, 9, 10**9)
    st = JobStore(str(tmp_path), sync=False, auto_compact=pol)
    dec = {"admitted": True, "reason": "accepted", "via": "default",
           "wcrt": {}}
    for i in range(25):
        st.record_decision(prof(f"j{i}", 1), dec, device=0)
    assert st.compactions >= 2
    with open(os.path.join(str(tmp_path), "journal.jsonl")) as f:
        assert sum(1 for ln in f if ln.strip()) < 10
    assert sorted(st.load().jobs) == sorted(f"j{i}" for i in range(25))
    st.close()


def test_failover_fold_displaced_until_settled(tmp_path):
    """A ``failover`` record moves the failed device's jobs onto the
    displaced ledger; they stay *unaccounted* until a follow-up
    decision (re-admission or refusal) settles them — the no-silent-
    job-loss audit the chaos suite replays."""
    st = JobStore(str(tmp_path), sync=False)
    ctl = AdmissionController(policy="ioctl", n_devices=2)
    for p in (prof("a", 1, device=0), prof("b", 2, device=1)):
        st.record_decision(p, ctl.try_admit(p), device=p.device)
    st.record_failover(0, epoch=1, reason="hw")
    mid = st.load()
    assert mid.epoch == 1 and mid.failed_devices == {0}
    assert mid.unaccounted() == ["a"] and sorted(mid.jobs) == ["b"]
    # settle "a": re-admitted on device 1 in the new epoch (the live
    # fail-over path re-derives the whole admission state, so the
    # displaced profile no longer charges the controller)
    ctl.release("a")
    a1 = prof("a", 1, device=1)
    st.record_decision(a1, ctl.try_admit(a1), device=1, epoch=1)
    state = st.load()
    assert state.unaccounted() == []
    assert list(state.jobs) == ["b", "a"]    # decision order preserved
    assert state.jobs["a"].device == 1
    # an explicit refusal also settles (accounted, not silently lost)
    st.record_failover(1, epoch=2, reason="hw")
    st.record_decision(prof("b", 2, device=1),
                       AdmissionDecision.refuse(
                           "validation-refused",
                           error="no surviving device"), epoch=2)
    end = st.load()
    # "a" lived on device 1 too — it stays *unaccounted* until settled,
    # which is exactly what the audit must flag
    assert end.unaccounted() == ["a"] and "b" not in end.jobs
    st.record_decision(prof("a", 1, device=0),
                       AdmissionDecision.refuse(
                           "validation-refused",
                           error="no surviving device"), epoch=2)
    assert st.load().unaccounted() == []
    # compaction round-trips the fault-containment state
    st.compact()
    snap = st.load()
    assert snap.epoch == 2 and snap.failed_devices == {0, 1}
    st.close()


def test_shed_fold_and_resume_decision(tmp_path):
    st = JobStore(str(tmp_path), sync=False)
    ctl = AdmissionController(policy="ioctl", n_devices=1)
    be = prof("be", 0, best_effort=True)
    st.record_decision(be, ctl.try_admit(be), device=0)
    st.record_carry("be", 0, 4)
    st.record_shed("be", "overload")
    mid = st.load()
    assert "be" not in mid.jobs and "be" in mid.shed
    assert mid.shed["be"].carry == {"iteration": 0, "slice": 4}
    ctl.release("be")
    st.record_decision(be, ctl.try_admit(be), device=0)   # resume
    state = st.load()
    assert "be" in state.jobs and state.shed == {}
    st.close()


def test_request_id_dedup_table_folds(tmp_path):
    st = JobStore(str(tmp_path), sync=False)
    ctl = AdmissionController(policy="ioctl", n_devices=1)
    p = prof("a", 1)
    st.record_decision(p, ctl.try_admit(p), device=0, request_id="r-1")
    st.compact()                     # the table survives compaction
    state = st.load()
    assert state.requests["r-1"]["job"] == "a"
    assert state.requests["r-1"]["admitted"] is True
    st.close()


def test_appends_are_thread_safe(tmp_path):
    st = JobStore(str(tmp_path), sync=False)

    def spam(k):
        for i in range(50):
            st.record_carry(f"job{k}", 0, i)

    threads = [threading.Thread(target=spam, args=(k,)) for k in range(4)]
    for t in threads:
        t.start()
    for t in threads:
        t.join()
    with open(os.path.join(str(tmp_path), "journal.jsonl")) as f:
        lines = [json.loads(ln) for ln in f if ln.strip()]
    assert len(lines) == 200          # no torn interleaved writes
    st.close()


# ---------------------------------------------------------------------------
# export / rebuild (recovery decision-conformance)
# ---------------------------------------------------------------------------

def _journal_two_jobs(tmp_path):
    st = JobStore(str(tmp_path))
    ctl = AdmissionController(policy="ioctl", n_devices=2)
    st.record_config(ctl.export_config(), {"n_devices": 2})
    for p in (prof("a", 1, device=0), prof("b", 2, device=1),
              prof("be", 0, best_effort=True)):
        st.record_decision(p, ctl.try_admit(p), device=p.device)
    st.close()
    return ctl


def test_rebuild_reproduces_admission_state(tmp_path):
    orig = _journal_two_jobs(tmp_path)
    state = JobStore(str(tmp_path)).load()
    ctl = AdmissionController.rebuild(state.config,
                                      state.admission_entries())
    assert [p.name for p in ctl.admitted] == [p.name
                                              for p in orig.admitted]
    assert ctl.export_config() == orig.export_config()
    # and the rebuilt controller prices new admissions identically
    nxt = prof("c", 3, device=0)
    assert decisions_match(orig.try_admit(nxt), ctl.try_admit(nxt))


def test_rebuild_conformance_mismatch_raises(tmp_path):
    _journal_two_jobs(tmp_path)
    state = JobStore(str(tmp_path)).load()
    entries = state.admission_entries()
    entries[0]["decision"] = dict(entries[0]["decision"],
                                  wcrt={"a": 999.0})   # drifted evidence
    with pytest.raises(RecoveryConformanceError, match="reproduce"):
        AdmissionController.rebuild(state.config, entries)
    # conform=False skips the identity check (debug escape hatch)
    ctl = AdmissionController.rebuild(state.config, entries,
                                      conform=False)
    assert len(ctl.admitted) == 3


def test_rebuild_refusal_on_readmission_raises(tmp_path):
    _journal_two_jobs(tmp_path)
    state = JobStore(str(tmp_path)).load()
    cfg = dict(state.config, headroom=1e-6)   # platform model drifted
    with pytest.raises(RecoveryConformanceError, match="refused"):
        AdmissionController.rebuild(cfg, state.admission_entries())


# ---------------------------------------------------------------------------
# checkpointer: shutdown drain + gc-vs-restore guard (satellite 3)
# ---------------------------------------------------------------------------

def test_atexit_drains_inflight_async_save(tmp_path):
    """An AsyncCheckpointer save in flight at interpreter exit must be
    drained, not killed with the daemon worker thread."""
    code = """
import sys
sys.path.insert(0, {src!r})
import numpy as np
from repro.sched import AsyncCheckpointer

class SlowArr(np.ndarray):
    pass

ckpt = AsyncCheckpointer({d!r}, keep=3)
import repro.sched.checkpointer as cp
orig = cp.save
def slow_save(ckpt_dir, step, tree):
    import time
    time.sleep(0.8)
    return orig(ckpt_dir, step, tree)
cp.save = slow_save
ckpt.save(1, {{"w": np.arange(4)}})
# exit immediately: without the atexit drain the worker dies mid-sleep
"""
    src = os.path.join(os.path.dirname(__file__), os.pardir, "src")
    env = dict(os.environ, REPRO_PALLAS="interpret")
    subprocess.run(
        [sys.executable, "-c",
         code.format(src=os.path.abspath(src), d=str(tmp_path))],
        check=True, env=env, timeout=120)
    from repro.sched import latest_step
    assert latest_step(str(tmp_path)) == 1


def test_gc_skips_step_held_by_concurrent_restore(tmp_path):
    import numpy as np

    from repro.sched import AsyncCheckpointer, restore, save
    from repro.sched.checkpointer import _reading

    for s in range(5):
        save(str(tmp_path), s, {"w": np.full(3, s)})
    ckpt = AsyncCheckpointer(str(tmp_path), keep=2)
    with _reading(str(tmp_path), 0):
        ckpt._gc()
        # step 0 is being read: exempt this pass
        assert os.path.isdir(os.path.join(str(tmp_path), "step_00000000"))
        out = restore(str(tmp_path), {"w": np.zeros(3)}, step=0)
        assert out["w"].tolist() == [0, 0, 0]
    ckpt._gc()                       # reader gone: next pass collects it
    assert not os.path.isdir(os.path.join(str(tmp_path), "step_00000000"))
