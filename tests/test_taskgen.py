"""Taskset generation (Table II) — structural invariants + hypothesis
property tests on UUniFast."""
import random

import pytest

from _optional import given, settings, st  # hypothesis or skip-shims

from repro.core import GenParams, generate_taskset, uunifast


@given(st.integers(0, 10_000), st.integers(1, 20),
       st.floats(0.05, 4.0))
@settings(max_examples=200, deadline=None)
def test_uunifast_sums_and_positivity(seed, n, total):
    utils = uunifast(random.Random(seed), n, total)
    assert len(utils) == n
    assert all(u >= 0 for u in utils)
    assert sum(utils) == pytest.approx(total, rel=1e-9)


@pytest.mark.parametrize("seed", range(50))
def test_taskset_structure(seed):
    p = GenParams()
    ts = generate_taskset(seed, p)
    n = len(ts.tasks)
    assert 3 * p.n_cpus <= n <= 6 * p.n_cpus
    prios = [t.priority for t in ts.tasks]
    assert len(set(prios)) == n
    # RM: strictly shorter period => strictly higher priority
    rt = sorted(ts.tasks, key=lambda t: t.period)
    for a, b in zip(rt, rt[1:]):
        assert a.priority > b.priority or a.period == b.period
    for t in ts.tasks:
        assert t.deadline == t.period
        if t.uses_gpu:
            assert 1 <= t.eta_g <= 3
            assert t.eta_c == t.eta_g + 1
            ratio = t.G / t.C
            assert 0.15 <= ratio <= 2.1  # G/C in [0.2, 2] up to split noise
            for g in t.gpu_segments:
                assert 0 < g.misc < g.total
        else:
            assert t.eta_g == 0


@pytest.mark.parametrize("seed", range(20))
def test_utilization_within_bounds(seed):
    ts = generate_taskset(seed, GenParams())
    per_cpu = {}
    for t in ts.tasks:
        per_cpu[t.cpu] = per_cpu.get(t.cpu, 0.0) + t.utilization
    for cpu, u in per_cpu.items():
        assert u <= 0.6 + 1e-6


def test_best_effort_ratio():
    p = GenParams(best_effort_ratio=0.5)
    ts = generate_taskset(3, p)
    n_be = sum(1 for t in ts.tasks if t.best_effort)
    assert n_be == round(0.5 * len(ts.tasks))
    for t in ts.tasks:
        if t.best_effort:
            assert t.priority < min(x.priority for x in ts.rt_tasks)


def test_bcet_ratio_applied():
    p = GenParams(bcet_ratio=0.7)
    ts = generate_taskset(0, p)
    for t in ts.tasks:
        assert t.C_best == pytest.approx(0.7 * t.C, rel=1e-9)
        for g in t.gpu_segments:
            assert g.exec_best == pytest.approx(0.7 * g.exec, rel=1e-9)


def test_n_tasks_total_override():
    p = GenParams(n_tasks_total=10)
    ts = generate_taskset(1, p)
    assert len(ts.tasks) == 10
