"""Hand-computed schedule tests in the style of the paper's Fig. 3.

Three tasks, priority tau1 > tau2 > tau3; tau1 alone on core 0, tau2/tau3 on
core 1.  Under the synchronization-based approach tau1 is blocked by the
long GPU segment of tau3 that is already holding the GPU; the IOCTL-based
approach preempts tau3's GPU execution; the kernel-thread approach reserves
the GPU for tau1's whole job from its release (paying epsilon on tau1's
core, mirroring the paper's '5.5 + epsilon' observation).

Every expected number below is derived by hand from the piece-level
semantics documented in repro.core.simulator.
"""
import math

import pytest

from repro.core import (GpuSegment, Task, Taskset, ioctl_busy_rta,
                        kthread_busy_rta, simulate)

EPS = 0.25


def fig3_taskset(epsilon=EPS, kthread_cpu=0):
    t1 = Task("tau1", cpu_segments=[2.5, 1.0],
              gpu_segments=[GpuSegment(0.0, 2.0)],
              period=100.0, deadline=100.0, cpu=0, priority=30)
    t2 = Task("tau2", cpu_segments=[1.0, 0.7],
              gpu_segments=[GpuSegment(0.0, 0.8)],
              period=100.0, deadline=100.0, cpu=1, priority=20)
    t3 = Task("tau3", cpu_segments=[0.5, 1.0],
              gpu_segments=[GpuSegment(0.0, 4.0)],
              period=100.0, deadline=100.0, cpu=1, priority=10)
    return Taskset([t1, t2, t3], n_cpus=2, epsilon=epsilon,
                   kthread_cpu=kthread_cpu)


def test_sync_priority_suspend_blocking():
    """tau3 grabs the GPU before tau1 requests it; non-preemptive access
    blocks tau1 for nearly tau3's whole 4-unit kernel."""
    ts = fig3_taskset()
    res = simulate(ts, "sync_priority", mode="suspend", horizon=100.0)
    # tau2 holds GPU 1.0-1.8, tau3 1.8-5.8; tau1 requests at 2.5, waits,
    # runs ge 5.8-7.8 and final CPU 7.8-8.8.
    assert res.mort["tau1"] == pytest.approx(8.8, abs=1e-6)
    assert res.mort["tau2"] == pytest.approx(2.5, abs=1e-6)
    assert res.mort["tau3"] == pytest.approx(6.8, abs=1e-6)


def test_ioctl_busy_preempts_gpu():
    """Segment-level preemption: tau1's GPU work overtakes tau3's."""
    ts = fig3_taskset()
    res = simulate(ts, "ioctl", mode="busy", horizon=100.0)
    # tau1: cpu 0-2.5, begin-update 2.5-2.75, ge 2.75-4.75,
    #       end-update 4.75-5.0, cpu 5.0-6.0.
    assert res.mort["tau1"] == pytest.approx(6.0, abs=1e-6)
    # tau2: begin 1.0-1.25, ge 1.25-2.05, end 2.05-2.3, cpu 2.3-3.0.
    assert res.mort["tau2"] == pytest.approx(3.0, abs=1e-6)
    # tau3: pending from 3.5, promoted by tau1's end-update at 5.0,
    #       ge 5.0-9.0, end 9.0-9.25, cpu 9.25-10.25.
    assert res.mort["tau3"] == pytest.approx(10.25, abs=1e-6)
    # preemption beats the synchronization-based schedule for tau1
    sync = simulate(fig3_taskset(), "sync_priority", mode="suspend",
                    horizon=100.0)
    assert res.mort["tau1"] < sync.mort["tau1"]


def test_kthread_busy_response_is_5_5_plus_eps():
    """Job-granular reservation: tau1's response is its stand-alone time
    plus exactly one runlist rewrite on its own core (the paper's
    '5.5 + epsilon' shape in Fig. 3b)."""
    ts = fig3_taskset(kthread_cpu=0)
    res = simulate(ts, "kthread", mode="busy", horizon=100.0)
    standalone = 2.5 + 2.0 + 1.0
    assert res.mort["tau1"] == pytest.approx(standalone + EPS, abs=1e-6)
    # tau2 waits for tau1's whole job (GPU reserved), then a rewrite:
    # ge 6.0-6.8, cpu 6.8-7.5.
    assert res.mort["tau2"] == pytest.approx(7.5, abs=1e-6)
    assert res.mort["tau3"] == pytest.approx(13.0, abs=1e-6)


def test_kthread_epsilon_scaling():
    """Doubling epsilon shifts tau1's kthread response by exactly 2x."""
    r1 = simulate(fig3_taskset(epsilon=0.25), "kthread", horizon=100.0)
    r2 = simulate(fig3_taskset(epsilon=0.5), "kthread", horizon=100.0)
    assert r2.mort["tau1"] - r1.mort["tau1"] == pytest.approx(0.25, abs=1e-6)


def test_unmanaged_round_robin_shares_gpu():
    """Default-driver time slicing: concurrent kernels interleave, so the
    highest-priority task's kernel is inflated by its GPU-sharing peers."""
    t1 = Task("t1", [0.0], [GpuSegment(0.0, 2.0)], 50.0, 50.0, 0, 30)
    t2 = Task("t2", [0.0], [GpuSegment(0.0, 2.0)], 50.0, 50.0, 1, 20)
    ts = Taskset([t1, t2], n_cpus=2, epsilon=0.0)
    res = simulate(ts, "unmanaged", mode="busy", horizon=50.0)
    # both kernels time-slice: combined makespan 4.0; t1 finishes within
    # [2.0, 4.0] and the loser at 4.0.
    assert max(res.mort["t1"], res.mort["t2"]) == pytest.approx(4.0, abs=1e-6)
    assert min(res.mort["t1"], res.mort["t2"]) >= 2.0 - 1e-9


def test_analysis_bounds_fig3():
    """Analytic WCRTs bound the simulated responses on the Fig. 3 taskset."""
    ts = fig3_taskset()
    res_k = simulate(fig3_taskset(), "kthread", horizon=400.0)
    res_i = simulate(fig3_taskset(), "ioctl", mode="busy", horizon=400.0)
    Rk = kthread_busy_rta(ts)
    Ri = ioctl_busy_rta(ts)
    for name in ("tau1", "tau2", "tau3"):
        assert not math.isinf(Rk[name])
        assert res_k.mort[name] <= Rk[name] + 1e-6
        assert res_i.mort[name] <= Ri[name] + 1e-6
