"""The policy registry (core/policy.py): one name space shared by the
simulator, the benchmarks, and the runtime executor — and one Algorithm 2
state machine behind both the simulated and the live IOCTL admission."""
import pytest

from repro.core import (Alg2State, GenParams, GpuSegment, SchedulingPolicy,
                        Task, Taskset, available_policies, generate_taskset,
                        make_policy, pick_reserved, policy_spec,
                        register_policy, simulate)
from repro.core import policy as policy_mod
from repro.core.ioctl import IoctlPolicy


def test_seed_policies_registered():
    names = available_policies()
    for name in ("unmanaged", "sync_priority", "sync_fifo", "kthread",
                 "ioctl"):
        assert name in names


def test_legacy_executor_mode_names_resolve():
    assert policy_spec("notify").name == "ioctl"
    assert policy_spec("poll").name == "kthread"
    assert policy_spec("unmanaged").name == "unmanaged"


def test_unknown_policy_rejected():
    with pytest.raises(ValueError, match="unknown scheduling approach"):
        make_policy("nonesuch")


def test_rtas_resolved_from_registry():
    from repro.core import (ioctl_busy_rta, ioctl_suspend_rta,
                            kthread_busy_rta)
    from repro.sched.admission import rta_for
    assert rta_for("ioctl", "busy") is ioctl_busy_rta
    assert rta_for("notify", "suspend") is ioctl_suspend_rta
    assert rta_for("poll", "busy") is kthread_busy_rta
    with pytest.raises(ValueError, match="no analysis"):
        rta_for("kthread", "suspend")


# ---------------------------------------------------------------------------
# a toy policy registered once shows up in all three consumers
# ---------------------------------------------------------------------------

class ToyPriorityPolicy(SchedulingPolicy):
    """Idealized zero-overhead preemptive priority GPU (no runlist cost):
    the highest-priority job wanting the device owns it, always."""

    name = "toy_prio"

    def gpu_owner(self):
        want = [j for j in self.sim.active_jobs()
                if j.wants_gpu() and j.task.device == self.device]
        return max(want, key=lambda j: j.task.gpu_priority, default=None)


@pytest.fixture
def toy_registered():
    register_policy("toy_prio", ToyPriorityPolicy, "test-only toy policy")
    yield
    policy_mod._REGISTRY.pop("toy_prio", None)


def fig3_like_taskset():
    t1 = Task("tau1", [2.5, 1.0], [GpuSegment(0.0, 2.0)], 100.0, 100.0, 0, 30)
    t3 = Task("tau3", [0.5, 1.0], [GpuSegment(0.0, 4.0)], 100.0, 100.0, 1, 10)
    return Taskset([t1, t3], n_cpus=2, epsilon=0.25)


def test_toy_policy_in_simulator(toy_registered):
    ts = fig3_like_taskset()
    res = simulate(ts, "toy_prio", mode="busy", horizon=100.0)
    # ideal preemption, zero epsilon: tau1 runs at its standalone time
    assert res.mort["tau1"] == pytest.approx(2.5 + 2.0 + 1.0, abs=1e-6)


def test_toy_policy_in_executor(toy_registered):
    from repro.sched import DeviceExecutor, RTJob
    ex = DeviceExecutor(policy="toy_prio")
    assert ex.policy_name == "toy_prio"
    job = RTJob("j", lambda job, it: None, period_s=1.0, priority=5)
    with ex._mutex:
        assert ex._admitted(job)  # base runtime face admits everything
    ex.shutdown()


def test_toy_policy_in_benchmarks(toy_registered):
    from benchmarks.run import bench_policies
    rows = bench_policies()
    assert any(r["policy"] == "toy_prio" for r in rows)
    assert any(r["policy"] == "ioctl" for r in rows)


# ---------------------------------------------------------------------------
# shared Algorithm 1 / 2 state machines
# ---------------------------------------------------------------------------

class FakeJob:
    """Runtime-shaped job (no .task): the accessors' duck-typing path."""

    def __init__(self, name, prio, rt=True):
        self.name = name
        self.priority = prio
        self.device_priority = prio
        self.is_rt = rt

    def __repr__(self):
        return self.name


def test_alg2_preemption_and_promotion():
    st = Alg2State()
    lo, hi, mid = FakeJob("lo", 1), FakeJob("hi", 3), FakeJob("mid", 2)
    assert st.add(lo) is True          # empty -> runlist rewrite
    assert st.add(hi) is True          # preempts lo
    assert st.running == [hi] and st.pending == [lo]
    assert lo.gpu_pending and not hi.gpu_pending
    assert st.add(mid) is False        # queued: cheap pending-only update
    assert st.remove(hi) is True       # mid promoted over lo
    assert st.running == [mid] and st.pending == [lo]
    assert st.remove(mid) is True      # union fallback re-admits lo
    assert st.running == [lo] and st.pending == []


def test_alg2_best_effort_displacement():
    st = Alg2State()
    be1, be2 = FakeJob("be1", 0, rt=False), FakeJob("be2", 0, rt=False)
    rt = FakeJob("rt", 5)
    st.add(be1)
    st.add(be2)
    assert st.running == [be1, be2]    # no RT member: BE co-run
    assert st.add(rt) is True          # displaces every best-effort TSG
    assert st.running == [rt]
    assert set(st.pending) == {be1, be2}
    st.remove(rt)                      # no RT pending: union re-admits BE
    assert set(st.running) == {be1, be2}


def test_executor_and_simulator_share_alg2():
    """The executor's task_running IS the policy's Alg2State list — the
    very class the simulator's IoctlPolicy drives."""
    from repro.sched import DeviceExecutor
    ex = DeviceExecutor(policy="ioctl")
    assert isinstance(ex.policy, IoctlPolicy)
    assert isinstance(ex.policy.alg2, Alg2State)
    assert ex.task_running is ex.policy.alg2.running
    sim_side = IoctlPolicy()
    assert type(sim_side.alg2) is type(ex.policy.alg2)
    ex.shutdown()


def test_pick_reserved_rule():
    jobs = [FakeJob("a", 1), FakeJob("be", 9, rt=False), FakeJob("b", 2)]
    assert pick_reserved(jobs).name == "b"     # highest-priority RT
    assert pick_reserved([jobs[1]]) is None    # best-effort never reserved
    assert pick_reserved([]) is None


def test_multi_device_simulator_needs_policy_per_device():
    from repro.core import Simulator, UnmanagedPolicy
    p = GenParams(n_cpus=2, tasks_per_cpu=(2, 3), n_devices=2)
    ts = generate_taskset(0, p)
    with pytest.raises(ValueError, match="one policy per device"):
        Simulator(ts, UnmanagedPolicy())
