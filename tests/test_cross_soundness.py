"""Cross-device busy-wait soundness (DESIGN.md §4, core/crossfix.py).

The simulator is ground truth: on multi-device platforms under
busy-waiting, every taskset the joint fixed-point analysis accepts must
have simulated MORT <= analytic WCRT for all tasks.  Tier-1 runs a small
seeded batch; the CI ``soundness`` job scales it past 200 tasksets via
``REPRO_SOUNDNESS_N`` (the batch is randomized-but-seeded: index i fully
determines the taskset).

Also pinned here: the constant-charge heuristic is *not* sound under
busy-waiting (golden counterexample), and the fixed point accepts
exactly as many tasksets as the heuristic on the heuristic's validated
sound region (single device, where the two coincide by construction).
"""

import math
import os
import warnings

import pytest

from repro.core import (
    GenParams,
    SoundnessWarning,
    generate_taskset,
    ioctl_busy_rta,
    kthread_busy_rta,
    schedulable,
    simulate,
)

APPROACHES = [
    ("kthread", kthread_busy_rta),
    ("ioctl", ioctl_busy_rta),
]

BATCH_N = int(os.environ.get("REPRO_SOUNDNESS_N", "24"))


def batch_case(i):
    """Deterministic batch point: device count, approach, and seed all
    derive from the index, spanning 1/2/4 devices x both busy modes."""
    n_devices = (1, 2, 4)[i % 3]
    approach, rta = APPROACHES[(i // 3) % 2]
    return n_devices, approach, rta, i


def make_taskset(seed, n_devices):
    p = GenParams(
        n_cpus=2, tasks_per_cpu=(2, 4), epsilon=0.5, n_devices=n_devices
    )
    ts = generate_taskset(seed, p)
    ts.kthread_cpu = ts.n_cpus  # dedicated scheduler core
    return ts


@pytest.mark.parametrize("i", range(BATCH_N))
def test_fixed_point_never_accepts_unsound(i):
    n_devices, approach, rta, seed = batch_case(i)
    ts = make_taskset(seed, n_devices)
    R = rta(ts)
    horizon = 6 * max(t.period for t in ts.tasks)
    res = simulate(ts, approach, mode="busy", horizon=horizon, exec_frac=1.0)
    checked = 0
    for t in ts.rt_tasks:
        bound = R[t.name]
        if bound is None or math.isinf(bound):
            continue  # not accepted: no guarantee claimed
        checked += 1
        assert res.mort[t.name] <= bound + 1e-6, (
            f"{approach}/busy n_devices={n_devices} seed={seed}: "
            f"{t.name} MORT {res.mort[t.name]:.4f} > WCRT {bound:.4f}"
        )
    if all(
        R[t.name] is not None and not math.isinf(R[t.name])
        for t in ts.rt_tasks
    ):
        assert checked == len(ts.rt_tasks)  # accepted => all tasks covered


@pytest.mark.parametrize("approach,rta", APPROACHES, ids=["kthread", "ioctl"])
def test_heuristic_unsound_golden_counterexample(approach, rta):
    """Golden case (2 devices, seed 4): the constant-charge projection's
    bound is exceeded in simulation — a core spinning behind its own
    device's contention occupies its CPU beyond the folded charge — while
    the joint fixed point holds."""
    ts = make_taskset(4, 2)
    with pytest.warns(SoundnessWarning):
        Rh = rta(ts, method="heuristic")
    Rf = rta(ts)
    horizon = 6 * max(t.period for t in ts.tasks)
    res = simulate(ts, approach, mode="busy", horizon=horizon, exec_frac=1.0)
    name = "tau1"
    assert res.mort[name] > Rh[name] + 1e-6  # heuristic bound broken
    assert res.mort[name] <= Rf[name] + 1e-6  # fixed point holds
    assert Rf[name] >= Rh[name]  # the iterate only adds demand


@pytest.mark.parametrize("seed", range(12))
@pytest.mark.parametrize("approach,rta", APPROACHES, ids=["kthread", "ioctl"])
def test_fixed_point_matches_heuristic_on_sound_region(seed, approach, rta):
    """Single device is the heuristic's validated sound region; there the
    fixed point degenerates to the same single-device recurrence, so the
    acceptance decisions coincide exactly (the fixed point gives up
    nothing where the heuristic was actually sound)."""
    ts = make_taskset(seed, 1)
    accept_fixed = schedulable(ts, rta)
    with warnings.catch_warnings():
        warnings.simplefilter("ignore", SoundnessWarning)
        accept_heur = schedulable(ts, rta, method="heuristic")
    assert accept_fixed == accept_heur
    assert rta(ts) == rta(ts, method="heuristic")
