"""Simulator ↔ executor trace conformance (DESIGN.md §2 as an executable
invariant, via the tests/conformance.py harness): live ClusterExecutor
runs are recorded through ExecutorTrace, replayed through the canonical
Algorithm 1/2 state machines and the discrete-event simulator, and the
decision sequences must agree — plus priority-inversion-freedom and
MORT ≤ WCRT on the same platform, on 1/2/4 devices, both approaches.

The kthread stale-reservation regression pinned here is a real find of
this harness: the runtime used to admit best-effort dispatches in the
completion → next-poll window where Algorithm 1's runlist is still
evicted (a priority-inversion window the simulator does not have).
"""
import time

import pytest

import conformance as C
from repro.sched import ClusterExecutor, RTJob


@pytest.mark.parametrize("n_devices", [1, 2, 4])
@pytest.mark.parametrize("policy,wait_mode", [("ioctl", "suspend"),
                                              ("kthread", "busy")],
                         ids=["ioctl", "kthread"])
def test_conformance_contention(policy, wait_mode, n_devices):
    run = C.run_executor(C.contention_scenario(n_devices), policy,
                         wait_mode, n_devices)
    counts = C.check_all(run)
    # every invariant actually bit: traffic on all devices, updates
    # replayed, decisions compared, bounds checked
    assert counts["dispatches"] >= 13 * n_devices
    assert counts["replayed_updates"] >= 3 * n_devices
    assert counts["agreed_decisions"] >= 3 * n_devices
    assert counts["wcrt_bounds"] == 2 * n_devices


@pytest.mark.parametrize("policy,wait_mode", [("ioctl", "suspend"),
                                              ("kthread", "busy")],
                         ids=["ioctl", "kthread"])
def test_two_device_isolation_pinned(policy, wait_mode):
    """Acceptance pin: a high-priority job admitted on device 0 is never
    delayed by jobs placed on device 1 — its trace shows no preempt
    events and its response time is its own execution (+ slack), while
    the simulator agrees with every admission decision of the run."""
    run = C.run_executor(C.isolation_scenario(), policy, wait_mode, 2)
    C.check_all(run)
    tr0 = run.cluster.executors[0].trace
    assert [e.job for e in tr0.of("preempt")] == []
    hp = run.jobs["hp0"]
    own = [s for s in run.specs if s.name == "hp0"][0].exec_ticks
    # never blocked: response ≤ own work + generous scheduling slack
    assert hp.stats.mort / C.TICK_S <= own + 4.0
    # and device 1 did see real contention (the test would be vacuous
    # against an idle rival device)
    tr1 = run.cluster.executors[1].trace
    assert len(tr1.of("preempt")) >= 1


@pytest.mark.parametrize("policy,wait_mode", [("ioctl", "suspend"),
                                              ("kthread", "busy")],
                         ids=["ioctl", "kthread"])
def test_fleet_scenario_mixed_criticality(policy, wait_mode):
    """The multi-model fleet pin: per device, two interactive RT
    'models' arriving in a burst over tier-1/tier-0 best-effort
    background work.  check_all asserts MORT ≤ admitted WCRT for every
    RT model and priority-inversion-freedom (best-effort never blocks
    RT) from the traces; on top, the per-model/per-tier stats surface
    must report every model under its tier with a coherent tail."""
    n_devices = 2
    run = C.run_executor(C.fleet_scenario(n_devices), policy,
                         wait_mode, n_devices)
    counts = C.check_all(run)
    assert counts["wcrt_bounds"] == 2 * n_devices   # every RT model
    per_model = run.cluster.per_model_stats()
    per_tier = run.cluster.per_tier_stats()
    assert {0, 1, 2} <= set(per_tier)
    tick_ms = C.TICK_S * 1e3
    for s in run.specs:
        m = per_model[s.name]
        assert m["tier"] == s.tier
        assert m["best_effort"] == s.best_effort
        assert m["completions"] >= 1
        assert s.name in per_tier[s.tier]["jobs"]
        # the stats surface re-states invariant 4 per model: observed
        # tail (ms -> ticks) within the admitted WCRT bound
        if not s.best_effort:
            assert m["deadline_misses"] == 0
            assert m["mort_ms"] is not None
            assert (m["mort_ms"] / tick_ms
                    <= run.wcrt_ticks[s.name] + 1e-9)
            assert m["p50_ms"] <= m["p99_ms"] <= m["mort_ms"]
    for t, row in per_tier.items():
        assert row["completions"] >= 1
        if row["p99_ms"] is not None:
            assert row["p99_ms"] <= row["mort_ms"] + 1e-9
    # tier rollup counts match the models under it
    assert per_tier[2]["jobs"] == sorted(
        s.name for s in run.specs if s.tier == 2)


def test_kthread_stale_reservation_window_regression():
    """After the reserved job completes, nothing may dispatch until the
    scheduler thread's next rewrite (Algorithm 1: runlists are only
    written by the kernel thread).  Drive the policy's runtime face
    directly to pin the exact window."""
    from repro.core import make_policy

    pol = make_policy("kthread")
    hi = RTJob("hi", lambda j, i: None, period_s=1.0, priority=20)
    lo = RTJob("lo", lambda j, i: None, period_s=1.0, priority=10)
    be = RTJob("be", lambda j, i: None, period_s=1.0, priority=0,
               best_effort=True)
    pol.runtime_apply(pol.runtime_pick([hi, lo]))
    assert pol.runtime_admitted(hi) and not pol.runtime_admitted(lo)
    pol.runtime_on_complete(hi)
    # the window between completion and the next poll: runlist still
    # evicted — neither the BE job nor lo may dispatch yet
    assert not pol.runtime_admitted(be)
    assert not pol.runtime_admitted(lo)
    # the next poll re-reserves for lo (and reports a rewrite even if
    # the picked job is unchanged, because the eviction is undone)
    assert pol.runtime_apply(pol.runtime_pick([lo]))
    assert pol.runtime_admitted(lo) and not pol.runtime_admitted(be)
    # and when no RT job is left, the poll re-admits everyone
    pol.runtime_on_complete(lo)
    assert not pol.runtime_admitted(be)
    assert pol.runtime_apply(pol.runtime_pick([]))
    assert pol.runtime_admitted(be)


def test_trace_event_order_is_mutex_order():
    """Events of one device are totally ordered (appended under the
    runlist mutex): timestamps are non-decreasing and every dispatch of
    a blocked job is preceded by its resume."""
    run = C.run_executor(C.contention_scenario(1), "ioctl", "suspend", 1)
    ev = run.cluster.executors[0].trace.events
    assert all(a.t <= b.t for a, b in zip(ev, ev[1:]))
    blocked = set()
    for e in ev:
        if e.event == "preempt":
            blocked.add(e.job)
        elif e.event == "resume":
            assert e.job in blocked
            blocked.discard(e.job)
        elif e.event == "dispatch":
            assert e.job not in blocked


def test_migration_free_assertion_fires():
    """assert_migration_free detects a forged cross-device dispatch."""
    cl = ClusterExecutor(n_devices=2, policy="ioctl", trace=True)
    job = RTJob("j", lambda j, i: None, period_s=1.0, priority=5,
                device=0)
    cl.bind_job(job)
    cl.executors[0].trace.emit(0, "dispatch", "j", uid=job.uid)
    cl.assert_migration_free()
    cl.executors[1].trace.emit(1, "dispatch", "j", uid=job.uid)
    with pytest.raises(AssertionError, match="migration"):
        cl.assert_migration_free()
    cl.shutdown()


def test_rebinding_refused():
    cl = ClusterExecutor(n_devices=2, policy="ioctl")
    job = RTJob("j", lambda j, i: None, period_s=1.0, priority=5)
    cl.bind_job(job, device=1)
    assert job.device == 1
    with pytest.raises(RuntimeError, match="migration-free"):
        cl.bind_job(job, device=0)
    # a job claiming a different device than its binding is caught at
    # dispatch-routing time as well
    job.device = 0
    with pytest.raises(RuntimeError, match="migration-free"):
        cl.run(job, lambda: None)
    cl.shutdown()


def test_executor_trace_smoke_single_executor():
    """ExecutorTrace on a bare DeviceExecutor (no cluster): the ioctl
    update snapshots carry the running/pending sets."""
    from repro.sched import DeviceExecutor, ExecutorTrace

    tr = ExecutorTrace()
    ex = DeviceExecutor(policy="ioctl", wait_mode="suspend", trace=tr)
    done = []

    def body(job, it):
        with ex.device_segment(job):
            ex.run(job, lambda: time.sleep(0.01))
        done.append(job.name)

    j = RTJob("solo", body, period_s=1.0, priority=5)
    j.start(ex)
    j.join(10)
    ex.shutdown()
    assert done == ["solo"]
    kinds = [e.event for e in tr.events]
    assert kinds == ["start", "update", "dispatch", "update", "complete"]
    begin = tr.of("update")[0]
    assert begin.info["which"] == "begin"
    assert begin.info["running"] == ("solo",)
