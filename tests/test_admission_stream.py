"""Streaming admission: the utilization-headroom fast-reject and the
batched arrival-burst path (`sched/admission.py`).

The headroom gate must honor the refuse-don't-crash contract (PR 5) —
a hopeless profile gets a refusal dict with an empty wcrt, never an
exception, and never poisons later admissions — and must be *sound*:
at the default ``headroom=1.0`` it only refuses profiles the full RTA
path would refuse anyway (it is a necessary condition, evaluated
before any fixed point runs).

``try_admit_many`` must be decision-identical to calling ``try_admit``
profile by profile, on bursts that mix real-time jobs with best-effort,
duplicate-name, out-of-range-device, and headroom-hopeless profiles —
under both vectorized backends.
"""
import math
import random

import pytest

from repro.core.batch_jax import HAVE_JAX
from repro.sched.admission import (AdmissionController, JobProfile,
                                   headroom_violation)

BACKENDS = [
    "numpy",
    pytest.param("jax", marks=pytest.mark.skipif(
        not HAVE_JAX, reason="jax not importable")),
]


def _prof(i, **kw):
    rng = random.Random(i)
    d = dict(name=f"job{i}",
             host_segments_ms=[rng.uniform(1.0, 4.0)],
             device_segments_ms=[(0.3, rng.uniform(2.0, 8.0))],
             period_ms=rng.choice([40.0, 60.0, 80.0, 120.0]),
             priority=500 - i, cpu=i % 4)
    d.update(kw)
    return JobProfile(**d)


def _mixed_burst():
    profs = [_prof(i) for i in range(14)]
    profs[3] = _prof(3, best_effort=True)
    profs[5] = _prof(5, device=9)            # out of range -> refusal
    profs[7] = _prof(7, name="job2")         # duplicate -> refusal
    profs[9] = _prof(9, period_ms=4.0)       # hopeless -> headroom gate
    profs[11] = _prof(11, cpu=77)            # Taskset build ValueError
    return profs


# --------------------------------------------------------------------------
# headroom fast-reject
# --------------------------------------------------------------------------

def test_headroom_refuses_core_overload_without_rta():
    ctl = AdmissionController(policy="ioctl", wait_mode="suspend")
    over = _prof(0, host_segments_ms=[12.0], period_ms=10.0)
    res = ctl.try_admit(over)
    assert not res["admitted"]
    assert res["wcrt"] == {}  # no fixed point ran
    assert "headroom" in res["error"] and "core" in res["error"]
    assert ctl.admitted == []  # refusal leaves no residue
    # the controller keeps working after the refusal
    assert ctl.try_admit(_prof(1))["admitted"]


def test_headroom_refuses_device_overload():
    ctl = AdmissionController(policy="ioctl", wait_mode="suspend")
    over = _prof(0, host_segments_ms=[0.5],
                 device_segments_ms=[(0.1, 11.0)], period_ms=10.0)
    res = ctl.try_admit(over)
    assert not res["admitted"] and "device 0" in res["error"]
    assert res["wcrt"] == {}


def test_headroom_exempts_best_effort():
    """BE jobs carry no guarantee, so the gate must not refuse them."""
    ctl = AdmissionController(policy="ioctl", wait_mode="suspend")
    over = _prof(0, host_segments_ms=[12.0], period_ms=10.0,
                 best_effort=True)
    assert ctl.try_admit(over)["via"] == "best_effort"


def test_headroom_violation_reports_per_core_and_device():
    ctl = AdmissionController(policy="ioctl", wait_mode="suspend")
    ts = ctl._taskset(_prof(0, host_segments_ms=[11.0], period_ms=10.0))
    assert "core 0" in headroom_violation(ts, 1.0)
    assert headroom_violation(ts, 2.0) is None  # slack widens the gate


@pytest.mark.parametrize("wait_mode", ["busy", "suspend"])
def test_headroom_gate_is_sound(wait_mode):
    """At headroom=1.0 the gate is a pure fast path: a controller with
    the gate and one with it disabled (headroom=inf, so only the RTA
    decides) admit exactly the same stream."""
    gated = AdmissionController(policy="ioctl", wait_mode=wait_mode)
    ungated = AdmissionController(policy="ioctl", wait_mode=wait_mode,
                                  headroom=math.inf)
    saw_gate_refusal = False
    for i in range(18):
        p = _prof(i, period_ms=random.Random(1000 + i).choice(
            [8.0, 15.0, 40.0, 80.0]))
        rg, ru = gated.try_admit(p), ungated.try_admit(p)
        assert rg["admitted"] == ru["admitted"], (wait_mode, i)
        saw_gate_refusal |= "headroom" in rg.get("error", "")
    assert [p.name for p in gated.admitted] == \
        [p.name for p in ungated.admitted]
    assert saw_gate_refusal  # the stream must actually exercise the gate


# --------------------------------------------------------------------------
# batched arrival bursts
# --------------------------------------------------------------------------

@pytest.mark.parametrize("backend", BACKENDS)
@pytest.mark.parametrize("wait_mode", ["busy", "suspend"])
def test_burst_matches_sequential(wait_mode, backend):
    seq = AdmissionController(policy="ioctl", wait_mode=wait_mode)
    bat = AdmissionController(policy="ioctl", wait_mode=wait_mode)
    profs = _mixed_burst()
    rs = [seq.try_admit(p) for p in profs]
    rb = bat.try_admit_many(profs, backend=backend)
    assert [r["admitted"] for r in rs] == [r["admitted"] for r in rb]
    assert [r["via"] for r in rs] == [r["via"] for r in rb]
    assert [r.get("error") for r in rs] == [r.get("error") for r in rb]
    assert [p.name for p in seq.admitted] == [p.name for p in bat.admitted]
    for a, b in zip(rs, rb):
        assert set(a["wcrt"]) == set(b["wcrt"])
        for name, r_s in a["wcrt"].items():
            r_b = b["wcrt"][name]
            if r_s is None or r_b is None:
                assert r_s is r_b  # best-effort: no bound either way
            elif math.isinf(r_s) or math.isinf(r_b):
                assert math.isinf(r_s) and math.isinf(r_b)
            else:
                assert abs(r_s - r_b) <= 1e-6 * max(1.0, abs(r_s))


def test_burst_audsley_retry_matches_sequential():
    """A burst whose tail only clears via GPU-priority reassignment
    still matches: the first RM refusal drops to the sequential path
    (Audsley retry included) and the remainder re-batches."""
    seq = AdmissionController(policy="ioctl", wait_mode="suspend")
    bat = AdmissionController(policy="ioctl", wait_mode="suspend")
    profs = [_prof(i, period_ms=30.0, host_segments_ms=[2.0],
                   device_segments_ms=[(0.3, 5.0)], cpu=i % 2)
             for i in range(8)]
    rs = [seq.try_admit(p) for p in profs]
    rb = bat.try_admit_many(profs)
    assert [r["admitted"] for r in rs] == [r["admitted"] for r in rb]
    assert [r["via"] for r in rs] == [r["via"] for r in rb]
    assert [p.name for p in seq.admitted] == [p.name for p in bat.admitted]


def test_burst_non_batch_rta_falls_back():
    """Approaches without a vectorized kind take the sequential path
    transparently (same results, no error)."""
    ctl = AdmissionController(policy="ioctl", wait_mode="suspend")
    ctl.rta = lambda ts, **kw: {t.name: 1.0 for t in ts.tasks}  # untagged
    profs = [_prof(i) for i in range(3)]
    res = ctl.try_admit_many(profs)
    assert [r["admitted"] for r in res] == [True] * 3
