"""The key soundness invariant (Table IV): simulated maximum observed
response times never exceed the analytic WCRT bounds, for every proposed
approach and analysis variant — including under execution-time variation
and GPU-segment priority assignment.

Also documents the two errata found in the paper's analysis (see
repro.core.analysis docstrings): the verbatim Lemma 1/Lemma 3 terms are
violated on concrete golden tasksets, while the corrected variants hold.
"""
import math

import pytest

from repro.core import (GenParams, assign_gpu_priorities, generate_taskset,
                        ioctl_busy_improved_rta, ioctl_busy_rta,
                        ioctl_suspend_improved_rta, ioctl_suspend_rta,
                        kthread_busy_rta, simulate)

CASES = [
    ("kthread", "busy", kthread_busy_rta),
    ("ioctl", "busy", ioctl_busy_rta),
    ("ioctl", "suspend", ioctl_suspend_rta),
    ("ioctl", "busy", ioctl_busy_improved_rta),
    ("ioctl", "suspend", ioctl_suspend_improved_rta),
]


def _check(ts, approach, mode, rta, horizon_periods=6, exec_frac=1.0, **kw):
    R = rta(ts, **kw)
    horizon = horizon_periods * max(t.period for t in ts.tasks)
    res = simulate(ts, approach, mode=mode, horizon=horizon,
                   exec_frac=exec_frac)
    for t in ts.rt_tasks:
        bound = R[t.name]
        if bound is None or math.isinf(bound):
            continue
        assert res.mort[t.name] <= bound + 1e-6, (
            f"{approach}/{mode}/{rta.__name__}: {t.name} "
            f"MORT {res.mort[t.name]:.4f} > WCRT {bound:.4f}")


@pytest.mark.parametrize("seed", range(40))
@pytest.mark.parametrize("approach,mode,rta", CASES,
                         ids=[c[2].__name__ for c in CASES])
def test_mort_bounded_by_wcrt(seed, approach, mode, rta):
    p = GenParams(n_cpus=2, tasks_per_cpu=(2, 4), epsilon=0.5)
    ts = generate_taskset(seed, p)
    ts.kthread_cpu = ts.n_cpus  # dedicated core for the kernel thread
    _check(ts, approach, mode, rta)


@pytest.mark.parametrize("seed", range(12))
def test_mort_bounded_with_execution_variation(seed):
    """Execution times below WCET must stay within the bounds too."""
    p = GenParams(n_cpus=2, tasks_per_cpu=(2, 4), epsilon=0.5,
                  bcet_ratio=0.6)
    ts = generate_taskset(seed, p)
    ts.kthread_cpu = ts.n_cpus
    for approach, mode, rta in CASES[:3]:
        for frac in (0.0, 0.5, 1.0):
            _check(ts, approach, mode, rta, exec_frac=frac)


@pytest.mark.parametrize("seed", range(15))
def test_mort_bounded_under_gpu_priority_assignment(seed):
    """Sec. V-C: the assigned GPU priorities drive both the runtime and the
    (use_gpu_prio) analysis; the bound must still hold."""
    p = GenParams(n_cpus=2, tasks_per_cpu=(2, 4), epsilon=0.5)
    ts = generate_taskset(seed, p)
    ts.kthread_cpu = ts.n_cpus
    assigned = assign_gpu_priorities(ts, ioctl_busy_rta)
    if assigned is None:
        pytest.skip("no feasible GPU priority assignment")
    assigned.kthread_cpu = assigned.n_cpus
    _check(assigned, "ioctl", "busy", ioctl_busy_rta, use_gpu_prio=True)


def test_erratum_lemma1_xi_term():
    """Golden case (GenParams(n_cpus=2, tasks_per_cpu=(2,4), eps=.5),
    seed 6): the paper's x_i makes K_i = 0 for a CPU-only task off the
    kernel-thread core, but its same-core higher-priority GPU tasks
    busy-wait through update-induced GPU pauses.  The verbatim bound is
    exceeded; the corrected bound holds."""
    p = GenParams(n_cpus=2, tasks_per_cpu=(2, 4), epsilon=0.5)
    ts = generate_taskset(6, p)
    ts.kthread_cpu = ts.n_cpus
    horizon = 6 * max(t.period for t in ts.tasks)
    res = simulate(ts, "kthread", mode="busy", horizon=horizon)
    verbatim = kthread_busy_rta(ts, corrected=False)
    corrected = kthread_busy_rta(ts, corrected=True)
    assert res.mort["tau1"] > verbatim["tau1"] + 1e-6   # paper bound broken
    assert res.mort["tau1"] <= corrected["tau1"] + 1e-6  # corrected holds


def test_erratum_lemma3_busy_stretch():
    """Golden case (seed 116): a same-core higher-priority GPU task's
    busy-window stretches by its own runlist-update blocking, which the
    verbatim Lemma 3 same-core term (C_h + G_h^*) omits."""
    p = GenParams(n_cpus=2, tasks_per_cpu=(2, 4), epsilon=0.5)
    ts = generate_taskset(116, p)
    ts.kthread_cpu = ts.n_cpus
    horizon = 6 * max(t.period for t in ts.tasks)
    res = simulate(ts, "ioctl", mode="busy", horizon=horizon)
    verbatim = ioctl_busy_rta(ts, corrected=False)
    corrected = ioctl_busy_rta(ts, corrected=True)
    assert res.mort["tau2"] > verbatim["tau2"] + 1e-6
    assert res.mort["tau2"] <= corrected["tau2"] + 1e-6
