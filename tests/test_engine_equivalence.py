"""Golden-trace equivalence for the event-driven engine.

The GOLDEN table below was captured by running the *seed* simulator (the
pre-engine, per-tick rescan loop removed in the engine refactor) over
fixed generated tasksets under every approach: max observed response time
per task (rounded to 1 ns) and total deadline-miss counts.  The
heap-based ``EventDrivenEngine`` must reproduce them exactly — any
semantic drift in the refactored scheduling core shows up here as a
ninth-decimal diff.

Also property-checks MORT <= WCRT on randomly generated tasksets with the
new engine, including the randomized per-piece execution-time path
(``exec_frac=None`` driven by ``Simulator.rng``).
"""
import math

import pytest

from repro.core import (GenParams, generate_taskset, ioctl_busy_rta,
                        ioctl_suspend_rta, kthread_busy_rta, simulate)

GEN = GenParams(n_cpus=2, tasks_per_cpu=(2, 4), epsilon=0.5)

GOLDEN = {
    0: {
        ('unmanaged', 'busy'): ({'tau0': 16.045206717, 'tau1': 110.345195437, 'tau2': 14.890292171, 'tau3': 3.360091572, 'tau4': 224.204540494, 'tau5': 216.847638825}, 0),
        ('sync_priority', 'suspend'): ({'tau0': 16.045206717, 'tau1': 215.729613211, 'tau2': 14.890292171, 'tau3': 12.44861184, 'tau4': 222.881411793, 'tau5': 216.920506643}, 0),
        ('sync_fifo', 'busy'): ({'tau0': 17.463722131, 'tau1': 215.729613211, 'tau2': 14.890292171, 'tau3': 112.989137207, 'tau4': 230.924723638, 'tau5': 223.567821968}, 4),
        ('kthread', 'busy'): ({'tau0': 16.045206717, 'tau1': 110.345195437, 'tau2': 14.890292171, 'tau3': 3.360091572, 'tau4': 234.102068207, 'tau5': 226.745166537}, 0),
        ('ioctl', 'busy'): ({'tau0': 16.045206717, 'tau1': 112.345195437, 'tau2': 14.890292171, 'tau3': 3.360091572, 'tau4': 228.204540494, 'tau5': 221.207730397}, 0),
        ('ioctl', 'suspend'): ({'tau0': 16.045206717, 'tau1': 112.345195437, 'tau2': 14.890292171, 'tau3': 3.360091572, 'tau4': 225.808543975, 'tau5': 221.207730397}, 0),
    },
    3: {
        ('unmanaged', 'busy'): ({'tau0': 38.509868047, 'tau1': 109.89374622, 'tau2': 10.528078658, 'tau3': 91.639959909, 'tau4': 139.866429435, 'tau5': 4.669075905}, 0),
        ('sync_priority', 'suspend'): ({'tau0': 38.509868047, 'tau1': 166.854654387, 'tau2': 35.479018381, 'tau3': 81.070244497, 'tau4': 126.00020146, 'tau5': 4.952559696}, 0),
        ('sync_fifo', 'busy'): ({'tau0': 40.000885507, 'tau1': 154.944888201, 'tau2': 38.470943503, 'tau3': 97.91584505, 'tau4': 144.606192087, 'tau5': 21.734660197}, 0),
        ('kthread', 'busy'): ({'tau0': 38.509868047, 'tau1': 112.636967172, 'tau2': 4.528078658, 'tau3': 85.639959909, 'tau4': 151.834707848, 'tau5': 4.669075905}, 0),
        ('ioctl', 'busy'): ({'tau0': 38.509868047, 'tau1': 115.89374622, 'tau2': 7.528078658, 'tau3': 91.639959909, 'tau4': 147.123208483, 'tau5': 7.669075905}, 0),
        ('ioctl', 'suspend'): ({'tau0': 38.509868047, 'tau1': 115.89374622, 'tau2': 7.528078658, 'tau3': 84.070244497, 'tau4': 140.820334402, 'tau5': 1.356596692}, 0),
    },
    6: {
        ('unmanaged', 'busy'): ({'tau0': 190.422106037, 'tau1': 117.353926833, 'tau2': 1.108426787, 'tau3': 4.330353874, 'tau4': 171.731057066, 'tau5': 115.091387181}, 0),
        ('sync_priority', 'suspend'): ({'tau0': 189.680631013, 'tau1': 116.675559158, 'tau2': 0.94913199, 'tau3': 36.252359017, 'tau4': 171.731057066, 'tau5': 115.091387181}, 0),
        ('sync_fifo', 'busy'): ({'tau0': 190.573944277, 'tau1': 117.353926833, 'tau2': 1.108426787, 'tau3': 36.467304605, 'tau4': 171.731057066, 'tau5': 115.091387181}, 0),
        ('kthread', 'busy'): ({'tau0': 192.700318133, 'tau1': 118.527582236, 'tau2': 1.829363736, 'tau3': 0.783072328, 'tau4': 171.731057066, 'tau5': 115.091387181}, 0),
        ('ioctl', 'busy'): ({'tau0': 199.422106037, 'tau1': 122.353926833, 'tau2': 4.108426787, 'tau3': 2.330353874, 'tau4': 171.731057066, 'tau5': 115.091387181}, 0),
        ('ioctl', 'suspend'): ({'tau0': 195.743738361, 'tau1': 118.675559158, 'tau2': 3.330970006, 'tau3': 2.330353874, 'tau4': 171.731057066, 'tau5': 115.091387181}, 0),
    },
    11: {
        ('unmanaged', 'busy'): ({'tau0': 28.936417665, 'tau1': 20.523515489, 'tau2': 86.574124852, 'tau3': 78.168574871, 'tau4': 5.069186026, 'tau5': 180.77465433, 'tau6': 116.11785776}, 0),
        ('sync_priority', 'suspend'): ({'tau0': 80.905206525, 'tau1': 43.29959497, 'tau2': 81.538900277, 'tau3': 75.953995402, 'tau4': 5.069186026, 'tau5': 136.651292997, 'tau6': 77.063682453}, 0),
        ('sync_fifo', 'busy'): ({'tau0': 44.58286692, 'tau1': 44.442413, 'tau2': 112.628910092, 'tau3': 87.550677768, 'tau4': 17.990803087, 'tau5': 171.99092488, 'tau6': 113.175160856}, 0),
        ('kthread', 'busy'): ({'tau0': 23.436417665, 'tau1': 16.523515489, 'tau2': 81.299040455, 'tau3': 163.241560243, 'tau4': 5.069186026, 'tau5': 251.045942156, 'tau6': 191.458331612}, 0),
        ('ioctl', 'busy'): ({'tau0': 26.936417665, 'tau1': 17.523515489, 'tau2': 83.859300364, 'tau3': 92.999429444, 'tau4': 5.069186026, 'tau5': 185.805956175, 'tau6': 121.149159606}, 0),
        ('ioctl', 'suspend'): ({'tau0': 23.609546365, 'tau1': 17.523515489, 'tau2': 73.262329534, 'tau3': 98.602363289, 'tau4': 5.069186026, 'tau5': 137.151292997, 'tau6': 71.901282553}, 0),
    },
    116: {
        ('unmanaged', 'busy'): ({'tau0': 18.147286645, 'tau1': 50.217962045, 'tau2': 35.595943808, 'tau3': 38.470164751, 'tau4': 74.898259081, 'tau5': 1.874272878, 'tau6': 73.099865627, 'tau7': 123.793825113}, 0),
        ('sync_priority', 'suspend'): ({'tau0': 42.424739322, 'tau1': 34.391643835, 'tau2': 22.569901821, 'tau3': 58.447465536, 'tau4': 71.568268794, 'tau5': 2.424149503, 'tau6': 71.258652289, 'tau7': 99.29573701}, 0),
        ('sync_fifo', 'busy'): ({'tau0': 42.424739322, 'tau1': 112.699802138, 'tau2': 59.105207843, 'tau3': 100.952004844, 'tau4': 76.580166914, 'tau5': 4.986955242, 'tau6': 70.36245327, 'tau7': 125.475732946}, 0),
        ('kthread', 'busy'): ({'tau0': 10.147286645, 'tau1': 106.196502216, 'tau2': 27.595943808, 'tau3': 94.448704921, 'tau4': 82.268513223, 'tau5': 1.874272878, 'tau6': 87.494124053, 'tau7': 137.163318435}, 0),
        ('ioctl', 'busy'): ({'tau0': 13.881125828, 'tau1': 92.203678298, 'tau2': 31.329782991, 'tau3': 50.790688188, 'tau4': 84.781261643, 'tau5': 1.874272878, 'tau6': 79.884082552, 'tau7': 134.676827675}, 0),
        ('ioctl', 'suspend'): ({'tau0': 13.881125828, 'tau1': 36.563362025, 'tau2': 24.069901821, 'tau3': 50.790688188, 'tau4': 80.808329209, 'tau5': 1.874272878, 'tau6': 79.884082552, 'tau7': 106.40575948}, 0),
    },
}


def _taskset(seed):
    ts = generate_taskset(seed, GEN)
    ts.kthread_cpu = ts.n_cpus  # dedicated scheduler core
    return ts


@pytest.mark.parametrize("seed", sorted(GOLDEN))
@pytest.mark.parametrize("approach,mode", sorted(next(iter(GOLDEN.values()))))
def test_engine_reproduces_seed_simulator(seed, approach, mode):
    ts = _taskset(seed)
    horizon = 4 * max(t.period for t in ts.tasks)
    res = simulate(ts, approach, mode=mode, horizon=horizon)
    want_mort, want_miss = GOLDEN[seed][(approach, mode)]
    got = {k: round(v, 9) for k, v in res.mort.items()}
    assert got == want_mort
    assert sum(res.deadline_misses.values()) == want_miss


RTAS = [("kthread", "busy", kthread_busy_rta),
        ("ioctl", "busy", ioctl_busy_rta),
        ("ioctl", "suspend", ioctl_suspend_rta)]


@pytest.mark.parametrize("seed", range(12))
def test_mort_bounded_by_wcrt_event_engine(seed):
    """The event-driven engine stays within the analytic bounds on random
    tasksets (complements tests/test_soundness.py with fresh seeds)."""
    ts = _taskset(200 + seed)
    horizon = 5 * max(t.period for t in ts.tasks)
    for approach, mode, rta in RTAS:
        R = rta(ts)
        res = simulate(ts, approach, mode=mode, horizon=horizon)
        for t in ts.rt_tasks:
            bound = R[t.name]
            if bound is None or math.isinf(bound):
                continue
            assert res.mort[t.name] <= bound + 1e-6, (
                f"{approach}/{mode}: {t.name} MORT {res.mort[t.name]:.4f} "
                f"> WCRT {bound:.4f}")


@pytest.mark.parametrize("seed", range(6))
def test_randomized_exec_times_bounded_and_seeded(seed):
    """exec_frac=None samples per-piece durations from Simulator.rng: runs
    are reproducible per seed, vary across seeds, and stay within WCRT."""
    p = GenParams(n_cpus=2, tasks_per_cpu=(2, 4), epsilon=0.5,
                  bcet_ratio=0.5)
    ts = generate_taskset(seed, p)
    ts.kthread_cpu = ts.n_cpus
    horizon = 5 * max(t.period for t in ts.tasks)
    a = simulate(ts, "ioctl", mode="busy", horizon=horizon,
                 exec_frac=None, seed=7)
    b = simulate(ts, "ioctl", mode="busy", horizon=horizon,
                 exec_frac=None, seed=7)
    c = simulate(ts, "ioctl", mode="busy", horizon=horizon,
                 exec_frac=None, seed=8)
    assert a.mort == b.mort                      # same seed, same schedule
    assert a.mort != c.mort                      # the seed is not ignored
    R = ioctl_busy_rta(ts)
    for t in ts.rt_tasks:
        bound = R[t.name]
        if bound is None or math.isinf(bound):
            continue
        for res in (a, c):
            assert res.mort[t.name] <= bound + 1e-6
