"""The GPU-access-segment abstraction (DESIGN.md §6): layout sharing with
the simulator, measured slice profiles mapping onto the η/G/ε task model,
the executor's sliced dispatch loop (bounded preemption delay), and the
measured-profile → admission-decision pipeline end-to-end."""
import time

import numpy as np
import pytest

from repro.core import GpuSegment, Task, build_pieces
from repro.core.segments import (SegmentedWorkload, SliceProfile, SlicedOp,
                                 WorkloadProfile, n_slices_for,
                                 segment_layout)
from repro.sched import (AdmissionController, DeviceExecutor, JobProfile,
                         RTJob)
from repro.sched.job import JobStats


def _task(n_cpu_segs=2, n_gpu_segs=1):
    return Task("t", [1.0] * n_cpu_segs,
                [GpuSegment(0.5, 3.0) for _ in range(n_gpu_segs)],
                period=100, deadline=100, cpu=0, priority=5)


# ---------------------------------------------------------------------------
# one segment structure for analysis and simulator
# ---------------------------------------------------------------------------

@pytest.mark.parametrize("nc,ng,ioctl", [(2, 1, True), (2, 1, False),
                                         (3, 2, True), (1, 0, True),
                                         (2, 3, False)])
def test_segment_layout_matches_build_pieces(nc, ng, ioctl):
    """The simulator's piece stream is exactly the shared layout with
    durations attached — segment boundaries defined once."""
    if ng > nc:
        t = Task("t", [1.0] * nc, [GpuSegment(0.5, 3.0)] * ng,
                 period=100, deadline=100, cpu=0, priority=5)
    else:
        t = _task(nc, ng)
    layout = segment_layout(t, ioctl)
    pieces = build_pieces(t, ioctl, epsilon=1.0)
    assert [(p.kind, p.seg if p.kind != "cpu" else layout[i][1])
            for i, p in enumerate(pieces)] == layout
    # eta counts visible in the layout match the analysis model
    assert sum(1 for k, _ in layout if k == "cpu") == t.eta_c
    assert sum(1 for k, _ in layout if k == "ge") == t.eta_g


def test_segment_layout_ioctl_brackets_every_ge():
    t = _task(3, 2)
    layout = segment_layout(t, True)
    for j in range(t.eta_g):
        i = layout.index(("ge", j))
        assert layout[i - 1] == ("upd", j)
        assert layout[i + 1] == ("upde", j)


# ---------------------------------------------------------------------------
# SlicedOp mechanics
# ---------------------------------------------------------------------------

def test_sliced_op_run_and_resume():
    def step(c, i):
        return c + [i]

    op = SlicedOp(4, lambda: [], step, tuple)
    assert op.run() == (0, 1, 2, 3)
    assert op.run(carry=[0, 1], start=2) == (0, 1, 2, 3)


def test_n_slices_for():
    assert n_slices_for(8, 3) == 3
    assert n_slices_for(8, 8) == 1
    assert n_slices_for(8, 100) == 1
    with pytest.raises(ValueError):
        n_slices_for(8, 0)
    with pytest.raises(ValueError):
        SlicedOp(0, lambda: None, lambda c, i: c, lambda c: c)


# ---------------------------------------------------------------------------
# measured slice profiles -> η/G/m/ε parameters
# ---------------------------------------------------------------------------

def test_slice_profile_maps_to_task_model():
    sp = SliceProfile("seg", slice_ms=[2.0, 3.0, 2.5], init_ms=0.4,
                      finalize_ms=0.1)
    assert sp.exec_ms == pytest.approx(7.5)     # G^e: sum of slices
    assert sp.misc_ms == pytest.approx(0.5)     # G^m: host-side work
    assert sp.max_slice_ms == 3.0               # the ε analogue
    g = sp.to_gpu_segment(margin=2.0)
    assert g.misc == pytest.approx(1.0) and g.exec == pytest.approx(15.0)

    wp = WorkloadProfile("job", host_ms=[1.0, 2.0],
                         device=[sp, SliceProfile("b", [5.0])])
    assert wp.eta_c == 2 and wp.eta_g == 2
    assert wp.max_slice_ms == 5.0
    assert wp.epsilon_ms(update_cost_ms=0.5) == pytest.approx(5.5)
    t = wp.to_task(period_ms=100, priority=7)
    assert t.eta_c == 2 and t.eta_g == 2
    assert t.G == pytest.approx(7.5 + 0.5 + 5.0)
    prof = JobProfile.from_workload(wp, period_ms=100, priority=7,
                                    margin=1.0)
    assert prof.to_task().G == pytest.approx(t.G)


def test_segmented_workload_profile_and_bind():
    calls = []

    def make_op():
        def step(c, i):
            time.sleep(0.002)
            calls.append(i)
            return c

        return SlicedOp(3, lambda: 0, step, lambda c: c, label="dev")

    wl = (SegmentedWorkload("w")
          .host(lambda: time.sleep(0.001))
          .device(make_op))
    assert wl.eta_c == 1 and wl.eta_g == 1
    prof = wl.profile(reps=2)
    assert prof.eta_c == 1 and prof.eta_g == 1
    assert len(prof.device[0].slice_ms) == 3
    assert prof.device[0].exec_ms >= 3 * 2.0 * 0.9
    assert prof.max_slice_ms >= 2.0 * 0.9

    # bind() dispatches the device segment through the executor
    ex = DeviceExecutor(policy="ioctl", wait_mode="suspend")
    calls.clear()
    job = RTJob("w", wl.bind(ex), period_s=10.0, priority=5)
    job.start(ex)
    job.join(20)
    ex.shutdown()
    assert calls == [0, 1, 2]
    assert len(job.stats.slice_times) == 4  # 3 slices + finalize


# ---------------------------------------------------------------------------
# executor: sliced dispatch bounds the preemption delay
# ---------------------------------------------------------------------------

def test_preemption_latency_bounded_by_one_slice():
    """A best-effort job streams 80ms slices (whole op: 400ms).  A
    high-priority release mid-op must reach the device within one slice
    + ε + scheduling margin — not after the whole op."""
    slice_s = 0.08
    ex = DeviceExecutor(policy="ioctl", wait_mode="suspend")
    t_first = []

    def be_body(job, it):
        def step(c, i):
            time.sleep(slice_s)
            return c

        with ex.device_segment(job):
            ex.run_sliced(job, SlicedOp(5, lambda: None, step,
                                        lambda c: c))

    def rt_body(job, it):
        with ex.device_segment(job):
            ex.run(job, lambda: t_first.append(time.perf_counter()))

    be = RTJob("be", be_body, period_s=10.0, priority=0, best_effort=True)
    rt = RTJob("rt", rt_body, period_s=10.0, priority=50)
    be.start(ex)
    time.sleep(slice_s * 1.5)  # release mid-op (inside slice 1 or 2)
    t_req = time.perf_counter()
    rt.start(ex)
    rt.join(20)
    be.join(20)
    ex.shutdown()
    assert t_first, "rt job never dispatched"
    latency = t_first[0] - t_req
    eps = max(ex.update_times) if ex.update_times else 0.0
    # bound: one in-flight slice + runlist update + OS scheduling margin
    assert latency <= slice_s + eps + 0.05, (
        f"preemption latency {latency * 1e3:.1f}ms exceeds one slice "
        f"({slice_s * 1e3:.0f}ms) + eps; whole-op wait would be "
        f"{5 * slice_s * 1e3:.0f}ms")
    # sanity: the bound actually separates sliced from whole-op waiting
    assert latency < 5 * slice_s


def test_run_sliced_checkpoint_and_resume():
    ex = DeviceExecutor(policy="ioctl", wait_mode="suspend")
    job = RTJob("j", lambda job, it: None, period_s=1.0, priority=5)
    snaps = {}

    def make_op():
        return SlicedOp(6, lambda: np.zeros(3),
                        lambda c, i: c + (i + 1),
                        lambda c: c * 10)

    with ex.device_segment(job):
        out = ex.run_sliced(job, make_op(),
                            checkpoint=lambda i, c: snaps.update({i: c}),
                            checkpoint_every=2)
    assert sorted(snaps) == [2, 4, 6]
    with ex.device_segment(job):
        resumed = ex.run_sliced(job, make_op(), carry=snaps[4], start=4)
    ex.shutdown()
    np.testing.assert_array_equal(out, resumed)
    assert len(job.stats.slice_times) == 6 + 1 + 2 + 1
    assert job.stats.max_slice_time == max(job.stats.slice_times)


# ---------------------------------------------------------------------------
# measured profile -> admission decision, end to end
# ---------------------------------------------------------------------------

def test_measured_profile_flows_into_admission():
    """Real (interpret-mode Pallas) sliced kernel → measured per-slice
    profile → η/G/ε JobProfile → RTA admission decision."""
    import jax
    import jax.numpy as jnp

    from repro.kernels.flash_attention import flash_attention_sliced

    ks = jax.random.split(jax.random.PRNGKey(0), 3)
    q = jax.random.normal(ks[0], (1, 64, 2, 16), jnp.float32)
    k = jax.random.normal(ks[1], (1, 64, 2, 16), jnp.float32)
    v = jax.random.normal(ks[2], (1, 64, 2, 16), jnp.float32)

    wl = SegmentedWorkload("attn").device(
        lambda: flash_attention_sliced(q, k, v, block_q=32, block_k=32,
                                       kv_slice=1, interpret=True))
    prof = wl.profile(reps=2)
    assert prof.eta_g == 1 and prof.device[0].n_slices == 2
    assert prof.device[0].exec_ms > 0

    ac = AdmissionController(policy="ioctl", wait_mode="suspend", n_cpus=1,
                             epsilon_ms=max(prof.epsilon_ms(0.1), 0.1))
    res = ac.try_admit(JobProfile.from_workload(
        prof, period_ms=60_000, priority=10))
    assert res["admitted"], res
    assert res["wcrt"]["attn"] > 0
    # an impossible deadline from the same measured profile is refused
    ac2 = AdmissionController(policy="ioctl", wait_mode="suspend", n_cpus=1,
                              epsilon_ms=max(prof.epsilon_ms(0.1), 0.1))
    tight = JobProfile.from_workload(prof, period_ms=60_000, priority=10)
    tight.deadline_ms = prof.device[0].exec_ms / 1e3  # way below G^e
    assert not ac2.try_admit(tight)["admitted"]


# ---------------------------------------------------------------------------
# JobStats: idle jobs must not read as meeting their deadline
# ---------------------------------------------------------------------------

def test_jobstats_mort_none_before_first_completion():
    st = JobStats()
    assert st.mort is None
    assert st.max_slice_time is None
    st.response_times.append(0.25)
    assert st.mort == 0.25
    st.slice_times.extend([0.01, 0.03])
    assert st.max_slice_time == 0.03
