"""Property-based fuzzing of every registered policy's runtime face.

Random arrival / priority / segment sequences are driven through the
same hook surface ``DeviceExecutor`` uses, asserting after every step:

  (a) the reserved job (policies with an Algorithm 1 reservation) is
      always a highest-device-priority active real-time job;
  (b) ``Alg2State`` (policies with Algorithm 2 lists) never admits two
      RT programs on one device concurrently — and never co-schedules a
      best-effort member with an RT member;
  (c) for the paper's approaches (a reservation or Alg2 lists), a
      best-effort job is never admitted while a real-time job is denied
      — BE work cannot block RT work.  The lock-based sync baselines
      are exempt by design: a best-effort lock holder blocking an RT
      waiter is exactly the priority inversion the paper's approaches
      remove (Sec. II).

``hypothesis`` stays optional via tests/_optional.py (property tests
skip without it); a seeded exhaustive-ish fallback below runs the same
driver regardless, so the invariants are exercised on every platform.
"""
import random

import pytest

from _optional import given, settings, st  # hypothesis or skip-shims
from repro.core import available_policies, make_policy
from repro.sched import RTJob

ACTIONS = ("start", "begin", "end", "complete", "poll")
MAX_JOBS = 5


def _jobs(prios, dprios, be_flags):
    return [RTJob(f"j{i}", lambda j, it: None, period_s=1.0,
                  priority=prios[i], device_priority=dprios[i],
                  best_effort=be_flags[i])
            for i in range(len(prios))]


def _check_invariants(pol, active, in_seg):
    paper_approach = (hasattr(pol, "reserved") or hasattr(pol, "alg2"))
    # (a) Algorithm 1: reserved is a top-device-priority active RT job
    res = getattr(pol, "reserved", None)
    if res is not None:
        assert res.is_rt, f"reserved a best-effort job: {res.name}"
        assert res in active, f"reserved a dead job: {res.name}"
        top = max(j.device_priority for j in active if j.is_rt)
        assert res.device_priority == top, (
            f"reserved {res.name} (dprio {res.device_priority}) over a "
            f"higher-priority active RT job (top {top})")
    # (b) Algorithm 2: at most one RT program; no BE next to an RT
    alg2 = getattr(pol, "alg2", None)
    if alg2 is not None:
        rt_running = [j for j in alg2.running if j.is_rt]
        assert len(rt_running) <= 1, (
            f"two RT programs admitted concurrently: "
            f"{[j.name for j in rt_running]}")
        if rt_running:
            be_running = [j for j in alg2.running if not j.is_rt]
            assert not be_running, (
                f"best-effort {[j.name for j in be_running]} co-admitted "
                f"with RT {rt_running[0].name}")
        assert not (set(map(id, alg2.running)) &
                    set(map(id, alg2.pending))), "running ∩ pending ≠ ∅"
    # (c) BE never blocks RT (paper approaches only; see module docstring)
    if paper_approach:
        domain = in_seg if pol.needs_segment_hooks else active
        denied_rt = [j for j in domain
                     if j.is_rt and not pol.runtime_admitted(j)]
        admitted_be = [j for j in domain
                       if not j.is_rt and pol.runtime_admitted(j)]
        assert not (denied_rt and admitted_be), (
            f"BE {[j.name for j in admitted_be]} admitted while RT "
            f"{[j.name for j in denied_rt]} is denied")


def drive(policy_name, prios, dprios, be_flags, script):
    """Interpret ``script`` (a list of (job_idx, action)) leniently —
    illegal transitions are skipped — exactly the way the executor
    drives the runtime face, checking invariants after every step."""
    pol = make_policy(policy_name)
    pol.runtime_attach(None)
    jobs = _jobs(prios, dprios, be_flags)
    active, in_seg, completed = [], [], set()

    def poll():
        if pol.wants_poll_thread:
            pol.runtime_poll([j for j in active if j.is_rt])

    steps = 0
    for idx, act in script:
        job = jobs[idx % len(jobs)]
        if act == "start":
            if job in active or job.uid in completed:
                continue
            active.append(job)
            pol.runtime_on_start(job)
            poll()
        elif act == "begin":
            if job not in active or job in in_seg:
                continue
            if pol.needs_segment_hooks:
                pol.runtime_segment_begin(job)
            in_seg.append(job)
        elif act == "end":
            if job not in in_seg:
                continue
            if pol.needs_segment_hooks:
                pol.runtime_segment_end(job)
            in_seg.remove(job)
        elif act == "complete":
            if job not in active:
                continue
            if job in in_seg:   # well-formed jobs close their segments
                if pol.needs_segment_hooks:
                    pol.runtime_segment_end(job)
                in_seg.remove(job)
            active.remove(job)
            completed.add(job.uid)
            pol.runtime_on_complete(job)
            poll()
        else:  # "poll"
            poll()
        _check_invariants(pol, active, in_seg)
        steps += 1
    return steps


# ---------------------------------------------------------------------------
# hypothesis properties (skip without the test extra)
# ---------------------------------------------------------------------------

@pytest.mark.parametrize("policy", available_policies())
@settings(max_examples=60, deadline=None)
@given(prios=st.permutations(list(range(1, MAX_JOBS + 1))),
       dprios=st.permutations(list(range(1, MAX_JOBS + 1))),
       be_flags=st.lists(st.booleans(), min_size=MAX_JOBS,
                         max_size=MAX_JOBS),
       script=st.lists(st.tuples(st.integers(0, MAX_JOBS - 1),
                                 st.sampled_from(ACTIONS)),
                       max_size=80))
def test_policy_invariants_fuzzed(policy, prios, dprios, be_flags, script):
    drive(policy, prios, dprios, be_flags, script)


@settings(max_examples=100, deadline=None)
@given(rt_flags=st.lists(st.booleans(), min_size=2, max_size=6),
       script=st.lists(st.tuples(st.integers(0, 5),
                                 st.sampled_from(["add", "remove"])),
                       max_size=60))
def test_alg2_state_never_two_rt(rt_flags, script):
    """Algorithm 2 in isolation: whatever the add/remove interleaving,
    task_running holds at most one RT member and never mixes RT with
    best-effort members."""
    from repro.core import Alg2State

    class Stub:
        def __init__(self, i, rt):
            self.name = f"s{i}"
            self.is_rt = rt
            self.priority = self.device_priority = i + 1
            self.gpu_pending = False

    stubs = [Stub(i, rt) for i, rt in enumerate(rt_flags)]
    st_ = Alg2State()
    inside = set()
    for idx, op in script:
        s = stubs[idx % len(stubs)]
        if op == "add" and id(s) not in inside:
            st_.add(s)
            inside.add(id(s))
        elif op == "remove" and id(s) in inside:
            st_.remove(s)
            inside.discard(id(s))
        rt_running = [j for j in st_.running if j.is_rt]
        assert len(rt_running) <= 1
        if rt_running:
            assert all(j.is_rt for j in st_.running)
        assert not (set(map(id, st_.running)) & set(map(id, st_.pending)))


# ---------------------------------------------------------------------------
# seeded fallback: same driver, no hypothesis required
# ---------------------------------------------------------------------------

def test_alg2_end_from_pending_does_not_corrupt_runlist():
    """Regression (found by this fuzzer): end() from a job that never
    reached task_running (its segment body errored/cancelled while
    pending — the executor's device_segment.__exit__ still calls end())
    used to run the handover and admit a pending RT job *next to* the
    current holder: two RT programs concurrently.  The departing
    pending job must simply be dropped."""
    from repro.core import Alg2State

    class Stub:
        def __init__(self, name, prio, rt=True):
            self.name = name
            self.is_rt = rt
            self.priority = self.device_priority = prio
            self.gpu_pending = False

    holder, waiter, be = Stub("hold", 20), Stub("wait", 10), \
        Stub("be", 0, rt=False)
    st_ = Alg2State()
    st_.add(holder)
    st_.add(be)       # pending behind the RT holder
    st_.add(waiter)   # pending, lower priority than holder
    assert [j.name for j in st_.running] == ["hold"]
    # the BE job gives up from pending: no handover, no membership change
    assert st_.remove(be) is False
    assert [j.name for j in st_.running] == ["hold"]
    assert not be.gpu_pending
    # the waiter gives up from pending: holder keeps the runlist alone
    assert st_.remove(waiter) is False
    assert [j.name for j in st_.running] == ["hold"]
    # and the real holder's end() still hands over normally
    st_.add(waiter)
    assert st_.remove(holder) is True
    assert [j.name for j in st_.running] == ["wait"]


def test_best_effort_device_priority_is_ignored():
    """Regression (found by this fuzzer): a best-effort RTJob built with
    an explicit high device_priority used to outrank RT arrivals in
    Alg2State.top_running, pushing the RT job to task_pending behind
    best-effort work.  BE jobs have no real-time priority — the
    constructor must pin their device priority to the BE level."""
    from repro.sched.job import BEST_EFFORT

    be = RTJob("be", lambda j, it: None, period_s=1.0, priority=0,
               device_priority=99, best_effort=True)
    assert be.device_priority == BEST_EFFORT
    # and the end-to-end Alg2 consequence: the RT arrival preempts
    drive("ioctl", [10, 0], [10, 99], [False, True],
          [(1, "start"), (1, "begin"), (0, "start"), (0, "begin")])


@pytest.mark.parametrize("policy", available_policies())
@pytest.mark.parametrize("seed", range(8))
def test_policy_invariants_seeded(policy, seed):
    # PYTHONHASHSEED-stable seed (hash() is randomized per process)
    rng = random.Random(10_000 * seed + sum(map(ord, policy)))
    n = rng.randint(1, MAX_JOBS)
    prios = rng.sample(range(1, 50), n)
    dprios = rng.sample(range(1, 50), n)
    be_flags = [rng.random() < 0.4 for _ in range(n)]
    script = [(rng.randrange(n), rng.choice(ACTIONS))
              for _ in range(120)]
    assert drive(policy, prios, dprios, be_flags, script) > 0
