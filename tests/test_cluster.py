"""ClusterExecutor: placement-aware admission (admit→place→bind), the
per-device executor/policy structure, boundary-device regressions for
the live crossfix admission path, and the disaggregated-serving smoke
(DESIGN.md §7)."""
import os
import subprocess
import sys

import pytest

from repro.sched import AdmissionController, ClusterExecutor, JobProfile, RTJob


def prof(name, prio, device=0, exec_ms=4.0, period_ms=50.0, cpu=0,
         best_effort=False):
    return JobProfile(name, host_segments_ms=[1.0],
                      device_segments_ms=[(0.5, exec_ms)],
                      period_ms=period_ms, priority=prio, cpu=cpu,
                      best_effort=best_effort, device=device)


# ---------------------------------------------------------------------------
# construction / structure
# ---------------------------------------------------------------------------

def test_one_policy_instance_per_device():
    cl = ClusterExecutor(n_devices=3, policy="ioctl")
    assert len(cl.executors) == 3
    assert [ex.device_index for ex in cl.executors] == [0, 1, 2]
    policies = [ex.policy for ex in cl.executors]
    assert len({id(p) for p in policies}) == 3  # no shared state
    assert all(p.name == "ioctl" for p in policies)
    cl.shutdown()


def test_kthread_cluster_coerces_admission_wait_mode():
    """kthread executors force busy-waiting; the cluster's admission must
    price that mode (Sec. V-A), not the requested suspend."""
    cl = ClusterExecutor(n_devices=2, policy="kthread",
                         wait_mode="suspend")
    assert all(ex.wait_mode == "busy" for ex in cl.executors)
    assert cl.admission.wait_mode == "busy"
    cl.shutdown()


def test_heterogeneous_policies_need_explicit_admission():
    with pytest.raises(ValueError, match="heterogeneous"):
        ClusterExecutor(n_devices=2, policy=["ioctl", "kthread"])
    ac = AdmissionController(policy="ioctl", wait_mode="busy", n_devices=2)
    cl = ClusterExecutor(n_devices=2, policy=["ioctl", "kthread"],
                         wait_mode="busy", admission=ac)
    assert cl.executors[1].policy.name == "kthread"
    cl.shutdown()


def test_admission_device_count_must_match():
    ac = AdmissionController(policy="ioctl", n_devices=3)
    with pytest.raises(ValueError, match="models 3 devices"):
        ClusterExecutor(n_devices=2, policy="ioctl", admission=ac)


# ---------------------------------------------------------------------------
# placement strategies
# ---------------------------------------------------------------------------

def test_pinned_placement_honors_profile_device():
    cl = ClusterExecutor(n_devices=2, policy="ioctl", n_cpus=2)
    r = cl._submit(prof("a", 20, device=1), body=lambda j, i: None)
    assert r["admitted"] and r["device"] == 1
    assert r["job"].device == 1
    cl.shutdown()


def test_round_robin_spreads_and_wraps():
    cl = ClusterExecutor(n_devices=2, policy="ioctl", n_cpus=4,
                         placement="round_robin")
    devs = [cl._submit(prof(f"j{i}", 20 - i, cpu=i % 4),
                      body=lambda j, i: None)["device"]
            for i in range(4)]
    assert devs == [0, 1, 0, 1]
    cl.shutdown()


def test_least_loaded_prefers_empty_device():
    cl = ClusterExecutor(n_devices=2, policy="ioctl", n_cpus=2,
                         placement="least_loaded")
    a = cl._submit(prof("a", 20, exec_ms=20.0), body=lambda j, i: None)
    b = cl._submit(prof("b", 19, exec_ms=4.0, cpu=1),
                  body=lambda j, i: None)
    assert a["device"] == 0 and b["device"] == 1
    cl.shutdown()


def test_placement_retries_next_candidate_when_admission_refuses():
    """least_loaded re-runs the cross-device admission per candidate: a
    device saturated by an admitted heavy job rejects the newcomer, and
    the placement falls through to the device where it fits."""
    cl = ClusterExecutor(n_devices=2, policy="ioctl", n_cpus=2,
                         wait_mode="suspend", placement="least_loaded",
                         epsilon_ms=0.1)
    # heavy RT load pinned to device 0 (just admissible alone there);
    # utilization-wise device 0 still looks *less* loaded than what b
    # brings, so least_loaded tries device 0 first — and must fall
    # through to device 1 on the RTA refusal
    a = cl._submit(prof("a", 20, device=0, exec_ms=30.0, period_ms=100.0),
                  strategy="pinned", body=lambda j, i: None)
    assert a["admitted"]
    b = cl._submit(prof("b", 30, exec_ms=80.0, period_ms=100.0, cpu=1),
                  body=lambda j, i: None)
    assert b["admitted"] and b["device"] == 1
    # with both devices refusing, the submit reports the last refusal
    c = cl._submit(prof("c", 10, exec_ms=90.0, period_ms=100.0, cpu=1),
                  body=lambda j, i: None)
    assert not c["admitted"] and c["device"] is None and c["job"] is None
    cl.shutdown()


def test_rejected_submit_leaves_no_state():
    cl = ClusterExecutor(n_devices=1, policy="ioctl", n_cpus=1)
    r = cl._submit(prof("x", 10, exec_ms=500.0, period_ms=50.0),
                  body=lambda j, i: None)
    assert not r["admitted"]
    assert cl.admission.admitted == []
    assert cl.stats()["jobs"][0] == []
    cl.shutdown()


def test_submit_requires_exactly_one_of_workload_and_body():
    cl = ClusterExecutor(n_devices=1, policy="ioctl")
    with pytest.raises(ValueError, match="exactly one"):
        cl._submit(prof("x", 10))
    cl.shutdown()


# ---------------------------------------------------------------------------
# the admit→place→bind transaction, live
# ---------------------------------------------------------------------------

def test_submitted_jobs_run_where_placed():
    cl = ClusterExecutor(n_devices=2, policy="ioctl", n_cpus=2,
                         trace=True)
    ran = {}

    def body_for(tag):
        def body(job, it):
            with cl.device_segment(job):
                cl.run(job, lambda: ran.setdefault(tag, job.device))
        return body

    r0 = cl._submit(prof("a", 20, device=0), body=body_for("a"),
                   start=True)
    r1 = cl._submit(prof("b", 19, device=1, cpu=1), body=body_for("b"),
                   start=True)
    cl.join(10)
    cl.shutdown()
    assert ran == {"a": 0, "b": 1}
    assert cl.executors[0].dispatches == 1
    assert cl.executors[1].dispatches == 1
    cl.assert_migration_free()
    assert r0["job"].stats.completions == 1
    assert r1["job"].stats.completions == 1
    morts = cl.per_device_mort()
    assert morts[0] is not None and morts[1] is not None


def test_segmented_workload_bind_device_mismatch_raises():
    from repro.core.segments import SegmentedWorkload, SlicedOp

    wl = SegmentedWorkload("w").device(
        lambda: SlicedOp(1, lambda: None, lambda c, i: c, lambda c: c))
    cl = ClusterExecutor(n_devices=2, policy="ioctl")
    body = wl.bind(cl, device=1)
    job = RTJob("w", body, period_s=1.0, priority=5, device=0)
    with pytest.raises(RuntimeError, match="pinned to device 1"):
        body(job, 0)
    # and against a plain DeviceExecutor of the wrong device index
    body0 = wl.bind(cl.executors[0], device=1)
    job2 = RTJob("w2", body0, period_s=1.0, priority=5)
    with pytest.raises(RuntimeError, match="cannot run"):
        body0(job2, 0)
    cl.shutdown()


# ---------------------------------------------------------------------------
# boundary-device regressions: the crossfix admission path driven by a
# live runtime (device == n_devices - 1, busy-wait, n_devices > 1)
# ---------------------------------------------------------------------------

@pytest.mark.parametrize("policy", ["ioctl", "kthread"])
@pytest.mark.parametrize("n_devices", [2, 4])
def test_boundary_device_busy_admission_live(policy, n_devices):
    """Admit onto the *last* device under busy-wait (the RTA resolves to
    core/crossfix.py) and actually run the job there — the path no test
    drove end-to-end before this suite."""
    cl = ClusterExecutor(n_devices=n_devices, policy=policy,
                         wait_mode="busy", n_cpus=2, epsilon_ms=0.5)
    boundary = n_devices - 1
    done = []

    def body(job, it):
        with cl.device_segment(job):
            cl.run(job, lambda: done.append(job.device))

    r = cl._submit(prof("edge", 20, device=boundary), body=body,
                  start=True)
    assert r["admitted"], r
    assert r["device"] == boundary
    assert r["wcrt"].get("edge") is not None
    # a second job on device 0 exercises the cross-device fold
    r2 = cl._submit(prof("other", 19, device=0, cpu=1),
                   body=body, start=True)
    assert r2["admitted"], r2
    cl.join(10)
    cl.shutdown()
    assert sorted(done) == [0, boundary]
    cl.assert_migration_free()


def test_try_admit_refuses_instead_of_crashing():
    """Regression (found while driving the live path): Taskset validation
    errors — colliding priorities, duplicate names — must surface as
    refusals; raising would take down the gatekeeper, and the best-effort
    fast path used to append unvalidated profiles that poisoned every
    later admission check."""
    ac = AdmissionController(policy="ioctl", wait_mode="busy", n_cpus=2,
                             epsilon_ms=0.5, n_devices=2)
    assert ac.try_admit(prof("a", 20, device=1))["admitted"]
    # colliding priority -> refusal, not ValueError
    r = ac.try_admit(prof("b", 20, device=0, cpu=1))
    assert not r["admitted"] and "unique" in r["error"]
    # duplicate name -> refusal
    r = ac.try_admit(prof("a", 19, device=0))
    assert not r["admitted"] and "already admitted" in r["error"]
    # best-effort profiles are validated too: a second BE profile with
    # the same priority (BE priorities collide with each other after the
    # Task rebase, not with RT ones) must not be appended — it used to
    # poison every later _taskset build
    assert ac.try_admit(prof("be1", 5, device=1,
                             best_effort=True))["admitted"]
    r = ac.try_admit(prof("be2", 5, device=0, best_effort=True))
    assert not r["admitted"] and "unique" in r["error"]
    assert [p.name for p in ac.admitted] == ["a", "be1"]
    # the controller still works afterwards
    assert ac.try_admit(prof("c", 18, device=0, cpu=1))["admitted"]


def test_cluster_release_allows_resubmission():
    """A retired job stops charging admission and its name becomes
    submittable again — even onto a different device.  Both generations
    *dispatch* (non-vacuously), so the released generation's device-0
    dispatch trace must not read as a migration of the device-1 rerun."""
    cl = ClusterExecutor(n_devices=2, policy="ioctl", n_cpus=2,
                         trace=True)

    def body(job, it):
        with cl.device_segment(job):
            cl.run(job, lambda: None)

    r1 = cl._submit(prof("req", 20, device=0, exec_ms=30.0,
                        period_ms=100.0),
                   body=body, start=True)
    assert r1["admitted"]
    r1["job"].join(10)
    # same name refused while still admitted
    assert not cl._submit(prof("req", 19, device=1),
                         body=body)["admitted"]
    assert cl.release("req")
    r2 = cl._submit(prof("req", 19, device=1), body=body, start=True)
    assert r2["admitted"] and r2["device"] == 1
    r2["job"].join(10)
    assert r1["job"].stats.completions == 1
    assert r2["job"].stats.completions == 1
    # dispatches happened on both devices under the same *name* but
    # different uids: not a migration
    cl.assert_migration_free()
    cl.shutdown()


def test_admission_release_frees_capacity():
    ac = AdmissionController(policy="ioctl", wait_mode="suspend", n_cpus=1,
                             epsilon_ms=0.5, n_devices=1)
    assert ac.try_admit(prof("big", 20, exec_ms=30.0))["admitted"]
    refused = ac.try_admit(prof("big2", 10, exec_ms=30.0))
    assert not refused["admitted"]
    assert ac.release("big")
    assert not ac.release("big")  # already gone
    assert ac.try_admit(prof("big2", 10, exec_ms=30.0))["admitted"]


# ---------------------------------------------------------------------------
# disaggregated serving (prefill/decode pools on separate devices)
# ---------------------------------------------------------------------------

def test_serve_disaggregated_two_device_subprocess():
    """`serve --n-devices 2` on a forced 2-device host platform: run in a
    subprocess so the XLA device-count flag does not leak."""
    env = dict(os.environ, PYTHONPATH="src",
               XLA_FLAGS="--xla_force_host_platform_device_count=2",
               REPRO_PALLAS="interpret")
    out = subprocess.run(
        [sys.executable, "-m", "repro.launch.serve", "--arch",
         "smollm-135m", "--reduced", "--batch", "2", "--prompt-len", "16",
         "--decode", "8", "--n-devices", "2"],
        env=env, capture_output=True, text=True, timeout=500,
        cwd=os.path.dirname(os.path.dirname(os.path.abspath(__file__))))
    assert "disaggregated serve OK" in out.stdout, \
        out.stdout[-2000:] + out.stderr[-2000:]
    assert "prefill -> device 0" in out.stdout
    assert "decode -> device 1" in out.stdout
