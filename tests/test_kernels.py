"""Pallas kernel validation: interpret-mode execution against the pure-jnp
oracles in kernels/ref.py, over shape/dtype sweeps and hypothesis-driven
randomized cases (deliverable c)."""
import jax
import jax.numpy as jnp
import numpy as np
import pytest

from _optional import given, settings, st  # hypothesis or skip-shims

from repro.kernels import ref
from repro.kernels.decode_attention import flash_decode
from repro.kernels.flash_attention import flash_attention
from repro.kernels.mamba_scan import mamba_scan_pallas
from repro.kernels.rwkv6 import rwkv6_scan_pallas

TOL = {jnp.float32: dict(rtol=2e-5, atol=2e-5),
       jnp.bfloat16: dict(rtol=2e-2, atol=2e-2)}


def _rand(key, shape, dtype):
    return jax.random.normal(key, shape, jnp.float32).astype(dtype)


# ---------------------------------------------------------------------------
# flash attention
# ---------------------------------------------------------------------------

@pytest.mark.parametrize("dtype", [jnp.float32, jnp.bfloat16])
@pytest.mark.parametrize("b,sq,sk,h,hkv,d,causal,window", [
    (2, 128, 128, 4, 2, 64, True, None),
    (1, 256, 256, 2, 2, 64, True, 96),       # sliding window
    (2, 128, 256, 4, 4, 128, True, None),    # q_offset (chunked prefill)
    (1, 128, 128, 2, 1, 64, False, None),    # non-causal (cross-attn)
    (1, 64, 64, 8, 8, 128, True, None),
])
def test_flash_attention_vs_oracle(b, sq, sk, h, hkv, d, causal, window,
                                   dtype):
    ks = jax.random.split(jax.random.PRNGKey(0), 3)
    q = _rand(ks[0], (b, sq, h, d), dtype)
    k = _rand(ks[1], (b, sk, hkv, d), dtype)
    v = _rand(ks[2], (b, sk, hkv, d), dtype)
    qo = sk - sq
    want = ref.attention_dense(q, k, v, causal=causal, window=window,
                               q_offset=qo)
    got = flash_attention(q, k, v, causal=causal, window=window,
                          q_offset=qo, block_q=64, block_k=64,
                          interpret=True)
    np.testing.assert_allclose(np.asarray(got, np.float32),
                               np.asarray(want, np.float32), **TOL[dtype])


def test_flash_attention_gradients_match_oracle():
    ks = jax.random.split(jax.random.PRNGKey(1), 3)
    q = _rand(ks[0], (1, 64, 2, 32), jnp.float32)
    k = _rand(ks[1], (1, 64, 2, 32), jnp.float32)
    v = _rand(ks[2], (1, 64, 2, 32), jnp.float32)

    def f_kernel(q, k, v):
        return flash_attention(q, k, v, interpret=True, block_q=32,
                               block_k=32).sum()

    def f_ref(q, k, v):
        return ref.attention_dense(q, k, v).sum()

    g1 = jax.grad(f_kernel, argnums=(0, 1, 2))(q, k, v)
    g2 = jax.grad(f_ref, argnums=(0, 1, 2))(q, k, v)
    for a, b_ in zip(g1, g2):
        np.testing.assert_allclose(np.asarray(a), np.asarray(b_),
                                   rtol=2e-4, atol=2e-4)


@given(st.integers(1, 3), st.integers(1, 4), st.integers(0, 1),
       st.booleans())
@settings(max_examples=10, deadline=None)
def test_flash_attention_hypothesis(b, hkv_pow, grp_pow, causal):
    hkv = 2 ** (hkv_pow - 1)
    h = hkv * (2 ** grp_pow)
    ks = jax.random.split(jax.random.PRNGKey(b * 17 + h), 3)
    q = _rand(ks[0], (b, 64, h, 32), jnp.float32)
    k = _rand(ks[1], (b, 64, hkv, 32), jnp.float32)
    v = _rand(ks[2], (b, 64, hkv, 32), jnp.float32)
    want = ref.attention_dense(q, k, v, causal=causal)
    got = flash_attention(q, k, v, causal=causal, block_q=32, block_k=32,
                          interpret=True)
    np.testing.assert_allclose(np.asarray(got), np.asarray(want),
                               rtol=2e-5, atol=2e-5)


def test_chunked_reference_matches_dense():
    """The XLA fallback (dry-run path) equals the oracle too."""
    ks = jax.random.split(jax.random.PRNGKey(2), 3)
    q = _rand(ks[0], (2, 128, 4, 32), jnp.float32)
    k = _rand(ks[1], (2, 128, 2, 32), jnp.float32)
    v = _rand(ks[2], (2, 128, 2, 32), jnp.float32)
    want = ref.attention_dense(q, k, v, causal=True, window=50)
    got = ref.attention_chunked(q, k, v, causal=True, window=50, chunk=32)
    np.testing.assert_allclose(np.asarray(got), np.asarray(want),
                               rtol=2e-5, atol=2e-5)


# ---------------------------------------------------------------------------
# flash decode
# ---------------------------------------------------------------------------

@pytest.mark.parametrize("dtype", [jnp.float32, jnp.bfloat16])
@pytest.mark.parametrize("b,smax,h,hkv,d,clen,window", [
    (2, 256, 4, 2, 64, 200, None),
    (1, 512, 8, 8, 64, 512, None),
    (2, 256, 4, 1, 128, 100, None),
    (2, 256, 4, 2, 64, 256, 128),            # ring-buffer window
    (3, 128, 6, 2, 64, 64, None),
])
def test_flash_decode_vs_oracle(b, smax, h, hkv, d, clen, window, dtype):
    ks = jax.random.split(jax.random.PRNGKey(3), 3)
    q = _rand(ks[0], (b, h, d), dtype)
    kc = _rand(ks[1], (b, smax, hkv, d), dtype)
    vc = _rand(ks[2], (b, smax, hkv, d), dtype)
    want = ref.decode_attention(q, kc, vc, clen, window=window)
    got = flash_decode(q, kc, vc, clen, window=window, block_k=64,
                       interpret=True)
    np.testing.assert_allclose(np.asarray(got, np.float32),
                               np.asarray(want, np.float32), **TOL[dtype])


def test_flash_decode_per_batch_lengths():
    ks = jax.random.split(jax.random.PRNGKey(4), 3)
    q = _rand(ks[0], (3, 4, 32), jnp.float32)
    kc = _rand(ks[1], (3, 128, 2, 32), jnp.float32)
    vc = _rand(ks[2], (3, 128, 2, 32), jnp.float32)
    lens = jnp.array([10, 64, 128], jnp.int32)
    want = ref.decode_attention(q, kc, vc, lens)
    got = flash_decode(q, kc, vc, lens, block_k=32, interpret=True)
    np.testing.assert_allclose(np.asarray(got), np.asarray(want),
                               rtol=2e-5, atol=2e-5)


# ---------------------------------------------------------------------------
# rwkv6 / mamba recurrences
# ---------------------------------------------------------------------------

@pytest.mark.parametrize("b,s,h,d,chunk", [
    (2, 64, 2, 16, 16), (1, 128, 4, 32, 32), (2, 32, 1, 64, 8)])
def test_rwkv6_kernel_vs_oracle(b, s, h, d, chunk):
    ks = jax.random.split(jax.random.PRNGKey(5), 5)
    r = _rand(ks[0], (b, s, h, d), jnp.float32)
    k = _rand(ks[1], (b, s, h, d), jnp.float32) * 0.3
    v = _rand(ks[2], (b, s, h, d), jnp.float32)
    w = jax.nn.sigmoid(_rand(ks[3], (b, s, h, d), jnp.float32))  # decay<1
    u = _rand(ks[4], (h, d), jnp.float32) * 0.1
    want_o, want_s = ref.rwkv6_scan(r, k, v, w, u)
    got_o, got_s = rwkv6_scan_pallas(r, k, v, w, u, chunk=chunk,
                                     interpret=True)
    np.testing.assert_allclose(np.asarray(got_o), np.asarray(want_o),
                               rtol=1e-4, atol=1e-4)
    np.testing.assert_allclose(np.asarray(got_s), np.asarray(want_s),
                               rtol=1e-4, atol=1e-4)


def test_rwkv6_kernel_with_initial_state():
    ks = jax.random.split(jax.random.PRNGKey(6), 5)
    b, s, h, d = 1, 32, 2, 16
    r = _rand(ks[0], (b, s, h, d), jnp.float32)
    k = _rand(ks[1], (b, s, h, d), jnp.float32) * 0.3
    v = _rand(ks[2], (b, s, h, d), jnp.float32)
    w = jax.nn.sigmoid(_rand(ks[3], (b, s, h, d), jnp.float32))
    u = _rand(ks[4], (h, d), jnp.float32) * 0.1
    s0 = _rand(ks[0], (b, h, d, d), jnp.float32)
    want_o, want_s = ref.rwkv6_scan(r, k, v, w, u, s0=s0)
    got_o, got_s = rwkv6_scan_pallas(r, k, v, w, u, s0=s0, chunk=8,
                                     interpret=True)
    np.testing.assert_allclose(np.asarray(got_o), np.asarray(want_o),
                               rtol=1e-4, atol=1e-4)
    np.testing.assert_allclose(np.asarray(got_s), np.asarray(want_s),
                               rtol=1e-4, atol=1e-4)


@pytest.mark.parametrize("bt,s,di,n,chunk,bd", [
    (2, 64, 64, 16, 16, 32), (1, 32, 128, 8, 8, 128), (2, 32, 32, 4, 32, 32)])
def test_mamba_kernel_vs_oracle(bt, s, di, n, chunk, bd):
    ks = jax.random.split(jax.random.PRNGKey(7), 5)
    x = _rand(ks[0], (bt, s, di), jnp.float32)
    dt = jax.nn.softplus(_rand(ks[1], (bt, s, di), jnp.float32))
    A = -jnp.exp(_rand(ks[2], (di, n), jnp.float32) * 0.5)
    B = _rand(ks[3], (bt, s, n), jnp.float32)
    C = _rand(ks[4], (bt, s, n), jnp.float32)
    D = jnp.ones((di,), jnp.float32)
    want_y, want_h = ref.mamba_scan(x, dt, A, B, C, D)
    got_y, got_h = mamba_scan_pallas(x, dt, A, B, C, D, chunk=chunk,
                                     block_d=bd, interpret=True)
    np.testing.assert_allclose(np.asarray(got_y), np.asarray(want_y),
                               rtol=1e-4, atol=1e-4)
    np.testing.assert_allclose(np.asarray(got_h), np.asarray(want_h),
                               rtol=1e-4, atol=1e-4)


def test_mamba_kernel_with_initial_state():
    ks = jax.random.split(jax.random.PRNGKey(8), 6)
    bt, s, di, n = 1, 16, 32, 8
    x = _rand(ks[0], (bt, s, di), jnp.float32)
    dt = jax.nn.softplus(_rand(ks[1], (bt, s, di), jnp.float32))
    A = -jnp.exp(_rand(ks[2], (di, n), jnp.float32) * 0.5)
    B = _rand(ks[3], (bt, s, n), jnp.float32)
    C = _rand(ks[4], (bt, s, n), jnp.float32)
    D = jnp.ones((di,), jnp.float32)
    h0 = _rand(ks[5], (bt, di, n), jnp.float32)
    want_y, want_h = ref.mamba_scan(x, dt, A, B, C, D, h0=h0)
    got_y, got_h = mamba_scan_pallas(x, dt, A, B, C, D, h0=h0, chunk=8,
                                     block_d=32, interpret=True)
    np.testing.assert_allclose(np.asarray(got_y), np.asarray(want_y),
                               rtol=1e-4, atol=1e-4)
    np.testing.assert_allclose(np.asarray(got_h), np.asarray(want_h),
                               rtol=1e-4, atol=1e-4)


# ---------------------------------------------------------------------------
# end-to-end: model with Pallas kernels == model with reference ops
# ---------------------------------------------------------------------------

def test_model_forward_with_pallas_kernels():
    from repro.configs import get
    from repro.kernels import ops
    from repro.models import forward, init_params
    cfg = get("olmo-1b").reduced()
    params = init_params(cfg, jax.random.PRNGKey(0))
    toks = jax.random.randint(jax.random.PRNGKey(1), (2, 64), 0,
                              cfg.vocab_size)
    want = forward(cfg, params, toks)
    ops.set_use_pallas(True, interpret=True)
    try:
        got = forward(cfg, params, toks)
    finally:
        ops.set_use_pallas(None)
    # bf16 end-to-end accumulation over 4 layers: ~2% of logit scale
    np.testing.assert_allclose(np.asarray(got, np.float32),
                               np.asarray(want, np.float32),
                               rtol=5e-2, atol=0.1)
