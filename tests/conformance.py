"""Simulator ↔ executor trace-conformance harness (DESIGN.md §2/§7).

DESIGN.md §2 claims the simulator and the runtime executor cannot drift
apart because both drive the *same* policy state machines.  This module
turns that claim into executable invariants.  A scenario is a list of
:class:`JobSpec`s (release offset, priority, device, alternating
host/device segments, all in abstract **ticks**); the harness

  * runs it on a live ``ClusterExecutor`` (one tick = ``TICK_S`` wall
    seconds, device programs are timed sleeps) with ``ExecutorTrace``
    recording every dispatch/preempt/resume/complete and every runlist
    update with its policy-state snapshot;
  * replays the identical timing through the discrete-event
    ``Simulator`` (one tick = one simulated ms) under recording
    subclasses of the same policies;

and checks, per device:

  1. **priority-inversion-freedom** — no job dispatches while a
     higher-device-priority real-time job is blocked (``preempt``-ed
     without a later ``resume``);
  2. **Algorithm 1/2 decision agreement** — the executor's recorded
     update sequence, replayed through a *fresh* ``Alg2State`` /
     ``pick_reserved``, reproduces every recorded rewrote-flag and
     running/pending/reserved snapshot;
  3. **simulator agreement** — the per-device sequence of admission
     decisions (Alg2 ``(which, job)`` updates under ioctl, reservation
     transitions under kthread) is identical between the live run and
     the simulator replay;
  4. **MORT ≤ WCRT** — measured response times (converted to ticks)
     stay below the bounds the admission analysis computed for the same
     platform (the cross-device fixed point on ``n_devices > 1`` busy
     platforms).

Scenario timings must be well separated (≥ 2 ticks between decision
points) so wall-clock jitter cannot reorder events; the stock scenarios
below obey this.
"""
from __future__ import annotations

import math
import time
from dataclasses import dataclass, field
from typing import Dict, List, Optional, Tuple

from repro.core import Alg2State, GpuSegment, Task, Taskset, pick_reserved
from repro.core.ioctl import IoctlPolicy
from repro.core.kthread import KernelThreadPolicy
from repro.core.simulator import Simulator
from repro.sched import ClusterExecutor, JobProfile, connect

# one tick = 25 ms of wall time on the executor, 1 ms in the simulator
TICK_S = 0.025


@dataclass(frozen=True)
class SegSpec:
    """One host segment followed by one device segment (the paper's
    alternating structure): ``host`` ticks of CPU work, then a bracketed
    device segment of ``programs`` dispatches (ticks each)."""
    host: float
    programs: Tuple[float, ...]


@dataclass(frozen=True)
class JobSpec:
    name: str
    priority: int
    segs: Tuple[SegSpec, ...]
    device: int = 0
    offset: float = 0.0          # release offset in ticks
    best_effort: bool = False
    tier: int = 0                # criticality tier (observability)

    @property
    def exec_ticks(self) -> float:
        return sum(s.host + sum(s.programs) for s in self.segs)


@dataclass
class ScenarioRun:
    specs: List[JobSpec]
    policy: str
    wait_mode: str
    n_devices: int
    cluster: ClusterExecutor
    jobs: Dict[str, object]
    wcrt_ticks: Dict[str, float] = field(default_factory=dict)


# --------------------------------------------------------------------------
# stock scenarios: contention on every device, cross-device independence
# --------------------------------------------------------------------------

def contention_scenario(n_devices: int) -> List[JobSpec]:
    """Per device: a best-effort streamer, a low- and a high-priority RT
    job whose releases overlap — exercising displacement (Alg2 pending),
    reservation handover (Alg1), and BE eviction.  Offsets/durations are
    device-staggered so no two decision points coincide."""
    specs: List[JobSpec] = []
    for d in range(n_devices):
        base = 3 * d                         # stagger devices
        specs.append(JobSpec(
            f"be{d}", priority=d, device=d, offset=base,
            best_effort=True,
            segs=(SegSpec(1, (2, 2, 2, 2, 2, 2, 2, 2)),)))
        specs.append(JobSpec(
            f"lo{d}", priority=10 + d, device=d, offset=base + 4,
            segs=(SegSpec(1, (3, 3, 3)),)))
        specs.append(JobSpec(
            f"hi{d}", priority=30 + d, device=d, offset=base + 8,
            segs=(SegSpec(1, (2, 2)),)))
    return specs


def fleet_scenario(n_devices: int = 2) -> List[JobSpec]:
    """A mixed-criticality model fleet under bursty arrivals: per
    device, two interactive "decode" RT models (tiers 2 and 1) whose
    releases land in a burst, over background best-effort "train"
    (tier 1) and "batch" (tier 0) models — the Sec. VII case study
    scaled to a zoo.  Decision points stay ≥ 2 ticks apart within each
    device (the harness's separation rule); devices are staggered."""
    specs: List[JobSpec] = []
    for d in range(n_devices):
        base = 3 * d
        # one background model per device (two concurrently draining
        # best-effort segments on one device would race their end
        # order against the simulator): train on even devices, batch
        # inference on odd — tiers 1 and 0 both live fleet-wide
        if d % 2 == 0:
            specs.append(JobSpec(
                f"train{d}", priority=5 + d, device=d, offset=base,
                best_effort=True, tier=1,
                segs=(SegSpec(1, (2, 2, 2, 2, 2, 2, 2, 2)),)))
        else:
            specs.append(JobSpec(
                f"batch{d}", priority=1 + d, device=d, offset=base,
                best_effort=True, tier=0,
                segs=(SegSpec(1, (3, 3, 3, 3, 3)),)))
        # the burst: both interactive models arrive 4 ticks apart; the
        # lower-priority one still holds a full program when the high
        # one drains, so the two RT ends stay well separated
        specs.append(JobSpec(
            f"chat{d}", priority=40 + d, device=d, offset=base + 8,
            tier=2, segs=(SegSpec(1, (2, 2)),)))
        specs.append(JobSpec(
            f"assist{d}", priority=20 + d, device=d, offset=base + 4,
            tier=1, segs=(SegSpec(1, (3, 3, 3)),)))
    return specs


def isolation_scenario() -> List[JobSpec]:
    """The acceptance pin: a high-priority job on device 0 against heavy
    traffic pinned to device 1 — the device-0 job must never wait."""
    return [
        JobSpec("hp0", priority=50, device=0, offset=6,
                segs=(SegSpec(1, (2, 2, 2)),)),
        JobSpec("heavy1a", priority=20, device=1, offset=0,
                segs=(SegSpec(1, (4, 4, 4, 4)),)),
        JobSpec("heavy1b", priority=30, device=1, offset=4,
                segs=(SegSpec(1, (4, 4, 4)),)),
        JobSpec("be1", priority=0, device=1, offset=2, best_effort=True,
                segs=(SegSpec(1, (3, 3, 3, 3, 3)),)),
    ]


# --------------------------------------------------------------------------
# executor side
# --------------------------------------------------------------------------

def _sleep_program(dur_s: float):
    def prog():
        time.sleep(dur_s)
        return None
    return prog


def _body(cluster: ClusterExecutor, spec: JobSpec):
    def body(job, it):
        for seg in spec.segs:
            if seg.host > 0:
                time.sleep(seg.host * TICK_S)
            with cluster.device_segment(job):
                for dur in seg.programs:
                    cluster.run(job, _sleep_program(dur * TICK_S))
    return body


def profile_of(spec: JobSpec, margin: float = 3.0,
               period_ticks: float = 10_000.0) -> JobProfile:
    """The admission profile of one spec: nominal tick durations as ms,
    inflated by ``margin`` (wall-clock sleeps overshoot, never undershoot
    by much, so the margin absorbs scheduler noise)."""
    return JobProfile(
        name=spec.name,
        host_segments_ms=[s.host * margin for s in spec.segs],
        device_segments_ms=[(0.0, sum(s.programs) * margin)
                            for s in spec.segs],
        period_ms=period_ticks, priority=spec.priority,
        cpu=0, best_effort=spec.best_effort, device=spec.device,
        tier=spec.tier)


def run_executor(specs: List[JobSpec], policy: str, wait_mode: str,
                 n_devices: int, margin: float = 3.0) -> ScenarioRun:
    """Admit every spec (cluster admission — the live crossfix path on
    busy multi-device platforms), run the scenario, return the run with
    traces and per-job WCRT bounds (ticks)."""
    names = [s.name for s in specs]
    assert len(set(names)) == len(names), "job names must be unique"
    cluster = ClusterExecutor(
        n_devices=n_devices, policy=policy, wait_mode=wait_mode,
        n_cpus=len(specs) + 1, epsilon_ms=0.5, trace=True,
        poll_interval=0.002)
    client = connect(cluster)   # the unified facade (DESIGN.md §9)
    jobs: Dict[str, object] = {}
    wcrt: Dict[str, float] = {}
    for i, s in enumerate(specs):
        prof = profile_of(s, margin)
        prof.cpu = i % cluster.admission.n_cpus
        res = client.submit(prof, body=_body(cluster, s))
        assert res["admitted"], (s.name, res)
        jobs[s.name] = res["job"]
        if not s.best_effort:
            wcrt[s.name] = res["wcrt"].get(s.name, math.inf)
    t0 = time.monotonic()
    for s in sorted(specs, key=lambda s: s.offset):
        delay = t0 + s.offset * TICK_S - time.monotonic()
        if delay > 0:
            time.sleep(delay)
        jobs[s.name].start(cluster)
    cluster.join(60)
    cluster.shutdown()
    return ScenarioRun(specs=list(specs), policy=policy,
                       wait_mode=wait_mode, n_devices=n_devices,
                       cluster=cluster, jobs=jobs, wcrt_ticks=wcrt)


# --------------------------------------------------------------------------
# invariant 1: priority-inversion-freedom from the trace
# --------------------------------------------------------------------------

def check_no_priority_inversion(run: ScenarioRun) -> int:
    """At every dispatch, no blocked (preempted, not yet resumed) RT job
    of higher device priority existed on that device.  Returns the number
    of dispatches checked."""
    checked = 0
    for ex in run.cluster.executors:
        dprio: Dict[str, int] = {}
        is_rt: Dict[str, bool] = {}
        blocked: Dict[str, bool] = {}
        for e in ex.trace.events:
            if e.event == "start":
                dprio[e.job] = e.info["device_priority"]
                is_rt[e.job] = e.info["rt"]
                blocked[e.job] = False
            elif e.event == "preempt":
                blocked[e.job] = True
            elif e.event in ("resume", "dispatch"):
                blocked[e.job] = False
                if e.event == "dispatch":
                    checked += 1
                    for k, b in blocked.items():
                        if not (b and is_rt[k]):
                            continue
                        if not is_rt[e.job] or dprio[k] > dprio[e.job]:
                            raise AssertionError(
                                f"priority inversion on device "
                                f"{e.device}: {e.job!r} dispatched while "
                                f"RT job {k!r} (prio {dprio[k]}) blocked")
            elif e.event == "complete":
                blocked.pop(e.job, None)
    return checked


# --------------------------------------------------------------------------
# invariant 2: Algorithm 1/2 decision agreement under local replay
# --------------------------------------------------------------------------

class _Stub:
    """Stand-in job for state-machine replay, rebuilt from trace data."""

    def __init__(self, name: str, dprio: int, rt: bool):
        self.name = name
        self.priority = dprio
        self.device_priority = dprio
        self.is_rt = rt
        self.gpu_pending = False

    def __repr__(self):
        return f"_Stub({self.name})"


def check_state_machine_replay(run: ScenarioRun) -> int:
    """Replay each device's recorded update sequence through a fresh
    instance of the canonical state machine (``Alg2State`` for ioctl,
    ``pick_reserved`` for kthread) and assert every recorded decision —
    the executor ran Algorithm 1/2 *exactly*.  Returns updates checked."""
    checked = 0
    for ex in run.cluster.executors:
        stubs: Dict[str, _Stub] = {}
        alg2 = Alg2State()
        for e in ex.trace.events:
            if e.event == "start":
                stubs[e.job] = _Stub(e.job, e.info["device_priority"],
                                     e.info["rt"])
            if e.event != "update":
                continue
            checked += 1
            if e.info["which"] == "poll":        # Algorithm 1
                cands = [_Stub(n, p, True)
                         for n, p in e.info["candidates"]]
                want = pick_reserved(cands)
                got = e.info["reserved"]
                assert (want.name if want else None) == got, (
                    f"Alg1 disagreement on device {ex.device_index}: "
                    f"pick_reserved -> {want}, executor reserved {got!r}")
            else:                                 # Algorithm 2
                stub = stubs[e.job]
                rewrote = (alg2.add(stub) if e.info["which"] == "begin"
                           else alg2.remove(stub))
                assert rewrote == e.info["rewrote"], (
                    f"Alg2 rewrote-flag disagreement at {e}")
                assert {j.name for j in alg2.running} == \
                    set(e.info["running"]), f"Alg2 running set at {e}"
                assert {j.name for j in alg2.pending} == \
                    set(e.info["pending"]), f"Alg2 pending set at {e}"
    return checked


# --------------------------------------------------------------------------
# invariant 3: simulator agreement on the decision sequence
# --------------------------------------------------------------------------

class RecordingIoctl(IoctlPolicy):
    """IoctlPolicy logging every Algorithm 2 update it performs."""

    def __init__(self, log: List[tuple], **kw):
        super().__init__(**kw)
        self._log = log

    def begin_update(self, job, piece) -> None:
        super().begin_update(job, piece)
        self._log.append((self.device, piece.which, job.task.name))


class RecordingKthread(KernelThreadPolicy):
    """KernelThreadPolicy logging every reservation transition."""

    def __init__(self, log: List[tuple], **kw):
        super().__init__(**kw)
        self._log = log
        self._last_logged: Optional[str] = None

    def _apply(self, tau_h) -> None:
        super()._apply(tau_h)
        name = tau_h.task.name if tau_h is not None else None
        if name != self._last_logged:
            self._last_logged = name
            self._log.append((self.device, "reserve", name))


def taskset_of(specs: List[JobSpec], n_devices: int,
               period_ticks: float = 10_000.0) -> Taskset:
    """The analysis/simulator Taskset of a scenario: tick durations as
    ms, one CPU per job (decisions must not depend on core contention —
    the executor gives every job its own thread), ε = 0 (the measured
    runlist update is microseconds ≈ 0 ticks)."""
    tasks = []
    for i, s in enumerate(specs):
        tasks.append(Task(
            name=s.name,
            cpu_segments=[seg.host for seg in s.segs],
            gpu_segments=[GpuSegment(0.0, sum(seg.programs))
                          for seg in s.segs],
            period=period_ticks, deadline=period_ticks,
            cpu=i, priority=s.priority, best_effort=s.best_effort,
            device=s.device))
    return Taskset(tasks, n_cpus=len(specs), epsilon=0.0,
                   kthread_cpu=len(specs), n_devices=n_devices)


def simulator_decision_log(specs: List[JobSpec], policy: str, mode: str,
                           n_devices: int) -> List[tuple]:
    """Replay the scenario timing through the simulator under recording
    policies; return the ordered decision log [(device, kind, name)]."""
    ts = taskset_of(specs, n_devices)
    log: List[tuple] = []
    if policy == "ioctl":
        policies = [RecordingIoctl(log) for _ in range(n_devices)]
    elif policy == "kthread":
        policies = [RecordingKthread(log) for _ in range(n_devices)]
        mode = "busy"
    else:
        raise ValueError(f"no recording policy for {policy!r}")
    horizon = max(s.offset + s.exec_ticks for s in specs) * 6 + 100
    Simulator(ts, policies, mode=mode, horizon=horizon,
              offsets={s.name: s.offset for s in specs}).run()
    return log


def executor_decision_log(run: ScenarioRun) -> List[tuple]:
    """The executor-side counterpart of :func:`simulator_decision_log`,
    extracted from the traces: per-device order is exact (every update
    is emitted under that device's runlist mutex)."""
    log: List[tuple] = []
    for ex in run.cluster.executors:
        for e in ex.trace.events:
            if e.event != "update":
                continue
            if e.info.get("which") == "poll":
                log.append((ex.device_index, "reserve",
                            e.info["reserved"]))
            else:
                log.append((ex.device_index, e.info["which"], e.job))
    return log


def _per_device(log: List[tuple], n_devices: int,
                drop_none: bool = False) -> Dict[int, List[tuple]]:
    out: Dict[int, List[tuple]] = {d: [] for d in range(n_devices)}
    for dev, kind, name in log:
        if drop_none and name is None:
            continue
        out[dev].append((kind, name))
    return out


def check_simulator_agreement(run: ScenarioRun) -> int:
    """Per device, the live decision sequence equals the simulator's.
    Reservation-cleared entries (name None) are dropped on both sides:
    the executor clears reservations silently on completion, the
    simulator via bookkeeping applies — the *who-got-the-device* order
    is the conformance claim.  Returns decisions compared."""
    sim = _per_device(
        simulator_decision_log(run.specs, run.policy, run.wait_mode,
                               run.n_devices),
        run.n_devices, drop_none=True)
    live = _per_device(executor_decision_log(run), run.n_devices,
                       drop_none=True)
    checked = 0
    for d in range(run.n_devices):
        assert live[d] == sim[d], (
            f"decision sequences diverge on device {d}:\n"
            f"  executor : {live[d]}\n  simulator: {sim[d]}")
        checked += len(live[d])
    return checked


# --------------------------------------------------------------------------
# invariant 4: measured MORT ≤ analysis WCRT
# --------------------------------------------------------------------------

def check_mort_vs_wcrt(run: ScenarioRun) -> int:
    """Every RT job's maximum observed response time (ticks) is bounded
    by the WCRT its admission computed.  Returns bounds checked."""
    checked = 0
    for s in run.specs:
        if s.best_effort:
            continue
        job = run.jobs[s.name]
        mort = job.stats.mort
        assert mort is not None, f"{s.name} never completed"
        bound = run.wcrt_ticks[s.name]
        assert math.isfinite(bound), f"{s.name}: admission gave inf WCRT"
        mort_ticks = mort / TICK_S
        assert mort_ticks <= bound + 1e-9, (
            f"{s.name}: MORT {mort_ticks:.2f} ticks > WCRT "
            f"{bound:.2f} ticks")
        checked += 1
    return checked


def check_all(run: ScenarioRun) -> Dict[str, int]:
    """Run every conformance invariant; returns counts per check."""
    run.cluster.assert_migration_free()
    return {
        "dispatches": check_no_priority_inversion(run),
        "replayed_updates": check_state_machine_replay(run),
        "agreed_decisions": check_simulator_agreement(run),
        "wcrt_bounds": check_mort_vs_wcrt(run),
    }
