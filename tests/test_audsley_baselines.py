"""GPU-segment priority assignment (Sec. V-C) and the MPCP/FMLP+ baseline
analyses (Sec. VII-A.1)."""
import math

import pytest

from repro.core import (GenParams, GpuSegment, Task, Taskset,
                        assign_gpu_priorities, fmlp_busy_rta,
                        fmlp_schedulable, generate_taskset,
                        ioctl_busy_rta, mpcp_busy_rta, mpcp_schedulable,
                        schedulable, schedulable_with_assignment, simulate)


def fig5b_taskset():
    """Sec. V-C/VI-B's motivating scenario: tau1 has high CPU priority and a
    long GPU kernel; tau2 has a tight deadline and a short kernel that gets
    stuck behind tau1's under default (CPU==GPU) priorities.  Swapping the
    *GPU segment* priorities rescues tau2 without hurting tau1's slack."""
    t1 = Task("tau1", [1.0], [GpuSegment(0.1, 20.0)], 60.0, 60.0, 0, 30)
    t2 = Task("tau2", [1.0], [GpuSegment(0.1, 1.0)], 30.0, 8.0, 1, 20)
    return Taskset([t1, t2], n_cpus=2, epsilon=0.1, kthread_cpu=2)


def test_audsley_rescues_unschedulable_taskset():
    ts = fig5b_taskset()
    assert not schedulable(ts, ioctl_busy_rta)
    assigned = assign_gpu_priorities(ts, ioctl_busy_rta)
    assert assigned is not None
    # tau2's GPU segment must have been raised above tau1's
    a = {t.name: t.gpu_priority for t in assigned.tasks}
    assert a["tau2"] > a["tau1"]
    assert schedulable_with_assignment(ts, ioctl_busy_rta)


def test_audsley_preserves_same_core_order():
    """Relative GPU priority order on one core must match CPU order."""
    for seed in range(30):
        ts = generate_taskset(seed, GenParams())
        assigned = assign_gpu_priorities(ts, ioctl_busy_rta)
        if assigned is None:
            continue
        by_cpu = {}
        for t in assigned.rt_tasks:
            if t.uses_gpu:
                by_cpu.setdefault(t.cpu, []).append(t)
        for tasks in by_cpu.values():
            tasks.sort(key=lambda t: t.priority)
            for a, b in zip(tasks, tasks[1:]):
                assert a.gpu_priority < b.gpu_priority


def test_audsley_assignment_is_simulated_safe():
    """The assigned priorities drive the ioctl runtime: still schedulable,
    and tau2's observed response stays within its (GPU-priority) bound."""
    ts = fig5b_taskset()
    assigned = assign_gpu_priorities(ts, ioctl_busy_rta)
    res = simulate(assigned, "ioctl", mode="busy", horizon=800.0)
    assert sum(res.deadline_misses.values()) == 0
    R = ioctl_busy_rta(assigned, use_gpu_prio=True)
    assert res.mort["tau2"] <= R["tau2"] + 1e-6


# ---------------------------------------------------------------------------
# baselines
# ---------------------------------------------------------------------------

def test_fmlp_fifo_blocking_grows_with_contenders():
    """FIFO blocking scales with the number of GPU users."""
    def make(n):
        tasks = [Task(f"g{k}", [1.0], [GpuSegment(0.1, 2.0)],
                      100.0 + k, 100.0 + k, k % 2, 50 - k)
                 for k in range(n)]
        return Taskset(tasks, n_cpus=2, epsilon=0.0, kthread_cpu=2)
    r2 = fmlp_busy_rta(make(2))
    r4 = fmlp_busy_rta(make(4))
    assert r4["g0"] > r2["g0"]


def test_mpcp_highest_priority_blocked_once():
    """Under MPCP the top task waits at most one lower-priority section."""
    t1 = Task("hi", [1.0], [GpuSegment(0.0, 1.0)], 50.0, 50.0, 0, 30)
    t2 = Task("lo", [1.0], [GpuSegment(0.0, 5.0)], 200.0, 200.0, 1, 10)
    ts = Taskset([t1, t2], n_cpus=2, epsilon=0.0, kthread_cpu=2)
    R = mpcp_busy_rta(ts)
    # C + G + one lower-priority gcs = 1 + 1 + 5 = 7
    assert R["hi"] == pytest.approx(7.0, abs=1e-9)


def test_baselines_bound_sync_simulation():
    """MPCP/FMLP+ analyses bound the corresponding lock-based schedules."""
    for seed in range(25):
        ts = generate_taskset(seed, GenParams(n_cpus=2, tasks_per_cpu=(2, 4),
                                              epsilon=0.0))
        horizon = 6 * max(t.period for t in ts.tasks)
        for rta, appr, mode in [(mpcp_busy_rta, "sync_priority", "busy"),
                                (mpcp_suspend_like, "sync_priority", "suspend"),
                                (fmlp_busy_rta, "sync_fifo", "busy")]:
            R = rta(ts)
            res = simulate(ts, appr, mode=mode, horizon=horizon)
            for t in ts.rt_tasks:
                b = R[t.name]
                if b is None or math.isinf(b):
                    continue
                assert res.mort[t.name] <= b + 1e-6, (
                    f"seed={seed} {rta.__name__} {t.name}: "
                    f"{res.mort[t.name]:.4f} > {b:.4f}")


def mpcp_suspend_like(ts):
    from repro.core import mpcp_suspend_rta
    return mpcp_suspend_rta(ts)


def test_schedulable_frontends():
    ts = generate_taskset(0, GenParams())
    assert isinstance(mpcp_schedulable(ts), bool)
    assert isinstance(fmlp_schedulable(ts), bool)
