"""Multi-GPU platform model (DESIGN.md §4): taskgen -> simulator ->
analysis for tasksets spanning >= 2 devices, in both wait modes (the
busy-wait bounds come from the cross-device fixed point,
core/crossfix.py; the larger randomized batch lives in
tests/test_cross_soundness.py)."""
import math

import pytest

from repro.core import (GenParams, GpuSegment, Task, Taskset,
                        assign_gpu_priorities, generate_taskset,
                        ioctl_busy_rta, ioctl_suspend_rta,
                        kthread_busy_rta, simulate)


def two_device_pair(n_devices=2):
    """Two GPU-heavy tasks on separate cores; same device -> they contend,
    separate devices -> they run concurrently."""
    t1 = Task("t1", [0.0], [GpuSegment(0.0, 2.0)], 50.0, 50.0, 0, 30,
              device=0)
    t2 = Task("t2", [0.0], [GpuSegment(0.0, 2.0)], 50.0, 50.0, 1, 20,
              device=1 if n_devices > 1 else 0)
    return Taskset([t1, t2], n_cpus=2, epsilon=0.0, n_devices=n_devices)


def test_devices_run_concurrently_unmanaged():
    # one device: the two kernels time-slice to a 4.0 makespan (seed test);
    # two devices: each kernel has its own GPU and finishes in 2.0
    single = simulate(two_device_pair(1), "unmanaged", mode="busy",
                      horizon=50.0)
    dual = simulate(two_device_pair(2), "unmanaged", mode="busy",
                    horizon=50.0)
    assert max(single.mort.values()) == pytest.approx(4.0, abs=1e-6)
    assert dual.mort["t1"] == pytest.approx(2.0, abs=1e-6)
    assert dual.mort["t2"] == pytest.approx(2.0, abs=1e-6)


@pytest.mark.parametrize("approach,mode", [
    ("unmanaged", "busy"), ("sync_priority", "suspend"),
    ("sync_fifo", "busy"), ("kthread", "busy"), ("ioctl", "busy"),
    ("ioctl", "suspend")])
def test_every_approach_runs_multi_device(approach, mode):
    p = GenParams(n_cpus=2, tasks_per_cpu=(2, 4), epsilon=0.5, n_devices=2)
    ts = generate_taskset(1, p)
    ts.kthread_cpu = ts.n_cpus
    assert len({t.device for t in ts.tasks if t.uses_gpu}) == 2
    horizon = 4 * max(t.period for t in ts.tasks)
    res = simulate(ts, approach, mode=mode, horizon=horizon)
    assert all(n > 0 for n in res.n_jobs.values())
    assert all(res.mort[t.name] > 0 for t in ts.tasks)


def test_taskgen_device_assignment_preserves_stream():
    """n_devices only adds the device labels: the taskset is otherwise
    byte-identical to the single-device generator (same rng stream)."""
    a = generate_taskset(3, GenParams(n_devices=1))
    b = generate_taskset(3, GenParams(n_devices=3))
    assert len(a.tasks) == len(b.tasks)
    for ta, tb in zip(a.tasks, b.tasks):
        assert ta.period == tb.period
        assert ta.cpu_segments == tb.cpu_segments
        assert len(ta.gpu_segments) == len(tb.gpu_segments)
        assert ta.priority == tb.priority
        assert ta.device == 0 or tb.device in range(3)
    gpu_devices = [t.device for t in b.tasks if t.uses_gpu]
    assert len(set(gpu_devices)) > 1  # round-robin actually spreads


@pytest.mark.parametrize("seed", range(8))
def test_multi_device_mort_bounded_suspend(seed):
    """taskgen -> simulator -> analysis on a 2-GPU platform: the per-device
    projection bounds hold under self-suspension (no busy-wait chains;
    the busy-mode caveat is documented in core.analysis)."""
    p = GenParams(n_cpus=2, tasks_per_cpu=(2, 4), epsilon=0.5, n_devices=2)
    ts = generate_taskset(seed, p)
    ts.kthread_cpu = ts.n_cpus
    horizon = 6 * max(t.period for t in ts.tasks)
    R = ioctl_suspend_rta(ts)
    res = simulate(ts, "ioctl", mode="suspend", horizon=horizon)
    checked = 0
    for t in ts.rt_tasks:
        bound = R[t.name]
        if bound is None or math.isinf(bound):
            continue
        checked += 1
        assert res.mort[t.name] <= bound + 1e-6, (
            f"{t.name}: MORT {res.mort[t.name]:.4f} > WCRT {bound:.4f}")
    assert checked > 0


@pytest.mark.parametrize("seed", range(6))
@pytest.mark.parametrize("approach,rta", [("kthread", kthread_busy_rta),
                                          ("ioctl", ioctl_busy_rta)],
                         ids=["kthread", "ioctl"])
def test_multi_device_mort_bounded_busy(seed, approach, rta):
    """Busy-wait companion of the suspend test above: the joint fixed
    point's bounds hold against the simulator on a 2-GPU platform."""
    p = GenParams(n_cpus=2, tasks_per_cpu=(2, 4), epsilon=0.5, n_devices=2)
    ts = generate_taskset(seed, p)
    ts.kthread_cpu = ts.n_cpus
    horizon = 6 * max(t.period for t in ts.tasks)
    R = rta(ts)
    res = simulate(ts, approach, mode="busy", horizon=horizon)
    checked = 0
    for t in ts.rt_tasks:
        bound = R[t.name]
        if bound is None or math.isinf(bound):
            continue
        checked += 1
        assert res.mort[t.name] <= bound + 1e-6, (
            f"{t.name}: MORT {res.mort[t.name]:.4f} > WCRT {bound:.4f}")
    assert checked > 0


@pytest.mark.parametrize("seed", range(4))
def test_audsley_preserves_n_devices(seed):
    """Regression: assign_gpu_priorities used to rebuild its working
    taskset without ``n_devices``, which made any multi-device call
    crash in Taskset validation ("device 1 out of range")."""
    p = GenParams(n_cpus=2, tasks_per_cpu=(2, 4), epsilon=0.5, n_devices=2)
    ts = generate_taskset(seed, p)
    ts.kthread_cpu = ts.n_cpus
    assigned = assign_gpu_priorities(ts, ioctl_busy_rta)
    if assigned is not None:
        assert assigned.n_devices == ts.n_devices
        assert {t.device for t in assigned.tasks} == \
            {t.device for t in ts.tasks}


def test_device_out_of_range_rejected():
    t = Task("x", [1.0], [GpuSegment(0.1, 1.0)], 10.0, 10.0, 0, 5, device=1)
    with pytest.raises(ValueError, match="device 1 out of range"):
        Taskset([t], n_cpus=1, n_devices=1)


def test_single_device_results_unchanged_by_field():
    """device=0 everywhere is the seed semantics: simulate agrees with the
    historical single-GPU behavior on a generated taskset."""
    p = GenParams(n_cpus=2, tasks_per_cpu=(2, 3), epsilon=0.5)
    ts = generate_taskset(5, p)
    assert ts.n_devices == 1
    assert all(t.device == 0 for t in ts.tasks)
    horizon = 4 * max(t.period for t in ts.tasks)
    res = simulate(ts, "ioctl", mode="busy", horizon=horizon)
    assert max(res.mort.values()) > 0
