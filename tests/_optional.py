"""Optional-dependency shims for the test suite.

``hypothesis`` is a test extra (``pip install .[test]``), not a runtime
dependency.  Importing ``given``/``settings``/``st`` from here keeps
modules collectable without it: property tests are skipped (not errored),
and every non-property test in the same module still runs.
"""
try:
    from hypothesis import given, settings  # noqa: F401
    from hypothesis import strategies as st  # noqa: F401
    HAVE_HYPOTHESIS = True
except ImportError:  # pragma: no cover - exercised only without the extra
    import pytest

    HAVE_HYPOTHESIS = False

    class _AnyStrategy:
        """Inert stand-in: any strategy constructor returns None."""

        def __getattr__(self, name):
            return lambda *a, **k: None

    st = _AnyStrategy()

    def given(*a, **k):
        return pytest.mark.skip(
            reason="hypothesis not installed (pip install .[test])")

    def settings(*a, **k):
        return lambda fn: fn
