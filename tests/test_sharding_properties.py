"""Property tests on the sharding rules (hypothesis): every generated
PartitionSpec must be valid for its tensor (rank, divisibility, no axis
reuse) on both production meshes and for every architecture/profile —
the invariant the dry-run depends on."""
import os

import jax
import pytest
from jax.sharding import PartitionSpec as P

from _optional import given, settings, st  # hypothesis or skip-shims

from repro.configs import SHAPES, get, names
from repro.models import transformer
from repro.parallel import sharding as shd


@pytest.fixture(scope="module")
def mesh():
    from repro.launch.mesh import make_test_mesh
    return make_test_mesh()  # (data=2, model=4) on 8 host devices? ->
    # single device fallback is fine: rules only need axis sizes


def _axes_of(spec):
    for entry in spec:
        if entry is None:
            continue
        for a in ((entry,) if isinstance(entry, str) else entry):
            yield a


def _check_specs(mesh, specs, pspecs):
    for leaf, ps in zip(jax.tree.leaves(specs),
                        jax.tree.leaves(pspecs,
                                        is_leaf=lambda x: isinstance(x, P))):
        assert len(ps) <= len(leaf.shape), (leaf.shape, ps)
        seen = []
        for dim, entry in zip(leaf.shape, tuple(ps)):
            if entry is None:
                continue
            size = 1
            for a in ((entry,) if isinstance(entry, str) else entry):
                assert a in mesh.axis_names, (a, ps)
                assert a not in seen, f"axis reused: {ps}"
                seen.append(a)
                size *= mesh.shape[a]
            assert dim % size == 0, (leaf.shape, ps)


@pytest.mark.parametrize("arch", names())
@pytest.mark.parametrize("profile", ["default", "fsdp_dp"])
def test_param_specs_valid_on_production_mesh(arch, profile):
    import dataclasses
    cfg = get(arch).config()
    if profile == "fsdp_dp":
        cfg = dataclasses.replace(cfg, sharding_profile="fsdp_dp",
                                  fsdp=False)

    class FakeMesh:  # axis sizes are all the rules consult
        axis_names = ("data", "model")
        shape = {"data": 16, "model": 16}

    specs = transformer.param_specs(cfg)
    pspecs = shd.param_pspecs(cfg, FakeMesh(), specs)
    _check_specs(FakeMesh(), specs, pspecs)
    # ZeRO-1 moments remain valid too
    z = shd.zero1_pspecs(FakeMesh(), specs, pspecs)
    _check_specs(FakeMesh(), specs, z)


@pytest.mark.parametrize("arch", names())
@pytest.mark.parametrize("shape_name", ["decode_32k", "long_500k"])
def test_cache_specs_valid(arch, shape_name):
    entry = get(arch)
    from repro.configs import applicable
    shape = SHAPES[shape_name]
    if not applicable(entry.sub_quadratic, shape):
        pytest.skip("shape not applicable")
    cfg = entry.config()

    class FakeMesh:
        axis_names = ("pod", "data", "model")
        shape = {"pod": 2, "data": 16, "model": 16}

    c_specs = jax.eval_shape(
        lambda: transformer.init_cache(cfg, shape.global_batch,
                                       shape.seq_len))
    pspecs = shd.cache_pspecs(cfg, FakeMesh(), c_specs, shape.global_batch)
    _check_specs(FakeMesh(), c_specs, pspecs)


@given(st.integers(1, 4096))
@settings(max_examples=50, deadline=None)
def test_batch_pspec_always_divides(b):
    class FakeMesh:
        axis_names = ("pod", "data", "model")
        shape = {"pod": 2, "data": 16, "model": 16}

    for profile in ("tp", "hybrid", "fsdp_dp"):
        spec = shd.batch_pspec(FakeMesh(), b, profile)
        size = 1
        for entry in spec:
            if entry is None:
                continue
            for a in ((entry,) if isinstance(entry, str) else entry):
                size *= FakeMesh.shape[a]
        assert b % size == 0, (b, profile, spec)


def test_dryrun_artifact_invariants():
    """The committed dry-run results: every non-skipped cell compiled OK
    and fits HBM; both meshes covered for every compiled arch x shape."""
    import json
    path = os.path.join(os.path.dirname(os.path.dirname(
        os.path.abspath(__file__))), "benchmarks", "results",
        "dryrun.json")
    if not os.path.exists(path):
        pytest.skip("dryrun.json not generated yet")
    with open(path) as f:
        data = json.load(f)
    assert len(data) == 80  # 10 archs x 4 shapes x 2 meshes
    for key, rec in data.items():
        if rec.get("skipped"):
            assert "long_500k" in key
            continue
        assert rec.get("ok"), f"{key}: {rec.get('error', '')[:100]}"
        assert rec["fits_hbm"], f"{key}: {rec['peak_hbm_bytes'] / 1e9:.1f}GB"
    compiled = {k.rsplit("|", 1)[0] for k, v in data.items()
                if v.get("ok")}
    for cell in compiled:  # every compiled cell passed on BOTH meshes
        assert data[f"{cell}|pod16x16"].get("ok")
        assert data[f"{cell}|pod2x16x16"].get("ok")
