"""Differential suite: sliced (resumable) kernel execution is value-
identical to the whole-grid kernels, for all four kernels, across slice
widths — interpret-mode Pallas on CPU.  Also pins the carry resume
contract: a snapshot taken mid-op and resumed (including through a
checkpoint save/restore roundtrip) reproduces the unsliced result."""
import jax
import jax.numpy as jnp
import numpy as np
import pytest

from repro.kernels import ops, ref
from repro.kernels.decode_attention import flash_decode, flash_decode_sliced
from repro.kernels.flash_attention import (flash_attention,
                                           flash_attention_sliced)
from repro.kernels.mamba_scan import mamba_scan_pallas, mamba_scan_sliced
from repro.kernels.rwkv6 import rwkv6_scan_pallas, rwkv6_scan_sliced
from repro.sched import latest_carry, save_carry


def _rand(key, shape):
    return jax.random.normal(key, shape, jnp.float32)


def _attn_inputs():
    ks = jax.random.split(jax.random.PRNGKey(0), 3)
    q = _rand(ks[0], (2, 128, 4, 32))
    k = _rand(ks[1], (2, 128, 2, 32))
    v = _rand(ks[2], (2, 128, 2, 32))
    return q, k, v


def _decode_inputs():
    ks = jax.random.split(jax.random.PRNGKey(1), 3)
    q = _rand(ks[0], (3, 4, 32))
    kc = _rand(ks[1], (3, 256, 2, 32))
    vc = _rand(ks[2], (3, 256, 2, 32))
    lens = jnp.array([10, 200, 256], jnp.int32)
    return q, kc, vc, lens


def _mamba_inputs():
    ks = jax.random.split(jax.random.PRNGKey(2), 6)
    bt, s, di, n = 2, 64, 32, 8
    x = _rand(ks[0], (bt, s, di))
    dt = jax.nn.softplus(_rand(ks[1], (bt, s, di)))
    A = -jnp.exp(_rand(ks[2], (di, n)) * 0.5)
    B = _rand(ks[3], (bt, s, n))
    C = _rand(ks[4], (bt, s, n))
    D = jnp.ones((di,), jnp.float32)
    h0 = _rand(ks[5], (bt, di, n))
    return x, dt, A, B, C, D, h0


def _rwkv_inputs():
    ks = jax.random.split(jax.random.PRNGKey(3), 5)
    b, s, h, d = 1, 64, 2, 16
    r = _rand(ks[0], (b, s, h, d))
    k = _rand(ks[1], (b, s, h, d)) * 0.3
    v = _rand(ks[2], (b, s, h, d))
    w = jax.nn.sigmoid(_rand(ks[3], (b, s, h, d)))
    u = _rand(ks[4], (h, d)) * 0.1
    return r, k, v, w, u


# ---------------------------------------------------------------------------
# sliced == unsliced (pinned numerical identity), multiple slice widths
# ---------------------------------------------------------------------------

@pytest.mark.parametrize("kv_slice", [1, 2, 3, 4])
@pytest.mark.parametrize("window", [None, 96])
def test_flash_attention_sliced_identity(kv_slice, window):
    q, k, v = _attn_inputs()
    want = flash_attention(q, k, v, causal=True, window=window,
                           block_q=64, block_k=32, interpret=True)
    op = flash_attention_sliced(q, k, v, causal=True, window=window,
                                block_q=64, block_k=32, kv_slice=kv_slice,
                                interpret=True)
    got = op.run()
    np.testing.assert_array_equal(np.asarray(got), np.asarray(want))
    # and both match the dense oracle
    np.testing.assert_allclose(
        np.asarray(got), np.asarray(ref.attention_dense(
            q, k, v, causal=True, window=window)), rtol=2e-5, atol=2e-5)


@pytest.mark.parametrize("kv_slice", [1, 3, 8])
def test_flash_decode_sliced_identity(kv_slice):
    q, kc, vc, lens = _decode_inputs()
    want = flash_decode(q, kc, vc, lens, block_k=32, interpret=True)
    op = flash_decode_sliced(q, kc, vc, lens, block_k=32,
                             kv_slice=kv_slice, interpret=True)
    got = op.run()
    np.testing.assert_array_equal(np.asarray(got), np.asarray(want))
    np.testing.assert_allclose(
        np.asarray(got), np.asarray(ref.decode_attention(q, kc, vc, lens)),
        rtol=2e-5, atol=2e-5)


@pytest.mark.parametrize("slice_chunks", [1, 2, 3, 8])
def test_mamba_sliced_identity(slice_chunks):
    x, dt, A, B, C, D, h0 = _mamba_inputs()
    want_y, want_h = mamba_scan_pallas(x, dt, A, B, C, D, h0=h0, chunk=8,
                                       block_d=32, interpret=True)
    op = mamba_scan_sliced(x, dt, A, B, C, D, h0=h0, chunk=8, block_d=32,
                           slice_chunks=slice_chunks, interpret=True)
    got_y, got_h = op.run()
    np.testing.assert_array_equal(np.asarray(got_y), np.asarray(want_y))
    np.testing.assert_array_equal(np.asarray(got_h), np.asarray(want_h))


@pytest.mark.parametrize("slice_chunks", [1, 2, 3, 8])
def test_rwkv6_sliced_identity(slice_chunks):
    r, k, v, w, u = _rwkv_inputs()
    want_o, want_s = rwkv6_scan_pallas(r, k, v, w, u, chunk=8,
                                       interpret=True)
    op = rwkv6_scan_sliced(r, k, v, w, u, chunk=8,
                           slice_chunks=slice_chunks, interpret=True)
    got_o, got_s = op.run()
    np.testing.assert_array_equal(np.asarray(got_o), np.asarray(want_o))
    np.testing.assert_array_equal(np.asarray(got_s), np.asarray(want_s))


# ---------------------------------------------------------------------------
# the carry resume contract (preemption / checkpoint mid-op)
# ---------------------------------------------------------------------------

def test_attention_carry_resume_after_snapshot(tmp_path):
    """Run half the slices, checkpoint the carry to disk, rebuild the op
    from scratch (as a restarted process would) and resume: identical to
    the uninterrupted run."""
    q, k, v = _attn_inputs()

    def make_op():
        return flash_attention_sliced(q, k, v, block_q=64, block_k=32,
                                      kv_slice=1, interpret=True)

    op = make_op()
    assert op.n_slices == 4
    carry = op.init()
    for i in range(2):
        carry = op.step(carry, i)
    save_carry(str(tmp_path), "attn", 2, carry)

    op2 = make_op()
    idx, restored = latest_carry(str(tmp_path), "attn", op2.init())
    assert idx == 2
    got = op2.run(carry=restored, start=idx)
    want = flash_attention(q, k, v, block_q=64, block_k=32, interpret=True)
    np.testing.assert_array_equal(np.asarray(got), np.asarray(want))


def test_rwkv6_carry_resume_mid_sequence():
    r, k, v, w, u = _rwkv_inputs()
    op = rwkv6_scan_sliced(r, k, v, w, u, chunk=8, slice_chunks=2,
                           interpret=True)
    carry = op.init()
    carry = op.step(carry, 0)   # first 2 time chunks
    got_o, got_s = op.run(carry=carry, start=1)
    want_o, want_s = rwkv6_scan_pallas(r, k, v, w, u, chunk=8,
                                       interpret=True)
    np.testing.assert_array_equal(np.asarray(got_o), np.asarray(want_o))
    np.testing.assert_array_equal(np.asarray(got_s), np.asarray(want_s))


# ---------------------------------------------------------------------------
# ops-layer dispatch: the sliced entry points on both backends
# ---------------------------------------------------------------------------

def test_ops_sliced_pallas_dispatch():
    q, k, v = _attn_inputs()
    ops.set_use_pallas(True, interpret=True)
    try:
        got = ops.attention_sliced(q, k, v, block_q=64, block_k=32,
                                   kv_slice=2).run()
    finally:
        ops.set_use_pallas(None)
    np.testing.assert_allclose(
        np.asarray(got), np.asarray(ref.attention_dense(q, k, v)),
        rtol=2e-5, atol=2e-5)


def test_ops_sliced_reference_path_recurrences():
    """With Pallas off, mamba/rwkv slicing runs the pure-jnp reference per
    window — identical to the whole-sequence reference."""
    x, dt, A, B, C, D, h0 = _mamba_inputs()
    ops.set_use_pallas(False)
    try:
        got_y, got_h = ops.mamba_scan_sliced(x, dt, A, B, C, D, h0=h0,
                                             chunk=8, slice_chunks=3).run()
        want_y, want_h = ref.mamba_scan(x, dt, A, B, C, D, h0=h0)
        np.testing.assert_allclose(np.asarray(got_y), np.asarray(want_y),
                                   rtol=1e-5, atol=1e-5)
        np.testing.assert_allclose(np.asarray(got_h), np.asarray(want_h),
                                   rtol=1e-5, atol=1e-5)

        r, k, v, w, u = _rwkv_inputs()
        got_o, got_s = ops.rwkv6_scan_sliced(r, k, v, w, u, chunk=8,
                                             slice_chunks=2).run()
        want_o, want_s = ref.rwkv6_scan(r, k, v, w, u)
        np.testing.assert_allclose(np.asarray(got_o), np.asarray(want_o),
                                   rtol=1e-5, atol=1e-5)
        np.testing.assert_allclose(np.asarray(got_s), np.asarray(want_s),
                                   rtol=1e-5, atol=1e-5)
    finally:
        ops.set_use_pallas(None)


def test_sliced_slice_count_contract():
    q, k, v = _attn_inputs()
    # 128 kv positions / block_k=32 -> 4 kv blocks
    for kv_slice, n in [(1, 4), (2, 2), (3, 2), (4, 1), (100, 1)]:
        op = flash_attention_sliced(q, k, v, block_q=64, block_k=32,
                                    kv_slice=kv_slice, interpret=True)
        assert op.n_slices == n, (kv_slice, op.n_slices)
