"""Per-architecture smoke tests (deliverable f): a REDUCED config of each
family runs one forward and one train step on CPU with shape and finiteness
asserts, plus a prefill+decode step (all archs are decoder-only)."""
import jax
import jax.numpy as jnp
import pytest

from repro.configs import get, names
from repro.models import (decode_step, forward, init_params, lm_loss,
                          param_count, prefill)

ARCHS = names()


def _batch_for(cfg, b=2, s=16):
    key = jax.random.PRNGKey(0)
    if cfg.input_mode == "embeddings":
        inputs = jax.random.normal(key, (b, s, cfg.d_model), jnp.float32)
    else:
        inputs = jax.random.randint(key, (b, s), 0, cfg.vocab_size)
    labels = jax.random.randint(jax.random.PRNGKey(1), (b, s), 0,
                                cfg.vocab_size)
    batch = {"inputs": inputs, "labels": labels}
    if any(sp.kind == "cross" for sp in cfg.pattern):
        batch["source"] = jax.random.normal(
            jax.random.PRNGKey(2), (b, cfg.cross_source_len, cfg.d_model),
            jnp.float32)
    return batch


@pytest.mark.parametrize("arch", ARCHS)
def test_forward_shapes_and_finite(arch):
    cfg = get(arch).reduced()
    params = init_params(cfg, jax.random.PRNGKey(0))
    batch = _batch_for(cfg)
    logits = forward(cfg, params, batch["inputs"],
                     source=batch.get("source"))
    b = 2
    assert logits.shape == (b, 16, cfg.vocab_size)
    assert bool(jnp.isfinite(logits.astype(jnp.float32)).all())


@pytest.mark.parametrize("arch", ARCHS)
def test_one_train_step(arch):
    cfg = get(arch).reduced()
    params = init_params(cfg, jax.random.PRNGKey(0))
    batch = _batch_for(cfg)

    @jax.jit
    def step(params, batch):
        loss, grads = jax.value_and_grad(
            lambda p: lm_loss(cfg, p, batch))(params)
        new = jax.tree.map(
            lambda p, g: (p - 1e-3 * g.astype(jnp.float32)).astype(p.dtype),
            params, grads)
        return loss, new

    loss, new_params = step(params, batch)
    assert bool(jnp.isfinite(loss)), f"{arch}: loss not finite"
    # parameters changed and stayed finite
    moved = jax.tree.map(lambda a, b: bool(jnp.any(a != b)), params,
                         new_params)
    assert any(jax.tree.leaves(moved)), f"{arch}: no parameter moved"
    for leaf in jax.tree.leaves(new_params):
        assert bool(jnp.isfinite(leaf.astype(jnp.float32)).all())


@pytest.mark.parametrize("arch", ARCHS)
def test_prefill_then_decode(arch):
    cfg = get(arch).reduced()
    params = init_params(cfg, jax.random.PRNGKey(0))
    batch = _batch_for(cfg, b=2, s=8)
    source = batch.get("source")
    last, cache, pos = prefill(cfg, params, batch["inputs"], max_len=16,
                               source=source)
    assert last.shape == (2, cfg.vocab_size)
    if cfg.input_mode == "embeddings":
        tok = jax.random.normal(jax.random.PRNGKey(5), (2, cfg.d_model),
                                jnp.float32)
    else:
        tok = jnp.array([1, 2], jnp.int32)
    logits, cache = decode_step(cfg, params, cache, tok, pos)
    assert logits.shape == (2, cfg.vocab_size)
    assert bool(jnp.isfinite(logits.astype(jnp.float32)).all())


def test_full_configs_match_published_sizes():
    expected = {  # billions, loose bands around the published counts
        "llama-3.2-vision-90b": (80, 95),
        "jamba-v0.1-52b": (45, 55),
        "smollm-135m": (0.12, 0.15),
        "olmo-1b": (1.0, 1.5),
        "minitron-8b": (7.0, 9.0),
        "internlm2-20b": (18, 22),
        "musicgen-medium": (1.2, 1.7),
        "dbrx-132b": (125, 138),
        "mixtral-8x22b": (135, 145),
        "rwkv6-1.6b": (1.2, 1.8),
    }
    for arch, (lo, hi) in expected.items():
        n = param_count(get(arch).config()) / 1e9
        assert lo <= n <= hi, f"{arch}: {n:.2f}B outside [{lo}, {hi}]"
