"""Serving engine as GPU-access segments: sliced decode is value-identical
to inline decode, engine state commits only at finalize (an abandoned
carry is harmless), and the segment dispatches through the preemptive
executor."""
import jax
import jax.numpy as jnp
import numpy as np
import pytest

from repro.configs import get
from repro.launch.serve import InferenceEngine
from repro.sched import DeviceExecutor, RTJob


@pytest.fixture(scope="module")
def engine():
    cfg = get("smollm-135m").reduced()
    eng = InferenceEngine(cfg, max_len=48)
    return eng


def _prefill(engine, seed=0):
    prompt = jax.random.randint(jax.random.PRNGKey(seed), (2, 8), 0,
                                engine.cfg.vocab_size)
    engine.prefill_batch(prompt)


@pytest.mark.parametrize("slice_tokens", [1, 2, 3])
def test_decode_segment_matches_inline_decode(engine, slice_tokens):
    n = 6
    _prefill(engine)
    want = engine.decode_chunk(n)          # inline (slice_tokens=1) path
    _prefill(engine)                       # reset engine state
    op = engine.decode_segment(n, slice_tokens=slice_tokens)
    assert op.n_slices == -(-n // slice_tokens)
    got = op.run()
    np.testing.assert_array_equal(np.asarray(got), np.asarray(want))


def test_decode_segment_commits_state_only_at_finalize(engine):
    _prefill(engine)
    pos_before = int(engine.pos if jnp.ndim(engine.pos) == 0
                     else engine.pos[0])
    op = engine.decode_segment(4)
    carry = op.init()
    carry = op.step(carry, 0)
    carry = op.step(carry, 1)
    # engine untouched while the carry is in flight (a preempted or
    # abandoned op must not corrupt the serving state)
    pos_mid = int(engine.pos if jnp.ndim(engine.pos) == 0
                  else engine.pos[0])
    assert pos_mid == pos_before
    for i in range(2, op.n_slices):
        carry = op.step(carry, i)
    toks = op.finalize(carry)
    pos_after = int(engine.pos if jnp.ndim(engine.pos) == 0
                    else engine.pos[0])
    assert pos_after == pos_before + 4
    assert toks.shape == (2, 4)


def test_decode_segment_under_executor(engine):
    _prefill(engine)
    want = engine.decode_chunk(5)
    _prefill(engine)
    ex = DeviceExecutor(policy="notify", wait_mode="suspend")
    got = []

    def body(job, it):
        with ex.device_segment(job):
            got.append(ex.run_sliced(job, engine.decode_segment(5)))

    job = RTJob("decode", body, period_s=10.0, priority=10)
    job.start(ex)
    job.join(60)
    ex.shutdown()
    assert got, "decode job did not complete"
    np.testing.assert_array_equal(np.asarray(got[0]), np.asarray(want))
    # one timing sample per token slice + one for finalize
    assert len(job.stats.slice_times) == 6
    assert job.stats.mort is not None
