"""Properties of the multi-tier degradation ladder (DESIGN.md §10/§12):
victim ordering, per-tier budget accounting, and the hysteresis
invariant that resuming a shed job can never re-arm the ladder that
shed it.  Property tests run under hypothesis when installed
(``pip install .[test]``); the seeded-random sweeps always run.
"""
import random

import pytest

from repro.sched.admission import JobProfile
from repro.sched.elastic import (ShedPolicy, can_resume, plan_shedding,
                                 profile_utilization, shed_order,
                                 tier_of, tier_utilization)

from _optional import HAVE_HYPOTHESIS, given, settings, st


def _prof(name: str, util: float, *, tier: int = 0,
          best_effort: bool = True, priority: int = 0,
          period_ms: float = 100.0) -> JobProfile:
    """A profile with exact device utilization ``util``."""
    return JobProfile(
        name=name, host_segments_ms=[0.1],
        device_segments_ms=[(0.0, util * period_ms)],
        period_ms=period_ms, priority=priority,
        best_effort=best_effort, tier=tier)


def _random_profs(rng: random.Random, n: int):
    return [
        _prof(f"p{i}", round(rng.uniform(0.02, 0.4), 3),
              tier=rng.randrange(3),
              best_effort=(rng.random() < 0.7),
              priority=rng.randrange(50))
        for i in range(n)
    ]


# --------------------------------------------------------------------------
# victim ordering
# --------------------------------------------------------------------------

def test_shed_order_pinned_tie_break_direction():
    """Lowest tier first; within a tier, largest utilization first —
    the ladder frees the most capacity from the least valuable work."""
    profs = [
        _prof("t2-big", 0.5, tier=2),
        _prof("t0-small", 0.1, tier=0),
        _prof("t0-big", 0.4, tier=0),
        _prof("t1-mid", 0.3, tier=1),
        _prof("rt", 0.9, tier=0, best_effort=False),  # never a victim
    ]
    assert [p.name for p in shed_order(profs)] == [
        "t0-big", "t0-small", "t1-mid", "t2-big"]


def test_shed_order_equal_tier_and_util_breaks_on_priority_then_name():
    profs = [
        _prof("b", 0.2, tier=1, priority=5),
        _prof("a", 0.2, tier=1, priority=5),
        _prof("c", 0.2, tier=1, priority=1),
    ]
    assert [p.name for p in shed_order(profs)] == ["c", "a", "b"]


def test_shed_order_excludes_rt_seeded():
    for seed in range(20):
        rng = random.Random(seed)
        profs = _random_profs(rng, rng.randrange(1, 12))
        order = shed_order(profs)
        assert all(p.best_effort for p in order)
        keys = [(tier_of(p), -profile_utilization(p), p.priority, p.name)
                for p in order]
        assert keys == sorted(keys)


# --------------------------------------------------------------------------
# per-tier budget accounting
# --------------------------------------------------------------------------

def _check_budget_accounting(profs, shed_at, budgets):
    victims = plan_shedding(profs, shed_at, tier_budgets=budgets)
    names = {p.name for p in victims}
    assert len(names) == len(victims)           # no double eviction
    assert all(p.best_effort for p in victims)  # RT is never shed
    survivors = [p for p in profs if p.name not in names]
    # every budgeted tier's surviving best-effort demand fits its
    # budget — unless the tier is empty of best-effort work entirely
    surv_be = tier_utilization(survivors)
    for t, budget in (budgets or {}).items():
        assert surv_be.get(t, 0.0) <= budget + 1e-9
    # the global ladder: survivors fit shed_at, or no best-effort work
    # is left to shed (RT alone exceeds the bound)
    total = sum(profile_utilization(p) for p in survivors)
    if total > shed_at + 1e-9:
        assert not [p for p in survivors if p.best_effort]
    return victims, survivors


def test_plan_shedding_budget_trims_even_when_device_fits():
    """The per-tier budget binds before the global threshold: a tier-0
    burst is trimmed to its budget while total utilization is still
    comfortably under shed_at."""
    profs = [
        _prof("bulk1", 0.2, tier=0),
        _prof("bulk2", 0.15, tier=0),
        _prof("bg", 0.1, tier=1),
    ]
    victims = plan_shedding(profs, shed_at=1.0,
                            tier_budgets={0: 0.2})
    # largest tier-0 victim first brings tier-0 from 0.35 to 0.15
    assert [p.name for p in victims] == ["bulk1"]
    # without budgets the device fits and nothing is shed
    assert plan_shedding(profs, shed_at=1.0) == []


def test_plan_shedding_budget_accounting_seeded():
    for seed in range(40):
        rng = random.Random(100 + seed)
        profs = _random_profs(rng, rng.randrange(1, 14))
        shed_at = rng.uniform(0.3, 1.5)
        budgets = ({t: rng.uniform(0.05, 0.6)
                    for t in range(3) if rng.random() < 0.5} or None)
        _check_budget_accounting(profs, shed_at, budgets)


@pytest.mark.skipif(not HAVE_HYPOTHESIS, reason="hypothesis extra")
@settings(max_examples=80, deadline=None)
@given(st.data())
def test_plan_shedding_budget_accounting_property(data):
    n = data.draw(st.integers(1, 14))
    profs = [
        _prof(f"p{i}",
              data.draw(st.floats(0.02, 0.5)),
              tier=data.draw(st.integers(0, 2)),
              best_effort=data.draw(st.booleans()),
              priority=data.draw(st.integers(0, 40)))
        for i in range(n)
    ]
    shed_at = data.draw(st.floats(0.2, 1.6))
    budgets = data.draw(st.one_of(
        st.none(),
        st.dictionaries(st.integers(0, 2), st.floats(0.05, 0.7),
                        max_size=3)))
    _check_budget_accounting(profs, shed_at, budgets or None)


# --------------------------------------------------------------------------
# hysteresis: resume never re-arms the ladder
# --------------------------------------------------------------------------

def test_shed_policy_validates_hysteresis_ordering():
    with pytest.raises(ValueError, match="resume_at < shed_at"):
        ShedPolicy(shed_at=0.8, resume_at=0.8)
    with pytest.raises(ValueError, match="resume_at < shed_at"):
        ShedPolicy(shed_at=0.5, resume_at=0.9)
    with pytest.raises(ValueError, match="budget"):
        ShedPolicy(shed_at=0.9, resume_at=0.7, tier_budgets={0: 0.0})
    pol = ShedPolicy(shed_at=0.9, resume_at=0.7,
                     tier_budgets={"1": "0.5"})
    assert pol.budget_for(1) == 0.5     # keys/values normalized
    assert pol.budget_for(0) is None


def test_resume_never_retriggers_shed_seeded():
    """The no-oscillation invariant across shed → resume → shed: any
    job that passes ``can_resume`` keeps the device at or under
    ``resume_at < shed_at``, so an immediately following shedding pass
    has nothing to do."""
    for seed in range(40):
        rng = random.Random(200 + seed)
        profs = _random_profs(rng, rng.randrange(2, 14))
        shed_at = rng.uniform(0.3, 1.2)
        resume_at = shed_at * rng.uniform(0.4, 0.95)
        budgets = ({t: rng.uniform(0.05, 0.6)
                    for t in range(3) if rng.random() < 0.5} or None)
        victims = plan_shedding(profs, shed_at, tier_budgets=budgets)
        names = {p.name for p in victims}
        live = [p for p in profs if p.name not in names]
        for cand in victims:
            if not can_resume(cand, live, resume_at,
                              tier_budgets=budgets):
                continue
            live = live + [cand]
            # the resumed state must not shed — not this job, not any
            assert plan_shedding(live, shed_at,
                                 tier_budgets=budgets) == []
            total = sum(profile_utilization(p) for p in live)
            assert total <= resume_at + 1e-9


def test_freshly_shed_global_victim_cannot_immediately_resume():
    """The last rung of the global ladder is always blocked from an
    immediate resume: its removal is what brought the device under
    ``shed_at``, so re-adding it lands above ``resume_at``."""
    for seed in range(30):
        rng = random.Random(300 + seed)
        profs = _random_profs(rng, rng.randrange(2, 12))
        shed_at = rng.uniform(0.3, 1.0)
        victims = plan_shedding(profs, shed_at)
        total = sum(profile_utilization(p) for p in profs)
        if not victims or total <= shed_at:
            continue
        names = {p.name for p in victims}
        live = [p for p in profs if p.name not in names]
        last = victims[-1]
        for resume_at in (0.9 * shed_at, 0.99 * shed_at):
            assert not can_resume(last, live, resume_at)


@pytest.mark.skipif(not HAVE_HYPOTHESIS, reason="hypothesis extra")
@settings(max_examples=80, deadline=None)
@given(st.data())
def test_resume_never_retriggers_shed_property(data):
    n = data.draw(st.integers(2, 12))
    profs = [
        _prof(f"p{i}", data.draw(st.floats(0.02, 0.5)),
              tier=data.draw(st.integers(0, 2)),
              best_effort=data.draw(st.booleans()))
        for i in range(n)
    ]
    shed_at = data.draw(st.floats(0.2, 1.4))
    resume_at = shed_at * data.draw(st.floats(0.3, 0.97))
    victims = plan_shedding(profs, shed_at)
    names = {p.name for p in victims}
    live = [p for p in profs if p.name not in names]
    for cand in victims:
        if can_resume(cand, live, resume_at):
            live = live + [cand]
            assert plan_shedding(live, shed_at) == []
