"""Validates EXPERIMENTS.md claims against the paper's (Sec. VII-A):
the proposed approaches dominate MPCP/FMLP+, the GPU-priority assignment
and improved analysis add schedulability, and FMLP+ is competitive at
light GPU load.  Small-n versions of the benchmark sweeps."""
import pytest

from benchmarks.prio_and_improved import (fig13_gpu_priority_gain,
                                          fig14_improved_analysis_gain)
from benchmarks.schedulability import acceptance
from repro.core import GenParams

N = 40


@pytest.fixture(scope="module")
def mid_band():
    return acceptance(GenParams(util_per_cpu=(0.30, 0.40)), N, seed0=7)


def test_ioctl_dominates_baselines(mid_band):
    r = mid_band
    ours = max(r["ioctl_busy"], r["ioctl_suspend"])
    baseline = max(r["mpcp"], r["fmlp+"])
    assert ours >= baseline + 0.2, r  # the paper's "up to 40%" gap


def test_suspend_at_least_busy(mid_band):
    # self-suspension frees the CPU during kernels; under CPU load it
    # should not lose to busy-waiting
    assert mid_band["ioctl_suspend"] >= mid_band["ioctl_busy"] - 0.05


def test_kthread_degrades_under_cpu_load():
    lo = acceptance(GenParams(util_per_cpu=(0.22, 0.28)), N, seed0=11)
    hi = acceptance(GenParams(util_per_cpu=(0.38, 0.44)), N, seed0=13)
    assert lo["kthread_busy"] > hi["kthread_busy"]
    # and kthread gives up more than ioctl does (Sec. VII-A.1 observation)
    assert (lo["kthread_busy"] - hi["kthread_busy"]) >= \
        (lo["ioctl_suspend"] - hi["ioctl_suspend"]) - 0.1


def test_best_effort_ratio_helps_ours_more():
    """Fig. 12: GPU preemption shields RT tasks from best-effort load."""
    none = acceptance(GenParams(util_per_cpu=(0.4, 0.5)), N, seed0=17)
    many = acceptance(GenParams(util_per_cpu=(0.4, 0.5),
                                best_effort_ratio=0.4), N, seed0=17)
    ours_gain = many["ioctl_busy"] - none["ioctl_busy"]
    base_gain = many["mpcp"] - none["mpcp"]
    assert ours_gain >= base_gain
    assert many["ioctl_busy"] >= many["mpcp"] + 0.2


def test_gpu_priority_assignment_never_hurts():
    rows = fig13_gpu_priority_gain(n=25)
    for r in rows:
        for m in ("kthread_busy", "ioctl_busy", "ioctl_suspend"):
            assert r[f"{m}+gpu_prio"] >= r[m] - 1e-9


def test_improved_analysis_gains_on_structured_tasksets():
    rows = fig14_improved_analysis_gain(n=25)
    gains = [r["ioctl_busy+improved"] - r["ioctl_busy"] for r in rows]
    assert max(gains) > 0.1  # Fig. 14: visible gain
    for r in rows:  # improvement is never negative
        assert r["ioctl_busy+improved"] >= r["ioctl_busy"] - 1e-9
        assert r["ioctl_suspend+improved"] >= r["ioctl_suspend"] - 1e-9
