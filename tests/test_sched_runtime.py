"""Runtime tests: preemptive priority executor (both modes), checkpointing,
fault tolerance, admission control."""
import os
import subprocess
import sys
import time

import jax.numpy as jnp
import numpy as np

from repro.sched import (AdmissionController, DeviceExecutor,
                         FaultTolerantLoop, JobProfile, RTJob, restore,
                         save)


def busy_program(duration_s):
    def prog():
        t0 = time.perf_counter()
        while time.perf_counter() - t0 < duration_s:
            pass
        return np.zeros(())
    return prog


def test_notify_mode_priority_preemption():
    """A high-priority job's device segment overtakes a best-effort job's
    remaining programs (preemption at program boundaries, Alg. 2)."""
    ex = DeviceExecutor(policy="ioctl", wait_mode="suspend")
    order = []

    def be_body(job, it):
        with ex.device_segment(job):
            for i in range(8):
                ex.run(job, busy_program(0.02))
                order.append(("be", i))

    def rt_body(job, it):
        time.sleep(0.05)  # release after BE has started
        with ex.device_segment(job):
            for i in range(3):
                ex.run(job, busy_program(0.02))
                order.append(("rt", i))

    be = RTJob("be", be_body, period_s=10.0, priority=0, best_effort=True)
    rt = RTJob("rt", rt_body, period_s=10.0, priority=50)
    be.start(ex)
    rt.start(ex)
    be.join(20)
    rt.join(20)
    ex.shutdown()
    # all rt programs complete before the final be program
    rt_last = max(i for i, e in enumerate(order) if e[0] == "rt")
    be_last = max(i for i, e in enumerate(order) if e[0] == "be")
    assert rt_last < be_last
    # and rt ran contiguously once admitted (no be interleave mid-segment)
    rt_idx = [i for i, e in enumerate(order) if e[0] == "rt"]
    assert rt_idx == list(range(rt_idx[0], rt_idx[0] + 3))


def test_notify_mode_two_rt_jobs_priority_order():
    ex = DeviceExecutor(policy="ioctl", wait_mode="suspend")
    done = []

    def body(tag, n):
        def b(job, it):
            with ex.device_segment(job):
                for _ in range(n):
                    ex.run(job, busy_program(0.01))
            done.append(tag)
        return b

    lo = RTJob("lo", body("lo", 10), period_s=10.0, priority=10)
    hi = RTJob("hi", body("hi", 2), period_s=10.0, priority=20)
    lo.start(ex)
    time.sleep(0.03)  # lo acquires the device first
    hi.start(ex)
    lo.join(20)
    hi.join(20)
    ex.shutdown()
    assert done == ["hi", "lo"]  # hi preempted lo and finished first


def test_poll_mode_job_granular_reservation():
    """Kernel-thread mode: reservation holds for the whole job; the
    lower-priority job makes no device progress while the high job runs."""
    ex = DeviceExecutor(policy="kthread", poll_interval=0.002)
    stamps = {"lo": [], "hi": []}

    def lo_body(job, it):
        for _ in range(6):
            ex.run(job, busy_program(0.02))
            stamps["lo"].append(time.monotonic())

    def hi_body(job, it):
        time.sleep(0.04)
        for _ in range(3):
            ex.run(job, busy_program(0.02))
            stamps["hi"].append(time.monotonic())

    lo = RTJob("lo2", lo_body, period_s=10.0, priority=10)
    hi = RTJob("hi2", hi_body, period_s=10.0, priority=20)
    lo.start(ex)
    hi.start(ex)
    lo.join(20)
    hi.join(20)
    ex.shutdown()
    hi_window = (min(stamps["hi"]), max(stamps["hi"]))
    # no lo completion strictly inside hi's active window (one may finish
    # right at the boundary due to program-granular preemption)
    inside = [t for t in stamps["lo"]
              if hi_window[0] + 0.025 < t < hi_window[1] - 0.025]
    assert len(inside) == 0, f"lo progressed during hi reservation: {inside}"


def test_epsilon_measured():
    ex = DeviceExecutor(policy="ioctl")
    j = RTJob("x", lambda job, it: None, period_s=1.0, priority=5)
    with ex._mutex:
        ex._ioctl_add(j)
        ex._ioctl_remove(j)
    assert len(ex.update_times) == 2
    assert all(t < 0.01 for t in ex.update_times)
    ex.shutdown()


# ---------------------------------------------------------------------------
# checkpointing / fault tolerance
# ---------------------------------------------------------------------------

def test_checkpoint_roundtrip_bf16(tmp_path):
    tree = {"w": jnp.arange(12, dtype=jnp.bfloat16).reshape(3, 4) / 3,
            "step": jnp.zeros((), jnp.int32),
            "m": {"v": jnp.ones((2, 2), jnp.float32) * 0.5}}
    save(str(tmp_path), 7, tree)
    back = restore(str(tmp_path), tree)
    for a, b in zip(__import__("jax").tree.leaves(tree),
                    __import__("jax").tree.leaves(back)):
        np.testing.assert_array_equal(np.asarray(a, np.float32),
                                      np.asarray(b, np.float32))


def test_fault_loop_restart(tmp_path):
    state = {"x": jnp.zeros((4,), jnp.float32)}
    loop = FaultTolerantLoop(str(tmp_path), state, save_every=2)
    calls = {"n": 0}

    def step(state, inc):
        calls["n"] += 1
        if calls["n"] == 4:
            raise RuntimeError("boom")
        return {"x": state["x"] + inc}, {"sum": float(state["x"].sum())}

    for _ in range(6):
        loop.run_step(step, 1.0)
    assert loop.stats.restarts == 1
    assert loop.stats.replayed_steps == 1  # step 3 rolled back and redone
    # state and step counter stay consistent after rollback: 6 calls, one
    # of which rolled back to the step-2 checkpoint and re-ran -> step 5
    assert loop.step == 5
    np.testing.assert_allclose(np.asarray(loop.state["x"]), 5.0)
    loop.run_step(step, 1.0)
    assert loop.step == 6
    np.testing.assert_allclose(np.asarray(loop.state["x"]), 6.0)


def test_elastic_rescale_subprocess():
    """Save on a (2,2) mesh, restore re-sharded on a (2,4) mesh — run in a
    subprocess so the 8-device host platform doesn't leak into this
    process."""
    code = r"""
import os
os.environ["XLA_FLAGS"] = "--xla_force_host_platform_device_count=8"
import jax, jax.numpy as jnp, numpy as np
from jax.sharding import NamedSharding, PartitionSpec as P
from repro.sched import save, restore
import tempfile

d = tempfile.mkdtemp()
mesh_a = jax.make_mesh((2, 2), ("data", "model"))
x = jax.device_put(jnp.arange(64, dtype=jnp.float32).reshape(8, 8),
                   NamedSharding(mesh_a, P("data", "model")))
save(d, 1, {"x": x})
mesh_b = jax.make_mesh((2, 4), ("data", "model"))
sh = {"x": NamedSharding(mesh_b, P("data", "model"))}
back = restore(d, {"x": x}, shardings=sh)
assert back["x"].sharding.num_devices == 8  # placed on the new mesh
assert len(back["x"].sharding.device_set) == 8
np.testing.assert_array_equal(np.asarray(back["x"]), np.asarray(x))
print("ELASTIC_OK")
"""
    env = dict(os.environ, PYTHONPATH="src")
    out = subprocess.run([sys.executable, "-c", code], env=env,
                         capture_output=True, text=True, timeout=300,
                         cwd=os.path.dirname(os.path.dirname(
                             os.path.abspath(__file__))))
    assert "ELASTIC_OK" in out.stdout, out.stderr[-2000:]


# ---------------------------------------------------------------------------
# admission control
# ---------------------------------------------------------------------------

def test_admission_controller_accepts_then_rejects():
    ac = AdmissionController(policy="ioctl", wait_mode="suspend",
                             n_cpus=2, epsilon_ms=0.5)
    light = JobProfile("infer", host_segments_ms=[1, 1],
                       device_segments_ms=[(0.5, 5.0)], period_ms=50,
                       priority=20, cpu=0)
    r1 = ac.try_admit(light)
    assert r1["admitted"] and r1["via"] == "default"
    heavy = JobProfile("train", host_segments_ms=[5, 5],
                       device_segments_ms=[(2.0, 200.0)], period_ms=100,
                       priority=10, cpu=1)
    r2 = ac.try_admit(heavy)
    assert not r2["admitted"]  # would blow its own deadline
    be = JobProfile("batch", host_segments_ms=[5],
                    device_segments_ms=[(2.0, 200.0)], period_ms=100,
                    priority=0, cpu=1, best_effort=True)
    r3 = ac.try_admit(be)
    assert r3["admitted"] and r3["via"] == "best_effort"


def test_admission_controller_multi_device_busy_and_bad_device():
    ac = AdmissionController(policy="ioctl", wait_mode="busy",
                             n_cpus=2, epsilon_ms=0.5, n_devices=2)
    a = JobProfile("a", host_segments_ms=[1.0],
                   device_segments_ms=[(0.5, 4.0)], period_ms=50,
                   priority=20, cpu=0, device=0)
    b = JobProfile("b", host_segments_ms=[1.0],
                   device_segments_ms=[(0.5, 4.0)], period_ms=50,
                   priority=19, cpu=1, device=1)
    assert ac.try_admit(a)["admitted"]
    assert ac.try_admit(b)["admitted"]
    # out-of-range device is refused, not a crash, and is not appended
    bad = JobProfile("bad", host_segments_ms=[1.0],
                     device_segments_ms=[(0.5, 4.0)], period_ms=50,
                     priority=18, cpu=0, device=2)
    r = ac.try_admit(bad)
    assert not r["admitted"] and "out of range" in r["error"]
    assert len(ac.admitted) == 2
