"""Unit tests for the response-time analyses (Lemmas 1-7) on hand-solvable
tasksets, plus structural properties (monotonicity, improved <= baseline)
and the cross-device busy-wait fixed point (core/crossfix.py): golden
acceptance vectors, convergence/divergence reporting, and the
SoundnessWarning contract of the heuristic escape hatch."""
import math
import warnings

import pytest

from repro.core import (GenParams, GpuSegment, SoundnessWarning, Task,
                        Taskset, bx_cpu_segment, bx_gpu_segment,
                        cross_fixed_point, generate_taskset,
                        ioctl_busy_improved_rta, ioctl_busy_rta,
                        ioctl_suspend_improved_rta, ioctl_suspend_rta,
                        kthread_busy_rta, kthread_K, overlap_cg, overlap_gc,
                        schedulable)
from repro.core.crossfix import MAX_OUTER


def two_task_set(eps=0.5):
    th = Task("hi", [1.0], [GpuSegment(0.5, 2.0)], 20.0, 20.0, 0, 20)
    tl = Task("lo", [2.0, 1.0], [GpuSegment(0.5, 3.0)], 60.0, 60.0, 0, 10)
    return Taskset([th, tl], n_cpus=1, epsilon=eps, kthread_cpu=1)


def test_kthread_rta_hand_computed():
    ts = two_task_set(eps=0.5)
    R = kthread_busy_rta(ts)
    # hi: no higher-priority tasks; K = 2*eps (own update pair)
    # R = C + G + K = 1 + 2.5 + 1.0 = 4.5
    assert R["hi"] == pytest.approx(4.5, abs=1e-9)
    # lo: C=3, G=3.5; K = 2e + ceil((R+Jh)/20)*2e, Jh = 4.5-3.5 = 1.0
    # hpp interference: ceil(R/20)*(C_h+G_h) = ceil(R/20)*3.5
    # fixed point: R = 6.5 + (1+1) + 3.5 = 12.0
    assert R["lo"] == pytest.approx(12.0, abs=1e-9)


def test_ioctl_busy_rta_hand_computed():
    ts = two_task_set(eps=0.5)
    R = ioctl_busy_rta(ts)
    # hi: C + G* + (eta+1)eps = 1 + (2.5+1.0) + 2*0.5 = 5.5
    assert R["hi"] == pytest.approx(5.5, abs=1e-9)
    # lo: C + G* + 2eps + ceil(R/20)*(C_h+G_h^*+stretch)
    #   = 3 + 4.5 + 1.0 + 1*(1+3.5+1.0) = 14.0 (corrected)
    assert R["lo"] == pytest.approx(14.0, abs=1e-9)
    # verbatim (no busy-stretch): 13.0
    Rv = ioctl_busy_rta(ts, corrected=False)
    assert Rv["lo"] == pytest.approx(13.0, abs=1e-9)


def test_ioctl_suspend_rta_hand_computed():
    ts = two_task_set(eps=0.5)
    R = ioctl_suspend_rta(ts)
    assert R["hi"] == pytest.approx(5.5, abs=1e-9)
    # lo: 3 + 4.5 + 1.0
    #   + ceil((R+J_h^c)/20)*(C_h + G_h^{m*}) ; J_h^c = 5.5-1.5 = 4.0
    #   + ceil((R+J_h^g)/20)*G_h^e           ; J_h^g = 5.5-2.0 = 3.5
    # R = 8.5 + 1*(1+1.5) + 1*2.0 = 13.0
    assert R["lo"] == pytest.approx(13.0, abs=1e-9)


def test_epsilon_monotonicity():
    for eps in (0.1, 0.5, 1.0):
        a = ioctl_busy_rta(two_task_set(eps=eps))
        b = ioctl_busy_rta(two_task_set(eps=eps + 0.1))
        for k in a:
            assert a[k] <= b[k] + 1e-12


@pytest.mark.parametrize("seed", range(30))
def test_improved_never_worse_than_baseline(seed):
    ts = generate_taskset(seed, GenParams())
    base_b = ioctl_busy_rta(ts)
    imp_b = ioctl_busy_improved_rta(ts)
    base_s = ioctl_suspend_rta(ts)
    imp_s = ioctl_suspend_improved_rta(ts)
    for t in ts.rt_tasks:
        assert imp_b[t.name] <= base_b[t.name] + 1e-9
        assert imp_s[t.name] <= base_s[t.name] + 1e-9


def test_overlap_terms_positive_when_periods_allow():
    """A long pure-GPU segment of the low-priority task fully contains
    several short high-priority CPU jobs: O^cg must be positive."""
    th = Task("hi", [0.5], [], 2.0, 2.0, 0, 20)
    tl = Task("lo", [1.0], [GpuSegment(0.0, 10.0)], 50.0, 50.0, 0, 10)
    ts = Taskset([th, tl], n_cpus=1, epsilon=0.1, kthread_cpu=1)
    bx = bx_gpu_segment(ts, tl, 0)
    assert bx == pytest.approx(10.0, abs=1e-9)  # hi has no GPU work
    # floor(10/2)-1 = 4 fully-contained hi jobs, each C=0.5
    assert overlap_cg(ts, tl, th) == pytest.approx(2.0, abs=1e-9)
    # and the improved analysis is strictly tighter for lo
    base = ioctl_busy_rta(ts)
    imp = ioctl_busy_improved_rta(ts)
    assert imp["lo"] < base["lo"] - 1.0


def test_overlap_gc_symmetric():
    th = Task("hi", [0.1], [GpuSegment(0.0, 0.4)], 2.0, 2.0, 0, 20)
    tl = Task("lo", [10.0], [GpuSegment(0.0, 1.0)], 50.0, 50.0, 1, 10)
    ts = Taskset([th, tl], n_cpus=2, epsilon=0.1, kthread_cpu=2)
    bx = bx_cpu_segment(ts, tl, 0)
    assert bx == pytest.approx(10.0, abs=1e-9)  # hi is on another core
    # floor(10/2)-1 = 4 contained hi jobs, each Ge=0.4
    assert overlap_gc(ts, tl, th) == pytest.approx(1.6, abs=1e-9)


def test_kthread_K_cpu_only_remote_core_is_zero_verbatim():
    t_gpu = Task("g", [1.0], [GpuSegment(0.1, 1.0)], 10.0, 10.0, 0, 20)
    t_cpu = Task("c", [1.0], [], 10.0, 10.0, 1, 10)
    ts = Taskset([t_gpu, t_cpu], n_cpus=2, epsilon=0.5, kthread_cpu=2)
    R = {}
    assert kthread_K(ts, t_cpu, 5.0, R, corrected=False) == 0.0
    # corrected: still 0 — no same-core GPU-using higher-priority task
    assert kthread_K(ts, t_cpu, 5.0, R, corrected=True) == 0.0
    # but a same-core GPU-using HP task flips x_i on
    t_cpu2 = Task("c2", [1.0], [], 10.0, 10.0, 0, 10)
    ts2 = Taskset([t_gpu, t_cpu2], n_cpus=1, epsilon=0.5, kthread_cpu=2)
    assert kthread_K(ts2, t_cpu2, 5.0, R, corrected=True) > 0.0


def test_unschedulable_detection():
    t1 = Task("a", [8.0], [GpuSegment(0.0, 8.0)], 10.0, 10.0, 0, 20)
    t2 = Task("b", [8.0], [], 10.0, 10.0, 0, 10)
    ts = Taskset([t1, t2], n_cpus=1, epsilon=0.5, kthread_cpu=1)
    R = ioctl_busy_rta(ts)
    assert math.isinf(R["b"])
    assert not schedulable(ts, ioctl_busy_rta)


# --------------------------------------------------------------------------
# cross-device busy-wait fixed point (core/crossfix.py)
# --------------------------------------------------------------------------

_GOLDEN_PARAMS = dict(n_cpus=2, tasks_per_cpu=(3, 5), epsilon=1.0,
                      util_per_cpu=(0.5, 0.65))

# Pinned acceptance vectors of the joint fixed point over seeds 0..15
# (generate_taskset with _GOLDEN_PARAMS, kthread_cpu = n_cpus).  These
# lock the analysis: any change to the occupancy model, the seed, or the
# iteration moves at least one bit here.
_GOLDEN_ACCEPT = {
    (2, "kthread"): [1, 0, 1, 0, 0, 1, 0, 0, 0, 0, 1, 0, 0, 0, 0, 1],
    (2, "ioctl"):   [1, 0, 1, 1, 0, 1, 1, 1, 0, 0, 1, 1, 1, 0, 1, 1],
    (4, "kthread"): [1, 0, 1, 1, 0, 1, 0, 0, 0, 0, 1, 0, 0, 0, 0, 1],
    (4, "ioctl"):   [1, 1, 1, 1, 0, 1, 1, 1, 1, 1, 1, 1, 1, 0, 1, 1],
}


def _golden_ts(seed, n_devices):
    ts = generate_taskset(seed, GenParams(n_devices=n_devices,
                                          **_GOLDEN_PARAMS))
    ts.kthread_cpu = ts.n_cpus
    return ts


@pytest.mark.parametrize("n_devices", [2, 4])
@pytest.mark.parametrize("approach,rta", [("kthread", kthread_busy_rta),
                                          ("ioctl", ioctl_busy_rta)],
                         ids=["kthread", "ioctl"])
def test_fixed_point_acceptance_golden_vectors(n_devices, approach, rta):
    got = [int(schedulable(_golden_ts(s, n_devices), rta))
           for s in range(16)]
    assert got == _GOLDEN_ACCEPT[(n_devices, approach)]


@pytest.mark.parametrize("seed", range(8))
def test_fixed_point_at_least_as_pessimistic_as_heuristic(seed):
    """The iterate only ever adds same-device contention on top of the
    heuristic's uncontended folded charge, so every joint bound is >= the
    heuristic bound (and the fixed point accepts a subset)."""
    ts = _golden_ts(seed, 2)
    Rf = ioctl_busy_rta(ts)
    with warnings.catch_warnings():
        warnings.simplefilter("ignore", SoundnessWarning)
        Rh = ioctl_busy_rta(ts, method="heuristic")
    for t in ts.rt_tasks:
        if Rh[t.name] is None:
            continue
        assert Rf[t.name] >= Rh[t.name] - 1e-9


def test_fixed_point_converges_on_feasible_set():
    ts = _golden_ts(0, 2)  # accepted by both approaches (golden vector)
    R, info = cross_fixed_point(ts, ioctl_busy_rta.__wrapped__, "ioctl")
    assert info["converged"] and not info["diverged"]
    assert 1 <= info["iterations"] <= MAX_OUTER
    assert all(R[t.name] is not None and not math.isinf(R[t.name])
               for t in ts.rt_tasks)


def test_fixed_point_terminates_and_reports_overload():
    """On an overloaded set the iteration must not spin: it either
    converges with inf entries or reports divergence — never a silent
    finite bound for a task past its deadline."""
    p = GenParams(n_cpus=2, tasks_per_cpu=(3, 5), epsilon=1.0,
                  util_per_cpu=(0.9, 0.95), n_devices=2)
    ts = generate_taskset(1, p)
    ts.kthread_cpu = ts.n_cpus
    R, info = cross_fixed_point(ts, ioctl_busy_rta.__wrapped__, "ioctl")
    assert info["iterations"] <= MAX_OUTER
    assert info["converged"] or info["diverged"]
    assert any(R[t.name] is not None and math.isinf(R[t.name])
               for t in ts.rt_tasks)
    assert not schedulable(ts, ioctl_busy_rta)


def test_fixed_point_early_exit_returns_partial_dict():
    """With early_exit the outer loop stops at the first diverged task;
    mirroring _rta_loop, still-iterating finite bounds are dropped (they
    are not fixed points, hence not upper bounds) and absent keys read
    as unschedulable everywhere."""
    p = GenParams(n_cpus=2, tasks_per_cpu=(3, 5), epsilon=1.0,
                  util_per_cpu=(0.9, 0.95), n_devices=2)
    ts = generate_taskset(1, p)
    ts.kthread_cpu = ts.n_cpus
    R, info = cross_fixed_point(ts, ioctl_busy_rta.__wrapped__, "ioctl",
                                early_exit=True)
    assert info["unschedulable"]
    for t in ts.rt_tasks:
        if t.name in R:
            assert math.isinf(R[t.name])  # no mid-iteration finite bounds
    assert not schedulable(ts, ioctl_busy_rta)


def test_heuristic_escape_hatch_warns_fixed_point_does_not():
    ts = _golden_ts(0, 2)
    with pytest.warns(SoundnessWarning):
        ioctl_busy_rta(ts, method="heuristic")
    with warnings.catch_warnings():
        warnings.simplefilter("error", SoundnessWarning)
        ioctl_busy_rta(ts)  # default path must stay silent
        kthread_busy_rta(ts)
    with pytest.raises(ValueError, match="unknown multi-device method"):
        ioctl_busy_rta(ts, method="bogus")
    # validated on single-device tasksets too, so typos can't hide until
    # the code first meets a multi-GPU platform
    single = generate_taskset(3, GenParams(n_cpus=2, tasks_per_cpu=(2, 4)))
    with pytest.raises(ValueError, match="unknown multi-device method"):
        ioctl_busy_rta(single, method="fixed-point")


def test_single_device_ignores_method_and_matches_seed_semantics():
    ts = generate_taskset(3, GenParams(n_cpus=2, tasks_per_cpu=(2, 4),
                                       epsilon=0.5))
    assert ioctl_busy_rta(ts) == ioctl_busy_rta(ts, method="heuristic")
