"""Unit tests for the response-time analyses (Lemmas 1-7) on hand-solvable
tasksets, plus structural properties (monotonicity, improved <= baseline)."""
import math

import pytest

from repro.core import (GenParams, GpuSegment, Task, Taskset, bx_cpu_segment,
                        bx_gpu_segment, generate_taskset,
                        ioctl_busy_improved_rta, ioctl_busy_rta,
                        ioctl_suspend_improved_rta, ioctl_suspend_rta,
                        kthread_busy_rta, kthread_K, overlap_cg, overlap_gc,
                        schedulable)


def two_task_set(eps=0.5):
    th = Task("hi", [1.0], [GpuSegment(0.5, 2.0)], 20.0, 20.0, 0, 20)
    tl = Task("lo", [2.0, 1.0], [GpuSegment(0.5, 3.0)], 60.0, 60.0, 0, 10)
    return Taskset([th, tl], n_cpus=1, epsilon=eps, kthread_cpu=1)


def test_kthread_rta_hand_computed():
    ts = two_task_set(eps=0.5)
    R = kthread_busy_rta(ts)
    # hi: no higher-priority tasks; K = 2*eps (own update pair)
    # R = C + G + K = 1 + 2.5 + 1.0 = 4.5
    assert R["hi"] == pytest.approx(4.5, abs=1e-9)
    # lo: C=3, G=3.5; K = 2e + ceil((R+Jh)/20)*2e, Jh = 4.5-3.5 = 1.0
    # hpp interference: ceil(R/20)*(C_h+G_h) = ceil(R/20)*3.5
    # fixed point: R = 6.5 + (1+1) + 3.5 = 12.0
    assert R["lo"] == pytest.approx(12.0, abs=1e-9)


def test_ioctl_busy_rta_hand_computed():
    ts = two_task_set(eps=0.5)
    R = ioctl_busy_rta(ts)
    # hi: C + G* + (eta+1)eps = 1 + (2.5+1.0) + 2*0.5 = 5.5
    assert R["hi"] == pytest.approx(5.5, abs=1e-9)
    # lo: C + G* + 2eps + ceil(R/20)*(C_h+G_h^*+stretch)
    #   = 3 + 4.5 + 1.0 + 1*(1+3.5+1.0) = 14.0 (corrected)
    assert R["lo"] == pytest.approx(14.0, abs=1e-9)
    # verbatim (no busy-stretch): 13.0
    Rv = ioctl_busy_rta(ts, corrected=False)
    assert Rv["lo"] == pytest.approx(13.0, abs=1e-9)


def test_ioctl_suspend_rta_hand_computed():
    ts = two_task_set(eps=0.5)
    R = ioctl_suspend_rta(ts)
    assert R["hi"] == pytest.approx(5.5, abs=1e-9)
    # lo: 3 + 4.5 + 1.0
    #   + ceil((R+J_h^c)/20)*(C_h + G_h^{m*}) ; J_h^c = 5.5-1.5 = 4.0
    #   + ceil((R+J_h^g)/20)*G_h^e           ; J_h^g = 5.5-2.0 = 3.5
    # R = 8.5 + 1*(1+1.5) + 1*2.0 = 13.0
    assert R["lo"] == pytest.approx(13.0, abs=1e-9)


def test_epsilon_monotonicity():
    for eps in (0.1, 0.5, 1.0):
        a = ioctl_busy_rta(two_task_set(eps=eps))
        b = ioctl_busy_rta(two_task_set(eps=eps + 0.1))
        for k in a:
            assert a[k] <= b[k] + 1e-12


@pytest.mark.parametrize("seed", range(30))
def test_improved_never_worse_than_baseline(seed):
    ts = generate_taskset(seed, GenParams())
    base_b = ioctl_busy_rta(ts)
    imp_b = ioctl_busy_improved_rta(ts)
    base_s = ioctl_suspend_rta(ts)
    imp_s = ioctl_suspend_improved_rta(ts)
    for t in ts.rt_tasks:
        assert imp_b[t.name] <= base_b[t.name] + 1e-9
        assert imp_s[t.name] <= base_s[t.name] + 1e-9


def test_overlap_terms_positive_when_periods_allow():
    """A long pure-GPU segment of the low-priority task fully contains
    several short high-priority CPU jobs: O^cg must be positive."""
    th = Task("hi", [0.5], [], 2.0, 2.0, 0, 20)
    tl = Task("lo", [1.0], [GpuSegment(0.0, 10.0)], 50.0, 50.0, 0, 10)
    ts = Taskset([th, tl], n_cpus=1, epsilon=0.1, kthread_cpu=1)
    bx = bx_gpu_segment(ts, tl, 0)
    assert bx == pytest.approx(10.0, abs=1e-9)  # hi has no GPU work
    # floor(10/2)-1 = 4 fully-contained hi jobs, each C=0.5
    assert overlap_cg(ts, tl, th) == pytest.approx(2.0, abs=1e-9)
    # and the improved analysis is strictly tighter for lo
    base = ioctl_busy_rta(ts)
    imp = ioctl_busy_improved_rta(ts)
    assert imp["lo"] < base["lo"] - 1.0


def test_overlap_gc_symmetric():
    th = Task("hi", [0.1], [GpuSegment(0.0, 0.4)], 2.0, 2.0, 0, 20)
    tl = Task("lo", [10.0], [GpuSegment(0.0, 1.0)], 50.0, 50.0, 1, 10)
    ts = Taskset([th, tl], n_cpus=2, epsilon=0.1, kthread_cpu=2)
    bx = bx_cpu_segment(ts, tl, 0)
    assert bx == pytest.approx(10.0, abs=1e-9)  # hi is on another core
    # floor(10/2)-1 = 4 contained hi jobs, each Ge=0.4
    assert overlap_gc(ts, tl, th) == pytest.approx(1.6, abs=1e-9)


def test_kthread_K_cpu_only_remote_core_is_zero_verbatim():
    t_gpu = Task("g", [1.0], [GpuSegment(0.1, 1.0)], 10.0, 10.0, 0, 20)
    t_cpu = Task("c", [1.0], [], 10.0, 10.0, 1, 10)
    ts = Taskset([t_gpu, t_cpu], n_cpus=2, epsilon=0.5, kthread_cpu=2)
    R = {}
    assert kthread_K(ts, t_cpu, 5.0, R, corrected=False) == 0.0
    # corrected: still 0 — no same-core GPU-using higher-priority task
    assert kthread_K(ts, t_cpu, 5.0, R, corrected=True) == 0.0
    # but a same-core GPU-using HP task flips x_i on
    t_cpu2 = Task("c2", [1.0], [], 10.0, 10.0, 0, 10)
    ts2 = Taskset([t_gpu, t_cpu2], n_cpus=1, epsilon=0.5, kthread_cpu=2)
    assert kthread_K(ts2, t_cpu2, 5.0, R, corrected=True) > 0.0


def test_unschedulable_detection():
    t1 = Task("a", [8.0], [GpuSegment(0.0, 8.0)], 10.0, 10.0, 0, 20)
    t2 = Task("b", [8.0], [], 10.0, 10.0, 0, 10)
    ts = Taskset([t1, t2], n_cpus=1, epsilon=0.5, kthread_cpu=1)
    R = ioctl_busy_rta(ts)
    assert math.isinf(R["b"])
    assert not schedulable(ts, ioctl_busy_rta)
