"""Differential equivalence: scalar reference RTAs vs the vectorized
backends — NumPy (`repro.core.batch`, DESIGN.md §5) and JAX
(`repro.core.batch_jax`, DESIGN.md §8) — a three-way net.

Three layers of protection, each run per backend:

  * **WCRT differential** — for every analysis kind, across 1/2/4-device
    tasksets, both busy modes plus the suspend analyses, with and
    without GPU-priority jitters: the batch WCRT vectors must agree
    with the scalar vectors on accept/reject (inf-for-inf) and on every
    finite bound to 1e-6.
  * **Pipeline differential** — the full Sec. VII-A evaluation (RM test
    + Audsley retry) must make identical decisions through
    ``batch_accept_many`` and the scalar ``schedulable`` +
    ``assign_gpu_priorities`` path (under JAX this exercises the
    floor-seeded — i.e. warm-started — lockstep Audsley against the
    scalar cold search), and the warm-started Audsley must return the
    exact assignment of the cold-started search.
  * **Pinned golden batch** — 120 tasksets across six generator
    configurations with hard-coded accept/reject bits for all three
    sweep methods, so a simultaneous drift of the backends (or a
    generator change) cannot slip through as "still equivalent".

``REPRO_BATCH_N`` widens the differential seed range in CI's soundness
job; the default keeps tier-1 fast.  ``REPRO_BATCH_BACKENDS`` (comma
list, default "numpy,jax") selects which vectorized backends the
differentials run under — the soundness matrix runs one backend per
leg.  The hypothesis property test rides along when the extra is
installed (tests/_optional.py).
"""
import math
import os

import pytest

from repro.core import (GenParams, generate_taskset, schedulable,
                        schedulable_many)
from repro.core.audsley import assign_gpu_priorities
from repro.core.batch import (BUSY_KINDS, KINDS, batch_accept_many,
                              batch_rta, batch_schedulable, scalar_rta)
from repro.core.batch_jax import HAVE_JAX

from _optional import HAVE_HYPOTHESIS, given, settings, st

N_DIFF = int(os.environ.get("REPRO_BATCH_N", "24"))

BACKENDS = [
    pytest.param(b, marks=pytest.mark.skipif(
        b == "jax" and not HAVE_JAX, reason="jax not importable"))
    for b in os.environ.get("REPRO_BATCH_BACKENDS", "numpy,jax").split(",")
]


def test_eps_constants_unified():
    """The ceil/floor tolerance has exactly one definition: the scalar
    analyses and both vectorized backends read the same constant, so
    acceptance bits cannot drift through a one-sided tolerance edit."""
    from repro.core import analysis
    from repro.core import batch as b
    from repro.core import batch_jax as bj
    assert b.CEIL_EPS == analysis._EPS == bj._EPS
    assert bj.CEIL_EPS is b.CEIL_EPS


def _gen(seed, **kw):
    ts = generate_taskset(seed, GenParams(**kw))
    ts.kthread_cpu = ts.n_cpus
    return ts


def _assert_vectors_match(sc, ba, ctx):
    assert set(sc) == set(ba), ctx
    for name, r_s in sc.items():
        r_b = ba[name]
        if r_s is None or r_b is None:
            assert r_s is r_b, (ctx, name, r_s, r_b)
        elif math.isinf(r_s) or math.isinf(r_b):
            assert math.isinf(r_s) and math.isinf(r_b), (ctx, name, r_s, r_b)
        else:
            assert abs(r_s - r_b) <= 1e-6 * max(1.0, abs(r_s)), \
                (ctx, name, r_s, r_b)


# --------------------------------------------------------------------------
# WCRT differential
# --------------------------------------------------------------------------

@pytest.mark.parametrize("backend", BACKENDS)
@pytest.mark.parametrize("kind", KINDS)
@pytest.mark.parametrize("n_devices", [1, 2, 4])
@pytest.mark.parametrize("use_gpu_prio", [False, True])
def test_wcrt_differential(kind, n_devices, use_gpu_prio, backend):
    seeds = range(N_DIFF // 3)
    tss = [_gen(s, n_devices=n_devices) for s in seeds]
    rta = scalar_rta(kind)
    batch = batch_rta(kind, tss, use_gpu_prio=use_gpu_prio,
                      backend=backend)
    for s, (ts, ba) in enumerate(zip(tss, batch)):
        sc = rta(ts, use_gpu_prio=use_gpu_prio)
        _assert_vectors_match(sc, ba, (kind, n_devices, use_gpu_prio, s))


@pytest.mark.parametrize("kind", BUSY_KINDS)
def test_wcrt_differential_heuristic(kind):
    """The method='heuristic' escape hatch projects identically (and both
    sides warn on multi-device tasksets)."""
    from repro.core import SoundnessWarning
    tss = [_gen(s, n_devices=2) for s in range(4)]
    with pytest.warns(SoundnessWarning):
        batch = batch_rta(kind, tss, method="heuristic")
    rta = scalar_rta(kind)
    for s, (ts, ba) in enumerate(zip(tss, batch)):
        with pytest.warns(SoundnessWarning):
            sc = rta(ts, method="heuristic")
        _assert_vectors_match(sc, ba, (kind, "heuristic", s))


def test_schedulable_many_dispatch():
    """analysis.schedulable_many routes tagged RTAs through the batch
    backend and falls back to the scalar loop, with equal decisions."""
    from repro.core import ioctl_busy_improved_rta
    tss = [_gen(s, util_per_cpu=(0.35, 0.45)) for s in range(10)]
    via_batch = schedulable_many(tss, ioctl_busy_improved_rta)
    via_scalar = schedulable_many(tss, ioctl_busy_improved_rta,
                                  backend="scalar")
    via_kind = schedulable_many(tss, "ioctl_busy_improved")
    assert via_batch == via_scalar == via_kind
    # "numpy" is an accepted alias of "batch"; "jax" lowers the same
    # pack to the jit-compiled kernels with identical decisions
    assert schedulable_many(tss, ioctl_busy_improved_rta,
                            backend="numpy") == via_batch
    if HAVE_JAX:
        assert schedulable_many(tss, "ioctl_busy_improved",
                                backend="jax") == via_batch
    # scalar-only kwargs stay call-compatible on the batch default:
    # early_exit is an acceleration hint (dropped), seeds/only force the
    # scalar path instead of raising
    assert schedulable_many(tss, ioctl_busy_improved_rta,
                            early_exit=True) == via_batch
    assert schedulable_many(tss, ioctl_busy_improved_rta,
                            seeds={}) == via_batch
    with pytest.raises(ValueError):
        schedulable_many(tss, ioctl_busy_improved_rta, backend="turbo")
    with pytest.raises(ValueError):
        schedulable_many(tss, "ioctl_busy_improved", backend="scalar")


def test_spec_validation_is_eager():
    """Typos in sweep specs must fail loudly even when every taskset is
    single-device (the cross_device wrapper's contract)."""
    tss = [_gen(0)]
    with pytest.raises(ValueError):
        batch_accept_many({"m": ("kthread_busy", "heuristik")}, tss)
    with pytest.raises(ValueError):
        batch_accept_many({"m": ("ioctl_suspend_improved", "heuristic")},
                          tss)
    with pytest.raises(ValueError):
        batch_accept_many({"m": ("no_such_kind", "fixed_point")}, tss)
    with pytest.raises(ValueError):
        batch_rta("kthread_busy", tss, method="heuristik")


# --------------------------------------------------------------------------
# pipeline differential (RM test + Audsley retry)
# --------------------------------------------------------------------------

PIPELINE_KINDS = ("kthread_busy", "ioctl_busy_improved",
                  "ioctl_suspend_improved")


def _scalar_pipeline(ts, rta):
    if schedulable(ts, rta):
        return True
    return assign_gpu_priorities(ts, rta) is not None


@pytest.mark.parametrize("backend", BACKENDS)
@pytest.mark.parametrize("kind", PIPELINE_KINDS)
def test_pipeline_differential(kind, backend):
    """The band forces Audsley retries, so under JAX this also pins the
    floor-seeded (warm-started) lockstep Audsley — candidate rows kernel
    included — against the scalar cold search's decisions."""
    tss = [_gen(s, util_per_cpu=(0.32, 0.42)) for s in range(N_DIFF)]
    batch = batch_accept_many({kind: (kind, "fixed_point")}, tss,
                              backend=backend)[kind]
    rta = scalar_rta(kind)
    scalar = [_scalar_pipeline(ts, rta) for ts in tss]
    assert batch == scalar


@pytest.mark.parametrize("backend", BACKENDS)
@pytest.mark.parametrize("kind", PIPELINE_KINDS)
def test_pipeline_differential_multi_device(kind, backend):
    """n_devices > 1 routes the RM test through the lockstep crossfix /
    folded projections and the retry through the scalar fallback."""
    tss = [_gen(s, n_devices=2, util_per_cpu=(0.32, 0.42))
           for s in range(max(6, N_DIFF // 4))]
    batch = batch_accept_many({kind: (kind, "fixed_point")}, tss,
                              backend=backend)[kind]
    rta = scalar_rta(kind)
    scalar = [_scalar_pipeline(ts, rta) for ts in tss]
    assert batch == scalar


@pytest.mark.parametrize("kind", PIPELINE_KINDS)
def test_warm_start_identical(kind):
    """Floor-seeded Audsley returns the cold search's exact result —
    same accept/reject and the same GPU-priority assignment."""
    rta = scalar_rta(kind)
    checked = 0
    for seed in range(N_DIFF):
        ts = _gen(seed, util_per_cpu=(0.35, 0.45))
        if schedulable(ts, rta):
            continue  # Audsley never runs on RM-accepted sets
        warm = assign_gpu_priorities(ts, rta, warm_start=True)
        cold = assign_gpu_priorities(ts, rta, warm_start=False)
        assert (warm is None) == (cold is None), (kind, seed)
        if warm is not None:
            gw = {t.name: t.gpu_priority for t in warm.tasks}
            gc = {t.name: t.gpu_priority for t in cold.tasks}
            assert gw == gc, (kind, seed)
        checked += 1
    assert checked > 0  # the band must actually exercise the retry


# --------------------------------------------------------------------------
# pinned golden batch (120 tasksets, 6 generator configurations)
# --------------------------------------------------------------------------

def golden_tasksets():
    cfgs = [GenParams(util_per_cpu=(0.30, 0.40)),
            GenParams(util_per_cpu=(0.40, 0.50)),
            GenParams(n_tasks_total=20, util_per_cpu=(0.30, 0.40)),
            GenParams(gpu_task_ratio=(0.6, 0.8), util_per_cpu=(0.30, 0.40)),
            GenParams(best_effort_ratio=0.3, util_per_cpu=(0.35, 0.45)),
            GenParams(n_cpus=6, util_per_cpu=(0.30, 0.40))]
    return [_gen(1000 * c + seed, **vars(p))
            for c, p in enumerate(cfgs) for seed in range(20)]


GOLDEN_ACCEPT = {
    "kthread_busy":
        "000010010001000010000000000000000000000000100000000000000000"
        "000000000000000000000100001000110101100000000000000000000000",
    "ioctl_busy_improved":
        "011010010000001110100000000000000000000010011111001100100001"
        "000000000000000000000101111110111101111100000000000000000000",
    "ioctl_suspend_improved":
        "011010110001001110100000000000000000000010001111001100101001"
        "000000000100000000001101111110111101111100000000000000000000",
}


@pytest.mark.parametrize("backend", BACKENDS)
def test_golden_batch_pinned(backend):
    tss = golden_tasksets()
    assert len(tss) >= 100
    acc = batch_accept_many(
        {k: (k, "fixed_point") for k in GOLDEN_ACCEPT}, tss,
        backend=backend)
    for kind, bits in GOLDEN_ACCEPT.items():
        got = "".join("1" if b else "0" for b in acc[kind])
        assert got == bits, \
            f"{kind} [{backend}]: golden acceptance drifted"


def test_golden_batch_matches_scalar():
    """The same 120 tasksets through the scalar pipeline — so the golden
    bits pin *both* backends, not just the batch one."""
    tss = golden_tasksets()
    stride = max(1, len(tss) // max(N_DIFF, 1))
    for kind, bits in GOLDEN_ACCEPT.items():
        rta = scalar_rta(kind)
        for i in range(0, len(tss), stride):
            assert _scalar_pipeline(tss[i], rta) == (bits[i] == "1"), \
                (kind, i)


# --------------------------------------------------------------------------
# property test (hypothesis-optional)
# --------------------------------------------------------------------------

@settings(max_examples=15, deadline=None)
@given(seed=st.integers(0, 10_000),
       n_devices=st.sampled_from([1, 2, 4]),
       kind=st.sampled_from(list(KINDS)),
       use_gpu_prio=st.booleans())
def test_property_wcrt_differential(seed, n_devices, kind, use_gpu_prio):
    ts = _gen(seed, n_devices=n_devices)
    sc = scalar_rta(kind)(ts, use_gpu_prio=use_gpu_prio)
    ba = batch_rta(kind, [ts], use_gpu_prio=use_gpu_prio)[0]
    _assert_vectors_match(sc, ba, (seed, n_devices, kind, use_gpu_prio))


if HAVE_HYPOTHESIS:
    # batch_schedulable must agree with analysis.schedulable decisions
    @settings(max_examples=15, deadline=None)
    @given(seed=st.integers(0, 10_000))
    def test_property_decisions(seed):
        ts = _gen(seed)
        for kind in PIPELINE_KINDS:
            assert batch_schedulable(kind, [ts]) == \
                [schedulable(ts, scalar_rta(kind))]
