"""The unified submission facade (sched/client.py, DESIGN.md §9):
``connect()`` against an in-process cluster and against a live daemon
socket behaves identically; the historical direct paths keep working but
emit DeprecationWarning."""
import warnings

import pytest

from repro.sched import (ClusterExecutor, DeviceExecutor, JobProfile,
                         SchedClient, connect)


def prof(name, prio, device=0, exec_ms=4.0, period_ms=50.0, cpu=0,
         best_effort=False):
    return JobProfile(name, host_segments_ms=[1.0],
                      device_segments_ms=[(0.5, exec_ms)],
                      period_ms=period_ms, priority=prio, cpu=cpu,
                      best_effort=best_effort, device=device)


# ---------------------------------------------------------------------------
# in-process backend
# ---------------------------------------------------------------------------

def test_connect_owns_fresh_cluster_and_shuts_it_down():
    client = connect(n_devices=2, policy="ioctl")
    assert isinstance(client, SchedClient)
    assert client.cluster.n_devices == 2
    dec = client.submit(prof("a", 1), body=lambda job, it: None)
    assert dec.accepted and dec.device == 0
    assert client.status()["admitted"] == ["a"]
    assert client.per_device_mort() == {0: None, 1: None}
    client.close()     # owned: close() shuts the cluster down


def test_connect_adopts_existing_cluster_without_owning_it():
    cl = ClusterExecutor(n_devices=1, policy="ioctl")
    with connect(cl) as client:
        assert client.cluster is cl
        client.submit(prof("a", 1), body=lambda job, it: None)
    # adopted: close() left the cluster alive
    assert not cl.executors[0]._stop.is_set()
    cl.shutdown()
    with pytest.raises(ValueError, match="kwargs"):
        connect(cl, n_devices=2)


def test_submit_workload_spec_runs_and_journals_nothing_without_store():
    with connect(n_devices=1, policy="ioctl") as client:
        dec = client.submit(
            prof("count", 1, exec_ms=10.0, period_ms=200.0),
            workload_spec={"name": "demo.count",
                           "kwargs": {"total": 16, "per_slice": 4}},
            n_iterations=1, start=True)
        assert dec.accepted
        client.join(30)
        assert client.cluster.find_job("count").stats.completions == 1


def test_submit_spec_is_exclusive_with_body():
    with connect(n_devices=1) as client:
        with pytest.raises(ValueError, match="alone"):
            client.submit(prof("a", 1), workload_spec="demo.spin",
                          body=lambda job, it: None)


def test_unknown_workload_spec_fails_fast():
    with connect(n_devices=1) as client:
        with pytest.raises(KeyError, match="unknown workload"):
            client.submit(prof("a", 1), workload_spec="no.such.thing")


def test_release_frees_name_on_both_faces():
    with connect(n_devices=1) as client:
        client.submit(prof("a", 1), body=lambda job, it: None)
        assert client.release("a") is True
        assert client.release("a") is False
        assert client.submit(prof("a", 1),
                             body=lambda job, it: None).accepted


# ---------------------------------------------------------------------------
# deprecation shims (the compat test of the acceptance criteria)
# ---------------------------------------------------------------------------

def test_direct_cluster_submit_warns_but_works():
    cl = ClusterExecutor(n_devices=1, policy="ioctl")
    with pytest.warns(DeprecationWarning, match="connect"):
        res = cl.submit(prof("a", 1), body=lambda job, it: None)
    assert res["admitted"] and res["device"] == 0   # historical face
    cl.shutdown()


def test_device_executor_mode_kwarg_warns_but_works():
    with pytest.warns(DeprecationWarning, match="deprecated"):
        ex = DeviceExecutor(mode="notify", wait_mode="suspend")
    assert ex.policy.name == "ioctl"    # legacy name still resolves
    ex.shutdown()


def test_admission_controller_mode_kwarg_warns_but_works():
    from repro.sched.admission import AdmissionController
    with pytest.warns(DeprecationWarning, match="policy"):
        ac = AdmissionController(mode="poll", wait_mode="busy")
    assert ac.policy == "kthread"       # legacy name still resolves
    assert ac.mode == "kthread"         # read-only alias survives
    with pytest.raises(ValueError, match="alone"):
        AdmissionController(policy="ioctl", mode="ioctl")


def test_facade_submit_does_not_warn():
    with connect(n_devices=1) as client:
        with warnings.catch_warnings():
            warnings.simplefilter("error", DeprecationWarning)
            client.submit(prof("a", 1), body=lambda job, it: None)


# ---------------------------------------------------------------------------
# socket backend, against an in-thread daemon
# ---------------------------------------------------------------------------

@pytest.fixture
def daemon(tmp_path):
    from repro.sched.daemon import SchedDaemon
    d = SchedDaemon(str(tmp_path / "store"),
                    str(tmp_path / "sock"), n_devices=1)
    d.start()
    yield d
    d.stop()


def test_socket_round_trip_matches_local_semantics(daemon):
    client = connect(daemon.socket_path)
    assert client.ping()["ok"] is True
    dec = client.submit(
        prof("count", 1, exec_ms=10.0, period_ms=200.0),
        workload_spec={"name": "demo.count",
                       "kwargs": {"total": 16, "per_slice": 4}},
        n_iterations=1, start=True)
    assert dec.accepted and dec.reason == "accepted" and dec.device == 0
    assert dec.wcrt["count"] > 0
    st = client.status()
    assert st["backend"] == "daemon" and st["admitted"] == ["count"]
    daemon.cluster.join(30)
    jobs = client.jobs()
    assert jobs["count"]["done_iterations"] == 1
    assert jobs["count"]["wcrt_ms"] == dec.wcrt["count"]
    assert set(client.per_device_mort()) == {0}     # int keys restored
    assert client.release("count") is True


def test_socket_refuses_live_workload_objects(daemon):
    client = connect(daemon.socket_path)
    with pytest.raises(ValueError, match="registered workload spec"):
        client.submit(prof("a", 1), body=lambda job, it: None)
    with pytest.raises(ValueError, match="workload_spec"):
        client.submit(prof("a", 1))


def test_socket_submit_unknown_workload_is_validation_refused(daemon):
    client = connect(daemon.socket_path)
    dec = daemon.handle({"op": "submit",
                         "profile": prof("a", 1).to_dict(),
                         "workload": "no.such.thing"})
    assert not dec["admitted"]
    assert dec["reason"] == "validation-refused"
    assert client.status()["admitted"] == []


def test_socket_env_routes_connect(daemon, monkeypatch):
    from repro.sched.client import SOCKET_ENV
    monkeypatch.setenv(SOCKET_ENV, daemon.socket_path)
    client = connect()
    assert client.status()["backend"] == "daemon"
    with pytest.raises(ValueError, match="kwargs"):
        connect(n_devices=2)


def test_client_cli_round_trip(daemon, capsys):
    from repro.sched.client import main
    assert main(["--socket", daemon.socket_path, "ping"]) == 0
    assert main(["--socket", daemon.socket_path, "submit",
                 "--name", "cli", "--workload", "demo.count",
                 "--workload-kwargs", '{"total": 8, "per_slice": 4}',
                 "--period-ms", "200", "--priority", "1",
                 "--exec-ms", "10", "--start"]) == 0
    out = capsys.readouterr().out
    assert '"admitted": true' in out
    daemon.cluster.join(30)
    assert main(["--socket", daemon.socket_path, "jobs"]) == 0
    assert '"done_iterations": 1' in capsys.readouterr().out
