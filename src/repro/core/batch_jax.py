"""JAX-native batch RTA solver (DESIGN.md §8) — the ``backend="jax"``
counterpart of the NumPy lockstep in `core/batch.py`.

The NumPy backend iterates the whole pack Jacobi-style: every round
materializes ``(S, N, N)`` interference matrices on the host and pays
full Python dispatch per round.  This backend lowers the padded
``_Pack`` arrays to device arrays once per solve and runs the entire
ascent inside ``jit``-compiled kernels:

  * **Priority-rank scan.**  ``lax.scan`` walks the tasks of every
    taskset in decreasing CPU-priority order (the batch is vmapped
    implicitly: one rank step analyzes the rank-k task of *all* S
    tasksets at once).  Each step rebuilds the analyzed task's Lemma
    1-4/6-7 interference row ``(S, N)`` on the fly from the pack masks
    — the ``(S, N, N)`` matrices are never materialized — and ascends
    its recurrence with a masked ``lax.while_loop``.  Because
    interference flows strictly from higher CPU priority under the
    RM-stage jitters, every interferer is *final* when its reader runs:
    the scan is exactly the scalar substitution order, which is the
    strongest possible identity argument (the NumPy Jacobi ascent
    converges to the same least fixed point; DESIGN.md §5).
  * **Per-element freezing.**  A task whose iterate crosses its
    deadline is frozen at ``inf`` immediately (the scalar ``_iterate``
    rule), and under ``decide=True`` its whole taskset lane retires:
    later ranks of a rejected taskset are skipped, the accept bit is
    already determined.  This is the scan-shaped equivalent of the
    NumPy backend's converged-row compaction — converged or decided
    work leaves the ascent, only the live tail iterates.
  * **Eq. 5-9 overlap fixed points.**  The best-case BX ascents run
    inside the same kernels on ``(S, K, N)`` tiles per rank
    (``_bx_lfp``), fused by XLA with the masks that consume them.
  * **Audsley rows kernel.**  The lockstep Audsley's per-round
    candidate tests (one single-task recurrence per still-active
    taskset, floor-seeded — the warm start's floor bounds become the
    initial carries) and its closing full-set tests go through the same
    machinery with GPU-priority (deadline-constant) jitters.

Exactness: x64 is mandatory — every kernel runs under the *scoped*
``jax.experimental.enable_x64`` context so the repo's f32 kernel code
sharing the process keeps its default dtypes — and the ceil/floor
tolerance is imported from `core/batch.py` (``CEIL_EPS``), so the two
backends cannot drift apart on acceptance bits through a tolerance
edit in one of them.

Recompilation is bounded by *bucketed* pack shapes: S rounds up to a
power of two (multiples of 2048 past 4096), N to a multiple of 4 and
the segment axes to multiples of 2, so a parameter sweep whose taskset
sizes wobble between points reuses one compiled kernel per bucket.

When this module does *not* run: ``backend="numpy"`` stays the default
everywhere (tiny batches are not worth the dispatch), multi-device
Audsley retries fall back to the scalar search in both backends, and a
broken jax install degrades gracefully — importing this module is lazy
(`batch.get_solver`) and ``get_jax_solver`` raises a clear error
instead of poisoning the NumPy path.
"""
from __future__ import annotations

from functools import partial
from typing import Dict, NamedTuple, Optional

import numpy as np

try:  # pragma: no cover - exercised only on broken installs
    import jax
    import jax.numpy as jnp
    from jax import lax
    from jax.experimental import enable_x64
    HAVE_JAX = True
    _JAX_ERROR = None
except Exception as e:  # noqa: BLE001 - any import failure disables us
    HAVE_JAX = False
    _JAX_ERROR = e

from .analysis import MAX_ITERS
from .batch import CEIL_EPS, _Pack

_EPS = CEIL_EPS
_IMPROVED = frozenset(("ioctl_busy_improved", "ioctl_suspend_improved"))


# --------------------------------------------------------------------------
# shape bucketing
# --------------------------------------------------------------------------

def _bucket_s(n: int) -> int:
    """Batch-axis bucket: powers of two up to 4096, then multiples of
    2048 (a 10k sweep point pads to 10240, not 16384)."""
    n = max(n, 1)
    if n <= 4096:
        return 1 << max(3, (n - 1).bit_length())
    return -(-n // 2048) * 2048


def _bucket_up(n: int, q: int) -> int:
    return max(q, -(-n // q) * q)


def _pad_rows(a: np.ndarray, S: int, fill) -> np.ndarray:
    """Pad axis 0 to S rows with ``fill`` (axes >= 1 already sized)."""
    if a.shape[0] == S:
        return a
    out = np.full((S,) + a.shape[1:], fill, dtype=a.dtype)
    out[: a.shape[0]] = a
    return out


def _pad2(a: np.ndarray, S: int, N: int, fill) -> np.ndarray:
    out = np.full((S, N) + a.shape[2:], fill, dtype=a.dtype)
    out[: a.shape[0], : a.shape[1]] = a
    return out


class _Arrs(NamedTuple):
    """The device-array view of a (bucketed) `_Pack` — a pytree, so one
    jitted kernel serves every pack of the same bucket shape."""

    valid: jnp.ndarray   # (S,N) bool
    ug: jnp.ndarray      # (S,N) bool
    C: jnp.ndarray
    G: jnp.ndarray
    Gm: jnp.ndarray
    Ge: jnp.ndarray
    C_best: jnp.ndarray
    Ge_best: jnp.ndarray
    eta_g: jnp.ndarray
    T: jnp.ndarray
    D: jnp.ndarray
    prio: jnp.ndarray
    gp: jnp.ndarray
    cpu: jnp.ndarray     # (S,N) int64
    eps: jnp.ndarray     # (S,)
    kcpu: jnp.ndarray    # (S,) int64
    cseg: jnp.ndarray    # (S,N,Kc)
    cseg_m: jnp.ndarray
    gseg: jnp.ndarray    # (S,N,Kg)
    gseg_m: jnp.ndarray


class _TaskRow(NamedTuple):
    """Per-analyzed-task scalars, one lane per batch element."""

    prio_i: jnp.ndarray
    cpu_i: jnp.ndarray
    gp_i: jnp.ndarray
    ug_i: jnp.ndarray
    C_i: jnp.ndarray
    G_i: jnp.ndarray
    eta_i: jnp.ndarray
    D_i: jnp.ndarray
    col: jnp.ndarray     # analyzed task's column index
    gseg_i: jnp.ndarray  # (B,Kg)
    gsegm_i: jnp.ndarray
    cseg_i: jnp.ndarray  # (B,Kc)
    csegm_i: jnp.ndarray


# --------------------------------------------------------------------------
# traced primitives (twins of core/batch.py's NumPy helpers)
# --------------------------------------------------------------------------

def _ceil_pos(x, T):
    return jnp.maximum(jnp.ceil(x / T - _EPS), 0.0)


def _floor_pos(x, T):
    return jnp.maximum(jnp.floor(x / T + _EPS), 0.0)


def _bx_lfp(init, w, T, live0, cap):
    """Least fixed point of BX = init + sum_h max(ceil(BX/T_h)-1,0)*w_h,
    ascending from ``init`` — overlap._best_fixed_point's conventions
    (return-previous-iterate, 4096-step cap) on (B, K) element tiles.
    Returns the iterate plus the still-live mask at exit (non-empty only
    when ``cap`` cut the ascent short)."""

    def cond(c):
        _, live, it = c
        return jnp.logical_and(live.any(), it < cap)

    def body(c):
        bx, live, it = c
        n = jnp.maximum(_ceil_pos(bx[..., None], T) - 1.0, 0.0)
        nxt = init + (n * w).sum(-1)
        step = live & (nxt > bx + _EPS)
        return jnp.where(step, nxt, bx), step, it + 1

    bx0 = jnp.where(live0, init, 0.0)
    bx, live, _ = lax.while_loop(cond, body, (bx0, live0, jnp.int32(0)))
    return bx, live


def _overlap_rows(A: _Arrs, ti: _TaskRow, mgpu_row, HPP_row, bx_cap):
    """O^cg / O^gc rows (B, N) for the analyzed tasks — Eqs. (5)-(9)
    from the per-task best-case segment fixed points, built on the fly
    (no (S,N,N) matrices; XLA fuses these with their consumers)."""
    T3 = A.T[:, None, :]
    w_g = jnp.where(mgpu_row, A.Ge_best, 0.0)[:, None, :]
    bxg, lg = _bx_lfp(ti.gseg_i, w_g, T3, ti.gsegm_i, bx_cap)
    fl = jnp.maximum(_floor_pos(bxg[..., None], T3) - 1.0, 0.0)
    fl = jnp.where(ti.gsegm_i[..., None], fl, 0.0)
    Ocg = (fl * A.C_best[:, None, :]).sum(axis=1)
    w_c = jnp.where(HPP_row, A.C_best, 0.0)[:, None, :]
    bxc, lc = _bx_lfp(ti.cseg_i, w_c, T3, ti.csegm_i, bx_cap)
    flc = jnp.maximum(_floor_pos(bxc[..., None], T3) - 1.0, 0.0)
    flc = jnp.where(ti.csegm_i[..., None], flc, 0.0)
    Ogc = (flc * A.Ge_best[:, None, :]).sum(axis=1)
    return Ocg, Ogc, lg.any(-1) | lc.any(-1)


def _build_task(kind: str, corrected: bool, floor_mode: bool,
                use_gpu_prio: bool, A: _Arrs, ti: _TaskRow, bx_cap: int):
    """const + interference-row term groups for the analyzed tasks —
    the single-task projection of `_build2d` (same Lemma 2/3/4/6/7
    tables; tests/test_batch_equivalence.py pins the equivalence)."""
    HPP = A.valid & (A.cpu == ti.cpu_i[:, None]) & \
        (A.prio > ti.prio_i[:, None])
    HP = A.valid & (A.prio > ti.prio_i[:, None])
    HPg = A.valid & (A.gp > ti.gp_i[:, None])
    hpsel = HPg if use_gpu_prio else HP
    none = jnp.zeros_like(HPP)
    remote = none if floor_mode else (hpsel & A.ug & ~HPP)
    eps1 = A.eps[:, None]
    ocap = jnp.zeros(A.valid.shape[0], dtype=bool)

    if kind == "kthread_busy":
        x = ti.ug_i | (ti.cpu_i == A.kcpu)
        if corrected:
            x = x | (HPP & A.ug).any(-1)
        const = ti.C_i + ti.G_i + jnp.where(x, 2.0 * A.eps, 0.0)
        kmask = none if floor_mode else (hpsel & A.ug)
        groups = [
            (jnp.where(kmask & x[:, None], 2.0 * eps1, 0.0), "job", None),
            (jnp.where(HPP, A.C + A.G, 0.0), None, None),
            (jnp.where(remote, A.C + A.G, 0.0), "job", None),
        ]
        return const, groups, ocap

    gstar_i = ti.G_i + 2.0 * A.eps * ti.eta_i
    const = ti.C_i + gstar_i + (ti.eta_i + 1.0) * A.eps
    gstar_h = A.G + 2.0 * eps1 * A.eta_g
    gestar_h = A.Ge + 2.0 * eps1 * A.eta_g
    gmstar_h = A.Gm + 2.0 * eps1 * A.eta_g
    HPPc = HPP & ~A.ug
    HPPg = HPP & A.ug
    Ocg = Ogc = None
    if kind in _IMPROVED:
        if floor_mode:
            iot = jnp.arange(A.valid.shape[1])[None, :]
            mgpu = A.valid & A.ug & (iot != ti.col[:, None])
        else:
            mgpu = hpsel & A.ug
        Ocg, Ogc, ocap = _overlap_rows(A, ti, mgpu, HPP, bx_cap)

    if kind in ("ioctl_busy", "ioctl_busy_improved"):
        stretch = (A.eta_g + 1.0) * eps1 if corrected else 0.0
        groups = [
            (jnp.where(HPPc, A.C, 0.0), None, Ocg),
            (jnp.where(HPPg, A.C + gstar_h + stretch, 0.0), None,
             Ocg + Ogc if Ocg is not None else None),
            (jnp.where(remote, gestar_h, 0.0), "gpu", Ogc),
        ]
    else:  # ioctl_suspend / ioctl_suspend_improved
        ug_col = ti.ug_i[:, None]
        groups = [
            (jnp.where(HPPc, A.C, 0.0), None, Ocg),
            (jnp.where(HPPg, A.C + gmstar_h, 0.0), "cpu", Ocg),
            (jnp.where(HPPg & ug_col, A.Ge, 0.0), "gpu", Ogc),
            (jnp.where(remote & ug_col, gestar_h, 0.0), "gpu", Ogc),
        ]
    return const, groups, ocap


def _ascend(const_i, groups, J: Dict[str, jnp.ndarray], T, D_i, R0, act0,
            cap):
    """Masked monotone ascent of the analyzed tasks' recurrences under
    ``lax.while_loop``, per-element inf-freezing, capped at ``cap``
    rounds.  At the full budget (MAX_ITERS+1, `_solve_rows`'s scalar
    per-task cap) leftover-active lanes go conservatively to inf; at a
    ladder rung below it they are reported back so the host re-solves
    only those lanes at the full budget."""

    def cond(c):
        _, act, it = c
        return jnp.logical_and(act.any(), it < cap)

    def body(c):
        R, act, it = c
        Rs = jnp.where(jnp.isfinite(R), R, 0.0)
        total = const_i
        for W, jk, O in groups:
            X = Rs[:, None] + (J[jk] if jk is not None else 0.0)
            term = _ceil_pos(X, T) * W
            if O is not None:
                term = jnp.maximum(term - O, 0.0)
            total = total + term.sum(-1)
        Rnew = jnp.where(act, total, R)
        newinf = act & (Rnew > D_i + _EPS)
        delta = jnp.abs(jnp.where(act, Rnew, 0.0) - jnp.where(act, R, 0.0))
        moved = act & ~newinf & (delta >= _EPS)
        R = jnp.where(newinf, jnp.inf, Rnew)
        return R, act & ~newinf & moved, it + 1

    R, act, _ = lax.while_loop(cond, body, (R0, act0, jnp.int32(0)))
    return jnp.where(act, jnp.inf, R), act  # cap exhausted: conservative


def _const_jitters(A: _Arrs) -> Dict[str, jnp.ndarray]:
    """Deadline-constant release jitters (the ``use_gpu_prio`` modes)."""
    Dz = jnp.where(A.valid, jnp.where(jnp.isinf(A.D), 0.0, A.D), 0.0)
    return {"job": jnp.maximum(Dz - (A.C + A.G), 0.0),
            "gpu": jnp.maximum(Dz - A.Ge, 0.0),
            "cpu": jnp.maximum(Dz - (A.C + A.Gm), 0.0)}


def _gather_task(A: _Arrs, col) -> _TaskRow:
    m = jnp.arange(A.valid.shape[0])
    return _TaskRow(
        prio_i=A.prio[m, col], cpu_i=A.cpu[m, col], gp_i=A.gp[m, col],
        ug_i=A.ug[m, col], C_i=A.C[m, col], G_i=A.G[m, col],
        eta_i=A.eta_g[m, col], D_i=A.D[m, col], col=col,
        gseg_i=A.gseg[m, col], gsegm_i=A.gseg_m[m, col],
        cseg_i=A.cseg[m, col], csegm_i=A.cseg_m[m, col])


# --------------------------------------------------------------------------
# the jitted kernels
# --------------------------------------------------------------------------

@partial(jax.jit, static_argnames=("kind", "use_gpu_prio", "corrected",
                                   "floor_mode", "decide")) \
    if HAVE_JAX else (lambda f: f)
def _solve_scan(A: _Arrs, order, analyzed, seeds, cap, bx_cap,
                *, kind: str, use_gpu_prio: bool, corrected: bool,
                floor_mode: bool, decide: bool):
    """Solve a whole pack: scan over priority ranks, one masked ascent
    per rank.  Under RM-stage (R-dependent) jitters every interferer is
    final when read — the scalar substitution order; under GPU-priority
    (constant) jitters the elements are independent and the order is
    immaterial, so one kernel serves every mode.

    Returns ``(R, capped)``: ``capped`` marks lanes where ``cap`` or
    ``bx_cap`` cut an ascent short of convergence — at a ladder rung
    below the full budget the host discards those lanes' results and
    re-solves them; at the full budget the inf freeze is the
    conservative scalar semantics and ``capped`` is moot."""
    S = A.valid.shape[0]
    m = jnp.arange(S)
    Jc = _const_jitters(A) if use_gpu_prio else None
    R0 = jnp.where(analyzed, jnp.where(jnp.isfinite(seeds), seeds,
                                       jnp.inf), 0.0)

    # Bucket-padding columns of `order` hold index 0, so the final ranks
    # of shorter rows re-analyze a task that is already final.  That is
    # an idempotent no-op: its interferers (strictly higher priority)
    # were final the first time, so the ascent re-converges in one step
    # at the same value without moving, freezing, or killing the lane.
    def step(carry, col):
        R, dead, capped = carry
        ti = _gather_task(A, col)
        analyzed_i = analyzed[m, col]
        Ri0 = R[m, col]
        act0 = analyzed_i & jnp.isfinite(Ri0) & ~dead
        if use_gpu_prio:
            J = Jc
        else:
            base = jnp.where(A.valid,
                             jnp.where(jnp.isinf(R), A.D, R), 0.0)
            J = {"job": jnp.maximum(base - (A.C + A.G), 0.0),
                 "gpu": jnp.maximum(base - A.Ge, 0.0),
                 "cpu": jnp.maximum(base - (A.C + A.Gm), 0.0)}
        const_i, groups, ocap = _build_task(kind, corrected, floor_mode,
                                            use_gpu_prio, A, ti, bx_cap)
        Ri, left = _ascend(const_i, groups, J, A.T, ti.D_i, Ri0, act0,
                           cap)
        R = R.at[m, col].set(Ri)
        # a decide-dead lane's bit is already settled (monotone ascent:
        # the first inf survives any further iterating), so a cap bite
        # there needs no re-solve
        capped = capped | ((left | (ocap & act0)) & ~dead)
        if decide:
            dead = dead | (analyzed_i & jnp.isinf(Ri))
        return (R, dead, capped), None

    dead0 = jnp.zeros((S,), dtype=bool)
    (R, _, capped), _ = lax.scan(step, (R0, dead0, dead0), order.T)
    return R, capped


@partial(jax.jit, static_argnames=("kind", "corrected")) \
    if HAVE_JAX else (lambda f: f)
def _solve_rows_kernel(A: _Arrs, ti: _TaskRow, seeds, cap, bx_cap, *,
                       kind: str, corrected: bool):
    """Audsley candidate tests: one single-task recurrence per lane
    under an overridden GPU-priority vector, floor-seeded.  Returns
    ``(R, capped)`` with `_solve_scan`'s ladder contract."""
    J = _const_jitters(A)
    const_i, groups, ocap = _build_task(kind, corrected, False, True, A,
                                        ti, bx_cap)
    act0 = jnp.isfinite(seeds)
    R0 = jnp.where(act0, seeds, jnp.inf)
    R, left = _ascend(const_i, groups, J, A.T, ti.D_i, R0, act0, cap)
    return R, left | (ocap & act0)


# --------------------------------------------------------------------------
# host-side lowering + the solver object
# --------------------------------------------------------------------------

def _lower(p: _Pack, gpu_prio: Optional[np.ndarray],
           rows: Optional[np.ndarray] = None) -> _Arrs:
    """Pack -> bucketed device arrays.  ``rows`` selects a row subset
    (the Audsley candidate rounds) before padding."""

    def sel(a):
        return a if rows is None else a[rows]

    S0 = p.S if rows is None else len(rows)
    S = _bucket_s(S0)
    N = _bucket_up(p.N, 4)
    Kc = _bucket_up(p.cseg.shape[2], 2)
    Kg = _bucket_up(p.gseg.shape[2], 2)
    # a caller-supplied override is already in target row space (the
    # full pack for solve2d, the selected rows for solve_rows)
    gp = sel(p.gpu_prio) if gpu_prio is None else gpu_prio
    f = jnp.asarray
    return _Arrs(
        valid=f(_pad2(sel(p.valid), S, N, False)),
        ug=f(_pad2(sel(p.uses_gpu), S, N, False)),
        C=f(_pad2(sel(p.C), S, N, 0.0)),
        G=f(_pad2(sel(p.G), S, N, 0.0)),
        Gm=f(_pad2(sel(p.Gm), S, N, 0.0)),
        Ge=f(_pad2(sel(p.Ge), S, N, 0.0)),
        C_best=f(_pad2(sel(p.C_best), S, N, 0.0)),
        Ge_best=f(_pad2(sel(p.Ge_best), S, N, 0.0)),
        eta_g=f(_pad2(sel(p.eta_g), S, N, 0.0)),
        T=f(_pad2(sel(p.T), S, N, 1.0)),
        D=f(_pad2(sel(p.D), S, N, np.inf)),
        prio=f(_pad2(sel(p.prio), S, N, -np.inf)),
        gp=f(_pad2(gp, S, N, -np.inf)),
        cpu=f(_pad2(sel(p.cpu), S, N, -1)),
        eps=f(_pad_rows(sel(p.eps), S, 0.0)),
        kcpu=f(_pad_rows(sel(p.kcpu), S, 0.0).astype(np.int64)),
        cseg=f(_pad_seg(sel(p.cseg), S, N, Kc)),
        cseg_m=f(_pad_seg(sel(p.cseg_m), S, N, Kc)),
        gseg=f(_pad_seg(sel(p.gseg), S, N, Kg)),
        gseg_m=f(_pad_seg(sel(p.gseg_m), S, N, Kg)),
    )


def _pad_seg(a: np.ndarray, S: int, N: int, K: int) -> np.ndarray:
    fill = False if a.dtype == bool else 0.0
    out = np.full((S, N, K), fill, dtype=a.dtype)
    out[: a.shape[0], : a.shape[1], : a.shape[2]] = a
    return out


def _order(prio: np.ndarray) -> np.ndarray:
    """Per-taskset columns in decreasing CPU priority (padding last)."""
    return np.argsort(-prio, axis=1, kind="stable").astype(np.int64)


# The iteration-cap ladder: pass 1 runs every lane under a small round
# budget (most RTA ascents converge in a handful of rounds), and only
# the lanes where the cap bit — the slow-convergence tail near
# saturation — are re-solved at the scalar backend's full budget.
# Without the ladder the while_loop runs every lane for as many rounds
# as the batch's *slowest* lane; this is the JAX analog of the NumPy
# backend's converged-row compaction.  The final rung's inf freeze is
# `_solve_rows`'s conservative cap semantics, so the ladder cannot
# change a decision.
_CAPS = (8, MAX_ITERS + 1)
_BX_CAPS = (64, 4096)


class JaxSolver:
    """`core/batch.py`'s solver protocol on the JAX kernels above."""

    name = "jax"

    def solve2d(self, p: _Pack, kind: str, use_gpu_prio: bool,
                corrected: bool, analyzed: np.ndarray,
                gpu_prio: Optional[np.ndarray] = None,
                seeds: Optional[np.ndarray] = None,
                floor_mode: bool = False,
                decide: bool = False) -> np.ndarray:
        if not use_gpu_prio:
            assert bool((analyzed == p.valid).all()), \
                "R-dependent jitters need the full task vector"
        out = np.empty((p.S, p.N))
        todo = np.arange(p.S)
        with enable_x64():
            for rung, (cap, bx_cap) in enumerate(zip(_CAPS, _BX_CAPS)):
                sub = None if len(todo) == p.S else todo
                gp = gpu_prio if gpu_prio is None or sub is None \
                    else gpu_prio[sub]
                A = _lower(p, gp, rows=sub)
                S, N = A.valid.shape
                prio = p.prio if sub is None else p.prio[sub]
                order = jnp.asarray(_pad2(_order(prio), S, N, 0))
                ana = analyzed if sub is None else analyzed[sub]
                if seeds is None:
                    sd = np.zeros((len(todo), p.N))
                else:
                    sd = seeds if sub is None else seeds[sub]
                R, capped = _solve_scan(
                    A, order, jnp.asarray(_pad2(ana, S, N, False)),
                    jnp.asarray(_pad2(sd, S, N, 0.0)), cap, bx_cap,
                    kind=kind, use_gpu_prio=use_gpu_prio,
                    corrected=corrected, floor_mode=floor_mode,
                    decide=decide)
                R = np.asarray(R)[: len(todo), : p.N]
                if rung == len(_CAPS) - 1:
                    out[todo] = R
                    break
                capped = np.asarray(capped)[: len(todo)]
                out[todo[~capped]] = R[~capped]
                todo = todo[capped]
                if not len(todo):
                    break
        return out

    def solve_rows(self, p: _Pack, rows: np.ndarray, cands: np.ndarray,
                   kind: str, corrected: bool, gp_rows: np.ndarray,
                   seeds: Optional[np.ndarray] = None) -> np.ndarray:
        rows = np.asarray(rows)
        cands = np.asarray(cands)
        M0 = len(rows)
        out = np.empty(M0)
        todo = np.arange(M0)
        with enable_x64():
            for rung, (cap, bx_cap) in enumerate(zip(_CAPS, _BX_CAPS)):
                A = _lower(p, gp_rows[todo], rows=rows[todo])
                S, _ = A.valid.shape
                col = np.zeros(S, dtype=np.int64)
                col[: len(todo)] = cands[todo]
                ti = _gather_task(A, jnp.asarray(col))
                sd = np.full(S, np.inf)  # dead padding lanes never run
                sd[: len(todo)] = (np.asarray(seeds)[todo]
                                   if seeds is not None else 0.0)
                R, capped = _solve_rows_kernel(
                    A, ti, jnp.asarray(sd), cap, bx_cap, kind=kind,
                    corrected=corrected)
                R = np.asarray(R)[: len(todo)]
                if rung == len(_CAPS) - 1:
                    out[todo] = R
                    break
                capped = np.asarray(capped)[: len(todo)]
                out[todo[~capped]] = R[~capped]
                todo = todo[capped]
                if not len(todo):
                    break
        return out


_SOLVER: Optional[JaxSolver] = None


def get_jax_solver() -> JaxSolver:
    if not HAVE_JAX:
        raise RuntimeError(
            "backend='jax' requested but jax failed to import "
            f"({_JAX_ERROR!r}); use backend='numpy'")
    global _SOLVER
    if _SOLVER is None:
        _SOLVER = JaxSolver()
    return _SOLVER
