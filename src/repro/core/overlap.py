"""Execution-overlap lower bounds for the reduced-pessimism analysis (Sec. VI-C).

Implements Eqs. (5)-(9):

  * ``BX^g_{i,j}`` — best-case relative completion time of tau_i's j-th pure
    GPU segment (Eq. 6, adapted from Bril et al.'s best-case RTA): smallest
    fixed point of
        BX = Ge_best_{i,j} + sum_{h in hp(i)} (ceil(BX/T_h) - 1) * Ge_best_h
    Converging upward from Ge_best_{i,j} yields the *smallest* fixed point,
    which is the safe direction (smaller BX -> fewer guaranteed overlapped
    jobs -> larger WCRT bound).

  * ``O^cg_{(i,j),h}`` (Eq. 5) — minimum CPU execution of tau_h fully
    overlapped with tau_i's j-th pure GPU segment:
        max((floor(BX^g_{i,j}/T_h) - 1) * C_best_h, 0)

  * ``O^gc_{(i,j),h}`` (Eq. 9) — minimum pure-GPU execution of tau_h fully
    overlapped with tau_i's j-th CPU segment.  NOTE: the paper prints a
    ceiling here; by the containment argument in Lemma 5's proof (m =
    floor(BX/T_h) arrivals, m-1 fully contained jobs) the floor is the sound
    choice, so we use floor for both O^cg and O^gc.

  * ``O^cg_{i,h}`` / ``O^gc_{i,h}`` (Eqs. 7/8) — sums over segments.

``BX^c_{i,j}`` (best-case completion of a CPU segment) is not printed in the
paper; we define it symmetrically to Eq. (6) with same-core best-case CPU
interference:
    BX^c = C_best_{i,j} + sum_{h in hpp(i)} (ceil(BX/T_h) - 1) * C_best_h
"""
from __future__ import annotations

import math

from .task_model import Task, Taskset

_MAX_ITERS = 4096


def _ceil(x: float, t: float) -> int:
    if x <= 0:
        return 0
    return max(math.ceil(x / t - 1e-9), 0)


def _floor(x: float, t: float) -> int:
    if x <= 0:
        return 0
    return max(math.floor(x / t + 1e-9), 0)


def _best_fixed_point(init: float, contrib) -> float:
    """Smallest fixed point of BX = init + sum contrib(BX), from below."""
    bx = init
    for _ in range(_MAX_ITERS):
        nxt = init + contrib(bx)
        if nxt <= bx + 1e-9:
            return bx
        bx = nxt
    return bx  # conservative: larger BX only if non-convergent (bounded use)


def bx_gpu_segment(ts: Taskset, ti: Task, j: int, use_gpu_prio: bool = False,
                   full_hp: bool = False) -> float:
    """Eq. (6): best-case completion time BX^g_{i,j} of the j-th pure GPU seg.

    ``full_hp`` replaces the priority-ordered interference set with *every*
    GPU-using task (a superset of the set at any GPU-priority assignment).
    A larger set can only raise BX, hence raise the overlap deduction and
    lower the WCRT recurrence — the pessimistic-floor direction needed by
    the warm-started Audsley seed (core/audsley.py, DESIGN.md §5).
    """
    ge_best = ti.gpu_segments[j].exec_best
    if full_hp:
        hps = [h for h in ts.tasks if h is not ti and h.uses_gpu]
    else:
        hps = [h for h in ts.hp(ti, by_gpu=use_gpu_prio) if h.uses_gpu]

    def contrib(bx: float) -> float:
        return sum((_ceil(bx, h.period) - 1) * h.Ge_best
                   for h in hps if _ceil(bx, h.period) > 1)

    return _best_fixed_point(ge_best, contrib)


def bx_cpu_segment(ts: Taskset, ti: Task, j: int) -> float:
    """Best-case completion time BX^c_{i,j} of the j-th CPU segment."""
    c_best = ti.cpu_segments_best[j]
    hps = ts.hpp(ti)

    def contrib(bx: float) -> float:
        return sum((_ceil(bx, h.period) - 1) * h.C_best
                   for h in hps if _ceil(bx, h.period) > 1)

    return _best_fixed_point(c_best, contrib)


def overlap_cg(ts: Taskset, ti: Task, th: Task, use_gpu_prio: bool = False,
               full_hp: bool = False) -> float:
    """Eqs. (5)+(7): minimum CPU execution of tau_h fully overlapped with
    tau_i's pure GPU segments, summed over all GPU segments of tau_i.
    ``full_hp`` is the Audsley-floor superset (see ``bx_gpu_segment``)."""
    if th.C_best <= 0:
        return 0.0
    total = 0.0
    for j in range(ti.eta_g):
        bx = bx_gpu_segment(ts, ti, j, use_gpu_prio, full_hp=full_hp)
        total += max((_floor(bx, th.period) - 1) * th.C_best, 0.0)
    return total


def overlap_gc(ts: Taskset, ti: Task, th: Task) -> float:
    """Eqs. (8)+(9): minimum pure-GPU execution of tau_h fully overlapped
    with tau_i's CPU segments, summed over all CPU segments of tau_i."""
    if th.Ge_best <= 0:
        return 0.0
    total = 0.0
    for j in range(ti.eta_c):
        bx = bx_cpu_segment(ts, ti, j)
        total += max((_floor(bx, th.period) - 1) * th.Ge_best, 0.0)
    return total
