"""Event-driven scheduling engine (DESIGN.md §1).

The engine advances a :class:`~repro.core.simulator.Simulator` through a
heap-based event queue instead of the seed's per-task rescan loop:

  * **Releases** are typed, time-anchored events kept in a min-heap — the
    only event class whose firing time is known arbitrarily far ahead
    (sporadic tasks with fixed periods/offsets).  Popping the heap replaces
    the O(n_tasks) "who releases next?" scan of every advance step.
  * **Piece completions, RR slice expiries, runlist-update completions and
    kthread polls** are *derived* events: their firing times depend on the
    current core/GPU allocation, which any event can change (a release can
    preempt the piece whose completion was 'scheduled').  The engine
    therefore re-derives the earliest such event from the active allocation
    after each step — only the jobs that actually hold a resource
    contribute, so the advance step touches the progressing set, not every
    job in the system.

Multi-device platforms (DESIGN.md §4): the engine instantiates one policy
per device and routes job-scoped hooks by ``task.device``; CPU arbitration
is global (cores are shared across devices), GPU arbitration is
per-device.

The semantics are piece-for-piece identical to the seed simulator loop —
`tests/test_engine_equivalence.py` pins golden MORT/deadline-miss traces
captured from the pre-engine implementation.
"""
from __future__ import annotations

import heapq
from typing import TYPE_CHECKING, Dict, List, Optional

if TYPE_CHECKING:  # pragma: no cover
    from .simulator import Job, Simulator

_TIME_EPS = 1e-9
_MAX_EVENTS = int(5e6)


class EventDrivenEngine:
    """Drives one Simulator to its horizon.

    The engine owns scheduling mechanics (core arbitration, the driver
    rt_mutex cascade, time advancement); the Simulator owns job lifecycle
    (release → pieces → completion) and result bookkeeping; the policies
    own all approach-specific arbitration state."""

    def __init__(self, sim: "Simulator"):
        self.sim = sim

    # ------------------------------------------------------------------
    # core arbitration
    # ------------------------------------------------------------------
    def _core_winners(self) -> Dict[int, Optional["Job"]]:
        """Highest-priority demanding job per core.  A started update piece
        is a non-preemptive kernel section and keeps its core outright."""
        sim = self.sim
        winners: Dict[int, Optional["Job"]] = {
            c: None for c in range(sim.ts.n_cpus)}
        active = sim.active_jobs()
        for j in active:
            if j.current_kind() == "upd" and j.upd_started:
                winners[j.task.cpu] = j
        for c in range(sim.ts.n_cpus):
            if winners[c] is not None:
                continue
            cands = [j for j in active
                     if j.task.cpu == c
                     and j.cpu_demand(sim.mode, sim.policy_for(j))]
            if cands:
                winners[c] = max(
                    cands,
                    key=lambda j: sim.policy_for(j).effective_priority(j))
        # policy machinery (e.g. the kernel thread mid-rewrite) can consume
        # a core outright
        for p in sim.policies:
            for c in p.occupied_cores():
                winners[c] = None
        return winners

    def _allocate(self) -> Dict[int, Optional["Job"]]:
        """Compute core winners, letting due runlist updates acquire the
        driver mutex: completion-side (driver-context) updates first, then
        winners standing at a begin() boundary — cascading through
        zero-cost (pending-only) updates."""
        sim = self.sim
        for _ in range(16 * (len(sim.jobs) + 2)):
            winners = self._core_winners()
            entered = False
            # driver-context end updates need no core and go first
            ends = sorted([j for j in sim.active_jobs()
                           if j.current_kind() == "upde"
                           and not j.upd_started],
                          key=lambda j: -j.task.priority)
            begins = sorted(
                [j for j in winners.values() if j is not None
                 and j.current_kind() == "upd" and not j.upd_started],
                key=lambda j: -sim.policy_for(j).effective_priority(j))
            for j in ends + begins:
                if sim.policy_for(j).try_acquire(j):
                    j.upd_started = True
                    piece = j.current_piece()
                    sim.policy_for(j).begin_update(j, piece)
                    entered = True
                    if piece.remaining <= _TIME_EPS:
                        sim._complete_piece(j)
                    break  # re-derive state after a change
            if not entered:
                return winners
        raise RuntimeError("allocation did not settle")

    # ------------------------------------------------------------------
    # the event loop
    # ------------------------------------------------------------------
    def run(self) -> None:
        sim = self.sim
        # release event queue: (time, task_index, task).  task_index makes
        # simultaneous releases fire in taskset order (seed-equivalent).
        heap: List[tuple] = [(sim.next_release[t.name], i, t)
                             for i, t in enumerate(sim.ts.tasks)]
        heapq.heapify(heap)

        guard = 0
        while sim.t < sim.horizon - _TIME_EPS:
            guard += 1
            if guard > _MAX_EVENTS:
                raise RuntimeError("simulator event budget exceeded")

            # 1. release events due now (fired in taskset order on ties)
            while heap and heap[0][0] <= sim.t + _TIME_EPS:
                due = []
                while heap and heap[0][0] <= sim.t + _TIME_EPS:
                    due.append(heapq.heappop(heap))
                due.sort(key=lambda e: e[1])
                for _, idx, task in due:
                    nxt = sim.next_release[task.name] + task.period
                    sim.next_release[task.name] = nxt
                    heapq.heappush(heap, (nxt, idx, task))
                    sim._release(task)

            # 2. allocation (lets due IOCTL updates enter the kernel section)
            winners = self._allocate()
            for p in sim.policies:
                p.notify_winners(winners)
            if any(p.recheck_winners_after_notify for p in sim.policies):
                winners = self._core_winners()  # a rewrite may block a core
            owners = {d: p.gpu_owner() for d, p in enumerate(sim.policies)}

            # driver-context end updates progress in wall time once started
            driver_upds = [j for j in sim.active_jobs()
                           if j.current_kind() == "upde" and j.upd_started]

            # 3. next event horizon: earliest of the queued releases and the
            # derived events of the current allocation
            dt = sim.horizon - sim.t
            if heap:
                dt = min(dt, heap[0][0] - sim.t)
            for c, j in winners.items():
                if j is not None and j.cpu_progresses():
                    dt = min(dt, j.current_piece().remaining)
            for owner in owners.values():
                if owner is not None and owner.wants_gpu():
                    dt = min(dt, owner.current_piece().remaining)
            for j in driver_upds:
                dt = min(dt, j.current_piece().remaining)
            for p in sim.policies:
                dt = min(dt, p.next_gpu_event())
            if dt <= _TIME_EPS:
                dt = _TIME_EPS  # numerical floor; completions fire below

            # 4. advance the progressing set
            for c, j in winners.items():
                if j is not None and j.cpu_progresses():
                    j.current_piece().remaining -= dt
            for owner in owners.values():
                if owner is not None and owner.wants_gpu():
                    owner.current_piece().remaining -= dt
            for j in driver_upds:
                j.current_piece().remaining -= dt
            for p in sim.policies:
                p.gpu_rr_advance(dt)
            sim.t += dt

            # 5. fire completions (cascades handled inside)
            for j in list(sim.jobs):
                p = j.current_piece()
                if p is None or not j.active:
                    continue
                if p.remaining <= _TIME_EPS:
                    progressed = (p.kind == "ge" or
                                  (p.kind == "upde" and j.upd_started) or
                                  j.cpu_progresses())
                    if progressed:
                        sim._complete_piece(j)
