"""Reduced-pessimism WCRT analysis (Sec. VI-C, Lemmas 6 and 7).

The baseline analysis assumes CPU and GPU preemptions both occur at full
extent across R_i.  Lemmas 6/7 subtract the *guaranteed minimum overlaps*
(Eqs. 5-9): CPU execution of higher-priority tasks that provably runs in
parallel with tau_i's pure GPU segments (O^cg) and higher-priority pure GPU
execution that provably runs in parallel with tau_i's CPU segments (O^gc).

Each interference term is clamped at >= 0 after subtraction (the overlap is a
lower bound on parallelism already counted inside the term).

The improvement applies to the IOCTL-based approach only (the kernel-thread
approach reserves the device at job granularity, so segment-level overlap
does not arise -- Sec. VII-A.3).

Both entry points run on the shared ``_rta_loop`` driver (early_exit /
only / multi-device semantics identical to `core.analysis`).
"""
from __future__ import annotations

from typing import Callable, Dict, Optional

from .analysis import (_gestar, _gmstar, _gstar, _gpu_hp_remote, _jitter,
                       _rta_loop, ceil_pos, cross_device, per_device)
from .overlap import overlap_cg, overlap_gc
from .task_model import Task, Taskset


@cross_device("ioctl")
def ioctl_busy_improved_rta(ts: Taskset, use_gpu_prio: bool = False,
                            corrected: bool = True,
                            early_exit: bool = False,
                            only: Optional[str] = None,
                            seeds: Optional[Dict[str, float]] = None,
                            overlap_floor: bool = False
                            ) -> Dict[str, Optional[float]]:
    """Lemma 6: IOCTL busy-waiting WCRT with overlap deduction.

    R_i = C_i + G_i^* + (eta_i^g+1)*eps
        + sum_{h in hpp, eta_h^g=0} max(ceil(R_i/T_h)*C_h - O^cg_{i,h}, 0)
        + sum_{h in hpp, eta_h^g>0} max(ceil(R_i/T_h)*(C_h+G_h^*)
                                        - (O^cg_{i,h} + O^gc_{i,h}), 0)
        + sum_{h in hp\\hpp, eta_h^g>0}
              max(ceil((R_i+J_h^g)/T_h)*G_h^{e*} - O^gc_{i,h}, 0)

    ``overlap_floor`` computes O^cg with the all-GPU-tasks interference
    superset (``overlap_cg(..., full_hp=True)``), which can only enlarge
    the deduction — it turns the recurrence into a pointwise lower bound
    of the recurrence at *any* GPU-priority assignment.  Only the
    warm-started Audsley seed (`core/audsley.py`) should set it.
    """
    eps = ts.epsilon

    def make_f(ti: Task, R: Dict) -> Callable:
        hpp_cpu = [h for h in ts.hpp(ti) if not h.uses_gpu]
        hpp_gpu = [h for h in ts.hpp(ti) if h.uses_gpu]
        remote = _gpu_hp_remote(ts, ti, use_gpu_prio)
        Ocg = {h.name: overlap_cg(ts, ti, h, use_gpu_prio,
                                  full_hp=overlap_floor)
               for h in hpp_cpu + hpp_gpu}
        Ogc = {h.name: overlap_gc(ts, ti, h) for h in hpp_gpu + remote}

        def f(R_i: float) -> float:
            v = ti.C + _gstar(ti, eps) + (ti.eta_g + 1) * eps
            for h in hpp_cpu:
                v += max(ceil_pos(R_i, h.period) * h.C - Ocg[h.name], 0.0)
            for h in hpp_gpu:
                stretch = (h.eta_g + 1) * eps if corrected else 0.0
                v += max(ceil_pos(R_i, h.period)
                         * (h.C + _gstar(h, eps) + stretch)
                         - (Ocg[h.name] + Ogc[h.name]), 0.0)
            for h in remote:
                J = _jitter(ts, h, "gpu", R, use_gpu_prio)
                v += max(ceil_pos(R_i + J, h.period) * _gestar(h, eps)
                         - Ogc[h.name], 0.0)
            return v
        return f

    return _rta_loop(ts, make_f, early_exit=early_exit, only=only,
                     r_independent=use_gpu_prio, seeds=seeds)


@per_device
def ioctl_suspend_improved_rta(ts: Taskset, use_gpu_prio: bool = False,
                               early_exit: bool = False,
                               only: Optional[str] = None,
                               seeds: Optional[Dict[str, float]] = None,
                               overlap_floor: bool = False
                               ) -> Dict[str, Optional[float]]:
    """Lemma 7: IOCTL self-suspension WCRT with overlap deduction.

    Follows Lemma 4 term-by-term, deducting O^cg from CPU-side interference
    and O^gc from GPU-side interference.  ``overlap_floor`` as in
    ``ioctl_busy_improved_rta`` (Audsley floor seed only).
    """
    eps = ts.epsilon

    def make_f(ti: Task, R: Dict) -> Callable:
        hpp_cpu = [h for h in ts.hpp(ti) if not h.uses_gpu]
        hpp_gpu = [h for h in ts.hpp(ti) if h.uses_gpu]
        remote = _gpu_hp_remote(ts, ti, use_gpu_prio)
        Ocg = {h.name: overlap_cg(ts, ti, h, use_gpu_prio,
                                  full_hp=overlap_floor)
               for h in hpp_cpu + hpp_gpu}
        Ogc = {h.name: overlap_gc(ts, ti, h) for h in hpp_gpu + remote}

        def f(R_i: float) -> float:
            v = ti.C + _gstar(ti, eps) + (ti.eta_g + 1) * eps
            for h in hpp_cpu:
                v += max(ceil_pos(R_i, h.period) * h.C - Ocg[h.name], 0.0)
            for h in hpp_gpu:
                Jc = _jitter(ts, h, "cpu", R, use_gpu_prio)
                v += max(ceil_pos(R_i + Jc, h.period) * (h.C + _gmstar(h, eps))
                         - Ocg[h.name], 0.0)
                if ti.uses_gpu:
                    Jg = _jitter(ts, h, "gpu", R, use_gpu_prio)
                    v += max(ceil_pos(R_i + Jg, h.period) * h.Ge
                             - Ogc[h.name], 0.0)
            if ti.uses_gpu:
                for h in remote:
                    Jg = _jitter(ts, h, "gpu", R, use_gpu_prio)
                    v += max(ceil_pos(R_i + Jg, h.period) * _gestar(h, eps)
                             - Ogc[h.name], 0.0)
            return v
        return f

    return _rta_loop(ts, make_f, early_exit=early_exit, only=only,
                     r_independent=use_gpu_prio, seeds=seeds)


ioctl_busy_improved_rta.batch_kind = "ioctl_busy_improved"
ioctl_suspend_improved_rta.batch_kind = "ioctl_suspend_improved"
