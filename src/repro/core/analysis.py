"""Baseline end-to-end response time analysis (Sec. VI-A / VI-B).

Implements:
  * Lemma 1 — runlist-update delay bound K_i under the kernel-thread approach.
  * Lemma 2 — WCRT under the kernel-thread approach (busy-waiting only).
  * Lemma 3 — WCRT under the IOCTL-based approach, busy-waiting mode.
  * Lemma 4 — WCRT under the IOCTL-based approach, self-suspension mode.
  * Sec. VI-B — variant under GPU-segment priority assignment: GPU preemption
    terms (and Eq. (1) runlist updates) are governed by GPU priorities, and
    release jitters use D_h in place of R_h (WCRTs of higher-GPU-priority
    tasks are unknown during Audsley assignment).

All analyses return a dict {task name -> WCRT}, with ``math.inf`` for tasks
whose recurrence exceeds the deadline (unschedulable).  Best-effort tasks are
not analyzed (value ``None``): they have no deadline.

Every entry point shares the ``_rta_loop`` driver, which adds two
result-preserving accelerations used by the schedulability sweeps:

  * ``early_exit=True`` stops at the first unschedulable task (the
    remaining WCRTs cannot rescue the taskset) — partial dicts are only
    returned on the failure path, so ``schedulable`` stays exact;
  * ``only=<name>`` computes just the prefix of tasks needed for one
    task's bound — with ``use_gpu_prio=True`` jitters are deadline-based
    (the OPA property), so a single task suffices (Audsley's inner loop).

Multi-device tasksets (``ts.n_devices > 1``, DESIGN.md §4) are analyzed
per device: tasks bound to other devices have their GPU segments folded
into an extra CPU charge standing in for their worst-case core occupancy
(executing/busy-waiting through their own device segments and runlist
updates) — since distinct devices share cores but not runlists, driver
locks, or GPU time.  Two projection regimes:

  * *self-suspension* (``per_device``): the folded charge is the
    constant ``G + (3*eta^g + 1)*eps`` — an occupancy bound because a
    suspending task yields its core while queued behind contention.
    Validated against the simulator (tests/test_multi_device.py).
  * *busy-waiting* (``cross_device``): a spinning task occupies its core
    for as long as it is queued behind its own device's contention, so
    the folded charge must itself be iterated — the joint cross-device
    fixed point in `core/crossfix.py` (default ``method="fixed_point"``).
    The pre-fixed-point constant-charge projection survives only as an
    explicit ``method="heuristic"`` escape hatch, which emits a
    ``SoundnessWarning`` (kept for benchmark comparisons).

Conventions:
  G_i^*  = G_i   + 2*eps*eta_i^g       (Sec. VI-A.2)
  G_i^e* = G_i^e + 2*eps*eta_i^g
  G_i^m* = G_i^m + 2*eps*eta_i^g
  J_h    = R_h - (C_h + G_h)           (Lemma 1)
  J_h^g  = R_h - G_h^e                 (Lemma 3)
  J_h^c  = R_h - (C_h + G_h^m)         (Lemma 4)
"""
from __future__ import annotations

import functools
import math
import warnings
from typing import Callable, Dict, Optional

from .task_model import Task, Taskset

MAX_ITERS = 4096
_EPS = 1e-9


class SoundnessWarning(UserWarning):
    """An analysis path without a validated soundness guarantee was used
    (e.g. the constant-charge multi-device projection under busy-waiting,
    which under-counts cross-device busy-wait coupling)."""


def ceil_pos(x: float, t: float) -> int:
    """ceil(x / t) robust to float noise, clamped at >= 0."""
    if x <= 0:
        return 0
    q = x / t
    c = math.ceil(q - 1e-9)
    return max(c, 0)


def _jitter(ts: Taskset, h: Task, kind: str, R: Dict[str, float],
            use_gpu_prio: bool) -> float:
    """Release jitter of a higher-priority task (Sec. VI-A / VI-B)."""
    base = ts_deadline(h) if use_gpu_prio else R.get(h.name, h.deadline)
    if base is None or math.isinf(base):
        base = h.deadline  # conservative fallback keeps recurrence finite
    if kind == "job":      # J_h = R_h - (C_h + G_h)
        j = base - (h.C + h.G)
    elif kind == "gpu":    # J_h^g = R_h - G_h^e
        j = base - h.Ge
    elif kind == "cpu":    # J_h^c = R_h - (C_h + G_h^m)
        j = base - (h.C + h.Gm)
    else:
        raise ValueError(kind)
    return max(j, 0.0)


def ts_deadline(t: Task) -> float:
    return t.deadline


def _iterate(ti: Task, f: Callable[[float], float], seed: float = 0.0) -> float:
    """Standard fixed-point iteration; inf if R exceeds the deadline.

    ``seed`` warm-starts the ascent.  The caller must guarantee
    ``seed <= lfp(f)``: for monotone ``f`` the Kleene iteration from any
    point at or below the least fixed point is nondecreasing and converges
    to the same least fixed point (Knaster–Tarski: ``f(s) < s`` would imply
    ``lfp <= s``), so the result is identical to the cold start — only the
    early iterations are skipped.  An ``inf`` seed means the caller already
    proved a lower bound beyond the deadline."""
    if math.isinf(seed):
        return math.inf
    R = f(max(seed, 0.0))
    for _ in range(MAX_ITERS):
        R_new = f(R)
        if R_new > ti.deadline + _EPS:
            return math.inf
        if abs(R_new - R) < _EPS:
            return R_new
        R = R_new
    return math.inf


def _gpu_hp_remote(ts: Taskset, ti: Task, use_gpu_prio: bool) -> list[Task]:
    """hp(tau_i) \\ hpp(tau_i) with eta^g>0; ordering per Sec. VI-B if asked."""
    hpp = set(id(t) for t in ts.hpp(ti))
    return [h for h in ts.hp(ti, by_gpu=use_gpu_prio)
            if id(h) not in hpp and h.uses_gpu]


# --------------------------------------------------------------------------
# shared fixed-point driver + multi-device projection
# --------------------------------------------------------------------------

def _rta_loop(ts: Taskset, make_f: Callable[[Task, Dict], Callable],
              early_exit: bool = False, only: Optional[str] = None,
              r_independent: bool = False,
              seeds: Optional[Dict[str, float]] = None
              ) -> Dict[str, Optional[float]]:
    """Run the per-task fixed points in decreasing priority order.

    ``make_f(ti, R)`` builds the recurrence for ``ti`` given the WCRTs of
    the higher-priority tasks computed so far.  ``r_independent`` declares
    that the recurrences never read ``R`` (deadline-based jitters), which
    lets ``only`` skip every other task outright.  ``seeds`` maps task
    names to warm-start values for the per-task iteration (must be lower
    bounds of the respective fixed points — see ``_iterate``; used by the
    warm-started Audsley assignment in `core/audsley.py`)."""
    R: Dict[str, Optional[float]] = {}
    for ti in ts.by_priority():
        if only is not None and r_independent and ti.name != only:
            continue
        if ti.is_rt:
            seed = seeds.get(ti.name, 0.0) if seeds else 0.0
            R[ti.name] = _iterate(ti, make_f(ti, R), seed=seed)
        else:
            R[ti.name] = None
        if only is not None and ti.name == only:
            return R
        if early_exit and ti.is_rt and math.isinf(R[ti.name]):
            return R  # partial: the taskset is already unschedulable
    return R


def fold_to_device(ts: Taskset, device: int,
                   occupancy: Optional[Dict[str, float]] = None) -> Taskset:
    """Single-device projection: tasks on ``device`` keep their structure;
    GPU tasks on other devices become CPU-only with their device work
    folded into an extra CPU segment.  The default charge is the
    *uncontended* core occupancy G + 2*eps*eta^g busy-wait stretch +
    (eta^g+1)*eps update blocking (sound under self-suspension);
    ``occupancy`` overrides it per task name — the cross-device fixed
    point (`core/crossfix.py`) passes its contention-aware iterate here.
    The folded segment's *best case* is 0: the overlap lemmas (Eqs. 5-9)
    read C_best as execution that is *guaranteed* to occur, and a
    suspended remote-device task may occupy its core arbitrarily little —
    inflating the best case would overstate guaranteed overlap and make
    the improved analyses optimistic."""
    from .crossfix import uncontended_occupancy
    tasks = []
    for t in ts.tasks:
        if t.uses_gpu and t.device != device:
            # single source of truth for the default charge: the fixed
            # point's seed must equal the fold default (seed == heuristic
            # == suspension-equivalent bound; see crossfix docstring)
            extra = uncontended_occupancy(t, ts.epsilon)
            if occupancy is not None and t.name in occupancy:
                extra = occupancy[t.name]
            tasks.append(Task(
                name=t.name,
                cpu_segments=tuple(t.cpu_segments) + (extra,),
                cpu_segments_best=tuple(t.cpu_segments_best) + (0.0,),
                gpu_segments=(),
                period=t.period, deadline=t.deadline, cpu=t.cpu,
                priority=t.priority, gpu_priority=t.gpu_priority,
                best_effort=t.best_effort, device=0))
        elif t.device != 0:
            import dataclasses
            tasks.append(dataclasses.replace(t, device=0))
        else:
            tasks.append(t)
    return Taskset(tasks=tasks, n_cpus=ts.n_cpus, epsilon=ts.epsilon,
                   kthread_cpu=ts.kthread_cpu, n_devices=1)


def _worse_bound(a: Optional[float], b: Optional[float]) -> bool:
    """None-aware "``a`` is a worse (larger) WCRT bound than ``b``"
    (None = best-effort, never worse); shared by the multi-device
    projections here and in `core/crossfix.py`."""
    if a is None:
        return False
    if b is None:
        return True
    return a > b


def merge_device_bounds(out: Dict[str, Optional[float]],
                        Rd: Dict[str, Optional[float]],
                        own_dev: Dict[str, int], d: int) -> None:
    """The per-device combination rule, shared by ``per_device``,
    `core/crossfix.py` and `core/batch.py`: a GPU task takes its bound
    from its own device's projection only; device-agnostic tasks keep
    the worst bound over projections."""
    for name, r in Rd.items():
        if name in own_dev:
            if own_dev[name] == d:
                out[name] = r
        elif name not in out or _worse_bound(r, out[name]):
            out[name] = r


def per_device(rta: Callable) -> Callable:
    """Lift a single-device RTA to multi-device tasksets (identity when
    ``n_devices == 1``).  Each GPU task takes its bound from its own
    device's projection; CPU-only tasks take the max over projections.

    The constant folded charge is an occupancy bound only when queued
    tasks yield their cores, so this decorator is reserved for the
    *self-suspension* analyses; busy-mode analyses go through
    ``cross_device`` below."""
    @functools.wraps(rta)
    def wrapper(ts: Taskset, *args, **kw):
        if ts.n_devices <= 1:
            return rta(ts, *args, **kw)
        # Warm-start seeds are defined against one recurrence; the
        # *merged* multi-device bound (max over projections for a
        # device-agnostic task) is not a lower bound of every single
        # projection's fixed point, so a seed proved on the merged
        # result could start a projection's ascent above its least
        # fixed point.  Drop seeds on the multi-device path (they only
        # accelerate — correctness is unaffected), mirroring
        # ``cross_device`` below.
        kw.pop("seeds", None)
        own_device = {t.name: t.device for t in ts.tasks if t.uses_gpu}
        out: Dict[str, Optional[float]] = {}
        for d in range(ts.n_devices):
            only = kw.get("only")
            if only is not None and own_device.get(only, d) != d:
                continue  # a GPU task's bound comes from its device only
            Rd = rta(fold_to_device(ts, d), *args, **kw)
            merge_device_bounds(out, Rd, own_device, d)
        return out

    return wrapper


def cross_device(occ_kind: str) -> Callable:
    """Lift a single-device *busy-mode* RTA to multi-device tasksets
    (identity when ``n_devices == 1``).

    Default ``method="fixed_point"`` runs the joint cross-device fixed
    point (`core/crossfix.py`): per-task WCRT bounds are iterated jointly
    across all devices, each task's busy-wait core occupancy re-derived
    from the current iterate of its device's contention — sound against
    the simulator (tests/test_cross_soundness.py).  The pre-fixed-point
    constant-charge projection is kept as an explicit
    ``method="heuristic"`` escape hatch for benchmark comparisons; it
    emits a ``SoundnessWarning``.

    ``occ_kind`` selects the per-rival device blocking model ("kthread":
    job-granular reservation, "ioctl": segment-granular admission)."""
    def deco(rta: Callable) -> Callable:
        heuristic = per_device(rta)

        @functools.wraps(rta)
        def wrapper(ts: Taskset, *args, method: str = "fixed_point", **kw):
            if args:  # tolerate legacy positional use_gpu_prio
                if len(args) > 1:
                    raise TypeError("pass analysis options by keyword")
            if method not in ("fixed_point", "heuristic"):
                # validate even on single-device tasksets (where the two
                # methods coincide) so a typo can't pass unit tests and
                # first surface on a multi-GPU platform
                raise ValueError(f"unknown multi-device method {method!r}")
            if ts.n_devices <= 1:
                return rta(ts, *args, **kw)
            if args:
                kw["use_gpu_prio"] = args[0]
            if method == "heuristic":
                warnings.warn(
                    "constant-charge per-device projection under "
                    "busy-waiting is a heuristic, not a sound bound "
                    "(cross-device busy-wait coupling); use the default "
                    "method='fixed_point'", SoundnessWarning, stacklevel=2)
                return heuristic(ts, **kw)
            # Warm-start seeds are defined against the single-device
            # recurrence; under the joint fixed point the folded occupancy
            # charges shift with GPU priorities, so a seed proved for one
            # assignment is not a lower bound for another.  Drop them
            # (seeds only accelerate — correctness is unaffected).
            kw.pop("seeds", None)
            from .crossfix import cross_fixed_point
            R, _ = cross_fixed_point(ts, rta, occ_kind, **kw)
            return R

        wrapper.occ_kind = occ_kind
        return wrapper
    return deco


# --------------------------------------------------------------------------
# Lemma 1 + Lemma 2: kernel-thread approach (busy-waiting)
# --------------------------------------------------------------------------

def kthread_K(ts: Taskset, ti: Task, R_i: float, R: Dict[str, float],
              use_gpu_prio: bool = False, corrected: bool = True) -> float:
    """Lemma 1: runlist update delay K_i.

    K_i = x_i * (2*eps + sum_{h in hp, eta_h^g>0} ceil((R_i+J_h)/T_h) * 2*eps)

    Paper: x_i = 1 iff tau_i uses the GPU or shares the kernel thread's core.

    ERRATUM (found by property testing the analysis against the simulator,
    see tests/test_soundness.py): the paper's x_i misses a *transitive*
    busy-wait effect: a CPU-only task on a different core than the kernel
    thread is still delayed by runlist updates whenever a same-core
    higher-priority GPU-using task busy-waits through an update-induced GPU
    pause.  With ``corrected=True`` (default), x_i = 1 also when any
    same-core higher-priority task uses the GPU, which restores soundness
    (MORT <= WCRT in all randomized sweeps).  ``corrected=False`` gives the
    paper's verbatim term.
    """
    x_i = 1 if (ti.uses_gpu or ti.cpu == ts.kthread_cpu) else 0
    if corrected and not x_i:
        x_i = 1 if any(h.uses_gpu for h in ts.hpp(ti)) else 0
    if not x_i:
        return 0.0
    eps = ts.epsilon
    total = 2.0 * eps
    hps = [h for h in ts.hp(ti, by_gpu=use_gpu_prio) if h.uses_gpu]
    for h in hps:
        J_h = _jitter(ts, h, "job", R, use_gpu_prio)
        total += ceil_pos(R_i + J_h, h.period) * 2.0 * eps
    return total


@cross_device("kthread")
def kthread_busy_rta(ts: Taskset, use_gpu_prio: bool = False,
                     corrected: bool = True, early_exit: bool = False,
                     only: Optional[str] = None,
                     seeds: Optional[Dict[str, float]] = None
                     ) -> Dict[str, Optional[float]]:
    """Lemma 2: WCRT under the kernel-thread approach.

    R_i = C_i + G_i + K_i
        + sum_{h in hpp(tau_i)}                ceil(R_i/T_h) * (C_h + G_h)
        + sum_{h in hp\\hpp, eta_h^g>0}       ceil((R_i+J_h)/T_h) * (C_h + G_h)

    Same-core preemption is jitter-free (busy-waiting keeps tau_h occupying
    its core for its whole job); remote GPU-using tasks effectively preempt
    through the job-granular runlist reservation (Sec. V-A under-utilization)
    and carry a release jitter J_h.
    """
    def make_f(ti: Task, R: Dict) -> Callable:
        hpp = ts.hpp(ti)
        remote = _gpu_hp_remote(ts, ti, use_gpu_prio)

        def f(R_i: float) -> float:
            v = ti.C + ti.G + kthread_K(ts, ti, R_i, R, use_gpu_prio,
                                        corrected)
            for h in hpp:
                v += ceil_pos(R_i, h.period) * (h.C + h.G)
            for h in remote:
                J_h = _jitter(ts, h, "job", R, use_gpu_prio)
                v += ceil_pos(R_i + J_h, h.period) * (h.C + h.G)
            return v
        return f

    return _rta_loop(ts, make_f, early_exit=early_exit, only=only,
                     r_independent=use_gpu_prio, seeds=seeds)


# --------------------------------------------------------------------------
# Lemma 3: IOCTL-based approach, busy-waiting
# --------------------------------------------------------------------------

def _gstar(t: Task, eps: float) -> float:
    return t.G + 2.0 * eps * t.eta_g


def _gestar(t: Task, eps: float) -> float:
    return t.Ge + 2.0 * eps * t.eta_g


def _gmstar(t: Task, eps: float) -> float:
    return t.Gm + 2.0 * eps * t.eta_g


@cross_device("ioctl")
def ioctl_busy_rta(ts: Taskset, use_gpu_prio: bool = False,
                   corrected: bool = True, early_exit: bool = False,
                   only: Optional[str] = None,
                   seeds: Optional[Dict[str, float]] = None
                   ) -> Dict[str, Optional[float]]:
    """Lemma 3: WCRT under the IOCTL-based approach with busy-waiting.

    R_i = C_i + G_i^* + (eta_i^g + 1) * eps
        + sum_{h in hpp, eta_h^g=0} ceil(R_i/T_h) * C_h
        + sum_{h in hpp, eta_h^g>0} ceil(R_i/T_h) * (C_h + G_h^*)
        + sum_{h in hp\\hpp, eta_h^g>0} ceil((R_i+J_h^g)/T_h) * G_h^{e*}

    ERRATUM (see kthread_K): under busy-waiting, a same-core higher-priority
    GPU-using task occupies the core not only for C_h + G_h^* but also for
    its own runlist-update blocking, bounded by its (eta_h^g + 1)*eps
    budget.  ``corrected=True`` (default) adds that stretch to the same-core
    term; ``corrected=False`` is the paper's verbatim Lemma 3.
    """
    eps = ts.epsilon

    def make_f(ti: Task, R: Dict) -> Callable:
        hpp_cpu = [h for h in ts.hpp(ti) if not h.uses_gpu]
        hpp_gpu = [h for h in ts.hpp(ti) if h.uses_gpu]
        remote = _gpu_hp_remote(ts, ti, use_gpu_prio)

        def f(R_i: float) -> float:
            v = ti.C + _gstar(ti, eps) + (ti.eta_g + 1) * eps
            for h in hpp_cpu:
                v += ceil_pos(R_i, h.period) * h.C
            for h in hpp_gpu:
                stretch = (h.eta_g + 1) * eps if corrected else 0.0
                v += ceil_pos(R_i, h.period) * (h.C + _gstar(h, eps) + stretch)
            for h in remote:
                J = _jitter(ts, h, "gpu", R, use_gpu_prio)
                v += ceil_pos(R_i + J, h.period) * _gestar(h, eps)
            return v
        return f

    return _rta_loop(ts, make_f, early_exit=early_exit, only=only,
                     r_independent=use_gpu_prio, seeds=seeds)


# --------------------------------------------------------------------------
# Lemma 4: IOCTL-based approach, self-suspension
# --------------------------------------------------------------------------

@per_device
def ioctl_suspend_rta(ts: Taskset, use_gpu_prio: bool = False,
                      early_exit: bool = False, only: Optional[str] = None,
                      seeds: Optional[Dict[str, float]] = None
                      ) -> Dict[str, Optional[float]]:
    """Lemma 4: WCRT under the IOCTL-based approach with self-suspension.

    R_i = C_i + G_i^* + (eta_i^g + 1) * eps
        + sum_{h in hpp, eta_h^g=0}             ceil(R_i/T_h) * C_h
        + sum_{h in hpp, eta_h^g>0}             ceil((R_i+J_h^c)/T_h) * (C_h + G_h^{m*})
        + sum_{h in hpp, eta_h^g>0, eta_i^g>0}  ceil((R_i+J_h^g)/T_h) * G_h^e
        + sum_{h in hp\\hpp, eta_h^g>0, eta_i^g>0}
                                                ceil((R_i+J_h^g)/T_h) * G_h^{e*}

    Under self-suspension there are no busy-wait chains, so GPU-side
    interference (the last two terms) applies only to GPU-using tau_i
    (Lemma 4's proof: remote tau_h "interferes with the GPU execution of
    tau_i").
    """
    eps = ts.epsilon

    def make_f(ti: Task, R: Dict) -> Callable:
        hpp_cpu = [h for h in ts.hpp(ti) if not h.uses_gpu]
        hpp_gpu = [h for h in ts.hpp(ti) if h.uses_gpu]
        remote = _gpu_hp_remote(ts, ti, use_gpu_prio)

        def f(R_i: float) -> float:
            v = ti.C + _gstar(ti, eps) + (ti.eta_g + 1) * eps
            for h in hpp_cpu:
                v += ceil_pos(R_i, h.period) * h.C
            for h in hpp_gpu:
                Jc = _jitter(ts, h, "cpu", R, use_gpu_prio)
                v += ceil_pos(R_i + Jc, h.period) * (h.C + _gmstar(h, eps))
                if ti.uses_gpu:
                    Jg = _jitter(ts, h, "gpu", R, use_gpu_prio)
                    v += ceil_pos(R_i + Jg, h.period) * h.Ge
            if ti.uses_gpu:
                for h in remote:
                    Jg = _jitter(ts, h, "gpu", R, use_gpu_prio)
                    v += ceil_pos(R_i + Jg, h.period) * _gestar(h, eps)
            return v
        return f

    return _rta_loop(ts, make_f, early_exit=early_exit, only=only,
                     r_independent=use_gpu_prio, seeds=seeds)


# --------------------------------------------------------------------------
# Schedulability helpers
# --------------------------------------------------------------------------

@functools.lru_cache(maxsize=512)
def supports_kwarg(rta: Callable, kwname: str) -> bool:
    """Whether an RTA callable accepts ``kwname`` (for the optional
    early_exit/only accelerations; external RTAs without them still work)."""
    try:
        import inspect
        return kwname in inspect.signature(rta).parameters
    except (TypeError, ValueError):  # pragma: no cover
        return False


def schedulable(ts: Taskset, rta: Callable[..., Dict[str, Optional[float]]],
                **kw) -> bool:
    if supports_kwarg(rta, "early_exit"):
        kw.setdefault("early_exit", True)
    R = rta(ts, **kw)
    for t in ts.rt_tasks:
        r = R.get(t.name, math.inf)  # absent => early-exited: unschedulable
        if r is None or math.isinf(r) or r > t.deadline + _EPS:
            return False
    return True


# `core/batch.py` resolves scalar RTA callables to its vectorized kinds
# through this tag (the improved variants tag themselves in
# `core/improved.py`).
kthread_busy_rta.batch_kind = "kthread_busy"
ioctl_busy_rta.batch_kind = "ioctl_busy"
ioctl_suspend_rta.batch_kind = "ioctl_suspend"


def schedulable_many(tasksets, rta, backend: str = "batch",
                     **kw) -> list[bool]:
    """Schedulability of a whole batch of tasksets under one analysis.

    ``backend="batch"`` (alias ``"numpy"``) routes RTAs that declare a
    vectorized equivalent (``rta.batch_kind``, or ``rta`` given directly
    as a kind string) to the NumPy backend in `core/batch.py`, which
    runs every task of every taskset in one masked lockstep fixed point
    — decision-identical to the scalar path
    (tests/test_batch_equivalence.py).  ``backend="jax"`` lowers the
    same pack to jit-compiled device kernels (`core/batch_jax.py`) —
    bit-identical decisions again, built for 10k+-taskset sweeps.
    ``backend="scalar"`` (or an untagged external RTA) evaluates
    ``schedulable`` per taskset — the reference implementation."""
    if backend not in ("batch", "numpy", "jax", "scalar"):
        raise ValueError(f"unknown analysis backend {backend!r}")
    tasksets = list(tasksets)
    if backend != "scalar":
        kind = rta if isinstance(rta, str) else getattr(
            rta, "batch_kind", None)
        # scalar-only kwargs: ``early_exit`` is a pure acceleration hint
        # (decisions unchanged — drop it); ``only``/``seeds`` change what
        # the scalar RTA computes, so they force the scalar path rather
        # than raising on an otherwise drop-in call.
        if kind is not None and not ("only" in kw or "seeds" in kw):
            kw.pop("early_exit", None)
            from .batch import batch_schedulable
            return batch_schedulable(
                kind, tasksets,
                backend="jax" if backend == "jax" else "numpy", **kw)
    if isinstance(rta, str):
        raise ValueError(
            f"kind string {rta!r} requires backend='batch'")
    return [schedulable(ts, rta, **kw) for ts in tasksets]
