"""Task model from Sec. IV of the paper.

A task tau_i := (C_i, G_i, T_i, D_i, eta_i^c, eta_i^g) is an alternating
sequence of CPU segments and GPU segments, statically partitioned to one CPU
core, with a unique fixed priority.  Each GPU segment G_{i,j} := (G^m, G^e)
where G^m is miscellaneous CPU work (kernel launch, driver communication) and
G^e is the *pure GPU segment* (no CPU intervention; the task busy-waits or
self-suspends on the CPU during it).

Best-case execution times (the paper's check-marked symbols) are carried as
``*_best`` fields; they default to the WCET (i.e. deterministic execution),
and are used by the reduced-pessimism analysis (Sec. VI-C).

All times are in milliseconds (float).
"""
from __future__ import annotations

import dataclasses
from dataclasses import dataclass
from typing import Optional, Sequence

# The G_{i,j} = (G^m, G^e) segment pair is shared with the simulator and
# runtime layers and lives in core/segments.py (DESIGN.md §6); it is
# re-exported here because the analysis vocabulary historically imported
# it from the task model.
from .segments import GpuSegment as GpuSegment

BEST_EFFORT_PRIORITY = -1_000_000  # below every real-time priority


@dataclass
class Task:
    """A sporadic task with constrained deadline, statically bound to a core.

    ``priority`` follows Linux rt_priority convention: larger = higher.
    ``gpu_priority`` defaults to ``priority`` (Sec. V-C assignment may change
    it).  ``best_effort`` tasks have no real-time priority (they map to
    CFS/default tasks in the paper's evaluation).  ``device`` is the index
    of the accelerator the task's GPU segments execute on (multi-GPU
    platforms, DESIGN.md §4); 0 on the paper's single-GPU platform.
    """

    name: str
    cpu_segments: Sequence[float]  # WCETs C_{i,1..eta^c}
    gpu_segments: Sequence[GpuSegment]
    period: float  # T_i
    deadline: float  # D_i <= T_i
    cpu: int  # statically assigned core id
    priority: int  # unique OS-level priority, larger = higher
    gpu_priority: Optional[int] = None
    best_effort: bool = False
    cpu_segments_best: Optional[Sequence[float]] = None
    device: int = 0  # accelerator index

    def __post_init__(self):
        self.cpu_segments = tuple(float(c) for c in self.cpu_segments)
        self.gpu_segments = tuple(self.gpu_segments)
        if self.cpu_segments_best is None:
            self.cpu_segments_best = self.cpu_segments
        self.cpu_segments_best = tuple(float(c) for c in self.cpu_segments_best)
        if len(self.cpu_segments_best) != len(self.cpu_segments):
            raise ValueError("best-case CPU segment count mismatch")
        if any(b > w + 1e-12 for b, w in zip(self.cpu_segments_best, self.cpu_segments)):
            raise ValueError("best-case CPU segments must not exceed WCET")
        if self.deadline > self.period + 1e-12:
            raise ValueError("constrained deadline required: D_i <= T_i")
        if self.gpu_priority is None:
            self.gpu_priority = self.priority
        if self.best_effort:
            # Best-effort tasks sit below all real-time priorities.
            self.priority = BEST_EFFORT_PRIORITY + self.priority % 1000
            self.gpu_priority = self.priority
        # cache the cumulative quantities: they are invariant after
        # construction (priority mutations don't touch segment times) and
        # sit on the hot path of every fixed-point RTA iteration
        self._C = sum(self.cpu_segments)
        self._C_best = sum(self.cpu_segments_best)
        self._G = sum(g.total for g in self.gpu_segments)
        self._Gm = sum(g.misc for g in self.gpu_segments)
        self._Ge = sum(g.exec for g in self.gpu_segments)
        self._Ge_best = sum(g.exec_best for g in self.gpu_segments)

    # --- cumulative quantities used throughout the analysis -----------------
    @property
    def C(self) -> float:
        return self._C

    @property
    def C_best(self) -> float:
        return self._C_best

    @property
    def G(self) -> float:
        return self._G

    @property
    def Gm(self) -> float:
        return self._Gm

    @property
    def Ge(self) -> float:
        return self._Ge

    @property
    def Ge_best(self) -> float:
        return self._Ge_best

    @property
    def eta_c(self) -> int:
        return len(self.cpu_segments)

    @property
    def eta_g(self) -> int:
        return len(self.gpu_segments)

    @property
    def uses_gpu(self) -> bool:
        return self.eta_g > 0

    @property
    def utilization(self) -> float:
        return (self.C + self.G) / self.period

    @property
    def is_rt(self) -> bool:
        return not self.best_effort

    def with_gpu_priority(self, gp: int) -> "Task":
        t = dataclasses.replace(self)
        t.gpu_priority = gp
        return t


@dataclass
class Taskset:
    """A taskset on a multi-core platform with ``n_devices`` GPUs
    (Sec. IV; the paper's platform has exactly one)."""

    tasks: list[Task]
    n_cpus: int
    epsilon: float = 1.0  # runlist update cost (ms), Table II
    kthread_cpu: int = 0  # core hosting the kernel thread (kthread approach)
    n_devices: int = 1    # number of accelerators (each with its own runlist)

    def __post_init__(self):
        prios = [t.priority for t in self.tasks]
        if len(set(prios)) != len(prios):
            raise ValueError("task priorities must be unique (footnote 4)")
        for t in self.tasks:
            if not (0 <= t.cpu < self.n_cpus):
                raise ValueError(f"{t.name}: cpu {t.cpu} out of range")
            if not (0 <= t.device < self.n_devices):
                raise ValueError(f"{t.name}: device {t.device} out of range")

    def tasks_on_device(self, device: int) -> list[Task]:
        """GPU-using tasks bound to ``device`` (CPU-only tasks are device-
        agnostic and excluded)."""
        return [t for t in self.tasks if t.uses_gpu and t.device == device]

    @property
    def rt_tasks(self) -> list[Task]:
        return [t for t in self.tasks if t.is_rt]

    def by_priority(self) -> list[Task]:
        """Tasks in decreasing priority order."""
        return sorted(self.tasks, key=lambda t: -t.priority)

    def hp(self, ti: Task, by_gpu: bool = False) -> list[Task]:
        """hp(tau_i): all higher-priority tasks in the system.

        With ``by_gpu`` (Sec. VI-B), ordering uses GPU-segment priorities.
        """
        key = (lambda t: t.gpu_priority) if by_gpu else (lambda t: t.priority)
        return [t for t in self.tasks if t is not ti and key(t) > key(ti)]

    def hpp(self, ti: Task) -> list[Task]:
        """hpp(tau_i): higher-priority tasks on the same core as tau_i."""
        return [t for t in self.tasks
                if t is not ti and t.cpu == ti.cpu and t.priority > ti.priority]

    def total_utilization(self) -> float:
        return sum(t.utilization for t in self.tasks)
