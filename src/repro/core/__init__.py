"""Core reproduction of 'Unleashing the Power of Preemptive Priority-based
Scheduling for Real-Time GPU Tasks' (Wang, Liu, Wong, Kim, 2024).

Public API:
  task model      : Task, GpuSegment, Taskset
  segments        : SlicedOp, SegmentedWorkload, SliceProfile,
                    WorkloadProfile, measure_sliced, segment_layout
                    (the one GPU-access-segment abstraction shared by
                    analysis, simulator, and runtime — DESIGN.md §6)
  policy registry : SchedulingPolicy, register_policy, make_policy,
                    available_policies, policy_spec, Alg2State, pick_reserved
  engine          : EventDrivenEngine (heap-based event queue)
  analyses        : kthread_busy_rta, ioctl_busy_rta, ioctl_suspend_rta,
                    ioctl_busy_improved_rta, ioctl_suspend_improved_rta,
                    schedulable, fold_to_device, cross_fixed_point
                    (multi-device busy-wait; SoundnessWarning gates the
                    heuristic escape hatch)
  batch backend   : schedulable_many, batch_rta, batch_schedulable,
                    batch_schedulable_with_assignment, batch_accept_many
                    (NumPy lockstep fixed points, DESIGN.md §5)
  baselines       : mpcp_schedulable, fmlp_schedulable (+ *_rta variants)
  priority assign : assign_gpu_priorities, schedulable_with_assignment
  generation      : GenParams, generate_taskset, uunifast
  simulation      : Simulator, simulate, SimResult
"""
from .analysis import (SoundnessWarning, fold_to_device, ioctl_busy_rta,
                       ioctl_suspend_rta, kthread_busy_rta, kthread_K,
                       schedulable, schedulable_many)
from .audsley import assign_gpu_priorities, schedulable_with_assignment
from .batch import (batch_accept_many, batch_rta, batch_schedulable,
                    batch_schedulable_with_assignment)
from .crossfix import (busy_occupancy, cross_fixed_point, occupancy_vector,
                       uncontended_occupancy)
from .baselines import (fmlp_busy_rta, fmlp_schedulable, fmlp_suspend_rta,
                        mpcp_busy_rta, mpcp_schedulable, mpcp_suspend_rta)
from .engine import EventDrivenEngine
from .improved import ioctl_busy_improved_rta, ioctl_suspend_improved_rta
from .ioctl import IoctlPolicy
from .kthread import KernelThreadPolicy
from .overlap import bx_cpu_segment, bx_gpu_segment, overlap_cg, overlap_gc
from .policy import (Alg2State, BasePolicy, SchedulingPolicy,
                     available_policies, job_gpu_priority, job_is_rt,
                     make_policy, pick_reserved, policy_spec,
                     register_policy)
from .runlist import Platform, Runlist, SyncPolicy, TSG, UnmanagedPolicy
from .segments import (GpuSegment, SegmentedWorkload, SlicedOp,
                       SliceProfile, WorkloadProfile, measure_sliced,
                       n_slices_for, segment_layout)
from .simulator import SimResult, Simulator, build_pieces, simulate
from .task_model import Task, Taskset
from .taskgen import GenParams, generate_taskset, uunifast

__all__ = [
    "Task", "GpuSegment", "Taskset",
    "SlicedOp", "SegmentedWorkload", "SliceProfile", "WorkloadProfile",
    "measure_sliced", "n_slices_for", "segment_layout",
    "SchedulingPolicy", "BasePolicy", "register_policy", "make_policy",
    "available_policies", "policy_spec", "Alg2State", "pick_reserved",
    "job_is_rt", "job_gpu_priority",
    "EventDrivenEngine",
    "kthread_busy_rta", "ioctl_busy_rta", "ioctl_suspend_rta", "kthread_K",
    "ioctl_busy_improved_rta", "ioctl_suspend_improved_rta", "schedulable",
    "schedulable_many", "batch_rta", "batch_schedulable",
    "batch_schedulable_with_assignment", "batch_accept_many",
    "fold_to_device", "SoundnessWarning", "cross_fixed_point",
    "busy_occupancy", "uncontended_occupancy", "occupancy_vector",
    "mpcp_schedulable", "fmlp_schedulable", "mpcp_busy_rta",
    "mpcp_suspend_rta", "fmlp_busy_rta", "fmlp_suspend_rta",
    "assign_gpu_priorities", "schedulable_with_assignment",
    "GenParams", "generate_taskset", "uunifast",
    "Simulator", "simulate", "SimResult", "build_pieces",
    "IoctlPolicy", "KernelThreadPolicy", "SyncPolicy", "UnmanagedPolicy",
    "Runlist", "TSG", "Platform",
    "bx_gpu_segment", "bx_cpu_segment", "overlap_cg", "overlap_gc",
]
