"""Discrete-event simulator for CPU+GPU task scheduling (Secs. II, V, VII).

Simulates a partitioned fixed-priority multi-core + one GPU platform running
a Taskset under one of the arbitration policies:

  * ``UnmanagedPolicy``    — default driver, time-sliced round-robin (Sec. II)
  * ``SyncPolicy``         — MPCP/FMLP+-style lock-based access (Sec. III)
  * ``KernelThreadPolicy`` — Algorithm 1 (busy-waiting only)
  * ``IoctlPolicy``        — Algorithm 2 (busy-waiting or self-suspension)

Execution semantics:
  * Jobs are alternating pieces: cpu -> [upd] gm ge [upd] -> cpu ...
    (``upd`` = epsilon-long runlist update, IOCTL policy only).
  * ``cpu``/``gm``/``upd`` pieces need the job's core; ``ge`` needs the GPU.
  * Busy-wait mode: the job occupies its core (at its priority) while its
    GPU work is pending/running; self-suspension releases the core.
  * ``upd`` pieces are non-preemptive kernel sections under a global
    rt_mutex and pause the GPU while in flight.
  * A task is a process: jobs of one task execute in order; a released job
    is dormant until its predecessor completes (its response time still
    counts from release).

The simulator is the ground truth used to validate that analytic WCRTs
bound the maximum observed response times (MORT <= WCRT, Table IV).
"""
from __future__ import annotations

import itertools
import math
import random
from dataclasses import dataclass, field
from typing import Dict, List, Optional

from .ioctl import IoctlPolicy
from .kthread import KernelThreadPolicy
from .runlist import BasePolicy, SyncPolicy, UnmanagedPolicy
from .task_model import Task, Taskset

_TIME_EPS = 1e-9


@dataclass
class Piece:
    kind: str          # cpu | gm | ge | upd
    duration: float    # actual execution requirement (sampled)
    remaining: float = None
    seg: int = -1      # gpu segment index
    which: str = ""    # upd: "begin" | "end"

    def __post_init__(self):
        if self.remaining is None:
            self.remaining = self.duration


class Job:
    _uid = itertools.count()

    def __init__(self, task: Task, release: float, pieces: List[Piece]):
        self.uid = next(Job._uid)
        self.task = task
        self.release = release
        self.abs_deadline = release + task.deadline
        self.pieces = pieces
        self.idx = 0
        self.active = False       # predecessor finished; competing for cores
        self.completion: Optional[float] = None
        # policy flags
        self.lock_wait = False    # waiting on a lock (sync / rt_mutex)
        self.gpu_pending = False  # in task_pending (ioctl)
        self.upd_started = False  # non-preemptive upd piece in flight

    # ------------------------------------------------------------------
    @property
    def done(self) -> bool:
        return self.idx >= len(self.pieces)

    def current_piece(self) -> Optional[Piece]:
        return None if self.done else self.pieces[self.idx]

    def current_kind(self) -> str:
        p = self.current_piece()
        return p.kind if p else "done"

    def wants_gpu(self) -> bool:
        return self.active and not self.done and self.current_kind() == "ge"

    def cpu_demand(self, mode: str, policy: BasePolicy) -> bool:
        """Does this job occupy (or want) its core right now?"""
        if not self.active or self.done:
            return False
        if policy.cpu_blocked(self):
            return False
        k = self.current_kind()
        if k in ("cpu", "gm"):
            return not self.lock_wait or mode == "busy"
        if k == "upd":
            return True  # ready to enter (or spinning on) the IOCTL
        if k == "ge":
            return mode == "busy"
        if k == "upde":
            return mode == "busy"  # busy-wait rejoins after driver release
        return False

    def cpu_progresses(self) -> bool:
        """Whether winning the core advances the current piece."""
        k = self.current_kind()
        if k in ("cpu", "gm"):
            return not self.lock_wait
        if k == "upd":
            return self.upd_started  # inside the kernel section
        return False  # ge/busy-wait/upde: occupancy only


@dataclass
class SimResult:
    response_times: Dict[str, List[float]]
    mort: Dict[str, float]
    deadline_misses: Dict[str, int]
    n_jobs: Dict[str, int]
    trace: List[tuple]

    def max_response(self, name: str) -> float:
        rts = self.response_times.get(name, [])
        return max(rts) if rts else 0.0


def build_pieces(task: Task, with_ioctl: bool, epsilon: float,
                 frac: float = 1.0) -> List[Piece]:
    """Alternate CPU and GPU segments; sample actual durations at
    best + frac * (wcet - best)."""
    def dur(w, b):
        return b + frac * (w - b)

    pieces: List[Piece] = []
    nc, ng = task.eta_c, task.eta_g
    for j in range(max(nc, ng)):
        if j < nc:
            pieces.append(Piece("cpu", dur(task.cpu_segments[j],
                                           task.cpu_segments_best[j])))
        if j < ng:
            g = task.gpu_segments[j]
            # IOCTL: the begin() update admits the TSG when *pure* GPU work
            # starts: G^m (async launch/driver work) is CPU-side and
            # co-schedules with other tasks' GPU execution, matching Lemma 3
            # where remote interference is G_h^{e*} (not G_h^m + G_h^{e*}).
            # The end() update runs in driver completion context ("upde"):
            # it needs no CPU core, so the runlist is released promptly
            # after the kernel finishes (the promptness assumption behind
            # the G^{e*} terms) without blocking CPU-only tasks.
            pieces.append(Piece("gm", dur(g.misc, g.misc_best), seg=j))
            if with_ioctl:
                pieces.append(Piece("upd", epsilon, seg=j, which="begin"))
            pieces.append(Piece("ge", dur(g.exec, g.exec_best), seg=j))
            if with_ioctl:
                pieces.append(Piece("upde", epsilon, seg=j, which="end"))
    return pieces


class Simulator:
    def __init__(self, ts: Taskset, policy: BasePolicy, mode: str = "busy",
                 horizon: float = 3000.0, exec_frac: float = 1.0,
                 offsets: Optional[Dict[str, float]] = None,
                 seed: int = 0, trace: bool = False):
        if isinstance(policy, KernelThreadPolicy) and mode != "busy":
            raise ValueError("kernel-thread approach requires busy-waiting "
                             "(self-suspension breaks state detection, Sec. V-A)")
        self.ts = ts
        self.policy = policy
        self.mode = mode
        self.horizon = horizon
        self.exec_frac = exec_frac
        self.offsets = offsets or {}
        self.rng = random.Random(seed)
        self.keep_trace = trace
        policy.attach(self)

        self.t = 0.0
        self.jobs: List[Job] = []          # in-flight (released, not done)
        self.queues: Dict[str, List[Job]] = {t.name: [] for t in ts.tasks}
        self.next_release: Dict[str, float] = {
            t.name: self.offsets.get(t.name, 0.0) for t in ts.tasks}
        self.result = SimResult({t.name: [] for t in ts.tasks},
                                {}, {t.name: 0 for t in ts.tasks},
                                {t.name: 0 for t in ts.tasks}, [])

    # ------------------------------------------------------------------
    def active_jobs(self) -> List[Job]:
        return [j for j in self.jobs if j.active and not j.done]

    def _trace(self, *ev) -> None:
        if self.keep_trace:
            self.result.trace.append((round(self.t, 6),) + ev)

    # ------------------------------------------------------------------
    def _release(self, task: Task) -> None:
        pieces = build_pieces(task, self.policy.needs_ioctl_pieces,
                              self.ts.epsilon, self.exec_frac)
        job = Job(task, self.t, pieces)
        self.jobs.append(job)
        self.queues[task.name].append(job)
        self.result.n_jobs[task.name] += 1
        self._trace("release", task.name)
        if self.queues[task.name][0] is job:
            self._activate(job)

    def _activate(self, job: Job) -> None:
        job.active = True
        self._trace("activate", job.task.name)
        self.policy.on_job_release(job)
        self._enter_piece(job)

    def _enter_piece(self, job: Job) -> None:
        """Hooks on entering the current piece (may be zero-length)."""
        p = job.current_piece()
        if p is None:
            self._complete_job(job)
            return
        if p.kind == "gm" and not self.policy.needs_ioctl_pieces:
            # segment boundary for lock-based / kthread policies
            self.policy.on_segment_begin(job)
        if p.kind not in ("upd", "upde") and p.remaining <= _TIME_EPS:
            self._complete_piece(job)

    def _complete_piece(self, job: Job) -> None:
        p = job.current_piece()
        self._trace("piece_done", job.task.name, p.kind, p.seg)
        job.idx += 1
        if p.kind in ("upd", "upde"):
            job.upd_started = False
            self.policy.on_update_done(job, p.which)
        elif p.kind == "ge":
            self.policy.on_ge_complete(job)
        self._enter_piece(job)


    def _complete_job(self, job: Job) -> None:
        job.completion = self.t
        rt = self.t - job.release
        res = self.result
        res.response_times[job.task.name].append(rt)
        if self.t > job.abs_deadline + _TIME_EPS and job.task.is_rt:
            res.deadline_misses[job.task.name] += 1
        self._trace("complete", job.task.name, round(rt, 6))
        self.jobs.remove(job)
        q = self.queues[job.task.name]
        q.pop(0)
        self.policy.on_job_complete(job)
        if q:  # successor job was waiting for the process to free up
            self._activate(q[0])

    # ------------------------------------------------------------------
    def _core_winners(self) -> Dict[int, Optional[Job]]:
        """Highest-priority demanding job per core.  A started update piece
        is a non-preemptive kernel section and keeps its core outright."""
        winners: Dict[int, Optional[Job]] = {c: None for c in range(self.ts.n_cpus)}
        for j in self.active_jobs():
            if j.current_kind() == "upd" and j.upd_started:
                winners[j.task.cpu] = j
        for c in range(self.ts.n_cpus):
            if winners[c] is not None:
                continue
            cands = [j for j in self.active_jobs()
                     if j.task.cpu == c and j.cpu_demand(self.mode, self.policy)]
            if cands:
                winners[c] = max(cands,
                                 key=lambda j: self.policy.effective_priority(j))
        # the kernel thread's update preempts everything on its core
        if isinstance(self.policy, KernelThreadPolicy) \
                and self.policy.kthread_cpu_busy() \
                and self.ts.kthread_cpu < self.ts.n_cpus:
            winners[self.ts.kthread_cpu] = None  # core consumed by kthread
        return winners

    def _allocate(self) -> Dict[int, Optional[Job]]:
        """Compute core winners, letting due runlist updates acquire the
        driver mutex: completion-side (driver-context) updates first, then
        winners standing at a begin() boundary — cascading through
        zero-cost (pending-only) updates."""
        for _ in range(16 * (len(self.jobs) + 2)):
            winners = self._core_winners()
            entered = False
            # driver-context end updates need no core and go first
            ends = sorted([j for j in self.active_jobs()
                           if j.current_kind() == "upde" and not j.upd_started],
                          key=lambda j: -j.task.priority)
            begins = sorted(
                [j for j in winners.values() if j is not None
                 and j.current_kind() == "upd" and not j.upd_started],
                key=lambda j: -self.policy.effective_priority(j))
            for j in ends + begins:
                if self.policy.try_acquire(j):
                    j.upd_started = True
                    piece = j.current_piece()
                    self.policy.begin_update(j, piece)
                    entered = True
                    if piece.remaining <= _TIME_EPS:
                        self._complete_piece(j)
                    break  # re-derive state after a change
            if not entered:
                return winners
        raise RuntimeError("allocation did not settle")

    def run(self) -> SimResult:
        guard = 0
        max_events = int(5e6)
        while self.t < self.horizon - _TIME_EPS:
            guard += 1
            if guard > max_events:
                raise RuntimeError("simulator event budget exceeded")

            # 1. releases due now
            for task in self.ts.tasks:
                while self.next_release[task.name] <= self.t + _TIME_EPS:
                    self.next_release[task.name] += task.period
                    self._release(task)

            # 2. allocation (lets due IOCTL updates enter the kernel section)
            winners = self._allocate()
            self.policy.notify_winners(winners)
            if isinstance(self.policy, KernelThreadPolicy):
                winners = self._core_winners()  # a rewrite may block a core
            owner = self.policy.gpu_owner()

            # driver-context end updates progress in wall time once started
            driver_upds = [j for j in self.active_jobs()
                           if j.current_kind() == "upde" and j.upd_started]

            # 3. next event horizon
            dt = self.horizon - self.t
            for task in self.ts.tasks:
                dt = min(dt, self.next_release[task.name] - self.t)
            for c, j in winners.items():
                if j is not None and j.cpu_progresses():
                    dt = min(dt, j.current_piece().remaining)
            if owner is not None and owner.wants_gpu():
                dt = min(dt, owner.current_piece().remaining)
            for j in driver_upds:
                dt = min(dt, j.current_piece().remaining)
            dt = min(dt, self.policy.next_gpu_event())
            if dt <= _TIME_EPS:
                dt = _TIME_EPS  # numerical floor; completions fire below

            # 4. advance
            for c, j in winners.items():
                if j is not None and j.cpu_progresses():
                    j.current_piece().remaining -= dt
            if owner is not None and owner.wants_gpu():
                owner.current_piece().remaining -= dt
            for j in driver_upds:
                j.current_piece().remaining -= dt
            self.policy.gpu_rr_advance(dt)
            self.t += dt

            # 5. fire completions (cascades handled inside)
            for j in list(self.jobs):
                p = j.current_piece()
                if p is None or not j.active:
                    continue
                if p.remaining <= _TIME_EPS:
                    progressed = (p.kind == "ge" or
                                  (p.kind == "upde" and j.upd_started) or
                                  j.cpu_progresses())
                    if progressed:
                        self._complete_piece(j)

        for name, rts in self.result.response_times.items():
            self.result.mort[name] = max(rts) if rts else 0.0
        return self.result


# --------------------------------------------------------------------------
# convenience front-ends
# --------------------------------------------------------------------------

def simulate(ts: Taskset, approach: str, mode: str = "busy",
             horizon: float = 3000.0, **kw) -> SimResult:
    """approach in {unmanaged, sync_priority, sync_fifo, kthread, ioctl}."""
    if approach == "unmanaged":
        policy: BasePolicy = UnmanagedPolicy()
    elif approach == "sync_priority":
        policy = SyncPolicy(order="priority")
    elif approach == "sync_fifo":
        policy = SyncPolicy(order="fifo")
    elif approach == "kthread":
        policy = KernelThreadPolicy(poll_interval=kw.pop("poll_interval", 0.0))
        mode = "busy"
    elif approach == "ioctl":
        policy = IoctlPolicy()
    else:
        raise ValueError(approach)
    return Simulator(ts, policy, mode=mode, horizon=horizon, **kw).run()
