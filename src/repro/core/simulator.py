"""Discrete-event simulator for CPU+GPU task scheduling (Secs. II, V, VII).

Simulates a partitioned fixed-priority multi-core platform with one or
more GPUs running a Taskset under one of the registered arbitration
policies (see `core/policy.py`):

  * ``unmanaged``     — default driver, time-sliced round-robin (Sec. II)
  * ``sync_priority`` / ``sync_fifo`` — MPCP/FMLP+-style lock-based access
  * ``kthread``       — Algorithm 1 (busy-waiting only)
  * ``ioctl``         — Algorithm 2 (busy-waiting or self-suspension)

Execution semantics:
  * Jobs are alternating pieces: cpu -> [upd] gm ge [upd] -> cpu ...
    (``upd`` = epsilon-long runlist update, IOCTL policy only).
  * ``cpu``/``gm``/``upd`` pieces need the job's core; ``ge`` needs the
    task's device.
  * Busy-wait mode: the job occupies its core (at its priority) while its
    GPU work is pending/running; self-suspension releases the core.
  * ``upd`` pieces are non-preemptive kernel sections under a global
    rt_mutex and pause the GPU while in flight.
  * A task is a process: jobs of one task execute in order; a released job
    is dormant until its predecessor completes (its response time still
    counts from release).

Time advancement lives in `core/engine.py` (heap-based event queue); this
module owns the job lifecycle and result bookkeeping.  On a multi-device
Taskset the simulator instantiates one policy per device and routes
job-scoped hooks by ``task.device`` (DESIGN.md §4).

The simulator is the ground truth used to validate that analytic WCRTs
bound the maximum observed response times (MORT <= WCRT, Table IV).
"""
from __future__ import annotations

import itertools
import random
from dataclasses import dataclass
from typing import Dict, List, Optional, Sequence, Union

from .engine import EventDrivenEngine
from .policy import SchedulingPolicy, make_policy
from .segments import segment_layout
from .task_model import Task, Taskset

_TIME_EPS = 1e-9


@dataclass
class Piece:
    kind: str          # cpu | gm | ge | upd
    duration: float    # actual execution requirement (sampled)
    remaining: float = None
    seg: int = -1      # gpu segment index
    which: str = ""    # upd: "begin" | "end"

    def __post_init__(self):
        if self.remaining is None:
            self.remaining = self.duration


class Job:
    _uid = itertools.count()

    def __init__(self, task: Task, release: float, pieces: List[Piece]):
        self.uid = next(Job._uid)
        self.task = task
        self.release = release
        self.abs_deadline = release + task.deadline
        self.pieces = pieces
        self.idx = 0
        self.active = False       # predecessor finished; competing for cores
        self.completion: Optional[float] = None
        # policy flags
        self.lock_wait = False    # waiting on a lock (sync / rt_mutex)
        self.gpu_pending = False  # in task_pending (ioctl)
        self.upd_started = False  # non-preemptive upd piece in flight

    # ------------------------------------------------------------------
    @property
    def done(self) -> bool:
        return self.idx >= len(self.pieces)

    def current_piece(self) -> Optional[Piece]:
        return None if self.done else self.pieces[self.idx]

    def current_kind(self) -> str:
        p = self.current_piece()
        return p.kind if p else "done"

    def wants_gpu(self) -> bool:
        return self.active and not self.done and self.current_kind() == "ge"

    def cpu_demand(self, mode: str, policy: SchedulingPolicy) -> bool:
        """Does this job occupy (or want) its core right now?"""
        if not self.active or self.done:
            return False
        if policy.cpu_blocked(self):
            return False
        k = self.current_kind()
        if k in ("cpu", "gm"):
            return not self.lock_wait or mode == "busy"
        if k == "upd":
            return True  # ready to enter (or spinning on) the IOCTL
        if k == "ge":
            return mode == "busy"
        if k == "upde":
            return mode == "busy"  # busy-wait rejoins after driver release
        return False

    def cpu_progresses(self) -> bool:
        """Whether winning the core advances the current piece."""
        k = self.current_kind()
        if k in ("cpu", "gm"):
            return not self.lock_wait
        if k == "upd":
            return self.upd_started  # inside the kernel section
        return False  # ge/busy-wait/upde: occupancy only


@dataclass
class SimResult:
    response_times: Dict[str, List[float]]
    mort: Dict[str, float]
    deadline_misses: Dict[str, int]
    n_jobs: Dict[str, int]
    trace: List[tuple]

    def max_response(self, name: str) -> float:
        rts = self.response_times.get(name, [])
        return max(rts) if rts else 0.0


def build_pieces(task: Task, with_ioctl: bool, epsilon: float,
                 frac: Optional[float] = 1.0,
                 rng: Optional[random.Random] = None) -> List[Piece]:
    """Alternate CPU and GPU segments; sample actual durations at
    best + frac * (wcet - best).  With ``frac=None`` each piece draws its
    own fraction uniformly from ``rng`` (randomized execution times)."""
    def dur(w, b):
        f = rng.random() if frac is None else frac
        return b + f * (w - b)

    if frac is None and rng is None:
        raise ValueError("frac=None (randomized durations) requires an rng")

    # The piece *structure* (where the segment boundaries and the IOCTL
    # runlist updates sit) is the shared definition in core/segments.py;
    # this function only samples durations onto it.  IOCTL placement
    # rationale: the begin() update admits the TSG when *pure* GPU work
    # starts — G^m (async launch/driver work) is CPU-side and co-schedules
    # with other tasks' GPU execution, matching Lemma 3 where remote
    # interference is G_h^{e*} (not G_h^m + G_h^{e*}).  The end() update
    # runs in driver completion context ("upde"): it needs no CPU core, so
    # the runlist is released promptly after the kernel finishes (the
    # promptness assumption behind the G^{e*} terms) without blocking
    # CPU-only tasks.
    pieces: List[Piece] = []
    for kind, j in segment_layout(task, with_ioctl):
        if kind == "cpu":
            pieces.append(Piece("cpu", dur(task.cpu_segments[j],
                                           task.cpu_segments_best[j])))
        elif kind == "gm":
            g = task.gpu_segments[j]
            pieces.append(Piece("gm", dur(g.misc, g.misc_best), seg=j))
        elif kind == "ge":
            g = task.gpu_segments[j]
            pieces.append(Piece("ge", dur(g.exec, g.exec_best), seg=j))
        elif kind == "upd":
            pieces.append(Piece("upd", epsilon, seg=j, which="begin"))
        else:  # upde
            pieces.append(Piece("upde", epsilon, seg=j, which="end"))
    return pieces


PolicyArg = Union[str, SchedulingPolicy, Sequence[SchedulingPolicy]]


class Simulator:
    """One simulation run.

    ``policy`` may be a registry name (one instance is built per device),
    a single policy instance (single-device tasksets only), or an explicit
    per-device sequence of instances.

    ``exec_frac`` selects actual execution times between best-case and
    WCET: a float places every piece at ``best + frac*(wcet-best)``;
    ``None`` samples a fresh fraction per piece from ``random.Random(seed)``
    — the only consumer of ``seed`` (deterministic runs ignore it).
    """

    def __init__(self, ts: Taskset, policy: PolicyArg, mode: str = "busy",
                 horizon: float = 3000.0,
                 exec_frac: Optional[float] = 1.0,
                 offsets: Optional[Dict[str, float]] = None,
                 seed: int = 0, trace: bool = False):
        if isinstance(policy, str):
            policies = [make_policy(policy) for _ in range(ts.n_devices)]
        elif isinstance(policy, SchedulingPolicy):
            if ts.n_devices > 1:
                raise ValueError(
                    "multi-device tasksets need one policy per device; "
                    "pass a registry name or a sequence of instances")
            policies = [policy]
        else:
            policies = list(policy)
            if len(policies) != ts.n_devices:
                raise ValueError(
                    f"{len(policies)} policies for {ts.n_devices} devices")
        if any(p.requires_busy_wait for p in policies) and mode != "busy":
            raise ValueError("kernel-thread approach requires busy-waiting "
                             "(self-suspension breaks state detection, Sec. V-A)")
        self.ts = ts
        self.policies = policies
        self.policy = policies[0]  # seed-API compatibility
        self.mode = mode
        self.horizon = horizon
        self.exec_frac = exec_frac
        self.offsets = offsets or {}
        self.rng = random.Random(seed)
        self.keep_trace = trace
        for d, p in enumerate(policies):
            p.device = d
            p.attach(self)

        self.t = 0.0
        self.jobs: List[Job] = []          # in-flight (released, not done)
        self.queues: Dict[str, List[Job]] = {t.name: [] for t in ts.tasks}
        self.next_release: Dict[str, float] = {
            t.name: self.offsets.get(t.name, 0.0) for t in ts.tasks}
        self.result = SimResult({t.name: [] for t in ts.tasks},
                                {}, {t.name: 0 for t in ts.tasks},
                                {t.name: 0 for t in ts.tasks}, [])
        self.engine = EventDrivenEngine(self)

    # ------------------------------------------------------------------
    def policy_for(self, job: Job) -> SchedulingPolicy:
        return self.policies[job.task.device]

    def active_jobs(self) -> List[Job]:
        return [j for j in self.jobs if j.active and not j.done]

    def _trace(self, *ev) -> None:
        if self.keep_trace:
            self.result.trace.append((round(self.t, 6),) + ev)

    # ------------------------------------------------------------------
    def _release(self, task: Task) -> None:
        policy = self.policies[task.device]
        pieces = build_pieces(task, policy.needs_ioctl_pieces,
                              self.ts.epsilon, self.exec_frac,
                              rng=self.rng)
        job = Job(task, self.t, pieces)
        self.jobs.append(job)
        self.queues[task.name].append(job)
        self.result.n_jobs[task.name] += 1
        self._trace("release", task.name)
        if self.queues[task.name][0] is job:
            self._activate(job)

    def _activate(self, job: Job) -> None:
        job.active = True
        self._trace("activate", job.task.name)
        self.policy_for(job).on_job_release(job)
        self._enter_piece(job)

    def _enter_piece(self, job: Job) -> None:
        """Hooks on entering the current piece (may be zero-length)."""
        p = job.current_piece()
        if p is None:
            self._complete_job(job)
            return
        if p.kind == "gm" and not self.policy_for(job).needs_ioctl_pieces:
            # segment boundary for lock-based / kthread policies
            self.policy_for(job).on_segment_begin(job)
        if p.kind not in ("upd", "upde") and p.remaining <= _TIME_EPS:
            self._complete_piece(job)

    def _complete_piece(self, job: Job) -> None:
        p = job.current_piece()
        self._trace("piece_done", job.task.name, p.kind, p.seg)
        job.idx += 1
        if p.kind in ("upd", "upde"):
            job.upd_started = False
            self.policy_for(job).on_update_done(job, p.which)
        elif p.kind == "ge":
            self.policy_for(job).on_ge_complete(job)
        self._enter_piece(job)

    def _complete_job(self, job: Job) -> None:
        job.completion = self.t
        rt = self.t - job.release
        res = self.result
        res.response_times[job.task.name].append(rt)
        if self.t > job.abs_deadline + _TIME_EPS and job.task.is_rt:
            res.deadline_misses[job.task.name] += 1
        self._trace("complete", job.task.name, round(rt, 6))
        self.jobs.remove(job)
        q = self.queues[job.task.name]
        q.pop(0)
        self.policy_for(job).on_job_complete(job)
        if q:  # successor job was waiting for the process to free up
            self._activate(q[0])

    # ------------------------------------------------------------------
    def run(self) -> SimResult:
        self.engine.run()
        for name, rts in self.result.response_times.items():
            self.result.mort[name] = max(rts) if rts else 0.0
        return self.result


# --------------------------------------------------------------------------
# convenience front-ends
# --------------------------------------------------------------------------

def simulate(ts: Taskset, approach: str, mode: str = "busy",
             horizon: float = 3000.0,
             policy_kw: Optional[dict] = None, **kw) -> SimResult:
    """Run ``ts`` under a registered approach.

    ``approach`` is any name in `core.policy.available_policies()`
    (seed set: unmanaged, sync_priority, sync_fifo, kthread, ioctl).
    ``policy_kw`` is forwarded to the policy factory; the historical
    ``poll_interval=`` keyword still reaches the kthread factory."""
    policy_kw = dict(policy_kw or {})
    if approach == "kthread" and "poll_interval" in kw:
        policy_kw.setdefault("poll_interval", kw.pop("poll_interval"))
    policies = [make_policy(approach, **policy_kw)
                for _ in range(ts.n_devices)]
    if any(p.requires_busy_wait for p in policies):
        mode = "busy"
    return Simulator(ts, policies, mode=mode, horizon=horizon, **kw).run()
