"""Random taskset generation (Sec. VII-A, Table II).

Parameters (defaults reproduce Table II):
  * n_cpus = 4
  * tasks per CPU ~ U[3, 6]
  * ratio of GPU-using tasks ~ U[40, 60]%
  * utilization per CPU ~ U[0.4, 0.6], split per-task with UUniFast
  * task period ~ U[30, 500] ms, deadline = period (constrained)
  * GPU segments per GPU-using task ~ U{1..3}
  * G_i/C_i ratio ~ U[0.2, 2]
  * G^m/G ratio ~ U[0.1, 0.3]
  * epsilon = 1 ms
Priorities are assigned Rate-Monotonic (shorter period -> higher priority),
unique via index tie-breaking (footnote 4).
"""
from __future__ import annotations

import dataclasses
import random
from typing import Optional

from .segments import GpuSegment
from .task_model import Task, Taskset


@dataclasses.dataclass
class GenParams:
    n_cpus: int = 4
    tasks_per_cpu: tuple[int, int] = (3, 6)
    gpu_task_ratio: tuple[float, float] = (0.4, 0.6)
    util_per_cpu: tuple[float, float] = (0.4, 0.6)
    period_ms: tuple[float, float] = (30.0, 500.0)
    gpu_segments: tuple[int, int] = (1, 3)
    g_to_c_ratio: tuple[float, float] = (0.2, 2.0)
    gm_to_g_ratio: tuple[float, float] = (0.1, 0.3)
    epsilon: float = 1.0
    best_effort_ratio: float = 0.0   # Fig. 12 sweep
    bcet_ratio: float = 1.0          # best-case = ratio * WCET
    n_tasks_total: Optional[int] = None  # Fig. 7 sweep (overrides per-cpu)
    n_devices: int = 1               # multi-GPU platform (DESIGN.md §4)


def uunifast(rng: random.Random, n: int, total_util: float) -> list[float]:
    """UUniFast [Bini & Buttazzo 2005]."""
    utils = []
    sum_u = total_util
    for i in range(1, n):
        next_sum = sum_u * rng.random() ** (1.0 / (n - i))
        utils.append(sum_u - next_sum)
        sum_u = next_sum
    utils.append(sum_u)
    return utils


def _split(rng: random.Random, total: float, n: int) -> list[float]:
    """Split `total` into n random positive parts (uniform simplex)."""
    if n == 1:
        return [total]
    cuts = sorted(rng.random() for _ in range(n - 1))
    bounds = [0.0] + cuts + [1.0]
    return [(bounds[k + 1] - bounds[k]) * total for k in range(n)]


def generate_taskset(seed: int, p: GenParams = GenParams()) -> Taskset:
    rng = random.Random(seed)
    # -- how many tasks on each CPU ------------------------------------------
    if p.n_tasks_total is not None:
        counts = [0] * p.n_cpus
        for i in range(p.n_tasks_total):
            counts[i % p.n_cpus] += 1
    else:
        counts = [rng.randint(*p.tasks_per_cpu) for _ in range(p.n_cpus)]

    specs = []  # (cpu, util)
    for cpu, cnt in enumerate(counts):
        if cnt == 0:
            continue
        u_cpu = rng.uniform(*p.util_per_cpu)
        for u in uunifast(rng, cnt, u_cpu):
            specs.append((cpu, u))

    n = len(specs)
    n_gpu = round(rng.uniform(*p.gpu_task_ratio) * n)
    gpu_idx = set(rng.sample(range(n), min(n_gpu, n)))
    n_be = round(p.best_effort_ratio * n)
    be_idx = set(rng.sample(range(n), min(n_be, n)))

    # Sample every task's parameters first (the rng stream is the golden
    # contract — construction order must not disturb it), assign Rate
    # Monotonic priorities from the sampled periods, then construct each
    # Task exactly once.  The historical construct-then-rebuild pass ran
    # __post_init__ (tuple conversion + cached sums) twice per task and
    # showed up in sweep profiles.
    drafts = []
    n_gpu_seen = 0  # device assignment: GPU tasks round-robin over devices
    for i, (cpu, util) in enumerate(specs):
        period = rng.uniform(*p.period_ms)
        budget = max(util * period, 1e-3)
        uses_gpu = i in gpu_idx
        # deterministic round-robin keeps the rng stream identical to the
        # single-device generator (golden tasksets are unchanged)
        device = n_gpu_seen % p.n_devices if uses_gpu else 0
        if uses_gpu:
            n_gpu_seen += 1
        if uses_gpu:
            g_ratio = rng.uniform(*p.g_to_c_ratio)
            C_total = budget / (1.0 + g_ratio)
            G_total = budget - C_total
            n_g = rng.randint(*p.gpu_segments)
            n_c = n_g + 1
            g_parts = _split(rng, G_total, n_g)
            gsegs = []
            for g in g_parts:
                m_frac = rng.uniform(*p.gm_to_g_ratio)
                gsegs.append(GpuSegment(
                    misc=g * m_frac, exec=g * (1.0 - m_frac),
                    misc_best=g * m_frac * p.bcet_ratio,
                    exec_best=g * (1.0 - m_frac) * p.bcet_ratio))
        else:
            C_total = budget
            n_c = 1
            gsegs = []
        c_parts = _split(rng, C_total, n_c)
        drafts.append((period, cpu, device, c_parts, gsegs))

    # -- Rate Monotonic priorities, unique -----------------------------------
    order = sorted(range(n), key=lambda k: (drafts[k][0], k))
    prio = [0] * n
    for rank, k in enumerate(order):
        prio[k] = (n - rank) * 10  # larger = higher priority

    tasks = []
    for i, (period, cpu, device, c_parts, gsegs) in enumerate(drafts):
        tasks.append(Task(
            name=f"tau{i}",
            cpu_segments=c_parts,
            cpu_segments_best=[c * p.bcet_ratio for c in c_parts],
            gpu_segments=gsegs,
            period=period, deadline=period, cpu=cpu,
            priority=prio[i],
            best_effort=(i in be_idx),
            device=device,
        ))

    return Taskset(tasks=tasks, n_cpus=p.n_cpus, epsilon=p.epsilon,
                   n_devices=p.n_devices)
