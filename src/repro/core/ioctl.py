"""IOCTL-based approach (Sec. V-B, Algorithm 2).

User programs bracket each GPU segment with cudaStreamBegin()/cudaStreamEnd()
macros; each call issues an IOCTL that runs the runlist-update procedure in
the driver under an rt_mutex.  In the simulator, these appear as explicit
``upd`` pieces in the job's piece sequence (cost epsilon each, executed on
the caller's core, non-preemptive — kernel path holding the driver lock —
and pausing the GPU while the runlist is rewritten).

Algorithm 2 state lives in the shared ``policy.Alg2State`` (two disjoint
lists, ``task_running`` and ``task_pending``) — the very same state machine
the runtime executor's notify mode drives, so the simulated admission and
the live admission cannot diverge (DESIGN.md §2).  One safety deviation
from the paper is noted in Alg2State: on removal with no pending real-time
task we take the union of the lists instead of overwriting task_running.

Both busy-waiting and self-suspension are supported during pure GPU
execution and while waiting for admission (Table I / Sec. VI).
"""
from __future__ import annotations

from typing import TYPE_CHECKING, Optional

from .analysis import ioctl_busy_rta, ioctl_suspend_rta
from .policy import (Alg2State, SchedulingPolicy, job_gpu_priority,
                     job_is_rt, register_policy)
from .runlist import Runlist, TSG

if TYPE_CHECKING:  # pragma: no cover
    from .simulator import Job


class IoctlPolicy(SchedulingPolicy):
    name = "ioctl"
    needs_ioctl_pieces = True
    needs_segment_hooks = True

    def __init__(self, rr_slice: float = 2.0):
        self.alg2 = Alg2State(on_enter_running=self._enter_running,
                              on_leave_running=self._leave_running)
        self.lock_holder: Optional["Job"] = None
        self.rr = Runlist(rr_slice)        # RR among best-effort members
        self._tsgs: dict = {}

    # task_running / task_pending views (kept for API compatibility)
    @property
    def running(self) -> list:
        return self.alg2.running

    @property
    def pending(self) -> list:
        return self.alg2.pending

    # ---- rt_mutex ----------------------------------------------------------
    # The update is a kernel section: a caller must win its core (ordinary
    # priority scheduling) to *enter* the IOCTL; once entered it acquires
    # the mutex and runs non-preemptively for at most epsilon.  Contending
    # callers therefore wait at most one epsilon for a lower-priority
    # holder (the paper's (eta_i^g + 1) * epsilon blocking term), and the
    # highest-priority waiter enters next (rt_mutex ordering emerges from
    # per-core priority scheduling at acquisition instants).
    def try_acquire(self, job: "Job") -> bool:
        if self.lock_holder is None or self.lock_holder is job:
            self.lock_holder = job
            return True
        return False

    def _release_lock(self) -> None:
        self.lock_holder = None

    # ---- best-effort TSG bookkeeping (Alg2State callbacks) -----------------
    def _enter_running(self, job) -> None:
        if not job_is_rt(job):
            self.rr.add(self._tsg(job))

    def _leave_running(self, job) -> None:
        tsg = self._tsgs.get(id(job))
        if tsg:
            self.rr.remove(tsg)

    def _tsg(self, job: "Job") -> TSG:
        if id(job) not in self._tsgs:
            self._tsgs[id(job)] = TSG(job=job,
                                      priority=job_gpu_priority(job))
        return self._tsgs[id(job)]

    # ---- simulator hooks ----------------------------------------------------
    def begin_update(self, job: "Job", piece) -> None:
        """Runs when the caller acquires the rt_mutex.  Executes Algorithm 2
        and prices the IOCTL: a call that actually rewrites the runlist
        (task_running membership changes) costs epsilon of CPU time at the
        caller's priority and freezes the GPU for epsilon (TSG eviction /
        context switch — a hardware-driven window that elapses in wall time
        once the runlist registers are written); a call that only touches
        task_pending is the cheap mode of the paper's overhead histogram
        (Table V) and is modeled as free."""
        if piece.which == "begin":
            rewrote = self.alg2.add(job)
        else:
            rewrote = self.alg2.remove(job)
        cost = self.sim.ts.epsilon if rewrote else 0.0
        piece.duration = cost
        piece.remaining = cost
        if cost > 0.0:
            self._gpu_pause_left = max(self._gpu_pause_left, cost)

    def on_update_done(self, job: "Job", which: str) -> None:
        self._release_lock()

    def on_job_complete(self, job: "Job") -> None:
        # defensive cleanup (a well-formed job has already called end())
        self.alg2.discard(job)
        self._tsgs.pop(id(job), None)

    _gpu_pause_left = 0.0

    # ---- resource arbitration ----------------------------------------------
    def update_in_flight(self) -> bool:
        return self._gpu_pause_left > 0.0

    def gpu_owner(self) -> Optional["Job"]:
        if self.update_in_flight():
            return None  # runlist rewrite / context switch pauses the GPU
        rt = [j for j in self.running if j.task.is_rt and j.wants_gpu()]
        if rt:
            return max(rt, key=lambda j: j.task.gpu_priority)
        cur = self.rr.current()
        return cur.job if cur else None

    def gpu_rr_advance(self, dt: float) -> None:
        if self._gpu_pause_left > 0.0:
            self._gpu_pause_left = max(self._gpu_pause_left - dt, 0.0)
        if not any(j.task.is_rt and j.wants_gpu() for j in self.running):
            if len(self.rr.runnable()) > 1:
                self.rr.advance(dt)

    def next_gpu_event(self) -> float:
        ev = float("inf")
        if self._gpu_pause_left > 0.0:
            ev = self._gpu_pause_left
        if not any(j.task.is_rt and j.wants_gpu() for j in self.running):
            if len(self.rr.runnable()) > 1:
                ev = min(ev, max(self.rr.slice_left, 1e-9))
        return ev

    def cpu_blocked(self, job: "Job") -> bool:
        if self.sim.mode != "suspend":
            return False
        k = job.current_kind()
        if k == "upd" and self.lock_holder not in (None, job) \
                and not job.upd_started:
            return True   # rt_mutex sleeps the waiter
        if k == "ge":
            return True   # self-suspended during pure GPU execution / wait
        return False

    # ---- runtime face (sched.executor notify mode) -------------------------
    def runtime_segment_begin(self, job) -> bool:
        return self.alg2.add(job)

    def runtime_segment_end(self, job) -> bool:
        return self.alg2.remove(job)

    def runtime_on_complete(self, job) -> None:
        self.alg2.discard(job)
        self._tsgs.pop(id(job), None)

    def runtime_admitted(self, job) -> bool:
        if job not in self.running:
            return False
        rt = [j for j in self.running if job_is_rt(j)]
        if rt:
            return job is max(rt, key=job_gpu_priority)
        return True


# Both wait modes carry their analytic guarantee on any platform: the
# busy entry resolves to the cross-device fixed point on n_devices > 1
# (core/crossfix.py); the suspend entry's per-device projection is sound
# as-is (no busy-wait chains).
register_policy("ioctl", IoctlPolicy,
                "Algorithm 2: IOCTL segment-granular runlist control",
                rtas={"busy": ioctl_busy_rta, "suspend": ioctl_suspend_rta})
