"""IOCTL-based approach (Sec. V-B, Algorithm 2).

User programs bracket each GPU segment with cudaStreamBegin()/cudaStreamEnd()
macros; each call issues an IOCTL that runs the runlist-update procedure in
the driver under an rt_mutex.  In the simulator, these appear as explicit
``upd`` pieces in the job's piece sequence (cost epsilon each, executed on
the caller's core, non-preemptive — kernel path holding the driver lock —
and pausing the GPU while the runlist is rewritten).

Algorithm 2 state: two disjoint lists, ``task_running`` (TSGs on the
runlist) and ``task_pending``.  Verbatim logic, with one safety deviation
noted inline: on removal with no pending real-time task, the paper sets
task_running <- task_pending, which would drop best-effort TSGs that
remained in task_running; we take the union instead.

Both busy-waiting and self-suspension are supported during pure GPU
execution and while waiting for admission (Table I / Sec. VI).
"""
from __future__ import annotations

from typing import TYPE_CHECKING, Optional

from .runlist import BasePolicy, Runlist, TSG

if TYPE_CHECKING:  # pragma: no cover
    from .simulator import Job


class IoctlPolicy(BasePolicy):
    name = "ioctl"
    needs_ioctl_pieces = True

    def __init__(self, rr_slice: float = 2.0):
        self.running: list["Job"] = []   # task_running
        self.pending: list["Job"] = []   # task_pending
        self.lock_holder: Optional["Job"] = None
        self.rr = Runlist(rr_slice)        # RR among best-effort members

    # ---- rt_mutex ----------------------------------------------------------
    # The update is a kernel section: a caller must win its core (ordinary
    # priority scheduling) to *enter* the IOCTL; once entered it acquires
    # the mutex and runs non-preemptively for at most epsilon.  Contending
    # callers therefore wait at most one epsilon for a lower-priority
    # holder (the paper's (eta_i^g + 1) * epsilon blocking term), and the
    # highest-priority waiter enters next (rt_mutex ordering emerges from
    # per-core priority scheduling at acquisition instants).
    def try_acquire(self, job: "Job") -> bool:
        if self.lock_holder is None or self.lock_holder is job:
            self.lock_holder = job
            return True
        return False

    def _release_lock(self) -> None:
        self.lock_holder = None

    # ---- Algorithm 2 -------------------------------------------------------
    def _ioctl_runlist_update(self, job: "Job", add: bool) -> None:
        gp = lambda j: j.task.gpu_priority
        if add:
            if not job.task.is_rt:                    # lines 6-10
                if not any(j.task.is_rt for j in self.running):
                    self._to_running(job)
                else:
                    self.pending.append(job)
                    job.gpu_pending = True
            else:                                     # lines 11-17
                tau_h = max(self.running, key=gp, default=None)
                if tau_h is None or gp(job) > gp(tau_h):
                    self._to_running(job)
                    if tau_h is not None and tau_h.task.is_rt:
                        # preempt tau_h: move to pending
                        self._from_running(tau_h)
                        self.pending.append(tau_h)
                        tau_h.gpu_pending = True
                    elif tau_h is not None:
                        # best-effort members are displaced as well
                        for be in [j for j in self.running
                                   if j is not job and not j.task.is_rt]:
                            self._from_running(be)
                            self.pending.append(be)
                            be.gpu_pending = True
                else:
                    self.pending.append(job)
                    job.gpu_pending = True
        else:                                         # lines 18-25
            rt_pend = [j for j in self.pending if j.task.is_rt]
            if rt_pend:
                tau_k = max(rt_pend, key=gp)
                self.pending.remove(tau_k)
                self._to_running(tau_k)
                self._from_running(job)
            else:
                self._from_running(job)
                # paper: task_running <- task_pending (union, see docstring)
                for j in list(self.pending):
                    self.pending.remove(j)
                    self._to_running(j)

    def _to_running(self, job: "Job") -> None:
        if job not in self.running:
            self.running.append(job)
        job.gpu_pending = False
        if not job.task.is_rt:
            self.rr.add(self._tsg(job))

    def _from_running(self, job: "Job") -> None:
        if job in self.running:
            self.running.remove(job)
        tsg = self._tsgs.get(job.uid)
        if tsg:
            self.rr.remove(tsg)

    _tsgs: dict = None

    def attach(self, sim) -> None:
        super().attach(sim)
        self._tsgs = {}

    def _tsg(self, job: "Job") -> TSG:
        if job.uid not in self._tsgs:
            self._tsgs[job.uid] = TSG(job=job, priority=job.task.gpu_priority)
        return self._tsgs[job.uid]

    # ---- simulator hooks ----------------------------------------------------
    def begin_update(self, job: "Job", piece) -> None:
        """Runs when the caller acquires the rt_mutex.  Executes Algorithm 2
        and prices the IOCTL: a call that actually rewrites the runlist
        (task_running membership changes) costs epsilon of CPU time at the
        caller's priority and freezes the GPU for epsilon (TSG eviction /
        context switch — a hardware-driven window that elapses in wall time
        once the runlist registers are written); a call that only touches
        task_pending is the cheap mode of the paper's overhead histogram
        (Table V) and is modeled as free."""
        before = set(j.uid for j in self.running)
        self._ioctl_runlist_update(job, add=(piece.which == "begin"))
        after = set(j.uid for j in self.running)
        cost = self.sim.ts.epsilon if before != after else 0.0
        piece.duration = cost
        piece.remaining = cost
        if cost > 0.0:
            self._gpu_pause_left = max(self._gpu_pause_left, cost)

    def on_update_done(self, job: "Job", which: str) -> None:
        self._release_lock()

    def on_job_complete(self, job: "Job") -> None:
        # defensive cleanup (a well-formed job has already called end())
        if job in self.running:
            self._from_running(job)
        if job in self.pending:
            self.pending.remove(job)
        self._tsgs.pop(job.uid, None)

    _gpu_pause_left = 0.0

    # ---- resource arbitration ----------------------------------------------
    def update_in_flight(self) -> bool:
        return self._gpu_pause_left > 0.0

    def gpu_owner(self) -> Optional["Job"]:
        if self.update_in_flight():
            return None  # runlist rewrite / context switch pauses the GPU
        rt = [j for j in self.running if j.task.is_rt and j.wants_gpu()]
        if rt:
            return max(rt, key=lambda j: j.task.gpu_priority)
        cur = self.rr.current()
        return cur.job if cur else None

    def gpu_rr_advance(self, dt: float) -> None:
        if self._gpu_pause_left > 0.0:
            self._gpu_pause_left = max(self._gpu_pause_left - dt, 0.0)
        if not any(j.task.is_rt and j.wants_gpu() for j in self.running):
            if len(self.rr.runnable()) > 1:
                self.rr.advance(dt)

    def next_gpu_event(self) -> float:
        ev = float("inf")
        if self._gpu_pause_left > 0.0:
            ev = self._gpu_pause_left
        if not any(j.task.is_rt and j.wants_gpu() for j in self.running):
            if len(self.rr.runnable()) > 1:
                ev = min(ev, max(self.rr.slice_left, 1e-9))
        return ev

    def cpu_blocked(self, job: "Job") -> bool:
        if self.sim.mode != "suspend":
            return False
        k = job.current_kind()
        if k == "upd" and self.lock_holder not in (None, job) \
                and not job.upd_started:
            return True   # rt_mutex sleeps the waiter
        if k == "ge":
            return True   # self-suspended during pure GPU execution / wait
        return False
