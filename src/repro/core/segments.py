"""The GPU-access-segment abstraction shared by analysis, simulator, and
runtime (DESIGN.md §6).

The paper's entire contribution is controlling *where* preemption can
happen: at the boundaries of GPU access segments (the IOCTL macro / the
kernel thread's runlist rewrites).  This module is the single place that
segment structure is defined, so the three layers that consume it cannot
drift apart:

  * **analysis** — :class:`GpuSegment` is the G_{i,j} = (G^m, G^e) pair of
    Sec. IV; ``task_model.Task`` profiles (and ``taskgen``) are built from
    it, and :class:`WorkloadProfile` maps *measured* per-slice times onto
    the η/G/ε parameters the RTAs consume;
  * **simulator** — :func:`segment_layout` is the canonical expansion of a
    task's segments into the alternating piece sequence
    (cpu → [upd] gm ge [upde] → …) that ``core.simulator.build_pieces``
    samples durations onto;
  * **runtime** — :class:`SlicedOp` is a resumable device operation (K
    grid-slices per dispatch, explicit carry between dispatches) and
    :class:`SegmentedWorkload` is a job body expressed as alternating host
    work and sliced device segments; ``sched.executor.DeviceExecutor.
    run_sliced`` re-checks admission before every slice, so the observed
    preemption delay is bounded by **one slice** (+ the runlist-update
    cost ε) instead of a whole device program.

Nothing here imports jax at module level — the analysis side stays
importable on hosts without an accelerator stack; the few measurement
helpers that need device synchronization import it lazily.
"""
from __future__ import annotations

from dataclasses import dataclass, field
from typing import TYPE_CHECKING, Any, Callable, List, Optional, Tuple

if TYPE_CHECKING:  # pragma: no cover
    from .task_model import Task


# --------------------------------------------------------------------------
# analysis face: the G_{i,j} = (G^m, G^e) pair of Sec. IV
# --------------------------------------------------------------------------

@dataclass(frozen=True)
class GpuSegment:
    """One GPU segment G_{i,j} = (G^m_{i,j}, G^e_{i,j}).

    ``misc`` is the CPU-side launch/driver work (WCET), ``exec`` the pure
    GPU execution; best-case fields default to the WCET (deterministic
    execution) and feed the reduced-pessimism analysis (Sec. VI-C)."""

    misc: float  # G^m_{i,j}: CPU-side launch/driver work (WCET)
    exec: float  # G^e_{i,j}: pure GPU execution (WCET)
    misc_best: Optional[float] = None
    exec_best: Optional[float] = None

    def __post_init__(self):
        if self.misc < 0 or self.exec < 0:
            raise ValueError("segment times must be non-negative")
        if self.misc_best is None:
            object.__setattr__(self, "misc_best", self.misc)
        if self.exec_best is None:
            object.__setattr__(self, "exec_best", self.exec)
        if self.misc_best > self.misc or self.exec_best > self.exec:
            raise ValueError("best-case must not exceed WCET")

    @property
    def total(self) -> float:
        """G_{i,j} <= G^m + G^e (we use the conservative sum)."""
        return self.misc + self.exec


# --------------------------------------------------------------------------
# simulator face: the canonical segment -> piece expansion
# --------------------------------------------------------------------------

def segment_layout(task: "Task", with_ioctl: bool) -> List[Tuple[str, int]]:
    """The alternating piece structure of one job of ``task``:
    ``[("cpu", j), ("gm", j), ("upd", j), ("ge", j), ("upde", j), ...]``.

    This is the one definition of where segment boundaries (and therefore
    the IOCTL approach's runlist updates — the preemption points) sit;
    ``simulator.build_pieces`` samples durations onto it and the runtime's
    :class:`SegmentedWorkload` mirrors it with real host work and sliced
    device dispatches.  ``upd`` (begin, needs the core) and ``upde`` (end,
    driver completion context) bracket the pure-GPU piece only under the
    IOCTL policy."""
    layout: List[Tuple[str, int]] = []
    nc, ng = task.eta_c, task.eta_g
    for j in range(max(nc, ng)):
        if j < nc:
            layout.append(("cpu", j))
        if j < ng:
            layout.append(("gm", j))
            if with_ioctl:
                layout.append(("upd", j))
            layout.append(("ge", j))
            if with_ioctl:
                layout.append(("upde", j))
    return layout


# --------------------------------------------------------------------------
# runtime face: sliced, resumable device operations
# --------------------------------------------------------------------------

@dataclass
class SlicedOp:
    """A resumable device operation: ``n_slices`` bounded-duration
    dispatches threading an explicit carry.

      carry = op.init()
      for i in range(op.n_slices):   # preemption point before every slice
          carry = op.step(carry, i)
      out = op.finalize(carry)

    The carry is an arbitrary pytree (kernel-specific: softmax row stats
    for attention, the recurrent h/S state for mamba/rwkv, the KV cache +
    emitted tokens for serving decode), so ``sched.checkpointer`` can
    snapshot it mid-job and a crashed or preempted job can resume at the
    last completed slice instead of re-running the whole segment."""

    n_slices: int
    init: Callable[[], Any]
    step: Callable[[Any, int], Any]
    finalize: Callable[[Any], Any]
    label: str = ""

    def __post_init__(self):
        if self.n_slices < 1:
            raise ValueError("a SlicedOp needs at least one slice")

    def run(self, carry: Any = None, start: int = 0) -> Any:
        """Inline execution (no executor): all slices, then finalize.
        ``carry``/``start`` resume from a snapshot."""
        if carry is None:
            carry = self.init()
        for i in range(start, self.n_slices):
            carry = self.step(carry, i)
        return self.finalize(carry)


def n_slices_for(total: int, per_slice: int) -> int:
    """Number of slices covering ``total`` grid steps at ``per_slice``
    steps per dispatch (last slice may be short)."""
    if per_slice < 1:
        raise ValueError("per_slice must be >= 1")
    return -(-total // per_slice)


# --------------------------------------------------------------------------
# measured profiles: real slices -> the paper's η/G/m_i/ε parameters
# --------------------------------------------------------------------------

@dataclass
class SliceProfile:
    """Measured timing of one sliced device segment.

    ``slice_ms[k]`` is the worst observed wall time of slice ``k`` across
    repetitions; ``init_ms``/``finalize_ms`` are the host-side carry
    setup/teardown around the dispatch loop (the G^m analogue)."""

    label: str
    slice_ms: List[float]
    init_ms: float = 0.0
    finalize_ms: float = 0.0

    @property
    def n_slices(self) -> int:
        return len(self.slice_ms)

    @property
    def exec_ms(self) -> float:
        """G^e: total pure device time of the segment."""
        return sum(self.slice_ms)

    @property
    def misc_ms(self) -> float:
        """G^m: CPU-side launch/teardown work of the segment."""
        return self.init_ms + self.finalize_ms

    @property
    def max_slice_ms(self) -> float:
        """The preemption-delay bound this segment imposes: a higher-
        priority arrival waits at most one in-flight slice (the ε analogue
        of thread-block-boundary preemption)."""
        return max(self.slice_ms)

    def to_gpu_segment(self, margin: float = 1.0) -> GpuSegment:
        """The analysis G_{i,j} this measured segment occupies, inflated
        by ``margin`` (measured times are observations, not WCETs)."""
        return GpuSegment(misc=self.misc_ms * margin,
                          exec=self.exec_ms * margin)


@dataclass
class WorkloadProfile:
    """Measured profile of a whole job body: alternating host segments and
    sliced device segments — the runtime-measured counterpart of the
    analysis Task (η^c host segments, η^g device segments)."""

    name: str
    host_ms: List[float] = field(default_factory=list)
    device: List[SliceProfile] = field(default_factory=list)

    @property
    def eta_c(self) -> int:
        return len(self.host_ms)

    @property
    def eta_g(self) -> int:
        return len(self.device)

    @property
    def max_slice_ms(self) -> float:
        """Worst single dispatch across all device segments — the residual
        a newly admitted higher-priority job may have to wait out."""
        return max((s.max_slice_ms for s in self.device), default=0.0)

    def epsilon_ms(self, update_cost_ms: float = 0.0) -> float:
        """The ε the admission test should use on this platform: the
        runlist-update (admission mutex) cost plus the bounded residual of
        one in-flight slice.  Pre-slicing, this had to cover the *longest
        whole device program* in the system."""
        return update_cost_ms + self.max_slice_ms

    def segments_ms(self, margin: float = 1.0
                    ) -> Tuple[List[float], List[Tuple[float, float]]]:
        """(host_segments_ms, [(misc_ms, exec_ms), ...]) with ``margin``
        applied — the shape ``sched.admission.JobProfile`` consumes."""
        host = [h * margin for h in self.host_ms]
        dev = [(s.misc_ms * margin, s.exec_ms * margin)
               for s in self.device]
        return host, dev

    def to_task(self, period_ms: float, priority: int, *,
                deadline_ms: Optional[float] = None, cpu: int = 0,
                device: int = 0, best_effort: bool = False,
                margin: float = 1.0) -> "Task":
        """Build the analysis Task directly (the admission-controller path
        goes through ``JobProfile.from_workload`` instead)."""
        from .task_model import Task
        host, _ = self.segments_ms(margin)
        return Task(
            name=self.name,
            cpu_segments=host or [0.0],
            gpu_segments=[s.to_gpu_segment(margin) for s in self.device],
            period=period_ms,
            deadline=deadline_ms or period_ms,
            cpu=cpu, priority=priority,
            best_effort=best_effort, device=device)


def measure_sliced(make_op: Callable[[], SlicedOp], reps: int = 3,
                   label: Optional[str] = None) -> SliceProfile:
    """Time one sliced device segment: per-slice wall times (worst over
    ``reps`` runs, first run treated as compile warm-up when reps > 1),
    plus the host-side init/finalize cost.  Each ``step`` is synchronized
    (``block_until_ready``) so a slice's time is its real device residency
    — the quantity that bounds the preemption delay."""
    import time as _time

    import jax as _jax

    runs: List[Tuple[float, List[float], float]] = []
    op_label = "segment"
    for _ in range(max(reps, 1)):
        op = make_op()
        op_label = op.label or op_label
        t0 = _time.perf_counter()
        carry = op.init()
        carry = _jax.block_until_ready(carry)
        t_init = (_time.perf_counter() - t0) * 1e3
        times = []
        for i in range(op.n_slices):
            t0 = _time.perf_counter()
            carry = op.step(carry, i)
            carry = _jax.block_until_ready(carry)
            times.append((_time.perf_counter() - t0) * 1e3)
        t0 = _time.perf_counter()
        _jax.block_until_ready(op.finalize(carry))
        runs.append((t_init, times, (_time.perf_counter() - t0) * 1e3))
    if len(runs) > 1:
        runs = runs[1:]  # drop the compile-polluted warm-up run
    return SliceProfile(
        label=label or op_label,
        slice_ms=[max(r[1][i] for r in runs)
                  for i in range(len(runs[0][1]))],
        init_ms=max(r[0] for r in runs),
        finalize_ms=max(r[2] for r in runs))


# --------------------------------------------------------------------------
# runtime workloads: a job body as alternating host/device segments
# --------------------------------------------------------------------------

@dataclass
class _Entry:
    kind: str                      # "host" | "device"
    fn: Callable                   # host thunk | () -> SlicedOp factory
    label: str = ""


class SegmentedWorkload:
    """A job body expressed in the paper's task structure: alternating
    host (CPU) segments and sliced device (GPU-access) segments.

    The same object serves all three layers:

      * ``bind(executor)`` → an ``RTJob`` body that brackets each device
        segment with ``device_segment()`` (the IOCTL macro) and dispatches
        it slice-by-slice via ``executor.run_sliced`` — preemption delay
        bounded by one slice;
      * ``profile(reps=...)`` → a :class:`WorkloadProfile` of measured
        host times and per-slice device times;
      * the profile's η/G/ε view feeds ``sched.admission`` (via
        ``JobProfile.from_workload``), closing the loop real kernel →
        measured segments → RTA admission → executor enforcement.
    """

    def __init__(self, name: str):
        self.name = name
        self._entries: List[_Entry] = []

    # -- construction ------------------------------------------------------
    def host(self, fn: Callable[[], Any],
             label: str = "") -> "SegmentedWorkload":
        """Append a host (CPU) segment: a plain thunk."""
        self._entries.append(_Entry("host", fn, label))
        return self

    def device(self, make_op: Callable[[], SlicedOp],
               label: str = "") -> "SegmentedWorkload":
        """Append a device segment: a factory producing a fresh
        :class:`SlicedOp` per release (carries are single-use)."""
        self._entries.append(_Entry("device", make_op, label))
        return self

    @property
    def eta_c(self) -> int:
        return sum(1 for e in self._entries if e.kind == "host")

    @property
    def eta_g(self) -> int:
        return sum(1 for e in self._entries if e.kind == "device")

    # -- runtime -----------------------------------------------------------
    def bind(self, executor, device: Optional[int] = None) -> Callable:
        """An ``RTJob`` body running this workload under ``executor``.

        ``device`` pins the job to one accelerator of a multi-device
        platform: the body binds ``job.device`` on first run (and a
        ``ClusterExecutor`` routes every dispatch by it), while a plain
        ``DeviceExecutor`` must *be* that device (``device_index``
        checked).  A job already bound elsewhere raises — the
        migration-free invariant (DESIGN.md §7)."""
        def body(job, it):
            if device is not None:
                bound = getattr(job, "device", None)
                if bound is None:
                    job.device = device
                elif bound != device:
                    raise RuntimeError(
                        f"job {job.name!r} is bound to device {bound}, "
                        f"workload is pinned to device {device}")
                ex_dev = getattr(executor, "device_index", None)
                if ex_dev is not None and ex_dev != device:
                    raise RuntimeError(
                        f"workload pinned to device {device} cannot run "
                        f"on executor of device {ex_dev}")
            self.run(executor, job)
        return body

    def run(self, executor, job) -> List[Any]:
        """Execute one release: host segments inline, device segments
        through the executor's sliced dispatch loop (admission re-checked
        before every slice).  Returns the device segments' outputs."""
        outs = []
        for e in self._entries:
            if e.kind == "host":
                e.fn()
            else:
                with executor.device_segment(job):
                    outs.append(executor.run_sliced(job, e.fn()))
        return outs

    # -- measurement -------------------------------------------------------
    def profile(self, reps: int = 3) -> WorkloadProfile:
        """Measure every segment (executor-free, device-synchronized).
        Host thunks run once per rep (worst time kept); device segments go
        through :func:`measure_sliced`."""
        import time as _time

        prof = WorkloadProfile(name=self.name)
        for e in self._entries:
            if e.kind == "host":
                times = []
                for _ in range(max(reps, 1)):
                    t0 = _time.perf_counter()
                    e.fn()
                    times.append((_time.perf_counter() - t0) * 1e3)
                if len(times) > 1:
                    times = times[1:]  # drop the compile-polluted warm-up
                prof.host_ms.append(max(times))
            else:
                prof.device.append(measure_sliced(
                    e.fn, reps=reps, label=e.label or None))
        return prof


__all__ = [
    "GpuSegment", "segment_layout",
    "SlicedOp", "n_slices_for",
    "SliceProfile", "WorkloadProfile", "measure_sliced",
    "SegmentedWorkload",
]
