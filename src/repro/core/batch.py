"""NumPy-vectorized batch RTA backend (DESIGN.md §5).

The schedulability sweeps (paper Figs. 7-12) evaluate thousands of random
tasksets through the Lemma 1-4/6-7 recurrences.  The scalar path in
`core/analysis.py` / `core/improved.py` walks them one task at a time,
re-deriving every interference term per fixed-point step.  This module
packs a whole *batch* of tasksets into padded ``(S, N)`` arrays (S
tasksets, N = max real-time tasks) and iterates **all tasks of all
tasksets in lockstep**: one masked array fixed point with per-element
divergence freezing replaces thousands of Python ``_iterate`` calls.

Why lockstep (Jacobi) iteration is decision- and value-identical to the
scalar (priority-ordered, Gauss-Seidel-style) reference:

  * Within one taskset the recurrences form a *triangular* monotone
    system — task i's recurrence reads only the response times of
    strictly higher-priority tasks (through the release jitters), never
    the other way around.  The scalar loop solves it exactly by
    substitution; the least fixed point of the joint system is that same
    solution.
  * Every term is monotone in the iterate vector, and the jitter
    fallback for a diverged task (``R_h -> D_h``) matches the scalar
    fallback.  Jacobi iteration from the zero vector (or any per-task
    seed at or below the task's fixed point) therefore ascends to the
    least fixed point — the scalar answer.
  * The recurrences are piecewise constant in the iterate (all
    dependence goes through ``ceil`` terms), so the ascent terminates
    *exactly* in finitely many rounds; a task whose iterate exceeds its
    deadline is frozen at ``inf`` immediately, exactly like
    ``_iterate``.

Multi-device tasksets are composed exactly like the scalar
decorators: the suspend-mode analyses (and the busy-mode
``method="heuristic"`` escape hatch) run every per-device projection of
every taskset in one batched solve and recombine (``per_device``
semantics), while the busy-mode default drives the `core/crossfix.py`
outer occupancy loop in lockstep across the batch — each outer round
folds all still-active tasksets with their current occupancy iterate
(``fold_to_device``), solves every projection in one batched inner
fixed point, and re-derives occupancies with the shared
``crossfix.occupancy_vector`` step.

``_audsley_lockstep`` additionally batches the Audsley GPU-priority
search: every still-active taskset's current candidate test is one
element of a shared single-task vector fixed point, warm-started from
the per-candidate floor bound (see `core/audsley.py` for the soundness
argument; the floor is computed here in one vectorized pre-solve).

The scalar path remains the reference implementation; differential and
golden equivalence is pinned in tests/test_batch_equivalence.py.
"""
from __future__ import annotations

import dataclasses
import functools
import math
import warnings
from typing import Dict, List, Optional, Sequence, Tuple

import numpy as np

from .analysis import (MAX_ITERS, SoundnessWarning, fold_to_device,
                       merge_device_bounds)
from .audsley import assign_gpu_priorities
from .task_model import Taskset

#: The ceil/floor robustness tolerance shared by every vectorized backend
#: (NumPy here, JAX in `core/batch_jax.py`).  This is THE definition: the
#: JAX backend imports it, so the two backends cannot silently drift apart
#: on acceptance bits through a tolerance edit in one of them.  It must
#: equal the scalar path's tolerance (analysis._EPS and the 1e-9 literals
#: in overlap._ceil/_floor) — pinned by tests/test_batch_equivalence.py.
CEIL_EPS = 1e-9
_EPS = CEIL_EPS

SUSPEND_KINDS = ("ioctl_suspend", "ioctl_suspend_improved")
BUSY_KINDS = ("kthread_busy", "ioctl_busy", "ioctl_busy_improved")
KINDS = BUSY_KINDS + SUSPEND_KINDS
_IMPROVED = frozenset(("ioctl_busy_improved", "ioctl_suspend_improved"))
_OCC_KIND = {"kthread_busy": "kthread", "ioctl_busy": "ioctl",
             "ioctl_busy_improved": "ioctl"}


def scalar_rta(kind: str, method: str = "fixed_point"):
    """The scalar reference callable for a batch kind (used for fallback
    paths — e.g. multi-device Audsley — and by the differential tests)."""
    from . import analysis as _a
    from . import improved as _i
    base = {
        "kthread_busy": _a.kthread_busy_rta,
        "ioctl_busy": _a.ioctl_busy_rta,
        "ioctl_suspend": _a.ioctl_suspend_rta,
        "ioctl_busy_improved": _i.ioctl_busy_improved_rta,
        "ioctl_suspend_improved": _i.ioctl_suspend_improved_rta,
    }[kind]
    if method == "heuristic" and kind in BUSY_KINDS:
        @functools.wraps(base)
        def wrapped(ts, **kw):
            kw.setdefault("method", "heuristic")
            return base(ts, **kw)
        return wrapped
    return base


# --------------------------------------------------------------------------
# array packing
# --------------------------------------------------------------------------

@dataclasses.dataclass
class _Pack:
    """Padded arrays over the real-time tasks of S single-device tasksets.

    Index convention for pair matrices built from these: ``M[s, i, h]``
    with ``i`` the analyzed task and ``h`` the interferer.  Best-effort
    tasks are never interference sources for real-time tasks (their
    priorities sit below every real-time priority by construction) and
    are never analyzed, so they are left out of the arrays entirely and
    only reappear as ``None`` entries in the result dicts.
    """

    S: int
    N: int
    valid: np.ndarray      # (S,N) bool: a real-time task occupies the slot
    uses_gpu: np.ndarray   # (S,N) bool
    C: np.ndarray          # (S,N) cumulative WCETs / per-task constants
    G: np.ndarray
    Gm: np.ndarray
    Ge: np.ndarray
    C_best: np.ndarray
    Ge_best: np.ndarray
    eta_g: np.ndarray      # (S,N) float (exact small ints)
    T: np.ndarray          # (S,N) period, pad 1.0
    D: np.ndarray          # (S,N) deadline, pad +inf
    prio: np.ndarray       # (S,N) CPU priority, pad -inf
    gpu_prio: np.ndarray   # (S,N) GPU priority, pad -inf
    cpu: np.ndarray        # (S,N) int, pad -1
    eps: np.ndarray        # (S,) per-taskset epsilon
    kcpu: np.ndarray       # (S,) kernel-thread core
    cseg: np.ndarray       # (S,N,Kc) best-case CPU segments, pad 0
    cseg_m: np.ndarray     # (S,N,Kc) bool
    gseg: np.ndarray       # (S,N,Kg) best-case pure-GPU segments, pad 0
    gseg_m: np.ndarray     # (S,N,Kg) bool
    names: List[List[str]]
    be_names: List[List[str]]
    # memo for priority-independent overlap matrices ("ogc", "ocg_cpu",
    # "ocg_full", "ocg_gpu0") — they are reused across the RM solve, the
    # Audsley floor solve and the closing full tests
    cache: Dict[str, np.ndarray] = dataclasses.field(default_factory=dict)

    def take(self, rows: Sequence[int]) -> "_Pack":
        """Row-subset copy (cached overlaps slice right along) — used by
        the Audsley lockstep to batch only the rejected tasksets."""
        r = np.asarray(rows)
        kw = {}
        for f in dataclasses.fields(self):
            v = getattr(self, f.name)
            if isinstance(v, np.ndarray):
                kw[f.name] = v[r]
            elif isinstance(v, list):
                kw[f.name] = [v[i] for i in rows]
            elif isinstance(v, dict):
                kw[f.name] = {k: a[r] for k, a in v.items()}
            else:
                kw[f.name] = v
        kw["S"] = len(rows)
        return _Pack(**kw)


def _pack(tasksets: Sequence[Taskset]) -> _Pack:
    for ts in tasksets:
        if ts.n_devices > 1:
            raise ValueError(
                "_pack expects single-device problems; multi-device "
                "tasksets are composed by batch_rta")
    rts = [ts.rt_tasks for ts in tasksets]
    S = len(tasksets)
    N = max([1] + [len(r) for r in rts])
    Kc = max([1] + [t.eta_c for r in rts for t in r])
    Kg = max([1] + [t.eta_g for r in rts for t in r])

    def z(*shape):
        return np.zeros(shape, dtype=np.float64)

    p = _Pack(
        S=S, N=N,
        valid=np.zeros((S, N), dtype=bool),
        uses_gpu=np.zeros((S, N), dtype=bool),
        C=z(S, N), G=z(S, N), Gm=z(S, N), Ge=z(S, N),
        C_best=z(S, N), Ge_best=z(S, N), eta_g=z(S, N),
        T=np.ones((S, N)), D=np.full((S, N), np.inf),
        prio=np.full((S, N), -np.inf), gpu_prio=np.full((S, N), -np.inf),
        cpu=np.full((S, N), -1, dtype=np.int64),
        eps=z(S), kcpu=z(S),
        cseg=z(S, N, Kc), cseg_m=np.zeros((S, N, Kc), dtype=bool),
        gseg=z(S, N, Kg), gseg_m=np.zeros((S, N, Kg), dtype=bool),
        names=[], be_names=[],
    )
    # Bulk fill: one row tuple per task appended to a flat list, then a
    # single scatter per field.  Item-wise ndarray stores used to dominate
    # packing cost at 10k-taskset batches (the JAX backend's scale), and
    # packing is shared Python work both backends pay.  The tuple reads
    # Task's cached cumulative slots directly — the property wrappers
    # cost ~2x per access and this loop touches every task of every
    # taskset in the batch.
    sidx: List[int] = []
    jidx: List[int] = []
    rows: List[tuple] = []
    csegs: List[tuple] = []
    gsegs: List[tuple] = []
    for s, ts in enumerate(tasksets):
        p.eps[s] = ts.epsilon
        p.kcpu[s] = ts.kthread_cpu
        p.names.append([t.name for t in rts[s]])
        p.be_names.append([t.name for t in ts.tasks if not t.is_rt])
        for j, t in enumerate(rts[s]):
            sidx.append(s)
            jidx.append(j)
            gs = t.gpu_segments
            rows.append((t._C, t._G, t._Gm, t._Ge, t._C_best, t._Ge_best,
                         len(gs), t.period, t.deadline, t.priority,
                         t.gpu_priority, t.cpu, bool(gs)))
            csegs.append(t.cpu_segments_best)
            gsegs.append(tuple(g.exec_best for g in gs))
    if rows:
        si = np.asarray(sidx)
        ji = np.asarray(jidx)
        cols = np.asarray(rows, dtype=np.float64)
        p.valid[si, ji] = True
        for k, f in enumerate(("C", "G", "Gm", "Ge", "C_best", "Ge_best",
                               "eta_g", "T", "D", "prio", "gpu_prio")):
            getattr(p, f)[si, ji] = cols[:, k]
        p.cpu[si, ji] = cols[:, 11].astype(np.int64)
        p.uses_gpu[si, ji] = cols[:, 12] != 0.0
        for seg, segm, per_task in ((p.cseg, p.cseg_m, csegs),
                                    (p.gseg, p.gseg_m, gsegs)):
            # flat scatter: (task, segment-slot) index pairs built with
            # repeat/cumsum instead of a per-task Python store
            counts = np.fromiter(map(len, per_task), dtype=np.int64,
                                 count=len(per_task))
            total = int(counts.sum())
            if not total:
                continue
            flat = np.fromiter(
                (v for segs in per_task for v in segs),
                dtype=np.float64, count=total)
            sr = np.repeat(si, counts)
            jr = np.repeat(ji, counts)
            starts = np.repeat(np.cumsum(counts) - counts, counts)
            kr = np.arange(total) - starts
            seg[sr, jr, kr] = flat
            segm[sr, jr, kr] = True
    return p


# --------------------------------------------------------------------------
# vectorized primitives (exact twins of the scalar helpers)
# --------------------------------------------------------------------------

def _ceil_pos(x: np.ndarray, T: np.ndarray) -> np.ndarray:
    """Vector twin of analysis.ceil_pos / overlap._ceil.  All call sites
    pass x >= 0 (iterates and jitters are non-negative), where clamping
    the ceiling at zero is exactly the scalar x <= 0 guard."""
    return np.maximum(np.ceil(x / T - _EPS), 0.0)


def _floor_pos(x: np.ndarray, T: np.ndarray) -> np.ndarray:
    """Vector twin of overlap._floor (x >= 0 at every call site)."""
    return np.maximum(np.floor(x / T + _EPS), 0.0)


def _bx_lfp(init: np.ndarray, w: np.ndarray, T: np.ndarray,
            live0: np.ndarray) -> np.ndarray:
    """Smallest fixed point of BX = init + sum_h max(ceil(BX/T_h)-1, 0)*w_h
    per element, ascending from ``init`` — the vector twin of
    overlap._best_fixed_point (including its return-previous-iterate
    convergence convention and 4096-step cap)."""
    bx = np.where(live0, init, 0.0)
    live = live0.copy()
    for _ in range(4096):
        if not live.any():
            break
        n = np.maximum(_ceil_pos(bx[..., None], T) - 1.0, 0.0)
        nxt = init + (n * w).sum(axis=-1)
        step = live & (nxt > bx + _EPS)
        bx = np.where(step, nxt, bx)
        live = step
    return bx


def _masks(p: _Pack, gpu_prio: np.ndarray):
    """hp / hpp / hp-by-GPU pair masks, [s, i, h]."""
    pv = p.valid[:, :, None] & p.valid[:, None, :]
    HP = pv & (p.prio[:, None, :] > p.prio[:, :, None])
    HPP = HP & (p.cpu[:, None, :] == p.cpu[:, :, None])
    HPg = pv & (gpu_prio[:, None, :] > gpu_prio[:, :, None])
    return HP, HPP, HPg


def _overlaps(p: _Pack, use_gpu_prio: bool, HP, HPP, HPg,
              floor_mode: bool, gpu_prio_default: bool
              ) -> Tuple[np.ndarray, np.ndarray]:
    """O^cg / O^gc matrices (S,N,N) — Eqs. (5)-(9) via the vectorized
    best-case segment fixed points.  ``floor_mode`` switches the O^cg
    interference set to the all-GPU-tasks superset (overlap.full_hp).
    O^gc never depends on GPU priorities and O^cg only through its hp
    set, so everything except the O^cg of an overridden assignment is
    memoized on the pack."""
    T4 = p.T[:, None, None, :]

    # BX^g_{i,j} then O^cg_{i,h} = sum_j max(floor(BX/T_h)-1, 0) * C_best_h
    if floor_mode:
        key = "ocg_full"
    elif not use_gpu_prio:
        key = "ocg_cpu"
    elif gpu_prio_default:
        key = "ocg_gpu0"
    else:
        key = None  # overridden assignment (Audsley full test)
    Ocg = p.cache.get(key) if key else None
    if Ocg is None:
        ug_h = p.uses_gpu[:, None, :]
        if floor_mode:
            eye = np.eye(p.N, dtype=bool)[None]
            mgpu = p.valid[:, :, None] & p.valid[:, None, :] & ug_h & ~eye
        else:
            mgpu = (HPg if use_gpu_prio else HP) & ug_h
        w_g = np.where(mgpu, p.Ge_best[:, None, :], 0.0)[:, :, None, :]
        live_g = p.gseg_m & p.valid[:, :, None]
        bxg = _bx_lfp(p.gseg, w_g, T4, live_g)
        fl = np.maximum(_floor_pos(bxg[..., None], T4) - 1.0, 0.0)
        fl = np.where(live_g[..., None], fl, 0.0)
        Ocg = (fl * p.C_best[:, None, None, :]).sum(axis=2)
        if key:
            p.cache[key] = Ocg

    # BX^c_{i,j} (hpp interference) then O^gc_{i,h}
    Ogc = p.cache.get("ogc")
    if Ogc is None:
        w_c = np.where(HPP, p.C_best[:, None, :], 0.0)[:, :, None, :]
        live_c = p.cseg_m & p.valid[:, :, None]
        bxc = _bx_lfp(p.cseg, w_c, T4, live_c)
        flc = np.maximum(_floor_pos(bxc[..., None], T4) - 1.0, 0.0)
        flc = np.where(live_c[..., None], flc, 0.0)
        Ogc = (flc * p.Ge_best[:, None, None, :]).sum(axis=2)
        p.cache["ogc"] = Ogc
    return Ocg, Ogc


# --------------------------------------------------------------------------
# recurrence term groups
# --------------------------------------------------------------------------
#
# Every analysis is expressed as
#     R_i = const_i + sum_groups sum_h [ ceil((R_i + J_h)/T_h) * W_ih ]_O
# where each group carries per-pair weights W (zero = inactive pair), a
# jitter kind (None / "job" / "gpu" / "cpu"), and an optional per-pair
# overlap deduction O with the term clamped at >= 0 (Lemmas 6/7).

def _build2d(p: _Pack, kind: str, use_gpu_prio: bool, corrected: bool,
             gpu_prio: Optional[np.ndarray] = None,
             floor_mode: bool = False):
    if kind not in KINDS:
        raise ValueError(f"unknown batch RTA kind {kind!r}")
    gpu_prio_default = gpu_prio is None
    if gpu_prio is None:
        gpu_prio = p.gpu_prio
    HP, HPP, HPg = _masks(p, gpu_prio)
    ug_h = p.uses_gpu[:, None, :]
    ug_i = p.uses_gpu[:, :, None]
    eps1 = p.eps[:, None]
    epsh = p.eps[:, None, None]
    Ch = p.C[:, None, :]
    Gh = p.G[:, None, :]
    remote = (HPg if use_gpu_prio else HP) & ug_h & ~HPP
    if floor_mode:
        remote = np.zeros_like(remote)

    if kind == "kthread_busy":
        # Lemma 2 with the Lemma 1 K_i term folded in: x_i*2eps goes into
        # the constant, the per-GPU-hp 2eps updates form a "job"-jitter
        # group gated by x_i.
        x = p.uses_gpu | (p.cpu == p.kcpu[:, None].astype(np.int64))
        if corrected:
            x = x | (HPP & ug_h).any(axis=-1)
        x = x & p.valid
        const = p.C + p.G + np.where(x, 2.0 * eps1, 0.0)
        kmask = (HPg if use_gpu_prio else HP) & ug_h
        if floor_mode:
            kmask = np.zeros_like(kmask)
        groups = [
            (np.where(kmask & x[:, :, None], 2.0 * epsh, 0.0), "job", None),
            (np.where(HPP, Ch + Gh, 0.0), None, None),
            (np.where(remote, Ch + Gh, 0.0), "job", None),
        ]
        return const, groups

    gstar = p.G + 2.0 * eps1 * p.eta_g
    const = p.C + gstar + (p.eta_g + 1.0) * eps1
    gstar_h = gstar[:, None, :]
    gestar_h = (p.Ge + 2.0 * eps1 * p.eta_g)[:, None, :]
    gmstar_h = (p.Gm + 2.0 * eps1 * p.eta_g)[:, None, :]
    HPPc = HPP & ~ug_h
    HPPg = HPP & ug_h
    improved = kind in _IMPROVED
    Ocg = Ogc = None
    if improved:
        Ocg, Ogc = _overlaps(p, use_gpu_prio, HP, HPP, HPg, floor_mode,
                             gpu_prio_default)

    if kind in ("ioctl_busy", "ioctl_busy_improved"):
        stretch = (p.eta_g[:, None, :] + 1.0) * epsh if corrected else 0.0
        groups = [
            (np.where(HPPc, Ch, 0.0), None, Ocg),
            (np.where(HPPg, Ch + gstar_h + stretch, 0.0), None,
             Ocg + Ogc if improved else None),
            (np.where(remote, gestar_h, 0.0), "gpu", Ogc),
        ]
    else:  # ioctl_suspend / ioctl_suspend_improved (Lemmas 4 / 7)
        groups = [
            (np.where(HPPc, Ch, 0.0), None, Ocg),
            (np.where(HPPg, Ch + gmstar_h, 0.0), "cpu", Ocg),
            (np.where(HPPg & ug_i, p.Ge[:, None, :], 0.0), "gpu", Ogc),
            (np.where(remote & ug_i, gestar_h, 0.0), "gpu", Ogc),
        ]
    return const, groups


# --------------------------------------------------------------------------
# the lockstep fixed point
# --------------------------------------------------------------------------

def _solve2d(p: _Pack, const: np.ndarray, groups, use_gpu_prio: bool,
             analyzed: np.ndarray, seeds: Optional[np.ndarray] = None,
             max_rounds: Optional[int] = None,
             decide: bool = False) -> np.ndarray:
    """Masked Jacobi ascent of all ``analyzed`` elements; returns (S,N)
    bounds with ``inf`` for diverged elements.  With R-dependent jitters
    (``use_gpu_prio=False``) every valid element must be analyzed — the
    interferers' iterates feed the jitters.

    Rows whose every element has stabilized are compacted out of the
    working set (tasksets converge at very different speeds, so the tail
    of the ascent runs on a small fraction of the batch), and each
    round computes one ceiling per *jitter kind* shared by all groups
    using it.

    ``decide=True`` is the accept-bit fast path: the ascent is monotone,
    so the first element to cross its deadline settles the row's
    accept/reject decision and the whole row retires immediately.  The
    returned bounds of such a row are only decision-accurate (some
    finite entries may be below their fixed point) — callers that need
    WCRT *values* must keep the default."""
    if not use_gpu_prio:
        assert bool((analyzed == p.valid).all()), \
            "R-dependent jitters need the full task vector"
    if max_rounds is None:
        # lockstep propagates one priority level per round, so a chain of
        # N tasks may legitimately need up to the *sum* of the per-task
        # iteration budgets; the scalar budget is MAX_ITERS per task.
        # (Unreachable in practice: the ascent moves on a finite ceil
        # lattice, which also bounds the scalar path.)
        max_rounds = MAX_ITERS * max(p.N, 1)
    S = const.shape[0]
    offs = {"job": p.C + p.G, "gpu": p.Ge, "cpu": p.C + p.Gm}
    used = sorted({jit for _, jit, _ in groups if jit is not None})
    valid = p.valid
    T_h = p.T[:, :, None].transpose(0, 2, 1)  # (S,1,N) view of periods
    D = p.D
    R = np.zeros_like(const)
    if seeds is not None:
        R = np.where(analyzed, seeds, 0.0)
    act = analyzed & np.isfinite(R)
    R_out = np.where(analyzed & ~act, np.inf, R)  # inf seed: diverged
    rows = np.arange(S)  # original row index of each working row
    R = R_out.copy()
    offs = {k: offs[k] for k in used}
    J_const = None
    if use_gpu_prio:
        base = np.where(valid, np.where(np.isinf(D), 0.0, D), 0.0)
        J_const = {k: np.maximum(base - offs[k], 0.0) for k in used}
    converged = False
    for _ in range(max_rounds):
        live = act.any(axis=1)
        n_live = int(np.count_nonzero(live))
        if n_live == 0:
            converged = True
            break
        if n_live * 2 <= len(rows):  # compact: drop stabilized rows
            R_out[rows] = R
            rows = rows[live]
            R, act, const, D, T_h, valid = (
                R[live], act[live], const[live], D[live], T_h[live],
                valid[live])
            groups = [(W[live], jit, None if O is None else O[live])
                      for W, jit, O in groups]
            offs = {k: v[live] for k, v in offs.items()}
            if J_const is not None:
                J_const = {k: v[live] for k, v in J_const.items()}
        if use_gpu_prio:
            J = J_const
        else:
            base = np.where(valid, np.where(np.isinf(R), D, R), 0.0)
            J = {k: np.maximum(base - offs[k], 0.0) for k in used}
        Rsafe = np.where(np.isfinite(R), R, 0.0)
        Ri = Rsafe[:, :, None]
        n_jit = {k: _ceil_pos(Ri + J[k][:, None, :], T_h) for k in used}
        n_none = _ceil_pos(Ri, T_h)
        total = const.copy()
        for W, jit, O in groups:
            term = (n_none if jit is None else n_jit[jit]) * W
            if O is not None:
                term = np.maximum(term - O, 0.0)
            total += term.sum(axis=-1)
        Rnew = np.where(act, total, R)
        newinf = act & (Rnew > D + _EPS)
        # frozen rows hold inf on both sides; mask before the diff
        delta = np.abs(np.where(act, Rnew, 0.0) - np.where(act, R, 0.0))
        moved = act & ~newinf & (delta >= _EPS)
        R = np.where(newinf, np.inf, Rnew)
        # a row (taskset) with no movement and no fresh divergence is at
        # its joint fixed point — rows are independent problems, so
        # retire the whole row (individual elements cannot be frozen
        # under R-dependent jitters: an interferer's base may still grow)
        quiet = ~(moved | newinf).any(axis=1)
        act = act & ~newinf & ~quiet[:, None]
        if decide:
            act = act & ~newinf.any(axis=1)[:, None]
        if not act.any():
            converged = True
            break
    if not converged:
        # round cap without stabilization: conservative, like _iterate's
        # MAX_ITERS exhaustion
        R = np.where(act, np.inf, R)
    R_out[rows] = R
    return R_out


def _unpack_dicts(p: _Pack, R: np.ndarray) -> List[Dict[str, Optional[float]]]:
    out: List[Dict[str, Optional[float]]] = []
    for s in range(p.S):
        d: Dict[str, Optional[float]] = {}
        for j, name in enumerate(p.names[s]):
            d[name] = float(R[s, j])
        for name in p.be_names[s]:
            d[name] = None
        out.append(d)
    return out


# --------------------------------------------------------------------------
# backend seam
# --------------------------------------------------------------------------
#
# Everything above this line is the shared problem *construction* (packing,
# term tables); everything below drives fixed points through a pluggable
# solver.  A solver owns the two ascent primitives:
#
#   solve2d(p, kind, ...)    -> (S, N) WCRT bounds for a whole pack
#   solve_rows(p, rows, ...) -> (M,) bounds for Audsley candidate tests
#
# The build step lives *inside* the solver so a backend may lower the pack
# to its own array representation (the JAX backend fuses build + ascent
# into jitted kernels); the NumPy solver simply composes the module-level
# helpers.  Decision identity across solvers is pinned by
# tests/test_batch_equivalence.py.

class _NumpySolver:
    """The reference vectorized backend: host NumPy, explicit rounds."""

    name = "numpy"

    def solve2d(self, p: _Pack, kind: str, use_gpu_prio: bool,
                corrected: bool, analyzed: np.ndarray,
                gpu_prio: Optional[np.ndarray] = None,
                seeds: Optional[np.ndarray] = None,
                floor_mode: bool = False,
                decide: bool = False) -> np.ndarray:
        const, groups = _build2d(p, kind, use_gpu_prio, corrected,
                                 gpu_prio=gpu_prio, floor_mode=floor_mode)
        return _solve2d(p, const, groups, use_gpu_prio, analyzed,
                        seeds=seeds, decide=decide)

    def solve_rows(self, p: _Pack, rows: np.ndarray, cands: np.ndarray,
                   kind: str, corrected: bool, gp_rows: np.ndarray,
                   seeds: Optional[np.ndarray] = None) -> np.ndarray:
        cg = _build_rows(p, rows, cands, kind, corrected, gp_rows)
        return _solve_rows(p, rows, *cg, seeds=seeds)


_NUMPY_SOLVER = _NumpySolver()

#: Accepted ``backend=`` spellings.  "batch" is the pre-JAX name of the
#: NumPy backend (kept for callers of analysis.schedulable_many).
BACKENDS = ("numpy", "batch", "jax")


def get_solver(backend: str = "numpy"):
    """Resolve a ``backend=`` name to a solver object.  The JAX backend
    is imported lazily so environments without a working jax install can
    still use the NumPy path (core/batch_jax.py gates on import)."""
    if backend in ("numpy", "batch"):
        return _NUMPY_SOLVER
    if backend == "jax":
        from . import batch_jax
        return batch_jax.get_jax_solver()
    raise ValueError(
        f"unknown batch backend {backend!r} (expected one of {BACKENDS})")


def _solve_problems(problems: Sequence[Taskset], kind: str,
                    use_gpu_prio: bool, corrected: bool,
                    solver=_NUMPY_SOLVER,
                    seed_dicts: Optional[Sequence[Optional[Dict[str, float]]]]
                    = None) -> List[Dict[str, Optional[float]]]:
    """Batched full-vector solve of single-device problems.

    ``seed_dicts`` (one optional name → value map per problem) warm-start
    the lockstep ascent.  Every value must be a lower bound of that
    task's fixed point in *its* problem (see `analysis._iterate` for the
    soundness argument); absent tasks seed from zero."""
    p = _pack(problems)
    seeds = None
    if seed_dicts is not None and any(seed_dicts):
        seeds = np.zeros((p.S, p.N))
        for s, d in enumerate(seed_dicts):
            if not d:
                continue
            for j, name in enumerate(p.names[s]):
                v = d.get(name)
                if v is not None:
                    seeds[s, j] = v
    R = solver.solve2d(p, kind, use_gpu_prio, corrected, analyzed=p.valid,
                       seeds=seeds)
    return _unpack_dicts(p, R)


# --------------------------------------------------------------------------
# public entry points
# --------------------------------------------------------------------------

def batch_rta(kind: str, tasksets: Sequence[Taskset],
              use_gpu_prio: bool = False, corrected: bool = True,
              method: str = "fixed_point", backend: str = "numpy",
              seeds: Optional[Sequence[Optional[Dict[str, float]]]] = None
              ) -> List[Dict[str, Optional[float]]]:
    """Vectorized WCRT vectors for a batch of tasksets (any device
    counts), value-equivalent to the scalar RTA of the same kind with
    ``early_exit=False``.

    ``seeds`` warm-starts the ascent: one optional name → lower-bound
    map per taskset (the streaming-admission controller passes the
    previously admitted set's converged bounds — sound because its
    prefix tasksets only *add* interference).  Seeds apply to
    single-device tasksets only; multi-device entries solve cold — a
    bound merged across per-device projections is not a lower bound of
    each projection's fixed point, and the cross-device occupancy
    charges shift with the iterate (exactly why `analysis.cross_device`
    drops scalar seeds too).  Seeding never changes results, only the
    number of ascent rounds."""
    if kind not in KINDS:
        raise ValueError(f"unknown batch RTA kind {kind!r}")
    if method not in ("fixed_point", "heuristic"):
        raise ValueError(f"unknown multi-device method {method!r}")
    solver = get_solver(backend)
    if method == "heuristic" and kind in SUSPEND_KINDS:
        raise ValueError("method='heuristic' applies to busy-mode kinds")
    tasksets = list(tasksets)
    if seeds is not None and len(seeds) != len(tasksets):
        raise ValueError(
            f"seeds must align 1:1 with tasksets "
            f"({len(seeds)} != {len(tasksets)})")
    out: List[Optional[Dict[str, Optional[float]]]] = [None] * len(tasksets)
    simple: List[Tuple[int, Taskset]] = []
    folded: List[Tuple[int, int, Taskset]] = []
    cross: List[int] = []
    for i, ts in enumerate(tasksets):
        if ts.n_devices <= 1:
            simple.append((i, ts))
        elif kind in SUSPEND_KINDS or method == "heuristic":
            for d in range(ts.n_devices):
                folded.append((i, d, fold_to_device(ts, d)))
        else:
            cross.append(i)
    if method == "heuristic" and any(
            ts.n_devices > 1 for ts in tasksets):
        warnings.warn(
            "constant-charge per-device projection under busy-waiting is "
            "a heuristic, not a sound bound (cross-device busy-wait "
            "coupling); use the default method='fixed_point'",
            SoundnessWarning, stacklevel=2)
    probs = [ts for _, ts in simple] + [f for _, _, f in folded]
    if probs:
        seed_dicts = None
        if seeds is not None and simple:
            # folded (multi-device) problems always solve cold
            seed_dicts = ([seeds[i] for i, _ in simple]
                          + [None] * len(folded))
        dicts = _solve_problems(probs, kind, use_gpu_prio, corrected,
                                solver=solver, seed_dicts=seed_dicts)
        for (i, _), d in zip(simple, dicts[:len(simple)]):
            out[i] = d
        for (i, dev, _), Rd in zip(folded, dicts[len(simple):]):
            if out[i] is None:
                out[i] = {}
            own_dev = {t.name: t.device
                       for t in tasksets[i].tasks if t.uses_gpu}
            merge_device_bounds(out[i], Rd, own_dev, dev)
    if cross:
        for i, d in zip(cross, _crossfix_lockstep(
                kind, [tasksets[i] for i in cross], use_gpu_prio,
                corrected, solver=solver)):
            out[i] = d
    return out  # type: ignore[return-value]


def batch_rta_prefixes(kind: str, ts: Taskset, n_candidates: int,
                       backend: str = "numpy", corrected: bool = True,
                       seeds: Optional[Dict[str, float]] = None
                       ) -> List[Dict[str, Optional[float]]]:
    """WCRT dicts for the growing *prefix family* of one single-device
    taskset: result k analyzes base + candidates[:k+1], where the
    candidates are ``ts``'s last ``n_candidates`` RT tasks (in task
    order) and the base is everything before them.

    Value-identical to ``batch_rta(kind, [prefix_0, …])`` — `_pack`
    lays tasks out in taskset order, so the prefix problems share one
    column layout and differ only in a triangular ``valid`` mask.
    Packing therefore touches each task *once* (O(base + burst) Python
    work) and expands by numpy tiling, instead of re-walking the shared
    base for every prefix (O(burst × base)).  This is the
    streaming-admission fast path: `sched/admission.py` batches every
    arrival burst as a prefix family over its admitted set
    (DESIGN.md §11).

    ``seeds`` is a single name → lower-bound map applied to every
    prefix (the admitted set's converged bounds are lower bounds for
    all of them — each prefix only adds interference on top of the
    same base)."""
    if kind not in KINDS:
        raise ValueError(f"unknown batch RTA kind {kind!r}")
    rt = ts.rt_tasks
    S = int(n_candidates)
    if not 0 < S <= len(rt):
        raise ValueError(
            f"n_candidates must be in 1..{len(rt)} (got {n_candidates})")
    n_base = len(rt) - S
    p1 = _pack([ts])
    N = p1.N

    def tile(a: np.ndarray) -> np.ndarray:
        return np.repeat(a, S, axis=0)

    # triangular mask: row k keeps the base plus candidates[:k+1]; the
    # masked-out columns are reset to _pack's padding values so the
    # expanded pack is field-for-field the pack of the prefix tasksets
    valid = (np.arange(N)[None, :]
             < (n_base + 1 + np.arange(S))[:, None]) & tile(p1.valid)
    pads = {"C": 0.0, "G": 0.0, "Gm": 0.0, "Ge": 0.0, "C_best": 0.0,
            "Ge_best": 0.0, "eta_g": 0.0, "T": 1.0, "D": np.inf,
            "prio": -np.inf, "gpu_prio": -np.inf}
    kw = {f: np.where(valid, tile(getattr(p1, f)), pad)
          for f, pad in pads.items()}
    m3 = valid[:, :, None]
    names = p1.names[0]
    p = _Pack(
        S=S, N=N, valid=valid,
        uses_gpu=tile(p1.uses_gpu) & valid,
        cpu=np.where(valid, tile(p1.cpu), -1),
        eps=np.repeat(p1.eps, S), kcpu=np.repeat(p1.kcpu, S),
        cseg=np.where(m3, tile(p1.cseg), 0.0),
        cseg_m=tile(p1.cseg_m) & m3,
        gseg=np.where(m3, tile(p1.gseg), 0.0),
        gseg_m=tile(p1.gseg_m) & m3,
        names=[names[: n_base + 1 + k] for k in range(S)],
        be_names=[list(p1.be_names[0]) for _ in range(S)],
        **kw)
    seeds_arr = None
    if seeds:
        row = np.zeros(N)
        for j, nm in enumerate(names):
            v = seeds.get(nm)
            if v is not None:
                row[j] = v
        seeds_arr = np.where(valid, row[None, :], 0.0)
    solver = get_solver(backend)
    R = solver.solve2d(p, kind, False, corrected, analyzed=p.valid,
                       seeds=seeds_arr)
    return _unpack_dicts(p, R)


def _crossfix_lockstep(kind: str, tasksets: List[Taskset],
                       use_gpu_prio: bool, corrected: bool,
                       solver=_NUMPY_SOLVER
                       ) -> List[Dict[str, Optional[float]]]:
    """The `core/crossfix.py` outer occupancy iteration, run in lockstep
    across a batch of multi-device busy-mode tasksets: each outer round
    batches *every* still-active taskset's per-device projections into
    one inner array fixed point.  Per-taskset trajectories are identical
    to ``cross_fixed_point(..., early_exit=False)`` — the occupancy step
    is the shared ``occupancy_vector`` and tasksets iterate
    independently."""
    from .crossfix import MAX_OUTER, occupancy_vector, uncontended_occupancy
    occ_kind = _OCC_KIND[kind]
    n = len(tasksets)
    occ = [{h.name: uncontended_occupancy(h, ts.epsilon)
            for h in ts.tasks if h.uses_gpu} for ts in tasksets]
    R: List[Dict[str, Optional[float]]] = [{} for _ in range(n)]

    def project(idxs: List[int]) -> None:
        probs, owner = [], []
        for i in idxs:
            for d in range(tasksets[i].n_devices):
                probs.append(fold_to_device(tasksets[i], d,
                                            occupancy=occ[i]))
                owner.append((i, d))
        dicts = _solve_problems(probs, kind, use_gpu_prio, corrected,
                                solver=solver)
        for i in idxs:
            R[i] = {}
        for (i, d), Rd in zip(owner, dicts):
            own_dev = {t.name: t.device
                       for t in tasksets[i].tasks if t.uses_gpu}
            merge_device_bounds(R[i], Rd, own_dev, d)

    active = list(range(n))
    project(active)
    for _ in range(MAX_OUTER - 1):
        if not active:
            break
        still = []
        for i in active:
            occ_new = occupancy_vector(tasksets[i], R[i], occ_kind,
                                       use_gpu_prio)
            if all(abs(occ_new[k] - occ[i][k]) < _EPS for k in occ[i]):
                continue  # converged: R[i] is the joint bound
            occ[i] = occ_new
            still.append(i)
        active = still
        if active:
            project(active)
    for i in active:  # outer cap hit: conservative divergence
        rt = {t.name for t in tasksets[i].rt_tasks}
        R[i] = {k: (math.inf if k in rt else v) for k, v in R[i].items()}
    return R


def batch_schedulable(kind: str, tasksets: Sequence[Taskset],
                      use_gpu_prio: bool = False, corrected: bool = True,
                      method: str = "fixed_point",
                      backend: str = "numpy") -> List[bool]:
    """Decision twin of ``analysis.schedulable`` over a batch."""
    tasksets = list(tasksets)
    dicts = batch_rta(kind, tasksets, use_gpu_prio=use_gpu_prio,
                      corrected=corrected, method=method, backend=backend)
    out = []
    for ts, R in zip(tasksets, dicts):
        ok = True
        for t in ts.rt_tasks:
            r = R.get(t.name, math.inf)
            if r is None or math.isinf(r) or r > t.deadline + _EPS:
                ok = False
                break
        out.append(ok)
    return out


# --------------------------------------------------------------------------
# lockstep Audsley assignment
# --------------------------------------------------------------------------

def _build_rows(p: _Pack, rows: np.ndarray, cands: np.ndarray,
                kind: str, corrected: bool, gp_rows: np.ndarray):
    """Single-task recurrences (GPU-priority jitters) for one candidate
    column per row — the Audsley candidate test collapsed to (M, N)
    arrays over the interferer axis only.  (Floor recurrences go through
    ``_build2d(floor_mode=True)``; there is deliberately no second
    floor-construction here.)

    KEEP IN SYNC with ``_build2d``: this is a deliberate perf
    specialization of the same Lemma 2/3/4/6/7 term tables (rebuilding
    the (S,N,N) matrices every Audsley round would dominate the
    search); any recurrence change must land in both builders — the
    differential suite's pipeline tests exercise this path for every
    kind."""
    m = np.arange(len(rows))
    V = p.valid[rows]
    prio = p.prio[rows]
    cpu = p.cpu[rows]
    ug = p.uses_gpu[rows]
    T = p.T[rows]
    eps = p.eps[rows]
    C = p.C[rows]
    G = p.G[rows]
    Gm = p.Gm[rows]
    Ge = p.Ge[rows]
    C_best = p.C_best[rows]
    Ge_best = p.Ge_best[rows]
    eta_g = p.eta_g[rows]
    kcpu = p.kcpu[rows]

    prio_i = prio[m, cands][:, None]
    cpu_i = cpu[m, cands][:, None]
    gp_i = gp_rows[m, cands][:, None]
    ug_i = ug[m, cands]
    HPP = V & (cpu == cpu_i) & (prio > prio_i)
    HPg = V & (gp_rows > gp_i)
    remote = HPg & ug & ~HPP

    D_i = p.D[rows][m, cands]
    eps_i = eps
    C_i = C[m, cands]
    G_i = G[m, cands]
    eta_i = eta_g[m, cands]

    if kind == "kthread_busy":
        x = ug_i | (cpu_i[:, 0] == kcpu.astype(np.int64))
        if corrected:
            x = x | (HPP & ug).any(axis=-1)
        const = C_i + G_i + np.where(x, 2.0 * eps_i, 0.0)
        kmask = HPg & ug
        groups = [
            (np.where(kmask & x[:, None], 2.0 * eps[:, None], 0.0),
             "job", None),
            (np.where(HPP, C + G, 0.0), None, None),
            (np.where(remote, C + G, 0.0), "job", None),
        ]
        return const, groups, T, D_i

    gstar_i = G_i + 2.0 * eps_i * eta_i
    const = C_i + gstar_i + (eta_i + 1.0) * eps_i
    gstar_h = G + 2.0 * eps[:, None] * eta_g
    gestar_h = Ge + 2.0 * eps[:, None] * eta_g
    gmstar_h = Gm + 2.0 * eps[:, None] * eta_g
    HPPc = HPP & ~ug
    HPPg = HPP & ug
    improved = kind in _IMPROVED
    Ocg = Ogc = None
    if improved:
        T3 = T[:, None, :]
        mgpu = HPg & ug
        w_g = np.where(mgpu, Ge_best, 0.0)[:, None, :]
        live_g = p.gseg_m[rows][m, cands]
        bxg = _bx_lfp(p.gseg[rows][m, cands], w_g, T3, live_g)
        fl = np.maximum(_floor_pos(bxg[..., None], T3) - 1.0, 0.0)
        fl = np.where(live_g[..., None], fl, 0.0)
        Ocg = (fl * C_best[:, None, :]).sum(axis=1)
        w_c = np.where(HPP, C_best, 0.0)[:, None, :]
        live_c = p.cseg_m[rows][m, cands]
        bxc = _bx_lfp(p.cseg[rows][m, cands], w_c, T3, live_c)
        flc = np.maximum(_floor_pos(bxc[..., None], T3) - 1.0, 0.0)
        flc = np.where(live_c[..., None], flc, 0.0)
        Ogc = (flc * Ge_best[:, None, :]).sum(axis=1)

    if kind in ("ioctl_busy", "ioctl_busy_improved"):
        stretch = (eta_g + 1.0) * eps[:, None] if corrected else 0.0
        groups = [
            (np.where(HPPc, C, 0.0), None, Ocg),
            (np.where(HPPg, C + gstar_h + stretch, 0.0), None,
             Ocg + Ogc if improved else None),
            (np.where(remote, gestar_h, 0.0), "gpu", Ogc),
        ]
    else:
        ug_col = ug_i[:, None]
        groups = [
            (np.where(HPPc, C, 0.0), None, Ocg),
            (np.where(HPPg, C + gmstar_h, 0.0), "cpu", Ocg),
            (np.where(HPPg & ug_col, Ge, 0.0), "gpu", Ogc),
            (np.where(remote & ug_col, gestar_h, 0.0), "gpu", Ogc),
        ]
    return const, groups, T, D_i


def _solve_rows(p: _Pack, rows: np.ndarray, const, groups, T, D_i,
                seeds: Optional[np.ndarray] = None) -> np.ndarray:
    """(M,)-vector fixed point for the single-task recurrences of
    ``_build_rows`` (deadline jitters — elements are independent)."""
    V = p.valid[rows]
    D_h = np.where(V, np.where(np.isinf(p.D[rows]), 0.0, p.D[rows]), 0.0)
    offs = {"job": p.C[rows] + p.G[rows], "gpu": p.Ge[rows],
            "cpu": p.C[rows] + p.Gm[rows]}
    used = {jit for _, jit, _ in groups if jit is not None}
    J = {k: np.maximum(D_h - offs[k], 0.0) for k in used}
    R = np.zeros_like(const)
    if seeds is not None:
        R = seeds.copy()
    act = np.isfinite(R)
    R = np.where(act, R, np.inf)
    for _ in range(MAX_ITERS + 1):
        if not act.any():
            break
        Rsafe = np.where(np.isfinite(R), R, 0.0)
        total = const.copy()
        for W, jit, O in groups:
            X = Rsafe[:, None] + (J[jit] if jit is not None else 0.0)
            term = _ceil_pos(X, T) * W
            if O is not None:
                term = np.maximum(term - O, 0.0)
            total += term.sum(axis=-1)
        Rnew = np.where(act, total, R)
        newinf = act & (Rnew > D_i + _EPS)
        delta = np.abs(np.where(act, Rnew, 0.0) - np.where(act, R, 0.0))
        moved = act & ~newinf & (delta >= _EPS)
        R = np.where(newinf, np.inf, Rnew)
        act = act & ~newinf & moved
    else:
        R = np.where(act, np.inf, R)
    return R


class _AudState:
    """Per-taskset Audsley progress for the lockstep search (decision
    flow identical to audsley.assign_gpu_priorities)."""

    def __init__(self, s: int, p: _Pack):
        self.s = s
        self.result: Optional[bool] = None
        self.need_full = False
        self.trial: Optional[int] = None
        self.old_gp = 0.0
        self.placedR: Dict[int, float] = {}
        prio = p.prio[s]
        gpu_cols = [j for j in range(p.N)
                    if p.valid[s, j] and p.uses_gpu[s, j]]
        if not gpu_cols:
            self.result = False  # scalar: no GPU tasks -> None -> reject
            return
        self.levels = sorted(float(prio[j]) for j in gpu_cols)
        self.top = max(self.levels) + 1.0
        self.gp = p.gpu_prio[s].copy()
        for j in gpu_cols:
            self.gp[j] = self.top + prio[j]  # provisional: above all levels
        self.unassigned = set(gpu_cols)
        self.level_idx = 0
        self.queue = self._eligible(p)

    def _eligible(self, p: _Pack) -> List[int]:
        """Lowest-CPU-priority unassigned GPU task per core, by priority."""
        prio = p.prio[self.s]
        cpu = p.cpu[self.s]
        lowest: Dict[int, int] = {}
        for j in sorted(self.unassigned, key=lambda j: prio[j]):
            lowest.setdefault(int(cpu[j]), j)
        return sorted(lowest.values(), key=lambda j: prio[j])


def _audsley_lockstep(kind: str, p: _Pack, corrected: bool,
                      solver=_NUMPY_SOLVER) -> List[bool]:
    """Audsley GPU-priority assignment for a pack of single-device
    tasksets, with every active taskset's current candidate test batched
    into one vector fixed point per round, floor-seeded (DESIGN.md §5).
    The closing full-set tests are independent of the level search, so
    they are deferred and run as one batched solve at the end."""
    states = [_AudState(s, p) for s in range(p.S)]

    # Floor bounds: one vectorized pre-solve of every candidate's
    # empty-remote / overlap-superset recurrence (use_gpu_prio jitters).
    # Valid seed at every level; an inf floor proves the candidate can
    # never pass (its tests are skipped, like the scalar warm start).
    cand_mask = p.valid & p.uses_gpu
    floor = solver.solve2d(p, kind, True, corrected, analyzed=cand_mask,
                           floor_mode=True)

    while True:
        trials: List[_AudState] = []
        for st in states:
            if st.result is not None or st.need_full:
                continue
            while st.result is None and st.trial is None:
                if not st.queue:
                    st.result = False
                    break
                cand = st.queue[0]
                if math.isinf(floor[st.s, cand]):
                    st.queue.pop(0)  # cannot pass at any level
                    continue
                st.trial = cand
                st.old_gp = st.gp[cand]
                st.gp[cand] = st.levels[st.level_idx]
            if st.trial is not None:
                trials.append(st)
        if not trials:
            break
        rows = np.array([st.s for st in trials])
        cands = np.array([st.trial for st in trials])
        gp_rows = np.stack([st.gp for st in trials])
        seeds = floor[rows, cands]
        R = solver.solve_rows(p, rows, cands, kind, corrected, gp_rows,
                              seeds=seeds)
        for st, r in zip(trials, R):
            cand = st.trial
            st.trial = None
            if math.isfinite(r):
                st.placedR[cand] = float(r)
                st.unassigned.remove(cand)
                st.level_idx += 1
                if st.level_idx >= len(st.levels):
                    st.need_full = True
                else:
                    st.queue = st._eligible(p)
            else:
                st.gp[cand] = st.old_gp
                st.queue.pop(0)
                if not st.queue:
                    st.result = False

    full = [st for st in states if st.need_full]
    if full:
        sub = p.take([st.s for st in full])
        gp = np.stack([st.gp for st in full])
        seeds = np.zeros((len(full), p.N))
        for k, st in enumerate(full):
            for col, r in st.placedR.items():
                seeds[k, col] = r  # placement bound == final bound
        R = solver.solve2d(sub, kind, True, corrected, analyzed=sub.valid,
                           gpu_prio=gp, seeds=seeds)
        for k, st in enumerate(full):
            st.result = bool(np.isfinite(R[k][sub.valid[k]]).all())
    return [bool(st.result) for st in states]


def batch_schedulable_with_assignment(
        kind: str, tasksets: Sequence[Taskset],
        method: str = "fixed_point", corrected: bool = True,
        backend: str = "numpy") -> List[bool]:
    """The Sec. VII-A evaluation pipeline over a batch: RM-priority test
    first, Audsley GPU-priority retry for the rejected sets.  Single-
    device retries run the lockstep Audsley; multi-device retries fall
    back to the scalar search (the joint busy fixed point has no
    per-candidate independence to batch over — core/audsley.py)."""
    return batch_accept_many({"_": (kind, method)}, tasksets,
                             corrected=corrected, backend=backend)["_"]


def batch_accept_many(specs: Dict[str, Tuple[str, str]],
                      tasksets: Sequence[Taskset],
                      corrected: bool = True,
                      backend: str = "numpy") -> Dict[str, List[bool]]:
    """Run several named ``(kind, method)`` evaluation pipelines over one
    batch, sharing the packed arrays across methods (the sweep driver's
    entry point: packing is per-batch Python work, everything after is
    array code)."""
    tasksets = list(tasksets)
    for name, (kind, method) in specs.items():
        # eager, even when every taskset is single-device (where method
        # is moot) — a typo'd spec must not first surface on a
        # multi-GPU platform (same contract as the cross_device wrapper)
        if kind not in KINDS:
            raise ValueError(f"unknown batch RTA kind {kind!r}")
        if method not in ("fixed_point", "heuristic"):
            raise ValueError(f"unknown multi-device method {method!r}")
        if method == "heuristic" and kind in SUSPEND_KINDS:
            raise ValueError(
                "method='heuristic' applies to busy-mode kinds")
    solver = get_solver(backend)
    single = [i for i, ts in enumerate(tasksets) if ts.n_devices <= 1]
    multi = [i for i, ts in enumerate(tasksets) if ts.n_devices > 1]
    pack = _pack([tasksets[i] for i in single]) if single else None
    out: Dict[str, List[bool]] = {}
    for name, (kind, method) in specs.items():
        acc = [False] * len(tasksets)
        if single:
            R = solver.solve2d(pack, kind, False, corrected,
                               analyzed=pack.valid, decide=True)
            ok = np.isfinite(np.where(pack.valid, R, 0.0)).all(axis=1)
            rej = [k for k in range(pack.S) if not ok[k]]
            if rej:
                res = _audsley_lockstep(kind, pack.take(rej), corrected,
                                        solver=solver)
                for k, r in zip(rej, res):
                    ok[k] = r
            for k, i in enumerate(single):
                acc[i] = bool(ok[k])
        if multi:
            # one batched RM test for the whole multi-device subset (the
            # crossfix lockstep batches their projections); only the
            # Audsley retries fall back to the scalar search
            ok_multi = batch_schedulable(
                kind, [tasksets[i] for i in multi], use_gpu_prio=False,
                corrected=corrected, method=method, backend=backend)
            rta = scalar_rta(kind, method)
            for i, ok in zip(multi, ok_multi):
                acc[i] = bool(ok) or (
                    assign_gpu_priorities(tasksets[i], rta) is not None)
        out[name] = acc
    return out
