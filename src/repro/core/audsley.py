"""GPU segment priority assignment (Sec. V-C) via Audsley's OPA.

If the schedulability test fails with default priorities (GPU priority ==
CPU priority), we search for a GPU-segment priority assignment, iterating
priority levels from lowest to highest.  Constraints from the paper:

  * Only the GPU segments get new priorities; CPU scheduling is untouched.
  * For tasks on the same CPU core the relative GPU-priority order must
    equal the relative CPU-priority order (deadlock prevention) -- so when
    assigning the lowest remaining GPU priority level, only the
    lowest-CPU-priority unassigned GPU-using task of each core is eligible.
  * During assignment, jitters use D_h instead of R_h (Sec. VI-B), which
    makes each per-task test depend only on the *set* of higher-GPU-priority
    tasks -- the property OPA requires.

The GPU priority *values* are the sorted CPU-priority values of the GPU-using
real-time tasks, so they remain comparable with the (unchanged) gpu_priority
of CPU-only and best-effort tasks.

Warm-started assignment (DESIGN.md §5): a candidate that fails a level is
re-tested at every subsequent level, and each test historically restarted
its fixed point from zero.  Since the recurrences are monotone, iterating
from any seed *at or below* the least fixed point is result-identical and
skips the early ascent.  Note the direction: as levels rise the candidate's
interference set *shrinks*, so the converged bound from a previous level
sits at or ABOVE the new fixed point and is NOT a sound seed.  Instead we
seed every test of a candidate with its *floor bound* — the converged
response time with an empty remote-interference set (candidate provisionally
above every GPU priority) and, for the overlap-improved analyses, the
all-GPU-tasks overlap superset (``overlap_floor=True``), whose larger
deduction keeps the floor recurrence a pointwise lower bound of the
recurrence at any level.  The floor is level- and state-independent, is
computed once per candidate (lazily, on its first test), prunes candidates
whose floor already misses the deadline, and — because under deadline-based
jitters a task's recurrence depends only on the *set* of tasks above it —
each placed candidate's converged bound equals its bound under the final
assignment, so the closing full-set test is seeded with the placement
bounds.  Warm-starting applies on single-device tasksets only: under the
multi-device busy fixed point (`core/crossfix.py`) the folded occupancy
charges shift with GPU priorities and no per-candidate floor is available.
"""
from __future__ import annotations

import dataclasses
import math
from typing import Callable, Dict, Optional, Tuple

from .analysis import supports_kwarg
from .task_model import Task, Taskset


def _test_task(ts: Taskset, name: str, rta: Callable,
               seeds: Optional[Dict[str, float]] = None,
               **kw) -> Tuple[bool, Optional[float]]:
    """Test one task's bound; returns (passes, converged bound)."""
    if supports_kwarg(rta, "only"):
        # With use_gpu_prio the jitters are deadline-based (the OPA
        # property), so on single-device / suspend paths the candidate's
        # bound alone is enough and ``only`` prunes the rest.  Under the
        # multi-device busy fixed point (core/crossfix.py) a task's bound
        # also depends on the other tasks' occupancy iterates, so the
        # joint analysis ignores ``only`` and computes the full vector —
        # the per-candidate test stays correct (we still only read the
        # candidate's bound) and _full_test gates final acceptance.
        kw.setdefault("only", name)
    if seeds and supports_kwarg(rta, "seeds"):
        kw.setdefault("seeds", seeds)
    R = rta(ts, use_gpu_prio=True, **kw)
    t = next(t for t in ts.tasks if t.name == name)
    r = R[name]
    ok = r is not None and not math.isinf(r) and r <= t.deadline + 1e-9
    return ok, r


def _full_test(ts: Taskset, rta: Callable,
               seeds: Optional[Dict[str, float]] = None, **kw) -> bool:
    if supports_kwarg(rta, "early_exit"):
        kw.setdefault("early_exit", True)
    if seeds and supports_kwarg(rta, "seeds"):
        kw.setdefault("seeds", seeds)
    R = rta(ts, use_gpu_prio=True, **kw)
    return all(not math.isinf(R.get(t.name, math.inf))
               and R[t.name] <= t.deadline + 1e-9 for t in ts.rt_tasks)


def assign_gpu_priorities(ts: Taskset, rta: Callable,
                          warm_start: bool = True) -> Optional[Taskset]:
    """Audsley assignment of GPU-segment priorities.

    Returns a new Taskset with gpu_priority fields set if one is found under
    which every real-time task passes ``rta`` (with use_gpu_prio=True), else
    None.  ``warm_start`` enables the result-identical floor-seeded
    candidate tests (module docstring); disable it to run every fixed
    point from zero (the reference behaviour, kept for differential
    testing).
    """
    gpu_tasks = sorted([t for t in ts.rt_tasks if t.uses_gpu],
                       key=lambda t: t.priority)
    if not gpu_tasks:
        return None
    levels = sorted(t.priority for t in gpu_tasks)  # reuse CPU prio values

    # Work on copies so the input taskset is untouched.
    pool = {t.name: dataclasses.replace(t) for t in ts.tasks}
    work = Taskset(tasks=list(pool.values()), n_cpus=ts.n_cpus,
                   epsilon=ts.epsilon, kthread_cpu=ts.kthread_cpu,
                   n_devices=ts.n_devices)
    unassigned = [pool[t.name] for t in gpu_tasks]
    # Unassigned tasks provisionally sit above every level (OPA invariant).
    top = max(levels) + 1
    for t in unassigned:
        t.gpu_priority = top + t.priority  # unique, above all levels

    warm = (warm_start and ts.n_devices == 1
            and supports_kwarg(rta, "seeds"))
    ceiling = top + max(t.priority for t in gpu_tasks) + 1
    floors: Dict[str, float] = {}    # candidate -> floor bound (seed)
    placed_R: Dict[str, float] = {}  # candidate -> bound at placement

    def candidate_floor(cand: Task) -> float:
        """Converged bound with an empty remote set (candidate above every
        GPU priority) and, where supported, the overlap floor — a lower
        bound of the candidate's fixed point at every level."""
        kw = {}
        if supports_kwarg(rta, "overlap_floor"):
            kw["overlap_floor"] = True
        old = cand.gpu_priority
        cand.gpu_priority = ceiling
        try:
            _, r = _test_task(work, cand.name, rta, **kw)
        finally:
            cand.gpu_priority = old
        return math.inf if r is None else r

    for level in levels:  # lowest first
        # Eligible: lowest-CPU-priority unassigned GPU task per core.
        lowest_per_core: Dict[int, Task] = {}
        for t in sorted(unassigned, key=lambda t: t.priority):
            lowest_per_core.setdefault(t.cpu, t)
        placed = None
        for cand in sorted(lowest_per_core.values(), key=lambda t: t.priority):
            seeds = None
            if warm:
                if cand.name not in floors:
                    floors[cand.name] = candidate_floor(cand)
                if math.isinf(floors[cand.name]):
                    continue  # floor already misses: fails at every level
                seeds = {cand.name: floors[cand.name]}
            old = cand.gpu_priority
            cand.gpu_priority = level
            ok, r = _test_task(work, cand.name, rta, seeds=seeds)
            if ok:
                placed = cand
                placed_R[cand.name] = r
                break
            cand.gpu_priority = old
        if placed is None:
            return None
        unassigned.remove(placed)

    # CPU-only tasks' schedulability can also shift with GPU priorities
    # (busy-wait chains); verify the whole set before accepting.  Each
    # placed candidate's final-assignment bound equals its placement bound
    # (set-identical interference under deadline jitters), so those seed
    # the full test.
    if _full_test(work, rta, seeds=placed_R if warm else None):
        return work
    return None


def schedulable_with_assignment(ts: Taskset, rta: Callable) -> bool:
    """The evaluation pipeline of Sec. VII-A: test with default (RM)
    priorities first; on failure, retry with Audsley GPU priorities."""
    from .analysis import schedulable
    if schedulable(ts, rta):
        return True
    return assign_gpu_priorities(ts, rta) is not None
