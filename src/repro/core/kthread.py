"""Kernel-thread approach (Sec. V-A, Algorithm 1).

A kernel thread on a designated core polls for task state changes every
sub-quantum.  On a change it updates the runlist:

    if a highest-priority GPU-using ready real-time task tau_h exists:
        keep only tau_h's TSGs on the runlists        (GPU reserved for tau_h)
    else:
        add all active TSGs back                      (best-effort progress)

Preemption is *job-granular*: the GPU stays reserved for tau_h across its
whole job, idling during tau_h's CPU segments (the under-utilization
discussed in Sec. V-A).  Tasks must busy-wait during pure GPU execution
(self-suspension would be misread as a state change), so the simulator
forces mode='busy'.

Cost model (aligned with Lemmas 1/2):
  * A runlist rewrite triggered by a *job-level event* (release/completion
    of a GPU-using task) that changes the reservation costs epsilon on the
    kernel thread's core at top priority and pauses the GPU (TSG eviction +
    context switch) — exactly the events Lemma 1 counts (2*eps per
    higher-priority GPU job + 2*eps for the task itself).
  * A state change whose re-evaluation leaves the reservation unchanged
    (e.g. a lower-priority release under a reserved higher-priority task)
    costs only the negligible polling check (footnote 3): no epsilon, no
    GPU interruption.
  * "Ready" means *actually scheduled*: a reserved task that is preempted
    on its own core during a CPU phase hands the (idle) GPU over to the
    next eligible task for free, and reacquires it when rescheduled — an
    idle-GPU runlist write, with no running context to evict.  A task whose
    pure-GPU work is in flight stays eligible while preempted (the kernel
    continues without CPU help; busy-wait resumption is charged to the
    task itself).  Without this, a reserved task's own local preemptors
    (possibly lower-priority than a remote victim) would extend the
    victim's blocking beyond the (C_h + G_h) per-job charge of Lemma 2.

The reservation rule itself (line 4) is the shared ``pick_reserved`` from
`core/policy.py` — the runtime executor's scheduler thread applies the same
function to live RTJobs (DESIGN.md §2).
"""
from __future__ import annotations

from typing import TYPE_CHECKING, Dict, Optional, Sequence, Tuple

from .analysis import kthread_busy_rta
from .policy import SchedulingPolicy, pick_reserved, register_policy
from .runlist import Runlist, TSG

if TYPE_CHECKING:  # pragma: no cover
    from .simulator import Job


class KernelThreadPolicy(SchedulingPolicy):
    name = "kthread"
    requires_busy_wait = True
    wants_poll_thread = True
    recheck_winners_after_notify = True

    def __init__(self, poll_interval: float = 0.0, rr_slice: float = 2.0):
        """poll_interval=0 models event-driven detection (the paper uses a
        1 ms polling loop whose pure check cost is negligible; >0 adds the
        detection latency)."""
        self.poll_interval = poll_interval
        self.runlist = Runlist(rr_slice)
        self.tsgs: dict[int, TSG] = {}
        self.reserved: Optional["Job"] = None
        self.job_event = False       # release/completion of a GPU-using task
        self.update_left = 0.0       # epsilon remaining for in-flight rewrite
        self.next_poll = 0.0
        self._last_winners: Dict[int, Optional["Job"]] = {}
        self._runtime_stale = False  # runlist evicted, awaiting next poll

    # ---- Algorithm 1 -------------------------------------------------------
    def _eligible(self, j: "Job") -> bool:
        """Ready = scheduled on its core, or its pure-GPU phase is current
        (submitted kernels run without CPU help)."""
        if j.current_kind() == "ge":
            return True
        return self._last_winners.get(j.task.cpu) is j

    def _pick_reserved(self) -> Optional["Job"]:
        """Line 4: highest-priority GPU-using ready real-time task on this
        policy's device (shared rule: policy.pick_reserved)."""
        cands = [j for j in self.sim.active_jobs()
                 if j.task.uses_gpu and not j.done
                 and j.task.device == self.device and self._eligible(j)]
        return pick_reserved(cands)

    def _apply(self, tau_h: Optional["Job"]) -> None:
        """Lines 5-9: reserve tau_h's TSGs, or re-admit all active TSGs."""
        self.reserved = tau_h
        if tau_h is not None:
            for tsg in self.tsgs.values():
                if tsg.job is tau_h:
                    self.runlist.add(tsg)
                else:
                    self.runlist.remove(tsg)
        else:
            for tsg in self.tsgs.values():
                self.runlist.add(tsg)

    # ---- scheduling-decision loop (driven by the engine) -------------------
    def notify_winners(self, winners: Dict[int, Optional["Job"]]) -> None:
        self._last_winners = dict(winners)
        if self.update_left > 0.0:
            return  # rewrite in flight; decision re-derived at completion
        if self.poll_interval > 0.0 and self.next_poll > 1e-12:
            return  # change is noticed at the next polling tick
        desired = self._pick_reserved()
        if desired is self.reserved:
            self._apply(desired)         # silent membership bookkeeping
            self.job_event = False
            return
        if self.job_event:
            self.job_event = False
            self.update_left = self.sim.ts.epsilon  # costly rewrite
            if self.sim.ts.epsilon <= 0.0:
                self._apply(self._pick_reserved())
        else:
            self._apply(desired)         # free idle-GPU handover

    def on_job_release(self, job: "Job") -> None:
        if job.task.uses_gpu:
            self.tsgs[job.uid] = TSG(job=job, priority=job.task.gpu_priority)
            self.job_event = True

    def on_job_complete(self, job: "Job") -> None:
        tsg = self.tsgs.pop(job.uid, None)
        if tsg:
            self.runlist.remove(tsg)
        if self.reserved is job:
            self.reserved = None
        if job.task.uses_gpu:
            self.job_event = True

    # ---- time advancement ---------------------------------------------------
    def gpu_rr_advance(self, dt: float) -> None:
        if self.update_left > 0.0:
            self.update_left -= dt
            if self.update_left <= 1e-12:
                self.update_left = 0.0
                self._apply(self._pick_reserved())
        if self.poll_interval > 0.0:
            self.next_poll -= dt
            if self.next_poll <= 1e-12:
                self.next_poll = self.poll_interval
        if self.reserved is None and len(self.runlist.runnable()) > 1:
            self.runlist.advance(dt)

    def next_gpu_event(self) -> float:
        ev = float("inf")
        if self.update_left > 0.0:
            ev = min(ev, self.update_left)
        if self.poll_interval > 0.0:
            ev = min(ev, max(self.next_poll, 1e-9))
        if self.reserved is None and len(self.runlist.runnable()) > 1:
            ev = min(ev, max(self.runlist.slice_left, 1e-9))
        return ev

    # ---- resource arbitration ----------------------------------------------
    def gpu_owner(self) -> Optional["Job"]:
        if self.update_left > 0.0:
            return None  # TSG eviction / context switch in progress
        if self.reserved is not None:
            return self.reserved if self.reserved.wants_gpu() else None
        cur = self.runlist.current()
        return cur.job if cur else None

    def kthread_cpu_busy(self) -> bool:
        """The kernel thread occupies its core (at top priority) while
        performing a runlist rewrite."""
        return self.update_left > 0.0

    def occupied_cores(self) -> Tuple[int, ...]:
        if self.kthread_cpu_busy() \
                and self.sim.ts.kthread_cpu < self.sim.ts.n_cpus:
            return (self.sim.ts.kthread_cpu,)
        return ()

    # ---- runtime face (scheduler thread in sched.executor) -----------------
    def runtime_pick(self, active_jobs: Sequence):
        """One polling-loop evaluation over live jobs: the device is
        reserved for the highest-priority active real-time job (job
        granularity — opaque jobs, no code changes)."""
        return pick_reserved(active_jobs)

    def runtime_apply(self, decision) -> bool:
        changed = decision is not self.reserved or self._runtime_stale
        self.reserved = decision
        self._runtime_stale = False
        return changed

    def runtime_on_complete(self, job) -> None:
        if self.reserved is job:
            # the reservation holder is gone, but Algorithm 1 only
            # rewrites runlists from the kernel thread: other TSGs stay
            # evicted until the next poll re-admits them.  Without this
            # stale window a best-effort job could dispatch between the
            # completion and the poll while a ready RT job is still
            # blocked — a priority-inversion window the simulator does
            # not have (found by tests/conformance.py).
            self.reserved = None
            self._runtime_stale = True

    def runtime_admitted(self, job) -> bool:
        if self.reserved is job:
            return True
        return self.reserved is None and not self._runtime_stale


# The busy-mode RTA is multi-device sound: on n_devices > 1 it resolves
# to the cross-device fixed point (core/crossfix.py), so admission over
# this registry entry carries the analytic guarantee on any platform.
register_policy("kthread", KernelThreadPolicy,
                "Algorithm 1: kernel-thread job-granular reservation",
                rtas={"busy": kthread_busy_rta})
