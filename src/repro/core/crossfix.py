"""Cross-device busy-wait fixed point (DESIGN.md §4).

Multi-device busy-wait analysis cannot be decomposed per device: a task
busy-waiting on device A occupies its CPU core for as long as it is
queued behind device-A contention, and that occupancy is CPU demand in
*every other* device's projection.  The folded per-device constant
``G + (3*eta^g + 1)*eps`` only covers the uncontended access (valid under
self-suspension, where the core is yielded while queued) — under
busy-waiting it silently under-charges, which is exactly the
cross-resource coupling GCAPS (arXiv:2406.05221) warns about.

This module closes the loop with a joint fixed point over all devices:

  * **Iteration variables** — the per-task WCRT vector ``R`` and the
    per-GPU-task *core-occupancy* vector ``occ`` (the CPU time a job
    occupies its core beyond its plain CPU segments: executing GPU
    segments, spinning behind same-device rivals, and runlist-update /
    rt_mutex blocking).
  * **Seed** — ``occ^0`` is the uncontended occupancy, i.e. exactly the
    charge that is valid in suspension mode; ``R^0`` is therefore the
    suspension-equivalent per-device bound.
  * **Step** — ``R^{k+1}`` re-runs the single-device RTAs on projections
    folded with ``occ^k``; ``occ^{k+1}`` re-derives each task's occupancy
    from the *current iterate* ``R^{k+1}`` (the number of same-device
    rival jobs that can hold the device while the task spins is windowed
    by its own response time).
  * **Monotonicity** — the inner RTAs are monotone in the folded charges
    and ``occ`` is monotone in ``R`` (ceil terms), so the iteration
    ascends from the suspension-mode seed to the least fixed point above
    it; any fixed point reached upper-bounds the true WCRT by the
    standard RTA argument.
  * **Termination / divergence** — ``occ`` only moves through ceil jumps
    and every inner bound is capped at its deadline, so the iteration
    either converges in finitely many outer rounds or drives some task
    past its deadline (``inf`` — the set is unschedulable).  A round cap
    backstops both; hitting it reports divergence conservatively.

The public entry point is :func:`cross_fixed_point`; `core.analysis`
wires it behind the busy-mode RTAs via the ``cross_device`` decorator.
"""
from __future__ import annotations

import math
from typing import Callable, Dict, Optional, Tuple

from .task_model import Task, Taskset

MAX_OUTER = 64
_EPS = 1e-9


def device_rivals(
    ts: Taskset, h: Task, use_gpu_prio: bool = False
) -> list[Task]:
    """GPU-using tasks that can hold ``h``'s device while ``h`` spins.

    Device arbitration (Algorithm 1's reservation, Algorithm 2's
    task_running) is governed by GPU-segment priorities; ``use_gpu_prio``
    selects the Sec. VI-B ordering, matching ``_gpu_hp_remote``.
    """
    return [
        k
        for k in ts.hp(h, by_gpu=use_gpu_prio)
        if k.uses_gpu and k.device == h.device
    ]


def uncontended_occupancy(h: Task, eps: float) -> float:
    """Core occupancy of one job of ``h`` beyond ``C_h`` with an
    uncontended device: ``G + 2*eps*eta^g`` eviction stretch plus
    ``(eta^g + 1)*eps`` runlist-update blocking.  This is the suspension
    -mode-valid charge — the seed of the busy-wait iteration."""
    return h.G + (3 * h.eta_g + 1) * eps


def busy_occupancy(
    ts: Taskset,
    h: Task,
    window: float,
    R: Dict[str, Optional[float]],
    occ_kind: str,
    use_gpu_prio: bool = False,
) -> float:
    """Worst-case core occupancy of one job of ``h`` beyond ``C_h`` under
    busy-waiting, given the current WCRT iterate.

    On top of the uncontended occupancy, every same-device rival job that
    arrives in ``h``'s response window can hold the device while ``h``
    spins on its core:

      * ``occ_kind == "kthread"`` — Algorithm 1 reserves the device at
        *job* granularity, so a rival job blocks for its whole job
        ``C_k + G_k`` plus the 2*eps reservation rewrite (Lemma 2's
        remote charge, with job jitter ``J_k``);
      * ``occ_kind == "ioctl"`` — Algorithm 2 admits at *segment*
        granularity, so a rival job blocks for its pure device time plus
        eviction costs ``G_k^{e*} = G_k^e + 2*eps*eta_k^g`` (Lemma 3's
        remote charge, with GPU jitter ``J_k^g``); rt_mutex /
        runlist-update blocking of ``h``'s own accesses is inside the
        seed's ``(eta^g + 1)*eps``.
    """
    from .analysis import _gestar, _jitter, ceil_pos

    eps = ts.epsilon
    occ = uncontended_occupancy(h, eps)
    for k in device_rivals(ts, h, use_gpu_prio):
        if occ_kind == "kthread":
            J = _jitter(ts, k, "job", R, use_gpu_prio)
            per_job = k.C + k.G + 2.0 * eps
        elif occ_kind == "ioctl":
            J = _jitter(ts, k, "gpu", R, use_gpu_prio)
            per_job = _gestar(k, eps)
        else:
            raise ValueError(f"unknown occupancy kind {occ_kind!r}")
        occ += ceil_pos(window + J, k.period) * per_job
    return occ


def occupancy_vector(
    ts: Taskset,
    R: Dict[str, Optional[float]],
    occ_kind: str,
    use_gpu_prio: bool = False,
) -> Dict[str, float]:
    """One occupancy step of the outer iteration: re-derive every GPU
    task's busy-wait core occupancy from the current WCRT iterate ``R``.

    Past-deadline iterates are capped at the deadline: the task already
    reports ``inf``, and the cap keeps the other tasks' numbers
    informative on the (rejected) set.  Module-level (rather than a
    closure inside :func:`cross_fixed_point`) so the vectorized batch
    backend (`core/batch.py`, DESIGN.md §5) can drive the same outer
    loop in lockstep across a whole batch of tasksets with the inner
    per-device bounds computed by its array fixed point.
    """
    occ: Dict[str, float] = {}
    for h in ts.tasks:
        if not h.uses_gpu:
            continue
        w = R.get(h.name)
        w = h.deadline if w is None or math.isinf(w) else min(w, h.deadline)
        occ[h.name] = busy_occupancy(ts, h, w, R, occ_kind, use_gpu_prio)
    return occ


def cross_fixed_point(
    ts: Taskset,
    base_rta: Callable[..., Dict[str, Optional[float]]],
    occ_kind: str,
    use_gpu_prio: bool = False,
    early_exit: bool = False,
    only: Optional[str] = None,
    max_outer: int = MAX_OUTER,
    **inner_kw,
) -> Tuple[Dict[str, Optional[float]], Dict]:
    """Joint WCRT bounds for a multi-device busy-waiting taskset.

    ``base_rta`` is the *single-device* recurrence (the undecorated RTA);
    it is re-run on every device projection each outer round, folded with
    the current occupancy iterate.  Returns ``(R, info)`` where ``info``
    carries ``converged`` / ``diverged`` flags and the outer ``iterations``
    count.

    ``only`` is accepted for interface compatibility but cannot prune the
    computation: under the joint fixed point a task's bound depends on
    every other task's iterate, so the full vector is computed and
    returned (Audsley's per-candidate independence property does *not*
    hold here — see `core.audsley`).  ``early_exit`` stops the outer
    iteration as soon as some real-time task diverges past its deadline:
    the iteration is monotone, so the set is already unschedulable.  On
    that path the result is *partial*, mirroring ``_rta_loop``: the
    diverged tasks report ``inf`` and still-iterating finite bounds are
    dropped (absent key == unschedulable to every caller), because a
    non-converged iterate is not an upper bound; ``info`` carries
    ``unschedulable=True`` with both flags False.
    """
    from .analysis import fold_to_device, merge_device_bounds

    gpu_tasks = [t for t in ts.tasks if t.uses_gpu]
    own = {t.name: t.device for t in gpu_tasks}
    rt_names = {t.name for t in ts.rt_tasks}

    def project(occ: Dict[str, float]) -> Dict[str, Optional[float]]:
        out: Dict[str, Optional[float]] = {}
        for d in range(ts.n_devices):
            Rd = base_rta(
                fold_to_device(ts, d, occupancy=occ),
                use_gpu_prio=use_gpu_prio,
                **inner_kw,
            )
            merge_device_bounds(out, Rd, own, d)
        return out

    eps = ts.epsilon
    occ = {h.name: uncontended_occupancy(h, eps) for h in gpu_tasks}
    R = project(occ)  # suspension-equivalent seed bound
    info = {"converged": False, "diverged": False, "iterations": 1,
            "unschedulable": False}
    # the seed projection above counts as round 1, so at most
    # max_outer - 1 further rounds keep iterations <= max_outer
    for _ in range(max_outer - 1):
        if early_exit and any(
            R.get(n) is None or math.isinf(R[n]) for n in rt_names
        ):
            # Monotone iteration cannot rescue a diverged task; return a
            # partial dict (see docstring) rather than mid-iteration
            # finite values that are not upper bounds.
            info["unschedulable"] = True
            R = {
                n: r
                for n, r in R.items()
                if n not in rt_names or r is None or math.isinf(r)
            }
            return R, info
        occ_new = occupancy_vector(ts, R, occ_kind, use_gpu_prio)
        if all(abs(occ_new[n] - occ[n]) < _EPS for n in occ):
            info["converged"] = True
            break
        occ = occ_new
        R = project(occ)
        info["iterations"] += 1
    else:
        # Round cap hit without convergence: a non-converged iterate is
        # not an upper bound, so report divergence conservatively.
        info["diverged"] = True
        R = {
            n: (math.inf if n in rt_names else r) for n, r in R.items()
        }
    return R, info
