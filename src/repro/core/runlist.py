"""Runlist / TSG model (Sec. II) and the baseline GPU arbitration policies.

The Tegra driver associates each process with a TSG (time-sliced group of
channels); active TSGs are placed on the *runlist*, which the GPU hardware
schedules round-robin with per-TSG time slices.  We model exactly the state
the scheduling approaches manipulate:

  * ``TSG``      — one per job in flight (pid, priority, active flag).
  * ``Runlist``  — the set of schedulable TSGs + round-robin rotation state
                   of ONE device.
  * ``Platform`` — N devices, each with its own runlist (DESIGN.md §4);
                   tasks carry a ``device`` index (default 0), and the
                   engine instantiates one policy per device.

Policies built directly on this model:
  * ``UnmanagedPolicy`` — the default driver: every active TSG is on the
    runlist; time-sliced round-robin, no priority, no preemption (Table I
    row 1).
  * ``SyncPolicy``      — synchronization-based GPU access control (MPCP /
    FMLP+ style): the GPU is a mutually exclusive resource; a task acquires
    the lock for the whole GPU segment; the queue is priority-ordered (MPCP)
    or FIFO (FMLP+); lock holders are priority-boosted on their core.

Both also implement the runtime face of ``SchedulingPolicy``, so the
device executor can run them by name from the registry.
"""
from __future__ import annotations

from dataclasses import dataclass
from typing import TYPE_CHECKING, List, Optional

from .policy import (BasePolicy, SchedulingPolicy, job_priority,
                     register_policy)

if TYPE_CHECKING:  # pragma: no cover
    from .simulator import Job

BOOST = 10_000_000  # priority boost for lock holders (global ceiling model)


@dataclass
class TSG:
    job: "Job"
    priority: int
    active: bool = True  # has submitted work (job in flight)


class Runlist:
    """Round-robin runlist: rotation over member TSGs with a time slice."""

    def __init__(self, slice_ms: float = 2.0):
        self.slice_ms = slice_ms
        self.members: list[TSG] = []
        self.rr_pos: int = 0
        self.slice_left: float = slice_ms

    def add(self, tsg: TSG) -> None:
        if tsg not in self.members:
            self.members.append(tsg)

    def remove(self, tsg: TSG) -> None:
        if tsg in self.members:
            idx = self.members.index(tsg)
            self.members.remove(tsg)
            if idx < self.rr_pos:
                self.rr_pos -= 1
            if self.rr_pos >= len(self.members):
                self.rr_pos = 0
                self.slice_left = self.slice_ms

    def clear(self) -> None:
        self.members.clear()
        self.rr_pos = 0
        self.slice_left = self.slice_ms

    def runnable(self) -> list[TSG]:
        """TSGs whose job currently has an active pure-GPU piece."""
        return [m for m in self.members
                if m.job.wants_gpu() and not m.job.done]

    def current(self) -> Optional[TSG]:
        run = self.runnable()
        if not run:
            return None
        # rotate rr_pos to the next runnable member
        n = len(self.members)
        for k in range(n):
            cand = self.members[(self.rr_pos + k) % n]
            if cand in run:
                if k != 0:  # moved on: fresh slice
                    self.rr_pos = (self.rr_pos + k) % n
                    self.slice_left = self.slice_ms
                return cand
        return None

    def advance(self, dt: float) -> None:
        self.slice_left -= dt
        if self.slice_left <= 1e-12:
            self.rr_pos = (self.rr_pos + 1) % max(len(self.members), 1)
            self.slice_left = self.slice_ms


class Platform:
    """N accelerators, one runlist each.  ``devices[d]`` is the hardware
    scheduling state of device d; policies layer their arbitration on top."""

    def __init__(self, n_devices: int = 1, slice_ms: float = 2.0):
        self.devices: List[Runlist] = [Runlist(slice_ms)
                                       for _ in range(n_devices)]

    def __len__(self) -> int:
        return len(self.devices)

    def __getitem__(self, d: int) -> Runlist:
        return self.devices[d]


class UnmanagedPolicy(SchedulingPolicy):
    """Default driver: time-sliced round-robin over all active TSGs."""

    name = "unmanaged"

    def __init__(self, slice_ms: float = 2.0):
        self.runlist = Runlist(slice_ms)
        self.tsgs: dict[int, TSG] = {}

    def on_job_release(self, job: "Job") -> None:
        tsg = TSG(job=job, priority=0)
        self.tsgs[job.uid] = tsg
        self.runlist.add(tsg)

    def on_job_complete(self, job: "Job") -> None:
        tsg = self.tsgs.pop(job.uid, None)
        if tsg:
            self.runlist.remove(tsg)

    def gpu_owner(self) -> Optional["Job"]:
        cur = self.runlist.current()
        return cur.job if cur else None

    def gpu_rr_advance(self, dt: float) -> None:
        if len(self.runlist.runnable()) > 1:
            self.runlist.advance(dt)

    def next_gpu_event(self) -> float:
        if len(self.runlist.runnable()) > 1:
            return max(self.runlist.slice_left, 1e-9)
        return float("inf")

    # runtime face: the default driver admits everything, always.


class SyncPolicy(SchedulingPolicy):
    """Synchronization-based access control (MPCP-like / FMLP+-like).

    The GPU segment (G^m + G^e) is a critical section under a global lock.
    ``order='priority'`` models MPCP, ``order='fifo'`` models FMLP+.
    Lock holders are priority-boosted on their core.
    """

    name = "sync"
    needs_segment_hooks = True

    def __init__(self, order: str = "priority"):
        assert order in ("priority", "fifo")
        self.order = order
        self.holder: Optional["Job"] = None
        self.queue: list["Job"] = []  # waiting jobs

    # ---- shared lock mechanics (simulator Jobs or runtime RTJobs) --------
    def _lock_acquire(self, job) -> bool:
        """Returns True if the lock was granted immediately."""
        if self.holder is None:
            self.holder = job
            return True
        self.queue.append(job)
        return False

    def _lock_release(self) -> None:
        self.holder = None
        if self.queue:
            if self.order == "priority":
                self.queue.sort(key=lambda j: -job_priority(j))
            self.holder = self.queue.pop(0)

    # ---- simulator face ---------------------------------------------------
    def on_segment_begin(self, job: "Job") -> None:
        if not self._lock_acquire(job):
            job.lock_wait = True

    def on_ge_complete(self, job: "Job") -> None:
        assert self.holder is job, "lock released by non-holder"
        self._lock_release()
        if self.holder is not None:
            self.holder.lock_wait = False

    def on_job_complete(self, job: "Job") -> None:
        if job in self.queue:
            self.queue.remove(job)

    def gpu_owner(self) -> Optional["Job"]:
        if self.holder is not None and self.holder.wants_gpu():
            return self.holder
        return None

    def effective_priority(self, job: "Job") -> int:
        if job is self.holder:
            return BOOST + job.task.priority
        return job.task.priority

    def cpu_blocked(self, job: "Job") -> bool:
        # waiting for the lock: blocked unless busy-waiting (sim handles
        # busy-wait CPU occupancy separately)
        return job.lock_wait and self.sim.mode == "suspend"

    # ---- runtime face -----------------------------------------------------
    def runtime_segment_begin(self, job) -> bool:
        self._lock_acquire(job)
        return False  # lock handoff is not a runlist rewrite

    def runtime_segment_end(self, job) -> bool:
        if self.holder is job:
            self._lock_release()
        elif job in self.queue:
            self.queue.remove(job)
        return False

    def runtime_on_complete(self, job) -> None:
        if self.holder is job:
            self._lock_release()
        if job in self.queue:
            self.queue.remove(job)

    def runtime_admitted(self, job) -> bool:
        return self.holder is None or self.holder is job


register_policy("unmanaged", UnmanagedPolicy,
                "default driver: time-sliced RR, no priority (Table I)")
register_policy("sync_priority",
                lambda **kw: SyncPolicy(order="priority", **kw),
                "MPCP-style lock-based GPU access, priority queue")
register_policy("sync_fifo",
                lambda **kw: SyncPolicy(order="fifo", **kw),
                "FMLP+-style lock-based GPU access, FIFO queue")

__all__ = ["TSG", "Runlist", "Platform", "UnmanagedPolicy", "SyncPolicy",
           "BasePolicy", "BOOST"]
