"""Scheduling-policy protocol, shared Algorithm 1/2 state machines, and the
name-based policy registry (DESIGN.md §3).

One ``SchedulingPolicy`` object carries *both* faces of a scheduling
approach:

  * the **simulator face** — the hook surface the event-driven engine
    (`core/engine.py`) drives: job releases/completions, GPU-segment
    boundaries, runlist-update pieces, CPU-winner notifications, and the
    resource-arbitration queries (``gpu_owner``, ``effective_priority``,
    ``next_gpu_event``);
  * the **runtime face** — the hook surface ``repro.sched.executor.
    DeviceExecutor`` drives with real threads and wall-clock time:
    ``runtime_on_start/complete``, ``runtime_segment_begin/end``,
    ``runtime_admitted``, ``runtime_poll``.

Both faces resolve admission through the *same* state machines below
(``Alg2State`` for the IOCTL approach's Algorithm 2, ``pick_reserved`` for
the kernel-thread approach's Algorithm 1), so the analysis-side model and
the driver-side implementation cannot drift apart — the divergence GCAPS
(arXiv:2406.05221) warns about.

The registry maps approach names ("unmanaged", "sync_priority",
"sync_fifo", "kthread", "ioctl", ...) to policy factories plus the RTA
functions that provide the approach's analytic guarantee.  `simulate()`,
`benchmarks/run.py`, and `DeviceExecutor` all resolve policies here, so a
newly registered policy is immediately available in all three.
"""
from __future__ import annotations

from dataclasses import dataclass, field
from typing import (TYPE_CHECKING, Callable, Dict, Iterable, List, Optional,
                    Sequence, Tuple)

if TYPE_CHECKING:  # pragma: no cover
    from .simulator import Job, Simulator


# --------------------------------------------------------------------------
# duck-typed job accessors: simulator Jobs carry a .task, runtime RTJobs
# carry the fields directly — the shared state machines accept either.
# --------------------------------------------------------------------------

def job_is_rt(j) -> bool:
    task = getattr(j, "task", None)
    return task.is_rt if task is not None else j.is_rt


def job_gpu_priority(j) -> int:
    """GPU/device-segment priority (Sec. V-C), falling back to the base
    priority for jobs without a distinct device priority."""
    task = getattr(j, "task", None)
    if task is not None:
        return task.gpu_priority
    return getattr(j, "device_priority", j.priority)


def job_priority(j) -> int:
    task = getattr(j, "task", None)
    return task.priority if task is not None else j.priority


# --------------------------------------------------------------------------
# Algorithm 1 (kernel-thread approach): job-granular device reservation
# --------------------------------------------------------------------------

def pick_reserved(candidates: Iterable) -> Optional[object]:
    """Line 4 of Algorithm 1: the highest-GPU-priority real-time candidate,
    or None when no real-time task is eligible (best-effort round-robin).

    Callers pre-filter ``candidates`` to the jobs that are *eligible* in
    their domain (ready + GPU-using in the simulator; active in the
    runtime executor, where every admitted job may dispatch programs)."""
    rt = [j for j in candidates if job_is_rt(j)]
    if not rt:
        return None
    return max(rt, key=job_gpu_priority)


# --------------------------------------------------------------------------
# Algorithm 2 (IOCTL approach): task_running / task_pending admission
# --------------------------------------------------------------------------

class Alg2State:
    """The two disjoint lists of Algorithm 2 and its add/remove procedure.

    This is the single implementation backing both the simulator's
    ``IoctlPolicy`` and the runtime executor's notify mode.  One deviation
    from the paper's verbatim pseudo-code (noted in `core/ioctl.py`): on
    removal with no pending real-time task we take the *union* of
    task_running and task_pending rather than overwriting, so best-effort
    TSGs that stayed in task_running are not dropped.

    ``on_enter_running`` / ``on_leave_running`` are optional callbacks for
    domain-specific bookkeeping (the simulator maintains best-effort TSG
    round-robin membership through them)."""

    def __init__(self,
                 on_enter_running: Optional[Callable] = None,
                 on_leave_running: Optional[Callable] = None):
        self.running: List = []   # task_running
        self.pending: List = []   # task_pending
        self._enter = on_enter_running
        self._leave = on_leave_running

    # -- membership helpers -------------------------------------------------
    def _to_running(self, job) -> None:
        if job not in self.running:
            self.running.append(job)
        job.gpu_pending = False
        if self._enter:
            self._enter(job)

    def _from_running(self, job) -> None:
        if job in self.running:
            self.running.remove(job)
        if self._leave:
            self._leave(job)

    def _to_pending(self, job) -> None:
        self.pending.append(job)
        job.gpu_pending = True

    def top_running(self):
        return max(self.running, key=job_gpu_priority, default=None)

    # -- Algorithm 2 --------------------------------------------------------
    def add(self, job) -> bool:
        """begin() IOCTL (lines 6-17).  Returns True iff the task_running
        membership changed (the costly runlist-rewrite mode)."""
        before = list(self.running)
        if not job_is_rt(job):                      # lines 6-10
            if not any(job_is_rt(j) for j in self.running):
                self._to_running(job)
            else:
                self._to_pending(job)
        else:                                       # lines 11-17
            tau_h = self.top_running()
            if tau_h is None or job_gpu_priority(job) > job_gpu_priority(tau_h):
                self._to_running(job)
                if tau_h is not None and job_is_rt(tau_h):
                    self._from_running(tau_h)       # preempt tau_h
                    self._to_pending(tau_h)
                elif tau_h is not None:
                    # best-effort members are displaced as well
                    for be in [j for j in self.running
                               if j is not job and not job_is_rt(j)]:
                        self._from_running(be)
                        self._to_pending(be)
            else:
                self._to_pending(job)
        return {id(j) for j in before} != {id(j) for j in self.running}

    def remove(self, job) -> bool:
        """end() IOCTL (lines 18-25).  Returns True iff task_running
        membership changed.

        A caller that never reached task_running (cancelled, or its
        segment body errored while still in task_pending — the runtime's
        ``device_segment.__exit__`` still issues the end() call) is just
        dropped from task_pending: the paper's handover (lines 19-22)
        assumes the *departing* task held the runlist, and running it for
        a pending caller would admit a second RT program next to the
        current holder (found by tests/test_policy_fuzz.py; unreachable
        in the simulator, where ge pieces only execute once admitted)."""
        if job not in self.running:
            if job in self.pending:
                self.pending.remove(job)
                job.gpu_pending = False
            return False
        before = list(self.running)
        rt_pend = [j for j in self.pending if job_is_rt(j)]
        if rt_pend:
            tau_k = max(rt_pend, key=job_gpu_priority)
            self.pending.remove(tau_k)
            self._to_running(tau_k)
            self._from_running(job)
        else:
            self._from_running(job)
            # paper: task_running <- task_pending (union, see docstring)
            for j in list(self.pending):
                self.pending.remove(j)
                self._to_running(j)
        return {id(j) for j in before} != {id(j) for j in self.running}

    def discard(self, job) -> None:
        """Defensive cleanup on job completion (a well-formed job has
        already issued its end() calls)."""
        if job in self.running:
            self._from_running(job)
        if job in self.pending:
            self.pending.remove(job)


# --------------------------------------------------------------------------
# the policy protocol
# --------------------------------------------------------------------------

class SchedulingPolicy:
    """Interface shared by the simulator engine and the runtime executor.

    All hooks are optional; the base class admits everything and owns
    nothing.  ``device`` is the index of the accelerator this instance
    arbitrates — the engine creates one instance per device and routes
    job-scoped hooks by ``job.task.device`` (DESIGN.md §4)."""

    name = "base"
    needs_ioctl_pieces = False   # insert `upd` pieces around GPU segments
    requires_busy_wait = False   # self-suspension breaks state detection
    wants_poll_thread = False    # runtime: spawn a scheduler/kernel thread
    needs_segment_hooks = False  # runtime: device_segment drives admission
    recheck_winners_after_notify = False  # a rewrite may block a CPU core
    device = 0

    # ---- simulator face ---------------------------------------------------
    def attach(self, sim: "Simulator") -> None:
        self.sim = sim

    def on_job_release(self, job: "Job") -> None: ...
    def on_job_complete(self, job: "Job") -> None: ...
    def on_segment_begin(self, job: "Job") -> None: ...
    def on_ge_complete(self, job: "Job") -> None: ...
    def on_update_done(self, job: "Job", which: str) -> None: ...
    def begin_update(self, job: "Job", piece) -> None: ...
    def notify_winners(self, winners) -> None: ...

    def try_acquire(self, job: "Job") -> bool:
        return True

    def gpu_owner(self) -> Optional["Job"]:
        raise NotImplementedError

    def gpu_rr_advance(self, dt: float) -> None: ...

    def next_gpu_event(self) -> float:
        return float("inf")

    def effective_priority(self, job: "Job") -> int:
        return job.task.priority

    def cpu_blocked(self, job: "Job") -> bool:
        """True if the job cannot use the CPU now (policy-specific)."""
        return False

    def occupied_cores(self) -> Tuple[int, ...]:
        """Cores consumed outright by the policy's own machinery (e.g. the
        kernel thread mid-rewrite)."""
        return ()

    # ---- runtime face (driven by sched.executor.DeviceExecutor) ----------
    def runtime_attach(self, executor) -> None:
        self.executor = executor

    def runtime_on_start(self, job) -> None: ...
    def runtime_on_complete(self, job) -> None: ...

    def runtime_segment_begin(self, job) -> bool:
        """device_segment entry.  Returns True iff the admission state was
        rewritten (the costly IOCTL mode — priced as epsilon)."""
        return False

    def runtime_segment_end(self, job) -> bool:
        return False

    def runtime_admitted(self, job) -> bool:
        return True

    def runtime_poll(self, active_jobs: Sequence) -> bool:
        """Periodic scheduler-thread evaluation (Algorithm 1 realization).
        Returns True iff the reservation changed (a runlist rewrite)."""
        return self.runtime_apply(self.runtime_pick(active_jobs))

    def runtime_pick(self, active_jobs: Sequence):
        """Scheduling decision of one poll tick (pure; not timed)."""
        return None

    def runtime_apply(self, decision) -> bool:
        """Apply a poll decision — the runlist-rewrite part, which the
        executor times as an epsilon sample.  Returns True iff changed."""
        return False


# --------------------------------------------------------------------------
# registry
# --------------------------------------------------------------------------

@dataclass(frozen=True)
class PolicySpec:
    """Registry entry: how to build the policy and which analyses price it.

    ``rtas`` maps wait modes ("busy"/"suspend") to the response-time
    analysis providing the approach's schedulability guarantee; approaches
    without an analytic guarantee (unmanaged) leave it empty."""
    name: str
    factory: Callable[..., SchedulingPolicy]
    description: str = ""
    rtas: Dict[str, Callable] = field(default_factory=dict)


_REGISTRY: Dict[str, PolicySpec] = {}

# legacy executor mode names accepted for backward compatibility
LEGACY_MODES = {"notify": "ioctl", "poll": "kthread",
                "unmanaged": "unmanaged"}


def register_policy(name: str, factory: Callable[..., SchedulingPolicy],
                    description: str = "",
                    rtas: Optional[Dict[str, Callable]] = None) -> None:
    """Register (or replace) a scheduling approach under ``name``."""
    _REGISTRY[name] = PolicySpec(name=name, factory=factory,
                                 description=description,
                                 rtas=dict(rtas or {}))


def policy_spec(name: str) -> PolicySpec:
    key = LEGACY_MODES.get(name, name)
    if key not in _REGISTRY:
        raise ValueError(
            f"unknown scheduling approach {name!r}; "
            f"registered: {', '.join(sorted(_REGISTRY))}")
    return _REGISTRY[key]


def make_policy(name: str, **kw) -> SchedulingPolicy:
    return policy_spec(name).factory(**kw)


def available_policies() -> List[str]:
    return sorted(_REGISTRY)


# BasePolicy is the historic name of the protocol (pre-registry); keep it
# importable for external code built against the seed API.
BasePolicy = SchedulingPolicy
