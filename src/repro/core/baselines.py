"""Synchronization-based baselines: MPCP and FMLP+ response-time analyses.

The paper compares against MPCP [Rajkumar'90; Patel et al. RTAS'18] and
FMLP+ [Brandenburg, ECRTS'14], both with busy-waiting and suspension-aware
variants.  Here the GPU is modeled as a single mutually exclusive resource;
each GPU segment G_{i,j} (misc + pure execution) is a global critical
section of length g_{i,j} = G^m_{i,j} + G^e_{i,j} executed non-preemptively
w.r.t. the GPU (lock holders are priority-boosted on their core, the classic
source of priority inversion the paper highlights).

These are faithful-in-spirit implementations of the cited analyses: the
protocol-specific refinements of the originals (e.g. per-segment priority
ceilings, partition-aware boosting windows) are simplified to the standard
textbook bounds, which is the granularity at which the paper's evaluation
compares (acceptance-ratio curves).

Notation:
  maxg_l   = max_j g_{l,j}        (longest critical section of tau_l)
  lp/hp    = lower/higher CPU priority;  lpp/hpp = same-core subsets
  gpu(t)   = t uses the GPU

MPCP (priority-ordered lock queue):
  per-request wait  W_i = max_{l in lp, gpu} maxg_l
                        + sum_{h in hp, gpu} (ceil(W_i/T_h)+1) * G_h
  total blocking    B_i = eta_i^g * W_i

FMLP+ (FIFO lock queue):
  per-request wait  W_i = sum_{j != i, gpu} maxg_j   (one request per task
                          can sit ahead in FIFO order)
  total blocking    B_i = eta_i^g * W_i

Response time:
  busy-wait:   waiting and GPU execution hold the CPU, so same-core
               higher-priority tasks contribute (C_h + G_h + B_h) and the
               task itself contributes C_i + G_i + B_i; plus one local
               lower-priority boosted section per own request arrival.
  suspension:  the task suspends while waiting/executing on the GPU; local
               higher-priority tasks contribute CPU demand (C_h + G_h^m)
               with jitter, and local lower-priority boosted critical
               sections preempt up to once per own GPU request plus once
               per lower-priority job arrival.
"""
from __future__ import annotations

import math
from typing import Callable, Dict, Optional

from .analysis import _rta_loop, ceil_pos
from .task_model import Task, Taskset


def _maxg(t: Task) -> float:
    return max((g.total for g in t.gpu_segments), default=0.0)


def _gpu_tasks(ts: Taskset) -> list[Task]:
    return [t for t in ts.tasks if t.uses_gpu]


def _request_wait_mpcp(ts: Taskset, ti: Task) -> float:
    """Fixed point of the MPCP per-request wait W_i."""
    lp_gpu = [t for t in _gpu_tasks(ts)
              if t.priority < ti.priority and t is not ti]
    hp_gpu = [h for h in _gpu_tasks(ts) if h.priority > ti.priority]
    base = max((_maxg(t) for t in lp_gpu), default=0.0)
    W = base
    for _ in range(1024):
        W_new = base + sum((ceil_pos(W, h.period) + 1) * h.G for h in hp_gpu)
        if abs(W_new - W) < 1e-9:
            return W_new
        if W_new > 100.0 * ti.period:  # diverged: effectively unbounded
            return math.inf
        W = W_new
    return math.inf


def _request_wait_fmlp(ts: Taskset, ti: Task) -> float:
    """FMLP+ FIFO per-request wait: one critical section per other task."""
    return sum(_maxg(j) for j in _gpu_tasks(ts) if j is not ti)


def _blocking(ts: Taskset, ti: Task, protocol: str) -> float:
    if not ti.uses_gpu:
        return 0.0
    W = (_request_wait_mpcp(ts, ti) if protocol == "mpcp"
         else _request_wait_fmlp(ts, ti))
    return ti.eta_g * W


def _boost_blocking(ts: Taskset, ti: Task, R_i: float) -> float:
    """Local lower-priority boosted critical sections: up to one per each of
    tau_i's GPU requests (+1 for initial arrival), bounded by arrivals."""
    lpp_gpu = [t for t in ts.tasks
               if t is not ti and t.cpu == ti.cpu
               and t.priority < ti.priority and t.uses_gpu]
    if not lpp_gpu:
        return 0.0
    per_event = max(_maxg(t) for t in lpp_gpu)
    events = ti.eta_g + 1
    arrivals = sum(ceil_pos(R_i, t.period) + 1 for t in lpp_gpu)
    return min(events, arrivals) * per_event


def _rta(ts: Taskset, protocol: str, mode: str,
         early_exit: bool = False) -> Dict[str, Optional[float]]:
    def make_f(ti: Task, R: Dict) -> Callable:
        B_i = _blocking(ts, ti, protocol)
        hpp = ts.hpp(ti)
        if math.isinf(B_i):
            return lambda R_i: math.inf

        if mode == "busy":
            def f(R_i: float) -> float:
                v = ti.C + ti.G + B_i + _boost_blocking(ts, ti, R_i)
                for h in hpp:
                    B_h = _blocking(ts, h, protocol)
                    if math.isinf(B_h):
                        return math.inf
                    v += ceil_pos(R_i, h.period) * (h.C + h.G + B_h)
                return v
        else:  # suspension-aware
            def f(R_i: float) -> float:
                v = ti.C + ti.G + B_i + _boost_blocking(ts, ti, R_i)
                for h in hpp:
                    J_h = max((R.get(h.name) or h.deadline) - (h.C + h.Gm), 0.0)
                    if math.isinf(J_h):
                        J_h = max(h.deadline - (h.C + h.Gm), 0.0)
                    v += ceil_pos(R_i + J_h, h.period) * (h.C + h.Gm)
                return v
        return f

    return _rta_loop(ts, make_f, early_exit=early_exit)


def mpcp_busy_rta(ts: Taskset, early_exit: bool = False
                  ) -> Dict[str, Optional[float]]:
    return _rta(ts, "mpcp", "busy", early_exit)


def mpcp_suspend_rta(ts: Taskset, early_exit: bool = False
                     ) -> Dict[str, Optional[float]]:
    return _rta(ts, "mpcp", "suspend", early_exit)


def fmlp_busy_rta(ts: Taskset, early_exit: bool = False
                  ) -> Dict[str, Optional[float]]:
    return _rta(ts, "fmlp", "busy", early_exit)


def fmlp_suspend_rta(ts: Taskset, early_exit: bool = False
                     ) -> Dict[str, Optional[float]]:
    return _rta(ts, "fmlp", "suspend", early_exit)


def _sched(ts: Taskset, rta: Callable) -> bool:
    R = rta(ts, early_exit=True)
    return all(not math.isinf(R.get(t.name, math.inf))
               and R[t.name] <= t.deadline + 1e-9 for t in ts.rt_tasks)


def mpcp_schedulable(ts: Taskset) -> bool:
    """Best of the busy / suspension-aware MPCP analyses (as the paper's
    curves take the protocol's best available analysis)."""
    return _sched(ts, mpcp_busy_rta) or _sched(ts, mpcp_suspend_rta)


def fmlp_schedulable(ts: Taskset) -> bool:
    return _sched(ts, fmlp_busy_rta) or _sched(ts, fmlp_suspend_rta)
