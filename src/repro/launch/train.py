"""Training driver: fault-tolerant, checkpointed, optionally running as a
preemptible best-effort job under the real-time executor.

  PYTHONPATH=src python -m repro.launch.train --arch olmo-1b --reduced \
      --steps 50 --batch 8 --seq 128 --ckpt /tmp/ckpt

On the CPU container this trains the reduced configs; the same driver
drives full configs on a real pod (mesh + shardings come from the same
rules the dry-run validated)."""
from __future__ import annotations

import argparse
import time

import jax

from ..configs import get
from ..configs.shapes import ShapeSpec
from ..data import SyntheticLM
from ..models import transformer
from ..optim import adamw
from ..sched.fault import FaultTolerantLoop, Heartbeat
from . import steps


def make_state(cfg, key):
    params = transformer.init_params(cfg, key)
    opt = adamw.init_opt_state(params)
    return {"params": params, "opt": opt}


def train(cfg, n_steps: int, global_batch: int, seq_len: int,
          ckpt_dir: str = "", save_every: int = 20, log_every: int = 10,
          fail_at: int = -1, executor=None, job=None):
    """Returns (state, losses).  ``fail_at`` injects a step failure to
    exercise restart-from-checkpoint (tests/benchmarks)."""
    opt_cfg = adamw.AdamWConfig(total_steps=n_steps, warmup_steps=5)
    step_fn = jax.jit(steps.build_train_step(cfg, opt_cfg))
    data = SyntheticLM(cfg.vocab_size, seq_len, global_batch, seed=17)
    state = make_state(cfg, jax.random.PRNGKey(0))

    loop = None
    if ckpt_dir:
        loop = FaultTolerantLoop(ckpt_dir, state, save_every=save_every)
    hb = Heartbeat(timeout_s=300.0)
    losses = []
    injected = {"done": False}

    def one_step(state, batch):
        if fail_at >= 0 and loop is not None \
                and loop.step == fail_at and not injected["done"]:
            injected["done"] = True
            raise RuntimeError("injected node failure")
        p, o, metrics = step_fn(state["params"], state["opt"], batch)
        return {"params": p, "opt": o}, metrics

    t0 = time.time()
    step = 0
    while step < n_steps:
        batch = {k: jax.numpy.asarray(v)
                 for k, v in data.batch_at(loop.step if loop else step)
                 .items()}
        if executor is not None and job is not None:
            with executor.device_segment(job):
                if loop is not None:
                    metrics = executor.run(job, loop.run_step, one_step,
                                           batch)
                else:
                    state, metrics = executor.run(job, one_step, state,
                                                  batch)
        elif loop is not None:
            metrics = loop.run_step(one_step, batch)
        else:
            state, metrics = one_step(state, batch)
        hb.beat()
        hb.check()
        step = loop.step if loop is not None else step + 1
        loss = float(metrics["loss"])
        losses.append(loss)
        if log_every and step % log_every == 0:
            print(f"step {step:5d} loss {loss:.4f} "
                  f"({(time.time() - t0) / max(step, 1):.3f}s/step)",
                  flush=True)
    hb.stop()
    if loop is not None:
        loop.ckpt.wait()
        return loop.state, losses, loop.stats
    return state, losses, None


def main() -> None:
    ap = argparse.ArgumentParser()
    ap.add_argument("--arch", required=True)
    ap.add_argument("--reduced", action="store_true",
                    help="use the reduced (CPU-runnable) config")
    ap.add_argument("--steps", type=int, default=50)
    ap.add_argument("--batch", type=int, default=8)
    ap.add_argument("--seq", type=int, default=128)
    ap.add_argument("--ckpt", default="")
    ap.add_argument("--fail-at", type=int, default=-1)
    args = ap.parse_args()

    entry = get(args.arch)
    cfg = entry.reduced() if args.reduced else entry.config()
    out = train(cfg, args.steps, args.batch, args.seq, ckpt_dir=args.ckpt,
                fail_at=args.fail_at)
    losses = out[1]
    print(f"first loss {losses[0]:.4f} -> last loss {losses[-1]:.4f}")
    if out[2] is not None:
        print("fault stats:", out[2])


if __name__ == "__main__":
    main()
