"""HLO-derived cost extraction for the roofline analysis.

``cost_analysis()`` supplies per-device FLOPs and bytes accessed;
collective bytes are parsed from the compiled HLO text (they are absent
from cost_analysis).  XLA counts a while(scan) body ONCE, so totals are
corrected with standalone layer-group compiles:
    total = full + (repeats - 1) * group.
"""
from __future__ import annotations

import re
from collections import defaultdict
from typing import Dict

_DTYPE_BYTES = {
    "pred": 1, "s8": 1, "u8": 1, "s16": 2, "u16": 2, "bf16": 2, "f16": 2,
    "s32": 4, "u32": 4, "f32": 4, "s64": 8, "u64": 8, "f64": 8,
}

_SHAPE_RE = re.compile(r"(pred|[sub]\d+|bf16|f16|f32|f64)\[([\d,]*)\]")
_COLL_RE = re.compile(
    r"^\s*(?:ROOT\s+)?%?[\w.\-]+\s*=\s*(?P<res>\([^)]*\)|[^\s]+)\s+"
    r"(?P<op>all-reduce|all-gather|reduce-scatter|all-to-all|"
    r"collective-permute)(?:-start)?\(", re.M)
_GROUPS_RE = re.compile(r"replica_groups=(\{\{[^}]*\}[^,]*\}|\[[^\]]*\]"
                        r"(?:<=\[\d+\])?)")


def _shape_bytes(text: str) -> int:
    total = 0
    for dt, dims in _SHAPE_RE.findall(text):
        n = 1
        for d in dims.split(","):
            if d:
                n *= int(d)
        total += n * _DTYPE_BYTES[dt]
    return total


def _group_size(line: str, n_devices: int) -> int:
    m = _GROUPS_RE.search(line)
    if not m:
        return n_devices
    g = m.group(1)
    if g.startswith("{{"):
        first = g[2:].split("}")[0]
        return max(len([x for x in first.split(",") if x.strip() != ""]), 1)
    # iota form: [n_groups,group_size]<=[total]
    dims = [int(x) for x in re.findall(r"\d+", g.split("<=")[0])]
    return dims[-1] if dims else n_devices


def collective_bytes(hlo_text: str, n_devices: int) -> Dict[str, float]:
    """Per-device link bytes per collective kind (ring-algorithm model):
      all-reduce       2 * size * (g-1)/g     (size = operand/result)
      all-gather       size * (g-1)/g         (size = gathered result)
      reduce-scatter   size * (g-1)           (size = scattered result)
      all-to-all       size * (g-1)/g
      collective-permute  size
    """
    out: Dict[str, float] = defaultdict(float)
    for m in _COLL_RE.finditer(hlo_text):
        line = hlo_text[m.start():hlo_text.find("\n", m.start())]
        op = m.group("op")
        size = _shape_bytes(m.group("res"))
        g = max(_group_size(line, n_devices), 1)
        if op == "all-reduce":
            moved = 2.0 * size * (g - 1) / g
        elif op == "all-gather":
            moved = size * (g - 1) / g
        elif op == "reduce-scatter":
            moved = size * (g - 1)
        elif op == "all-to-all":
            moved = size * (g - 1) / g
        else:  # collective-permute
            moved = float(size)
        out[op] += moved
        out["total"] += moved
    return dict(out)


def cost_summary(compiled) -> Dict[str, float]:
    ca = compiled.cost_analysis()
    if isinstance(ca, list):
        ca = ca[0]
    return {"flops": float(ca.get("flops", 0.0)),
            "bytes": float(ca.get("bytes accessed", 0.0))}


def memory_summary(compiled) -> Dict[str, float]:
    ma = compiled.memory_analysis()
    return {
        "argument_bytes": float(ma.argument_size_in_bytes),
        "output_bytes": float(ma.output_size_in_bytes),
        "temp_bytes": float(ma.temp_size_in_bytes),
        "alias_bytes": float(ma.alias_size_in_bytes),
        "code_bytes": float(ma.generated_code_size_in_bytes),
    }


def peak_hbm_bytes(mem: Dict[str, float]) -> float:
    """Live bytes: arguments + outputs + temporaries - aliased (donated
    inputs reuse their buffers for outputs)."""
    return (mem["argument_bytes"] + mem["output_bytes"]
            + mem["temp_bytes"] - mem["alias_bytes"])


def corrected(full: Dict[str, float], group: Dict[str, float],
              repeats: int) -> Dict[str, float]:
    out = {}
    for k in set(full) | set(group):
        out[k] = full.get(k, 0.0) + (repeats - 1) * group.get(k, 0.0)
    return out
