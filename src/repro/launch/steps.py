"""Step builders + input specs shared by the dry-run, the trainer and the
server.  Everything returns pure functions ready for jax.jit with explicit
shardings; nothing here touches devices."""
from __future__ import annotations

import functools
from typing import Any, Dict

import jax
import jax.numpy as jnp

from ..configs.shapes import ShapeSpec
from ..models import transformer
from ..models.blocks import ModelConfig
from ..optim import adamw
from ..parallel import sharding as shd
from ..parallel.hints import set_hook


# --------------------------------------------------------------------------
# input specs (ShapeDtypeStruct stand-ins — weak-type-correct, shardable,
# no device allocation)
# --------------------------------------------------------------------------

def batch_specs(cfg: ModelConfig, shape: ShapeSpec) -> Dict[str, Any]:
    b, s = shape.global_batch, shape.seq_len
    if cfg.input_mode == "embeddings":
        inputs = jax.ShapeDtypeStruct((b, s, cfg.d_model), jnp.bfloat16)
    else:
        inputs = jax.ShapeDtypeStruct((b, s), jnp.int32)
    out = {"inputs": inputs,
           "labels": jax.ShapeDtypeStruct((b, s), jnp.int32)}
    if any(sp.kind == "cross" for sp in cfg.pattern):
        out["source"] = jax.ShapeDtypeStruct(
            (b, cfg.cross_source_len, cfg.d_model), jnp.bfloat16)
    return out


def decode_input_specs(cfg: ModelConfig, shape: ShapeSpec
                       ) -> Dict[str, Any]:
    b = shape.global_batch
    if cfg.input_mode == "embeddings":
        token = jax.ShapeDtypeStruct((b, cfg.d_model), jnp.bfloat16)
    else:
        token = jax.ShapeDtypeStruct((b,), jnp.int32)
    return {"token": token, "pos": jax.ShapeDtypeStruct((b,), jnp.int32)}


def param_specs(cfg: ModelConfig):
    return transformer.param_specs(cfg)


def opt_specs(cfg: ModelConfig):
    return jax.eval_shape(adamw.init_opt_state, transformer.param_specs(cfg))


def cache_specs(cfg: ModelConfig, shape: ShapeSpec):
    return jax.eval_shape(
        lambda: transformer.init_cache(cfg, shape.global_batch,
                                       shape.seq_len))


# --------------------------------------------------------------------------
# step functions
# --------------------------------------------------------------------------

def build_train_step(cfg: ModelConfig,
                     opt_cfg: adamw.AdamWConfig = adamw.AdamWConfig()):
    """One optimizer step; cfg.grad_accum > 1 splits the global batch into
    microbatches accumulated in fp32 (python-unrolled: activation memory
    scales 1/grad_accum and XLA cost_analysis stays exact)."""
    acc = max(cfg.grad_accum, 1)

    def train_step(params, opt_state, batch):
        if acc == 1:
            loss, grads = jax.value_and_grad(
                lambda p: transformer.lm_loss(cfg, p, batch))(params)
        else:
            loss = 0.0
            grads = jax.tree.map(
                lambda p: jnp.zeros(p.shape, jnp.float32), params)
            mb_size = batch["labels"].shape[0] // acc
            for i in range(acc):
                mb = {k: v[i * mb_size:(i + 1) * mb_size]
                      for k, v in batch.items()}
                li, gi = jax.value_and_grad(
                    lambda p: transformer.lm_loss(cfg, p, mb))(params)
                loss = loss + li / acc
                grads = jax.tree.map(
                    lambda a, g: a + g.astype(jnp.float32) / acc, grads, gi)
        params, opt_state, om = adamw.adamw_update(opt_cfg, grads,
                                                   opt_state, params)
        return params, opt_state, {"loss": loss, **om}

    return train_step


def build_prefill_step(cfg: ModelConfig, max_len: int):
    def prefill_step(params, batch):
        return transformer.prefill(cfg, params, batch["inputs"], max_len,
                                   source=batch.get("source"))

    return prefill_step


def build_decode_step(cfg: ModelConfig):
    def decode_step(params, cache, token, pos):
        return transformer.decode_step(cfg, params, cache, token, pos)

    return decode_step


# --------------------------------------------------------------------------
# sharding assembly: everything jit needs for one (arch x shape x mesh) cell
# --------------------------------------------------------------------------

def jit_cell(cfg: ModelConfig, shape: ShapeSpec, mesh,
             opt_cfg: adamw.AdamWConfig = adamw.AdamWConfig()):
    """Returns (jitted_fn, arg_specs) for the cell's step kind, with
    explicit in/out shardings and donation, plus the hint hook installed."""
    n = shd.named
    pspecs = transformer.param_specs(cfg)
    p_sh = n(mesh, shd.param_pspecs(cfg, mesh, pspecs))
    set_hook(shd.make_hint_hook(cfg, mesh, shape.global_batch,
                                shape.seq_len))
    bax = shd.batch_pspec(mesh, shape.global_batch, cfg.sharding_profile)[0]
    from jax.sharding import NamedSharding, PartitionSpec as P

    if shape.kind == "train":
        o_specs = opt_specs(cfg)
        o_sh = n(mesh, shd.zero1_pspecs(
            mesh, o_specs, {"m": shd.param_pspecs(cfg, mesh, pspecs),
                            "v": shd.param_pspecs(cfg, mesh, pspecs),
                            "step": P()}))
        b_specs = batch_specs(cfg, shape)
        b_sh = n(mesh, shd.input_pspecs(cfg, mesh, "train",
                                        shape.global_batch))
        b_sh = {k: b_sh[k] for k in b_specs}
        metr_sh = {"loss": NamedSharding(mesh, P()),
                   "lr": NamedSharding(mesh, P()),
                   "grad_norm": NamedSharding(mesh, P())}
        fn = jax.jit(build_train_step(cfg, opt_cfg),
                     in_shardings=(p_sh, o_sh, b_sh),
                     out_shardings=(p_sh, o_sh, metr_sh),
                     donate_argnums=(0, 1))
        return fn, (pspecs, o_specs, b_specs)

    if shape.kind == "prefill":
        b_specs = batch_specs(cfg, shape)
        b_sh = n(mesh, shd.input_pspecs(cfg, mesh, "prefill",
                                        shape.global_batch))
        b_sh = {k: b_sh[k] for k in b_specs}
        c_specs = cache_specs(cfg, shape)
        c_sh = n(mesh, shd.cache_pspecs(cfg, mesh, c_specs,
                                        shape.global_batch))
        out_sh = (NamedSharding(mesh, P(bax, None)), c_sh,
                  NamedSharding(mesh, P(bax)))
        fn = jax.jit(build_prefill_step(cfg, shape.seq_len),
                     in_shardings=(p_sh, b_sh), out_shardings=out_sh)
        return fn, (pspecs, b_specs)

    if shape.kind == "decode":
        c_specs = cache_specs(cfg, shape)
        c_sh = n(mesh, shd.cache_pspecs(cfg, mesh, c_specs,
                                        shape.global_batch))
        d_specs = decode_input_specs(cfg, shape)
        tok_sp = P(bax) if cfg.input_mode == "tokens" else P(bax, None)
        fn = jax.jit(build_decode_step(cfg),
                     in_shardings=(p_sh, c_sh,
                                   NamedSharding(mesh, tok_sp),
                                   NamedSharding(mesh, P(bax))),
                     out_shardings=(NamedSharding(mesh, P(bax, None)), c_sh),
                     donate_argnums=(1,))
        return fn, (pspecs, c_specs, d_specs["token"], d_specs["pos"])

    raise ValueError(shape.kind)


# --------------------------------------------------------------------------
# per-layer-group component (scan-body cost correction, see DESIGN.md)
# --------------------------------------------------------------------------

def jit_layer_group(cfg: ModelConfig, shape: ShapeSpec, mesh,
                    mode: str):
    """Compile one pattern-group application standalone so its cost can be
    multiplied by (repeats - 1): XLA's cost_analysis counts a scan body
    once.  mode: "train" (fwd+bwd via vjp) or "fwd"."""
    import numpy as np
    from jax.sharding import NamedSharding, PartitionSpec as P

    pspecs = transformer.param_specs(cfg)
    group_specs = jax.tree.map(
        lambda leaf: jax.ShapeDtypeStruct(leaf.shape[1:], leaf.dtype),
        pspecs["blocks"])
    group_psh = jax.tree.map(
        lambda sp: P(*sp[1:]),
        shd.param_pspecs(cfg, mesh, pspecs)["blocks"],
        is_leaf=lambda x: isinstance(x, P))

    b = shape.global_batch
    s = shape.seq_len if shape.kind != "decode" else 1
    x_spec = jax.ShapeDtypeStruct((b, s, cfg.d_model), cfg.dtype)
    has_cross = any(sp.kind == "cross" for sp in cfg.pattern)
    src_spec = jax.ShapeDtypeStruct(
        (b, cfg.cross_source_len, cfg.d_model), cfg.dtype) if has_cross \
        else None
    positions = np.arange(s)

    def group_fwd(gp, x, source):
        pos = jnp.asarray(positions)
        for i, spec in enumerate(cfg.pattern):
            apply = functools.partial(transformer._apply_block, cfg, spec)
            if cfg.remat and mode == "train":
                apply = jax.checkpoint(
                    apply, policy=getattr(jax.checkpoint_policies,
                                          cfg.remat_policy))
            x, _ = apply(gp[i], x, pos, source)
        return x

    set_hook(shd.make_hint_hook(cfg, mesh, shape.global_batch, s))
    bax = shd.batch_pspec(mesh, shape.global_batch, cfg.sharding_profile)[0]
    tp = mesh.shape.get("model", 1)
    s_ax = "model" if (cfg.sharding_profile != "fsdp_dp"
                       and s % tp == 0 and s >= tp) else None
    x_sh = NamedSharding(mesh, P(bax, s_ax, None))
    src_sh = NamedSharding(mesh, P(bax, None, None)) if has_cross else None

    if mode == "train":
        def fn(gp, x, ct, source=None):
            y, vjp = jax.vjp(lambda g, xx: group_fwd(g, xx, source), gp, x)
            return vjp(ct)

        args = (group_specs, x_spec, x_spec) + ((src_spec,) if has_cross
                                                else ())
        in_sh = (shd.named(mesh, group_psh), x_sh, x_sh) + (
            (src_sh,) if has_cross else ())
        return jax.jit(fn, in_shardings=in_sh), args

    if mode == "decode":
        from ..models import blocks as blk
        c_specs = cache_specs(cfg, shape)
        c_slice = jax.tree.map(
            lambda leaf: jax.ShapeDtypeStruct(leaf.shape[1:], leaf.dtype), c_specs)
        c_psh = jax.tree.map(
            lambda sp: P(*sp[1:]),
            shd.cache_pspecs(cfg, mesh, c_specs, shape.global_batch),
            is_leaf=lambda x: isinstance(x, P))
        pos_spec = jax.ShapeDtypeStruct((b,), jnp.int32)
        x1_spec = jax.ShapeDtypeStruct((b, 1, cfg.d_model), cfg.dtype)

        def fn(gp, gc, x, pos):
            new_c = []
            for i, spec in enumerate(cfg.pattern):
                p, c = gp[i], gc[i]
                if spec.kind == "attn":
                    x, c = blk.attention_block_decode(cfg, p["core"], x, c,
                                                      pos)
                elif spec.kind == "cross":
                    x, c = blk.attention_block_decode(cfg, p["core"], x, c,
                                                      pos, is_cross=True)
                elif spec.kind == "mamba":
                    x, c = blk.mamba_block_decode(cfg, p["core"], x, c)
                elif spec.kind == "rwkv":
                    x, c = blk.rwkv_block_decode(cfg, p["core"], x, c)
                if "ffn" in p:
                    if spec.moe:
                        x = blk.moe_block(cfg, p["ffn"], x, no_drop=True)
                    else:
                        x = blk.mlp_block(cfg, p["ffn"], x)
                new_c.append(c)
            return x, tuple(new_c)

        in_sh = (shd.named(mesh, group_psh), shd.named(mesh, c_psh),
                 NamedSharding(mesh, P(bax, None, None)),
                 NamedSharding(mesh, P(bax)))
        return jax.jit(fn, in_shardings=in_sh), \
            (group_specs, c_slice, x1_spec, pos_spec)

    def fn(gp, x, source=None):
        return group_fwd(gp, x, source)

    args = (group_specs, x_spec) + ((src_spec,) if has_cross else ())
    in_sh = (shd.named(mesh, group_psh), x_sh) + ((src_sh,) if has_cross
                                                  else ())
    return jax.jit(fn, in_shardings=in_sh), args
