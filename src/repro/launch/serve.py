"""Serving driver: batched prefill + sliced decode, executor-ready.

The engine exposes device work as GPU-access segments (`repro.core.
segments.SlicedOp`): ``decode_segment(n)`` is a sliced, resumable segment
— ``slice_tokens`` decode programs per dispatch, with the KV cache /
position / emitted tokens threaded as the explicit carry — so the
real-time executor preempts between slices with delay bounded by one
slice, and a checkpoint can snapshot the carry mid-generation.  This is
the TPU analogue of the paper's thread-block-granularity preemption
window (DESIGN.md §6).

  PYTHONPATH=src python -m repro.launch.serve --arch smollm-135m --reduced \
      --batch 4 --prompt-len 32 --decode 64
"""
from __future__ import annotations

import argparse
import time

import jax
import jax.numpy as jnp
import numpy as np

from ..configs import get
from ..core.segments import SlicedOp, n_slices_for
from ..models import transformer


class InferenceEngine:
    def __init__(self, cfg, params=None, max_len: int = 256, seed: int = 0):
        self.cfg = cfg
        self.params = params if params is not None else \
            transformer.init_params(cfg, jax.random.PRNGKey(seed))
        self.max_len = max_len
        self._prefill = jax.jit(
            lambda p, t: transformer.prefill(cfg, p, t, max_len))
        self._decode = jax.jit(
            lambda p, c, tok, pos: transformer.decode_step(cfg, p, c, tok,
                                                           pos))
        self.cache = None
        self.pos = None
        self.last_tok = None

    def prefill_batch(self, tokens: jax.Array):
        """tokens: (B, S).  Returns last-token logits."""
        logits, self.cache, self.pos = self._prefill(self.params, tokens)
        self.last_tok = jnp.argmax(logits, axis=-1).astype(jnp.int32)
        return logits

    # -- GPU-access segments (executor-dispatched) ----------------------
    def prefill_segment(self, tokens: jax.Array) -> SlicedOp:
        """Prefill as a one-slice device segment (a single XLA program;
        its measured duration is its own preemption-delay bound)."""
        def step(carry, i):
            return self.prefill_batch(tokens)

        return SlicedOp(1, lambda: None, step, lambda logits: logits,
                        label="prefill")

    def decode_segment(self, n: int, slice_tokens: int = 1) -> SlicedOp:
        """Generate ``n`` tokens as a sliced segment: ``slice_tokens``
        jitted decode programs per dispatch (the preemption grain), carry
        = {cache, pos, tok, out}.  The engine state is committed at
        finalize, so a preempted/abandoned carry never corrupts the
        engine; ``finalize`` returns the (B, n) tokens."""
        b = self.last_tok.shape[0]

        def init():
            return {"cache": self.cache, "pos": self.pos,
                    "tok": self.last_tok,
                    "out": jnp.zeros((b, n), jnp.int32)}

        def step(carry, i):
            cache, pos, tok, out = (carry["cache"], carry["pos"],
                                    carry["tok"], carry["out"])
            for t in range(i * slice_tokens,
                           min((i + 1) * slice_tokens, n)):
                logits, cache = self._decode(self.params, cache, tok, pos)
                pos = pos + 1
                tok = jnp.argmax(logits, axis=-1).astype(jnp.int32)
                out = jax.lax.dynamic_update_slice(
                    out, tok[:, None], (0, t))
            return {"cache": cache, "pos": pos, "tok": tok, "out": out}

        def finalize(carry):
            self.cache = carry["cache"]
            self.pos = carry["pos"]
            self.last_tok = carry["tok"]
            return carry["out"]

        return SlicedOp(n_slices_for(n, slice_tokens), init, step,
                        finalize, label="decode")

    def decode_chunk(self, n: int, greedy: bool = True):
        """Generate ``n`` tokens inline (no executor): runs the sliced
        segment to completion.  Returns (B, n) tokens."""
        return self.decode_segment(n).run()


def main() -> None:
    ap = argparse.ArgumentParser()
    ap.add_argument("--arch", required=True)
    ap.add_argument("--reduced", action="store_true")
    ap.add_argument("--batch", type=int, default=4)
    ap.add_argument("--prompt-len", type=int, default=32)
    ap.add_argument("--decode", type=int, default=64)
    args = ap.parse_args()

    entry = get(args.arch)
    cfg = entry.reduced() if args.reduced else entry.config()
    eng = InferenceEngine(cfg, max_len=args.prompt_len + args.decode + 8)
    toks = jax.random.randint(jax.random.PRNGKey(1),
                              (args.batch, args.prompt_len), 0,
                              cfg.vocab_size)
    t0 = time.perf_counter()
    eng.prefill_batch(toks)
    t1 = time.perf_counter()
    out = eng.decode_chunk(args.decode)
    jax.block_until_ready(out)
    t2 = time.perf_counter()
    print(f"prefill {args.batch}x{args.prompt_len}: {(t1 - t0) * 1e3:.1f} ms")
    per_tok = (t2 - t1) * 1e3 / args.decode
    print(f"decode {args.decode} tokens: {per_tok:.2f} ms/tok "
          f"({args.batch * 1e3 / per_tok / 1e3:.1f} tok/s aggregate)")
    print("sample:", np.asarray(out[0, :16]))


if __name__ == "__main__":
    main()
