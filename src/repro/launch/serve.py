"""Serving driver: batched prefill + sliced decode, executor-ready.

The engine exposes device work as GPU-access segments (`repro.core.
segments.SlicedOp`): ``decode_segment(n)`` is a sliced, resumable segment
— ``slice_tokens`` decode programs per dispatch, with the KV cache /
position / emitted tokens threaded as the explicit carry — so the
real-time executor preempts between slices with delay bounded by one
slice, and a checkpoint can snapshot the carry mid-generation.  This is
the TPU analogue of the paper's thread-block-granularity preemption
window (DESIGN.md §6).

  PYTHONPATH=src python -m repro.launch.serve --arch smollm-135m --reduced \
      --batch 4 --prompt-len 32 --decode 64

``--n-devices N`` (N > 1) switches to disaggregated serving: a prefill
pool on device 0 and a decode pool on device N-1, each driven by its own
preemptive ``DeviceExecutor`` inside a ``ClusterExecutor`` whose
placement-aware admission pins the pools to their devices (DESIGN.md
§7).  The KV cache is handed off between pools with an explicit
``device_put``.  On a CPU host, expose devices with
``XLA_FLAGS=--xla_force_host_platform_device_count=N``.
"""
from __future__ import annotations

import argparse
import threading
import time

import jax
import jax.numpy as jnp
import numpy as np

from ..configs import get
from ..core.segments import SlicedOp, n_slices_for
from ..models import transformer


class InferenceEngine:
    def __init__(self, cfg, params=None, max_len: int = 256, seed: int = 0,
                 device=None):
        """``device`` (a ``jax.Device``) places the params — and therefore
        every jitted program — on one accelerator of a multi-device host;
        None keeps the platform default."""
        self.cfg = cfg
        self.device = device
        self.params = params if params is not None else \
            transformer.init_params(cfg, jax.random.PRNGKey(seed))
        if device is not None:
            self.params = jax.device_put(self.params, device)
        self.max_len = max_len
        self._prefill = jax.jit(
            lambda p, t: transformer.prefill(cfg, p, t, max_len))
        self._decode = jax.jit(
            lambda p, c, tok, pos: transformer.decode_step(cfg, p, c, tok,
                                                           pos))
        self.cache = None
        self.pos = None
        self.last_tok = None

    def prefill_batch(self, tokens: jax.Array):
        """tokens: (B, S).  Returns last-token logits."""
        if self.device is not None:
            tokens = jax.device_put(tokens, self.device)
        logits, self.cache, self.pos = self._prefill(self.params, tokens)
        self.last_tok = jnp.argmax(logits, axis=-1).astype(jnp.int32)
        return logits

    def adopt_state(self, cache, pos, last_tok) -> None:
        """Take over another engine's KV state — the prefill→decode
        handoff of disaggregated serving.  The state is ``device_put``
        onto this engine's device (the explicit cross-device transfer
        the placement layer charges to the handoff, not to a segment)."""
        if self.device is not None:
            cache, pos, last_tok = jax.device_put(
                (cache, pos, last_tok), self.device)
        self.cache, self.pos, self.last_tok = cache, pos, last_tok

    # -- GPU-access segments (executor-dispatched) ----------------------
    def prefill_segment(self, tokens: jax.Array) -> SlicedOp:
        """Prefill as a one-slice device segment (a single XLA program;
        its measured duration is its own preemption-delay bound)."""
        def step(carry, i):
            return self.prefill_batch(tokens)

        return SlicedOp(1, lambda: None, step, lambda logits: logits,
                        label="prefill")

    def decode_segment(self, n: int, slice_tokens: int = 1) -> SlicedOp:
        """Generate ``n`` tokens as a sliced segment: ``slice_tokens``
        jitted decode programs per dispatch (the preemption grain), carry
        = {cache, pos, tok, out}.  The engine state is committed at
        finalize, so a preempted/abandoned carry never corrupts the
        engine; ``finalize`` returns the (B, n) tokens."""
        b = self.last_tok.shape[0]

        def init():
            return {"cache": self.cache, "pos": self.pos,
                    "tok": self.last_tok,
                    "out": jnp.zeros((b, n), jnp.int32)}

        def step(carry, i):
            cache, pos, tok, out = (carry["cache"], carry["pos"],
                                    carry["tok"], carry["out"])
            for t in range(i * slice_tokens,
                           min((i + 1) * slice_tokens, n)):
                logits, cache = self._decode(self.params, cache, tok, pos)
                pos = pos + 1
                tok = jnp.argmax(logits, axis=-1).astype(jnp.int32)
                out = jax.lax.dynamic_update_slice(
                    out, tok[:, None], (0, t))
            return {"cache": cache, "pos": pos, "tok": tok, "out": out}

        def finalize(carry):
            self.cache = carry["cache"]
            self.pos = carry["pos"]
            self.last_tok = carry["tok"]
            return carry["out"]

        return SlicedOp(n_slices_for(n, slice_tokens), init, step,
                        finalize, label="decode")

    def decode_chunk(self, n: int, greedy: bool = True):
        """Generate ``n`` tokens inline (no executor): runs the sliced
        segment to completion.  Returns (B, n) tokens."""
        return self.decode_segment(n).run()


def run_disaggregated(cfg, args) -> None:
    """Prefill and decode pools on separate devices: the classic
    disaggregated-serving scenario, on the cluster runtime.  Each pool
    is an RT job pinned to its device; admission runs the cross-device
    analysis on the pinned placements before either job may start.
    Submission goes through the unified facade (``repro.sched.connect``
    → ``SchedClient``, DESIGN.md §9) — the bodies still bracket their
    device segments on the executor face via ``client.cluster``."""
    from ..sched import JobProfile, connect

    n = args.n_devices
    devs = jax.devices()
    if len(devs) < n:
        raise SystemExit(
            f"--n-devices {n} but only {len(devs)} device(s) visible; "
            f"set XLA_FLAGS=--xla_force_host_platform_device_count={n}")
    prefill_dev, decode_dev = 0, n - 1
    max_len = args.prompt_len + args.decode + 8
    pre = InferenceEngine(cfg, max_len=max_len, device=devs[prefill_dev])
    dec = InferenceEngine(cfg, params=pre.params, max_len=max_len,
                          device=devs[decode_dev])
    toks = jax.random.randint(jax.random.PRNGKey(1),
                              (args.batch, args.prompt_len), 0,
                              cfg.vocab_size)

    # warm-up + nominal WCETs for the admission profiles (margin below)
    pre.prefill_batch(toks)
    jax.block_until_ready(pre.cache)
    dec.adopt_state(pre.cache, pre.pos, pre.last_tok)
    dec.decode_chunk(2)
    t0 = time.perf_counter()
    pre.prefill_batch(toks)
    jax.block_until_ready(pre.cache)
    prefill_ms = (time.perf_counter() - t0) * 1e3
    dec.adopt_state(pre.cache, pre.pos, pre.last_tok)
    t0 = time.perf_counter()
    jax.block_until_ready(dec.decode_chunk(4))
    decode_ms = (time.perf_counter() - t0) * 1e3 / 4 * args.decode

    client = connect(n_devices=n, policy="ioctl", wait_mode="suspend",
                     n_cpus=2, epsilon_ms=1.0)
    cluster = client.cluster
    handoff = threading.Event()
    out: dict = {}
    times: dict = {}

    def prefill_body(job, it):
        t = time.perf_counter()
        with cluster.device_segment(job):
            cluster.run_sliced(job, pre.prefill_segment(toks))
        dec.adopt_state(pre.cache, pre.pos, pre.last_tok)
        times["prefill_ms"] = (time.perf_counter() - t) * 1e3
        handoff.set()

    def decode_body(job, it):
        if not handoff.wait(timeout=120):
            raise RuntimeError("prefill pool never handed off")
        t = time.perf_counter()
        with cluster.device_segment(job):
            out["tokens"] = cluster.run_sliced(
                job, dec.decode_segment(args.decode, slice_tokens=4))
        times["decode_ms"] = (time.perf_counter() - t) * 1e3

    period = max(prefill_ms + decode_ms, 1.0) * 20
    m = 3.0  # one observation is not a WCET
    r_pre = client.submit(
        JobProfile("prefill", [1.0], [(1.0, prefill_ms * m)],
                   period_ms=period, priority=40, cpu=0,
                   device=prefill_dev),
        body=prefill_body)
    r_dec = client.submit(
        JobProfile("decode", [1.0], [(1.0, decode_ms * m)],
                   period_ms=period, priority=50, cpu=1,
                   device=decode_dev),
        body=decode_body)
    # check both admissions before starting either pool: a refusal must
    # not leave the other pool's thread running behind an exception
    for tag, r in (("prefill", r_pre), ("decode", r_dec)):
        if not r.accepted:
            client.close(shutdown=True)
            raise SystemExit(f"{tag} pool refused admission "
                             f"({r.reason}): {r.error or r.wcrt}")
    print(f"admission: prefill -> device {r_pre['device']} "
          f"({r_pre['via']}), decode -> device {r_dec['device']} "
          f"({r_dec['via']})")
    assert r_pre["device"] != r_dec["device"]
    r_pre.job.start(cluster)
    r_dec.job.start(cluster)
    try:
        client.join(180)
    finally:
        client.close(shutdown=True)
    cluster.assert_migration_free()

    if "tokens" not in out:
        raise SystemExit("decode pool produced no tokens "
                         "(handoff or pool failure — see traceback above)")
    toks_out = out["tokens"]
    per_tok = times["decode_ms"] / args.decode
    print(f"prefill pool (device {prefill_dev}): "
          f"{args.batch}x{args.prompt_len} in {times['prefill_ms']:.1f} ms")
    print(f"decode pool (device {decode_dev}): {args.decode} tokens, "
          f"{per_tok:.2f} ms/tok "
          f"({args.batch * 1e3 / per_tok / 1e3:.1f} tok/s aggregate)")
    morts = client.per_device_mort()
    print("per-device MORT (s):",
          {d: (round(v, 3) if v is not None else None)
           for d, v in morts.items()})
    print("sample:", np.asarray(toks_out[0, :16]))
    print("disaggregated serve OK")


def register_serving_workloads(cfg, seed: int = 1) -> None:
    """Register the serving segments in the durable-workload registry
    (``repro.sched.workloads``): ``serve.decode`` is a prefill + sliced
    decode whose carry (KV cache, position, emitted tokens) checkpoints
    mid-generation — a daemon submission of it survives a restart and
    resumes decoding at the journaled slice."""
    from ..sched.workloads import register_workload

    engines: dict = {}

    def decode_factory(batch: int = 2, prompt_len: int = 16,
                       decode: int = 32, slice_tokens: int = 4):
        key = (batch, prompt_len, decode)
        eng = engines.get(key)
        if eng is None:
            eng = InferenceEngine(cfg,
                                  max_len=prompt_len + decode + 8)
            engines[key] = eng
        toks = jax.random.randint(jax.random.PRNGKey(seed),
                                  (batch, prompt_len), 0, cfg.vocab_size)
        eng.prefill_batch(toks)
        return eng.decode_segment(decode, slice_tokens=slice_tokens)

    register_workload("serve.decode", decode_factory)


def run_daemon(cfg, args) -> None:
    """Daemon mode: the serving workloads registered, then the durable
    scheduling daemon (`repro.sched.daemon`) owning the cluster — submit
    with ``python -m repro.sched.client --socket ... submit --workload
    serve.decode ...`` and the generation survives ``kill -9``."""
    import os
    import signal

    from ..sched.daemon import SchedDaemon

    register_serving_workloads(cfg)
    daemon = SchedDaemon(args.store, args.socket,
                         n_devices=args.n_devices)
    daemon.start()
    print(f"serve daemon ready pid={os.getpid()} "
          f"socket={daemon.socket_path} "
          f"recovered={daemon.recovery['recovered']} "
          f"resumed={sorted(daemon.recovery['resumed'])}", flush=True)
    signal.signal(signal.SIGTERM, lambda *a: daemon._stop.set())
    try:
        daemon.serve_forever()
    finally:
        daemon.stop()


def main() -> None:
    ap = argparse.ArgumentParser()
    ap.add_argument("--arch", required=True)
    ap.add_argument("--reduced", action="store_true")
    ap.add_argument("--batch", type=int, default=4)
    ap.add_argument("--prompt-len", type=int, default=32)
    ap.add_argument("--decode", type=int, default=64)
    ap.add_argument("--n-devices", type=int, default=1,
                    help="N>1: disaggregated prefill/decode pools on "
                         "separate devices via ClusterExecutor")
    ap.add_argument("--daemon", action="store_true",
                    help="run the durable scheduling daemon with the "
                         "serving workloads registered")
    ap.add_argument("--store", default=None,
                    help="daemon job-store directory (--daemon)")
    ap.add_argument("--socket", default=None,
                    help="daemon unix socket (--daemon; default "
                         "<store>/sock)")
    args = ap.parse_args()

    entry = get(args.arch)
    cfg = entry.reduced() if args.reduced else entry.config()
    if args.daemon:
        if not args.store:
            ap.error("--daemon requires --store")
        run_daemon(cfg, args)
        return
    if args.n_devices > 1:
        run_disaggregated(cfg, args)
        return
    eng = InferenceEngine(cfg, max_len=args.prompt_len + args.decode + 8)
    toks = jax.random.randint(jax.random.PRNGKey(1),
                              (args.batch, args.prompt_len), 0,
                              cfg.vocab_size)
    t0 = time.perf_counter()
    eng.prefill_batch(toks)
    t1 = time.perf_counter()
    out = eng.decode_chunk(args.decode)
    jax.block_until_ready(out)
    t2 = time.perf_counter()
    print(f"prefill {args.batch}x{args.prompt_len}: {(t1 - t0) * 1e3:.1f} ms")
    per_tok = (t2 - t1) * 1e3 / args.decode
    print(f"decode {args.decode} tokens: {per_tok:.2f} ms/tok "
          f"({args.batch * 1e3 / per_tok / 1e3:.1f} tok/s aggregate)")
    print("sample:", np.asarray(out[0, :16]))


if __name__ == "__main__":
    main()
