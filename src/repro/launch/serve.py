"""Serving driver: batched prefill + chunked decode, executor-ready.

The engine exposes device work in bounded-duration chunks (``decode_chunk``)
so the real-time executor can preempt between chunks — the TPU analogue of
the paper's thread-block-granularity preemption window.

  PYTHONPATH=src python -m repro.launch.serve --arch smollm-135m --reduced \
      --batch 4 --prompt-len 32 --decode 64
"""
from __future__ import annotations

import argparse
import time

import jax
import jax.numpy as jnp
import numpy as np

from ..configs import get
from ..models import transformer


class InferenceEngine:
    def __init__(self, cfg, params=None, max_len: int = 256, seed: int = 0):
        self.cfg = cfg
        self.params = params if params is not None else \
            transformer.init_params(cfg, jax.random.PRNGKey(seed))
        self.max_len = max_len
        self._prefill = jax.jit(
            lambda p, t: transformer.prefill(cfg, p, t, max_len))
        self._decode = jax.jit(
            lambda p, c, tok, pos: transformer.decode_step(cfg, p, c, tok,
                                                           pos))
        self.cache = None
        self.pos = None
        self.last_tok = None

    def prefill_batch(self, tokens: jax.Array):
        """tokens: (B, S).  Returns last-token logits."""
        logits, self.cache, self.pos = self._prefill(self.params, tokens)
        self.last_tok = jnp.argmax(logits, axis=-1).astype(jnp.int32)
        return logits

    def decode_chunk(self, n: int, greedy: bool = True):
        """Generate ``n`` tokens; one jitted program per token (the
        preemption boundary).  Returns (B, n) tokens."""
        out = []
        for _ in range(n):
            logits, self.cache = self._decode(self.params, self.cache,
                                              self.last_tok, self.pos)
            self.pos = self.pos + 1
            self.last_tok = jnp.argmax(logits, axis=-1).astype(jnp.int32)
            out.append(self.last_tok)
        return jnp.stack(out, axis=1)


def main() -> None:
    ap = argparse.ArgumentParser()
    ap.add_argument("--arch", required=True)
    ap.add_argument("--reduced", action="store_true")
    ap.add_argument("--batch", type=int, default=4)
    ap.add_argument("--prompt-len", type=int, default=32)
    ap.add_argument("--decode", type=int, default=64)
    args = ap.parse_args()

    entry = get(args.arch)
    cfg = entry.reduced() if args.reduced else entry.config()
    eng = InferenceEngine(cfg, max_len=args.prompt_len + args.decode + 8)
    toks = jax.random.randint(jax.random.PRNGKey(1),
                              (args.batch, args.prompt_len), 0,
                              cfg.vocab_size)
    t0 = time.perf_counter()
    eng.prefill_batch(toks)
    t1 = time.perf_counter()
    out = eng.decode_chunk(args.decode)
    jax.block_until_ready(out)
    t2 = time.perf_counter()
    print(f"prefill {args.batch}x{args.prompt_len}: {(t1 - t0) * 1e3:.1f} ms")
    per_tok = (t2 - t1) * 1e3 / args.decode
    print(f"decode {args.decode} tokens: {per_tok:.2f} ms/tok "
          f"({args.batch * 1e3 / per_tok / 1e3:.1f} tok/s aggregate)")
    print("sample:", np.asarray(out[0, :16]))


if __name__ == "__main__":
    main()
