"""Heterogeneous multi-model serving fleet (DESIGN.md §12).

A *fleet* is N models from the config registry served together on one
multi-device cluster as a mixed-criticality workload: interactive
decode segments run as RT jobs with admission-checked WCRTs, while
background training / batch-eval runs best-effort underneath — shed
first under overload (``sched.elastic``), never able to block an RT
dispatch (the priority-inversion-freedom invariant the conformance
harness pins).  Every member contributes its own *measured*
``WorkloadProfile`` through the ``SegmentedWorkload.profile()``
pipeline, so admission prices the fleet from real per-slice times, and
``ClusterExecutor.stats()`` reports MORT / deadline misses / p50/p99
per model and per criticality tier.

  PYTHONPATH=src python -m repro.launch.fleet --n-devices 2 \
      --duration 6 --models chat,assist,train

``--daemon`` registers every member as a durable workload
(``fleet.<member>``) and runs the scheduling daemon instead, so fleet
submissions survive ``kill -9`` (same pattern as
``repro.launch.serve --daemon``).  On a CPU host, expose devices with
``XLA_FLAGS=--xla_force_host_platform_device_count=N``.
"""
from __future__ import annotations

import argparse
import json
import time
from dataclasses import dataclass, replace
from typing import Dict, List, Optional, Sequence

import jax
import jax.numpy as jnp

from ..configs import get
from ..core.segments import SegmentedWorkload, SlicedOp
from ..models import transformer
from ..optim import adamw
from .serve import InferenceEngine
from .steps import build_train_step

#: criticality tiers of the default fleet (DESIGN.md §12): interactive
#: chat > latency-tolerant assist/refresh > bulk background
TIER_INTERACTIVE, TIER_STANDARD, TIER_BULK = 2, 1, 0


@dataclass(frozen=True)
class FleetMember:
    """One model of the fleet: which registry architecture, what role
    its device work plays, and where it sits in the criticality order.

    ``role``:
      * ``decode``  — RT interactive serving: a prefill segment + a
        sliced decode segment per release;
      * ``train``   — best-effort training: one optimizer step per
        slice (state committed at finalize);
      * ``eval``    — best-effort batch inference: one forward
        ``lm_loss`` per slice.
    """
    name: str
    arch: str                # configs registry name (reduced() is used)
    role: str                # decode | train | eval
    tier: int
    priority: int
    period_ms: float
    device: int = 0
    best_effort: bool = False
    batch: int = 2
    prompt_len: int = 8
    decode_tokens: int = 4
    slice_tokens: int = 2
    microbatches: int = 2
    seq_len: int = 16
    n_iterations: int = 1000

    def __post_init__(self):
        if self.role not in ("decode", "train", "eval"):
            raise ValueError(f"unknown fleet role {self.role!r}")
        if (self.role != "decode") != self.best_effort:
            raise ValueError(
                f"{self.name}: decode members are RT, train/eval members "
                "are best-effort — the mixed-criticality contract")


def default_fleet(n_devices: int = 2,
                  models: Optional[Sequence[str]] = None
                  ) -> List[FleetMember]:
    """The reference fleet: two interactive decode models over two
    background models, spread across the devices.  ``models`` filters
    by member name (CI runs a 3-model subset)."""
    last = max(n_devices - 1, 0)
    fleet = [
        FleetMember("chat", "smollm-135m", "decode",
                    tier=TIER_INTERACTIVE, priority=50,
                    period_ms=1500.0, device=0),
        FleetMember("assist", "olmo-1b", "decode",
                    tier=TIER_STANDARD, priority=30,
                    period_ms=2000.0, device=last),
        FleetMember("train", "minitron-8b", "train",
                    tier=TIER_STANDARD, priority=5,
                    period_ms=800.0, device=last, best_effort=True),
        FleetMember("batch-eval", "mixtral-8x22b", "eval",
                    tier=TIER_BULK, priority=1,
                    period_ms=600.0, device=0, best_effort=True),
    ]
    if models:
        wanted = set(models)
        unknown = wanted - {m.name for m in fleet}
        if unknown:
            raise ValueError(f"unknown fleet member(s) {sorted(unknown)}; "
                             f"available: {[m.name for m in fleet]}")
        fleet = [m for m in fleet if m.name in wanted]
    return [replace(m, device=min(m.device, last)) for m in fleet]


# --------------------------------------------------------------------------
# member -> SegmentedWorkload (the measured pipeline's entry)
# --------------------------------------------------------------------------

def build_member_workload(member: FleetMember, jdev=None,
                          seed: int = 0) -> SegmentedWorkload:
    """The member's device work as a ``SegmentedWorkload`` — profiled
    for admission and bound as the RT/BE job body.  ``jdev`` (a
    ``jax.Device``) places the params so the programs really run on the
    member's scheduling device."""
    cfg = get(member.arch).reduced()
    if member.role == "decode":
        eng = InferenceEngine(
            cfg, max_len=member.prompt_len + member.decode_tokens + 8,
            seed=seed, device=jdev)
        prompt = jnp.zeros((member.batch, member.prompt_len), jnp.int32)
        return (SegmentedWorkload(member.name)
                .device(lambda: eng.prefill_segment(prompt),
                        label="prefill")
                .device(lambda: eng.decode_segment(
                    member.decode_tokens,
                    slice_tokens=member.slice_tokens), label="decode"))

    params = transformer.init_params(cfg, jax.random.PRNGKey(seed))
    if jdev is not None:
        params = jax.device_put(params, jdev)
    shape = (member.batch, member.seq_len)
    mbs = [{"inputs": jnp.zeros(shape, jnp.int32),
            "labels": jnp.zeros(shape, jnp.int32)}
           for _ in range(member.microbatches)]
    if jdev is not None:
        mbs = jax.device_put(mbs, jdev)

    if member.role == "train":
        state = {"params": params, "opt": adamw.init_opt_state(params)}
        step_fn = jax.jit(build_train_step(cfg))

        def train_op() -> SlicedOp:
            def step(carry, i):
                p, o, _ = step_fn(carry[0], carry[1], mbs[i])
                return (p, o)

            def finalize(carry):
                state.update(params=carry[0], opt=carry[1])
                return None

            return SlicedOp(len(mbs),
                            lambda: (state["params"], state["opt"]),
                            step, finalize, label="train_step")

        return SegmentedWorkload(member.name).device(train_op,
                                                     label="train")

    # eval: forward-only lm_loss, one microbatch per slice
    loss_fn = jax.jit(lambda p, b: transformer.lm_loss(cfg, p, b))

    def eval_op() -> SlicedOp:
        return SlicedOp(len(mbs),
                        lambda: jnp.zeros((), jnp.float32),
                        lambda carry, i: carry + loss_fn(params, mbs[i]),
                        lambda carry: float(carry), label="eval")

    return SegmentedWorkload(member.name).device(eval_op, label="eval")


def member_op_factory(member: FleetMember, seed: int = 1):
    """A durable-workload factory for one member: builds the member's
    stack lazily on first use, then returns a fresh ``SlicedOp`` per
    release (for decode members the prefill runs inline, mirroring
    ``serve.register_serving_workloads``)."""
    built: dict = {}

    def factory() -> SlicedOp:
        wl = built.get("wl")
        if wl is None:
            wl = build_member_workload(member, seed=seed)
            built["wl"] = wl
        if member.role == "decode":
            # entries: [prefill, decode] — run prefill to completion
            # inline, hand the executor the resumable decode segment
            wl._entries[0].fn().run()
            return wl._entries[1].fn()
        return wl._entries[0].fn()

    return factory


def register_fleet_workloads(members: Sequence[FleetMember],
                             seed: int = 1) -> None:
    """Register every member as ``fleet.<name>`` in the durable-workload
    registry, so daemon submissions of fleet work survive a restart."""
    from ..sched.workloads import register_workload

    for m in members:
        register_workload(f"fleet.{m.name}", member_op_factory(m, seed))


# --------------------------------------------------------------------------
# the fleet run: profile -> admit -> run -> per-tier report
# --------------------------------------------------------------------------

def launch_fleet(members: Sequence[FleetMember], *, n_devices: int = 2,
                 duration_s: float = 6.0, policy: str = "ioctl",
                 wait_mode: str = "suspend", reps: int = 2,
                 margin: float = 2.0, shed_policy=None,
                 verbose: bool = True) -> dict:
    """Serve the fleet end-to-end: build + profile every member, admit
    the fleet onto an owned cluster (RT members must pass the RTA; a
    refusal aborts before anything starts), run for ``duration_s``, and
    return the observability report — admission evidence plus the
    per-model / per-tier stats surface.

    Raises ``SystemExit`` if any RT member is refused admission."""
    from ..sched import JobProfile, connect

    log = print if verbose else (lambda *a, **k: None)
    jdevs = jax.devices()
    if n_devices > 1 and len(jdevs) < n_devices:
        log(f"WARNING: --n-devices {n_devices} but only {len(jdevs)} jax "
            f"device(s); programs share one physical device (set "
            f"XLA_FLAGS=--xla_force_host_platform_device_count="
            f"{n_devices})")

    workloads: Dict[str, SegmentedWorkload] = {}
    profiles: Dict[str, object] = {}
    for m in members:
        jdev = jdevs[m.device] if len(jdevs) > m.device else None
        t0 = time.perf_counter()
        workloads[m.name] = build_member_workload(m, jdev=jdev)
        # the first profile rep doubles as the jit warm-up
        profiles[m.name] = workloads[m.name].profile(reps=reps)
        log(f"profiled {m.name} ({m.arch}, {m.role}, tier {m.tier}): "
            f"max slice {profiles[m.name].max_slice_ms:.1f}ms "
            f"[{time.perf_counter() - t0:.1f}s]")

    # epsilon = admission-update cost + one in-flight slice (any
    # member's): preemption takes effect at slice boundaries
    max_slice = max(p.max_slice_ms for p in profiles.values())
    eps_ms = 1.0 + max_slice * 1.2

    client = connect(n_devices=n_devices, policy=policy,
                     wait_mode=wait_mode, n_cpus=2, epsilon_ms=eps_ms,
                     shed_policy=shed_policy)
    cluster = client.cluster
    report: dict = {"n_devices": n_devices, "epsilon_ms": eps_ms,
                    "models": {}}
    jobs = []
    try:
        for m in members:
            res = client.submit(
                JobProfile.from_workload(
                    profiles[m.name], period_ms=m.period_ms,
                    priority=m.priority, best_effort=m.best_effort,
                    margin=margin, device=m.device, tier=m.tier),
                workload=workloads[m.name],
                n_iterations=m.n_iterations)
            wcrt = (res.get("wcrt") or {}).get(m.name)
            report["models"][m.name] = {
                "arch": m.arch, "role": m.role, "tier": m.tier,
                "best_effort": m.best_effort,
                "admitted": bool(res.accepted),
                "device": res.get("device"),
                "wcrt_ms": wcrt,
            }
            if not res.accepted and not m.best_effort:
                raise SystemExit(
                    f"RT member {m.name!r} refused admission "
                    f"({res.reason}): {res.error or res.get('wcrt')}")
            log(f"admitted {m.name} -> device {res.get('device')} "
                f"({'BE' if m.best_effort else f'WCRT {wcrt:.1f}ms'})")
            if res.job is not None:
                jobs.append((m, res.job))

        # best-effort background first, then the RT models over it
        for m, job in jobs:
            if m.best_effort:
                job.start(cluster, stop_after_s=duration_s)
        time.sleep(0.05)
        for m, job in jobs:
            if not m.best_effort:
                job.start(cluster, stop_after_s=duration_s)
        client.join(duration_s * 10 + 120)

        report["per_model"] = cluster.per_model_stats()
        report["per_tier"] = cluster.per_tier_stats()
        report["per_device_mort"] = client.per_device_mort()
        report["admission_latency"] = client.admission_latency()
    finally:
        client.close(shutdown=True)
    cluster.assert_migration_free()
    return report


def check_fleet_report(report: dict) -> None:
    """The fleet acceptance assertions: every admitted RT model
    completed releases and observed MORT within its admitted WCRT."""
    for name, m in report["models"].items():
        if m["best_effort"] or not m["admitted"]:
            continue
        stats = report["per_model"][name]
        assert stats["completions"] > 0, f"{name} never completed"
        assert stats["mort_ms"] is not None
        assert stats["mort_ms"] <= m["wcrt_ms"] + 1e-6, \
            f"{name}: MORT {stats['mort_ms']:.1f}ms exceeds admitted " \
            f"WCRT {m['wcrt_ms']:.1f}ms"


def _print_report(report: dict) -> None:
    for name, m in report["models"].items():
        s = report["per_model"].get(name, {})
        kind = "BE" if m["best_effort"] else f"WCRT {m['wcrt_ms']:.1f}ms"
        mort = (f"{s['mort_ms']:.1f}" if s.get("mort_ms") is not None
                else "-")
        p99 = (f"{s['p99_ms']:.1f}" if s.get("p99_ms") is not None
               else "-")
        print(f"  {name:<10} tier {m['tier']} dev {m['device']} "
              f"[{kind}] completions {s.get('completions', 0)} "
              f"misses {s.get('deadline_misses', 0)} "
              f"MORT {mort}ms p99 {p99}ms")
    for tier in sorted(report["per_tier"], reverse=True):
        t = report["per_tier"][tier]
        p99 = (f"{t['p99_ms']:.1f}" if t.get("p99_ms") is not None
               else "-")
        print(f"  tier {tier}: jobs {t['jobs']} completions "
              f"{t['completions']} misses {t['deadline_misses']} "
              f"p99 {p99}ms util {t['utilization']:.3f}")


def run_fleet_daemon(members: Sequence[FleetMember], args) -> None:
    """Daemon mode: the fleet workloads registered durable, then the
    scheduling daemon owning the cluster — submit with
    ``python -m repro.sched.client --socket ... submit --workload
    fleet.chat ...`` and the fleet survives ``kill -9``."""
    import os
    import signal

    from ..sched.daemon import SchedDaemon

    register_fleet_workloads(members)
    daemon = SchedDaemon(args.store, args.socket,
                         n_devices=args.n_devices,
                         shed_policy=_shed_from_args(args))
    daemon.start()
    print(f"fleet daemon ready pid={os.getpid()} "
          f"socket={daemon.socket_path} "
          f"workloads={[f'fleet.{m.name}' for m in members]}", flush=True)
    signal.signal(signal.SIGTERM, lambda *a: daemon._stop.set())
    try:
        daemon.serve_forever()
    finally:
        daemon.stop()


def _shed_from_args(args):
    from ..sched.elastic import ShedPolicy

    if args.shed_at is None:
        if args.tier_budget:
            raise SystemExit("--tier-budget needs --shed-at")
        return None
    budgets = {int(t): float(b) for t, b in
               (spec.split("=", 1) for spec in (args.tier_budget or []))}
    return ShedPolicy(
        shed_at=args.shed_at,
        resume_at=(args.resume_at if args.resume_at is not None
                   else 0.8 * args.shed_at),
        tier_budgets=budgets or None)


def main() -> None:
    ap = argparse.ArgumentParser(
        description="mixed-criticality multi-model serving fleet")
    ap.add_argument("--n-devices", type=int, default=2)
    ap.add_argument("--duration", type=float, default=6.0,
                    help="seconds to serve before stopping the fleet")
    ap.add_argument("--models", default=None,
                    help="comma-separated member subset (default: all "
                         "four reference models)")
    ap.add_argument("--policy", default="ioctl")
    ap.add_argument("--wait-mode", default="suspend")
    ap.add_argument("--reps", type=int, default=2,
                    help="profile repetitions per member")
    ap.add_argument("--shed-at", type=float, default=None,
                    help="per-device utilization above which best-effort "
                         "members are shed")
    ap.add_argument("--resume-at", type=float, default=None)
    ap.add_argument("--tier-budget", action="append", default=[],
                    metavar="TIER=FRAC",
                    help="per-tier best-effort utilization budget "
                         "(repeatable; requires --shed-at)")
    ap.add_argument("--json", default=None,
                    help="write the fleet report to PATH")
    ap.add_argument("--daemon", action="store_true",
                    help="register fleet workloads and run the durable "
                         "scheduling daemon instead of a one-shot run")
    ap.add_argument("--store", default=None,
                    help="daemon job-store directory (--daemon)")
    ap.add_argument("--socket", default=None,
                    help="daemon unix socket (--daemon)")
    args = ap.parse_args()

    models = args.models.split(",") if args.models else None
    members = default_fleet(args.n_devices, models)
    if args.daemon:
        if not args.store:
            ap.error("--daemon requires --store")
        run_fleet_daemon(members, args)
        return

    report = launch_fleet(
        members, n_devices=args.n_devices, duration_s=args.duration,
        policy=args.policy, wait_mode=args.wait_mode, reps=args.reps,
        shed_policy=_shed_from_args(args))
    _print_report(report)
    if args.json:
        with open(args.json, "w") as f:
            json.dump(report, f, indent=2, default=str)
        print(f"wrote {args.json}")
    check_fleet_report(report)
    print(f"fleet OK: {len(members)} models, "
          f"{len(report['per_tier'])} tiers, "
          f"{args.n_devices} devices")


if __name__ == "__main__":
    main()
