"""Production mesh construction.

Pure functions (no module-level jax device-state access) so importing this
module never locks the backend: ``dryrun.py`` must set
XLA_FLAGS=--xla_force_host_platform_device_count=512 before any jax import.
"""
from __future__ import annotations

import jax


def make_production_mesh(*, multi_pod: bool = False):
    """Single pod: (data=16, model=16) = 256 chips (TPU v5e-256).
    Multi-pod: (pod=2, data=16, model=16) = 512 chips; the "pod" axis is
    the data-center-network data-parallel dimension."""
    shape = (2, 16, 16) if multi_pod else (16, 16)
    axes = ("pod", "data", "model") if multi_pod else ("data", "model")
    return jax.make_mesh(
        shape, axes,
        axis_types=(jax.sharding.AxisType.Auto,) * len(axes))


def make_test_mesh(*, multi_pod: bool = False):
    """Miniature mesh for CI on 8 host devices: (2,2,2) or (2,4)."""
    if multi_pod:
        return jax.make_mesh((2, 2, 2), ("pod", "data", "model"),
                             axis_types=(jax.sharding.AxisType.Auto,) * 3)
    return jax.make_mesh((2, 4), ("data", "model"),
                         axis_types=(jax.sharding.AxisType.Auto,) * 2)
