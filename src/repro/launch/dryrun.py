import os
os.environ["XLA_FLAGS"] = "--xla_force_host_platform_device_count=512"

"""Multi-pod dry-run (deliverable e).

For every (architecture x input shape x mesh) cell: build the step function
with production shardings, ``.lower().compile()`` it, and record
memory_analysis / cost_analysis / collective schedule.  Compilation success
proves the distribution config is coherent; the recorded numbers feed the
roofline analysis (EXPERIMENTS.md).

The scan-body cost correction additionally compiles one layer group
standalone (see hlo_analysis).  Results are merged into a JSON cache so
the run is resumable cell by cell.

Usage:
  PYTHONPATH=src python -m repro.launch.dryrun \
      --arch all --shape all --mesh both --out benchmarks/results/dryrun.json
"""
import argparse   # noqa: E402
import json       # noqa: E402
import time       # noqa: E402
import traceback  # noqa: E402

import jax        # noqa: E402

from ..configs import SHAPES, applicable, get, names          # noqa: E402
from ..models.transformer import param_count                   # noqa: E402
from . import hlo_analysis as hlo                              # noqa: E402
from . import steps                                            # noqa: E402
from .mesh import make_production_mesh                         # noqa: E402

HBM_PER_CHIP = 16e9  # TPU v5e


def active_param_count(cfg) -> int:
    """Parameters touched per token (MoE: top-k of E experts)."""
    total = param_count(cfg)
    if cfg.moe_experts:
        from ..models import transformer
        specs = transformer.param_specs(cfg)
        import numpy as np
        moe_leaves = 0
        for path, leaf in jax.tree_util.tree_flatten_with_path(specs)[0]:
            ps = "/".join(str(getattr(k, "key", getattr(k, "idx", k)))
                          for k in path)
            if "ffn" in ps and leaf.ndim == 4:  # (R, E, d, f) expert mats
                moe_leaves += int(np.prod(leaf.shape))
        total -= moe_leaves
        total += int(moe_leaves * cfg.moe_top_k / cfg.moe_experts)
    return total


def analytic_memory(cfg, shape, mesh) -> dict:
    """Per-device HBM model from specs x shardings (exact for parameters /
    optimizer / caches / scan carries; working-set terms use the flash-tile
    memory behaviour of the production kernels).  This is the fit
    criterion: raw memory_analysis() on the CPU backend is inflated by
    bf16->f32 dot legalization (fp32 copies of every weight), an artifact
    absent on TPU — both numbers are reported (EXPERIMENTS.md §Dry-run)."""
    from ..models import transformer
    from ..parallel import sharding as shd
    from jax.sharding import PartitionSpec as P

    pspecs = transformer.param_specs(cfg)
    p_ps = shd.param_pspecs(cfg, mesh, pspecs)
    params_local = shd.local_bytes(mesh, pspecs, p_ps)
    out = {"params": params_local}

    daxes = shd.data_axes(mesh)
    bshards = min(shape.global_batch, shd.axis_size(mesh, daxes))
    tp = mesh.shape.get("model", 1)
    b_l = shape.global_batch / bshards
    d = cfg.d_model

    if shape.kind == "train":
        o_specs = steps.opt_specs(cfg)
        o_ps = shd.zero1_pspecs(mesh, o_specs,
                                {"m": p_ps, "v": p_ps, "step": P()})
        out["opt"] = shd.local_bytes(mesh, o_specs, o_ps)
        out["grads"] = params_local
        acc = max(cfg.grad_accum, 1)
        if acc > 1:
            out["grad_accum_fp32"] = 2 * params_local
        s_l = shape.seq_len / (tp if shape.seq_len % tp == 0 else 1)
        x_res = b_l / acc * s_l * d * 2
        out["saved_residuals"] = cfg.repeats * len(cfg.pattern) * x_res
        x_full = b_l * shape.seq_len * d * 2
        chunk = min(1024, shape.seq_len)
        h_l = max(cfg.n_heads / (1 if cfg.sharding_profile == "hybrid"
                                 else min(tp, cfg.n_heads)), 1)
        attn_tile = b_l * h_l * s_l * chunk * 4
        logits_chunk = (b_l * (shape.seq_len / max(cfg.loss_chunks, 1))
                        * cfg.vocab_size / (tp if cfg.vocab_size % tp == 0
                                            else 1) * 4)
        out["working_set"] = (4 * x_full + 3 * attn_tile
                              + 3 * logits_chunk) / acc
    else:
        c_specs = steps.cache_specs(cfg, shape)
        c_ps = shd.cache_pspecs(cfg, mesh, c_specs, shape.global_batch)
        out["cache"] = shd.local_bytes(mesh, c_specs, c_ps)
        if shape.kind == "prefill":
            x_full = b_l * shape.seq_len * d * 2
            out["working_set"] = 6 * x_full
        else:
            out["working_set"] = 16 * b_l * d * 4

    out["total"] = float(sum(out.values()))
    return out


def run_cell(arch: str, shape_name: str, mesh, mesh_name: str,
             components: bool = True, cfg=None) -> dict:
    """``cfg`` overrides the registry config (perf-iteration variants)."""
    entry = get(arch)
    if cfg is None:
        cfg = entry.config()
    shape = SHAPES[shape_name]
    rec = {"arch": arch, "shape": shape_name, "mesh": mesh_name,
           "kind": shape.kind, "n_devices": mesh.size,
           "params": param_count(cfg),
           "params_active": active_param_count(cfg),
           "repeats": cfg.repeats, "ok": False}
    if not applicable(entry.sub_quadratic, shape):
        rec["skipped"] = ("long_500k needs sub-quadratic attention; "
                          f"{arch} is full-attention (see DESIGN.md)")
        return rec
    t0 = time.time()
    with mesh:
        fn, arg_specs = steps.jit_cell(cfg, shape, mesh)
        lowered = fn.lower(*arg_specs)
        rec["t_lower_s"] = round(time.time() - t0, 2)
        t1 = time.time()
        compiled = lowered.compile()
        rec["t_compile_s"] = round(time.time() - t1, 2)
        rec["cost"] = hlo.cost_summary(compiled)
        rec["memory"] = hlo.memory_summary(compiled)
        rec["peak_hbm_raw"] = hlo.peak_hbm_bytes(rec["memory"])
        rec["memory_analytic"] = analytic_memory(cfg, shape, mesh)
        rec["peak_hbm_bytes"] = rec["memory_analytic"]["total"]
        rec["fits_hbm"] = rec["peak_hbm_bytes"] <= HBM_PER_CHIP
        text = compiled.as_text()
        rec["collectives"] = hlo.collective_bytes(text, mesh.size)

        if components and cfg.repeats > 1:
            mode = "train" if shape.kind == "train" else (
                "decode" if shape.kind == "decode" else "fwd")
            gfn, gargs = steps.jit_layer_group(cfg, shape, mesh, mode)
            gcompiled = gfn.lower(*gargs).compile()
            gcost = hlo.cost_summary(gcompiled)
            gcoll = hlo.collective_bytes(gcompiled.as_text(), mesh.size)
            rec["group_cost"] = gcost
            rec["group_collectives"] = gcoll
            rec["cost_corrected"] = hlo.corrected(rec["cost"], gcost,
                                                  cfg.repeats)
            rec["collectives_corrected"] = hlo.corrected(
                rec["collectives"], gcoll, cfg.repeats)
        else:
            rec["cost_corrected"] = dict(rec["cost"])
            rec["collectives_corrected"] = dict(rec["collectives"])
    rec["ok"] = True
    return rec


def main() -> None:
    ap = argparse.ArgumentParser()
    ap.add_argument("--arch", default="all")
    ap.add_argument("--shape", default="all")
    ap.add_argument("--mesh", default="both",
                    choices=["single", "multi", "both"])
    ap.add_argument("--out", default="benchmarks/results/dryrun.json")
    ap.add_argument("--no-components", action="store_true")
    ap.add_argument("--force", action="store_true",
                    help="recompute cells already in the cache")
    args = ap.parse_args()

    archs = names() if args.arch == "all" else args.arch.split(",")
    shapes = list(SHAPES) if args.shape == "all" else args.shape.split(",")
    meshes = {"single": [False], "multi": [True],
              "both": [False, True]}[args.mesh]

    os.makedirs(os.path.dirname(args.out) or ".", exist_ok=True)
    results = {}
    if os.path.exists(args.out):
        # always keep the cache; --force only recomputes *selected* cells
        with open(args.out) as f:
            results = json.load(f)

    n_fail = 0
    for multi in meshes:
        mesh_name = "pod2x16x16" if multi else "pod16x16"
        mesh = make_production_mesh(multi_pod=multi)
        for arch in archs:
            for shape in shapes:
                key = f"{arch}|{shape}|{mesh_name}"
                if key in results and results[key].get("ok") \
                        and not args.force:
                    print(f"[cached] {key}")
                    continue
                print(f"[dryrun] {key} ...", flush=True)
                try:
                    rec = run_cell(arch, shape, mesh, mesh_name,
                                   components=not args.no_components)
                except Exception as e:  # record the failure, keep going
                    rec = {"arch": arch, "shape": shape, "mesh": mesh_name,
                           "ok": False, "error": f"{type(e).__name__}: {e}",
                           "traceback": traceback.format_exc()[-2000:]}
                    n_fail += 1
                results[key] = rec
                with open(args.out, "w") as f:
                    json.dump(results, f, indent=1, sort_keys=True)
                status = ("SKIP" if rec.get("skipped")
                          else "ok" if rec.get("ok") else "FAIL")
                extra = ""
                if rec.get("ok") and not rec.get("skipped"):
                    hbm = rec["peak_hbm_bytes"] / 1e9
                    extra = (f" hbm={hbm:.2f}GB fits={rec['fits_hbm']}"
                             f" flops={rec['cost_corrected']['flops']:.3g}"
                             f" lower={rec['t_lower_s']}s"
                             f" compile={rec['t_compile_s']}s")
                print(f"[dryrun] {key}: {status}{extra}", flush=True)

    n_ok = sum(1 for r in results.values() if r.get("ok"))
    print(f"\ndone: {n_ok} ok / {len(results)} total, {n_fail} new failures")


if __name__ == "__main__":
    main()
