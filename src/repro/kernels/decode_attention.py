"""Flash-decode kernel (Pallas, TPU target): single-token attention over a
(possibly very long) KV cache.

Grid = (batch, kv_blocks): each step loads one KV block into VMEM, computes
partial (max, sum, acc) for *all* heads of that batch element (the
online-softmax merge), and flushes q's output at the last block.  GQA is
exploited natively: the score matmul is (G q-heads x D) @ (D x bk) per KV
head — q heads grouped by their kv head, so the cache is read once.

Invalid tail entries (cache_len <= idx) and sliding windows are masked via
the per-batch length vector (SMEM-style scalar input).
"""
from __future__ import annotations

import functools
from typing import Optional

import jax
import jax.numpy as jnp
from jax.experimental import pallas as pl
from jax.experimental.pallas import tpu as pltpu

from ..core.segments import SlicedOp, n_slices_for

NEG_INF = -1e30


def _block_update(len_ref, q_ref, k_ref, v_ref, m_scr, l_scr, acc_scr, *,
                  scale: float, window: Optional[int], cols_base,
                  block_k: int, n_heads: int, n_kv_heads: int):
    """One online-softmax KV-block merge on the VMEM carry — shared by the
    whole-grid kernel and the sliced (resumable) kernel."""
    g = n_heads // n_kv_heads
    q = q_ref[0].astype(jnp.float32)              # (H, D)
    k = k_ref[0].astype(jnp.float32)              # (bk, Hkv, D)
    v = v_ref[0].astype(jnp.float32)
    cache_len = len_ref[0]

    cols = cols_base + jax.lax.iota(jnp.int32, block_k)
    valid = cols < cache_len
    if window is not None:
        valid &= cols >= cache_len - window

    # scores per kv head group: (Hkv, G, D) x (Hkv, bk, D) -> (Hkv, G, bk)
    qg = q.reshape(n_kv_heads, g, -1)
    kg = jnp.transpose(k, (1, 0, 2))              # (Hkv, bk, D)
    s = jax.lax.dot_general(
        qg, kg, (((2,), (2,)), ((0,), (0,))),
        preferred_element_type=jnp.float32) * scale     # (Hkv, G, bk)
    s = jnp.where(valid[None, None, :], s, NEG_INF)
    s = s.reshape(n_heads, block_k)

    m_prev = m_scr[...]
    m_new = jnp.maximum(m_prev, jnp.max(s, axis=1))
    p = jnp.exp(s - m_new[:, None])               # (H, bk)
    corr = jnp.exp(m_prev - m_new)
    l_scr[...] = l_scr[...] * corr + jnp.sum(p, axis=1)
    vg = jnp.transpose(v, (1, 0, 2))              # (Hkv, bk, D)
    pv = jax.lax.dot_general(
        p.reshape(n_kv_heads, g, block_k), vg,
        (((2,), (1,)), ((0,), (0,))),
        preferred_element_type=jnp.float32)       # (Hkv, G, D)
    acc_scr[...] = acc_scr[...] * corr[:, None] \
        + pv.reshape(n_heads, -1)
    m_scr[...] = m_new


def _kernel(len_ref, q_ref, k_ref, v_ref, o_ref, m_scr, l_scr, acc_scr, *,
            scale: float, window: Optional[int], block_k: int, n_kv: int,
            n_heads: int, n_kv_heads: int):
    ki = pl.program_id(1)

    @pl.when(ki == 0)
    def _init():
        m_scr[...] = jnp.full_like(m_scr, NEG_INF)
        l_scr[...] = jnp.zeros_like(l_scr)
        acc_scr[...] = jnp.zeros_like(acc_scr)

    _block_update(len_ref, q_ref, k_ref, v_ref, m_scr, l_scr, acc_scr,
                  scale=scale, window=window, cols_base=ki * block_k,
                  block_k=block_k, n_heads=n_heads, n_kv_heads=n_kv_heads)

    @pl.when(ki == n_kv - 1)
    def _flush():
        denom = jnp.maximum(l_scr[...], 1e-30)
        o_ref[0] = (acc_scr[...] / denom[:, None]).astype(o_ref.dtype)


def _carry_kernel(len_ref, q_ref, k_ref, v_ref, m0_ref, l0_ref, acc0_ref,
                  m_ref, l_ref, acc_ref, m_scr, l_scr, acc_scr, *,
                  scale: float, window: Optional[int], kv_offset: int,
                  block_k: int, n_kv: int, n_heads: int, n_kv_heads: int):
    """Resumable slice over ``n_kv`` cache blocks starting at absolute
    position ``kv_offset``; the (m, l, acc) merge state is an explicit
    carry instead of being normalized away at the last block."""
    ki = pl.program_id(1)

    @pl.when(ki == 0)
    def _init():
        m_scr[...] = m0_ref[0]
        l_scr[...] = l0_ref[0]
        acc_scr[...] = acc0_ref[0]

    _block_update(len_ref, q_ref, k_ref, v_ref, m_scr, l_scr, acc_scr,
                  scale=scale, window=window,
                  cols_base=kv_offset + ki * block_k,
                  block_k=block_k, n_heads=n_heads, n_kv_heads=n_kv_heads)

    @pl.when(ki == n_kv - 1)
    def _flush():
        m_ref[0] = m_scr[...]
        l_ref[0] = l_scr[...]
        acc_ref[0] = acc_scr[...]


def flash_decode(q, k_cache, v_cache, cache_len, *,
                 window: Optional[int] = None, block_k: int = 512,
                 interpret: bool = False):
    """q: (B, H, D); caches: (B, Smax, Hkv, D); cache_len: (B,) or scalar.
    Returns (B, H, D)."""
    b, h, d = q.shape
    smax, hkv = k_cache.shape[1], k_cache.shape[2]
    block_k = min(block_k, smax)
    assert smax % block_k == 0, (smax, block_k)
    n_kv = smax // block_k
    lens = jnp.broadcast_to(jnp.asarray(cache_len, jnp.int32), (b,))
    kernel = functools.partial(
        _kernel, scale=d ** -0.5, window=window, block_k=block_k,
        n_kv=n_kv, n_heads=h, n_kv_heads=hkv)
    return pl.pallas_call(
        kernel,
        grid=(b, n_kv),
        in_specs=[
            pl.BlockSpec((1,), lambda b_, k_: (b_,)),
            pl.BlockSpec((1, h, d), lambda b_, k_: (b_, 0, 0)),
            pl.BlockSpec((1, block_k, hkv, d), lambda b_, k_:
                         (b_, k_, 0, 0)),
            pl.BlockSpec((1, block_k, hkv, d), lambda b_, k_:
                         (b_, k_, 0, 0)),
        ],
        out_specs=pl.BlockSpec((1, h, d), lambda b_, k_: (b_, 0, 0)),
        out_shape=jax.ShapeDtypeStruct((b, h, d), q.dtype),
        scratch_shapes=[
            pltpu.VMEM((h,), jnp.float32),
            pltpu.VMEM((h,), jnp.float32),
            pltpu.VMEM((h, d), jnp.float32),
        ],
        interpret=interpret,
    )(lens, q, k_cache, v_cache)


def flash_decode_sliced(q, k_cache, v_cache, cache_len, *,
                        window: Optional[int] = None, block_k: int = 512,
                        kv_slice: int = 1,
                        interpret: bool = False) -> SlicedOp:
    """Sliced, resumable flash decode: each slice merges ``kv_slice``
    cache blocks into the explicit (m, l, acc) carry — fp32 (B,H) /
    (B,H) / (B,H,D) — visiting blocks in the same order as
    :func:`flash_decode`, so the result is value-identical (pinned in
    tests/test_sliced_kernels.py)."""
    b, h, d = q.shape
    smax, hkv = k_cache.shape[1], k_cache.shape[2]
    block_k = min(block_k, smax)
    assert smax % block_k == 0, (smax, block_k)
    n_kv = smax // block_k
    n_slices = n_slices_for(n_kv, kv_slice)
    lens = jnp.broadcast_to(jnp.asarray(cache_len, jnp.int32), (b,))
    scale = d ** -0.5

    def init():
        return (jnp.full((b, h), NEG_INF, jnp.float32),
                jnp.zeros((b, h), jnp.float32),
                jnp.zeros((b, h, d), jnp.float32))

    def step(carry, i):
        m0, l0, acc0 = carry
        k0 = i * kv_slice
        nk = min(kv_slice, n_kv - k0)
        ks = k_cache[:, k0 * block_k:(k0 + nk) * block_k]
        vs = v_cache[:, k0 * block_k:(k0 + nk) * block_k]
        kernel = functools.partial(
            _carry_kernel, scale=scale, window=window,
            kv_offset=k0 * block_k, block_k=block_k, n_kv=nk,
            n_heads=h, n_kv_heads=hkv)
        carry_spec_1d = pl.BlockSpec((1, h), lambda b_, k_: (b_, 0))
        carry_spec_2d = pl.BlockSpec((1, h, d), lambda b_, k_: (b_, 0, 0))
        return pl.pallas_call(
            kernel,
            grid=(b, nk),
            in_specs=[
                pl.BlockSpec((1,), lambda b_, k_: (b_,)),
                pl.BlockSpec((1, h, d), lambda b_, k_: (b_, 0, 0)),
                pl.BlockSpec((1, block_k, hkv, d), lambda b_, k_:
                             (b_, k_, 0, 0)),
                pl.BlockSpec((1, block_k, hkv, d), lambda b_, k_:
                             (b_, k_, 0, 0)),
                carry_spec_1d, carry_spec_1d, carry_spec_2d,
            ],
            out_specs=[carry_spec_1d, carry_spec_1d, carry_spec_2d],
            out_shape=[
                jax.ShapeDtypeStruct((b, h), jnp.float32),
                jax.ShapeDtypeStruct((b, h), jnp.float32),
                jax.ShapeDtypeStruct((b, h, d), jnp.float32),
            ],
            scratch_shapes=[
                pltpu.VMEM((h,), jnp.float32),
                pltpu.VMEM((h,), jnp.float32),
                pltpu.VMEM((h, d), jnp.float32),
            ],
            interpret=interpret,
        )(lens, q, ks, vs, m0, l0, acc0)

    def finalize(carry):
        _, lsum, acc = carry
        denom = jnp.maximum(lsum, 1e-30)
        return (acc / denom[..., None]).astype(q.dtype)

    return SlicedOp(n_slices, init, step, finalize, label="flash_decode")
