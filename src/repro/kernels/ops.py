"""Public jit-ready wrappers for the kernel layer.

Every perf-critical op in the model stack goes through this module, which
dispatches between the Pallas TPU kernels (``use_pallas=True``, the target
runtime) and the pure-jnp references in ``ref.py`` (CPU dry-run, smoke
tests, and the oracle for kernel validation).

The global default is platform-derived: Pallas on TPU, reference elsewhere.
``set_use_pallas`` overrides it (tests use interpret-mode Pallas on CPU);
the ``REPRO_PALLAS`` environment variable provides the same override for
subprocesses (``interpret`` → interpret-mode Pallas, as in CI's
runtime-smoke job; ``on``/``off`` → force the dispatch).

The ``*_sliced`` entry points return a :class:`repro.core.segments.
SlicedOp` — the op split into K-grid-step dispatches with an explicit
carry — so the real-time executor can preempt between slices (bounded
preemption delay, DESIGN.md §6) and checkpoint mid-op.
"""
from __future__ import annotations

import os
from typing import Optional

import jax

from ..core.segments import SlicedOp
from . import ref

_USE_PALLAS: Optional[bool] = None  # None -> auto (TPU only)
_INTERPRET = False                  # run Pallas kernels in interpret mode

_ENV = os.environ.get("REPRO_PALLAS", "").lower()
if _ENV == "interpret":             # CI runtime-smoke: exercise the Pallas
    _USE_PALLAS, _INTERPRET = True, True   # path on CPU runners
elif _ENV in ("on", "1", "true"):
    _USE_PALLAS = True
elif _ENV in ("off", "0", "false"):
    _USE_PALLAS = False


def set_use_pallas(value: Optional[bool], interpret: bool = False) -> None:
    global _USE_PALLAS, _INTERPRET
    _USE_PALLAS = value
    _INTERPRET = interpret


def use_pallas() -> bool:
    if _USE_PALLAS is not None:
        return _USE_PALLAS
    return jax.default_backend() == "tpu"


def interpret_mode() -> bool:
    return _INTERPRET


def _sliced_interpret() -> bool:
    """Sliced execution always goes through the Pallas kernels (the carry
    contract is kernel-level); off-TPU they run in interpret mode."""
    if use_pallas():
        return _INTERPRET
    return jax.default_backend() != "tpu"


# --------------------------------------------------------------------------
# attention
# --------------------------------------------------------------------------

def attention(q, k, v, *, causal: bool = True, window: Optional[int] = None,
              q_offset: int = 0, chunk: int = 2048):
    """Multi-head (GQA) attention: q (B,Sq,H,D), k/v (B,Sk,Hkv,D)."""
    if use_pallas():
        from .flash_attention import flash_attention
        return flash_attention(q, k, v, causal=causal, window=window,
                               q_offset=q_offset, interpret=_INTERPRET)
    return ref.attention(q, k, v, causal=causal, window=window,
                         q_offset=q_offset, chunk=chunk)


def decode_attention(q, k_cache, v_cache, cache_len, *,
                     window: Optional[int] = None):
    """Single-token attention over a KV cache: q (B,H,D)."""
    if use_pallas():
        from .decode_attention import flash_decode
        return flash_decode(q, k_cache, v_cache, cache_len, window=window,
                            interpret=_INTERPRET)
    return ref.decode_attention(q, k_cache, v_cache, cache_len,
                                window=window)


# --------------------------------------------------------------------------
# recurrences
# --------------------------------------------------------------------------

def mamba_scan(x, dt, A, B, C, D, h0=None):
    if use_pallas():
        from .mamba_scan import mamba_scan_pallas
        return mamba_scan_pallas(x, dt, A, B, C, D, h0=h0,
                                 interpret=_INTERPRET)
    return ref.mamba_scan(x, dt, A, B, C, D, h0=h0)


def rwkv6_scan(r, k, v, w, u, s0=None):
    if use_pallas():
        from .rwkv6 import rwkv6_scan_pallas
        return rwkv6_scan_pallas(r, k, v, w, u, s0=s0, interpret=_INTERPRET)
    return ref.rwkv6_scan(r, k, v, w, u, s0=s0)


def mamba_decode_step(x, dt, A, B, C, D, h):
    return ref.mamba_decode_step(x, dt, A, B, C, D, h)


def rwkv6_decode_step(r, k, v, w, u, state):
    return ref.rwkv6_decode_step(r, k, v, w, u, state)


# --------------------------------------------------------------------------
# sliced, resumable entry points (bounded preemption delay — DESIGN.md §6)
# --------------------------------------------------------------------------

def attention_sliced(q, k, v, *, causal: bool = True,
                     window: Optional[int] = None, q_offset: int = 0,
                     block_q: int = 128, block_k: int = 128,
                     kv_slice: int = 1) -> SlicedOp:
    """Flash attention as a SlicedOp: ``kv_slice`` kv-block grid steps per
    dispatch, explicit (m, l, acc) carry between dispatches.  Value-
    identical to :func:`attention` on the Pallas path."""
    from .flash_attention import flash_attention_sliced
    return flash_attention_sliced(
        q, k, v, causal=causal, window=window, q_offset=q_offset,
        block_q=block_q, block_k=block_k, kv_slice=kv_slice,
        interpret=_sliced_interpret())


def decode_attention_sliced(q, k_cache, v_cache, cache_len, *,
                            window: Optional[int] = None,
                            block_k: int = 512,
                            kv_slice: int = 1) -> SlicedOp:
    """Flash decode as a SlicedOp over cache blocks (carry: m, l, acc)."""
    from .decode_attention import flash_decode_sliced
    return flash_decode_sliced(
        q, k_cache, v_cache, cache_len, window=window, block_k=block_k,
        kv_slice=kv_slice, interpret=_sliced_interpret())


def mamba_scan_sliced(x, dt, A, B, C, D, h0=None, *, chunk: int = 32,
                      block_d: int = 512,
                      slice_chunks: int = 1) -> SlicedOp:
    """Selective scan as a SlicedOp over time windows (carry: recurrent h
    + output buffer).  Each window dispatches through the normal
    pallas/reference dispatch, so this works on both paths."""
    from .mamba_scan import mamba_scan_sliced as _sliced
    if use_pallas():
        return _sliced(x, dt, A, B, C, D, h0=h0, chunk=chunk,
                       block_d=block_d, slice_chunks=slice_chunks,
                       interpret=_INTERPRET)
    return _sliced(x, dt, A, B, C, D, h0=h0, chunk=chunk, block_d=block_d,
                   slice_chunks=slice_chunks,
                   scan_fn=lambda xw, dtw, A_, Bw, Cw, D_, h:
                   ref.mamba_scan(xw, dtw, A_, Bw, Cw, D_, h0=h))


def rwkv6_scan_sliced(r, k, v, w, u, s0=None, *, chunk: int = 32,
                      slice_chunks: int = 1) -> SlicedOp:
    """WKV recurrence as a SlicedOp over time windows (carry: (B,H,D,D)
    state + output buffer); pallas/reference dispatch per window."""
    from .rwkv6 import rwkv6_scan_sliced as _sliced
    if use_pallas():
        return _sliced(r, k, v, w, u, s0=s0, chunk=chunk,
                       slice_chunks=slice_chunks, interpret=_INTERPRET)
    return _sliced(r, k, v, w, u, s0=s0, chunk=chunk,
                   slice_chunks=slice_chunks,
                   scan_fn=lambda rw, kw, vw, ww, u_, st:
                   ref.rwkv6_scan(rw, kw, vw, ww, u_, s0=st))
