"""Public jit-ready wrappers for the kernel layer.

Every perf-critical op in the model stack goes through this module, which
dispatches between the Pallas TPU kernels (``use_pallas=True``, the target
runtime) and the pure-jnp references in ``ref.py`` (CPU dry-run, smoke
tests, and the oracle for kernel validation).

The global default is platform-derived: Pallas on TPU, reference elsewhere.
``set_use_pallas`` overrides it (tests use interpret-mode Pallas on CPU).
"""
from __future__ import annotations

from typing import Optional

import jax

from . import ref

_USE_PALLAS: Optional[bool] = None  # None -> auto (TPU only)
_INTERPRET = False                  # run Pallas kernels in interpret mode


def set_use_pallas(value: Optional[bool], interpret: bool = False) -> None:
    global _USE_PALLAS, _INTERPRET
    _USE_PALLAS = value
    _INTERPRET = interpret


def use_pallas() -> bool:
    if _USE_PALLAS is not None:
        return _USE_PALLAS
    return jax.default_backend() == "tpu"


def interpret_mode() -> bool:
    return _INTERPRET


# --------------------------------------------------------------------------
# attention
# --------------------------------------------------------------------------

def attention(q, k, v, *, causal: bool = True, window: Optional[int] = None,
              q_offset: int = 0, chunk: int = 2048):
    """Multi-head (GQA) attention: q (B,Sq,H,D), k/v (B,Sk,Hkv,D)."""
    if use_pallas():
        from .flash_attention import flash_attention
        return flash_attention(q, k, v, causal=causal, window=window,
                               q_offset=q_offset, interpret=_INTERPRET)
    return ref.attention(q, k, v, causal=causal, window=window,
                         q_offset=q_offset, chunk=chunk)


def decode_attention(q, k_cache, v_cache, cache_len, *,
                     window: Optional[int] = None):
    """Single-token attention over a KV cache: q (B,H,D)."""
    if use_pallas():
        from .decode_attention import flash_decode
        return flash_decode(q, k_cache, v_cache, cache_len, window=window,
                            interpret=_INTERPRET)
    return ref.decode_attention(q, k_cache, v_cache, cache_len,
                                window=window)


# --------------------------------------------------------------------------
# recurrences
# --------------------------------------------------------------------------

def mamba_scan(x, dt, A, B, C, D, h0=None):
    if use_pallas():
        from .mamba_scan import mamba_scan_pallas
        return mamba_scan_pallas(x, dt, A, B, C, D, h0=h0,
                                 interpret=_INTERPRET)
    return ref.mamba_scan(x, dt, A, B, C, D, h0=h0)


def rwkv6_scan(r, k, v, w, u, s0=None):
    if use_pallas():
        from .rwkv6 import rwkv6_scan_pallas
        return rwkv6_scan_pallas(r, k, v, w, u, s0=s0, interpret=_INTERPRET)
    return ref.rwkv6_scan(r, k, v, w, u, s0=s0)


def mamba_decode_step(x, dt, A, B, C, D, h):
    return ref.mamba_decode_step(x, dt, A, B, C, D, h)


def rwkv6_decode_step(r, k, v, w, u, state):
    return ref.rwkv6_decode_step(r, k, v, w, u, state)
