"""Pure-jnp reference oracles for every kernel in this package.

These are the ground truth the Pallas kernels are validated against
(interpret=True on CPU), and the path used by the dry-run (CPU backend,
cost_analysis sees real FLOPs) and by smoke tests.

Shapes follow the q/k/v convention (batch, seq, heads, head_dim); GQA is
expressed by n_kv_heads <= n_heads with n_heads % n_kv_heads == 0.
"""
from __future__ import annotations

from typing import Optional

import jax
import jax.numpy as jnp

NEG_INF = -1e30


def repeat_kv(k: jax.Array, n_rep: int) -> jax.Array:
    """(B, S, Hkv, D) -> (B, S, Hkv*n_rep, D)"""
    if n_rep == 1:
        return k
    b, s, h, d = k.shape
    return jnp.broadcast_to(k[:, :, :, None, :], (b, s, h, n_rep, d)) \
              .reshape(b, s, h * n_rep, d)


def attention(q: jax.Array, k: jax.Array, v: jax.Array, *,
              causal: bool = True, window: Optional[int] = None,
              q_offset: int = 0, chunk: int = 2048) -> jax.Array:
    """Multi-head attention; dispatches to the dense oracle for short keys
    and to the flash-pattern chunked implementation (online softmax over
    KV blocks, memory O(Sq x chunk)) for long ones — mirroring the Pallas
    kernel's memory behaviour so dry-run memory_analysis is meaningful."""
    sk = k.shape[1]
    if sk > chunk and sk % chunk == 0:
        return attention_chunked(q, k, v, causal=causal, window=window,
                                 q_offset=q_offset, chunk=chunk)
    return attention_dense(q, k, v, causal=causal, window=window,
                           q_offset=q_offset)


def attention_dense(q: jax.Array, k: jax.Array, v: jax.Array, *,
                    causal: bool = True, window: Optional[int] = None,
                    q_offset: int = 0) -> jax.Array:
    """Dense reference (the oracle for kernel validation).

    q: (B, Sq, H, D); k, v: (B, Sk, Hkv, D).  Softmax in fp32.
    ``window``: sliding-window attention — key j is visible from query i iff
    i - window < j <= i (with i indexed at q_offset for decode).
    """
    b, sq, h, d = q.shape
    hkv = k.shape[2]
    k = repeat_kv(k, h // hkv)
    v = repeat_kv(v, h // hkv)
    scale = d ** -0.5
    logits = jnp.einsum("bqhd,bkhd->bhqk", q, k,
                        preferred_element_type=jnp.float32) * scale
    sk = k.shape[1]
    qi = jnp.arange(sq)[:, None] + q_offset
    kj = jnp.arange(sk)[None, :]
    mask = jnp.ones((sq, sk), dtype=bool)
    if causal:
        mask &= kj <= qi
    if window is not None:
        mask &= kj > qi - window
    logits = jnp.where(mask[None, None], logits, NEG_INF)
    probs = jax.nn.softmax(logits, axis=-1)
    out = jnp.einsum("bhqk,bkhd->bqhd", probs.astype(v.dtype), v)
    return out.astype(q.dtype)


def attention_chunked(q: jax.Array, k: jax.Array, v: jax.Array, *,
                      causal: bool = True, window: Optional[int] = None,
                      q_offset: int = 0, chunk: int = 2048) -> jax.Array:
    """Flash-pattern attention: lax.scan over KV chunks with running
    (max, sum, acc) — numerically identical to the dense path.

    NOTE for the roofline: XLA's cost_analysis counts the chunk scan body
    once; benchmarks/roofline.py adds the analytic (n_chunks-1) correction
    for attention FLOPs (closed form, documented in EXPERIMENTS.md)."""
    b, sq, h, d = q.shape
    sk, hkv = k.shape[1], k.shape[2]
    n_rep = h // hkv
    k = repeat_kv(k, n_rep)
    v = repeat_kv(v, n_rep)
    scale = d ** -0.5
    n_chunks = sk // chunk
    kc = jnp.moveaxis(k.reshape(b, n_chunks, chunk, h, d), 1, 0)
    vc = jnp.moveaxis(v.reshape(b, n_chunks, chunk, h, d), 1, 0)
    qi = jnp.arange(sq)[:, None] + q_offset

    def body(carry, xs):
        m, lsum, acc = carry
        idx, kci, vci = xs
        s = jnp.einsum("bqhd,bkhd->bhqk", q, kci,
                       preferred_element_type=jnp.float32) * scale
        kj = idx * chunk + jnp.arange(chunk)[None, :]
        mask = jnp.ones((sq, chunk), dtype=bool)
        if causal:
            mask &= kj <= qi
        if window is not None:
            mask &= kj > qi - window
        s = jnp.where(mask[None, None], s, NEG_INF)
        m_new = jnp.maximum(m, jnp.max(s, axis=-1))
        p = jnp.exp(s - m_new[..., None])
        corr = jnp.exp(m - m_new)
        lsum = lsum * corr + jnp.sum(p, axis=-1)
        acc = acc * corr[..., None] + jnp.einsum(
            "bhqk,bkhd->bhqd", p.astype(vci.dtype), vci,
            preferred_element_type=jnp.float32)
        return (m_new, lsum, acc), None

    m0 = jnp.full((b, h, sq), NEG_INF, jnp.float32)
    l0 = jnp.zeros((b, h, sq), jnp.float32)
    a0 = jnp.zeros((b, h, sq, d), jnp.float32)
    # remat the chunk body: the backward recomputes scores from (q, k)
    # instead of stacking per-chunk probabilities (flash-backward memory)
    body = jax.checkpoint(body,
                          policy=jax.checkpoint_policies.nothing_saveable)
    (m, lsum, acc), _ = jax.lax.scan(
        body, (m0, l0, a0), (jnp.arange(n_chunks), kc, vc))
    out = acc / jnp.maximum(lsum, 1e-30)[..., None]
    return jnp.moveaxis(out, 1, 2).astype(q.dtype)


def decode_attention(q: jax.Array, k_cache: jax.Array, v_cache: jax.Array,
                     cache_len: jax.Array | int, *,
                     window: Optional[int] = None) -> jax.Array:
    """Single-step attention over a KV cache.

    q: (B, H, D) new-token queries; caches: (B, Smax, Hkv, D);
    cache_len: number of valid entries (the new token is already written).
    """
    b, h, d = q.shape
    hkv = k_cache.shape[2]
    g = h // hkv
    # grouped-query formulation: q heads grouped by their KV head, so the
    # cache is contracted directly — no materialized KV repeat (3x less
    # cache-side read traffic for GQA decode, see EXPERIMENTS.md §Perf)
    qg = q.reshape(b, hkv, g, d)
    scale = d ** -0.5
    from ..parallel.hints import shard_hint
    logits = shard_hint(
        jnp.einsum("bhgd,bshd->bhgs", qg, k_cache,
                   preferred_element_type=jnp.float32),
        "decode_scores") * scale
    smax = k_cache.shape[1]
    kj = jnp.arange(smax)[None, :]
    valid = kj < jnp.asarray(cache_len).reshape(-1, 1)
    if window is not None:
        valid &= kj >= jnp.asarray(cache_len).reshape(-1, 1) - window
    logits = jnp.where(valid[:, None, None, :], logits, NEG_INF)
    probs = jax.nn.softmax(logits, axis=-1)
    out = jnp.einsum("bhgs,bshd->bhgd", probs.astype(v_cache.dtype),
                     v_cache)
    return out.reshape(b, h, d).astype(q.dtype)


def mamba_scan(x: jax.Array, dt: jax.Array, A: jax.Array, B: jax.Array,
               C: jax.Array, D: jax.Array,
               h0: Optional[jax.Array] = None):
    """Selective state-space (S6) scan.

    x, dt: (Bt, S, Di); A: (Di, N); B, C: (Bt, S, N); D: (Di,)
    Returns (y (Bt,S,Di), h_final (Bt,Di,N)).
    Discretization: h_t = exp(dt_t * A) h_{t-1} + dt_t * B_t * x_t.
    """
    bt, s, di = x.shape
    n = A.shape[1]
    dA = jnp.exp(dt[..., None] * A[None, None])            # (Bt,S,Di,N)
    dBx = (dt * x)[..., None] * B[:, :, None, :]           # (Bt,S,Di,N)
    if h0 is None:
        h0 = jnp.zeros((bt, di, n), dtype=jnp.float32)

    def step(h, inp):
        da, dbx, c = inp
        h = da * h + dbx
        y = jnp.einsum("bdn,bn->bd", h, c)
        return h, y

    xs = (jnp.moveaxis(dA, 1, 0).astype(jnp.float32),
          jnp.moveaxis(dBx, 1, 0).astype(jnp.float32),
          jnp.moveaxis(C, 1, 0).astype(jnp.float32))
    h_final, ys = jax.lax.scan(step, h0, xs)
    y = jnp.moveaxis(ys, 0, 1) + x.astype(jnp.float32) * D[None, None]
    return y.astype(x.dtype), h_final


def rwkv6_scan(r: jax.Array, k: jax.Array, v: jax.Array, w: jax.Array,
               u: jax.Array, s0: Optional[jax.Array] = None):
    """RWKV6 (Finch) WKV recurrence with data-dependent per-channel decay.

    r, k, w: (B, S, H, D); v: (B, S, H, D); u: (H, D)
    state S: (B, H, D, D) with S_t = diag(w_t) S_{t-1} + k_t^T v_t
    out_t  = r_t @ (S_{t-1} + diag(u) k_t^T v_t)
    Returns (out (B,S,H,D), final state).
    """
    b, s, h, d = r.shape
    if s0 is None:
        s0 = jnp.zeros((b, h, d, d), dtype=jnp.float32)

    def step(S, inp):
        rt, kt, vt, wt = inp
        kv = kt[..., :, None] * vt[..., None, :]          # (B,H,D,D)
        out = jnp.einsum("bhd,bhde->bhe", rt,
                         S + u[None, :, :, None] * kv)
        S = wt[..., None] * S + kv
        return S, out

    xs = tuple(jnp.moveaxis(t, 1, 0).astype(jnp.float32)
               for t in (r, k, v, w))
    s_final, outs = jax.lax.scan(step, s0, xs)
    return jnp.moveaxis(outs, 0, 1).astype(r.dtype), s_final


def rwkv6_decode_step(r, k, v, w, u, state):
    """One-token RWKV6 update.  r,k,v,w: (B,H,D); state: (B,H,D,D)."""
    kv = k[..., :, None] * v[..., None, :]
    out = jnp.einsum("bhd,bhde->bhe", r.astype(jnp.float32),
                     state + u[None, :, :, None] * kv.astype(jnp.float32))
    new_state = w[..., None].astype(jnp.float32) * state \
        + kv.astype(jnp.float32)
    return out.astype(r.dtype), new_state


def mamba_decode_step(x, dt, A, B, C, D, h):
    """One-token S6 update.  x, dt: (Bt, Di); B, C: (Bt, N); h: (Bt,Di,N)."""
    dA = jnp.exp(dt[..., None] * A[None])                  # (Bt,Di,N)
    dBx = (dt * x)[..., None] * B[:, None, :]
    h = dA.astype(jnp.float32) * h + dBx.astype(jnp.float32)
    y = jnp.einsum("bdn,bn->bd", h, C.astype(jnp.float32))
    y = y + x.astype(jnp.float32) * D[None]
    return y.astype(x.dtype), h
