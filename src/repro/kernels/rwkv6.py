"""RWKV6 (Finch) WKV recurrence kernel (Pallas, TPU target).

The recurrence S_t = diag(w_t) S_{t-1} + k_t v_t^T is memory-bound on the
(D x D) per-head state; the kernel keeps the state resident in VMEM across
the whole sequence (grid = (batch, heads, time_chunks), time innermost)
instead of round-tripping it to HBM every token — the chunked-recurrence
adaptation of RWKV's CUDA kernel to the TPU memory hierarchy.

Within a chunk the timestep loop is a fori_loop over VMEM-resident r/k/v/w
tiles (chunk x D); the final state is a second kernel output flushed at the
last chunk (so prefill gets the decode state for free).
"""
from __future__ import annotations

import functools
from typing import Optional

import jax
import jax.numpy as jnp
from jax.experimental import pallas as pl
from jax.experimental.pallas import tpu as pltpu

from ..core.segments import SlicedOp, n_slices_for


def _kernel(r_ref, k_ref, v_ref, w_ref, u_ref, s0_ref, o_ref, sf_ref,
            s_scr, *, chunk: int, n_chunks: int):
    ci = pl.program_id(2)

    @pl.when(ci == 0)
    def _init():
        s_scr[...] = s0_ref[0, 0].astype(jnp.float32)

    r = r_ref[0, :, 0].astype(jnp.float32)        # (chunk, D)
    k = k_ref[0, :, 0].astype(jnp.float32)
    v = v_ref[0, :, 0].astype(jnp.float32)
    w = w_ref[0, :, 0].astype(jnp.float32)
    u = u_ref[0].astype(jnp.float32)              # (D,)

    def step(t, carry):
        s, out = carry
        kv = k[t][:, None] * v[t][None, :]        # (D, D)
        o_t = jnp.sum((s + u[:, None] * kv) * r[t][:, None], axis=0)
        s = w[t][:, None] * s + kv
        out = out.at[t].set(o_t)
        return s, out

    out0 = jnp.zeros((chunk, r.shape[1]), jnp.float32)
    s, out = jax.lax.fori_loop(0, chunk, step, (s_scr[...], out0))
    s_scr[...] = s
    o_ref[0, :, 0] = out.astype(o_ref.dtype)

    @pl.when(ci == n_chunks - 1)
    def _flush():
        sf_ref[0, 0] = s_scr[...]


def rwkv6_scan_pallas(r, k, v, w, u, s0: Optional[jax.Array] = None,
                      chunk: int = 32, interpret: bool = False):
    """r,k,v,w: (B, S, H, D); u: (H, D); s0: (B, H, D, D) fp32 or None.
    Returns (out (B,S,H,D), final state (B,H,D,D) fp32)."""
    b, s, h, d = r.shape
    chunk = min(chunk, s)
    assert s % chunk == 0
    n_chunks = s // chunk
    if s0 is None:
        s0 = jnp.zeros((b, h, d, d), jnp.float32)
    kernel = functools.partial(_kernel, chunk=chunk, n_chunks=n_chunks)
    tchunk = lambda b_, h_, c_: (b_, c_, h_, 0)
    out, s_final = pl.pallas_call(
        kernel,
        grid=(b, h, n_chunks),
        in_specs=[
            pl.BlockSpec((1, chunk, 1, d), tchunk),
            pl.BlockSpec((1, chunk, 1, d), tchunk),
            pl.BlockSpec((1, chunk, 1, d), tchunk),
            pl.BlockSpec((1, chunk, 1, d), tchunk),
            pl.BlockSpec((1, d), lambda b_, h_, c_: (h_, 0)),
            pl.BlockSpec((1, 1, d, d), lambda b_, h_, c_: (b_, h_, 0, 0)),
        ],
        out_specs=[
            pl.BlockSpec((1, chunk, 1, d), tchunk),
            pl.BlockSpec((1, 1, d, d), lambda b_, h_, c_: (b_, h_, 0, 0)),
        ],
        out_shape=[
            jax.ShapeDtypeStruct((b, s, h, d), r.dtype),
            jax.ShapeDtypeStruct((b, h, d, d), jnp.float32),
        ],
        scratch_shapes=[pltpu.VMEM((d, d), jnp.float32)],
        interpret=interpret,
    )(r, k, v, w, u, s0)
    return out, s_final


def rwkv6_scan_sliced(r, k, v, w, u, s0: Optional[jax.Array] = None,
                      chunk: int = 32, slice_chunks: int = 1,
                      interpret: bool = False, scan_fn=None) -> SlicedOp:
    """Sliced, resumable WKV recurrence: each slice dispatches
    ``slice_chunks`` time-chunk grid steps of :func:`rwkv6_scan_pallas`
    on its window, threading the (B,H,D,D) recurrent state — already a
    kernel-level (s0 in, s_final out) pair — through the carry with the
    output buffer.  Value-identical to the whole-sequence kernel.

    ``scan_fn`` overrides the per-window scan (ops.py passes the
    pallas/reference dispatcher)."""
    b, s, h, d = r.shape
    chunk = min(chunk, s)
    assert s % chunk == 0, (s, chunk)
    n_chunks = s // chunk
    n_slices = n_slices_for(n_chunks, slice_chunks)
    if scan_fn is None:
        def scan_fn(rw, kw, vw, ww, u_, st):
            return rwkv6_scan_pallas(rw, kw, vw, ww, u_, s0=st,
                                     chunk=chunk, interpret=interpret)

    def init():
        st = s0 if s0 is not None else jnp.zeros((b, h, d, d), jnp.float32)
        return (st, jnp.zeros((b, s, h, d), r.dtype))

    def step(carry, i):
        st, out = carry
        t0 = i * slice_chunks * chunk
        t1 = min(t0 + slice_chunks * chunk, s)
        ow, st = scan_fn(r[:, t0:t1], k[:, t0:t1], v[:, t0:t1],
                         w[:, t0:t1], u, st)
        out = jax.lax.dynamic_update_slice(out, ow.astype(out.dtype),
                                           (0, t0, 0, 0))
        return (st, out)

    def finalize(carry):
        st, out = carry
        return out, st

    return SlicedOp(n_slices, init, step, finalize, label="rwkv6_scan")
