"""Flash attention forward kernel (Pallas, TPU target).

TPU-native adaptation of the flash algorithm (DESIGN.md §2): the grid is
(batch, heads, q_blocks, kv_blocks) with the kv dimension innermost — TPU
executes the grid sequentially, so the VMEM scratch accumulators (running
max / sum / output) persist across the kv blocks of one q block and the
output tile is flushed exactly once, at the last kv block.  Block shapes
are MXU-aligned (q/kv blocks multiples of 128 when the sequence allows,
head_dim 64/128 as published).

Causal + sliding-window masking is applied inside the kernel; fully-masked
kv blocks still iterate (masked to -inf) — Pallas TPU requires a static
grid; the §Perf log measures the win from skipping them via block-triangle
grids on the hillclimbed cells.

Backward uses the XLA reference path via jax.custom_vjp (recompute-based,
matching the chunked reference); a Pallas backward kernel is a recorded
future optimization.

Validated against ref.attention_dense in interpret mode (tests/test_kernels).
"""
from __future__ import annotations

import functools
from typing import Optional

import jax
import jax.numpy as jnp
from jax.experimental import pallas as pl
from jax.experimental.pallas import tpu as pltpu

from ..core.segments import SlicedOp, n_slices_for
from . import ref

NEG_INF = -1e30


def _block_update(q_ref, k_ref, v_ref, m_scr, l_scr, acc_scr, *,
                  scale: float, causal: bool, window: Optional[int],
                  rows_base, cols_base, block_q: int, block_k: int):
    """One online-softmax block update on the VMEM carry scratch — the
    single definition shared by the whole-grid kernel and the sliced
    (resumable) kernel, so the two execute bit-identical math."""
    q = q_ref[0, 0].astype(jnp.float32)           # (bq, d)
    k = k_ref[0, 0].astype(jnp.float32)           # (bk, d)
    v = v_ref[0, 0].astype(jnp.float32)
    s = jnp.dot(q, k.T, preferred_element_type=jnp.float32) * scale

    rows = rows_base + jax.lax.broadcasted_iota(
        jnp.int32, (block_q, block_k), 0)
    cols = cols_base + jax.lax.broadcasted_iota(
        jnp.int32, (block_q, block_k), 1)
    mask = jnp.ones((block_q, block_k), jnp.bool_)
    if causal:
        mask &= cols <= rows
    if window is not None:
        mask &= cols > rows - window
    s = jnp.where(mask, s, NEG_INF)

    m_prev = m_scr[...]
    m_new = jnp.maximum(m_prev, jnp.max(s, axis=1))
    p = jnp.exp(s - m_new[:, None])
    corr = jnp.exp(m_prev - m_new)
    l_scr[...] = l_scr[...] * corr + jnp.sum(p, axis=1)
    acc_scr[...] = acc_scr[...] * corr[:, None] + jnp.dot(
        p.astype(v.dtype), v, preferred_element_type=jnp.float32)
    m_scr[...] = m_new


def _fwd_kernel(q_ref, k_ref, v_ref, o_ref, m_scr, l_scr, acc_scr, *,
                scale: float, causal: bool, window: Optional[int],
                q_offset: int, block_q: int, block_k: int, n_kv: int):
    qi = pl.program_id(2)
    ki = pl.program_id(3)

    @pl.when(ki == 0)
    def _init():
        m_scr[...] = jnp.full_like(m_scr, NEG_INF)
        l_scr[...] = jnp.zeros_like(l_scr)
        acc_scr[...] = jnp.zeros_like(acc_scr)

    _block_update(q_ref, k_ref, v_ref, m_scr, l_scr, acc_scr,
                  scale=scale, causal=causal, window=window,
                  rows_base=qi * block_q + q_offset,
                  cols_base=ki * block_k,
                  block_q=block_q, block_k=block_k)

    @pl.when(ki == n_kv - 1)
    def _flush():
        denom = jnp.maximum(l_scr[...], 1e-30)
        o_ref[0, 0] = (acc_scr[...] / denom[:, None]).astype(o_ref.dtype)


def _fwd_carry_kernel(q_ref, k_ref, v_ref, m0_ref, l0_ref, acc0_ref,
                      m_ref, l_ref, acc_ref, m_scr, l_scr, acc_scr, *,
                      scale: float, causal: bool, window: Optional[int],
                      q_offset: int, kv_offset: int, block_q: int,
                      block_k: int, n_kv: int):
    """Resumable slice: same grid walk as ``_fwd_kernel`` over ``n_kv`` kv
    blocks starting at absolute column ``kv_offset``, but the softmax row
    stats + output accumulator enter as an explicit carry and leave as
    outputs instead of being normalized in place — the executor preempts
    between dispatches and a checkpoint can snapshot the carry."""
    qi = pl.program_id(2)
    ki = pl.program_id(3)

    @pl.when(ki == 0)
    def _init():
        m_scr[...] = m0_ref[0, 0]
        l_scr[...] = l0_ref[0, 0]
        acc_scr[...] = acc0_ref[0, 0]

    _block_update(q_ref, k_ref, v_ref, m_scr, l_scr, acc_scr,
                  scale=scale, causal=causal, window=window,
                  rows_base=qi * block_q + q_offset,
                  cols_base=kv_offset + ki * block_k,
                  block_q=block_q, block_k=block_k)

    @pl.when(ki == n_kv - 1)
    def _flush():
        m_ref[0, 0] = m_scr[...]
        l_ref[0, 0] = l_scr[...]
        acc_ref[0, 0] = acc_scr[...]


def _fwd(q, k, v, *, causal, window, q_offset, block_q, block_k,
         interpret):
    b, h, sq, d = q.shape
    sk = k.shape[2]
    block_q = min(block_q, sq)
    block_k = min(block_k, sk)
    assert sq % block_q == 0 and sk % block_k == 0, (sq, sk)
    n_q, n_kv = sq // block_q, sk // block_k
    grid = (b, h, n_q, n_kv)
    kernel = functools.partial(
        _fwd_kernel, scale=d ** -0.5, causal=causal, window=window,
        q_offset=q_offset, block_q=block_q, block_k=block_k, n_kv=n_kv)
    return pl.pallas_call(
        kernel,
        grid=grid,
        in_specs=[
            pl.BlockSpec((1, 1, block_q, d), lambda b_, h_, q_, k_:
                         (b_, h_, q_, 0)),
            pl.BlockSpec((1, 1, block_k, d), lambda b_, h_, q_, k_:
                         (b_, h_, k_, 0)),
            pl.BlockSpec((1, 1, block_k, d), lambda b_, h_, q_, k_:
                         (b_, h_, k_, 0)),
        ],
        out_specs=pl.BlockSpec((1, 1, block_q, d), lambda b_, h_, q_, k_:
                               (b_, h_, q_, 0)),
        out_shape=jax.ShapeDtypeStruct((b, h, sq, d), q.dtype),
        scratch_shapes=[
            pltpu.VMEM((block_q,), jnp.float32),   # running max
            pltpu.VMEM((block_q,), jnp.float32),   # running sum
            pltpu.VMEM((block_q, d), jnp.float32),  # output accumulator
        ],
        interpret=interpret,
    )(q, k, v)


@functools.partial(jax.custom_vjp,
                   nondiff_argnums=(3, 4, 5, 6, 7, 8))
def _flash(q, k, v, causal, window, q_offset, block_q, block_k, interpret):
    return _fwd(q, k, v, causal=causal, window=window, q_offset=q_offset,
                block_q=block_q, block_k=block_k, interpret=interpret)


def _flash_fwd(q, k, v, causal, window, q_offset, block_q, block_k,
               interpret):
    out = _flash(q, k, v, causal, window, q_offset, block_q, block_k,
                 interpret)
    return out, (q, k, v)


def _flash_bwd(causal, window, q_offset, block_q, block_k, interpret,
               res, g):
    q, k, v = res
    # recompute-based backward through the (chunked) reference — the
    # gradients of flash attention equal those of exact attention

    def f(q_, k_, v_):
        qt = jnp.moveaxis(q_, 1, 2)
        kt = jnp.moveaxis(k_, 1, 2)
        vt = jnp.moveaxis(v_, 1, 2)
        o = ref.attention(qt, kt, vt, causal=causal, window=window,
                          q_offset=q_offset)
        return jnp.moveaxis(o, 1, 2)

    _, vjp = jax.vjp(f, q, k, v)
    return vjp(g)


_flash.defvjp(_flash_fwd, _flash_bwd)


def flash_attention(q, k, v, *, causal: bool = True,
                    window: Optional[int] = None, q_offset: int = 0,
                    block_q: int = 128, block_k: int = 128,
                    interpret: bool = False):
    """Public wrapper matching ref.attention's (B, S, H, D) convention.
    GQA is handled by repeating KV heads (the kernel sees full heads)."""
    b, sq, h, d = q.shape
    hkv = k.shape[2]
    k = ref.repeat_kv(k, h // hkv)
    v = ref.repeat_kv(v, h // hkv)
    qt = jnp.moveaxis(q, 1, 2)   # (B, H, S, D)
    kt = jnp.moveaxis(k, 1, 2)
    vt = jnp.moveaxis(v, 1, 2)
    o = _flash(qt, kt, vt, causal, window, q_offset, block_q, block_k,
               interpret)
    return jnp.moveaxis(o, 1, 2)


def flash_attention_sliced(q, k, v, *, causal: bool = True,
                           window: Optional[int] = None, q_offset: int = 0,
                           block_q: int = 128, block_k: int = 128,
                           kv_slice: int = 1,
                           interpret: bool = False) -> SlicedOp:
    """Sliced, resumable flash attention (DESIGN.md §6).

    Each slice dispatches ``kv_slice`` kv-block grid steps and threads the
    online-softmax carry (running max m, running sum l, unnormalized
    accumulator acc — fp32, (B,H,Sq)/(B,H,Sq)/(B,H,Sq,D)) explicitly, so
    the executor can preempt between slices with delay bounded by one
    slice.  The kv blocks are visited in the same order with the same
    block shapes as the whole-grid kernel, so the result is value-identical
    to :func:`flash_attention` (pinned in tests/test_sliced_kernels.py).
    Forward-only: slicing exists for inference serving; training goes
    through :func:`flash_attention`."""
    b, sq, h, d = q.shape
    sk, hkv = k.shape[1], k.shape[2]
    k = ref.repeat_kv(k, h // hkv)
    v = ref.repeat_kv(v, h // hkv)
    qt = jnp.moveaxis(q, 1, 2)   # (B, H, S, D)
    kt = jnp.moveaxis(k, 1, 2)
    vt = jnp.moveaxis(v, 1, 2)
    block_q = min(block_q, sq)
    block_k = min(block_k, sk)
    assert sq % block_q == 0 and sk % block_k == 0, (sq, sk)
    n_q, n_kv = sq // block_q, sk // block_k
    n_slices = n_slices_for(n_kv, kv_slice)
    scale = d ** -0.5

    def init():
        return (jnp.full((b, h, sq), NEG_INF, jnp.float32),
                jnp.zeros((b, h, sq), jnp.float32),
                jnp.zeros((b, h, sq, d), jnp.float32))

    def step(carry, i):
        m0, l0, acc0 = carry
        k0 = i * kv_slice
        nk = min(kv_slice, n_kv - k0)
        ks = kt[:, :, k0 * block_k:(k0 + nk) * block_k]
        vs = vt[:, :, k0 * block_k:(k0 + nk) * block_k]
        kernel = functools.partial(
            _fwd_carry_kernel, scale=scale, causal=causal, window=window,
            q_offset=q_offset, kv_offset=k0 * block_k, block_q=block_q,
            block_k=block_k, n_kv=nk)
        carry_spec_1d = pl.BlockSpec(
            (1, 1, block_q), lambda b_, h_, q_, k_: (b_, h_, q_))
        carry_spec_2d = pl.BlockSpec(
            (1, 1, block_q, d), lambda b_, h_, q_, k_: (b_, h_, q_, 0))
        return pl.pallas_call(
            kernel,
            grid=(b, h, n_q, nk),
            in_specs=[
                pl.BlockSpec((1, 1, block_q, d), lambda b_, h_, q_, k_:
                             (b_, h_, q_, 0)),
                pl.BlockSpec((1, 1, block_k, d), lambda b_, h_, q_, k_:
                             (b_, h_, k_, 0)),
                pl.BlockSpec((1, 1, block_k, d), lambda b_, h_, q_, k_:
                             (b_, h_, k_, 0)),
                carry_spec_1d, carry_spec_1d, carry_spec_2d,
            ],
            out_specs=[carry_spec_1d, carry_spec_1d, carry_spec_2d],
            out_shape=[
                jax.ShapeDtypeStruct((b, h, sq), jnp.float32),
                jax.ShapeDtypeStruct((b, h, sq), jnp.float32),
                jax.ShapeDtypeStruct((b, h, sq, d), jnp.float32),
            ],
            scratch_shapes=[
                pltpu.VMEM((block_q,), jnp.float32),
                pltpu.VMEM((block_q,), jnp.float32),
                pltpu.VMEM((block_q, d), jnp.float32),
            ],
            interpret=interpret,
        )(qt, ks, vs, m0, l0, acc0)

    def finalize(carry):
        _, lsum, acc = carry
        denom = jnp.maximum(lsum, 1e-30)
        o = (acc / denom[..., None]).astype(q.dtype)
        return jnp.moveaxis(o, 1, 2)

    return SlicedOp(n_slices, init, step, finalize,
                    label="flash_attention")
