"""Mamba (S6) selective-scan kernel (Pallas, TPU target).

State h: (Di, N) with Di up to 8192, N=16.  Grid = (batch, d_inner blocks,
time_chunks) with time innermost; each block keeps its (bd, N) state slice
in VMEM across the sequence.  The elementwise recurrence
    h_t = exp(dt_t * A) * h_{t-1} + (dt_t * x_t) * B_t
    y_t = (h_t @ C_t) + D * x_t
is VPU work over (bd, N) tiles; the kernel fuses the discretization,
recurrence and C-contraction so x/dt/B/C stream through VMEM once.
"""
from __future__ import annotations

import functools
from typing import Optional

import jax
import jax.numpy as jnp
from jax.experimental import pallas as pl
from jax.experimental.pallas import tpu as pltpu

from ..core.segments import SlicedOp, n_slices_for


def _kernel(x_ref, dt_ref, a_ref, b_ref, c_ref, d_ref, h0_ref,
            y_ref, hf_ref, h_scr, *, chunk: int, n_chunks: int):
    ci = pl.program_id(2)

    @pl.when(ci == 0)
    def _init():
        h_scr[...] = h0_ref[0].astype(jnp.float32)

    x = x_ref[0].astype(jnp.float32)      # (chunk, bd)
    dt = dt_ref[0].astype(jnp.float32)    # (chunk, bd)
    A = a_ref[...].astype(jnp.float32)    # (bd, N)
    B = b_ref[0].astype(jnp.float32)      # (chunk, N)
    C = c_ref[0].astype(jnp.float32)      # (chunk, N)
    D = d_ref[...].astype(jnp.float32)    # (bd,)

    def step(t, carry):
        h, ys = carry
        dA = jnp.exp(dt[t][:, None] * A)              # (bd, N)
        h = dA * h + (dt[t] * x[t])[:, None] * B[t][None, :]
        y = jnp.sum(h * C[t][None, :], axis=1) + D * x[t]
        ys = ys.at[t].set(y)
        return h, ys

    ys0 = jnp.zeros((chunk, x.shape[1]), jnp.float32)
    h, ys = jax.lax.fori_loop(0, chunk, step, (h_scr[...], ys0))
    h_scr[...] = h
    y_ref[0] = ys.astype(y_ref.dtype)

    @pl.when(ci == n_chunks - 1)
    def _flush():
        hf_ref[0] = h_scr[...]


def mamba_scan_pallas(x, dt, A, B, C, D, h0: Optional[jax.Array] = None,
                      chunk: int = 32, block_d: int = 512,
                      interpret: bool = False):
    """x, dt: (Bt, S, Di); A: (Di, N); B, C: (Bt, S, N); D: (Di,).
    Returns (y (Bt,S,Di), h_final (Bt,Di,N) fp32)."""
    bt, s, di = x.shape
    n = A.shape[1]
    chunk = min(chunk, s)
    block_d = min(block_d, di)
    assert s % chunk == 0 and di % block_d == 0
    n_chunks, n_blocks = s // chunk, di // block_d
    if h0 is None:
        h0 = jnp.zeros((bt, di, n), jnp.float32)
    kernel = functools.partial(_kernel, chunk=chunk, n_chunks=n_chunks)
    y, h_final = pl.pallas_call(
        kernel,
        grid=(bt, n_blocks, n_chunks),
        in_specs=[
            pl.BlockSpec((1, chunk, block_d), lambda b_, d_, c_:
                         (b_, c_, d_)),
            pl.BlockSpec((1, chunk, block_d), lambda b_, d_, c_:
                         (b_, c_, d_)),
            pl.BlockSpec((block_d, n), lambda b_, d_, c_: (d_, 0)),
            pl.BlockSpec((1, chunk, n), lambda b_, d_, c_: (b_, c_, 0)),
            pl.BlockSpec((1, chunk, n), lambda b_, d_, c_: (b_, c_, 0)),
            pl.BlockSpec((block_d,), lambda b_, d_, c_: (d_,)),
            pl.BlockSpec((1, block_d, n), lambda b_, d_, c_: (b_, d_, 0)),
        ],
        out_specs=[
            pl.BlockSpec((1, chunk, block_d), lambda b_, d_, c_:
                         (b_, c_, d_)),
            pl.BlockSpec((1, block_d, n), lambda b_, d_, c_: (b_, d_, 0)),
        ],
        out_shape=[
            jax.ShapeDtypeStruct((bt, s, di), x.dtype),
            jax.ShapeDtypeStruct((bt, di, n), jnp.float32),
        ],
        scratch_shapes=[pltpu.VMEM((block_d, n), jnp.float32)],
        interpret=interpret,
    )(x, dt, A, B, C, D, h0)
    return y, h_final


def mamba_scan_sliced(x, dt, A, B, C, D, h0: Optional[jax.Array] = None,
                      chunk: int = 32, block_d: int = 512,
                      slice_chunks: int = 1, interpret: bool = False,
                      scan_fn=None) -> SlicedOp:
    """Sliced, resumable selective scan: each slice dispatches
    ``slice_chunks`` time-chunk grid steps of :func:`mamba_scan_pallas`
    on its window, threading the recurrent state h — which the kernel
    already exposes as (h0 in, h_final out) — through the carry together
    with the output buffer.  The recurrence is sequential in time, so the
    sliced result is value-identical to the whole-sequence kernel.

    ``scan_fn`` overrides the per-window scan (ops.py passes the
    pallas/reference dispatcher so slicing works on both paths)."""
    bt, s, di = x.shape
    n = A.shape[1]
    chunk = min(chunk, s)
    assert s % chunk == 0, (s, chunk)
    n_chunks = s // chunk
    n_slices = n_slices_for(n_chunks, slice_chunks)
    if scan_fn is None:
        def scan_fn(xw, dtw, A_, Bw, Cw, D_, h):
            return mamba_scan_pallas(xw, dtw, A_, Bw, Cw, D_, h0=h,
                                     chunk=chunk, block_d=block_d,
                                     interpret=interpret)

    def init():
        h = h0 if h0 is not None else jnp.zeros((bt, di, n), jnp.float32)
        return (h, jnp.zeros((bt, s, di), x.dtype))

    def step(carry, i):
        h, y = carry
        t0 = i * slice_chunks * chunk
        t1 = min(t0 + slice_chunks * chunk, s)
        yw, h = scan_fn(x[:, t0:t1], dt[:, t0:t1], A, B[:, t0:t1],
                       C[:, t0:t1], D, h)
        y = jax.lax.dynamic_update_slice(y, yw.astype(y.dtype), (0, t0, 0))
        return (h, y)

    def finalize(carry):
        h, y = carry
        return y, h

    return SlicedOp(n_slices, init, step, finalize, label="mamba_scan")
