"""Deterministic synthetic LM data pipeline.

Markov-chain token streams (fixed seed) so training loss decreases
measurably and runs are reproducible across restarts — each batch is a
pure function of (seed, step), which also makes the pipeline trivially
shardable per host: hosts materialize only their slice of the global
batch (``host_slice``)."""
from __future__ import annotations

from typing import Dict, Iterator, Optional

import numpy as np


class SyntheticLM:
    def __init__(self, vocab_size: int, seq_len: int, global_batch: int,
                 seed: int = 0, host_slice: Optional[slice] = None):
        self.vocab = vocab_size
        self.seq = seq_len
        self.batch = global_batch
        self.seed = seed
        self.host_slice = host_slice or slice(None)
        rng = np.random.default_rng(seed)
        k = min(vocab_size, 64)
        # sparse transition structure => learnable bigram statistics
        self.trans = rng.dirichlet(np.full(k, 0.1), size=vocab_size)
        self.support = rng.integers(0, vocab_size, size=(vocab_size, k))
        self.cum = np.cumsum(self.trans, axis=1)

    def batch_at(self, step: int) -> Dict[str, np.ndarray]:
        rng = np.random.default_rng(
            np.random.SeedSequence([self.seed, step]))
        b = self.batch
        toks = np.empty((b, self.seq + 1), np.int32)
        toks[:, 0] = rng.integers(0, self.vocab, size=b)
        u = rng.random((b, self.seq))
        for t in range(self.seq):
            cur = toks[:, t]
            idx = (self.cum[cur] < u[:, t:t + 1]).sum(axis=1)
            idx = np.minimum(idx, self.support.shape[1] - 1)
            toks[:, t + 1] = self.support[cur, idx]
        sl = self.host_slice
        return {"inputs": toks[sl, :-1], "labels": toks[sl, 1:]}

    def __iter__(self) -> Iterator[Dict[str, np.ndarray]]:
        step = 0
        while True:
            yield self.batch_at(step)
            step += 1
