"""Assigned input shapes (identical for every LM-family architecture).

  train_4k    : seq 4096,   global batch 256   -> train_step
  prefill_32k : seq 32768,  global batch 32    -> serve prefill
  decode_32k  : one token, KV cache 32768, global batch 128 -> serve decode
  long_500k   : one token, context 524288, batch 1 (sub-quadratic archs only)
"""
import dataclasses


@dataclasses.dataclass(frozen=True)
class ShapeSpec:
    name: str
    kind: str          # "train" | "prefill" | "decode"
    seq_len: int
    global_batch: int
    sub_quadratic_only: bool = False


SHAPES = {
    "train_4k": ShapeSpec("train_4k", "train", 4096, 256),
    "prefill_32k": ShapeSpec("prefill_32k", "prefill", 32768, 32),
    "decode_32k": ShapeSpec("decode_32k", "decode", 32768, 128),
    "long_500k": ShapeSpec("long_500k", "decode", 524288, 1,
                           sub_quadratic_only=True),
}


def applicable(arch_sub_quadratic: bool, shape: ShapeSpec) -> bool:
    """long_500k needs sub-quadratic attention (SSM/hybrid/SWA); dense
    full-attention archs skip it (recorded in DESIGN.md)."""
    return arch_sub_quadratic or not shape.sub_quadratic_only
