"""smollm-135m [dense]: 30L d_model=576 9H (GQA kv=3) d_ff=1536 vocab=49152
— llama-arch small. [hf:HuggingFaceTB/SmolLM-135M; hf]  9 heads do not
divide the 16-way model axis: hybrid profile (TP on MLP, replicated
attention) — the dp-heavy baseline the §Perf log hillclimbs."""
from ..models.blocks import BlockSpec, ModelConfig
from .registry import ArchEntry, register


def config() -> ModelConfig:
    return ModelConfig(
        name="smollm-135m", n_layers=30, d_model=576, n_heads=9,
        n_kv_heads=3, d_ff=1536, vocab_size=49152,
        pattern=(BlockSpec("attn"),), tie_embeddings=True,
        sharding_profile="hybrid")


def reduced() -> ModelConfig:
    return ModelConfig(
        name="smollm-135m-reduced", n_layers=4, d_model=72, n_heads=9,
        n_kv_heads=3, d_ff=192, vocab_size=128,
        pattern=(BlockSpec("attn"),), tie_embeddings=True, remat=False,
        sharding_profile="hybrid")


register(ArchEntry("smollm-135m", "dense", config, reduced,
                   notes="9 heads indivisible by tp=16 -> hybrid profile"))
