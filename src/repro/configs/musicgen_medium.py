"""musicgen-medium [audio]: 48L d_model=1536 24H (GQA kv=24) d_ff=6144
vocab=2048 — decoder-only over EnCodec tokens. [arXiv:2306.05284; hf]
The EnCodec frontend is a STUB: input_specs supplies precomputed frame
embeddings (B, S, d_model); logits project onto the 2048-entry codebook.
24 heads divide 8 but not 16 -> hybrid profile."""
from ..models.blocks import BlockSpec, ModelConfig
from .registry import ArchEntry, register


def config() -> ModelConfig:
    return ModelConfig(
        name="musicgen-medium", n_layers=48, d_model=1536, n_heads=24,
        n_kv_heads=24, d_ff=6144, vocab_size=2048,
        pattern=(BlockSpec("attn"),), input_mode="embeddings",
        mlp_variant="gelu", sharding_profile="hybrid")


def reduced() -> ModelConfig:
    return ModelConfig(
        name="musicgen-medium-reduced", n_layers=4, d_model=96, n_heads=6,
        n_kv_heads=6, d_ff=192, vocab_size=128,
        pattern=(BlockSpec("attn"),), input_mode="embeddings",
        mlp_variant="gelu", remat=False, sharding_profile="hybrid")


register(ArchEntry("musicgen-medium", "audio", config, reduced,
                   notes="EnCodec frontend stubbed; embeddings input"))
