"""rwkv6-1.6b [ssm]: 24L d_model=2048 (attn-free) d_ff=7168 vocab=65536 —
Finch, data-dependent decay. [arXiv:2404.05892; unverified]
O(1) decode state (per-head D x D WKV matrix) -> long_500k applicable."""
from ..models.blocks import BlockSpec, ModelConfig
from .registry import ArchEntry, register


def config() -> ModelConfig:
    return ModelConfig(
        name="rwkv6-1.6b", n_layers=24, d_model=2048, n_heads=32,
        n_kv_heads=32, d_ff=7168, vocab_size=65536,
        pattern=(BlockSpec("rwkv"),), rwkv_head_dim=64,
        sharding_profile="tp")


def reduced() -> ModelConfig:
    return ModelConfig(
        name="rwkv6-1.6b-reduced", n_layers=4, d_model=64, n_heads=4,
        n_kv_heads=4, d_ff=128, vocab_size=128,
        pattern=(BlockSpec("rwkv"),), rwkv_head_dim=16, remat=False)


register(ArchEntry("rwkv6-1.6b", "ssm", config, reduced,
                   sub_quadratic=True,
                   notes="attn-free; wkv state (H,64,64) per layer"))
