"""mixtral-8x22b [moe]: 56L d_model=6144 48H (GQA kv=8) d_ff=16384
vocab=32768, MoE 8e top-2, SWA. [arXiv:2401.04088; hf]
8 experts don't divide the 16-way model axis -> TP-within-expert; the
4096-token sliding window bounds the decode cache, so long_500k runs."""
from ..models.blocks import BlockSpec, ModelConfig
from .registry import ArchEntry, register


def config() -> ModelConfig:
    return ModelConfig(
        name="mixtral-8x22b", n_layers=56, d_model=6144, n_heads=48,
        n_kv_heads=8, d_ff=16384, vocab_size=32768,
        pattern=(BlockSpec("attn", moe=True),),
        moe_experts=8, moe_top_k=2, window=4096, fsdp=True,
        sharding_profile="tp")


def reduced() -> ModelConfig:
    return ModelConfig(
        name="mixtral-8x22b-reduced", n_layers=4, d_model=64, n_heads=4,
        n_kv_heads=2, d_ff=128, vocab_size=128,
        pattern=(BlockSpec("attn", moe=True),),
        moe_experts=4, moe_top_k=2, window=8, remat=False)


register(ArchEntry("mixtral-8x22b", "moe", config, reduced,
                   sub_quadratic=True,
                   notes="SWA-4096 ring cache -> long_500k applicable; "
                         "TP-within-expert (8e vs 16-way axis)"))
