"""--arch registry: one entry per assigned architecture.

Each ``ArchEntry`` carries the FULL published config (exercised only via the
dry-run: ShapeDtypeStruct, no allocation), a REDUCED config of the same
family for CPU smoke tests, and metadata used by the roofline analysis
(active-parameter count for MoE MODEL_FLOPS, sub-quadratic applicability).
"""
from __future__ import annotations

import dataclasses
from typing import Callable, Dict

from ..models.blocks import ModelConfig


@dataclasses.dataclass(frozen=True)
class ArchEntry:
    name: str
    family: str                    # dense | moe | hybrid | ssm | vlm | audio
    config: Callable[[], ModelConfig]
    reduced: Callable[[], ModelConfig]
    sub_quadratic: bool = False    # long_500k applicability
    notes: str = ""


_REGISTRY: Dict[str, ArchEntry] = {}


def register(entry: ArchEntry) -> ArchEntry:
    _REGISTRY[entry.name] = entry
    return entry


def get(name: str) -> ArchEntry:
    if name not in _REGISTRY:
        _load_all()
    return _REGISTRY[name]


def names() -> list[str]:
    _load_all()
    return sorted(_REGISTRY)


def _load_all() -> None:
    from . import (dbrx_132b, internlm2_20b, jamba_v01_52b,  # noqa: F401
                   llama32_vision_90b, minitron_8b, mixtral_8x22b,
                   musicgen_medium, olmo_1b, rwkv6_1b6, smollm_135m)
