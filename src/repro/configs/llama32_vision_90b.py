"""llama-3.2-vision-90b [vlm]: 100L d_model=8192 64H (GQA kv=8) d_ff=28672
vocab=128256 — cross-attn image layers every 5th layer (20 cross + 80 self).
[hf:meta-llama/Llama-3.2-11B-Vision; unverified]  The vision frontend is a
STUB: input_specs supplies precomputed patch embeddings for the cross-attn
source."""
from ..models.blocks import BlockSpec, ModelConfig
from .registry import ArchEntry, register

PATTERN = (BlockSpec("attn"), BlockSpec("attn"), BlockSpec("attn"),
           BlockSpec("attn"), BlockSpec("cross"))


def config() -> ModelConfig:
    return ModelConfig(
        name="llama-3.2-vision-90b", n_layers=100, d_model=8192, n_heads=64,
        n_kv_heads=8, d_ff=28672, vocab_size=128256, pattern=PATTERN,
        cross_source_len=1601,  # ViT-H/14 @ 560px patch tokens (stubbed)
        rope_theta=500_000.0, fsdp=True, grad_accum=2,
        sharding_profile="tp")


def reduced() -> ModelConfig:
    return ModelConfig(
        name="llama-3.2-vision-90b-reduced", n_layers=10, d_model=64,
        n_heads=4, n_kv_heads=2, d_ff=160, vocab_size=128, pattern=PATTERN,
        cross_source_len=8, remat=False, sharding_profile="tp")


register(ArchEntry("llama-3.2-vision-90b", "vlm", config, reduced,
                   sub_quadratic=False,
                   notes="cross-attn image layers; frontend stubbed"))
