"""internlm2-20b [dense]: 48L d_model=6144 48H (GQA kv=8) d_ff=16384
vocab=92544 — GQA. [arXiv:2403.17297; hf]"""
from ..models.blocks import BlockSpec, ModelConfig
from .registry import ArchEntry, register


def config() -> ModelConfig:
    return ModelConfig(
        name="internlm2-20b", n_layers=48, d_model=6144, n_heads=48,
        n_kv_heads=8, d_ff=16384, vocab_size=92544,
        pattern=(BlockSpec("attn"),), rope_theta=1_000_000.0,
        fsdp=True, sharding_profile="tp")


def reduced() -> ModelConfig:
    return ModelConfig(
        name="internlm2-20b-reduced", n_layers=4, d_model=96, n_heads=6,
        n_kv_heads=2, d_ff=256, vocab_size=128,
        pattern=(BlockSpec("attn"),), remat=False)


register(ArchEntry("internlm2-20b", "dense", config, reduced))
