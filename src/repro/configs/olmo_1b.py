"""olmo-1b [dense]: 16L d_model=2048 16H (GQA kv=16) d_ff=8192 vocab=50304
— non-parametric LayerNorm. [arXiv:2402.00838; hf]"""
from ..models.blocks import BlockSpec, ModelConfig
from .registry import ArchEntry, register


def config() -> ModelConfig:
    return ModelConfig(
        name="olmo-1b", n_layers=16, d_model=2048, n_heads=16,
        n_kv_heads=16, d_ff=8192, vocab_size=50304,
        pattern=(BlockSpec("attn"),), norm="nonparam",
        sharding_profile="tp")


def reduced() -> ModelConfig:
    return ModelConfig(
        name="olmo-1b-reduced", n_layers=4, d_model=64, n_heads=4,
        n_kv_heads=4, d_ff=256, vocab_size=128,
        pattern=(BlockSpec("attn"),), norm="nonparam", remat=False)


register(ArchEntry("olmo-1b", "dense", config, reduced,
                   notes="non-parametric LN"))
