"""dbrx-132b [moe]: 40L d_model=6144 48H (GQA kv=8) d_ff=10752 vocab=100352,
MoE 16e top-4 — fine-grained. [hf:databricks/dbrx-base; unverified]
16 experts = 16-way model axis -> pure expert parallelism (1 expert/shard)."""
from ..models.blocks import BlockSpec, ModelConfig
from .registry import ArchEntry, register


def config() -> ModelConfig:
    return ModelConfig(
        name="dbrx-132b", n_layers=40, d_model=6144, n_heads=48,
        n_kv_heads=8, d_ff=10752, vocab_size=100352,
        pattern=(BlockSpec("attn", moe=True),),
        moe_experts=16, moe_top_k=4, fsdp=True, sharding_profile="tp")


def reduced() -> ModelConfig:
    return ModelConfig(
        name="dbrx-132b-reduced", n_layers=4, d_model=64, n_heads=4,
        n_kv_heads=2, d_ff=96, vocab_size=128,
        pattern=(BlockSpec("attn", moe=True),),
        moe_experts=4, moe_top_k=4, remat=False)


register(ArchEntry("dbrx-132b", "moe", config, reduced,
                   notes="EP: 16 experts over the 16-way model axis"))
