"""jamba-v0.1-52b [hybrid]: 32L d_model=4096 32H (GQA kv=8) d_ff=14336
vocab=65536, MoE 16e top-2 — Mamba+attn 1:7 interleave, MoE every other
layer. [arXiv:2403.19887; hf]  Period-8 block: attention at position 4,
Mamba elsewhere; MoE on odd positions."""
from ..models.blocks import BlockSpec, ModelConfig
from .registry import ArchEntry, register

PATTERN = tuple(
    BlockSpec("attn" if i == 4 else "mamba", moe=(i % 2 == 1))
    for i in range(8))


def config() -> ModelConfig:
    return ModelConfig(
        name="jamba-v0.1-52b", n_layers=32, d_model=4096, n_heads=32,
        n_kv_heads=8, d_ff=14336, vocab_size=65536, pattern=PATTERN,
        moe_experts=16, moe_top_k=2, mamba_d_state=16, mamba_expand=2,
        fsdp=True, sharding_profile="tp")


def reduced() -> ModelConfig:
    return ModelConfig(
        name="jamba-v0.1-52b-reduced", n_layers=8, d_model=64, n_heads=4,
        n_kv_heads=2, d_ff=128, vocab_size=128, pattern=PATTERN,
        moe_experts=4, moe_top_k=2, mamba_d_state=8, remat=False)


register(ArchEntry("jamba-v0.1-52b", "hybrid", config, reduced,
                   sub_quadratic=True,
                   notes="Mamba+attn 1:7, MoE 16e top-2; 512k KV of the 4 "
                         "attn layers shards over the mesh"))
