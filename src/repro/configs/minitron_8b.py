"""minitron-8b [dense]: 32L d_model=4096 32H (GQA kv=8) d_ff=16384
vocab=256000 — pruned nemotron. [arXiv:2407.14679; hf]  The 256k vocabulary
makes the (sharded) embedding/unembedding the dominant memory term."""
from ..models.blocks import BlockSpec, ModelConfig
from .registry import ArchEntry, register


def config() -> ModelConfig:
    return ModelConfig(
        name="minitron-8b", n_layers=32, d_model=4096, n_heads=32,
        n_kv_heads=8, d_ff=16384, vocab_size=256000,
        pattern=(BlockSpec("attn"),), mlp_variant="relu2",
        sharding_profile="tp")


def reduced() -> ModelConfig:
    return ModelConfig(
        name="minitron-8b-reduced", n_layers=4, d_model=64, n_heads=4,
        n_kv_heads=2, d_ff=256, vocab_size=512,
        pattern=(BlockSpec("attn"),), mlp_variant="relu2", remat=False)


register(ArchEntry("minitron-8b", "dense", config, reduced,
                   notes="256k vocab stresses embedding sharding"))
