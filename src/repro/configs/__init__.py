from .registry import ArchEntry, get, names, register
from .shapes import SHAPES, ShapeSpec, applicable

__all__ = ["ArchEntry", "get", "names", "register", "SHAPES", "ShapeSpec",
           "applicable"]
