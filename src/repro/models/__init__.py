from .blocks import BlockSpec, ModelConfig
from .transformer import (decode_step, forward, init_cache, init_params,
                          lm_loss, param_count, param_specs, prefill)

__all__ = ["BlockSpec", "ModelConfig", "decode_step", "forward",
           "init_cache", "init_params", "lm_loss", "param_count",
           "param_specs", "prefill"]
