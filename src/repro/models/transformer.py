"""Pattern-based decoder model covering all 10 assigned architectures.

The layer stack is ``cfg.pattern`` repeated ``cfg.repeats`` times; parameters
for each pattern position are stacked over repeats and the repeats dimension
is consumed by ``jax.lax.scan`` — HLO size is proportional to the pattern
length, not the depth (essential for 100-layer dry-run compiles).

Entry points (all pure functions of (cfg, params, ...)):
  init_params    : real parameters (reduced configs / examples)
  param_specs    : ShapeDtypeStruct pytree (dry-run, no allocation)
  forward        : (B, S) tokens -> (B, S, V) logits           [train]
  prefill        : forward + populated decode cache            [serve]
  init_cache     : empty decode cache pytree
  decode_step    : one-token step with cache update            [serve]
  lm_loss        : causal LM cross-entropy (+z-loss)
"""
from __future__ import annotations

import functools
from typing import Any, Dict, Optional, Tuple

import jax
import jax.numpy as jnp

from . import blocks
from .blocks import BlockSpec, ModelConfig
from ..parallel.hints import shard_hint

INIT_FNS = {
    "attn": blocks.init_attention,
    "cross": functools.partial(blocks.init_attention, cross=True),
    "mamba": blocks.init_mamba,
    "rwkv": blocks.init_rwkv,
}


# --------------------------------------------------------------------------
# parameters
# --------------------------------------------------------------------------

def init_params(cfg: ModelConfig, key: jax.Array) -> Dict[str, Any]:
    """params['blocks'] is a list with one dict per pattern position; every
    leaf carries a leading ``repeats`` dimension (consumed by lax.scan)."""
    keys = jax.random.split(key, 4)
    params: Dict[str, Any] = {}
    params["embed"] = jax.random.normal(
        keys[0], (cfg.vocab_size, cfg.d_model), cfg.dtype) * 0.02
    if not cfg.tie_embeddings:
        params["unembed"] = jax.random.normal(
            keys[1], (cfg.d_model, cfg.vocab_size), cfg.dtype) \
            * cfg.d_model ** -0.5
    if cfg.norm == "rms":
        params["final_norm_w"] = jnp.ones((cfg.d_model,), jnp.float32)

    def one_repeat(k):
        ks = jax.random.split(k, len(cfg.pattern))
        out = []
        for spec, kk in zip(cfg.pattern, ks):
            k1, k2 = jax.random.split(kk)
            p = {"core": INIT_FNS[spec.kind](cfg, k1)}
            if spec.kind in ("attn", "cross"):
                p["ffn"] = (blocks.init_moe if spec.moe
                            else blocks.init_mlp)(cfg, k2)
            elif spec.moe:  # mamba/rwkv blocks with MoE channel path
                p["ffn"] = blocks.init_moe(cfg, k2)
            out.append(p)
        return out

    rep_keys = jax.random.split(keys[2], cfg.repeats)
    params["blocks"] = jax.vmap(one_repeat)(rep_keys)
    return params


def param_specs(cfg: ModelConfig) -> Dict[str, Any]:
    """Parameter ShapeDtypeStructs without allocating anything."""
    return jax.eval_shape(lambda k: init_params(cfg, k),
                          jax.ShapeDtypeStruct((2,), jnp.uint32))


def param_count(cfg: ModelConfig) -> int:
    import numpy as np
    specs = param_specs(cfg)
    return int(sum(np.prod(leaf.shape) for leaf in jax.tree.leaves(specs)))


# --------------------------------------------------------------------------
# forward (train) — optionally emitting the decode cache (prefill)
# --------------------------------------------------------------------------

def _apply_block(cfg: ModelConfig, spec: BlockSpec, p: Dict, x: jax.Array,
                 positions: jax.Array, source: Optional[jax.Array]):
    """Returns (x_out, state) where state feeds prefill cache population."""
    if spec.kind == "attn":
        x, state = blocks.attention_block(cfg, p["core"], x, positions)
    elif spec.kind == "cross":
        x, state = blocks.attention_block(cfg, p["core"], x, positions,
                                          source=source)
    elif spec.kind == "mamba":
        x, state = blocks.mamba_block(cfg, p["core"], x)
    elif spec.kind == "rwkv":
        x, state = blocks.rwkv_block(cfg, p["core"], x)
    else:
        raise ValueError(spec.kind)
    if "ffn" in p:
        x = (blocks.moe_block if spec.moe else blocks.mlp_block)(
            cfg, p["ffn"], x)
    return x, state


def _state_to_cache(cfg: ModelConfig, spec: BlockSpec, state,
                    max_len: int, positions: jax.Array):
    """Convert a forward-pass block state into decode-cache format."""
    if spec.kind == "attn":
        k, v = state
        b, s = k.shape[0], k.shape[1]
        smax = max_len if cfg.window is None else min(max_len, cfg.window)
        m = min(s, smax)
        slots = (positions[-m:] % smax).astype(jnp.int32)
        kc = jnp.zeros((b, smax) + k.shape[2:], k.dtype)
        vc = jnp.zeros((b, smax) + v.shape[2:], v.dtype)
        kc = kc.at[:, slots].set(k[:, -m:])
        vc = vc.at[:, slots].set(v[:, -m:])
        return {"k": kc, "v": vc}
    if spec.kind == "cross":
        k, v = state
        return {"k": k, "v": v}
    if spec.kind in ("mamba", "rwkv"):
        return state
    raise ValueError(spec.kind)


def embed_inputs(cfg: ModelConfig, params, inputs: jax.Array) -> jax.Array:
    if cfg.input_mode == "embeddings" or inputs.ndim == 3:
        return inputs.astype(cfg.dtype)
    return params["embed"][inputs]


def _forward(cfg: ModelConfig, params: Dict[str, Any], inputs: jax.Array,
             source: Optional[jax.Array], with_cache: bool,
             max_len: int = 0):
    x = embed_inputs(cfg, params, inputs)
    s = x.shape[1]
    positions = jnp.arange(s)

    def repeat_body(x, rep_params):
        states = []
        for i, spec in enumerate(cfg.pattern):
            apply = functools.partial(_apply_block, cfg, spec)
            if cfg.remat and not with_cache:
                apply = jax.checkpoint(
                    apply, policy=getattr(jax.checkpoint_policies,
                                          cfg.remat_policy))
            x, state = apply(rep_params[i], x, positions, source)
            x = shard_hint(x, "residual")
            if with_cache:
                states.append(_state_to_cache(cfg, spec, state, max_len,
                                              positions))
        return x, (tuple(states) if with_cache else None)

    x = shard_hint(x, "residual")
    x, caches = jax.lax.scan(repeat_body, x, params["blocks"])
    x = blocks.norm(cfg, params.get("final_norm_w"), x)
    unembed = params["embed"].T if cfg.tie_embeddings else params["unembed"]
    logits = shard_hint(x @ unembed, "logits")
    return logits, caches


def forward(cfg: ModelConfig, params, inputs: jax.Array,
            source: Optional[jax.Array] = None) -> jax.Array:
    """inputs: (B, S) int tokens or (B, S, d) embeddings; source: optional
    (B, S_src, d) stub-frontend embeddings for cross-attention layers."""
    return _forward(cfg, params, inputs, source, with_cache=False)[0]


# --------------------------------------------------------------------------
# loss
# --------------------------------------------------------------------------

def lm_loss(cfg: ModelConfig, params, batch: Dict[str, jax.Array],
            z_loss: float = 1e-4) -> jax.Array:
    """Causal LM cross-entropy (+z-loss), computed in sequence chunks so
    the fp32 logits working set stays bounded (vocab stays model-sharded,
    gold extraction via one-hot einsum — sharding-friendly, no gather
    across the vocab axis).  Each chunk is rematerialized in the backward
    pass (jax.checkpoint)."""
    x = _forward_trunk(cfg, params, batch["inputs"],
                       source=batch.get("source"))
    # leave sequence parallelism before the unembedding: vocab takes the
    # model axis in the loss chunks (avoids a full-vocab materialization
    # when GSPMD resolves the seq-vs-vocab sharding conflict)
    x = shard_hint(x, "pre_loss")
    labels = batch["labels"]
    mask = batch.get("mask", jnp.ones(labels.shape, jnp.float32))
    unembed = params["embed"].T if cfg.tie_embeddings else params["unembed"]

    s = x.shape[1]
    nc = cfg.loss_chunks if (s % cfg.loss_chunks == 0
                             and s >= cfg.loss_chunks) else 1

    def chunk_loss(xc, lc, mc):
        logits = shard_hint(
            shard_hint(xc @ unembed, "logits").astype(jnp.float32),
            "logits")
        logz = jax.nn.logsumexp(logits, axis=-1)
        onehot = shard_hint(
            jax.nn.one_hot(lc, cfg.vocab_size, dtype=logits.dtype),
            "logits")
        gold = jnp.einsum("bsv,bsv->bs", logits, onehot)
        nll = logz - gold
        return jnp.sum((nll + z_loss * logz ** 2) * mc), jnp.sum(mc)

    chunk_loss = jax.checkpoint(chunk_loss)
    tot, cnt = 0.0, 0.0
    step = s // nc
    for i in range(nc):
        sl = slice(i * step, (i + 1) * step)
        li, ci = chunk_loss(x[:, sl], labels[:, sl], mask[:, sl])
        tot = tot + li
        cnt = cnt + ci
    return tot / jnp.maximum(cnt, 1.0)


def _forward_trunk(cfg: ModelConfig, params, inputs, source=None):
    """forward() without the unembedding (the loss chunks it)."""
    x = embed_inputs(cfg, params, inputs)
    s = x.shape[1]
    positions = jnp.arange(s)

    def repeat_body(x, rep_params):
        for i, spec in enumerate(cfg.pattern):
            apply = functools.partial(_apply_block, cfg, spec)
            if cfg.remat:
                apply = jax.checkpoint(
                    apply, policy=getattr(jax.checkpoint_policies,
                                          cfg.remat_policy))
            x, _ = apply(rep_params[i], x, positions, source)
            x = shard_hint(x, "residual")
        return x, None

    x = shard_hint(x, "residual")
    x, _ = jax.lax.scan(repeat_body, x, params["blocks"])
    return blocks.norm(cfg, params.get("final_norm_w"), x)


# --------------------------------------------------------------------------
# decode path
# --------------------------------------------------------------------------

def init_cache(cfg: ModelConfig, batch: int, max_len: int,
               source_len: Optional[int] = None) -> Tuple:
    """Empty decode cache: tuple over pattern positions, leaves stacked
    with a leading ``repeats`` dimension."""
    hkv, hd = cfg.n_kv_heads, cfg.head_dim

    def make_one(spec: BlockSpec):
        if spec.kind == "attn":
            smax = max_len if cfg.window is None else min(max_len, cfg.window)
            return {"k": jnp.zeros((batch, smax, hkv, hd), cfg.dtype),
                    "v": jnp.zeros((batch, smax, hkv, hd), cfg.dtype)}
        if spec.kind == "cross":
            slen = source_len or cfg.cross_source_len
            return {"k": jnp.zeros((batch, slen, hkv, hd), cfg.dtype),
                    "v": jnp.zeros((batch, slen, hkv, hd), cfg.dtype)}
        if spec.kind == "mamba":
            return {"conv": jnp.zeros(
                        (batch, cfg.mamba_d_conv - 1, cfg.mamba_d_inner),
                        cfg.dtype),
                    "ssm": jnp.zeros(
                        (batch, cfg.mamba_d_inner, cfg.mamba_d_state),
                        jnp.float32)}
        if spec.kind == "rwkv":
            return {"wkv": jnp.zeros(
                        (batch, cfg.rwkv_heads, cfg.rwkv_head_dim,
                         cfg.rwkv_head_dim), jnp.float32),
                    "shift_tm": jnp.zeros((batch, 1, cfg.d_model), cfg.dtype),
                    "shift_cm": jnp.zeros((batch, 1, cfg.d_model), cfg.dtype)}
        raise ValueError(spec.kind)

    return tuple(
        jax.tree.map(
            lambda leaf: jnp.broadcast_to(leaf, (cfg.repeats,) + leaf.shape),
            make_one(spec))
        for spec in cfg.pattern)


def prefill(cfg: ModelConfig, params, tokens: jax.Array, max_len: int,
            source: Optional[jax.Array] = None):
    """Full-sequence forward that also populates the decode cache.

    Returns (last-token logits (B, V), cache, next positions (B,))."""
    b, s = tokens.shape[0], tokens.shape[1]
    logits, caches = _forward(cfg, params, tokens, source,
                              with_cache=True, max_len=max_len)
    return logits[:, -1], caches, jnp.full((b,), s, jnp.int32)


def decode_step(cfg: ModelConfig, params: Dict[str, Any], cache: Tuple,
                token: jax.Array, pos: jax.Array
                ) -> Tuple[jax.Array, Tuple]:
    """token: (B,) int32 (or (B, d) embedding); pos: (B,) position of the
    new token.  Returns (logits (B, V), new cache)."""
    if token.ndim == 2:           # precomputed frontend embedding (B, d)
        x = token[:, None].astype(cfg.dtype)
    else:                         # token ids (B,) — embed via codebook
        x = params["embed"][token][:, None]

    def repeat_body(x, pc):
        rep_p, rep_c = pc
        new_c = []
        for i, spec in enumerate(cfg.pattern):
            p, c = rep_p[i], rep_c[i]
            if spec.kind == "attn":
                x, c = blocks.attention_block_decode(cfg, p["core"], x, c,
                                                     pos)
            elif spec.kind == "cross":
                x, c = blocks.attention_block_decode(cfg, p["core"], x, c,
                                                     pos, is_cross=True)
            elif spec.kind == "mamba":
                x, c = blocks.mamba_block_decode(cfg, p["core"], x, c)
            elif spec.kind == "rwkv":
                x, c = blocks.rwkv_block_decode(cfg, p["core"], x, c)
            if "ffn" in p:
                if spec.moe:
                    x = blocks.moe_block(cfg, p["ffn"], x, no_drop=True)
                else:
                    x = blocks.mlp_block(cfg, p["ffn"], x)
            new_c.append(c)
        return x, tuple(new_c)

    x, new_cache = jax.lax.scan(repeat_body, x,
                                (params["blocks"], tuple(cache)))
    x = blocks.norm(cfg, params.get("final_norm_w"), x[:, 0])
    unembed = params["embed"].T if cfg.tie_embeddings else params["unembed"]
    return x @ unembed, new_cache
