"""Transformer / SSM building blocks shared by all 10 architectures.

Everything is a pure function of (config, params, inputs).  Parameters are
plain dict pytrees created by the matching ``init_*`` functions; stacking
over layers is handled by models.transformer.

Conventions:
  x            : (B, S, d_model) activations, cfg.dtype (bf16 by default)
  params       : weights in cfg.dtype; norm weights in fp32
  head layout  : (B, S, H, head_dim)
"""
from __future__ import annotations

import dataclasses
from typing import Any, Optional, Tuple

import jax
import jax.numpy as jnp

from ..kernels import ops


# --------------------------------------------------------------------------
# config
# --------------------------------------------------------------------------

@dataclasses.dataclass(frozen=True)
class BlockSpec:
    kind: str          # "attn" | "cross" | "mamba" | "rwkv"
    moe: bool = False  # FFN of this block is a mixture of experts


@dataclasses.dataclass(frozen=True)
class ModelConfig:
    name: str
    n_layers: int
    d_model: int
    n_heads: int
    n_kv_heads: int
    d_ff: int
    vocab_size: int
    pattern: Tuple[BlockSpec, ...] = (BlockSpec("attn"),)
    head_dim: Optional[int] = None           # default d_model // n_heads
    norm: str = "rms"                         # "rms" | "nonparam"
    moe_experts: int = 0
    moe_top_k: int = 0
    moe_capacity: float = 1.25
    window: Optional[int] = None              # sliding-window attention
    rope_theta: float = 10_000.0
    cross_source_len: int = 64                # stub frontend tokens (vlm/audio)
    input_mode: str = "tokens"                # "tokens" | "embeddings"
    mamba_d_state: int = 16
    mamba_d_conv: int = 4
    mamba_expand: int = 2
    rwkv_head_dim: int = 64
    attn_chunk: int = 2048                    # flash KV-chunk (ref path)
    mlp_variant: str = "swiglu"               # "swiglu" | "gelu" | "relu2"
    tie_embeddings: bool = False
    dtype: Any = jnp.bfloat16
    sharding_profile: str = "tp"              # "tp" | "hybrid"
    fsdp: bool = False                        # ZeRO-3: params over data axes
    grad_accum: int = 1                       # microbatched grad accumulation
    remat: bool = True
    remat_policy: str = "nothing_saveable"    # or "dots_with_no_batch_dims_saveable"
    loss_chunks: int = 8                      # seq-chunked xent (memory)
    moe_seq_chunks: int = 4                   # chunked MoE dispatch (memory)

    def __post_init__(self):
        if self.n_layers % len(self.pattern) != 0:
            raise ValueError("n_layers must be a multiple of the pattern")
        if self.head_dim is None:
            object.__setattr__(self, "head_dim", self.d_model // self.n_heads)

    @property
    def repeats(self) -> int:
        return self.n_layers // len(self.pattern)

    @property
    def mamba_d_inner(self) -> int:
        return self.mamba_expand * self.d_model

    @property
    def rwkv_heads(self) -> int:
        return self.d_model // self.rwkv_head_dim

    def block_at(self, layer: int) -> BlockSpec:
        return self.pattern[layer % len(self.pattern)]


# --------------------------------------------------------------------------
# norms / rope
# --------------------------------------------------------------------------

def rms_norm(x: jax.Array, weight: Optional[jax.Array]) -> jax.Array:
    xf = x.astype(jnp.float32)
    var = jnp.mean(xf * xf, axis=-1, keepdims=True)
    out = xf * jax.lax.rsqrt(var + 1e-6)
    if weight is not None:
        out = out * weight
    return out.astype(x.dtype)


def nonparametric_ln(x: jax.Array) -> jax.Array:
    """OLMo-style LayerNorm without adaptive gain/bias."""
    xf = x.astype(jnp.float32)
    mu = jnp.mean(xf, axis=-1, keepdims=True)
    var = jnp.var(xf, axis=-1, keepdims=True)
    return ((xf - mu) * jax.lax.rsqrt(var + 1e-5)).astype(x.dtype)


def norm(cfg: ModelConfig, w: Optional[jax.Array], x: jax.Array) -> jax.Array:
    if cfg.norm == "nonparam":
        return nonparametric_ln(x)
    return rms_norm(x, w)


def rope(q: jax.Array, k: jax.Array, positions: jax.Array,
         theta: float) -> Tuple[jax.Array, jax.Array]:
    """Rotary embeddings.  q,k: (B, S, H, D); positions: (S,) or (B, S)."""
    d = q.shape[-1]
    half = d // 2
    freqs = theta ** (-jnp.arange(0, half, dtype=jnp.float32) / half)
    if positions.ndim == 1:
        ang = positions.astype(jnp.float32)[None, :, None] * freqs[None, None]
    else:
        ang = positions.astype(jnp.float32)[:, :, None] * freqs[None, None]
    cos, sin = jnp.cos(ang)[..., None, :], jnp.sin(ang)[..., None, :]

    def rot(t):
        t1, t2 = t[..., :half], t[..., half:]
        return jnp.concatenate([t1 * cos - t2 * sin,
                                t2 * cos + t1 * sin], axis=-1).astype(t.dtype)

    return rot(q), rot(k)


# --------------------------------------------------------------------------
# attention blocks
# --------------------------------------------------------------------------

def init_attention(cfg: ModelConfig, key, cross: bool = False) -> dict:
    d, hd = cfg.d_model, cfg.head_dim
    h, hkv = cfg.n_heads, cfg.n_kv_heads
    k1, k2, k3, k4 = jax.random.split(key, 4)
    scale = d ** -0.5
    p = {
        "wq": jax.random.normal(k1, (d, h * hd), cfg.dtype) * scale,
        "wk": jax.random.normal(k2, (d, hkv * hd), cfg.dtype) * scale,
        "wv": jax.random.normal(k3, (d, hkv * hd), cfg.dtype) * scale,
        "wo": jax.random.normal(k4, (h * hd, d), cfg.dtype) * scale,
    }
    if cfg.norm == "rms":
        p["norm_w"] = jnp.ones((d,), jnp.float32)
    return p


def _split_heads(t: jax.Array, n: int) -> jax.Array:
    b, s, _ = t.shape
    return t.reshape(b, s, n, -1)


def attention_block(cfg: ModelConfig, p: dict, x: jax.Array,
                    positions: jax.Array,
                    source: Optional[jax.Array] = None):
    """Self- or cross-attention with pre-norm and residual.

    Returns (x_out, (k, v)) — the per-layer keys/values feed prefill cache
    population (k/v are post-RoPE for self-attention)."""
    h = norm(cfg, p.get("norm_w"), x)
    q = _split_heads(h @ p["wq"], cfg.n_heads)
    kv_src = norm(cfg, p.get("norm_w"), source) if source is not None else h
    k = _split_heads(kv_src @ p["wk"], cfg.n_kv_heads)
    v = _split_heads(kv_src @ p["wv"], cfg.n_kv_heads)
    if source is None:
        q, k = rope(q, k, positions, cfg.rope_theta)
        out = ops.attention(q, k, v, causal=True, window=cfg.window,
                            chunk=cfg.attn_chunk)
    else:
        out = ops.attention(q, k, v, causal=False, window=None)
    b, s, _, _ = out.shape
    return x + out.reshape(b, s, -1) @ p["wo"], (k, v)


def attention_block_decode(cfg: ModelConfig, p: dict, x: jax.Array,
                           cache: dict, pos: jax.Array,
                           is_cross: bool = False) -> Tuple[jax.Array, dict]:
    """One-token attention; cache: {k: (B,Smax,Hkv,D), v: ...}.

    Sliding-window caches are ring buffers of size ``window``: the write
    slot is pos % Smax and, once full, every slot is a valid key (exactly
    the last ``window`` positions)."""
    b = x.shape[0]
    h = norm(cfg, p.get("norm_w"), x)          # (B, 1, d)
    q = _split_heads(h @ p["wq"], cfg.n_heads)  # (B,1,H,D)
    if is_cross:
        # cross-attention reads the (precomputed) source cache only
        out = ops.attention(q, cache["k"], cache["v"], causal=False)
        return x + out.reshape(b, 1, -1) @ p["wo"], cache
    k_new = _split_heads(h @ p["wk"], cfg.n_kv_heads)
    v_new = _split_heads(h @ p["wv"], cfg.n_kv_heads)
    q, k_new = rope(q, k_new, pos.reshape(b, 1), cfg.rope_theta)
    smax = cache["k"].shape[1]
    slot = pos % smax
    cache_len = jnp.minimum(pos + 1, smax)
    k_cache = _write_at(cache["k"], k_new, slot)
    v_cache = _write_at(cache["v"], v_new, slot)
    out = ops.decode_attention(q[:, 0], k_cache, v_cache, cache_len)
    new_cache = {"k": k_cache, "v": v_cache}
    return x + out.reshape(b, 1, -1) @ p["wo"], new_cache


def _write_at(cache: jax.Array, new: jax.Array, pos: jax.Array) -> jax.Array:
    """Scatter (B,1,H,D) into (B,Smax,H,D) at per-batch position pos.

    A true scatter (not a full-cache select): with donated caches XLA
    updates rows in place instead of rewriting the whole buffer — the
    §Perf decode-path fix."""
    b = cache.shape[0]
    pos = jnp.broadcast_to(jnp.asarray(pos), (b,))
    return cache.at[jnp.arange(b), pos].set(new[:, 0].astype(cache.dtype))


# --------------------------------------------------------------------------
# MLP / MoE
# --------------------------------------------------------------------------

def init_mlp(cfg: ModelConfig, key) -> dict:
    d, f = cfg.d_model, cfg.d_ff
    k1, k2, k3 = jax.random.split(key, 3)
    scale = d ** -0.5
    p = {
        "wi": jax.random.normal(k1, (d, f), cfg.dtype) * scale,
        "wo": jax.random.normal(k3, (f, d), cfg.dtype) * (f ** -0.5),
    }
    if cfg.mlp_variant == "swiglu":
        p["wg"] = jax.random.normal(k2, (d, f), cfg.dtype) * scale
    if cfg.norm == "rms":
        p["norm_w"] = jnp.ones((d,), jnp.float32)
    return p


def mlp_block(cfg: ModelConfig, p: dict, x: jax.Array) -> jax.Array:
    h = norm(cfg, p.get("norm_w"), x)
    up = h @ p["wi"]
    if cfg.mlp_variant == "swiglu":
        up = up * jax.nn.silu(h @ p["wg"])
    elif cfg.mlp_variant == "relu2":
        up = jnp.square(jax.nn.relu(up))       # minitron/nemotron
    else:
        up = jax.nn.gelu(up)                   # musicgen
    return x + up @ p["wo"]


def init_moe(cfg: ModelConfig, key) -> dict:
    d, f, e = cfg.d_model, cfg.d_ff, cfg.moe_experts
    k1, k2, k3, k4 = jax.random.split(key, 4)
    scale = d ** -0.5
    p = {
        "router": jax.random.normal(k1, (d, e), jnp.float32) * scale,
        "wi": jax.random.normal(k2, (e, d, f), cfg.dtype) * scale,
        "wg": jax.random.normal(k3, (e, d, f), cfg.dtype) * scale,
        "wo": jax.random.normal(k4, (e, f, d), cfg.dtype) * (f ** -0.5),
    }
    if cfg.norm == "rms":
        p["norm_w"] = jnp.ones((d,), jnp.float32)
    return p


def moe_block(cfg: ModelConfig, p: dict, x: jax.Array,
              no_drop: bool = False) -> jax.Array:
    """Top-k token-choice MoE with capacity-bounded gather dispatch.

    Tokens are routed to their top-k experts; each expert processes at most
    C = ceil(k * T / E * capacity_factor) tokens (overflow is dropped, the
    standard GShard discipline).  Dispatch/combine use gather/scatter so
    compute is E*C*d*f (active FLOPs), not dense all-experts.
    ``no_drop`` (decode path, where T = batch is tiny) sets C = T so
    single-token steps are capacity-loss-free.

    Long sequences are dispatched in ``cfg.moe_seq_chunks`` chunks with
    per-chunk capacity C/chunks, bounding the token-gather working set
    (the chunked-capacity discipline slightly redistributes drops).
    """
    b, s, d = x.shape
    h = norm(cfg, p.get("norm_w"), x)
    flat = h.reshape(-1, d)                                  # (T, d)
    t = flat.shape[0]
    nc = cfg.moe_seq_chunks if (t > 65536 and not no_drop
                                and t % cfg.moe_seq_chunks == 0) else 1
    parts = []
    for i in range(nc):
        parts.append(_moe_dispatch(cfg, p, flat[i * (t // nc):
                                                (i + 1) * (t // nc)],
                                   no_drop))
    out = jnp.concatenate(parts, axis=0) if nc > 1 else parts[0]
    return x + out.reshape(b, s, d)


def _moe_dispatch(cfg: ModelConfig, p: dict, flat: jax.Array,
                  no_drop: bool) -> jax.Array:
    from ..parallel.hints import shard_hint
    d = flat.shape[-1]
    e, k = cfg.moe_experts, cfg.moe_top_k
    t = flat.shape[0]
    cap = t if no_drop \
        else int(max(1, -(-k * t * cfg.moe_capacity // e)))  # ceil

    logits = (flat @ p["router"].astype(flat.dtype)).astype(jnp.float32)
    gates, eidx = jax.lax.top_k(logits, k)                   # (T, k)
    gates = jax.nn.softmax(gates, axis=-1)

    # position of each (token, slot) within its expert
    onehot = jax.nn.one_hot(eidx, e, dtype=jnp.int32)        # (T, k, E)
    flat_oh = onehot.reshape(t * k, e)
    pos_in_e = jnp.cumsum(flat_oh, axis=0) * flat_oh - 1     # (T*k, E)
    pos = jnp.max(pos_in_e, axis=-1).reshape(t, k)           # (T, k)
    keep = pos < cap

    # scatter token ids into (E, C) slots
    slot_e = eidx.reshape(-1)                                # (T*k,)
    slot_c = jnp.where(keep, pos, cap).reshape(-1)           # overflow -> cap
    tok_id = jnp.broadcast_to(jnp.arange(t)[:, None], (t, k)).reshape(-1)
    slots = jnp.full((e, cap + 1), t, dtype=jnp.int32)       # t = pad token
    slots = slots.at[slot_e, slot_c].set(tok_id)[:, :cap]    # (E, C)

    padded = jnp.concatenate([flat, jnp.zeros((1, d), flat.dtype)], axis=0)
    xin = shard_hint(padded[slots], "moe_in")                # (E, C, d)
    up = shard_hint(jnp.einsum("ecd,edf->ecf", xin, p["wi"]), "moe_hidden")
    gate = jax.nn.silu(jnp.einsum("ecd,edf->ecf", xin, p["wg"]))
    xout = shard_hint(jnp.einsum("ecf,efd->ecd", up * gate, p["wo"]),
                      "moe_in")                              # (E, C, d)

    # combine: apply gates in slot space, then scatter-add back to tokens
    gate_w = jnp.where(keep, gates, 0.0).astype(flat.dtype)  # (T, k)
    gflat = jnp.zeros((e, cap + 1), flat.dtype)
    gflat = gflat.at[slot_e, slot_c].set(gate_w.reshape(-1))[:, :cap]
    out = jnp.zeros((t + 1, d), flat.dtype)
    out = out.at[slots.reshape(-1)].add(
        (xout * gflat[..., None]).reshape(-1, d))
    return out[:t]


# --------------------------------------------------------------------------
# Mamba (S6) block
# --------------------------------------------------------------------------

def init_mamba(cfg: ModelConfig, key) -> dict:
    d, di, n = cfg.d_model, cfg.mamba_d_inner, cfg.mamba_d_state
    kc = cfg.mamba_d_conv
    k1, k2, k3, k4, k5 = jax.random.split(key, 5)
    scale = d ** -0.5
    p = {
        "in_proj": jax.random.normal(k1, (d, 2 * di), cfg.dtype) * scale,
        "conv_w": jax.random.normal(k2, (kc, di), cfg.dtype) * 0.1,
        "x_proj": jax.random.normal(k3, (di, 2 * n + 1), cfg.dtype)
                  * di ** -0.5,
        "dt_bias": jnp.zeros((di,), jnp.float32),
        "A_log": jnp.log(jnp.broadcast_to(
            jnp.arange(1, n + 1, dtype=jnp.float32), (di, n)).copy()),
        "D": jnp.ones((di,), jnp.float32),
        "out_proj": jax.random.normal(k4, (di, d), cfg.dtype) * di ** -0.5,
    }
    if cfg.norm == "rms":
        p["norm_w"] = jnp.ones((d,), jnp.float32)
    return p


def _mamba_inner(cfg: ModelConfig, p: dict, h: jax.Array,
                 conv_state=None, ssm_state=None, single_step=False):
    di, n = cfg.mamba_d_inner, cfg.mamba_d_state
    xz = h @ p["in_proj"]
    xin, z = jnp.split(xz, 2, axis=-1)                      # (B,S,Di)
    if single_step:
        # conv_state: (B, kconv-1, Di) of previous inputs
        win = jnp.concatenate([conv_state, xin], axis=1)    # (B,kc,Di)
        conv = jnp.einsum("bkd,kd->bd", win, p["conv_w"])[:, None]
        new_conv_state = win[:, 1:]
    else:
        kc = cfg.mamba_d_conv
        pad = jnp.zeros(xin.shape[:1] + (kc - 1,) + xin.shape[2:], xin.dtype)
        xpad = jnp.concatenate([pad, xin], axis=1)
        conv = sum(xpad[:, i:i + xin.shape[1]] * p["conv_w"][i]
                   for i in range(kc))
        new_conv_state = xpad[:, xin.shape[1]:]             # last kc-1 inputs
    conv = jax.nn.silu(conv)
    proj = conv @ p["x_proj"]                               # (B,S,2N+1)
    Bm, Cm, dt_raw = proj[..., :n], proj[..., n:2 * n], proj[..., 2 * n:]
    dt = jax.nn.softplus(dt_raw.astype(jnp.float32)
                         + p["dt_bias"][None, None])        # (B,S,1)
    dt = jnp.broadcast_to(dt, conv.shape).astype(jnp.float32)
    A = -jnp.exp(p["A_log"])
    if single_step:
        y, new_ssm = ops.mamba_decode_step(
            conv[:, 0], dt[:, 0], A, Bm[:, 0].astype(jnp.float32),
            Cm[:, 0].astype(jnp.float32), p["D"], ssm_state)
        y = y[:, None]
    else:
        y, new_ssm = ops.mamba_scan(conv, dt, A, Bm.astype(jnp.float32),
                                    Cm.astype(jnp.float32), p["D"], h0=ssm_state)
    y = y * jax.nn.silu(z)
    return y @ p["out_proj"], new_conv_state, new_ssm


def mamba_block(cfg: ModelConfig, p: dict, x: jax.Array):
    """Returns (x_out, {"conv", "ssm"}) — final states for prefill."""
    h = norm(cfg, p.get("norm_w"), x)
    out, conv_state, ssm_state = _mamba_inner(cfg, p, h)
    return x + out, {"conv": conv_state, "ssm": ssm_state}


def mamba_block_decode(cfg: ModelConfig, p: dict, x: jax.Array,
                       cache: dict) -> Tuple[jax.Array, dict]:
    h = norm(cfg, p.get("norm_w"), x)
    out, conv_state, ssm_state = _mamba_inner(
        cfg, p, h, conv_state=cache["conv"], ssm_state=cache["ssm"],
        single_step=True)
    return x + out, {"conv": conv_state, "ssm": ssm_state}


# --------------------------------------------------------------------------
# RWKV6 block (time-mix + channel-mix)
# --------------------------------------------------------------------------

def init_rwkv(cfg: ModelConfig, key) -> dict:
    d, hd = cfg.d_model, cfg.rwkv_head_dim
    h = cfg.rwkv_heads
    ks = jax.random.split(key, 8)
    scale = d ** -0.5
    p = {
        "mix_r": jnp.full((d,), 0.5, jnp.float32),
        "mix_k": jnp.full((d,), 0.5, jnp.float32),
        "mix_v": jnp.full((d,), 0.5, jnp.float32),
        "mix_w": jnp.full((d,), 0.5, jnp.float32),
        "wr": jax.random.normal(ks[0], (d, d), cfg.dtype) * scale,
        "wk": jax.random.normal(ks[1], (d, d), cfg.dtype) * scale,
        "wv": jax.random.normal(ks[2], (d, d), cfg.dtype) * scale,
        "w0": jnp.full((d,), -4.0, jnp.float32),   # decay base
        "w_lora_a": jax.random.normal(ks[3], (d, 64), cfg.dtype) * scale,
        "w_lora_b": jax.random.normal(ks[4], (64, d), cfg.dtype) * 64 ** -0.5,
        "u": jnp.zeros((h, hd), jnp.float32),      # per-head bonus
        "wo": jax.random.normal(ks[5], (d, d), cfg.dtype) * scale,
        "cm_k": jax.random.normal(ks[6], (d, cfg.d_ff), cfg.dtype) * scale,
        "cm_v": jax.random.normal(ks[7], (cfg.d_ff, d), cfg.dtype)
                * cfg.d_ff ** -0.5,
        "cm_mix": jnp.full((d,), 0.5, jnp.float32),
    }
    if cfg.norm == "rms":
        p["norm_w"] = jnp.ones((d,), jnp.float32)
        p["norm_w2"] = jnp.ones((d,), jnp.float32)
    return p


def _token_shift(x: jax.Array, prev: Optional[jax.Array] = None) -> jax.Array:
    """x_{t-1}: shift right by one along seq; prev fills position 0."""
    pad = prev if prev is not None \
        else jnp.zeros_like(x[:, :1])
    return jnp.concatenate([pad, x[:, :-1]], axis=1)


def _rwkv_time_mix(cfg: ModelConfig, p: dict, h: jax.Array,
                   h_prev: jax.Array, state):
    b, s, d = h.shape
    nh, hd = cfg.rwkv_heads, cfg.rwkv_head_dim
    mix = lambda m: h * m + h_prev * (1.0 - m)
    r = (mix(p["mix_r"]).astype(cfg.dtype) @ p["wr"]).reshape(b, s, nh, hd)
    k = (mix(p["mix_k"]).astype(cfg.dtype) @ p["wk"]).reshape(b, s, nh, hd)
    v = (mix(p["mix_v"]).astype(cfg.dtype) @ p["wv"]).reshape(b, s, nh, hd)
    # data-dependent decay (Finch): w = exp(-exp(w0 + lora(x)))
    wx = mix(p["mix_w"]).astype(cfg.dtype)
    w_log = p["w0"] + (jax.nn.tanh(wx @ p["w_lora_a"]) @ p["w_lora_b"]) \
        .astype(jnp.float32)
    w = jnp.exp(-jnp.exp(w_log)).reshape(b, s, nh, hd)
    if s == 1 and state is not None:
        out, new_state = ops.rwkv6_decode_step(
            r[:, 0], k[:, 0], v[:, 0], w[:, 0], p["u"], state)
        out = out[:, None]
    else:
        out, new_state = ops.rwkv6_scan(r, k, v, w, p["u"], s0=state)
    return out.reshape(b, s, d) @ p["wo"], new_state


def _rwkv_channel_mix(cfg: ModelConfig, p: dict, h: jax.Array,
                      h_prev: jax.Array):
    mixed = h * p["cm_mix"] + h_prev * (1.0 - p["cm_mix"])
    k = jnp.square(jax.nn.relu(mixed.astype(cfg.dtype) @ p["cm_k"]))
    return k @ p["cm_v"]


def rwkv_block(cfg: ModelConfig, p: dict, x: jax.Array):
    """Returns (x_out, {"wkv","shift_tm","shift_cm"}) for prefill."""
    h = norm(cfg, p.get("norm_w"), x)
    tm, wkv_state = _rwkv_time_mix(cfg, p, h, _token_shift(h), None)
    x = x + tm
    h2 = norm(cfg, p.get("norm_w2", p.get("norm_w")), x)
    out = x + _rwkv_channel_mix(cfg, p, h2, _token_shift(h2))
    return out, {"wkv": wkv_state, "shift_tm": h[:, -1:],
                 "shift_cm": h2[:, -1:]}


def rwkv_block_decode(cfg: ModelConfig, p: dict, x: jax.Array,
                      cache: dict) -> Tuple[jax.Array, dict]:
    h = norm(cfg, p.get("norm_w"), x)
    tm, wkv_state = _rwkv_time_mix(cfg, p, h, cache["shift_tm"],
                                   cache["wkv"])
    x = x + tm
    h2 = norm(cfg, p.get("norm_w2", p.get("norm_w")), x)
    out = x + _rwkv_channel_mix(cfg, p, h2, cache["shift_cm"])
    return out, {"wkv": wkv_state, "shift_tm": h, "shift_cm": h2}
