"""Sharding rules: parameter/batch/cache PartitionSpecs per architecture.

Profiles (cfg.sharding_profile):
  "tp"     : Megatron-style tensor parallelism over the "model" axis —
             attention heads, MLP ff, vocab; KV-head weights replicated
             when n_kv < tp (KV replication trick); MoE experts sharded
             over "model" when divisible (EP) else TP-within-expert.
  "hybrid" : small models whose head counts don't divide the model axis:
             MLP/vocab TP only, attention replicated (the honest baseline
             the §Perf log improves).
  "fsdp_dp": no tensor parallelism — the batch shards over BOTH mesh axes
             and parameters/optimizer fully shard over all devices (pure
             ZeRO-3 data parallelism).  The beyond-paper §Perf change for
             collective-bound training cells: per-layer weight all-gathers
             replace the (much larger) sequence-parallel activation
             gathers.

Data parallelism is over ("pod", "data"); ZeRO-1 shards optimizer moments
over the data axes on the first divisible replicated dimension.  Sequence
parallelism (residual seq-sharded over "model" between blocks) is applied
through the shard-hint hook to keep scan-carry activations within HBM.

Every rule degrades to replication when a dimension is indivisible — the
dry-run proves what actually fits/compiles.
"""
from __future__ import annotations

import re
from typing import Any, Dict, Optional, Tuple

import jax
import jax.numpy as jnp
import numpy as np
from jax.sharding import Mesh, NamedSharding
from jax.sharding import PartitionSpec as P

from ..models.blocks import ModelConfig


# --------------------------------------------------------------------------
# mesh helpers
# --------------------------------------------------------------------------

def data_axes(mesh: Mesh) -> Tuple[str, ...]:
    return tuple(a for a in ("pod", "data") if a in mesh.axis_names)


def axis_size(mesh: Mesh, axes) -> int:
    if axes is None:
        return 1
    if isinstance(axes, str):
        axes = (axes,)
    n = 1
    for a in axes:
        n *= mesh.shape[a]
    return n


def _div(n: int, mesh: Mesh, axes) -> bool:
    return n % axis_size(mesh, axes) == 0


# --------------------------------------------------------------------------
# parameter specs
# --------------------------------------------------------------------------

def _param_rule(cfg: ModelConfig, mesh: Mesh, path: str,
                shape: Tuple[int, ...]) -> P:
    tp = axis_size(mesh, "model")
    hybrid = cfg.sharding_profile == "hybrid"
    pure_dp = cfg.sharding_profile == "fsdp_dp"
    heads_ok = cfg.n_heads % tp == 0 and not hybrid and not pure_dp
    kv_ok = cfg.n_kv_heads % tp == 0 and not hybrid and not pure_dp

    def m(dim: int) -> Optional[str]:
        if pure_dp:
            return None
        return "model" if dim % tp == 0 else None

    if path.endswith("embed"):
        return P(m(shape[0]), None)
    if path.endswith("unembed"):
        return P(None, m(shape[1]))
    if "norm" in path or "mix" in path or path.endswith("router") \
            or path.endswith("dt_bias"):
        return P(*([None] * len(shape)))

    # --- attention core ---
    if re.search(r"core.*\bwq\b", path):
        return P(None, m(shape[1])) if heads_ok else P(None, None)
    if re.search(r"core.*\bwk\b", path) or re.search(r"core.*\bwv\b", path):
        # rwkv wr/wk/wv are (d, d) head-aligned; attention wk/wv are KV
        if cfg.pattern[0].kind == "rwkv" and shape[0] == shape[1]:
            return P(None, m(shape[1]))
        return P(None, m(shape[1])) if kv_ok else P(None, None)
    if re.search(r"core.*\bwo\b", path):
        if hybrid:
            return P(None, None)
        return P(m(shape[0]), None)
    if re.search(r"core.*\bwr\b", path):      # rwkv receptance
        return P(None, m(shape[1]))
    if path.endswith("w_lora_a"):
        return P(None, None)
    if path.endswith("w_lora_b"):
        return P(None, m(shape[1]))
    if path.endswith("u"):                    # rwkv bonus (H, hd)
        return P(m(shape[0]), None)
    if path.endswith("cm_k"):
        return P(None, m(shape[1]))
    if path.endswith("cm_v"):
        return P(m(shape[0]), None)

    # --- mamba ---
    if path.endswith("in_proj"):
        return P(None, m(shape[1]))
    if path.endswith("conv_w"):
        return P(None, m(shape[1]))
    if path.endswith("x_proj"):
        return P(m(shape[0]), None)
    if path.endswith("A_log"):
        return P(m(shape[0]), None)
    if path.endswith("D"):
        return P(m(shape[0]))
    if path.endswith("out_proj"):
        return P(m(shape[0]), None)

    # --- mlp / moe ---
    if path.endswith("wi") or path.endswith("wg"):
        if len(shape) == 3:  # moe (E, d, f)
            if m(shape[0]) is not None:
                return P("model", None, None)          # EP
            return P(None, None, m(shape[2]))          # TP-within-expert
        return P(None, m(shape[1]))
    if path.endswith("wo"):
        if len(shape) == 3:  # moe (E, f, d)
            if m(shape[0]) is not None:
                return P("model", None, None)
            return P(None, m(shape[1]), None)
        return P(m(shape[0]), None)

    return P(*([None] * len(shape)))


def _path_str(path) -> str:
    parts = []
    for k in path:
        if hasattr(k, "key"):
            parts.append(str(k.key))
        elif hasattr(k, "idx"):
            parts.append(str(k.idx))
        else:
            parts.append(str(k))
    return "/".join(parts)


def _fsdp_extend(mesh: Mesh, spec: P, shape: Tuple[int, ...],
                 min_elems: int = 1 << 16, all_axes: bool = False) -> P:
    """ZeRO-3/FSDP: additionally shard the first replicated, divisible dim
    of large parameters over the data axes (or every mesh axis for the
    fsdp_dp profile).  Inside a layer scan, GSPMD all-gathers only the
    current slice at its point of use (the standard MaxText
    fsdp-with-scan pattern)."""
    if int(np.prod(shape)) < min_elems:
        return spec
    daxes = tuple(mesh.axis_names) if all_axes else data_axes(mesh)
    dsize = axis_size(mesh, daxes)
    dims = list(spec) + [None] * (len(shape) - len(spec))
    for i, (d, s) in enumerate(zip(dims, shape)):
        if d is None and s % dsize == 0 and s >= dsize:
            dims[i] = daxes if len(daxes) > 1 else daxes[0]
            return P(*dims)
    return spec


def param_pspecs(cfg: ModelConfig, mesh: Mesh, specs) -> Any:
    """PartitionSpec pytree matching param_specs(cfg).  Leaves under
    'blocks' carry a leading repeats dim -> specs shift right by one."""

    pure_dp = cfg.sharding_profile == "fsdp_dp"
    want_fsdp = cfg.fsdp or pure_dp

    def rule(path, leaf):
        ps = _path_str(path)
        if "blocks" in ps:
            inner = _param_rule(cfg, mesh, ps, tuple(leaf.shape[1:]))
            if want_fsdp:
                inner = _fsdp_extend(mesh, inner, tuple(leaf.shape[1:]),
                                     all_axes=pure_dp)
            return P(None, *inner)
        spec = _param_rule(cfg, mesh, ps, tuple(leaf.shape))
        if want_fsdp:
            spec = _fsdp_extend(mesh, spec, tuple(leaf.shape),
                                all_axes=pure_dp)
        return spec

    return jax.tree_util.tree_map_with_path(rule, specs)


def named(mesh: Mesh, pspec_tree) -> Any:
    return jax.tree.map(lambda p: NamedSharding(mesh, p), pspec_tree,
                        is_leaf=lambda x: isinstance(x, P))


# --------------------------------------------------------------------------
# ZeRO-1 optimizer-state specs
# --------------------------------------------------------------------------

def zero1_pspecs(mesh: Mesh, specs, pspecs) -> Any:
    """Moments: param sharding + the first replicated, divisible dim
    additionally sharded over the data axes (ZeRO-1)."""
    daxes = data_axes(mesh)
    dsize = axis_size(mesh, daxes)

    def rule(leaf, ps):
        dims = list(ps) + [None] * (len(leaf.shape) - len(ps))
        used = set()
        for d in dims:
            for a in ((d,) if isinstance(d, str) else (d or ())):
                used.add(a)
        if used & set(daxes):
            return P(*dims)  # FSDP already shards over the data axes
        for i, (d, s) in enumerate(zip(dims, leaf.shape)):
            if d is None and s % dsize == 0 and s >= dsize:
                dims[i] = daxes if len(daxes) > 1 else daxes[0]
                break
        return P(*dims)

    return jax.tree.map(rule, specs, pspecs,
                        is_leaf=lambda x: isinstance(x, P))


# --------------------------------------------------------------------------
# batch / cache / activation specs
# --------------------------------------------------------------------------

def batch_pspec(mesh: Mesh, global_batch: int,
                profile: str = "tp") -> P:
    """Batch dim over ("pod","data") when divisible, else "data", else
    replicated (tiny batches).  The fsdp_dp profile spreads the batch over
    every mesh axis it divides."""
    if profile == "fsdp_dp":
        for axes in (tuple(mesh.axis_names),
                     tuple(a for a in mesh.axis_names if a != "pod"),
                     data_axes(mesh)):
            if axes and _div(global_batch, mesh, axes):
                return P(axes if len(axes) > 1 else axes[0])
    daxes = data_axes(mesh)
    if _div(global_batch, mesh, daxes):
        return P(daxes if len(daxes) > 1 else daxes[0])
    if "data" in mesh.axis_names and global_batch % mesh.shape["data"] == 0:
        return P("data")
    return P(None)


def input_pspecs(cfg: ModelConfig, mesh: Mesh, kind: str,
                 global_batch: int) -> Dict[str, P]:
    b = batch_pspec(mesh, global_batch, cfg.sharding_profile)
    bax = b[0]
    toks = P(bax, None) if cfg.input_mode == "tokens" \
        else P(bax, None, None)
    out = {"inputs": toks, "labels": P(bax, None)}
    if any(sp.kind == "cross" for sp in cfg.pattern):
        out["source"] = P(bax, None, None)
    if kind == "decode":
        out["token"] = P(bax) if cfg.input_mode == "tokens" \
            else P(bax, None)
        out["pos"] = P(bax)
    return out


def cache_pspecs(cfg: ModelConfig, mesh: Mesh, cache_specs,
                 global_batch: int) -> Any:
    """KV caches (R, B, S, H, D): batch over data axes when divisible;
    KV heads over "model" when divisible, else the sequence dim.
    Recurrent states: heads/d_inner over "model" when divisible."""
    tp = mesh.shape.get("model", 1)
    if cfg.sharding_profile == "fsdp_dp":
        tp = 10 ** 9  # nothing divides: no model-axis use in caches
    bspec = batch_pspec(mesh, global_batch, cfg.sharding_profile)
    bax = bspec[0]

    def rule(path, leaf):
        ps = _path_str(path)
        leaf_name = ps.rsplit("/", 1)[-1]
        shp = leaf.shape
        if leaf_name in ("k", "v"):                # (R, B, S, H, D)
            h_ax = "model" if shp[3] % tp == 0 else None
            s_ax = "model" if (h_ax is None and shp[2] % tp == 0) else None
            bx = bax if (bax and shp[1] % axis_size(mesh, bax) == 0) else None
            return P(None, bx, s_ax, h_ax, None)
        if ps.endswith("ssm"):                     # (R, B, Di, N)
            bx = bax if (bax and shp[1] % axis_size(mesh, bax) == 0) else None
            return P(None, bx, "model" if shp[2] % tp == 0 else None, None)
        if ps.endswith("conv"):                    # (R, B, kc-1, Di)
            bx = bax if (bax and shp[1] % axis_size(mesh, bax) == 0) else None
            return P(None, bx, None, "model" if shp[3] % tp == 0 else None)
        if ps.endswith("wkv"):                     # (R, B, H, D, D)
            bx = bax if (bax and shp[1] % axis_size(mesh, bax) == 0) else None
            return P(None, bx, "model" if shp[2] % tp == 0 else None,
                     None, None)
        if "shift" in ps:                          # (R, B, 1, d)
            bx = bax if (bax and shp[1] % axis_size(mesh, bax) == 0) else None
            return P(None, bx, None, None)
        return P(*([None] * len(shp)))

    return jax.tree_util.tree_map_with_path(rule, cache_specs)


def shard_factor(mesh: Mesh, spec: P) -> int:
    """Number of shards a PartitionSpec splits a tensor into."""
    n = 1
    for entry in spec:
        if entry is None:
            continue
        for a in ((entry,) if isinstance(entry, str) else entry):
            n *= mesh.shape[a]
    return n


def local_bytes(mesh: Mesh, specs, pspecs) -> float:
    """Per-device bytes of a spec tree under a PartitionSpec tree."""
    total = 0.0
    for leaf, ps in zip(jax.tree.leaves(specs),
                        jax.tree.leaves(pspecs,
                                        is_leaf=lambda x: isinstance(x, P))):
        total += leaf.size * leaf.dtype.itemsize / shard_factor(mesh, ps)
    return total


def make_hint_hook(cfg: ModelConfig, mesh: Mesh, global_batch: int,
                   seq_len: int):
    """Shard-hint hook: sequence parallelism on the residual stream (seq
    over "model") + batch sharding — the memory-critical constraint for
    deep scans."""
    tp = mesh.shape.get("model", 1)
    pure_dp = cfg.sharding_profile == "fsdp_dp"
    bspec = batch_pspec(mesh, global_batch, cfg.sharding_profile)
    bax = bspec[0]

    def hook(x, kind):
        if kind == "moe_in" and x.ndim == 3:     # (E, C, d)
            e_ax = "model" if (not pure_dp and x.shape[0] % tp == 0) \
                else None
            c_ax = None
            if bax and x.shape[1] % axis_size(mesh, bax) == 0:
                c_ax = bax
            return jax.lax.with_sharding_constraint(
                x, NamedSharding(mesh, P(e_ax, c_ax, None)))
        if kind == "moe_hidden" and x.ndim == 3:  # (E, C, f)
            e_ax = "model" if (not pure_dp and x.shape[0] % tp == 0) \
                else None
            f_ax = "model" if (not pure_dp and e_ax is None
                               and x.shape[2] % tp == 0) else None
            c_ax = None
            if bax and x.shape[1] % axis_size(mesh, bax) == 0:
                c_ax = bax
            return jax.lax.with_sharding_constraint(
                x, NamedSharding(mesh, P(e_ax, c_ax, f_ax)))
        if kind == "decode_scores" and x.ndim == 4:
            # (B, Hkv, G, S): keep scores sequence-sharded so the decode
            # softmax runs as sharded partials + a tiny all-reduce instead
            # of gathering the KV cache (distributed flash-decode)
            bx = bax if (bax and x.shape[0] % axis_size(mesh, bax) == 0) \
                else None
            s_ax = "model" if (not pure_dp and x.shape[3] % tp == 0
                               and x.shape[3] >= tp) else None
            return jax.lax.with_sharding_constraint(
                x, NamedSharding(mesh, P(bx, None, None, s_ax)))
        if kind == "residual" and x.ndim == 3:
            s_ax = "model" if (not pure_dp and x.shape[1] % tp == 0
                               and x.shape[1] >= tp) else None
            bx = bax if (bax and x.shape[0] % axis_size(mesh, bax) == 0) \
                else None
            return jax.lax.with_sharding_constraint(
                x, NamedSharding(mesh, P(bx, s_ax, None)))
        if kind == "pre_loss" and x.ndim == 3:
            bx = bax if (bax and x.shape[0] % axis_size(mesh, bax) == 0) \
                else None
            return jax.lax.with_sharding_constraint(
                x, NamedSharding(mesh, P(bx, None, None)))
        if kind == "logits" and x.ndim == 3:
            bx = bax if (bax and x.shape[0] % axis_size(mesh, bax) == 0) \
                else None
            v_ax = "model" if (not pure_dp and x.shape[2] % tp == 0) \
                else None
            return jax.lax.with_sharding_constraint(
                x, NamedSharding(mesh, P(bx, None, v_ax)))
        return x

    return hook
