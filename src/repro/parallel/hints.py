"""Sharding hints: the model code stays mesh-agnostic and calls
``shard_hint(x, kind)`` at strategic points; the launch layer installs a
hook that applies ``with_sharding_constraint`` with the profile's
NamedSharding for that kind (or leaves x untouched on a single device).

Kinds currently emitted:
  residual   : (B, S, d) the inter-block residual stream (SP target)
  logits     : (B, S, V) pre-loss logits
"""
from __future__ import annotations

from typing import Callable, Optional

import jax

_HOOK: Optional[Callable] = None


def set_hook(fn: Optional[Callable]) -> None:
    global _HOOK
    _HOOK = fn


def shard_hint(x: jax.Array, kind: str) -> jax.Array:
    if _HOOK is None:
        return x
    return _HOOK(x, kind)
