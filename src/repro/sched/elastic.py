"""Elastic scaling: re-shard a training state onto a different mesh.

When nodes join or leave, the framework rebuilds the mesh and re-places the
(checkpointed) state under the new sharding rules.  Because checkpoints are
stored as full logical arrays (checkpointer.py) and sharding rules are pure
functions of (config, mesh), rescaling is: save -> new mesh -> restore with
the new NamedShardings -> recompile steps.  ``rescale`` packages that."""
from __future__ import annotations

from typing import Any, Tuple

import jax

from ..models.blocks import ModelConfig
from ..parallel import sharding as shd
from . import checkpointer


def state_shardings(cfg: ModelConfig, mesh, state_specs) -> Any:
    """NamedShardings for a {params, opt} training state on ``mesh``."""
    from jax.sharding import PartitionSpec as P
    p_ps = shd.param_pspecs(cfg, mesh, state_specs["params"])
    out = {"params": p_ps}
    if "opt" in state_specs:
        out["opt"] = shd.zero1_pspecs(
            mesh, state_specs["opt"],
            {"m": p_ps, "v": p_ps, "step": P()})
    return shd.named(mesh, out)


def rescale(cfg: ModelConfig, ckpt_dir: str, state_like: Any,
            new_mesh) -> Tuple[Any, Any]:
    """Restore the newest checkpoint re-sharded for ``new_mesh``.

    Returns (state, shardings).  The caller re-jits its step functions
    with the returned shardings (compilation is mesh-specific)."""
    shards = state_shardings(cfg, new_mesh, jax.eval_shape(
        lambda: state_like) if not isinstance(state_like, dict)
        else jax.tree.map(
            lambda leaf: jax.ShapeDtypeStruct(leaf.shape, leaf.dtype),
            state_like))
    state = checkpointer.restore(ckpt_dir, state_like, shardings=shards)
    return state, shards
