"""Elastic capacity management: best-effort degradation under overload,
and mesh re-scaling for training state.

Two faces of the same idea — capacity is not fixed, so the platform
degrades gracefully instead of falling over:

  * **scheduling** (DESIGN.md §10): :class:`ShedPolicy` +
    :func:`plan_shedding` form the overload degradation ladder.  When a
    device's *total* admitted utilization (RT + best-effort) crosses
    ``shed_at``, best-effort jobs are evicted — lowest tier first — to
    bring the device back under the bound, so an RT arrival that fits
    residual RT capacity is admitted with the device actually able to
    serve it, and best-effort work is *shed* (resumable from its
    checkpointed carry) rather than silently starved.  Resumption is
    hysteretic: a shed job only comes back when total utilization with
    it re-included stays under ``resume_at < shed_at``, so the ladder
    does not oscillate at the boundary.  Best-effort tasks never appear
    in any RTA (they are provably non-interfering at analysis level) —
    shedding is a *runtime* capacity decision layered under the
    analytical admission gate, never a substitute for it.  For the
    same reason, shedding a best-effort job leaves the admission
    controller's warm-start cache intact (DESIGN.md §11): BE tasks
    never enter the RT recurrences, so evicting one changes no fixed
    point — only an *RT* removal invalidates the cached bounds.

  * **training**: when nodes join or leave, the framework rebuilds the
    mesh and re-places the (checkpointed) state under the new sharding
    rules.  Because checkpoints are stored as full logical arrays
    (checkpointer.py) and sharding rules are pure functions of
    (config, mesh), rescaling is: save -> new mesh -> restore with the
    new NamedShardings -> recompile steps.  ``rescale`` packages that.
"""
from __future__ import annotations

from dataclasses import dataclass
from typing import TYPE_CHECKING, Any, Iterable, List, Tuple

import jax

from ..models.blocks import ModelConfig
from ..parallel import sharding as shd
from . import checkpointer

if TYPE_CHECKING:  # pragma: no cover
    from .admission import JobProfile


# --------------------------------------------------------------------------
# scheduling face: the overload degradation ladder (DESIGN.md §10)
# --------------------------------------------------------------------------

@dataclass(frozen=True)
class ShedPolicy:
    """Overload thresholds on a device's total admitted utilization
    (RT + best-effort GPU demand, Σ (G^m+G^e)/T per profile).

    ``shed_at``: shedding starts when total utilization would exceed
    this after the arriving job is admitted.  ``resume_at``: a shed job
    is re-admitted only while total utilization with it included stays
    at or under this (hysteresis — must be < ``shed_at``)."""
    shed_at: float = 1.0
    resume_at: float = 0.8

    def __post_init__(self):
        if not (0.0 < self.resume_at < self.shed_at):
            raise ValueError(
                f"need 0 < resume_at < shed_at, got resume_at="
                f"{self.resume_at:g}, shed_at={self.shed_at:g}")


def profile_utilization(prof: "JobProfile") -> float:
    """One profile's device utilization: Σ (G^m + G^e) / T."""
    return sum(m + e for m, e in prof.device_segments_ms) / prof.period_ms


def shed_order(profs: Iterable["JobProfile"]) -> List["JobProfile"]:
    """Victim order of the degradation ladder: best-effort only, lowest
    tier (priority) first, then largest demand first — each rung frees
    the most capacity from the least valuable work."""
    return sorted((p for p in profs if p.best_effort),
                  key=lambda p: (p.priority, -profile_utilization(p),
                                 p.name))


def plan_shedding(profs: Iterable["JobProfile"], shed_at: float
                  ) -> List["JobProfile"]:
    """The victims to evict so Σ utilization over ``profs`` drops to
    ``shed_at`` or below — fewest rungs first (the ladder stops as soon
    as the device fits).  Returns [] when the device already fits, and
    every best-effort profile when even that cannot fit (RT demand
    alone exceeds the bound — shedding has done all it can; the RT
    admission gate is the authority on whether that is acceptable)."""
    profs = list(profs)
    total = sum(profile_utilization(p) for p in profs)
    victims: List["JobProfile"] = []
    for p in shed_order(profs):
        if total <= shed_at + 1e-9:
            break
        victims.append(p)
        total -= profile_utilization(p)
    return victims


def can_resume(prof: "JobProfile", live: Iterable["JobProfile"],
               resume_at: float) -> bool:
    """Hysteretic re-admission check for one shed job against the
    currently admitted profiles on its device."""
    total = sum(profile_utilization(p) for p in live)
    return total + profile_utilization(prof) <= resume_at + 1e-9


def state_shardings(cfg: ModelConfig, mesh, state_specs) -> Any:
    """NamedShardings for a {params, opt} training state on ``mesh``."""
    from jax.sharding import PartitionSpec as P
    p_ps = shd.param_pspecs(cfg, mesh, state_specs["params"])
    out = {"params": p_ps}
    if "opt" in state_specs:
        out["opt"] = shd.zero1_pspecs(
            mesh, state_specs["opt"],
            {"m": p_ps, "v": p_ps, "step": P()})
    return shd.named(mesh, out)


def rescale(cfg: ModelConfig, ckpt_dir: str, state_like: Any,
            new_mesh) -> Tuple[Any, Any]:
    """Restore the newest checkpoint re-sharded for ``new_mesh``.

    Returns (state, shardings).  The caller re-jits its step functions
    with the returned shardings (compilation is mesh-specific)."""
    shards = state_shardings(cfg, new_mesh, jax.eval_shape(
        lambda: state_like) if not isinstance(state_like, dict)
        else jax.tree.map(
            lambda leaf: jax.ShapeDtypeStruct(leaf.shape, leaf.dtype),
            state_like))
    state = checkpointer.restore(ckpt_dir, state_like, shardings=shards)
    return state, shards
