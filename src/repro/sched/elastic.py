"""Elastic capacity management: best-effort degradation under overload,
and mesh re-scaling for training state.

Two faces of the same idea — capacity is not fixed, so the platform
degrades gracefully instead of falling over:

  * **scheduling** (DESIGN.md §10): :class:`ShedPolicy` +
    :func:`plan_shedding` form the overload degradation ladder.  When a
    device's *total* admitted utilization (RT + best-effort) crosses
    ``shed_at``, best-effort jobs are evicted — lowest tier first — to
    bring the device back under the bound, so an RT arrival that fits
    residual RT capacity is admitted with the device actually able to
    serve it, and best-effort work is *shed* (resumable from its
    checkpointed carry) rather than silently starved.  Resumption is
    hysteretic: a shed job only comes back when total utilization with
    it re-included stays under ``resume_at < shed_at``, so the ladder
    does not oscillate at the boundary.  Best-effort tasks never appear
    in any RTA (they are provably non-interfering at analysis level) —
    shedding is a *runtime* capacity decision layered under the
    analytical admission gate, never a substitute for it.  For the
    same reason, shedding a best-effort job leaves the admission
    controller's warm-start cache intact (DESIGN.md §11): BE tasks
    never enter the RT recurrences, so evicting one changes no fixed
    point — only an *RT* removal invalidates the cached bounds.

  * **training**: when nodes join or leave, the framework rebuilds the
    mesh and re-places the (checkpointed) state under the new sharding
    rules.  Because checkpoints are stored as full logical arrays
    (checkpointer.py) and sharding rules are pure functions of
    (config, mesh), rescaling is: save -> new mesh -> restore with the
    new NamedShardings -> recompile steps.  ``rescale`` packages that.
"""
from __future__ import annotations

from dataclasses import dataclass
from typing import (TYPE_CHECKING, Any, Dict, Iterable, List, Mapping,
                    Optional, Tuple)

import jax

from ..models.blocks import ModelConfig
from ..parallel import sharding as shd
from . import checkpointer

if TYPE_CHECKING:  # pragma: no cover
    from .admission import JobProfile


# --------------------------------------------------------------------------
# scheduling face: the overload degradation ladder (DESIGN.md §10)
# --------------------------------------------------------------------------

@dataclass(frozen=True)
class ShedPolicy:
    """Overload thresholds on a device's total admitted utilization
    (RT + best-effort GPU demand, Σ (G^m+G^e)/T per profile).

    ``shed_at``: shedding starts when total utilization would exceed
    this after the arriving job is admitted.  ``resume_at``: a shed job
    is re-admitted only while total utilization with it included stays
    at or under this (hysteresis — must be < ``shed_at``).

    ``tier_budgets`` (optional) refines the ladder per criticality
    tier: ``{tier: budget}`` caps the *best-effort* utilization each
    tier may hold on one device, enforced before the global threshold
    — a runaway low tier is trimmed to its budget even while the
    device as a whole still fits, so a burst of tier-0 batch work
    cannot crowd out tier-1 background jobs the way a single global
    threshold allows.  Tiers without an entry are uncapped below the
    global thresholds.  RT demand is never budgeted here: the
    analytical admission gate (headroom + RTA) is the authority on RT
    capacity."""
    shed_at: float = 1.0
    resume_at: float = 0.8
    tier_budgets: Optional[Mapping[int, float]] = None

    def __post_init__(self):
        if not (0.0 < self.resume_at < self.shed_at):
            raise ValueError(
                f"need 0 < resume_at < shed_at, got resume_at="
                f"{self.resume_at:g}, shed_at={self.shed_at:g}")
        if self.tier_budgets is not None:
            budgets = {int(t): float(b)
                       for t, b in dict(self.tier_budgets).items()}
            for t, b in budgets.items():
                if not (0.0 < b):
                    raise ValueError(f"tier {t} budget must be > 0, "
                                     f"got {b:g}")
            object.__setattr__(self, "tier_budgets", budgets)

    def budget_for(self, tier: int) -> Optional[float]:
        """The best-effort utilization cap of ``tier`` on one device,
        or None when the tier is uncapped."""
        if self.tier_budgets is None:
            return None
        return self.tier_budgets.get(int(tier))


def profile_utilization(prof: "JobProfile") -> float:
    """One profile's device utilization: Σ (G^m + G^e) / T."""
    return sum(m + e for m, e in prof.device_segments_ms) / prof.period_ms


def tier_of(prof: "JobProfile") -> int:
    """A profile's criticality tier (0 for profiles predating the tier
    field, e.g. journaled before it existed)."""
    return int(getattr(prof, "tier", 0) or 0)


def tier_utilization(profs: Iterable["JobProfile"],
                     best_effort_only: bool = True
                     ) -> Dict[int, float]:
    """Per-tier Σ utilization over ``profs`` — by default best-effort
    demand only (the quantity the tier budgets cap)."""
    out: Dict[int, float] = {}
    for p in profs:
        if best_effort_only and not p.best_effort:
            continue
        t = tier_of(p)
        out[t] = out.get(t, 0.0) + profile_utilization(p)
    return out


def shed_order(profs: Iterable["JobProfile"]) -> List["JobProfile"]:
    """Victim order of the degradation ladder: best-effort only, lowest
    tier first, then largest demand first — each rung frees the most
    capacity from the least valuable work.  (Priority and name are
    deterministic later tie-breaks only.)"""
    return sorted((p for p in profs if p.best_effort),
                  key=lambda p: (tier_of(p), -profile_utilization(p),
                                 p.priority, p.name))


def plan_shedding(profs: Iterable["JobProfile"], shed_at: float,
                  tier_budgets: Optional[Mapping[int, float]] = None
                  ) -> List["JobProfile"]:
    """The victims to evict so the device fits again — fewest rungs
    first (the ladder stops as soon as the device fits).

    Two stacked conditions, both on one device's admitted profiles:

      1. **per-tier budgets** (when given): each budgeted tier's
         best-effort utilization is trimmed to its budget, largest
         victim first within the tier;
      2. **global threshold**: Σ utilization over what remains must
         drop to ``shed_at`` or below.

    Returns [] when the device already fits, and every best-effort
    profile when even that cannot fit (RT demand alone exceeds the
    bound — shedding has done all it can; the RT admission gate is the
    authority on whether that is acceptable)."""
    profs = list(profs)
    victims: List["JobProfile"] = []
    if tier_budgets:
        per_tier = tier_utilization(profs)
        for p in shed_order(profs):
            t = tier_of(p)
            budget = dict(tier_budgets).get(t)
            if budget is None or per_tier.get(t, 0.0) <= budget + 1e-9:
                continue
            victims.append(p)
            per_tier[t] -= profile_utilization(p)
        profs = [p for p in profs if p not in victims]
    total = sum(profile_utilization(p) for p in profs)
    for p in shed_order(profs):
        if total <= shed_at + 1e-9:
            break
        victims.append(p)
        total -= profile_utilization(p)
    return victims


def can_resume(prof: "JobProfile", live: Iterable["JobProfile"],
               resume_at: float,
               tier_budgets: Optional[Mapping[int, float]] = None
               ) -> bool:
    """Hysteretic re-admission check for one shed job against the
    currently admitted profiles on its device: total utilization with
    the candidate re-included must stay at or under ``resume_at``, and
    (when the candidate's tier is budgeted) the tier's best-effort
    utilization with it re-included must stay within its budget — or
    the resume would immediately re-arm the ladder that shed it."""
    live = list(live)
    u = profile_utilization(prof)
    total = sum(profile_utilization(p) for p in live)
    if total + u > resume_at + 1e-9:
        return False
    if tier_budgets and prof.best_effort:
        budget = dict(tier_budgets).get(tier_of(prof))
        if budget is not None:
            held = tier_utilization(live).get(tier_of(prof), 0.0)
            if held + u > budget + 1e-9:
                return False
    return True


def state_shardings(cfg: ModelConfig, mesh, state_specs) -> Any:
    """NamedShardings for a {params, opt} training state on ``mesh``."""
    from jax.sharding import PartitionSpec as P
    p_ps = shd.param_pspecs(cfg, mesh, state_specs["params"])
    out = {"params": p_ps}
    if "opt" in state_specs:
        out["opt"] = shd.zero1_pspecs(
            mesh, state_specs["opt"],
            {"m": p_ps, "v": p_ps, "step": P()})
    return shd.named(mesh, out)


def rescale(cfg: ModelConfig, ckpt_dir: str, state_like: Any,
            new_mesh) -> Tuple[Any, Any]:
    """Restore the newest checkpoint re-sharded for ``new_mesh``.

    Returns (state, shardings).  The caller re-jits its step functions
    with the returned shardings (compilation is mesh-specific)."""
    shards = state_shardings(cfg, new_mesh, jax.eval_shape(
        lambda: state_like) if not isinstance(state_like, dict)
        else jax.tree.map(
            lambda leaf: jax.ShapeDtypeStruct(leaf.shape, leaf.dtype),
            state_like))
    state = checkpointer.restore(ckpt_dir, state_like, shardings=shards)
    return state, shards
