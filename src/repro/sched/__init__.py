from .admission import AdmissionController, JobProfile
from .checkpointer import (AsyncCheckpointer, latest_carry, latest_step,
                           restore, save, save_carry)
from .executor import DeviceExecutor
from .fault import FaultTolerantLoop, Heartbeat, StallError, with_retry
from .job import RTJob

__all__ = ["AdmissionController", "JobProfile", "AsyncCheckpointer",
           "latest_step", "restore", "save", "save_carry", "latest_carry",
           "DeviceExecutor", "FaultTolerantLoop", "Heartbeat", "StallError",
           "with_retry", "RTJob"]
