from .admission import (AdmissionController, AdmissionDecision, JobProfile,
                        RecoveryConformanceError, decisions_match)
from .checkpointer import (AsyncCheckpointer, latest_carry, latest_step,
                           restore, save, save_carry)
from .cluster import ClusterExecutor
from .elastic import ShedPolicy
from .executor import DeviceExecutor, ExecutorTrace, TraceEvent
from .fault import (DeviceFailedError, DeviceHealth, FaultContained,
                    FaultTolerantLoop, HealthConfig, Heartbeat, JobEvicted,
                    StallError, with_retry)
from .faultinject import FaultInjector, FaultSpec, InjectedFault
from .job import RTJob
from .store import CompactionPolicy, JobRecord, JobStore, StoreState
from .workloads import register_workload

__all__ = ["AdmissionController", "AdmissionDecision", "JobProfile",
           "RecoveryConformanceError", "decisions_match",
           "AsyncCheckpointer", "latest_step", "restore", "save",
           "save_carry", "latest_carry", "SOCKET_ENV", "SchedClient",
           "connect", "ClusterExecutor", "DeviceExecutor", "ExecutorTrace",
           "TraceEvent", "FaultTolerantLoop", "Heartbeat", "StallError",
           "with_retry", "RTJob", "JobRecord", "JobStore", "StoreState",
           "register_workload", "FaultContained", "JobEvicted",
           "DeviceFailedError", "DeviceHealth", "HealthConfig",
           "ShedPolicy", "CompactionPolicy", "FaultInjector", "FaultSpec",
           "InjectedFault", "Supervisor"]


def __getattr__(name):
    # lazy: the daemon pulls in the full runtime stack, and an eager
    # client import would double-import under `python -m
    # repro.sched.client` (runpy warns about the stale sys.modules copy)
    if name == "SchedDaemon":
        from .daemon import SchedDaemon
        return SchedDaemon
    if name == "Supervisor":
        from .supervisor import Supervisor
        return Supervisor
    if name in ("SchedClient", "connect", "SOCKET_ENV"):
        from . import client
        return getattr(client, name)
    raise AttributeError(f"module {__name__!r} has no attribute {name!r}")
