from .admission import AdmissionController, JobProfile
from .checkpointer import (AsyncCheckpointer, latest_carry, latest_step,
                           restore, save, save_carry)
from .cluster import ClusterExecutor
from .executor import DeviceExecutor, ExecutorTrace, TraceEvent
from .fault import FaultTolerantLoop, Heartbeat, StallError, with_retry
from .job import RTJob

__all__ = ["AdmissionController", "JobProfile", "AsyncCheckpointer",
           "latest_step", "restore", "save", "save_carry", "latest_carry",
           "ClusterExecutor", "DeviceExecutor", "ExecutorTrace",
           "TraceEvent", "FaultTolerantLoop", "Heartbeat", "StallError",
           "with_retry", "RTJob"]
