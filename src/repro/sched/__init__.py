from .admission import AdmissionController, JobProfile
from .checkpointer import AsyncCheckpointer, latest_step, restore, save
from .executor import DeviceExecutor
from .fault import FaultTolerantLoop, Heartbeat, StallError, with_retry
from .job import RTJob

__all__ = ["AdmissionController", "JobProfile", "AsyncCheckpointer",
           "latest_step", "restore", "save", "DeviceExecutor",
           "FaultTolerantLoop", "Heartbeat", "StallError", "with_retry",
           "RTJob"]
