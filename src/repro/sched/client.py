"""One public submission facade: ``repro.sched.connect()`` → SchedClient.

Every way of getting work onto the platform now goes through one
surface (DESIGN.md §9):

    client = repro.sched.connect()                  # in-process cluster
    client = repro.sched.connect(n_devices=2, policy="ioctl")
    client = repro.sched.connect(cluster)           # wrap an existing one
    client = repro.sched.connect("/run/schedd.sock")  # daemon socket
    client = repro.sched.connect()  # $REPRO_SCHED_SOCKET set → daemon

``SchedClient.submit/release/status/per_device_mort`` behave identically
against an in-process :class:`~repro.sched.cluster.ClusterExecutor` and
the daemon's unix socket; the historical direct paths
(``ClusterExecutor.submit``, ``DeviceExecutor(mode=...)``) still work
but emit ``DeprecationWarning``.

Over the socket, a submission's workload must be a *registered spec*
(``sched.workloads``) so the daemon can journal and reconstruct it;
in-process clients may additionally pass live ``workload=``/``body=``
objects (which are not durable — a spec-based submission is journaled
and survives a crash, a closure-based one does not).

The socket protocol is one JSON request line per connection, one JSON
response line back — deliberately connectionless per call, so a client
survives a daemon restart without resubscribing (the recovery suite
kills the daemon mid-conversation).
"""
from __future__ import annotations

import json
import os
import random
import socket as socketlib
import time
import uuid
from typing import Any, Dict, Mapping, Optional, Union

from .admission import AdmissionDecision, JobProfile
from .cluster import ClusterExecutor
from .workloads import make_body, normalize_spec

__all__ = ["SchedClient", "connect", "SOCKET_ENV"]

SOCKET_ENV = "REPRO_SCHED_SOCKET"


def _int_keys(d: Mapping) -> dict:
    """JSON object keys are strings; device-indexed maps come back
    int-keyed."""
    return {int(k): v for k, v in d.items()}


# --------------------------------------------------------------------------
# backends
# --------------------------------------------------------------------------

class _LocalBackend:
    """Facade over an in-process ClusterExecutor (owned or adopted)."""

    def __init__(self, cluster: ClusterExecutor, owns: bool):
        self.cluster = cluster
        self._owns = owns

    def submit(self, prof: JobProfile, *, workload=None, body=None,
               workload_spec=None, n_iterations=1, start=False,
               stop_after_s=None, strategy=None) -> AdmissionDecision:
        meta = None
        if workload_spec is not None:
            if workload is not None or body is not None:
                raise ValueError("pass workload_spec= alone, not with "
                                 "workload=/body=")
            spec = normalize_spec(workload_spec)
            body = make_body(self.cluster, prof.name, spec,
                             store=self.cluster.store)
            meta = {"workload": spec}
        return self.cluster._submit(
            prof, workload, body, strategy=strategy,
            n_iterations=n_iterations, start=start,
            stop_after_s=stop_after_s, journal_meta=meta)

    def release(self, name: str) -> bool:
        return self.cluster.release(name)

    def status(self) -> dict:
        return {"pid": os.getpid(), "backend": "local",
                "n_devices": self.cluster.n_devices,
                "placement": self.cluster.placement,
                "admitted": [p.name for p in
                             self.cluster.admission.admitted],
                "stats": self.cluster.stats()}

    def per_device_mort(self) -> Dict[int, Optional[float]]:
        return self.cluster.per_device_mort()

    def ping(self) -> dict:
        return {"ok": True, "pid": os.getpid(), "backend": "local"}

    def join(self, timeout: Optional[float] = None) -> None:
        self.cluster.join(timeout)

    def close(self, shutdown: Optional[bool] = None) -> None:
        if shutdown if shutdown is not None else self._owns:
            self.cluster.shutdown()


class _SocketBackend:
    """Facade over the daemon's unix socket (one JSON line per call).

    Transport failures — the daemon restarting under its supervisor,
    a connection refused on a half-created socket, a timeout — are
    retried with jittered exponential backoff.  Retrying is safe even
    for ``submit``: every logical submission carries a ``request_id``
    (a fresh UUID per ``submit()`` call), and the daemon dedups by id
    against its journal, so a retry that races a restart returns the
    journaled decision instead of double-admitting the job."""

    def __init__(self, path: Union[str, os.PathLike], *,
                 retries: int = 3, backoff_s: float = 0.1,
                 max_backoff_s: float = 2.0,
                 timeout_s: float = 60.0):
        self.path = os.fspath(path)
        self.cluster = None   # execution lives in the daemon process
        self.retries = retries
        self.backoff_s = backoff_s
        self.max_backoff_s = max_backoff_s
        self.timeout_s = timeout_s
        self._rng = random.Random()

    def _request_once(self, req: dict, op: str, timeout: float) -> Any:
        with socketlib.socket(socketlib.AF_UNIX,
                              socketlib.SOCK_STREAM) as s:
            s.settimeout(timeout)
            s.connect(self.path)
            s.sendall((json.dumps(req) + "\n").encode())
            s.shutdown(socketlib.SHUT_WR)
            buf = b""
            while True:
                chunk = s.recv(65536)
                if not chunk:
                    break
                buf += chunk
        if not buf.strip():
            raise ConnectionError(f"no response from daemon for {op!r} "
                                  "(connection closed)")
        resp = json.loads(buf.decode())
        if not resp.get("ok"):
            # an application-level refusal: the daemon IS alive and
            # answered — never retried (only transport errors are)
            raise RuntimeError(f"daemon refused {op!r}: "
                               f"{resp.get('error')}")
        return resp.get("result")

    def request(self, op: str, timeout: Optional[float] = None,
                **payload) -> Any:
        req = dict(payload, op=op)
        timeout = self.timeout_s if timeout is None else timeout
        err: Optional[BaseException] = None
        for i in range(self.retries + 1):
            try:
                return self._request_once(req, op, timeout)
            except (ConnectionError, FileNotFoundError, socketlib.timeout,
                    OSError) as e:
                err = e
            if i < self.retries:
                delay = min(self.backoff_s * (2 ** i), self.max_backoff_s)
                time.sleep(delay * self._rng.uniform(0.5, 1.5))
        raise RuntimeError(
            f"daemon unreachable for {op!r} after "
            f"{self.retries + 1} attempts: {err}") from err

    def submit(self, prof: JobProfile, *, workload=None, body=None,
               workload_spec=None, n_iterations=1, start=False,
               stop_after_s=None, strategy=None) -> AdmissionDecision:
        if workload is not None or body is not None:
            raise ValueError(
                "a daemon submission must be a registered workload spec "
                "(workload_spec=...): live workload/body objects cannot "
                "be journaled or reconstructed after a crash")
        if workload_spec is None:
            raise ValueError("pass workload_spec= (a sched.workloads "
                             "registry name or {'name', 'kwargs'} spec)")
        result = self.request(
            "submit", profile=prof.to_dict(),
            workload=normalize_spec(workload_spec, check=False),
            n_iterations=n_iterations, start=start,
            stop_after_s=stop_after_s, strategy=strategy,
            request_id=uuid.uuid4().hex)
        return AdmissionDecision(result)

    def release(self, name: str) -> bool:
        return bool(self.request("release", name=name))

    def status(self) -> dict:
        st = self.request("status")
        stats = st.get("stats") or {}
        for key in ("per_device_mort", "dispatches", "updates", "jobs",
                    "per_tier"):
            if key in stats:
                stats[key] = _int_keys(stats[key])
        return st

    def per_device_mort(self) -> Dict[int, Optional[float]]:
        return _int_keys(self.request("per_device_mort"))

    def ping(self) -> dict:
        return self.request("ping")

    def join(self, timeout: Optional[float] = None) -> None:
        raise NotImplementedError(
            "join() is in-process only: daemon jobs outlive the client "
            "by design — poll status()/jobs() instead")

    def close(self, shutdown: Optional[bool] = None) -> None:
        if shutdown:
            try:
                self.request("shutdown")
            except (OSError, RuntimeError):
                pass  # daemon already gone


# --------------------------------------------------------------------------
# the public client
# --------------------------------------------------------------------------

class SchedClient:
    """The one submission surface: submit / release / status /
    per_device_mort, identical against an in-process cluster and the
    daemon socket."""

    def __init__(self, backend):
        self._backend = backend

    @property
    def cluster(self) -> Optional[ClusterExecutor]:
        """The in-process cluster (None for a socket client) — job
        bodies that bracket their own device segments still talk to
        the executor face directly."""
        return self._backend.cluster

    def submit(self, prof: JobProfile, *, workload=None, body=None,
               workload_spec=None, n_iterations: int = 1,
               start: bool = False,
               stop_after_s: Optional[float] = None,
               strategy: Optional[str] = None) -> AdmissionDecision:
        """Admit → place → bind (→ start) one job; returns the
        structured :class:`AdmissionDecision` with the winning device.

        ``workload_spec`` (registry name or spec dict) is the durable
        path and works on both backends; ``workload=`` (a
        SegmentedWorkload) and ``body=`` (a callable) are in-process
        only."""
        return self._backend.submit(
            prof, workload=workload, body=body,
            workload_spec=workload_spec, n_iterations=n_iterations,
            start=start, stop_after_s=stop_after_s, strategy=strategy)

    def release(self, name: str) -> bool:
        """Retire an admitted job: stops charging admissions, frees the
        name."""
        return self._backend.release(name)

    def status(self) -> dict:
        return self._backend.status()

    def admission_latency(self) -> dict:
        """Per-decision admission latency summary (decisions / window /
        mean / p50 / p99 / max, ms) from the controller's sliding
        window — the live counterpart of the metric
        benchmarks/admission_bench.py reports offline.  Served through
        the stats reply, so it works against both backends."""
        return (self.status().get("stats") or {}).get(
            "admission_latency", {})

    def per_device_mort(self) -> Dict[int, Optional[float]]:
        return self._backend.per_device_mort()

    def per_model_stats(self) -> dict:
        """Per-model observability (tier, MORT, deadline misses,
        nearest-rank p50/p99 ms) — served through the stats reply, so
        it works against both backends."""
        return (self.status().get("stats") or {}).get("per_model", {})

    def per_tier_stats(self) -> dict:
        """Tier-level rollup (pooled tail latency, miss counts, tier
        utilization vs budget) — both backends."""
        return (self.status().get("stats") or {}).get("per_tier", {})

    def ping(self) -> dict:
        return self._backend.ping()

    def jobs(self) -> dict:
        """Per-job detail (completions, MORT, admitted WCRT evidence)
        — daemon backend only for now; local callers hold the RTJob."""
        if isinstance(self._backend, _SocketBackend):
            return self._backend.request("jobs")
        raise NotImplementedError("jobs() detail is served by the "
                                  "daemon; local callers hold the RTJob")

    def join(self, timeout: Optional[float] = None) -> None:
        self._backend.join(timeout)

    def close(self, shutdown: Optional[bool] = None) -> None:
        """Release the client.  ``shutdown=True`` also stops the
        backend (an owned in-process cluster shuts down by default; an
        adopted one and a daemon keep running)."""
        self._backend.close(shutdown)

    def __enter__(self) -> "SchedClient":
        return self

    def __exit__(self, *exc) -> None:
        self.close()


def connect(target: Union[str, os.PathLike, ClusterExecutor, None] = None,
            **cluster_kwargs) -> SchedClient:
    """The unified entry point of the scheduling platform.

      * ``connect()`` — ``$REPRO_SCHED_SOCKET`` if set (daemon client),
        else a fresh in-process single-device cluster;
      * ``connect(n_devices=4, policy="ioctl", ...)`` — a fresh
        in-process cluster built from the kwargs (owned: ``close()``
        shuts it down);
      * ``connect(existing_cluster)`` — adopt a live ClusterExecutor
        (not owned);
      * ``connect("/path/to/sock")`` — the daemon at that socket.
    """
    if isinstance(target, ClusterExecutor):
        if cluster_kwargs:
            raise ValueError("cluster kwargs make no sense when "
                             "adopting an existing cluster")
        return SchedClient(_LocalBackend(target, owns=False))
    if target is None:
        env = os.environ.get(SOCKET_ENV)
        if env:
            if cluster_kwargs:
                raise ValueError(
                    f"cluster kwargs make no sense with {SOCKET_ENV} "
                    f"set (the daemon owns the platform)")
            return SchedClient(_SocketBackend(env))
        cluster_kwargs.setdefault("n_devices", 1)
        return SchedClient(_LocalBackend(
            ClusterExecutor(**cluster_kwargs), owns=True))
    # a path → daemon socket
    if cluster_kwargs:
        raise ValueError("cluster kwargs make no sense for a daemon "
                         "socket (the daemon owns the platform)")
    return SchedClient(_SocketBackend(target))


# --------------------------------------------------------------------------
# CLI: the daemon's command-line client
# --------------------------------------------------------------------------

def main(argv=None) -> int:
    import argparse

    ap = argparse.ArgumentParser(
        prog="repro.sched.client",
        description="CLI client for the scheduling daemon")
    ap.add_argument("--socket", default=os.environ.get(SOCKET_ENV),
                    help=f"daemon unix socket (default: ${SOCKET_ENV})")
    sub = ap.add_subparsers(dest="cmd", required=True)
    for simple in ("ping", "status", "jobs", "mort", "shutdown",
                   "compact", "audit"):
        sub.add_parser(simple)
    rel = sub.add_parser("release")
    rel.add_argument("name")
    fd = sub.add_parser("fail-device",
                        help="declare a device failed (opens a new "
                             "binding epoch; jobs fail over)")
    fd.add_argument("device", type=int)
    fd.add_argument("--reason", default="operator")
    sb = sub.add_parser("submit")
    sb.add_argument("--name", required=True)
    sb.add_argument("--workload", required=True,
                    help="registered workload name (sched.workloads)")
    sb.add_argument("--workload-kwargs", default="{}",
                    help="JSON kwargs for the workload factory")
    sb.add_argument("--period-ms", type=float, required=True)
    sb.add_argument("--priority", type=int, required=True)
    sb.add_argument("--deadline-ms", type=float, default=None)
    sb.add_argument("--host-ms", type=float, default=1.0)
    sb.add_argument("--misc-ms", type=float, default=0.5)
    sb.add_argument("--exec-ms", type=float, required=True,
                    help="device WCET of the whole segment (ms)")
    sb.add_argument("--cpu", type=int, default=0)
    sb.add_argument("--device", type=int, default=0)
    sb.add_argument("--best-effort", action="store_true")
    sb.add_argument("--tier", type=int, default=0,
                    help="criticality tier (per-tier stats grouping and "
                         "the shedding ladder's victim key)")
    sb.add_argument("--n-iterations", type=int, default=1)
    sb.add_argument("--start", action="store_true")
    sb.add_argument("--stop-after-s", type=float, default=None)
    args = ap.parse_args(argv)
    if not args.socket:
        ap.error(f"--socket (or ${SOCKET_ENV}) is required")

    client = connect(args.socket)
    if args.cmd == "ping":
        out = client.ping()
    elif args.cmd == "status":
        out = client.status()
    elif args.cmd == "jobs":
        out = client.jobs()
    elif args.cmd == "mort":
        out = client.per_device_mort()
    elif args.cmd == "release":
        out = {"released": client.release(args.name)}
    elif args.cmd == "compact":
        out = client._backend.request("compact")
    elif args.cmd == "audit":
        out = client._backend.request("audit")
    elif args.cmd == "fail-device":
        out = client._backend.request("fail_device", device=args.device,
                                      reason=args.reason)
    elif args.cmd == "shutdown":
        client.close(shutdown=True)
        out = {"ok": True}
    else:  # submit
        prof = JobProfile(
            name=args.name, host_segments_ms=[args.host_ms],
            device_segments_ms=[(args.misc_ms, args.exec_ms)],
            period_ms=args.period_ms, priority=args.priority,
            cpu=args.cpu, deadline_ms=args.deadline_ms,
            best_effort=args.best_effort, device=args.device,
            tier=args.tier)
        dec = client.submit(
            prof,
            workload_spec={"name": args.workload,
                           "kwargs": json.loads(args.workload_kwargs)},
            n_iterations=args.n_iterations, start=args.start,
            stop_after_s=args.stop_after_s)
        out = dec.journal_form()
    print(json.dumps(out, indent=2, sort_keys=True, default=str))
    return 0 if not isinstance(out, dict) or out.get("ok", True) else 1


if __name__ == "__main__":
    raise SystemExit(main())
