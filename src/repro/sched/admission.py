"""RTA-driven admission control: before a new real-time job is accepted
onto the executor, its measured worst-case segment times are folded into
the current taskset and the paper's schedulability test decides.

This is where the paper's analysis becomes an operational guarantee: jobs
admitted here have analytically bounded response times under the chosen
scheduling approach (kthread/ioctl x busy/suspend), including the measured
runlist-update overhead epsilon.  On multi-device platforms
(``n_devices > 1``) the busy-wait RTAs resolve to the cross-device fixed
point (core/crossfix.py), so busy-mode admission is sound — not the
pre-fixed-point per-device heuristic.

The analysis matching each approach lives in the policy registry
(`core.policy.PolicySpec.rtas`), so the executor, the simulator, and the
admission controller all resolve one policy name to one consistent
(implementation, analysis) pair."""
from __future__ import annotations

import dataclasses
import math
from dataclasses import dataclass
from typing import Callable, Iterable, List, Mapping, Optional

from ..core import GpuSegment, Task, Taskset, schedulable
from ..core.analysis import _EPS
from ..core.audsley import assign_gpu_priorities
from ..core.policy import policy_spec
from ..core.segments import WorkloadProfile


class RecoveryConformanceError(RuntimeError):
    """Recovery re-ran admission over the journaled taskset and did NOT
    reproduce the recorded decisions — the store and the analysis have
    drifted (a changed RTA, a corrupted journal, a different platform
    config), so the journaled guarantees cannot be trusted.  The
    recovery path must refuse to come up rather than silently serve
    jobs whose admission evidence no longer holds (the durable analogue
    of tests/conformance.py's live↔simulated identity)."""


# Reason codes carried by every admission decision, in refusal order:
# the first gate that fires names the decision.
REASONS = ("accepted", "validation-refused", "headroom-fast-reject",
           "rta-reject")


class AdmissionDecision(dict):
    """Structured admission result (one decision of ``try_admit``).

    A ``dict`` subclass on purpose: every existing call site reads the
    mapping face (``res["admitted"]``, ``res.get("error")``,
    ``res["wcrt"]``) and the job store journals decisions verbatim as
    JSON — both keep working unchanged — while new code gets the typed
    surface: ``bool(decision)`` is the acceptance, ``.reason`` is one
    of :data:`REASONS`, ``.wcrt`` the RTA evidence, ``.device``/``.job``
    the binding ``ClusterExecutor`` attached.

    Keys always present: ``admitted`` (bool), ``reason``, ``via``
    (``"default"``/``"audsley"``/``"best_effort"``/None), ``wcrt``
    (task name → WCRT ms; empty when no fixed point ran).  Optional:
    ``error`` (human-readable refusal), ``gpu_priorities`` (Audsley
    assignment), ``device`` (binding), ``job`` (the live RTJob —
    stripped before journaling)."""

    def __init__(self, *args, **kw):
        super().__init__(*args, **kw)
        self.setdefault("admitted", False)
        self.setdefault("reason",
                        "accepted" if self["admitted"] else "rta-reject")
        self.setdefault("via", None)
        self.setdefault("wcrt", {})
        if self["reason"] not in REASONS:
            raise ValueError(f"unknown reason code {self['reason']!r} "
                             f"(expected one of {REASONS})")
        if self["admitted"] != (self["reason"] == "accepted"):
            raise ValueError(
                f"admitted={self['admitted']} contradicts "
                f"reason={self['reason']!r}")

    # -- typed face ------------------------------------------------------
    def __bool__(self) -> bool:
        return bool(self["admitted"])

    @property
    def accepted(self) -> bool:
        return bool(self["admitted"])

    @property
    def reason(self) -> str:
        return self["reason"]

    @property
    def via(self) -> Optional[str]:
        return self["via"]

    @property
    def wcrt(self) -> dict:
        return self["wcrt"]

    @property
    def error(self) -> Optional[str]:
        return self.get("error")

    @property
    def device(self) -> Optional[int]:
        return self.get("device")

    @property
    def job(self):
        return self.get("job")

    # -- helpers ---------------------------------------------------------
    @classmethod
    def accept(cls, via: str, wcrt: Optional[dict] = None,
               **extra) -> "AdmissionDecision":
        return cls(admitted=True, reason="accepted", via=via,
                   wcrt=dict(wcrt or {}), **extra)

    @classmethod
    def refuse(cls, reason: str, *, error: Optional[str] = None,
               wcrt: Optional[dict] = None, **extra) -> "AdmissionDecision":
        d = cls(admitted=False, reason=reason, via=None,
                wcrt=dict(wcrt or {}), **extra)
        if error is not None:
            d["error"] = error
        return d

    def bound(self, device: Optional[int], job=None) -> "AdmissionDecision":
        """A copy with the placement attached (``ClusterExecutor``'s
        admit→place→bind result)."""
        out = AdmissionDecision(self)
        out["device"] = device
        out["job"] = job
        return out

    def journal_form(self) -> dict:
        """The JSON-serializable view the job store appends verbatim:
        everything except the live RTJob handle."""
        return {k: v for k, v in self.items() if k != "job"}


def decisions_match(a: Mapping, b: Mapping, tol: float = 1e-6) -> bool:
    """Decision identity for recovery conformance: same acceptance,
    reason, via, Audsley assignment, and WCRT evidence (to ``tol``,
    inf-for-inf).  ``device``/``job``/``error`` wording are excluded —
    placement is compared separately by the recovery path and the
    refusal text is presentation, not evidence."""
    if (bool(a.get("admitted")) != bool(b.get("admitted"))
            or a.get("reason") != b.get("reason")
            or a.get("via") != b.get("via")
            or a.get("gpu_priorities") != b.get("gpu_priorities")):
        return False
    wa, wb = a.get("wcrt") or {}, b.get("wcrt") or {}
    if set(wa) != set(wb):
        return False
    for k, va in wa.items():
        vb = wb[k]
        va = math.inf if va is None else float(va)
        vb = math.inf if vb is None else float(vb)
        if math.isinf(va) or math.isinf(vb):
            if va != vb:
                return False
        elif abs(va - vb) > tol:
            return False
    return True


def rta_for(policy: str, wait_mode: str) -> Callable:
    """Resolve the RTA guaranteeing (approach, wait mode); accepts registry
    names and the executor's legacy mode names ("notify"/"poll")."""
    spec = policy_spec(policy)
    try:
        return spec.rtas[wait_mode]
    except KeyError:
        raise ValueError(
            f"approach {spec.name!r} has no analysis for "
            f"wait_mode={wait_mode!r} (available: {sorted(spec.rtas)})")


@dataclass
class JobProfile:
    """Measured WCETs of one job (ms): host segments and device segments
    (launch misc + pure device time)."""
    name: str
    host_segments_ms: List[float]
    device_segments_ms: List[tuple]  # (misc_ms, exec_ms)
    period_ms: float
    priority: int
    cpu: int = 0
    deadline_ms: Optional[float] = None
    best_effort: bool = False
    device: int = 0  # accelerator the device segments execute on

    def to_task(self) -> Task:
        return Task(
            name=self.name,
            cpu_segments=self.host_segments_ms,
            gpu_segments=[GpuSegment(m, e) for m, e in
                          self.device_segments_ms],
            period=self.period_ms,
            deadline=self.deadline_ms or self.period_ms,
            cpu=self.cpu, priority=self.priority,
            best_effort=self.best_effort, device=self.device)

    @classmethod
    def from_workload(cls, wp: "WorkloadProfile", period_ms: float,
                      priority: int, *, cpu: int = 0,
                      deadline_ms: Optional[float] = None,
                      best_effort: bool = False, device: int = 0,
                      margin: float = 1.2) -> "JobProfile":
        """Build the admission profile from a *measured*
        ``core.segments.WorkloadProfile`` (host segment times + per-slice
        device times), inflated by ``margin`` — observations are not
        WCETs.  This is the end of the measured pipeline: real sliced
        kernel → per-slice times → η/G segments → RTA admission."""
        host, dev = wp.segments_ms(margin)
        return cls(name=wp.name,
                   host_segments_ms=host or [0.0],
                   device_segments_ms=dev,
                   period_ms=period_ms, priority=priority, cpu=cpu,
                   deadline_ms=deadline_ms, best_effort=best_effort,
                   device=device)

    def to_dict(self) -> dict:
        """JSON-serializable form (the job store journals profiles)."""
        d = dataclasses.asdict(self)
        d["device_segments_ms"] = [list(s) for s in
                                   self.device_segments_ms]
        return d

    @classmethod
    def from_dict(cls, d: Mapping) -> "JobProfile":
        """Inverse of :meth:`to_dict` (JSON round-trips tuples as
        lists; ``to_task`` unpacks either, but recovery compares
        profiles by value so the shape is normalized here)."""
        d = dict(d)
        d["device_segments_ms"] = [tuple(s) for s in
                                   d["device_segments_ms"]]
        return cls(**d)


def headroom_violation(ts: Taskset, headroom: float = 1.0
                       ) -> Optional[str]:
    """Utilization fast-reject: the long-run RT demand each CPU core and
    each accelerator must serve, against a ``headroom`` capacity bound.

    This is a *necessary* condition, so refusing on it is sound: a core
    charges at least C + G^m per period for every RT task bound to it
    (the suspend-mode floor — busy-waiting only adds demand), and a
    device serves G^e per period for every RT task targeting it.  If
    either exceeds 1.0, backlog grows without bound and every RTA in
    the registry diverges to a refusal anyway — the gate just refuses
    *before* any fixed point runs.  ``headroom < 1.0`` reserves slack
    (a conservative gate that can refuse RTA-acceptable sets).

    Returns a human-readable reason, or None when the gate passes.
    """
    cpu_u: dict = {}
    dev_u: dict = {}
    for t in ts.rt_tasks:
        cpu_u[t.cpu] = cpu_u.get(t.cpu, 0.0) + (t.C + t.Gm) / t.period
        if t.uses_gpu:
            dev_u[t.device] = dev_u.get(t.device, 0.0) + t.Ge / t.period
    for core, u in sorted(cpu_u.items()):
        if u > headroom + _EPS:
            return (f"RT utilization {u:.3f} on core {core} exceeds "
                    f"headroom {headroom:g}")
    for dev, u in sorted(dev_u.items()):
        if u > headroom + _EPS:
            return (f"RT utilization {u:.3f} on device {dev} exceeds "
                    f"headroom {headroom:g}")
    return None


class AdmissionController:
    def __init__(self, mode: str = "notify", wait_mode: str = "suspend",
                 n_cpus: int = 4, epsilon_ms: float = 1.0,
                 try_gpu_priorities: bool = True, n_devices: int = 1,
                 headroom: float = 1.0):
        self.mode, self.wait_mode = mode, wait_mode
        self.rta = rta_for(mode, wait_mode)
        self.n_cpus = n_cpus
        self.epsilon_ms = epsilon_ms
        self.try_gpu_priorities = try_gpu_priorities
        self.n_devices = n_devices
        self.headroom = headroom
        self.admitted: List[JobProfile] = []

    def _taskset(self, *extra: JobProfile) -> Taskset:
        profs = self.admitted + list(extra)
        return Taskset([p.to_task() for p in profs], n_cpus=self.n_cpus,
                       epsilon=self.epsilon_ms,
                       kthread_cpu=self.n_cpus,  # dedicated scheduler core
                       n_devices=self.n_devices)

    def try_admit(self, prof: JobProfile) -> AdmissionDecision:
        """Returns an :class:`AdmissionDecision` (a dict with keys
        ``admitted``/``reason``/``via``/``wcrt``/…, so historical
        ``res["admitted"]`` call sites read it unchanged).
        Best-effort jobs are always admitted (they have no guarantee) —
        but still validated, or an unbuildable profile would poison every
        later ``_taskset()`` build."""
        if not (0 <= prof.device < self.n_devices):
            # refuse, don't crash: a bad profile must not take down the
            # admission path (Taskset validation would raise), nor may it
            # be appended and poison every later _taskset() build
            return AdmissionDecision.refuse(
                "validation-refused",
                error=f"device {prof.device} out of range for "
                      f"{self.n_devices}-device platform")
        if any(p.name == prof.name for p in self.admitted):
            # a duplicate name would silently merge WCRT dict entries
            return AdmissionDecision.refuse(
                "validation-refused",
                error=f"job name {prof.name!r} already admitted")
        try:
            # same refuse-don't-crash rule for every other profile defect
            # Taskset validation catches (colliding priorities, bad cpu):
            # a live gatekeeper must return a refusal, not raise
            ts = self._taskset(prof)
        except ValueError as e:
            return AdmissionDecision.refuse("validation-refused",
                                            error=str(e))
        if prof.best_effort:
            self.admitted.append(prof)
            return AdmissionDecision.accept("best_effort")
        reason = headroom_violation(ts, self.headroom)
        if reason is not None:
            # the fast-reject: a hopeless taskset never reaches a fixed
            # point (wcrt stays empty — nothing was computed)
            return AdmissionDecision.refuse("headroom-fast-reject",
                                            error=reason)
        rta = self.rta
        if schedulable(ts, rta):
            self.admitted.append(prof)
            return AdmissionDecision.accept("default", rta(ts))
        if self.try_gpu_priorities:
            assigned = assign_gpu_priorities(ts, rta)
            if assigned is not None:
                self.admitted.append(prof)
                return AdmissionDecision.accept(
                    "audsley", rta(assigned, use_gpu_prio=True),
                    gpu_priorities={t.name: t.gpu_priority
                                    for t in assigned.tasks})
        return AdmissionDecision.refuse("rta-reject", wcrt=rta(ts))

    def try_admit_many(self, profs: Iterable[JobProfile], *,
                       backend: str = "numpy") -> List[AdmissionDecision]:
        """Admit an arrival burst in order, batching the RTA fixed
        points through `core/batch.py` (``backend="jax"`` lowers them
        to the jit-compiled device kernels — the streaming-admission
        fast path).

        Decision-identical to calling ``try_admit`` per profile: the
        burst is analyzed under *optimistic prefix* tasksets — profile
        k is tested against admitted + burst[:k+1] — which is exactly
        the sequential state while every earlier profile is being
        admitted.  At the first profile the batch cannot clear (an RTA
        refusal, a best-effort job, a validation defect, or a headroom
        refusal) that one profile goes through the sequential path —
        including the Audsley retry and the exact refusal dict — and
        the remainder re-batches against the updated state.  WCRTs in
        batched results are the batch solver's vectors (value-equal to
        the scalar ones to float tolerance, inf-for-inf)."""
        profs = list(profs)
        kind = getattr(self.rta, "batch_kind", None)
        if kind is None or len(profs) <= 1:
            return [self.try_admit(p) for p in profs]
        from ..core.batch import batch_rta
        results: List[AdmissionDecision] = []
        i = 0
        while i < len(profs):
            run: List[JobProfile] = []
            tss: List[Taskset] = []
            j = i
            while j < len(profs):
                p = profs[j]
                if (p.best_effort
                        or not (0 <= p.device < self.n_devices)
                        or any(q.name == p.name
                               for q in self.admitted + run)):
                    break
                try:
                    ts = self._taskset(*run, p)
                except ValueError:
                    break
                if headroom_violation(ts, self.headroom) is not None:
                    break
                run.append(p)
                tss.append(ts)
                j += 1
            if not run:
                # burst head needs non-RTA handling (best-effort,
                # refusal): sequential produces the exact result dict
                results.append(self.try_admit(profs[i]))
                i += 1
                continue
            wcrts = batch_rta(kind, tss, backend=backend)
            k = 0
            while k < len(run) and self._accepts(tss[k], wcrts[k]):
                k += 1
            for p, w in zip(run[:k], wcrts[:k]):
                self.admitted.append(p)
                results.append(AdmissionDecision.accept("default", w))
            i += k
            if k < len(run):
                # first refusal: sequential fallback runs the Audsley
                # retry; everything after it re-batches next round
                results.append(self.try_admit(profs[i]))
                i += 1
        return results

    @staticmethod
    def _accepts(ts: Taskset, R: dict) -> bool:
        """`analysis.schedulable`'s accept criterion on a WCRT dict."""
        for t in ts.rt_tasks:
            r = R.get(t.name, math.inf)
            if r is None or math.isinf(r) or r > t.deadline + _EPS:
                return False
        return True

    def release(self, name: str) -> bool:
        """Retire an admitted profile (its job left the platform) so its
        demand no longer charges future admissions."""
        for i, p in enumerate(self.admitted):
            if p.name == name:
                del self.admitted[i]
                return True
        return False

    def on_device(self, device: int) -> List[JobProfile]:
        """Admitted profiles bound to ``device`` (RT and best-effort)."""
        return [p for p in self.admitted if p.device == device]

    def device_utilization(self, device: int, *,
                           include_best_effort: bool = True) -> float:
        """Total admitted GPU utilization on ``device`` — the overload
        metric of the shedding ladder (`sched.elastic`).  Unlike every
        RTA input, this *includes* best-effort demand by default: BE
        tasks never interfere analytically, but they do occupy the
        device at runtime."""
        from .elastic import profile_utilization
        return sum(profile_utilization(p) for p in self.on_device(device)
                   if include_best_effort or not p.best_effort)

    # ------------------------------------------------------------------
    # durable state: export / rebuild (sched/store.py, sched/daemon.py)
    # ------------------------------------------------------------------
    def export_config(self) -> dict:
        """The constructor arguments that reproduce this controller's
        platform model — journaled by the job store so recovery builds
        an identically configured gatekeeper."""
        return {"mode": self.mode, "wait_mode": self.wait_mode,
                "n_cpus": self.n_cpus, "epsilon_ms": self.epsilon_ms,
                "try_gpu_priorities": self.try_gpu_priorities,
                "n_devices": self.n_devices, "headroom": self.headroom}

    def export_state(self) -> dict:
        """Config + the admitted profiles in admission order (the order
        *is* state: each decision was taken against the prefix)."""
        return {"config": self.export_config(),
                "admitted": [p.to_dict() for p in self.admitted]}

    @classmethod
    def rebuild(cls, config: Mapping, entries: Iterable[Mapping], *,
                conform: bool = True) -> "AdmissionController":
        """Rebuild a controller from journaled state by *re-running*
        admission over the journaled profiles in their recorded order.

        Each ``entry`` is ``{"profile": ..., "decision": ...}`` as the
        job store recorded it.  With ``conform=True`` (the recovery
        default) every re-derived decision must be decision-identical
        to the recorded one (:func:`decisions_match` — acceptance,
        reason, via, Audsley assignment, WCRT evidence to tolerance) or
        :class:`RecoveryConformanceError` is raised: an admitted RT
        job's guarantee survives a crash only if the analysis still
        proves it."""
        ctl = cls(**dict(config))
        for n, entry in enumerate(entries):
            prof = JobProfile.from_dict(entry["profile"])
            recorded = entry.get("decision")
            redone = ctl.try_admit(prof)
            if not redone["admitted"]:
                raise RecoveryConformanceError(
                    f"journaled job {prof.name!r} (entry {n}) refused on "
                    f"re-admission: {redone.get('error') or redone['wcrt']}")
            if conform and recorded is not None \
                    and not decisions_match(redone, recorded):
                raise RecoveryConformanceError(
                    f"journaled job {prof.name!r} (entry {n}): recovered "
                    f"decision {redone.journal_form()} does not reproduce "
                    f"the recorded decision {dict(recorded)}")
        return ctl
