"""RTA-driven admission control: before a new real-time job is accepted
onto the executor, its measured worst-case segment times are folded into
the current taskset and the paper's schedulability test decides.

This is where the paper's analysis becomes an operational guarantee: jobs
admitted here have analytically bounded response times under the chosen
scheduling approach (kthread/ioctl x busy/suspend), including the measured
runlist-update overhead epsilon.  On multi-device platforms
(``n_devices > 1``) the busy-wait RTAs resolve to the cross-device fixed
point (core/crossfix.py), so busy-mode admission is sound — not the
pre-fixed-point per-device heuristic.

The analysis matching each approach lives in the policy registry
(`core.policy.PolicySpec.rtas`), so the executor, the simulator, and the
admission controller all resolve one policy name to one consistent
(implementation, analysis) pair."""
from __future__ import annotations

from dataclasses import dataclass
from typing import Callable, List, Optional

from ..core import GpuSegment, Task, Taskset, schedulable
from ..core.audsley import assign_gpu_priorities
from ..core.policy import policy_spec
from ..core.segments import WorkloadProfile


def rta_for(policy: str, wait_mode: str) -> Callable:
    """Resolve the RTA guaranteeing (approach, wait mode); accepts registry
    names and the executor's legacy mode names ("notify"/"poll")."""
    spec = policy_spec(policy)
    try:
        return spec.rtas[wait_mode]
    except KeyError:
        raise ValueError(
            f"approach {spec.name!r} has no analysis for "
            f"wait_mode={wait_mode!r} (available: {sorted(spec.rtas)})")


@dataclass
class JobProfile:
    """Measured WCETs of one job (ms): host segments and device segments
    (launch misc + pure device time)."""
    name: str
    host_segments_ms: List[float]
    device_segments_ms: List[tuple]  # (misc_ms, exec_ms)
    period_ms: float
    priority: int
    cpu: int = 0
    deadline_ms: Optional[float] = None
    best_effort: bool = False
    device: int = 0  # accelerator the device segments execute on

    def to_task(self) -> Task:
        return Task(
            name=self.name,
            cpu_segments=self.host_segments_ms,
            gpu_segments=[GpuSegment(m, e) for m, e in
                          self.device_segments_ms],
            period=self.period_ms,
            deadline=self.deadline_ms or self.period_ms,
            cpu=self.cpu, priority=self.priority,
            best_effort=self.best_effort, device=self.device)

    @classmethod
    def from_workload(cls, wp: "WorkloadProfile", period_ms: float,
                      priority: int, *, cpu: int = 0,
                      deadline_ms: Optional[float] = None,
                      best_effort: bool = False, device: int = 0,
                      margin: float = 1.2) -> "JobProfile":
        """Build the admission profile from a *measured*
        ``core.segments.WorkloadProfile`` (host segment times + per-slice
        device times), inflated by ``margin`` — observations are not
        WCETs.  This is the end of the measured pipeline: real sliced
        kernel → per-slice times → η/G segments → RTA admission."""
        host, dev = wp.segments_ms(margin)
        return cls(name=wp.name,
                   host_segments_ms=host or [0.0],
                   device_segments_ms=dev,
                   period_ms=period_ms, priority=priority, cpu=cpu,
                   deadline_ms=deadline_ms, best_effort=best_effort,
                   device=device)


class AdmissionController:
    def __init__(self, mode: str = "notify", wait_mode: str = "suspend",
                 n_cpus: int = 4, epsilon_ms: float = 1.0,
                 try_gpu_priorities: bool = True, n_devices: int = 1):
        self.mode, self.wait_mode = mode, wait_mode
        self.rta = rta_for(mode, wait_mode)
        self.n_cpus = n_cpus
        self.epsilon_ms = epsilon_ms
        self.try_gpu_priorities = try_gpu_priorities
        self.n_devices = n_devices
        self.admitted: List[JobProfile] = []

    def _taskset(self, extra: Optional[JobProfile] = None) -> Taskset:
        profs = self.admitted + ([extra] if extra else [])
        return Taskset([p.to_task() for p in profs], n_cpus=self.n_cpus,
                       epsilon=self.epsilon_ms,
                       kthread_cpu=self.n_cpus,  # dedicated scheduler core
                       n_devices=self.n_devices)

    def try_admit(self, prof: JobProfile) -> dict:
        """Returns {admitted: bool, wcrt: {...}, via: "default"|"audsley"}.
        Best-effort jobs are always admitted (they have no guarantee) —
        but still validated, or an unbuildable profile would poison every
        later ``_taskset()`` build."""
        if not (0 <= prof.device < self.n_devices):
            # refuse, don't crash: a bad profile must not take down the
            # admission path (Taskset validation would raise), nor may it
            # be appended and poison every later _taskset() build
            return {"admitted": False, "via": None, "wcrt": {},
                    "error": f"device {prof.device} out of range for "
                             f"{self.n_devices}-device platform"}
        if any(p.name == prof.name for p in self.admitted):
            # a duplicate name would silently merge WCRT dict entries
            return {"admitted": False, "via": None, "wcrt": {},
                    "error": f"job name {prof.name!r} already admitted"}
        try:
            # same refuse-don't-crash rule for every other profile defect
            # Taskset validation catches (colliding priorities, bad cpu):
            # a live gatekeeper must return a refusal, not raise
            ts = self._taskset(prof)
        except ValueError as e:
            return {"admitted": False, "via": None, "wcrt": {},
                    "error": str(e)}
        if prof.best_effort:
            self.admitted.append(prof)
            return {"admitted": True, "via": "best_effort", "wcrt": {}}
        rta = self.rta
        if schedulable(ts, rta):
            self.admitted.append(prof)
            return {"admitted": True, "via": "default",
                    "wcrt": rta(ts)}
        if self.try_gpu_priorities:
            assigned = assign_gpu_priorities(ts, rta)
            if assigned is not None:
                self.admitted.append(prof)
                return {"admitted": True, "via": "audsley",
                        "wcrt": rta(assigned, use_gpu_prio=True),
                        "gpu_priorities": {t.name: t.gpu_priority
                                           for t in assigned.tasks}}
        return {"admitted": False, "via": None, "wcrt": rta(ts)}

    def release(self, name: str) -> bool:
        """Retire an admitted profile (its job left the platform) so its
        demand no longer charges future admissions."""
        for i, p in enumerate(self.admitted):
            if p.name == name:
                del self.admitted[i]
                return True
        return False
