"""RTA-driven admission control: before a new real-time job is accepted
onto the executor, its measured worst-case segment times are folded into
the current taskset and the paper's schedulability test decides.

This is where the paper's analysis becomes an operational guarantee: jobs
admitted here have analytically bounded response times under the chosen
scheduling approach (kthread/ioctl x busy/suspend), including the measured
runlist-update overhead epsilon.  On multi-device platforms
(``n_devices > 1``) the busy-wait RTAs resolve to the cross-device fixed
point (core/crossfix.py), so busy-mode admission is sound — not the
pre-fixed-point per-device heuristic.

The analysis matching each approach lives in the policy registry
(`core.policy.PolicySpec.rtas`), so the executor, the simulator, and the
admission controller all resolve one policy name to one consistent
(implementation, analysis) pair."""
from __future__ import annotations

import dataclasses
import math
import time
import warnings
from collections import deque
from dataclasses import dataclass
from typing import (Callable, Dict, Iterable, List, Mapping, Optional,
                    Sequence)

from ..core import GpuSegment, Task, Taskset
from ..core.analysis import _EPS, supports_kwarg
from ..core.audsley import assign_gpu_priorities
from ..core.policy import policy_spec
from ..core.segments import WorkloadProfile


class RecoveryConformanceError(RuntimeError):
    """Recovery re-ran admission over the journaled taskset and did NOT
    reproduce the recorded decisions — the store and the analysis have
    drifted (a changed RTA, a corrupted journal, a different platform
    config), so the journaled guarantees cannot be trusted.  The
    recovery path must refuse to come up rather than silently serve
    jobs whose admission evidence no longer holds (the durable analogue
    of tests/conformance.py's live↔simulated identity)."""


# Reason codes carried by every admission decision, in refusal order:
# the first gate that fires names the decision.
REASONS = ("accepted", "validation-refused", "headroom-fast-reject",
           "rta-reject")


class AdmissionDecision(dict):
    """Structured admission result (one decision of ``try_admit``).

    A ``dict`` subclass on purpose: every existing call site reads the
    mapping face (``res["admitted"]``, ``res.get("error")``,
    ``res["wcrt"]``) and the job store journals decisions verbatim as
    JSON — both keep working unchanged — while new code gets the typed
    surface: ``bool(decision)`` is the acceptance, ``.reason`` is one
    of :data:`REASONS`, ``.wcrt`` the RTA evidence, ``.device``/``.job``
    the binding ``ClusterExecutor`` attached.

    Keys always present: ``admitted`` (bool), ``reason``, ``via``
    (``"default"``/``"audsley"``/``"best_effort"``/None), ``wcrt``
    (task name → WCRT ms; empty when no fixed point ran).  Optional:
    ``error`` (human-readable refusal), ``gpu_priorities`` (Audsley
    assignment), ``latency_ms`` (decision-processing latency measured
    by the controller — presentation, never compared by
    :func:`decisions_match`), ``device`` (binding), ``job`` (the live
    RTJob — stripped before journaling)."""

    def __init__(self, *args, **kw):
        super().__init__(*args, **kw)
        self.setdefault("admitted", False)
        self.setdefault("reason",
                        "accepted" if self["admitted"] else "rta-reject")
        self.setdefault("via", None)
        self.setdefault("wcrt", {})
        if self["reason"] not in REASONS:
            raise ValueError(f"unknown reason code {self['reason']!r} "
                             f"(expected one of {REASONS})")
        if self["admitted"] != (self["reason"] == "accepted"):
            raise ValueError(
                f"admitted={self['admitted']} contradicts "
                f"reason={self['reason']!r}")

    # -- typed face ------------------------------------------------------
    def __bool__(self) -> bool:
        return bool(self["admitted"])

    @property
    def accepted(self) -> bool:
        return bool(self["admitted"])

    @property
    def reason(self) -> str:
        return self["reason"]

    @property
    def via(self) -> Optional[str]:
        return self["via"]

    @property
    def wcrt(self) -> dict:
        return self["wcrt"]

    @property
    def error(self) -> Optional[str]:
        return self.get("error")

    @property
    def device(self) -> Optional[int]:
        return self.get("device")

    @property
    def job(self):
        return self.get("job")

    # -- helpers ---------------------------------------------------------
    @classmethod
    def accept(cls, via: str, wcrt: Optional[dict] = None,
               **extra) -> "AdmissionDecision":
        return cls(admitted=True, reason="accepted", via=via,
                   wcrt=dict(wcrt or {}), **extra)

    @classmethod
    def refuse(cls, reason: str, *, error: Optional[str] = None,
               wcrt: Optional[dict] = None, **extra) -> "AdmissionDecision":
        d = cls(admitted=False, reason=reason, via=None,
                wcrt=dict(wcrt or {}), **extra)
        if error is not None:
            d["error"] = error
        return d

    def bound(self, device: Optional[int], job=None) -> "AdmissionDecision":
        """A copy with the placement attached (``ClusterExecutor``'s
        admit→place→bind result)."""
        out = AdmissionDecision(self)
        out["device"] = device
        out["job"] = job
        return out

    def journal_form(self) -> dict:
        """The JSON-serializable view the job store appends verbatim:
        everything except the live RTJob handle."""
        return {k: v for k, v in self.items() if k != "job"}


def decisions_match(a: Mapping, b: Mapping, tol: float = 1e-6) -> bool:
    """Decision identity for recovery conformance: same acceptance,
    reason, via, Audsley assignment, and WCRT evidence (to ``tol``,
    inf-for-inf).  ``device``/``job``/``error`` wording are excluded —
    placement is compared separately by the recovery path and the
    refusal text is presentation, not evidence."""
    if (bool(a.get("admitted")) != bool(b.get("admitted"))
            or a.get("reason") != b.get("reason")
            or a.get("via") != b.get("via")
            or a.get("gpu_priorities") != b.get("gpu_priorities")):
        return False
    wa, wb = a.get("wcrt") or {}, b.get("wcrt") or {}
    if set(wa) != set(wb):
        return False
    for k, va in wa.items():
        vb = wb[k]
        va = math.inf if va is None else float(va)
        vb = math.inf if vb is None else float(vb)
        if math.isinf(va) or math.isinf(vb):
            if va != vb:
                return False
        elif abs(va - vb) > tol:
            return False
    return True


def nearest_rank(sorted_vals: Sequence[float], q: float) -> float:
    """Nearest-rank percentile over an ascending-sorted sample: the
    smallest element with at least ``q`` of the sample at or below it
    (index ``ceil(q*n) - 1``).  The naive ``int(q*n)`` index is biased
    one rank high — at n <= 100 its p99 is the window *maximum*."""
    if not sorted_vals:
        raise ValueError("percentile of an empty sample")
    return sorted_vals[max(0, math.ceil(q * len(sorted_vals)) - 1)]


def rta_for(policy: str, wait_mode: str) -> Callable:
    """Resolve the RTA guaranteeing (approach, wait mode); accepts registry
    names and the executor's legacy mode names ("notify"/"poll")."""
    spec = policy_spec(policy)
    try:
        return spec.rtas[wait_mode]
    except KeyError:
        raise ValueError(
            f"approach {spec.name!r} has no analysis for "
            f"wait_mode={wait_mode!r} (available: {sorted(spec.rtas)})")


@dataclass
class JobProfile:
    """Measured WCETs of one job (ms): host segments and device segments
    (launch misc + pure device time)."""
    name: str
    host_segments_ms: List[float]
    device_segments_ms: List[tuple]  # (misc_ms, exec_ms)
    period_ms: float
    priority: int
    cpu: int = 0
    deadline_ms: Optional[float] = None
    best_effort: bool = False
    device: int = 0  # accelerator the device segments execute on
    #: criticality tier (observability grouping + the shedding ladder's
    #: primary victim key; per-tier budgets in `sched.elastic` key on
    #: it).  Higher = more valuable; never consulted by any RTA.
    tier: int = 0

    def to_task(self) -> Task:
        return Task(
            name=self.name,
            cpu_segments=self.host_segments_ms,
            gpu_segments=[GpuSegment(m, e) for m, e in
                          self.device_segments_ms],
            period=self.period_ms,
            deadline=self.deadline_ms or self.period_ms,
            cpu=self.cpu, priority=self.priority,
            best_effort=self.best_effort, device=self.device)

    @classmethod
    def from_workload(cls, wp: "WorkloadProfile", period_ms: float,
                      priority: int, *, cpu: int = 0,
                      deadline_ms: Optional[float] = None,
                      best_effort: bool = False, device: int = 0,
                      tier: int = 0,
                      margin: float = 1.2) -> "JobProfile":
        """Build the admission profile from a *measured*
        ``core.segments.WorkloadProfile`` (host segment times + per-slice
        device times), inflated by ``margin`` — observations are not
        WCETs.  This is the end of the measured pipeline: real sliced
        kernel → per-slice times → η/G segments → RTA admission."""
        host, dev = wp.segments_ms(margin)
        return cls(name=wp.name,
                   host_segments_ms=host or [0.0],
                   device_segments_ms=dev,
                   period_ms=period_ms, priority=priority, cpu=cpu,
                   deadline_ms=deadline_ms, best_effort=best_effort,
                   device=device, tier=tier)

    def to_dict(self) -> dict:
        """JSON-serializable form (the job store journals profiles)."""
        d = dataclasses.asdict(self)
        d["device_segments_ms"] = [list(s) for s in
                                   self.device_segments_ms]
        return d

    @classmethod
    def from_dict(cls, d: Mapping) -> "JobProfile":
        """Inverse of :meth:`to_dict` (JSON round-trips tuples as
        lists; ``to_task`` unpacks either, but recovery compares
        profiles by value so the shape is normalized here)."""
        d = dict(d)
        d["device_segments_ms"] = [tuple(s) for s in
                                   d["device_segments_ms"]]
        return cls(**d)


def headroom_violation(ts: Taskset, headroom: float = 1.0
                       ) -> Optional[str]:
    """Utilization fast-reject: the long-run RT demand each CPU core and
    each accelerator must serve, against a ``headroom`` capacity bound.

    This is a *necessary* condition, so refusing on it is sound: a core
    charges at least C + G^m per period for every RT task bound to it
    (the suspend-mode floor — busy-waiting only adds demand), and a
    device serves G^e per period for every RT task targeting it.  If
    either exceeds 1.0, backlog grows without bound and every RTA in
    the registry diverges to a refusal anyway — the gate just refuses
    *before* any fixed point runs.  ``headroom < 1.0`` reserves slack
    (a conservative gate that can refuse RTA-acceptable sets).

    Returns a human-readable reason, or None when the gate passes.
    """
    cpu_u: dict = {}
    dev_u: dict = {}
    for t in ts.rt_tasks:
        cpu_u[t.cpu] = cpu_u.get(t.cpu, 0.0) + (t.C + t.Gm) / t.period
        if t.uses_gpu:
            dev_u[t.device] = dev_u.get(t.device, 0.0) + t.Ge / t.period
    for core, u in sorted(cpu_u.items()):
        if u > headroom + _EPS:
            return (f"RT utilization {u:.3f} on core {core} exceeds "
                    f"headroom {headroom:g}")
    for dev, u in sorted(dev_u.items()):
        if u > headroom + _EPS:
            return (f"RT utilization {u:.3f} on device {dev} exceeds "
                    f"headroom {headroom:g}")
    return None


class AdmissionController:
    """RTA gatekeeper with *incremental* decision state (DESIGN.md §11).

    Every decision used to rebuild the full :class:`Taskset` from
    scratch and run its fixed point cold from zero.  The controller now
    keeps three kinds of persistent state so a streaming decision costs
    O(new work):

      * **built tasks** — each admitted profile's :class:`Task` is
        converted once and reused by every later ``_taskset()`` build;
      * **running utilization totals** — per-core and per-device RT
        demand (the exact sums ``headroom_violation`` re-derives) plus
        per-device profile load, maintained add-on-admit and recounted
        on release, so the headroom gate and placement load queries
        stop re-summing the admitted set;
      * **warm-start seeds** — the admitted set's *converged* WCRT dict
        under the default (RM-priority) recurrence.  Admitting a task
        only **adds** interference, so the previous fixed point sits at
        or below the new one component-wise and is a sound Kleene seed
        (`analysis._iterate`); the candidate itself seeds from zero.
        Any **removal** (``release`` of an RT profile, shedding,
        ``fail_device`` epoch reset via the ``admitted`` setter) shrinks
        interference, leaving cached bounds *above* the new fixed point
        — the unsound direction (see `core/audsley.py`) — so the cache
        is invalidated and the next decision re-solves cold.  An
        Audsley accept also invalidates: its bounds hold under the
        reassigned GPU priorities, not the default recurrence the next
        RM test runs.  Seeds are used on single-device platforms only
        (multi-device merged bounds are not per-projection lower
        bounds; `analysis.per_device` / `analysis.cross_device` drop
        them defensively).

    ``warm_start=False`` reverts the *decision path* to the
    from-scratch baseline this PR replaced — every decision re-converts
    every admitted profile, re-sums the headroom utilizations from the
    built taskset, and runs its fixed point cold from zero — so warm vs
    cold decision identity is directly testable
    (tests/test_admission_warm.py) and the incremental state's payoff
    is directly benchmarkable (benchmarks/admission_bench.py).  The
    bookkeeping itself stays maintained either way: ``release``,
    ``device_utilization`` and the latency window serve both modes."""

    #: sliding window of per-decision latencies kept for the summary
    LATENCY_WINDOW = 4096

    def __init__(self, policy: Optional[str] = None,
                 wait_mode: str = "suspend",
                 n_cpus: int = 4, epsilon_ms: float = 1.0,
                 try_gpu_priorities: bool = True, n_devices: int = 1,
                 headroom: float = 1.0, warm_start: bool = True,
                 mode: Optional[str] = None):
        if mode is not None:
            if policy is not None:
                raise ValueError("pass policy= alone, not with the "
                                 "deprecated mode= alias")
            warnings.warn(
                "AdmissionController(mode=...) is deprecated; pass a "
                "registry policy name (policy=...)",
                DeprecationWarning, stacklevel=2)
            policy = mode
        # canonical registry name (legacy executor labels map through
        # the registry), so export_config round-trips one spelling
        self.policy = policy_spec(policy or "ioctl").name
        self.wait_mode = wait_mode
        self.rta = rta_for(self.policy, wait_mode)
        self.n_cpus = n_cpus
        self.epsilon_ms = epsilon_ms
        self.try_gpu_priorities = try_gpu_priorities
        self.n_devices = n_devices
        self.headroom = headroom
        self.warm_start = warm_start
        self._admitted: List[JobProfile] = []
        self._names: set = set()
        self._tasks: Dict[str, Task] = {}
        self._cpu_util: Dict[int, float] = {}   # RT (C+Gm)/T per core
        self._dev_util: Dict[int, float] = {}   # RT Ge/T per device
        self._load_all: Dict[int, float] = {}   # profile load per device
        self._load_rt: Dict[int, float] = {}    # ... RT profiles only
        self._warm: Optional[Dict[str, Optional[float]]] = None
        self._latencies: deque = deque(maxlen=self.LATENCY_WINDOW)
        self._n_decisions = 0

    @property
    def mode(self) -> str:
        """Backward-compatible read alias of :attr:`policy` (the
        constructor's ``mode=`` spelling is deprecated)."""
        return self.policy

    # ------------------------------------------------------------------
    # incremental bookkeeping
    # ------------------------------------------------------------------
    @property
    def admitted(self) -> List[JobProfile]:
        """Admitted profiles in admission order.  Assigning to this
        property replaces the set wholesale (the fail-over epoch reset
        in `sched/cluster.py` does), rebuilding the bookkeeping and
        invalidating the warm-start cache — cached bounds from the old
        set are not lower bounds for an arbitrary new one."""
        return self._admitted

    @admitted.setter
    def admitted(self, profs: Iterable[JobProfile]) -> None:
        self._admitted = list(profs)
        self._tasks = {p.name: p.to_task() for p in self._admitted}
        self._names = set(self._tasks)
        self._warm = None
        self._recount()

    def _charge(self, prof: JobProfile, task: Task) -> None:
        """Add one admitted profile to the running totals (the same
        accumulation order a cold re-sum over the admitted list would
        use, so incremental and from-scratch floats are bit-equal)."""
        from .elastic import profile_utilization
        u = profile_utilization(prof)
        self._load_all[prof.device] = \
            self._load_all.get(prof.device, 0.0) + u
        if task.is_rt:
            self._load_rt[prof.device] = \
                self._load_rt.get(prof.device, 0.0) + u
            self._cpu_util[task.cpu] = (self._cpu_util.get(task.cpu, 0.0)
                                        + (task.C + task.Gm) / task.period)
            if task.uses_gpu:
                self._dev_util[task.device] = \
                    (self._dev_util.get(task.device, 0.0)
                     + task.Ge / task.period)

    def _recount(self) -> None:
        """Rebuild the running totals from the admitted list.  Used on
        removal instead of subtracting: re-accumulating in admission
        order reproduces exactly the floats a freshly built controller
        would hold, so warm/cold decision identity survives float
        non-associativity at the headroom boundary."""
        self._cpu_util, self._dev_util = {}, {}
        self._load_all, self._load_rt = {}, {}
        for p in self._admitted:
            self._charge(p, self._tasks[p.name])

    def _register(self, prof: JobProfile, task: Task) -> None:
        self._admitted.append(prof)
        self._names.add(prof.name)
        self._tasks[prof.name] = task
        self._charge(prof, task)

    def _build_taskset(self, extra_tasks: List[Task]) -> Taskset:
        if self.warm_start:
            tasks = [self._tasks[p.name] for p in self._admitted]
        else:
            # faithful from-scratch baseline: re-convert every admitted
            # profile per decision, exactly what every decision paid
            # before the incremental state existed (to_task is pure, so
            # the Tasksets — and decisions — are identical either way)
            tasks = [p.to_task() for p in self._admitted]
        tasks.extend(extra_tasks)
        return Taskset(tasks, n_cpus=self.n_cpus,
                       epsilon=self.epsilon_ms,
                       kthread_cpu=self.n_cpus,  # dedicated scheduler core
                       n_devices=self.n_devices)

    def _taskset(self, *extra: JobProfile) -> Taskset:
        return self._build_taskset([p.to_task() for p in extra])

    def _headroom_reason(self, task: Optional[Task],
                         cpu_util: Optional[Dict[int, float]] = None,
                         dev_util: Optional[Dict[int, float]] = None
                         ) -> Optional[str]:
        """`headroom_violation` on the running totals plus one candidate
        — O(cores + devices) instead of O(admitted tasks), same refusal
        text, same first-violation order (cores then devices, sorted)."""
        cpu_u = dict(cpu_util if cpu_util is not None else self._cpu_util)
        dev_u = dict(dev_util if dev_util is not None else self._dev_util)
        if task is not None and task.is_rt:
            cpu_u[task.cpu] = (cpu_u.get(task.cpu, 0.0)
                               + (task.C + task.Gm) / task.period)
            if task.uses_gpu:
                dev_u[task.device] = (dev_u.get(task.device, 0.0)
                                      + task.Ge / task.period)
        for core, u in sorted(cpu_u.items()):
            if u > self.headroom + _EPS:
                return (f"RT utilization {u:.3f} on core {core} exceeds "
                        f"headroom {self.headroom:g}")
        for dev, u in sorted(dev_u.items()):
            if u > self.headroom + _EPS:
                return (f"RT utilization {u:.3f} on device {dev} exceeds "
                        f"headroom {self.headroom:g}")
        return None

    def _seed_dict(self) -> Optional[Dict[str, float]]:
        """Warm-start seeds for the next decision, or None when cold.
        Existing tasks seed from their cached converged WCRT (a lower
        bound of the grown fixed point — admission only adds
        interference); the candidate is absent and seeds from its zero
        floor inside the solver.  Single-device only: a merged
        multi-device bound is not a lower bound of each projection."""
        if (not self.warm_start or self._warm is None
                or self.n_devices != 1):
            return None
        seeds = {k: v for k, v in self._warm.items()
                 if v is not None and math.isfinite(v)}
        return seeds or None

    def _stamp(self, dec: AdmissionDecision,
               t0: float) -> AdmissionDecision:
        lat = (time.perf_counter() - t0) * 1e3
        dec["latency_ms"] = lat
        self._latencies.append(lat)
        self._n_decisions += 1
        return dec

    def latency_summary(self) -> dict:
        """Decision-latency percentiles over the sliding window — the
        live counterpart of benchmarks/admission_bench.py's metric,
        surfaced through ``ClusterExecutor.stats()`` / the daemon's
        status reply / ``SchedClient.admission_latency()``."""
        lat = sorted(self._latencies)
        if not lat:
            return {"decisions": self._n_decisions, "window": 0}

        def pct(q: float) -> float:
            return nearest_rank(lat, q)

        return {"decisions": self._n_decisions,
                "window": len(lat),
                "mean_ms": sum(lat) / len(lat),
                "p50_ms": pct(0.50),
                "p99_ms": pct(0.99),
                "max_ms": lat[-1]}

    # ------------------------------------------------------------------
    # decisions
    # ------------------------------------------------------------------
    def try_admit(self, prof: JobProfile) -> AdmissionDecision:
        """Returns an :class:`AdmissionDecision` (a dict with keys
        ``admitted``/``reason``/``via``/``wcrt``/…, so historical
        ``res["admitted"]`` call sites read it unchanged).
        Best-effort jobs are always admitted (they have no guarantee) —
        but still validated, or an unbuildable profile would poison every
        later ``_taskset()`` build."""
        t0 = time.perf_counter()
        return self._stamp(self._try_admit(prof), t0)

    def _try_admit(self, prof: JobProfile) -> AdmissionDecision:
        if not (0 <= prof.device < self.n_devices):
            # refuse, don't crash: a bad profile must not take down the
            # admission path (Taskset validation would raise), nor may it
            # be appended and poison every later _taskset() build
            return AdmissionDecision.refuse(
                "validation-refused",
                error=f"device {prof.device} out of range for "
                      f"{self.n_devices}-device platform")
        if prof.name in self._names:
            # a duplicate name would silently merge WCRT dict entries
            return AdmissionDecision.refuse(
                "validation-refused",
                error=f"job name {prof.name!r} already admitted")
        try:
            # same refuse-don't-crash rule for every other profile defect
            # Taskset validation catches (colliding priorities, bad cpu):
            # a live gatekeeper must return a refusal, not raise
            task = prof.to_task()
            ts = self._build_taskset([task])
        except ValueError as e:
            return AdmissionDecision.refuse("validation-refused",
                                            error=str(e))
        if prof.best_effort:
            # BE tasks never interfere analytically, so the RT fixed
            # point — and the warm cache — are untouched by this accept
            self._register(prof, task)
            return AdmissionDecision.accept("best_effort")
        if self.warm_start:
            reason = self._headroom_reason(task)
        else:
            # from-scratch baseline: re-sum the built taskset.  Both
            # accumulate in admission order, so the sums — and the
            # boundary-case decisions — are bit-equal.
            reason = headroom_violation(ts, self.headroom)
        if reason is not None:
            # the fast-reject: a hopeless taskset never reaches a fixed
            # point (wcrt stays empty — nothing was computed)
            return AdmissionDecision.refuse("headroom-fast-reject",
                                            error=reason)
        rta = self.rta
        seeds = self._seed_dict()
        if seeds is not None and supports_kwarg(rta, "seeds"):
            R = rta(ts, seeds=seeds)
        else:
            R = rta(ts)
        if self._accepts(ts, R):
            self._register(prof, task)
            # commit the freshly converged bounds: they are the admitted
            # set's fixed point and seed the next grown decision
            self._warm = dict(R)
            return AdmissionDecision.accept("default", R)
        return self._reject_or_retry(prof, task, ts, R)

    def _reject_or_retry(self, prof: JobProfile, task: Task,
                         ts: Taskset, R: dict) -> AdmissionDecision:
        """RM-test failure tail shared by the scalar and batched paths:
        the Audsley retry, else the refusal carrying the failed bounds.
        ``R`` is the already-converged default-recurrence WCRT dict for
        ``ts`` — the batched path hands over its solver's vector so the
        refusal never re-runs the fixed point it just watched fail."""
        if self.try_gpu_priorities:
            assigned = assign_gpu_priorities(ts, self.rta)
            if assigned is not None:
                self._register(prof, task)
                # Audsley bounds hold under the *reassigned* GPU
                # priorities — not lower bounds of the default
                # recurrence the next RM test runs — so go cold
                self._warm = None
                return AdmissionDecision.accept(
                    "audsley", self.rta(assigned, use_gpu_prio=True),
                    gpu_priorities={t.name: t.gpu_priority
                                    for t in assigned.tasks})
        return AdmissionDecision.refuse("rta-reject", wcrt=R)

    def try_admit_many(self, profs: Iterable[JobProfile], *,
                       backend: str = "numpy") -> List[AdmissionDecision]:
        """Admit an arrival burst in order, batching the RTA fixed
        points through `core/batch.py` (``backend="jax"`` lowers them
        to the jit-compiled device kernels — the streaming-admission
        fast path).

        Decision-identical to calling ``try_admit`` per profile: the
        burst is analyzed under *optimistic prefix* tasksets — profile
        k is tested against admitted + burst[:k+1] — which is exactly
        the sequential state while every earlier profile is being
        admitted.  A best-effort job, validation defect, or headroom
        refusal at the burst head goes through the sequential path for
        the exact decision dict.  An *RTA* refusal reuses the bounds
        the batch just converged for that very taskset — the shared
        tail runs the Audsley retry (or builds the refusal) without
        re-running the fixed point it watched fail.  Either way the
        remainder re-batches against the updated state.  WCRTs in
        batched results are the batch solver's vectors (value-equal to
        the scalar ones to float tolerance, inf-for-inf).

        The prefix batches share the controller's warm-start seeds
        (every prefix grows the same admitted set, so the cached bounds
        are lower bounds for all of them); the accepted prefix's last
        WCRT vector — the new admitted set's fixed point — is committed
        back into the cache.  Batched decisions carry ``latency_ms``
        measured from the start of their batch round."""
        profs = list(profs)
        kind = getattr(self.rta, "batch_kind", None)
        if kind is None or len(profs) <= 1:
            return [self.try_admit(p) for p in profs]
        from ..core.batch import batch_rta, batch_rta_prefixes
        results: List[AdmissionDecision] = []
        i = 0
        while i < len(profs):
            t0 = time.perf_counter()
            run: List[JobProfile] = []
            run_tasks: List[Task] = []
            run_names: set = set()
            tss: List[Taskset] = []
            cpu_u = dict(self._cpu_util)
            dev_u = dict(self._dev_util)
            j = i
            while j < len(profs):
                p = profs[j]
                if (p.best_effort
                        or not (0 <= p.device < self.n_devices)
                        or p.name in self._names
                        or p.name in run_names):
                    break
                try:
                    task = p.to_task()
                    ts = self._build_taskset(run_tasks + [task])
                except ValueError:
                    break
                if self.warm_start:
                    reason = self._headroom_reason(task, cpu_u, dev_u)
                else:
                    reason = headroom_violation(ts, self.headroom)
                if reason is not None:
                    break
                run.append(p)
                run_tasks.append(task)
                run_names.add(p.name)
                tss.append(ts)
                if task.is_rt:
                    cpu_u[task.cpu] = (cpu_u.get(task.cpu, 0.0)
                                       + (task.C + task.Gm) / task.period)
                    if task.uses_gpu:
                        dev_u[task.device] = (dev_u.get(task.device, 0.0)
                                              + task.Ge / task.period)
                j += 1
            if not run:
                # burst head needs non-RTA handling (best-effort,
                # refusal): sequential produces the exact result dict
                results.append(self.try_admit(profs[i]))
                i += 1
                continue
            seed = self._seed_dict()
            if self.warm_start and self.n_devices == 1:
                # the run's prefix problems share the admitted set as a
                # common base: pack it once and expand by valid-mask
                # (bit-identical to batch_rta over the prefix tasksets)
                wcrts = batch_rta_prefixes(kind, tss[-1], len(run),
                                           backend=backend, seeds=seed)
            else:
                wcrts = batch_rta(
                    kind, tss, backend=backend,
                    seeds=None if seed is None else [seed] * len(tss))
            k = 0
            while k < len(run) and self._accepts(tss[k], wcrts[k]):
                k += 1
            for p, task, w in zip(run[:k], run_tasks[:k], wcrts[:k]):
                self._register(p, task)
                results.append(self._stamp(
                    AdmissionDecision.accept("default", w), t0))
            if k:
                self._warm = dict(wcrts[k - 1])
            i += k
            if k < len(run):
                # first refusal: its taskset is tss[k] exactly (the
                # accepted prefix was just registered), so hand the
                # batch's already-converged bounds to the shared tail —
                # Audsley retry or refusal — instead of re-running the
                # scalar fixed point the batch just watched fail
                results.append(self._stamp(self._reject_or_retry(
                    run[k], run_tasks[k], tss[k], wcrts[k]), t0))
                i += 1
        return results

    @staticmethod
    def _accepts(ts: Taskset, R: dict) -> bool:
        """`analysis.schedulable`'s accept criterion on a WCRT dict."""
        for t in ts.rt_tasks:
            r = R.get(t.name, math.inf)
            if r is None or math.isinf(r) or r > t.deadline + _EPS:
                return False
        return True

    def release(self, name: str) -> bool:
        """Retire an admitted profile (its job left the platform) so its
        demand no longer charges future admissions.

        Removing an RT profile *shrinks* interference: the cached
        converged bounds now sit above the new fixed point — the
        unsound seed direction — so the warm cache is invalidated and
        the next decision re-solves cold (and repopulates the cache on
        accept).  A best-effort release keeps the cache: BE tasks never
        enter the RT recurrences, so the fixed point is unchanged."""
        for i, p in enumerate(self._admitted):
            if p.name == name:
                del self._admitted[i]
                self._names.discard(name)
                task = self._tasks.pop(name)
                if task.is_rt:
                    self._warm = None
                self._recount()
                return True
        return False

    def on_device(self, device: int) -> List[JobProfile]:
        """Admitted profiles bound to ``device`` (RT and best-effort)."""
        return [p for p in self._admitted if p.device == device]

    def device_utilization(self, device: int, *,
                           include_best_effort: bool = True) -> float:
        """Total admitted GPU utilization on ``device`` — the overload
        metric of the shedding ladder (`sched.elastic`).  Unlike every
        RTA input, this *includes* best-effort demand by default: BE
        tasks never interfere analytically, but they do occupy the
        device at runtime.  O(1): served from the running per-device
        totals the bookkeeping maintains."""
        loads = self._load_all if include_best_effort else self._load_rt
        return loads.get(device, 0.0)

    # ------------------------------------------------------------------
    # durable state: export / rebuild (sched/store.py, sched/daemon.py)
    # ------------------------------------------------------------------
    def export_config(self) -> dict:
        """The constructor arguments that reproduce this controller's
        platform model — journaled by the job store so recovery builds
        an identically configured gatekeeper."""
        return {"policy": self.policy, "wait_mode": self.wait_mode,
                "n_cpus": self.n_cpus, "epsilon_ms": self.epsilon_ms,
                "try_gpu_priorities": self.try_gpu_priorities,
                "n_devices": self.n_devices, "headroom": self.headroom}

    def export_state(self) -> dict:
        """Config + the admitted profiles in admission order (the order
        *is* state: each decision was taken against the prefix)."""
        return {"config": self.export_config(),
                "admitted": [p.to_dict() for p in self.admitted]}

    @classmethod
    def rebuild(cls, config: Mapping, entries: Iterable[Mapping], *,
                conform: bool = True) -> "AdmissionController":
        """Rebuild a controller from journaled state by *re-running*
        admission over the journaled profiles in their recorded order.

        Each ``entry`` is ``{"profile": ..., "decision": ...}`` as the
        job store recorded it.  With ``conform=True`` (the recovery
        default) every re-derived decision must be decision-identical
        to the recorded one (:func:`decisions_match` — acceptance,
        reason, via, Audsley assignment, WCRT evidence to tolerance) or
        :class:`RecoveryConformanceError` is raised: an admitted RT
        job's guarantee survives a crash only if the analysis still
        proves it."""
        config = dict(config)
        if "mode" in config:
            # journals from before the policy= rename carry "mode";
            # normalize silently — a compatibility read, not a new use
            # of the deprecated alias
            config.setdefault("policy", config.pop("mode"))
        ctl = cls(**config)
        for n, entry in enumerate(entries):
            prof = JobProfile.from_dict(entry["profile"])
            recorded = entry.get("decision")
            redone = ctl.try_admit(prof)
            if not redone["admitted"]:
                raise RecoveryConformanceError(
                    f"journaled job {prof.name!r} (entry {n}) refused on "
                    f"re-admission: {redone.get('error') or redone['wcrt']}")
            if conform and recorded is not None \
                    and not decisions_match(redone, recorded):
                raise RecoveryConformanceError(
                    f"journaled job {prof.name!r} (entry {n}): recovered "
                    f"decision {redone.journal_form()} does not reproduce "
                    f"the recorded decision {dict(recorded)}")
        return ctl
