"""Fault tolerance: heartbeat watchdog, device health escalation,
restart-from-checkpoint, and straggler mitigation.

On a real pod, node failure surfaces as a stuck or failed collective; here
the same control flow is driven by exceptions from the step function and by
heartbeat staleness.  The contract: the trainer's step loop is wrapped by
``FaultTolerantLoop.run_step`` — any step failure rolls back to the newest
checkpoint and replays; ``Heartbeat`` detects silent stalls (deadlocked
collectives) and raises in the main loop; chunk-level re-dispatch
(``with_retry``) bounds straggler impact for idempotent device work.

The scheduler-side failure model (DESIGN.md §10) builds on the same
primitives: :class:`DeviceHealth` is the per-device slice-level heartbeat
with a **stall → suspect → failed** escalation ladder —
``sched.executor.DeviceExecutor.run_sliced`` arms it around every
dispatch, ``sched.cluster.ClusterExecutor``'s health monitor polls it and
opens a fail-over binding epoch when a device is declared failed.
:class:`FaultContained` is the exception family an ``RTJob`` absorbs as
an *orderly* stop (eviction under load shedding, a failed device) rather
than an anonymous dead thread.
"""
from __future__ import annotations

import random
import threading
import time
from dataclasses import dataclass, field
from typing import Any, Callable, List, Optional, Tuple

from . import checkpointer


class StallError(RuntimeError):
    pass


class FaultContained(RuntimeError):
    """Base of the orderly-stop exception family: raised through a job
    body when the platform (not the job) decided the job must stop —
    ``RTJob`` catches it, records the reason, and ends the job cleanly
    instead of leaking a dead thread (no silent job loss)."""


class JobEvicted(FaultContained):
    """The job was evicted mid-segment (load shedding / drain): its
    latest checkpointed carry is the resume point."""


class DeviceFailedError(FaultContained):
    """The device this job is bound to was declared failed; the cluster
    re-runs the job's admission against the surviving devices."""


class Heartbeat:
    """Watchdog: the worker beats every step; a monitor thread flags a
    stall when the last beat is older than ``timeout_s``.  A beat clears
    a previously flagged stall — a recovered worker is not permanently
    poisoned (``check()`` only raises while the stall is current)."""

    def __init__(self, timeout_s: float = 30.0):
        self.timeout_s = timeout_s
        self._last = time.monotonic()
        self._stalled = False
        self._stop = threading.Event()
        self._thread = threading.Thread(target=self._watch, daemon=True)
        self._thread.start()

    def beat(self) -> None:
        self._last = time.monotonic()
        self._stalled = False

    def check(self) -> None:
        if self._stalled:
            raise StallError("heartbeat timeout — presumed node failure")

    def _watch(self) -> None:
        while not self._stop.is_set():
            if time.monotonic() - self._last > self.timeout_s:
                self._stalled = True
            time.sleep(min(self.timeout_s / 4, 1.0))

    def stop(self) -> None:
        self._stop.set()


def with_retry(fn: Callable, n_retries: int = 2,
               timeout_s: Optional[float] = None,
               backoff_s: float = 0.05, max_backoff_s: float = 2.0,
               rng: Optional[random.Random] = None) -> Callable:
    """Straggler mitigation for idempotent device work: re-dispatch on
    failure (the REEF-style reset degenerates to re-running idempotent
    programs, cf. DESIGN.md).

    ``timeout_s`` is a *per-attempt* deadline, enforced: the call runs on
    a worker thread and an attempt that exceeds the deadline counts as a
    failure (``StallError``) and is retried.  Because the stalled attempt
    cannot be interrupted, the wrapped work must be idempotent — which is
    this helper's contract anyway.  Retries are spaced by jittered
    exponential backoff (``backoff_s * 2**attempt``, capped at
    ``max_backoff_s``, jittered uniformly in [0.5x, 1.5x]) so a burst of
    stragglers does not re-dispatch in lockstep."""
    rng = rng or random.Random()

    def attempt(a, kw):
        if timeout_s is None:
            return fn(*a, **kw)
        box: dict = {}

        def work():
            try:
                box["ret"] = fn(*a, **kw)
            except Exception as e:  # noqa: BLE001 — relayed to caller
                box["err"] = e

        t = threading.Thread(target=work, daemon=True)
        t.start()
        t.join(timeout_s)
        if t.is_alive():
            raise StallError(
                f"attempt exceeded timeout_s={timeout_s:g} — presumed "
                "straggler; re-dispatching (work must be idempotent)")
        if "err" in box:
            raise box["err"]
        return box.get("ret")

    def wrapped(*a, **kw):
        err: Optional[BaseException] = None
        for i in range(n_retries + 1):
            try:
                return attempt(a, kw)
            except FaultContained:
                raise           # an orderly platform stop is not a straggler
            except Exception as e:  # noqa: BLE001 — deliberate catch-all
                err = e
            if i < n_retries:
                delay = min(backoff_s * (2 ** i), max_backoff_s)
                time.sleep(delay * rng.uniform(0.5, 1.5))
        raise err

    return wrapped


# --------------------------------------------------------------------------
# per-device health: slice-level heartbeat + stall→suspect→failed ladder
# --------------------------------------------------------------------------

HEALTHY, SUSPECT, FAILED = "healthy", "suspect", "failed"


@dataclass
class HealthConfig:
    """Escalation thresholds for :class:`DeviceHealth` (DESIGN.md §10).

    A slice in flight longer than ``stall_timeout_s`` without a beat
    moves the device to *suspect*; a suspect device that still has not
    beaten after another ``fail_timeout_s`` is declared *failed*.  A beat
    while suspect de-escalates back to healthy.  ``error_threshold``
    slice exceptions (cumulative) also declare the device failed.
    ``poll_interval_s`` is the cluster health monitor's cadence;
    ``auto_failover`` lets the monitor call
    ``ClusterExecutor.fail_device`` itself on a failed verdict.  A
    fail-over bumps the binding epoch and reassigns the controller's
    admitted set wholesale, which drops its warm-start WCRT cache
    (removal is the unsound seed direction, DESIGN.md §11); the
    re-admission sweep that rebinds survivors repopulates it."""
    stall_timeout_s: float = 5.0
    fail_timeout_s: float = 5.0
    error_threshold: int = 3
    poll_interval_s: float = 0.1
    auto_failover: bool = True


class DeviceHealth:
    """Slice-level health of one device executor.

    Armed only while a dispatch is in flight (an idle device is not
    stalling); every slice completion beats.  ``check()`` advances the
    stall → suspect → failed ladder and returns the current state —
    transitions are recorded in ``transitions`` for the audit trail."""

    def __init__(self, device: int, config: Optional[HealthConfig] = None):
        self.device = device
        self.config = config or HealthConfig()
        self.state = HEALTHY
        self.errors: List[str] = []
        self.transitions: List[Tuple[float, str, str, str]] = []
        self._lock = threading.Lock()
        self._inflight: Optional[Tuple[str, int]] = None  # (job, slice)
        self._last_beat = time.monotonic()
        self._suspect_since: Optional[float] = None

    # -- executor-side hooks (called around every dispatch) -------------
    def slice_begin(self, job: str, slice_idx: int) -> None:
        with self._lock:
            self._inflight = (job, slice_idx)
            self._last_beat = time.monotonic()

    def beat(self) -> None:
        with self._lock:
            self._last_beat = time.monotonic()
            if self.state == SUSPECT:
                # the ladder runs both ways: a beat from a recovered
                # device clears the suspicion (cf. Heartbeat.beat)
                self._to(HEALTHY, "beat received while suspect")
                self._suspect_since = None

    def slice_end(self) -> None:
        with self._lock:
            self._inflight = None
            self._last_beat = time.monotonic()
            if self.state == SUSPECT:
                self._to(HEALTHY, "slice completed while suspect")
                self._suspect_since = None

    def record_error(self, job: str, exc: BaseException) -> None:
        with self._lock:
            self.errors.append(f"{job}: {type(exc).__name__}: {exc}")
            if (self.state != FAILED
                    and len(self.errors) >= self.config.error_threshold):
                self._to(FAILED,
                         f"{len(self.errors)} slice exceptions "
                         f"(threshold {self.config.error_threshold})")

    # -- monitor-side ----------------------------------------------------
    def check(self, now: Optional[float] = None) -> str:
        now = time.monotonic() if now is None else now
        with self._lock:
            if self.state == FAILED or self._inflight is None:
                return self.state
            stale = now - self._last_beat
            if self.state == HEALTHY \
                    and stale > self.config.stall_timeout_s:
                job, s = self._inflight
                self._to(SUSPECT, f"slice {s} of {job!r} stalled "
                                  f"{stale:.2f}s")
                self._suspect_since = now
            elif self.state == SUSPECT and self._suspect_since is not None \
                    and now - self._suspect_since \
                    > self.config.fail_timeout_s:
                job, s = self._inflight
                self._to(FAILED, f"slice {s} of {job!r} still stalled "
                                 f"{stale:.2f}s after suspect")
            return self.state

    @property
    def reason(self) -> str:
        return self.transitions[-1][3] if self.transitions else ""

    def _to(self, state: str, why: str) -> None:
        # caller holds self._lock
        self.transitions.append((time.monotonic(), self.state, state, why))
        self.state = state


@dataclass
class FaultStats:
    failures: int = 0
    restarts: int = 0
    replayed_steps: int = 0
    events: List[str] = field(default_factory=list)


class FaultTolerantLoop:
    """Checkpoint/restart wrapper around a step function.

    state = (params, opt_state, ...) pytree; ``save_every`` controls the
    checkpoint cadence.  On a step exception the state is restored from
    the newest checkpoint and the intervening steps are replayed."""

    def __init__(self, ckpt_dir: str, state: Any, save_every: int = 10,
                 max_restarts: int = 5,
                 shardings: Any = None):
        self.ckpt = checkpointer.AsyncCheckpointer(ckpt_dir)
        self.ckpt_dir = ckpt_dir
        self.state = state
        self.save_every = save_every
        self.max_restarts = max_restarts
        self.shardings = shardings
        self.step = 0
        self.stats = FaultStats()
        checkpointer.save(ckpt_dir, 0, state)  # step-0 baseline

    def run_step(self, step_fn: Callable, *args) -> Any:
        """Run one step with restart-on-failure; returns step metrics."""
        for attempt in range(self.max_restarts + 1):
            try:
                self.state, metrics = step_fn(self.state, *args)
                self.step += 1
                if self.step % self.save_every == 0:
                    self.ckpt.save(self.step, self.state)
                return metrics
            except Exception as e:  # noqa: BLE001
                self.stats.failures += 1
                self.stats.events.append(
                    f"step {self.step}: {type(e).__name__}: {e}")
                if attempt == self.max_restarts:
                    raise
                self._restart()
        raise RuntimeError("unreachable")

    def _restart(self) -> None:
        self.ckpt.wait()
        restored_step = checkpointer.latest_step(self.ckpt_dir) or 0
        self.state = checkpointer.restore(
            self.ckpt_dir, self.state, shardings=self.shardings)
        self.stats.restarts += 1
        self.stats.replayed_steps += self.step - restored_step
        self.step = restored_step
