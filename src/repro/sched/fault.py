"""Fault tolerance: heartbeat watchdog, restart-from-checkpoint, and
straggler mitigation for the training loop.

On a real pod, node failure surfaces as a stuck or failed collective; here
the same control flow is driven by exceptions from the step function and by
heartbeat staleness.  The contract: the trainer's step loop is wrapped by
``FaultTolerantLoop.run_step`` — any step failure rolls back to the newest
checkpoint and replays; ``Heartbeat`` detects silent stalls (deadlocked
collectives) and raises in the main loop; chunk-level re-dispatch
(``with_retry``) bounds straggler impact for idempotent device work."""
from __future__ import annotations

import threading
import time
from dataclasses import dataclass, field
from typing import Any, Callable, List, Optional

from . import checkpointer


class StallError(RuntimeError):
    pass


class Heartbeat:
    """Watchdog: the worker beats every step; a monitor thread flags a
    stall when the last beat is older than ``timeout_s``."""

    def __init__(self, timeout_s: float = 30.0):
        self.timeout_s = timeout_s
        self._last = time.monotonic()
        self._stalled = False
        self._stop = threading.Event()
        self._thread = threading.Thread(target=self._watch, daemon=True)
        self._thread.start()

    def beat(self) -> None:
        self._last = time.monotonic()

    def check(self) -> None:
        if self._stalled:
            raise StallError("heartbeat timeout — presumed node failure")

    def _watch(self) -> None:
        while not self._stop.is_set():
            if time.monotonic() - self._last > self.timeout_s:
                self._stalled = True
            time.sleep(min(self.timeout_s / 4, 1.0))

    def stop(self) -> None:
        self._stop.set()


def with_retry(fn: Callable, n_retries: int = 2,
               timeout_s: Optional[float] = None) -> Callable:
    """Straggler mitigation for idempotent device work: re-dispatch on
    failure (the REEF-style reset degenerates to re-running idempotent
    programs, cf. DESIGN.md)."""

    def wrapped(*a, **kw):
        err = None
        for _ in range(n_retries + 1):
            try:
                return fn(*a, **kw)
            except Exception as e:  # noqa: BLE001 — deliberate catch-all
                err = e
        raise err

    return wrapped


@dataclass
class FaultStats:
    failures: int = 0
    restarts: int = 0
    replayed_steps: int = 0
    events: List[str] = field(default_factory=list)


class FaultTolerantLoop:
    """Checkpoint/restart wrapper around a step function.

    state = (params, opt_state, ...) pytree; ``save_every`` controls the
    checkpoint cadence.  On a step exception the state is restored from
    the newest checkpoint and the intervening steps are replayed."""

    def __init__(self, ckpt_dir: str, state: Any, save_every: int = 10,
                 max_restarts: int = 5,
                 shardings: Any = None):
        self.ckpt = checkpointer.AsyncCheckpointer(ckpt_dir)
        self.ckpt_dir = ckpt_dir
        self.state = state
        self.save_every = save_every
        self.max_restarts = max_restarts
        self.shardings = shardings
        self.step = 0
        self.stats = FaultStats()
        checkpointer.save(ckpt_dir, 0, state)  # step-0 baseline

    def run_step(self, step_fn: Callable, *args) -> Any:
        """Run one step with restart-on-failure; returns step metrics."""
        for attempt in range(self.max_restarts + 1):
            try:
                self.state, metrics = step_fn(self.state, *args)
                self.step += 1
                if self.step % self.save_every == 0:
                    self.ckpt.save(self.step, self.state)
                return metrics
            except Exception as e:  # noqa: BLE001
                self.stats.failures += 1
                self.stats.events.append(
                    f"step {self.step}: {type(e).__name__}: {e}")
                if attempt == self.max_restarts:
                    raise
                self._restart()
        raise RuntimeError("unreachable")

    def _restart(self) -> None:
        self.ckpt.wait()
        restored_step = checkpointer.latest_step(self.ckpt_dir) or 0
        self.state = checkpointer.restore(
            self.ckpt_dir, self.state, shardings=self.shardings)
        self.stats.restarts += 1
        self.stats.replayed_steps += self.step - restored_step
        self.step = restored_step
